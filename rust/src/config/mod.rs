//! Configuration system: model shape specs (Qwen2.5-series plus the small
//! real-training presets), parallelization strategy, and the top-level train
//! configuration the launcher consumes (JSON files or CLI flags).

mod model;
mod parallel;
mod train;

pub use model::{ModelSpec, PRESETS};
pub use parallel::{ParallelConfig, RecomputeGranularity};
pub use train::{ChunkFlowParams, TrainConfig};
