//! Parallelization strategy configuration: the paper's `<TP, SP, PP,
//! RecomputeGranularity>` tuples (Table 3) plus data parallelism.

use crate::util::json::Json;

/// Activation recomputation granularity (Megatron terminology).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RecomputeGranularity {
    /// No recomputation: all activations retained for backward.
    None,
    /// Selective: attention score/softmax activations recomputed (cheap,
    /// removes the O(s^2) and large attention buffers).
    Selective,
    /// Full: every layer's activations recomputed from layer-boundary
    /// checkpoints; backward effectively pays an extra forward.
    Full,
}

impl RecomputeGranularity {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "none" => Ok(Self::None),
            "selective" => Ok(Self::Selective),
            "full" => Ok(Self::Full),
            _ => anyhow::bail!("unknown recompute granularity `{s}` (none|selective|full)"),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Self::None => "none",
            Self::Selective => "selective",
            Self::Full => "full",
        }
    }

    /// Extra forward-compute multiplier paid during the backward pass.
    /// (Backward base cost is 2x forward; full recompute adds ~1x more.)
    pub fn backward_extra_fwd(&self) -> f64 {
        match self {
            Self::None => 0.0,
            // Recomputing attention internals is a small slice of total fwd.
            Self::Selective => 0.15,
            Self::Full => 1.0,
        }
    }
}

/// `<TP, SP, PP>` + DP + recompute. SP in the paper's tables always equals
/// TP (Megatron-style sequence parallelism over the TP group), so we keep a
/// single `tp_sp` degree and a flag.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ParallelConfig {
    /// Tensor-parallel degree (== sequence-parallel degree when sp enabled).
    pub tp: u64,
    /// Sequence parallelism enabled (Megatron SP over the TP group).
    pub sp: bool,
    /// Pipeline-parallel degree (number of stages).
    pub pp: u64,
    /// Data-parallel degree.
    pub dp: u64,
    pub recompute: RecomputeGranularity,
}

impl ParallelConfig {
    pub fn new(tp: u64, pp: u64, recompute: RecomputeGranularity) -> Self {
        Self { tp, sp: true, pp, dp: 1, recompute }
    }

    /// Total GPUs this strategy occupies.
    pub fn world_size(&self) -> u64 {
        self.tp * self.pp * self.dp
    }

    /// Format like the paper: `<4,4,4,selective>`.
    pub fn paper_format(&self) -> String {
        format!("<{},{},{},{}>", self.tp, self.tp, self.pp, self.recompute.as_str())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("tp", Json::num(self.tp as f64)),
            ("sp", Json::Bool(self.sp)),
            ("pp", Json::num(self.pp as f64)),
            ("dp", Json::num(self.dp as f64)),
            ("recompute", Json::str(self.recompute.as_str())),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        Ok(Self {
            tp: j.req_u64("tp")?,
            sp: j.opt_bool("sp", true),
            pp: j.req_u64("pp")?,
            dp: j.opt_u64("dp", 1),
            recompute: RecomputeGranularity::parse(j.opt_str("recompute", "selective"))?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn world_size() {
        let mut p = ParallelConfig::new(4, 4, RecomputeGranularity::Selective);
        assert_eq!(p.world_size(), 16);
        p.dp = 2;
        assert_eq!(p.world_size(), 32);
    }

    #[test]
    fn paper_format_matches_table3() {
        let p = ParallelConfig::new(4, 4, RecomputeGranularity::Full);
        assert_eq!(p.paper_format(), "<4,4,4,full>");
        let p = ParallelConfig::new(8, 4, RecomputeGranularity::Selective);
        assert_eq!(p.paper_format(), "<8,8,4,selective>");
    }

    #[test]
    fn recompute_parse_roundtrip() {
        for g in [
            RecomputeGranularity::None,
            RecomputeGranularity::Selective,
            RecomputeGranularity::Full,
        ] {
            assert_eq!(RecomputeGranularity::parse(g.as_str()).unwrap(), g);
        }
        assert!(RecomputeGranularity::parse("partial").is_err());
    }

    #[test]
    fn recompute_cost_ordering() {
        assert!(
            RecomputeGranularity::None.backward_extra_fwd()
                < RecomputeGranularity::Selective.backward_extra_fwd()
        );
        assert!(
            RecomputeGranularity::Selective.backward_extra_fwd()
                < RecomputeGranularity::Full.backward_extra_fwd()
        );
    }

    #[test]
    fn json_roundtrip() {
        let p = ParallelConfig { tp: 8, sp: true, pp: 4, dp: 2, recompute: RecomputeGranularity::Full };
        assert_eq!(ParallelConfig::from_json(&p.to_json()).unwrap(), p);
    }
}
