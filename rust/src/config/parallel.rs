//! Parallelization strategy configuration: the paper's `<TP, SP, PP,
//! RecomputeGranularity>` tuples (Table 3) plus data parallelism.

use crate::util::json::Json;

/// Activation recomputation granularity (Megatron terminology).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RecomputeGranularity {
    /// No recomputation: all activations retained for backward.
    None,
    /// Selective: attention score/softmax activations recomputed (cheap,
    /// removes the O(s^2) and large attention buffers).
    Selective,
    /// Full: every layer's activations recomputed from layer-boundary
    /// checkpoints; backward effectively pays an extra forward.
    Full,
}

impl RecomputeGranularity {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "none" => Ok(Self::None),
            "selective" => Ok(Self::Selective),
            "full" => Ok(Self::Full),
            _ => anyhow::bail!("unknown recompute granularity `{s}` (none|selective|full)"),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Self::None => "none",
            Self::Selective => "selective",
            Self::Full => "full",
        }
    }

    /// Extra forward-compute multiplier paid during the backward pass.
    /// (Backward base cost is 2x forward; full recompute adds ~1x more.)
    pub fn backward_extra_fwd(&self) -> f64 {
        match self {
            Self::None => 0.0,
            // Recomputing attention internals is a small slice of total fwd.
            Self::Selective => 0.15,
            Self::Full => 1.0,
        }
    }
}

/// `<TP, SP, PP>` + DP + recompute.
///
/// `sp` is the chunk-aware sequence-parallel degree: the number of ranks a
/// *long* (dependent) chunk's query rows are ring-sharded across. It is an
/// independent axis (`sp = 1` means off), unlike Megatron-style SP, which
/// is glued to the TP group and adds no ranks — the paper's Table-3 tuples
/// print `SP == TP` for exactly that reason, and our cost/memory models
/// already fold that flavor into the `/tp` terms.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ParallelConfig {
    /// Tensor-parallel degree.
    pub tp: u64,
    /// Chunk-aware sequence-parallel degree (ring shards per long chunk;
    /// 1 = off). Short/standalone chunks never shard — see [`Self::sp_shards`].
    pub sp: u64,
    /// Pipeline-parallel degree (number of stages).
    pub pp: u64,
    /// Data-parallel degree.
    pub dp: u64,
    pub recompute: RecomputeGranularity,
}

impl ParallelConfig {
    pub fn new(tp: u64, pp: u64, recompute: RecomputeGranularity) -> Self {
        Self { tp, sp: 1, pp, dp: 1, recompute }
    }

    /// Total GPUs this strategy occupies. Ring SP shards a chunk across
    /// `sp` additional ranks, so the degree multiplies the world size.
    pub fn world_size(&self) -> u64 {
        self.tp * self.sp.max(1) * self.pp * self.dp
    }

    /// Ring shards a chunk of `tokens` query rows splits into: dependent
    /// (long-sequence) chunks shard `sp` ways, capped by the row count;
    /// standalone (short) chunks stay whole — the per-chunk heterogeneity
    /// FlexSP exploits. This single rule is shared by the cost model, the
    /// memory model, the simulator, and the trainer, so they can never
    /// disagree about which chunks shard.
    pub fn sp_shards(&self, dependent: bool, tokens: u64) -> u64 {
        if dependent {
            self.sp.max(1).min(tokens.max(1))
        } else {
            1
        }
    }

    /// Format like the paper: `<4,4,4,selective>`. The SP slot is the
    /// actual sequence-parallel degree (1 when off) — it used to echo `tp`
    /// unconditionally, silently claiming Megatron SP on configs that never
    /// enabled any sequence parallelism.
    pub fn paper_format(&self) -> String {
        format!("<{},{},{},{}>", self.tp, self.sp.max(1), self.pp, self.recompute.as_str())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("tp", Json::num(self.tp as f64)),
            ("sp", Json::num(self.sp as f64)),
            ("pp", Json::num(self.pp as f64)),
            ("dp", Json::num(self.dp as f64)),
            ("recompute", Json::str(self.recompute.as_str())),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        // Back-compat: `sp` used to be a bool glued to the TP group
        // (degree-free); either legacy value maps to "no chunk-aware SP".
        let sp = match j.get("sp") {
            Some(Json::Bool(_)) | None => 1,
            Some(v) => v
                .as_f64()
                .map(|x| x as u64)
                .ok_or_else(|| anyhow::anyhow!("`sp` must be a number (or legacy bool)"))?,
        };
        Ok(Self {
            tp: j.req_u64("tp")?,
            sp: sp.max(1),
            pp: j.req_u64("pp")?,
            dp: j.opt_u64("dp", 1),
            recompute: RecomputeGranularity::parse(j.opt_str("recompute", "selective"))?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn world_size() {
        let mut p = ParallelConfig::new(4, 4, RecomputeGranularity::Selective);
        assert_eq!(p.world_size(), 16);
        p.dp = 2;
        assert_eq!(p.world_size(), 32);
        p.sp = 4;
        assert_eq!(p.world_size(), 128, "ring SP ranks multiply the world");
    }

    #[test]
    fn paper_format_prints_actual_sp_degree() {
        // Re-pinned for the SP-slot fix: the second slot is the real SP
        // degree, not an echo of TP. Chunk-aware SP off prints 1.
        let p = ParallelConfig::new(4, 4, RecomputeGranularity::Full);
        assert_eq!(p.paper_format(), "<4,1,4,full>");
        let mut p = ParallelConfig::new(8, 4, RecomputeGranularity::Selective);
        assert_eq!(p.paper_format(), "<8,1,4,selective>");
        p.sp = 4;
        assert_eq!(p.paper_format(), "<8,4,4,selective>");
    }

    #[test]
    fn sp_shards_rule() {
        let mut p = ParallelConfig::new(1, 1, RecomputeGranularity::Selective);
        p.sp = 4;
        assert_eq!(p.sp_shards(true, 8192), 4, "long chunks shard sp ways");
        assert_eq!(p.sp_shards(false, 8192), 1, "short chunks stay whole");
        assert_eq!(p.sp_shards(true, 3), 3, "shards never exceed query rows");
        p.sp = 1;
        assert_eq!(p.sp_shards(true, 8192), 1, "sp=1 is a no-op");
    }

    #[test]
    fn recompute_parse_roundtrip() {
        for g in [
            RecomputeGranularity::None,
            RecomputeGranularity::Selective,
            RecomputeGranularity::Full,
        ] {
            assert_eq!(RecomputeGranularity::parse(g.as_str()).unwrap(), g);
        }
        assert!(RecomputeGranularity::parse("partial").is_err());
    }

    #[test]
    fn recompute_cost_ordering() {
        assert!(
            RecomputeGranularity::None.backward_extra_fwd()
                < RecomputeGranularity::Selective.backward_extra_fwd()
        );
        assert!(
            RecomputeGranularity::Selective.backward_extra_fwd()
                < RecomputeGranularity::Full.backward_extra_fwd()
        );
    }

    #[test]
    fn json_roundtrip() {
        let p = ParallelConfig { tp: 8, sp: 4, pp: 4, dp: 2, recompute: RecomputeGranularity::Full };
        assert_eq!(ParallelConfig::from_json(&p.to_json()).unwrap(), p);
    }

    #[test]
    fn json_accepts_legacy_bool_sp() {
        // Pre-degree artifacts/checkpoints serialized `sp` as a bool; both
        // legacy values mean "no chunk-aware SP" (degree 1).
        for legacy in ["true", "false"] {
            let j = Json::parse(&format!(
                r#"{{"tp": 4, "sp": {legacy}, "pp": 2, "dp": 1, "recompute": "selective"}}"#
            ))
            .unwrap();
            let p = ParallelConfig::from_json(&j).unwrap();
            assert_eq!(p.sp, 1);
            assert_eq!(p.tp, 4);
        }
    }
}
