//! Top-level training configuration consumed by the launcher and the real
//! trainer, loadable from JSON or assembled from CLI flags.

use super::{ModelSpec, ParallelConfig, RecomputeGranularity};
use crate::util::json::Json;

/// ChunkFlow's two tunables (paper §5): the chunk length limit and the
/// number of chunks whose activations the scheduler may retain.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChunkFlowParams {
    pub chunk_size: u64,
    pub k: u64,
}

impl ChunkFlowParams {
    pub fn new(chunk_size: u64, k: u64) -> Self {
        assert!(chunk_size > 0 && k > 0);
        Self { chunk_size, k }
    }

    /// Format like the paper's Table 4: `(8K, 16)`.
    pub fn paper_format(&self) -> String {
        format!("({}, {})", crate::util::format_tokens(self.chunk_size), self.k)
    }
}

/// Everything a training run needs.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub model: ModelSpec,
    pub parallel: ParallelConfig,
    pub chunkflow: ChunkFlowParams,
    /// Max sequence length admitted from the dataset (context length).
    pub context_length: u64,
    /// Sequences per optimizer step across all DP ranks.
    pub global_batch_size: u64,
    /// Sequences per micro-step (baseline path; ChunkFlow packs chunks).
    pub micro_batch_size: u64,
    pub steps: u64,
    pub seed: u64,
    pub lr: f64,
    pub adam_beta1: f64,
    pub adam_beta2: f64,
    pub adam_eps: f64,
    pub weight_decay: f64,
    pub grad_clip: f64,
    /// Directory of AOT artifacts for the real trainer.
    pub artifacts_dir: String,
}

impl TrainConfig {
    /// Defaults for the small real-training path.
    pub fn default_for(model: ModelSpec) -> Self {
        Self {
            model,
            parallel: ParallelConfig::new(1, 1, RecomputeGranularity::Selective),
            chunkflow: ChunkFlowParams::new(512, 1),
            context_length: 2048,
            global_batch_size: 8,
            micro_batch_size: 1,
            steps: 100,
            seed: 1234,
            lr: 3e-4,
            adam_beta1: 0.9,
            adam_beta2: 0.95,
            adam_eps: 1e-8,
            weight_decay: 0.0,
            grad_clip: 1.0,
            artifacts_dir: "artifacts".into(),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", self.model.to_json()),
            ("parallel", self.parallel.to_json()),
            ("chunk_size", Json::num(self.chunkflow.chunk_size as f64)),
            ("k", Json::num(self.chunkflow.k as f64)),
            ("context_length", Json::num(self.context_length as f64)),
            ("global_batch_size", Json::num(self.global_batch_size as f64)),
            ("micro_batch_size", Json::num(self.micro_batch_size as f64)),
            ("steps", Json::num(self.steps as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("lr", Json::num(self.lr)),
            ("adam_beta1", Json::num(self.adam_beta1)),
            ("adam_beta2", Json::num(self.adam_beta2)),
            ("adam_eps", Json::num(self.adam_eps)),
            ("weight_decay", Json::num(self.weight_decay)),
            ("grad_clip", Json::num(self.grad_clip)),
            ("artifacts_dir", Json::str(self.artifacts_dir.clone())),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let model = ModelSpec::from_json(
            j.get("model").ok_or_else(|| anyhow::anyhow!("missing `model`"))?,
        )?;
        let parallel = match j.get("parallel") {
            Some(p) => ParallelConfig::from_json(p)?,
            None => ParallelConfig::new(1, 1, RecomputeGranularity::Selective),
        };
        let defaults = TrainConfig::default_for(model.clone());
        Ok(Self {
            model,
            parallel,
            chunkflow: ChunkFlowParams::new(
                j.opt_u64("chunk_size", defaults.chunkflow.chunk_size),
                j.opt_u64("k", defaults.chunkflow.k),
            ),
            context_length: j.opt_u64("context_length", defaults.context_length),
            global_batch_size: j.opt_u64("global_batch_size", defaults.global_batch_size),
            micro_batch_size: j.opt_u64("micro_batch_size", defaults.micro_batch_size),
            steps: j.opt_u64("steps", defaults.steps),
            seed: j.opt_u64("seed", defaults.seed),
            lr: j.opt_f64("lr", defaults.lr),
            adam_beta1: j.opt_f64("adam_beta1", defaults.adam_beta1),
            adam_beta2: j.opt_f64("adam_beta2", defaults.adam_beta2),
            adam_eps: j.opt_f64("adam_eps", defaults.adam_eps),
            weight_decay: j.opt_f64("weight_decay", defaults.weight_decay),
            grad_clip: j.opt_f64("grad_clip", defaults.grad_clip),
            artifacts_dir: j.opt_str("artifacts_dir", &defaults.artifacts_dir).to_string(),
        })
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<Self> {
        Self::from_json(&Json::parse_file(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunkflow_params_format() {
        assert_eq!(ChunkFlowParams::new(8 * 1024, 16).paper_format(), "(8K, 16)");
        assert_eq!(ChunkFlowParams::new(32 * 1024, 1).paper_format(), "(32K, 1)");
    }

    #[test]
    #[should_panic]
    fn zero_chunk_size_rejected() {
        ChunkFlowParams::new(0, 1);
    }

    #[test]
    fn json_roundtrip() {
        let mut cfg = TrainConfig::default_for(ModelSpec::preset("tiny").unwrap());
        cfg.chunkflow = ChunkFlowParams::new(1024, 2);
        cfg.steps = 7;
        cfg.lr = 1e-3;
        let j = cfg.to_json();
        let back = TrainConfig::from_json(&j).unwrap();
        assert_eq!(back.chunkflow, cfg.chunkflow);
        assert_eq!(back.steps, 7);
        assert_eq!(back.lr, 1e-3);
        assert_eq!(back.model, cfg.model);
    }

    #[test]
    fn defaults_fill_missing_fields() {
        let j = Json::parse(
            r#"{"model": {"name":"t","hidden_size":64,"num_layers":1,"num_heads":2,
                "num_kv_heads":2,"intermediate_size":128,"vocab_size":256}}"#,
        )
        .unwrap();
        let cfg = TrainConfig::from_json(&j).unwrap();
        assert_eq!(cfg.chunkflow.k, 1);
        assert_eq!(cfg.parallel.pp, 1);
    }

    #[test]
    fn load_from_file() {
        let dir = std::env::temp_dir().join("chunkflow_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cfg.json");
        let cfg = TrainConfig::default_for(ModelSpec::preset("tiny").unwrap());
        cfg.to_json().write_file(&path).unwrap();
        let loaded = TrainConfig::load(&path).unwrap();
        assert_eq!(loaded.model.name, "tiny");
    }
}
