//! Transformer model shape specifications.
//!
//! The analytic memory/compute models (`memory`, `sim`) consume these shape
//! parameters; the real trainer uses the small presets whose artifacts are
//! produced by `python/compile/aot.py`. The Qwen2.5-series entries follow
//! the published architecture configs (Qwen2.5 technical report): GQA
//! attention, SwiGLU MLP, tied/untied embeddings as released.

use crate::util::json::Json;

/// Shape of a decoder-only transformer.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelSpec {
    pub name: String,
    pub hidden_size: u64,
    pub num_layers: u64,
    pub num_heads: u64,
    /// Key/value heads (GQA); equals `num_heads` for MHA.
    pub num_kv_heads: u64,
    /// MLP intermediate size (SwiGLU has 3 such matrices).
    pub intermediate_size: u64,
    pub vocab_size: u64,
    /// Whether input/output embeddings share weights.
    pub tie_embeddings: bool,
}

impl ModelSpec {
    pub fn head_dim(&self) -> u64 {
        self.hidden_size / self.num_heads
    }

    /// Parameters of ONE decoder layer: attention + SwiGLU MLP + the
    /// layer's two RMSNorms. `param_count` is exactly
    /// `layer_param_count * L + final_norm + embed (+ lm_head)`.
    pub fn layer_param_count(&self) -> u64 {
        let h = self.hidden_size;
        let kv = self.num_kv_heads * self.head_dim();
        // Attention: Q (h*h) + K,V (h*kv each) + O (h*h); Qwen uses QKV bias.
        let attn = h * h + 2 * h * kv + h * h + (h + 2 * kv);
        // SwiGLU MLP: gate + up (h*i each) + down (i*h).
        let mlp = 3 * h * self.intermediate_size;
        attn + mlp + 2 * h
    }

    /// Total parameter count.
    pub fn param_count(&self) -> u64 {
        // Per-layer blocks (incl. the two per-layer norms) plus final norm.
        let norms_final = self.hidden_size;
        let embed = self.vocab_size * self.hidden_size;
        let lm_head =
            if self.tie_embeddings { 0 } else { self.vocab_size * self.hidden_size };
        self.layer_param_count() * self.num_layers + norms_final + embed + lm_head
    }

    /// Bytes of one token's KV cache across all layers (bf16 = 2 bytes).
    pub fn kv_bytes_per_token(&self) -> u64 {
        // K and V, per layer: num_kv_heads * head_dim each.
        2 * self.num_kv_heads * self.head_dim() * self.num_layers * 2
    }

    /// Forward FLOPs for `tokens` new tokens attending to a context that
    /// ends at `ctx_end` tokens (ctx_end >= tokens). Standard 2*P*T matmul
    /// term plus the attention score/value term which is quadratic in
    /// context. Backward is ~2x this (see `sim::cost`).
    pub fn fwd_flops(&self, tokens: u64, ctx_end: u64) -> f64 {
        let dense = 2.0 * self.param_count() as f64 * tokens as f64;
        // Attention scores + weighted values: 2 * 2 * T * ctx_avg * h per layer.
        let ctx_avg = (ctx_end as f64 + (ctx_end - tokens) as f64) / 2.0;
        let attn =
            4.0 * tokens as f64 * ctx_avg * self.hidden_size as f64 * self.num_layers as f64;
        dense + attn
    }

    /// Forward FLOPs of ONE decoder layer (its dense matmuls plus its share
    /// of the causal-attention term) — the per-stage building block of the
    /// elastic-partition cost model (`sim::cost::partition_stage_costs`).
    pub fn layer_fwd_flops(&self, tokens: u64, ctx_end: u64) -> f64 {
        let dense = 2.0 * self.layer_param_count() as f64 * tokens as f64;
        let ctx_avg = (ctx_end as f64 + (ctx_end - tokens) as f64) / 2.0;
        dense + 4.0 * tokens as f64 * ctx_avg * self.hidden_size as f64
    }

    /// Forward FLOPs of the LM-head matmul ([T, h] × [h, V]) the LAST
    /// pipeline stage pays on top of its layers — the head side of the
    /// embed/head stage asymmetry (the embedding lookup is a gather, ~0
    /// FLOPs, so stage 0 carries no analogous surcharge). Counted whether
    /// or not the head weights are tied: tying shares parameters, not
    /// compute.
    pub fn head_fwd_flops(&self, tokens: u64) -> f64 {
        2.0 * self.vocab_size as f64 * self.hidden_size as f64 * tokens as f64
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("hidden_size", Json::num(self.hidden_size as f64)),
            ("num_layers", Json::num(self.num_layers as f64)),
            ("num_heads", Json::num(self.num_heads as f64)),
            ("num_kv_heads", Json::num(self.num_kv_heads as f64)),
            ("intermediate_size", Json::num(self.intermediate_size as f64)),
            ("vocab_size", Json::num(self.vocab_size as f64)),
            ("tie_embeddings", Json::Bool(self.tie_embeddings)),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<ModelSpec> {
        Ok(ModelSpec {
            name: j.req_str("name")?.to_string(),
            hidden_size: j.req_u64("hidden_size")?,
            num_layers: j.req_u64("num_layers")?,
            num_heads: j.req_u64("num_heads")?,
            num_kv_heads: j.req_u64("num_kv_heads")?,
            intermediate_size: j.req_u64("intermediate_size")?,
            vocab_size: j.req_u64("vocab_size")?,
            tie_embeddings: j.opt_bool("tie_embeddings", false),
        })
    }

    /// Look up a preset by name (see [`PRESETS`]).
    pub fn preset(name: &str) -> anyhow::Result<ModelSpec> {
        PRESETS
            .iter()
            .find(|(n, _)| *n == name)
            .map(|(_, f)| f())
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown model preset `{name}` (have: {})",
                    PRESETS.iter().map(|(n, _)| *n).collect::<Vec<_>>().join(", ")
                )
            })
    }
}

/// Known model presets. Qwen2.5 shapes per the technical report; `tiny` and
/// `gpt-100m` are the real-trainer presets whose AOT artifacts exist.
pub const PRESETS: &[(&str, fn() -> ModelSpec)] = &[
    ("qwen2.5-7b", qwen7b),
    ("qwen2.5-14b", qwen14b),
    ("qwen2.5-32b", qwen32b),
    ("qwen2.5-72b", qwen72b),
    ("gpt-100m", gpt100m),
    ("tiny", tiny),
];

fn qwen7b() -> ModelSpec {
    ModelSpec {
        name: "qwen2.5-7b".into(),
        hidden_size: 3584,
        num_layers: 28,
        num_heads: 28,
        num_kv_heads: 4,
        intermediate_size: 18944,
        vocab_size: 152064,
        tie_embeddings: false,
    }
}

fn qwen14b() -> ModelSpec {
    ModelSpec {
        name: "qwen2.5-14b".into(),
        hidden_size: 5120,
        num_layers: 48,
        num_heads: 40,
        num_kv_heads: 8,
        intermediate_size: 13824,
        vocab_size: 152064,
        tie_embeddings: false,
    }
}

fn qwen32b() -> ModelSpec {
    ModelSpec {
        name: "qwen2.5-32b".into(),
        hidden_size: 5120,
        num_layers: 64,
        num_heads: 40,
        num_kv_heads: 8,
        intermediate_size: 27648,
        vocab_size: 152064,
        tie_embeddings: false,
    }
}

fn qwen72b() -> ModelSpec {
    ModelSpec {
        name: "qwen2.5-72b".into(),
        hidden_size: 8192,
        num_layers: 80,
        num_heads: 64,
        num_kv_heads: 8,
        intermediate_size: 29568,
        vocab_size: 152064,
        tie_embeddings: false,
    }
}

/// ~100M-parameter byte-level GPT used for the real end-to-end training run
/// (examples/train_e2e.rs). Must stay in sync with python/compile/model.py.
fn gpt100m() -> ModelSpec {
    ModelSpec {
        name: "gpt-100m".into(),
        hidden_size: 768,
        num_layers: 12,
        num_heads: 12,
        num_kv_heads: 12,
        intermediate_size: 2048,
        vocab_size: 512,
        tie_embeddings: true,
    }
}

/// Minutes-scale preset for tests and the quickstart example.
fn tiny() -> ModelSpec {
    ModelSpec {
        name: "tiny".into(),
        hidden_size: 128,
        num_layers: 2,
        num_heads: 4,
        num_kv_heads: 4,
        intermediate_size: 384,
        vocab_size: 512,
        tie_embeddings: true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qwen_param_counts_near_nominal() {
        // Within 15% of nameplate size (nameplate rounds aggressively).
        let cases = [
            ("qwen2.5-7b", 7.6e9),
            ("qwen2.5-14b", 14.7e9),
            ("qwen2.5-32b", 32.5e9),
            ("qwen2.5-72b", 72.7e9),
        ];
        for (name, nominal) in cases {
            let p = ModelSpec::preset(name).unwrap().param_count() as f64;
            let rel = (p - nominal).abs() / nominal;
            assert!(rel < 0.15, "{name}: {p:.3e} vs nominal {nominal:.3e} (rel {rel:.2})");
        }
    }

    #[test]
    fn gpt100m_is_about_100m() {
        let p = ModelSpec::preset("gpt-100m").unwrap().param_count() as f64;
        assert!((8.0e7..1.3e8).contains(&p), "gpt-100m has {p:.3e} params");
    }

    #[test]
    fn head_dim_divides() {
        for (name, f) in PRESETS {
            let m = f();
            assert_eq!(m.hidden_size % m.num_heads, 0, "{name}");
            assert_eq!(m.num_heads % m.num_kv_heads, 0, "{name}");
        }
    }

    #[test]
    fn kv_bytes_per_token_7b() {
        let m = ModelSpec::preset("qwen2.5-7b").unwrap();
        // 4 kv heads * 128 head_dim * 2 (K+V) * 28 layers * 2 bytes = 57344.
        assert_eq!(m.kv_bytes_per_token(), 57344);
    }

    #[test]
    fn flops_monotone_in_context() {
        let m = ModelSpec::preset("qwen2.5-7b").unwrap();
        let near = m.fwd_flops(1024, 1024);
        let far = m.fwd_flops(1024, 128 * 1024);
        assert!(far > near);
    }

    #[test]
    fn json_roundtrip() {
        let m = ModelSpec::preset("qwen2.5-14b").unwrap();
        let j = m.to_json();
        assert_eq!(ModelSpec::from_json(&j).unwrap(), m);
    }

    #[test]
    fn unknown_preset_is_error() {
        assert!(ModelSpec::preset("nope").is_err());
    }
}
