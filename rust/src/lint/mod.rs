//! Determinism lint — `chunkflow lint-src`.
//!
//! The repo's standing contracts (bit-identical lattices, byte-diffed
//! `BENCH_chunkflow.json`, serial-vs-parallel sweep identity) die quietly
//! the moment a nondeterminism source slips into a committed-artifact path.
//! This is a token-level scanner over `rust/src/**` that flags the four
//! hazard classes that have actually bitten projects like this one:
//!
//! | rule id            | hazard                                              |
//! |--------------------|-----------------------------------------------------|
//! | `map-iteration`    | `HashMap`/`HashSet` (iteration order is seeded per   |
//! |                    | process; use `BTreeMap`/`BTreeSet`)                 |
//! | `float-sort-unwrap`| `partial_cmp(..).unwrap()` on float sort keys       |
//! |                    | (panics on NaN mid-sort; use `total_cmp`)           |
//! | `wall-clock`       | `Instant::now`/`SystemTime` outside the timing      |
//! |                    | utilities and probes                                |
//! | `unseeded-rng`     | entropy-seeded RNG construction                     |
//!
//! The scanner strips comments, strings and char literals first, so prose
//! mentioning `HashMap` never trips it. Audited exceptions live in
//! `rust/lint-allow.toml`; CI runs the lint so any *new* hazard fails the
//! build while the allowlist documents the old ones. Unused allowlist
//! entries are themselves errors — the list can only shrink.

use std::path::{Path, PathBuf};

pub const RULE_MAP_ITER: &str = "map-iteration";
pub const RULE_FLOAT_SORT: &str = "float-sort-unwrap";
pub const RULE_WALL_CLOCK: &str = "wall-clock";
pub const RULE_UNSEEDED_RNG: &str = "unseeded-rng";

/// Files where wall-clock reads are the *point* (benchmark timing, log
/// timestamps, hardware probes) — allowed without an allowlist entry.
const WALL_CLOCK_FREE: &[&str] = &["util/bench.rs", "util/log.rs", "sweep/probe.rs"];

/// One lint hit.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Finding {
    /// Path relative to the scan root, with `/` separators.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    pub rule: &'static str,
    /// The offending token sequence.
    pub snippet: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.snippet)
    }
}

/// An audited exception from `lint-allow.toml`.
#[derive(Clone, Debug)]
pub struct AllowEntry {
    /// File suffix the entry covers (e.g. `src/train/mod.rs`).
    pub file: String,
    pub rule: String,
    pub reason: String,
}

/// Parse the minimal TOML dialect the allowlist uses: `[[allow]]` tables
/// with `key = "value"` lines. No dependencies, no general TOML.
pub fn parse_allowlist(text: &str) -> anyhow::Result<Vec<AllowEntry>> {
    let mut entries = Vec::new();
    let mut current: Option<(String, String, String)> = None;
    for (i, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        if line == "[[allow]]" {
            if let Some(e) = current.take() {
                entries.push(finish_entry(e, i)?);
            }
            current = Some((String::new(), String::new(), String::new()));
            continue;
        }
        let Some((key, value)) = line.split_once('=') else {
            anyhow::bail!("lint-allow.toml line {}: expected `key = \"value\"`", i + 1);
        };
        let value = value.trim();
        anyhow::ensure!(
            value.len() >= 2 && value.starts_with('"') && value.ends_with('"'),
            "lint-allow.toml line {}: value must be a double-quoted string",
            i + 1
        );
        let value = value[1..value.len() - 1].to_string();
        let Some(entry) = current.as_mut() else {
            anyhow::bail!("lint-allow.toml line {}: key outside an [[allow]] table", i + 1);
        };
        match key.trim() {
            "file" => entry.0 = value,
            "rule" => entry.1 = value,
            "reason" => entry.2 = value,
            other => anyhow::bail!("lint-allow.toml line {}: unknown key `{other}`", i + 1),
        }
    }
    if let Some(e) = current.take() {
        entries.push(finish_entry(e, text.lines().count())?);
    }
    Ok(entries)
}

fn finish_entry(
    (file, rule, reason): (String, String, String),
    line: usize,
) -> anyhow::Result<AllowEntry> {
    anyhow::ensure!(
        !file.is_empty() && !rule.is_empty() && !reason.is_empty(),
        "lint-allow.toml entry ending near line {line}: needs file, rule and reason"
    );
    Ok(AllowEntry { file, rule, reason })
}

/// A source token: identifier text plus its 1-based line.
struct Tok {
    text: String,
    line: usize,
}

/// Strip comments (line + nested block), string literals (plain and raw)
/// and char literals, then collect identifier-ish tokens and the `.`/`(`
/// punctuation the rules need for adjacency checks.
fn tokenize(src: &str) -> Vec<Tok> {
    let b: Vec<char> = src.chars().collect();
    let mut toks = Vec::new();
    let mut i = 0;
    let mut line = 1;
    let n = b.len();
    while i < n {
        let c = b[i];
        if c == '\n' {
            line += 1;
            i += 1;
        } else if c == '/' && i + 1 < n && b[i + 1] == '/' {
            while i < n && b[i] != '\n' {
                i += 1;
            }
        } else if c == '/' && i + 1 < n && b[i + 1] == '*' {
            let mut depth = 1;
            i += 2;
            while i < n && depth > 0 {
                if b[i] == '\n' {
                    line += 1;
                    i += 1;
                } else if b[i] == '/' && i + 1 < n && b[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == '*' && i + 1 < n && b[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
        } else if c == 'r'
            && i + 1 < n
            && (b[i + 1] == '"' || b[i + 1] == '#')
            && !prev_is_ident(&b, i)
        {
            // Raw string r"..." / r#"..."# (any number of #).
            let mut j = i + 1;
            let mut hashes = 0;
            while j < n && b[j] == '#' {
                hashes += 1;
                j += 1;
            }
            if j < n && b[j] == '"' {
                j += 1;
                'raw: while j < n {
                    if b[j] == '\n' {
                        line += 1;
                    }
                    if b[j] == '"' {
                        let mut k = 0;
                        while k < hashes && j + 1 + k < n && b[j + 1 + k] == '#' {
                            k += 1;
                        }
                        if k == hashes {
                            j += 1 + hashes;
                            break 'raw;
                        }
                    }
                    j += 1;
                }
                i = j;
            } else {
                // `r` was just an identifier start (e.g. `rf`).
                let start = i;
                while i < n && (b[i].is_alphanumeric() || b[i] == '_') {
                    i += 1;
                }
                toks.push(Tok { text: b[start..i].iter().collect(), line });
            }
        } else if c == '"' {
            i += 1;
            while i < n {
                if b[i] == '\\' {
                    i += 2;
                } else if b[i] == '"' {
                    i += 1;
                    break;
                } else {
                    if b[i] == '\n' {
                        line += 1;
                    }
                    i += 1;
                }
            }
        } else if c == '\'' {
            // Char literal or lifetime. A lifetime is `'` + ident not
            // followed by a closing quote.
            if i + 1 < n && b[i + 1] == '\\' {
                i += 2;
                while i < n && b[i] != '\'' {
                    i += 1;
                }
                i += 1;
            } else if i + 2 < n && b[i + 2] == '\'' {
                i += 3;
            } else {
                // Lifetime: skip the quote, let the ident tokenize (it
                // cannot collide with any rule pattern).
                i += 1;
            }
        } else if c.is_alphabetic() || c == '_' {
            let start = i;
            while i < n && (b[i].is_alphanumeric() || b[i] == '_') {
                i += 1;
            }
            toks.push(Tok { text: b[start..i].iter().collect(), line });
        } else if c == '.' || c == '(' || c == ':' {
            toks.push(Tok { text: c.to_string(), line });
            i += 1;
        } else {
            if c == ';' {
                toks.push(Tok { text: ";".to_string(), line });
            }
            i += 1;
        }
    }
    toks
}

fn prev_is_ident(b: &[char], i: usize) -> bool {
    i > 0 && (b[i - 1].is_alphanumeric() || b[i - 1] == '_')
}

/// Scan one file's source text. `rel` is the path relative to the scan
/// root (used for the wall-clock default allowance).
pub fn scan_source(rel: &str, src: &str) -> Vec<Finding> {
    let toks = tokenize(src);
    let mut findings = Vec::new();
    let wall_clock_free = WALL_CLOCK_FREE.iter().any(|f| rel.ends_with(f));
    let push = |out: &mut Vec<Finding>, line: usize, rule: &'static str, snippet: &str| {
        out.push(Finding { file: rel.to_string(), line, rule, snippet: snippet.to_string() });
    };
    for (idx, tok) in toks.iter().enumerate() {
        match tok.text.as_str() {
            "HashMap" | "HashSet" => {
                push(&mut findings, tok.line, RULE_MAP_ITER, &tok.text);
            }
            "partial_cmp" => {
                // `partial_cmp` ... `unwrap`/`expect` before the next `;`
                // is the NaN-panicking comparator idiom.
                for next in &toks[idx + 1..] {
                    match next.text.as_str() {
                        ";" => break,
                        "unwrap" | "expect" => {
                            push(
                                &mut findings,
                                tok.line,
                                RULE_FLOAT_SORT,
                                "partial_cmp(..).unwrap()",
                            );
                            break;
                        }
                        _ => {}
                    }
                }
            }
            "Instant" | "SystemTime" if !wall_clock_free => {
                // `Instant::now(` / `SystemTime::now(` (or any SystemTime
                // read — `SystemTime` only appears to read wall time).
                let is_now = toks[idx + 1..]
                    .iter()
                    .take(3)
                    .any(|t| t.text == "now");
                if tok.text == "SystemTime" || is_now {
                    push(
                        &mut findings,
                        tok.line,
                        RULE_WALL_CLOCK,
                        &format!("{}::now()", tok.text),
                    );
                }
            }
            "thread_rng" | "from_entropy" | "from_os_rng" | "OsRng" => {
                push(&mut findings, tok.line, RULE_UNSEEDED_RNG, &tok.text);
            }
            _ => {}
        }
    }
    findings
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> anyhow::Result<()> {
    let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", dir.display()))?
        .collect::<Result<Vec<_>, _>>()?
        .into_iter()
        .map(|e| e.path())
        .collect();
    entries.sort();
    for path in entries {
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Outcome of a lint run: surviving findings plus allowlist accounting.
#[derive(Debug)]
pub struct LintReport {
    /// Findings not covered by the allowlist — these fail the build.
    pub findings: Vec<Finding>,
    /// Findings suppressed by an allowlist entry.
    pub allowed: Vec<(Finding, String)>,
    /// Allowlist entries that matched nothing — also fail the build.
    pub unused_allows: Vec<AllowEntry>,
    pub files_scanned: usize,
}

impl LintReport {
    pub fn is_clean(&self) -> bool {
        self.findings.is_empty() && self.unused_allows.is_empty()
    }
}

/// Scan every `.rs` file under `root` and apply the allowlist.
pub fn lint_tree(root: &Path, allowlist: &[AllowEntry]) -> anyhow::Result<LintReport> {
    anyhow::ensure!(root.is_dir(), "lint root {} is not a directory", root.display());
    let mut files = Vec::new();
    collect_rs_files(root, &mut files)?;
    let mut findings = Vec::new();
    let mut allowed = Vec::new();
    let mut used = vec![false; allowlist.len()];
    for path in &files {
        let src = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        for f in scan_source(&rel, &src) {
            // Allowlist entries name paths like `src/train/mod.rs`; match
            // by suffix against the scan-relative path.
            let hit = allowlist.iter().enumerate().find(|(_, a)| {
                a.rule == f.rule && (a.file.ends_with(&f.file) || f.file.ends_with(&a.file))
            });
            match hit {
                Some((i, a)) => {
                    used[i] = true;
                    allowed.push((f, a.reason.clone()));
                }
                None => findings.push(f),
            }
        }
    }
    let unused_allows = allowlist
        .iter()
        .zip(&used)
        .filter(|(_, &u)| !u)
        .map(|(a, _)| a.clone())
        .collect();
    Ok(LintReport { findings, allowed, unused_allows, files_scanned: files.len() })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flags_hash_map_and_set() {
        let src = "use std::collections::HashMap;\nfn f() { let s: HashSet<u32> = Default::default(); }\n";
        let f = scan_source("src/x.rs", src);
        assert_eq!(f.len(), 2);
        assert_eq!(f[0].rule, RULE_MAP_ITER);
        assert_eq!(f[0].line, 1);
        assert_eq!(f[1].line, 2);
    }

    #[test]
    fn ignores_hazards_in_comments_and_strings() {
        let src = "// HashMap iteration order would be bad here.\n\
                   /* SystemTime::now() in a /* nested */ block comment */\n\
                   fn f() -> &'static str { \"HashMap Instant::now() thread_rng\" }\n\
                   const R: &str = r#\"partial_cmp(a).unwrap()\"#;\n";
        assert!(scan_source("src/x.rs", src).is_empty());
    }

    #[test]
    fn flags_partial_cmp_unwrap_but_not_total_cmp() {
        let bad = "v.sort_by(|a, b| a.partial_cmp(b).unwrap());";
        let f = scan_source("src/x.rs", bad);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, RULE_FLOAT_SORT);
        let good = "v.sort_by(|a, b| a.total_cmp(b));\nlet c = a.partial_cmp(&b);\n";
        assert!(scan_source("src/x.rs", good).is_empty());
    }

    #[test]
    fn flags_wall_clock_outside_timing_utils() {
        let src = "let t = std::time::Instant::now();\nlet s = SystemTime::now();";
        let f = scan_source("src/sim/mod.rs", src);
        assert_eq!(f.len(), 2);
        assert!(f.iter().all(|x| x.rule == RULE_WALL_CLOCK));
        // The same source inside the timing utilities is fine.
        assert!(scan_source("src/util/bench.rs", src).is_empty());
        assert!(scan_source("src/sweep/probe.rs", src).is_empty());
        // `Instant` as a type name alone (no ::now) is fine.
        assert!(scan_source("src/x.rs", "fn f(t: Instant) -> Instant { t }").is_empty());
    }

    #[test]
    fn flags_unseeded_rng() {
        let src = "let mut r = rand::thread_rng();\nlet g = SmallRng::from_entropy();\nlet o = OsRng;";
        let f = scan_source("src/x.rs", src);
        assert_eq!(f.len(), 3);
        assert!(f.iter().all(|x| x.rule == RULE_UNSEEDED_RNG));
        // Seeded construction is fine.
        assert!(scan_source("src/x.rs", "let r = Rng::new(seed);").is_empty());
    }

    #[test]
    fn allowlist_parses_and_suppresses() {
        let toml = r#"
# audited exceptions
[[allow]]
file = "src/train/mod.rs"    # step timing
rule = "wall-clock"
reason = "operator-facing step timing, never in artifacts"
"#;
        let allows = parse_allowlist(toml).unwrap();
        assert_eq!(allows.len(), 1);
        assert_eq!(allows[0].rule, "wall-clock");
        assert!(allows[0].reason.contains("step timing"));
    }

    #[test]
    fn allowlist_rejects_incomplete_entries() {
        assert!(parse_allowlist("[[allow]]\nfile = \"x.rs\"\n").is_err());
        assert!(parse_allowlist("file = \"x.rs\"\n").is_err());
        assert!(parse_allowlist("[[allow]]\nfile = x.rs\nrule = \"r\"\nreason = \"z\"").is_err());
    }

    #[test]
    fn finding_display_is_greppable() {
        let f = Finding {
            file: "src/x.rs".into(),
            line: 7,
            rule: RULE_MAP_ITER,
            snippet: "HashMap".into(),
        };
        assert_eq!(f.to_string(), "src/x.rs:7: [map-iteration] HashMap");
    }

    #[test]
    fn synthetic_hazard_fixture_fails_and_allowlist_scopes_it() {
        // End-to-end over a temp tree: a hazard fixture must fail the lint,
        // and an allowlist entry for it must flip the run clean while an
        // unrelated entry is reported unused.
        let dir = std::env::temp_dir().join(format!(
            "chunkflow-lint-test-{}",
            std::process::id()
        ));
        let sub = dir.join("deep");
        std::fs::create_dir_all(&sub).unwrap();
        std::fs::write(
            sub.join("hazard.rs"),
            "use std::collections::HashMap;\nfn t() { let _ = std::time::Instant::now(); }\n",
        )
        .unwrap();
        std::fs::write(dir.join("clean.rs"), "fn ok() -> u32 { 1 }\n").unwrap();

        let report = lint_tree(&dir, &[]).unwrap();
        assert_eq!(report.files_scanned, 2);
        assert_eq!(report.findings.len(), 2);
        assert!(!report.is_clean());

        let allows = vec![
            AllowEntry {
                file: "deep/hazard.rs".into(),
                rule: RULE_MAP_ITER.into(),
                reason: "test fixture".into(),
            },
            AllowEntry {
                file: "deep/hazard.rs".into(),
                rule: RULE_WALL_CLOCK.into(),
                reason: "test fixture".into(),
            },
            AllowEntry {
                file: "nonexistent.rs".into(),
                rule: RULE_MAP_ITER.into(),
                reason: "stale".into(),
            },
        ];
        let report = lint_tree(&dir, &allows).unwrap();
        assert!(report.findings.is_empty());
        assert_eq!(report.allowed.len(), 2);
        assert_eq!(report.unused_allows.len(), 1);
        assert!(!report.is_clean(), "unused allowlist entries must fail");

        std::fs::remove_dir_all(&dir).unwrap();
    }
}
