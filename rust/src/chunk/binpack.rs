//! Bin packing — the inner loop of Algorithm 1.
//!
//! The paper's heuristic asks for the minimal number of bins of capacity
//! `ChunkSize` that hold the short sequences. [`binpack_min_bins`] answers
//! it with a *single* unbounded best-fit-decreasing (BFD) pass: sort items
//! once by decreasing weight, keep the open bins in an ordered index keyed
//! on `(remaining capacity, bin index)`, and place each item into the
//! tightest bin that fits, opening a new bin when none does. That is
//! O(n log n) total, and it yields the minimal bin count *reachable by BFD*
//! directly — no sweep over candidate bin counts is needed, because bounded
//! BFD with budget `BinCnt` succeeds if and only if unbounded BFD opens at
//! most `BinCnt` bins, and on success it produces the *same* bins: the
//! budget only ever matters at the moment BFD would open one bin too many.
//! The previous sweep-upward implementation is retained as
//! [`binpack_min_bins_bounded`], a reference oracle; a property test asserts
//! the two produce identical bins, item for item, and the benchmark suite
//! measures the single-pass win.
//!
//! On solution quality this module makes no theorem-level claim: the classic
//! `11/9·OPT + 1` additive bound is *FFD's*, and whether this BFD variant is
//! never worse than first-fit is unproven. What the property tests actually
//! guarantee: every packing is a valid partition within capacity, the bin
//! count never drops below the token-sum lower bound `⌈Σw/cap⌉`, and on
//! random long-tail instances the observed count stays within
//! `11/9·⌈Σw/cap⌉ + 1` — an empirical check against the lower bound, not a
//! proof against OPT.

use std::collections::BTreeSet;

/// Pack `weights` into bins of capacity `cap`, minimizing the bin count
/// reachable by best-fit-decreasing. Returns item-index bins in bin-creation
/// order; items within a bin appear in decreasing-weight (stable) order.
///
/// Single unbounded BFD pass, O(n log n): the open bins live in a
/// [`BTreeSet`] keyed on `(remaining capacity, bin index)`, so the tightest
/// bin that still fits an item of weight `w` is the first element of
/// `range((w, 0)..)` — with the same lowest-index tiebreak among equal
/// remainders as the linear-scan reference, which keeps the output
/// bit-identical to [`binpack_min_bins_bounded`].
pub fn binpack_min_bins(weights: &[u64], cap: u64) -> Vec<Vec<usize>> {
    assert!(weights.iter().all(|&w| w <= cap), "item exceeds capacity");
    if weights.is_empty() {
        return Vec::new();
    }
    let mut order: Vec<usize> = (0..weights.len()).collect();
    // Decreasing weight; stable tiebreak on index for determinism.
    order.sort_by_key(|&i| (std::cmp::Reverse(weights[i]), i));

    let mut bins: Vec<Vec<usize>> = Vec::new();
    let mut by_rem: BTreeSet<(u64, usize)> = BTreeSet::new();
    for &i in &order {
        let w = weights[i];
        // Best fit: the open bin with least remaining space that still fits.
        match by_rem.range((w, 0)..).next().copied() {
            Some((rem, b)) => {
                by_rem.remove(&(rem, b));
                by_rem.insert((rem - w, b));
                bins[b].push(i);
            }
            None => {
                let b = bins.len();
                by_rem.insert((cap - w, b));
                bins.push(vec![i]);
            }
        }
    }
    bins
}

/// Try to pack `weights` into at most `bin_cnt` bins of capacity `cap`
/// using best-fit-decreasing. Returns item-index bins on success.
///
/// O(n·bins) linear-scan best fit — part of the reference oracle kept for
/// tests and benchmarks; production code paths use [`binpack_min_bins`].
pub fn fits_in_bins(weights: &[u64], cap: u64, bin_cnt: usize) -> Option<Vec<Vec<usize>>> {
    assert!(weights.iter().all(|&w| w <= cap), "item exceeds capacity");
    let mut order: Vec<usize> = (0..weights.len()).collect();
    // Decreasing weight; stable tiebreak on index for determinism.
    order.sort_by_key(|&i| (std::cmp::Reverse(weights[i]), i));

    let mut bins: Vec<Vec<usize>> = Vec::new();
    let mut loads: Vec<u64> = Vec::new();
    for &i in &order {
        let w = weights[i];
        // Best fit: the open bin with least remaining space that still fits.
        let mut best: Option<(usize, u64)> = None;
        for (b, &load) in loads.iter().enumerate() {
            if load + w <= cap {
                let rem = cap - load - w;
                if best.map_or(true, |(_, brem)| rem < brem) {
                    best = Some((b, rem));
                }
            }
        }
        match best {
            Some((b, _)) => {
                bins[b].push(i);
                loads[b] += w;
            }
            None => {
                if bins.len() == bin_cnt {
                    return None;
                }
                bins.push(vec![i]);
                loads.push(w);
            }
        }
    }
    Some(bins)
}

/// Reference oracle: pack minimizing bin count by sweeping `BinCnt` upward
/// from the token-sum lower bound (the paper's Algorithm 1, lines 8-10,
/// written literally) and accepting the first count bounded BFD satisfies.
/// O(n²) per attempt, O(n³) worst case. Kept so property tests can assert
/// [`binpack_min_bins`] is bit-identical and benchmarks can measure the
/// single-pass speedup; not used on production paths.
pub fn binpack_min_bins_bounded(weights: &[u64], cap: u64) -> Vec<Vec<usize>> {
    if weights.is_empty() {
        return Vec::new();
    }
    let total: u64 = weights.iter().sum();
    let lower = (total.div_ceil(cap) as usize).max(1);
    for bin_cnt in lower..=weights.len() {
        if let Some(bins) = fits_in_bins(weights, cap, bin_cnt) {
            return bins;
        }
    }
    // One bin per item always fits (every item <= cap).
    fits_in_bins(weights, cap, weights.len()).expect("one bin per item must fit")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, ensure, gen_mix, gen_pair, gen_u64, gen_vec};

    fn validate(bins: &[Vec<usize>], weights: &[u64], cap: u64) {
        // Partition check.
        let mut seen = vec![false; weights.len()];
        for bin in bins {
            let load: u64 = bin.iter().map(|&i| weights[i]).sum();
            assert!(load <= cap, "bin over capacity: {load} > {cap}");
            for &i in bin {
                assert!(!seen[i], "item {i} duplicated");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "all items packed");
    }

    #[test]
    fn exact_fit_uses_lower_bound() {
        // 6 items of 4 into cap 8 => exactly 3 bins.
        let w = vec![4; 6];
        let bins = binpack_min_bins(&w, 8);
        validate(&bins, &w, 8);
        assert_eq!(bins.len(), 3);
    }

    #[test]
    fn classic_ffd_instance() {
        let w = vec![7, 6, 5, 4, 3, 2, 1]; // total 28, cap 10 => lower 3
        let bins = binpack_min_bins(&w, 10);
        validate(&bins, &w, 10);
        assert_eq!(bins.len(), 3, "7+3, 6+4, 5+2+1 is a 3-bin packing");
    }

    #[test]
    fn single_item() {
        let bins = binpack_min_bins(&[5], 8);
        assert_eq!(bins, vec![vec![0]]);
    }

    #[test]
    fn empty_input() {
        assert!(binpack_min_bins(&[], 8).is_empty());
        assert!(binpack_min_bins_bounded(&[], 8).is_empty());
    }

    #[test]
    fn items_at_capacity() {
        let w = vec![8, 8, 8];
        let bins = binpack_min_bins(&w, 8);
        validate(&bins, &w, 8);
        assert_eq!(bins.len(), 3);
    }

    #[test]
    fn deterministic_across_repeated_runs() {
        // BFD with the stable index tiebreak must be a pure function of its
        // input: repeated runs (and equal-weight permutation ties) yield
        // identical bins — the property the parallel sweep's bit-identical
        // JSON guarantee rests on.
        let w = vec![7, 3, 7, 3, 5, 5, 1, 9, 2, 8];
        let first = binpack_min_bins(&w, 10);
        for _ in 0..10 {
            assert_eq!(binpack_min_bins(&w, 10), first);
        }
        validate(&first, &w, 10);
    }

    #[test]
    fn infeasible_bin_count_returns_none() {
        assert!(fits_in_bins(&[5, 5, 5], 8, 2).is_none());
        assert!(fits_in_bins(&[5, 5, 5], 8, 3).is_some());
    }

    #[test]
    #[should_panic(expected = "item exceeds capacity")]
    fn oversized_item_panics() {
        fits_in_bins(&[9], 8, 1);
    }

    #[test]
    #[should_panic(expected = "item exceeds capacity")]
    fn oversized_item_panics_in_single_pass() {
        binpack_min_bins(&[9], 8);
    }

    #[test]
    fn matches_bounded_oracle_on_fixed_instances() {
        for (w, cap) in [
            (vec![7u64, 6, 5, 4, 3, 2, 1], 10u64),
            (vec![4; 6], 8),
            (vec![8, 8, 8], 8),
            (vec![7, 3, 7, 3, 5, 5, 1, 9, 2, 8], 10),
            (vec![1; 37], 5),
            (vec![10, 1, 1, 1, 1, 1, 1, 1, 1, 1, 1], 10),
        ] {
            assert_eq!(
                binpack_min_bins(&w, cap),
                binpack_min_bins_bounded(&w, cap),
                "weights {w:?} cap {cap}"
            );
        }
    }

    #[test]
    fn prop_identical_bins_to_bounded_oracle_on_longtail() {
        // The load-bearing property of this PR: the single-pass packer
        // returns *the same bins* (not just the same count) as the bounded
        // sweep it replaced, on long-tail instances shaped like real SFT
        // batches (mostly short items, a heavy tail near capacity).
        let gen = gen_pair(
            gen_vec(gen_mix(gen_u64(1, 800), gen_u64(800, 4000), 0.15), 0, 80),
            gen_u64(4000, 8192),
        );
        check(300, gen, |(weights, cap)| {
            let fast = binpack_min_bins(weights, *cap);
            let oracle = binpack_min_bins_bounded(weights, *cap);
            ensure(
                fast == oracle,
                "single-pass BFD must equal the bounded-sweep oracle bin-for-bin",
            )?;
            Ok(())
        });
    }

    #[test]
    fn prop_valid_packing_and_near_optimal() {
        let gen = gen_pair(gen_vec(gen_u64(1, 1000), 1, 60), gen_u64(1000, 4000));
        check(400, gen, |(weights, cap)| {
            let bins = binpack_min_bins(weights, *cap);
            // Validity.
            let mut seen = vec![false; weights.len()];
            for bin in &bins {
                let load: u64 = bin.iter().map(|&i| weights[i]).sum();
                ensure(load <= *cap, "bin within capacity")?;
                for &i in bin {
                    ensure(!seen[i], "no duplicates")?;
                    seen[i] = true;
                }
            }
            ensure(seen.iter().all(|&s| s), "all packed")?;
            // Empirical quality check: bins <= 11/9 * lower + 1, where
            // lower = ceil(sum/cap) <= OPT. This pins observed behaviour on
            // random instances; it is NOT a theorem for this BFD variant
            // (the 11/9·OPT+1 bound is FFD's).
            let total: u64 = weights.iter().sum();
            let lower = total.div_ceil(*cap) as f64;
            ensure(
                (bins.len() as f64) <= (11.0 / 9.0) * lower.max(1.0) + 1.0,
                "within the empirical 11/9 band of the lower bound",
            )?;
            Ok(())
        });
    }

    #[test]
    fn prop_monotone_in_capacity() {
        // Larger capacity never needs more bins.
        let gen = gen_vec(gen_u64(1, 500), 1, 40);
        check(200, gen, |weights| {
            let b1 = binpack_min_bins(weights, 600).len();
            let b2 = binpack_min_bins(weights, 1200).len();
            ensure(b2 <= b1, "doubling capacity cannot increase bins")?;
            Ok(())
        });
    }
}
