//! Bin packing with a bin-count budget — the inner loop of Algorithm 1.
//!
//! The paper's heuristic: for BinCnt = 1.. try to pack the short sequences
//! into `BinCnt` bins of capacity `ChunkSize`; accept the first feasible
//! count. We decide feasibility with best-fit-decreasing (BFD) restricted to
//! the allowed number of bins. BFD is a strong heuristic for this decision
//! problem; since we sweep BinCnt upward, the returned packing is always
//! valid and uses the minimal count *reachable by BFD* — at most 11/9·OPT+1
//! by the classic FFD bound, and we start the sweep at the token-sum lower
//! bound so typical cases are provably optimal.

/// Try to pack `weights` into at most `bin_cnt` bins of capacity `cap`
/// using best-fit-decreasing. Returns item-index bins on success.
pub fn fits_in_bins(weights: &[u64], cap: u64, bin_cnt: usize) -> Option<Vec<Vec<usize>>> {
    assert!(weights.iter().all(|&w| w <= cap), "item exceeds capacity");
    let mut order: Vec<usize> = (0..weights.len()).collect();
    // Decreasing weight; stable tiebreak on index for determinism.
    order.sort_by_key(|&i| (std::cmp::Reverse(weights[i]), i));

    let mut bins: Vec<Vec<usize>> = Vec::new();
    let mut loads: Vec<u64> = Vec::new();
    for &i in &order {
        let w = weights[i];
        // Best fit: the open bin with least remaining space that still fits.
        let mut best: Option<(usize, u64)> = None;
        for (b, &load) in loads.iter().enumerate() {
            if load + w <= cap {
                let rem = cap - load - w;
                if best.map_or(true, |(_, brem)| rem < brem) {
                    best = Some((b, rem));
                }
            }
        }
        match best {
            Some((b, _)) => {
                bins[b].push(i);
                loads[b] += w;
            }
            None => {
                if bins.len() == bin_cnt {
                    return None;
                }
                bins.push(vec![i]);
                loads.push(w);
            }
        }
    }
    Some(bins)
}

/// Pack minimizing bin count: sweep BinCnt from the token-sum lower bound
/// upward (paper Algorithm 1, lines 8-10).
pub fn binpack_min_bins(weights: &[u64], cap: u64) -> Vec<Vec<usize>> {
    if weights.is_empty() {
        return Vec::new();
    }
    let total: u64 = weights.iter().sum();
    let lower = (total.div_ceil(cap) as usize).max(1);
    for bin_cnt in lower..=weights.len() {
        if let Some(bins) = fits_in_bins(weights, cap, bin_cnt) {
            return bins;
        }
    }
    // One bin per item always fits (every item <= cap).
    fits_in_bins(weights, cap, weights.len()).expect("one bin per item must fit")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, ensure, gen_pair, gen_u64, gen_vec};

    fn validate(bins: &[Vec<usize>], weights: &[u64], cap: u64) {
        // Partition check.
        let mut seen = vec![false; weights.len()];
        for bin in bins {
            let load: u64 = bin.iter().map(|&i| weights[i]).sum();
            assert!(load <= cap, "bin over capacity: {load} > {cap}");
            for &i in bin {
                assert!(!seen[i], "item {i} duplicated");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "all items packed");
    }

    #[test]
    fn exact_fit_uses_lower_bound() {
        // 6 items of 4 into cap 8 => exactly 3 bins.
        let w = vec![4; 6];
        let bins = binpack_min_bins(&w, 8);
        validate(&bins, &w, 8);
        assert_eq!(bins.len(), 3);
    }

    #[test]
    fn classic_ffd_instance() {
        let w = vec![7, 6, 5, 4, 3, 2, 1]; // total 28, cap 10 => lower 3
        let bins = binpack_min_bins(&w, 10);
        validate(&bins, &w, 10);
        assert_eq!(bins.len(), 3, "7+3, 6+4, 5+2+1 is a 3-bin packing");
    }

    #[test]
    fn single_item() {
        let bins = binpack_min_bins(&[5], 8);
        assert_eq!(bins, vec![vec![0]]);
    }

    #[test]
    fn empty_input() {
        assert!(binpack_min_bins(&[], 8).is_empty());
    }

    #[test]
    fn items_at_capacity() {
        let w = vec![8, 8, 8];
        let bins = binpack_min_bins(&w, 8);
        validate(&bins, &w, 8);
        assert_eq!(bins.len(), 3);
    }

    #[test]
    fn deterministic_across_repeated_runs() {
        // BFD with the stable index tiebreak must be a pure function of its
        // input: repeated runs (and equal-weight permutation ties) yield
        // identical bins — the property the parallel sweep's bit-identical
        // JSON guarantee rests on.
        let w = vec![7, 3, 7, 3, 5, 5, 1, 9, 2, 8];
        let first = binpack_min_bins(&w, 10);
        for _ in 0..10 {
            assert_eq!(binpack_min_bins(&w, 10), first);
        }
        validate(&first, &w, 10);
    }

    #[test]
    fn infeasible_bin_count_returns_none() {
        assert!(fits_in_bins(&[5, 5, 5], 8, 2).is_none());
        assert!(fits_in_bins(&[5, 5, 5], 8, 3).is_some());
    }

    #[test]
    #[should_panic(expected = "item exceeds capacity")]
    fn oversized_item_panics() {
        fits_in_bins(&[9], 8, 1);
    }

    #[test]
    fn prop_valid_packing_and_near_optimal() {
        let gen = gen_pair(gen_vec(gen_u64(1, 1000), 1, 60), gen_u64(1000, 4000));
        check(400, gen, |(weights, cap)| {
            let bins = binpack_min_bins(weights, *cap);
            // Validity.
            let mut seen = vec![false; weights.len()];
            for bin in &bins {
                let load: u64 = bin.iter().map(|&i| weights[i]).sum();
                ensure(load <= *cap, "bin within capacity")?;
                for &i in bin {
                    ensure(!seen[i], "no duplicates")?;
                    seen[i] = true;
                }
            }
            ensure(seen.iter().all(|&s| s), "all packed")?;
            // FFD quality bound: bins <= 11/9 * OPT + 1, and OPT >= ceil(sum/cap).
            let total: u64 = weights.iter().sum();
            let lower = total.div_ceil(*cap) as f64;
            ensure(
                (bins.len() as f64) <= (11.0 / 9.0) * lower.max(1.0) + 1.0,
                "within FFD bound of lower bound",
            )?;
            Ok(())
        });
    }

    #[test]
    fn prop_monotone_in_capacity() {
        // Larger capacity never needs more bins.
        let gen = gen_vec(gen_u64(1, 500), 1, 40);
        check(200, gen, |weights| {
            let b1 = binpack_min_bins(weights, 600).len();
            let b2 = binpack_min_bins(weights, 1200).len();
            ensure(b2 <= b1, "doubling capacity cannot increase bins")?;
            Ok(())
        });
    }
}
