//! Chunk construction — the paper's Algorithm 1.
//!
//! Given a batch of variable-length sequences and a `ChunkSize`:
//! - sequences longer than `ChunkSize` are split into ⌈len/ChunkSize⌉
//!   *dependent* chunks (contiguous token ranges of one sequence);
//! - the remaining short sequences are bin-packed into *standalone* chunks
//!   of at most `ChunkSize` total tokens, minimizing the number of bins
//!   (chunks) to maximize per-chunk GPU efficiency.
//!
//! Bin-count minimization runs a single unbounded best-fit-decreasing pass
//! in O(n log n) (see [`binpack`]): it returns exactly the packing the
//! paper's literal `BinCnt = 1, 2, …` sweep over bounded BFD would accept
//! first, without the sweep. The result is always a *valid* packing; no
//! optimality theorem is claimed for this BFD variant — the property tests
//! pin validity, the token-sum lower bound, and bin-for-bin identity with
//! the retained bounded-sweep reference oracle
//! ([`binpack_min_bins_bounded`]).

pub mod binpack;

pub use binpack::{binpack_min_bins, binpack_min_bins_bounded, fits_in_bins};

use crate::data::Sequence;

/// A contiguous token range of one original sequence carried by a chunk.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Segment {
    pub seq_id: u64,
    /// Token offset within the original sequence.
    pub offset: u64,
    pub len: u64,
}

/// How a chunk relates to original sequences.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ChunkKind {
    /// One or more *complete* short sequences packed together; no cross-chunk
    /// state, can be scheduled freely.
    Standalone,
    /// The `index`-th of `num_chunks` pieces of long sequence `seq_id`;
    /// forward depends on KV state of pieces `0..index`.
    Dependent { seq_id: u64, index: usize, num_chunks: usize },
}

/// A scheduling unit: at most `ChunkSize` tokens.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Chunk {
    /// Dense id within the constructed set (stable ordering).
    pub id: usize,
    pub kind: ChunkKind,
    pub segments: Vec<Segment>,
}

impl Chunk {
    pub fn total_len(&self) -> u64 {
        self.segments.iter().map(|s| s.len).sum()
    }

    pub fn is_dependent(&self) -> bool {
        matches!(self.kind, ChunkKind::Dependent { .. })
    }

    /// For dependent chunks: tokens of the same sequence that precede this
    /// chunk (the KV-prefix length its attention must consume).
    pub fn prefix_len(&self) -> u64 {
        match self.kind {
            ChunkKind::Standalone => 0,
            ChunkKind::Dependent { .. } => self.segments[0].offset,
        }
    }
}

/// Result of Algorithm 1 on one batch.
#[derive(Clone, Debug)]
pub struct ChunkSet {
    pub chunk_size: u64,
    pub chunks: Vec<Chunk>,
}

impl ChunkSet {
    /// Groups of dependent chunks by sequence, each sorted by index —
    /// the unit Algorithm 2 schedules.
    pub fn dependent_groups(&self) -> Vec<Vec<&Chunk>> {
        let mut by_seq: std::collections::BTreeMap<u64, Vec<&Chunk>> = Default::default();
        for c in &self.chunks {
            if let ChunkKind::Dependent { seq_id, .. } = c.kind {
                by_seq.entry(seq_id).or_default().push(c);
            }
        }
        let mut groups: Vec<Vec<&Chunk>> = by_seq.into_values().collect();
        for g in &mut groups {
            g.sort_by_key(|c| match c.kind {
                ChunkKind::Dependent { index, .. } => index,
                ChunkKind::Standalone => unreachable!(),
            });
        }
        groups
    }

    pub fn standalone_chunks(&self) -> Vec<&Chunk> {
        self.chunks.iter().filter(|c| !c.is_dependent()).collect()
    }

    pub fn total_tokens(&self) -> u64 {
        self.chunks.iter().map(|c| c.total_len()).sum()
    }
}

/// Algorithm 1: reorganize `batch` into chunks of at most `chunk_size`.
///
/// The chunk vector is sized exactly up front (dependent-chunk count is
/// computable from the lengths alone, standalone count comes from the
/// packer), so the hot tuning loop does a single chunk-list allocation per
/// call instead of amortized-doubling growth.
pub fn construct_chunks(batch: &[Sequence], chunk_size: u64) -> ChunkSet {
    assert!(chunk_size > 0, "chunk_size must be positive");

    // One partition pass: count the dependent chunks the long sequences will
    // produce and collect the short ones for packing.
    let mut short: Vec<&Sequence> = Vec::with_capacity(batch.len());
    let mut n_dependent = 0usize;
    for s in batch {
        if s.len > chunk_size {
            n_dependent += s.len.div_ceil(chunk_size) as usize;
        } else {
            short.push(s);
        }
    }

    // Lines 8-13: bin-pack the short sequences minimizing bin count.
    let weights: Vec<u64> = short.iter().map(|s| s.len).collect();
    let bins = binpack_min_bins(&weights, chunk_size);

    let mut chunks: Vec<Chunk> = Vec::with_capacity(n_dependent + bins.len());

    // Lines 3-7: split long sequences (batch order, as before).
    for seq in batch.iter().filter(|s| s.len > chunk_size) {
        let num_chunks = seq.len.div_ceil(chunk_size) as usize;
        for index in 0..num_chunks {
            let offset = index as u64 * chunk_size;
            let len = chunk_size.min(seq.len - offset);
            chunks.push(Chunk {
                id: 0, // assigned below
                kind: ChunkKind::Dependent { seq_id: seq.id, index, num_chunks },
                segments: vec![Segment { seq_id: seq.id, offset, len }],
            });
        }
    }

    for bin in bins {
        let segments = bin
            .into_iter()
            .map(|i| Segment { seq_id: short[i].id, offset: 0, len: short[i].len })
            .collect();
        chunks.push(Chunk { id: 0, kind: ChunkKind::Standalone, segments });
    }

    for (i, c) in chunks.iter_mut().enumerate() {
        c.id = i;
    }
    ChunkSet { chunk_size, chunks }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, ensure, gen_mix, gen_u64, gen_vec};

    fn seqs(lens: &[u64]) -> Vec<Sequence> {
        lens.iter().enumerate().map(|(i, &len)| Sequence { id: i as u64, len }).collect()
    }

    #[test]
    fn paper_figure4_example() {
        // Figure 4: 16 sequences; one long sequence (seq 6) splits into 4
        // chunks, the 15 short ones pack into 3 chunks => 7 chunks total.
        // Reconstruct a compatible instance: ChunkSize=8K, seq6 = 32K,
        // 15 short sequences totalling ~3 chunks' worth.
        let k = 1024;
        let mut lens = vec![2 * k; 15]; // 30K of short => 24K fits 3 bins of 8K? 30K needs 4
        lens[0] = 1 * k;
        lens[1] = 1 * k;
        lens[2] = 1 * k;
        lens[3] = 1 * k;
        lens[4] = 1 * k;
        lens[5] = 1 * k; // now total = 9*2K + 6*1K = 24K => exactly 3 bins of 8K
        let mut all = seqs(&lens);
        all.push(Sequence { id: 6_000, len: 32 * k }); // the long one
        let set = construct_chunks(&all, 8 * k);
        let dep: Vec<_> = set.chunks.iter().filter(|c| c.is_dependent()).collect();
        let sta: Vec<_> = set.standalone_chunks();
        assert_eq!(dep.len(), 4, "long 32K seq at 8K ChunkSize -> 4 chunks");
        assert_eq!(sta.len(), 3, "24K of shorts pack into 3 chunks of 8K");
        assert_eq!(set.chunks.len(), 7);
    }

    #[test]
    fn dependent_chunks_cover_sequence_in_order() {
        let set = construct_chunks(&seqs(&[10_000]), 3_000);
        let groups = set.dependent_groups();
        assert_eq!(groups.len(), 1);
        let g = &groups[0];
        assert_eq!(g.len(), 4); // ceil(10000/3000)
        let mut expected_offset = 0;
        for (i, c) in g.iter().enumerate() {
            match c.kind {
                ChunkKind::Dependent { index, num_chunks, .. } => {
                    assert_eq!(index, i);
                    assert_eq!(num_chunks, 4);
                }
                _ => panic!(),
            }
            assert_eq!(c.segments[0].offset, expected_offset);
            expected_offset += c.segments[0].len;
        }
        assert_eq!(expected_offset, 10_000);
        // Last chunk is the remainder.
        assert_eq!(g[3].total_len(), 1_000);
    }

    #[test]
    fn exact_multiple_split() {
        let set = construct_chunks(&seqs(&[8192]), 2048);
        let g = &set.dependent_groups()[0];
        assert_eq!(g.len(), 4);
        assert!(g.iter().all(|c| c.total_len() == 2048));
    }

    #[test]
    fn sequence_equal_to_chunksize_is_standalone() {
        let set = construct_chunks(&seqs(&[2048]), 2048);
        assert_eq!(set.chunks.len(), 1);
        assert!(!set.chunks[0].is_dependent());
    }

    #[test]
    fn empty_batch() {
        let set = construct_chunks(&[], 1024);
        assert!(set.chunks.is_empty());
        assert!(set.dependent_groups().is_empty());
    }

    #[test]
    fn single_sequence_much_longer_than_chunk_size() {
        // One 1M-token sequence at ChunkSize 2K: 512 dependent chunks, no
        // standalone chunks, contiguous full coverage.
        let k = 1024;
        let set = construct_chunks(&seqs(&[1024 * k]), 2 * k);
        assert_eq!(set.chunks.len(), 512);
        assert!(set.standalone_chunks().is_empty());
        assert!(set.chunks.iter().all(|c| c.is_dependent()));
        assert!(set.chunks.iter().all(|c| c.total_len() == 2 * k));
        assert_eq!(set.total_tokens(), 1024 * k);
        let groups = set.dependent_groups();
        assert_eq!(groups.len(), 1);
        assert_eq!(groups[0].last().unwrap().prefix_len(), 1022 * k);
    }

    #[test]
    fn all_sequences_exactly_chunk_size() {
        // Sequences of exactly ChunkSize are standalone (not split) and
        // each fills one chunk completely.
        let lens = vec![2048u64; 7];
        let set = construct_chunks(&seqs(&lens), 2048);
        assert_eq!(set.chunks.len(), 7);
        assert!(set.chunks.iter().all(|c| !c.is_dependent()));
        assert!(set.chunks.iter().all(|c| c.total_len() == 2048));
        assert!(set.chunks.iter().all(|c| c.segments.len() == 1));
    }

    #[test]
    fn construction_is_deterministic_under_fixed_seed() {
        use crate::data::{BatchSampler, LengthDistribution};
        let draw = || {
            let mut s = BatchSampler::new(
                LengthDistribution::evaluation_dataset(),
                256 * 1024,
                128,
                99,
            );
            construct_chunks(&s.next_batch(), 8 * 1024)
        };
        let a = draw();
        let b = draw();
        assert_eq!(a.chunks, b.chunks, "same seed must give identical chunk sets");
        // And re-running Algorithm 1 on the same batch is pure.
        let mut s = BatchSampler::new(
            LengthDistribution::evaluation_dataset(),
            256 * 1024,
            128,
            99,
        );
        let batch = s.next_batch();
        assert_eq!(
            construct_chunks(&batch, 8 * 1024).chunks,
            construct_chunks(&batch, 8 * 1024).chunks
        );
    }

    #[test]
    fn prefix_len_matches_offset() {
        let set = construct_chunks(&seqs(&[5000]), 2000);
        let g = &set.dependent_groups()[0];
        assert_eq!(g[0].prefix_len(), 0);
        assert_eq!(g[1].prefix_len(), 2000);
        assert_eq!(g[2].prefix_len(), 4000);
    }

    #[test]
    fn ids_are_dense_and_unique() {
        let set = construct_chunks(&seqs(&[100, 5000, 300, 9000]), 2048);
        let ids: Vec<usize> = set.chunks.iter().map(|c| c.id).collect();
        assert_eq!(ids, (0..set.chunks.len()).collect::<Vec<_>>());
    }

    // ----- property tests ---------------------------------------------------

    #[test]
    fn prop_tokens_preserved_and_bounded() {
        // Long-tail-ish mixture of lengths, random chunk sizes.
        let gen = crate::util::prop::gen_pair(
            gen_vec(gen_mix(gen_u64(1, 2_000), gen_u64(2_000, 200_000), 0.1), 0, 64),
            gen_u64(512, 16_384),
        );
        check(300, gen, |(lens, chunk_size)| {
            let batch = seqs(lens);
            let set = construct_chunks(&batch, *chunk_size);
            ensure(
                set.total_tokens() == lens.iter().sum::<u64>(),
                "total tokens preserved",
            )?;
            for c in &set.chunks {
                ensure(c.total_len() <= *chunk_size, "chunk within ChunkSize")?;
                ensure(!c.segments.is_empty(), "no empty chunks")?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_dependent_groups_are_contiguous_partitions() {
        let gen = crate::util::prop::gen_pair(
            gen_vec(gen_u64(1, 100_000), 1, 16),
            gen_u64(1_000, 8_192),
        );
        check(300, gen, |(lens, chunk_size)| {
            let batch = seqs(lens);
            let set = construct_chunks(&batch, *chunk_size);
            for group in set.dependent_groups() {
                let seq_id = group[0].segments[0].seq_id;
                let orig = batch.iter().find(|s| s.id == seq_id).unwrap();
                ensure(orig.len > *chunk_size, "only long seqs become dependent")?;
                let mut offset = 0u64;
                for c in &group {
                    ensure(c.segments.len() == 1, "dependent chunk = single segment")?;
                    ensure(c.segments[0].offset == offset, "contiguous coverage")?;
                    offset += c.segments[0].len;
                }
                ensure(offset == orig.len, "group covers whole sequence")?;
                // All chunks except possibly the last are full.
                for c in &group[..group.len() - 1] {
                    ensure(c.total_len() == *chunk_size, "non-final chunks full")?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_standalone_chunks_hold_complete_short_sequences() {
        let gen = crate::util::prop::gen_pair(
            gen_vec(gen_u64(1, 4_096), 0, 64),
            gen_u64(1_024, 8_192),
        );
        check(300, gen, |(lens, chunk_size)| {
            let batch = seqs(lens);
            let set = construct_chunks(&batch, *chunk_size);
            let mut seen = std::collections::BTreeSet::new();
            for c in set.standalone_chunks() {
                for s in &c.segments {
                    ensure(s.offset == 0, "standalone segments are whole sequences")?;
                    let orig = batch.iter().find(|q| q.id == s.seq_id).unwrap();
                    ensure(s.len == orig.len, "segment covers full sequence")?;
                    ensure(seen.insert(s.seq_id), "each short sequence appears once")?;
                }
            }
            let n_short = batch.iter().filter(|s| s.len <= *chunk_size).count();
            ensure(seen.len() == n_short, "every short sequence packed")?;
            Ok(())
        });
    }

    #[test]
    fn prop_bin_count_is_at_least_lower_bound() {
        let gen = crate::util::prop::gen_pair(
            gen_vec(gen_u64(1, 4_000), 1, 48),
            gen_u64(4_000, 8_192),
        );
        check(200, gen, |(lens, chunk_size)| {
            let batch = seqs(lens);
            let set = construct_chunks(&batch, *chunk_size);
            let n_bins = set.standalone_chunks().len() as u64;
            let total: u64 = lens.iter().sum();
            let lower = total.div_ceil(*chunk_size);
            ensure(n_bins >= lower, "bins >= ceiling lower bound")?;
            // Sanity upper bound: first-fit can't be worse than one bin per
            // sequence.
            ensure(n_bins <= lens.len() as u64, "bins <= n sequences")?;
            Ok(())
        });
    }
}
