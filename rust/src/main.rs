//! `chunkflow` — launcher CLI for the ChunkFlow reproduction.
//!
//! Subcommands:
//!   train      run the real PJRT-backed trainer (tiny / gpt-100m artifacts)
//!   report     regenerate paper tables & figures (report <id>|all)
//!   simulate   one-off pipeline simulation for a model/context
//!   sweep      parallel scenario sweep -> BENCH_chunkflow.json
//!   benchdiff  compare two BENCH_chunkflow.json artifacts for metric drift
//!   tune       (ChunkSize, K) grid search (§5)
//!   check      static schedule/memory verification of scenario plans
//!   lint-src   determinism lint over the Rust source tree
//!   data       inspect the synthetic long-tail datasets
//!   help       this text

use chunkflow::config::{
    ChunkFlowParams, ModelSpec, ParallelConfig, RecomputeGranularity, TrainConfig,
};
use chunkflow::data::{BatchSampler, LengthDistribution};
use chunkflow::runtime::{Backend, Manifest, ReferenceBackend};
use chunkflow::sim::{simulate_baseline_iteration, simulate_chunkflow_iteration, CostModel};
use chunkflow::sweep::{self, Scenario, SweepEngine};
use chunkflow::train::Trainer;
use chunkflow::tune::GridSearch;
use chunkflow::util::cli::{flag, render_help, Args, FlagSpec};
use chunkflow::util::json::Json;

fn flags() -> Vec<FlagSpec> {
    vec![
        flag("model", true, "model preset (tiny|gpt-100m|qwen2.5-{7b,14b,32b,72b})"),
        flag("backend", true, "train backend: reference (pure Rust, default) | pjrt"),
        flag("context", true, "context length, e.g. 32K / 256K"),
        flag("chunk-size", true, "ChunkSize in tokens (e.g. 8K)"),
        flag("k", true, "retention budget K"),
        flag("stages", true, "pipeline stages for train (reference backend; default 1)"),
        flag("partition", true, "uneven per-stage layer counts, e.g. 6,4,2 (train; default equal)"),
        flag("policy", true, "pipeline schedule policy: state-aware-1f1b (default) | chunk-interleaved"),
        flag("dp", true, "data-parallel replica groups for train (reference backend; default 1)"),
        flag("sp", true, "sequence-parallel ring degree; shards long chunks (default 1)"),
        flag("joint", false, "tune: search the joint (ChunkSize, K, dp, pp, sp) space"),
        flag("offload-budget-bytes", true, "KV residency budget; spill coldest chunk KV to disk"),
        flag("fast-path", false, "parallel reference-backend kernels (RAYON_NUM_THREADS caps)"),
        flag("min-fastpath-speedup", true, "benchdiff: minimum runtime/*_fast pair speedup"),
        flag("steps", true, "training steps"),
        flag("max-retries", true, "supervised-executor retries per micro-step (reference; default 0)"),
        flag("handoff-timeout-secs", true, "pipeline handoff deadline override (default: cost-model scaled)"),
        flag("checkpoint-dir", true, "rotating-checkpoint directory (reference train)"),
        flag("checkpoint-every", true, "checkpoint every N steps (0 = end of run only; default 0)"),
        flag("checkpoint-keep", true, "checkpoint generations to keep (default 3)"),
        flag("resume", false, "resume train from the newest valid checkpoint in --checkpoint-dir"),
        flag("batch", true, "global batch size (sequences)"),
        flag("lr", true, "learning rate"),
        flag("seed", true, "random seed"),
        flag("tp", true, "tensor-parallel degree"),
        flag("pp", true, "pipeline-parallel degree"),
        flag("recompute", true, "none|selective|full"),
        flag("artifacts", true, "artifacts directory"),
        flag("dataset", true, "lmsys|eval"),
        flag("iters", true, "simulation iterations to average"),
        flag("out", true, "output JSON path"),
        flag("scenario", true, "sweep scenarios: smoke|paper|<name>[,<name>...]"),
        flag("all", false, "check: verify every registered scenario (registry + smoke)"),
        flag("skip-preflight", false, "skip the static plan verification pre-flight"),
        flag("root", true, "lint-src: source tree to scan (default rust/src)"),
        flag("allowlist", true, "lint-src: audited-exception file (default rust/lint-allow.toml)"),
        flag("measure-exec", false, "attach measured executor bubble ratios (reference probe)"),
        flag("serial", false, "run the sweep serially (reference order)"),
        flag("threads", true, "sweep worker threads (default: all cores)"),
        flag("list", false, "list registered sweep scenarios and exit"),
        flag("quick", false, "smaller batches for fast reports"),
        flag("verbose", false, "debug logging"),
    ]
}

const SUBCOMMANDS: &[(&str, &str)] = &[
    ("train", "run the real chunked trainer (reference backend or PJRT artifacts)"),
    ("report", "regenerate paper tables/figures: report <table1|figure8|...|all>"),
    ("simulate", "simulate one training iteration (baseline vs chunkflow)"),
    ("sweep", "parallel scenario sweep writing BENCH_chunkflow.json"),
    ("benchdiff", "compare two BENCH_chunkflow.json artifacts: benchdiff <old> <new>"),
    ("tune", "grid-search (ChunkSize, K) for a configuration"),
    ("check", "statically verify scenario plans (schedule/memory rules)"),
    ("lint-src", "scan the source tree for determinism hazards"),
    ("data", "print dataset distribution statistics"),
];

fn main() {
    chunkflow::util::log::init();
    // Arm the deterministic fault-injection registry from the environment
    // before any subsystem runs (a no-op unless built with `fault-inject`
    // and `CHUNKFLOW_FAULT_PLAN` is set).
    if let Err(e) = chunkflow::util::fault::install_from_env() {
        eprintln!("error: {e:#}");
        std::process::exit(2);
    }
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let spec = flags();
    let args = match Args::parse(&argv, &spec) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{}", render_help("chunkflow", SUBCOMMANDS, &spec));
            std::process::exit(2);
        }
    };
    if args.get_bool("verbose") {
        chunkflow::util::log::set_level(chunkflow::util::log::Level::Debug);
    }
    let result = match args.subcommand.as_deref() {
        Some("train") => cmd_train(&args),
        Some("report") => cmd_report(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("benchdiff") => cmd_benchdiff(&args),
        Some("tune") => cmd_tune(&args),
        Some("check") => cmd_check(&args),
        Some("lint-src") => cmd_lint_src(&args),
        Some("data") => cmd_data(&args),
        _ => {
            println!("{}", render_help("chunkflow", SUBCOMMANDS, &spec));
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dataset(args: &Args) -> LengthDistribution {
    match args.get_or("dataset", "eval") {
        "lmsys" => LengthDistribution::lmsys_chat_1m(),
        _ => LengthDistribution::evaluation_dataset(),
    }
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let model = ModelSpec::preset(args.get_or("model", "tiny"))?;
    let mut cfg = TrainConfig::default_for(model);
    cfg.context_length = args.get_u64("context", 1024)?;
    cfg.global_batch_size = args.get_u64("batch", 8)?;
    cfg.steps = args.get_u64("steps", 50)?;
    cfg.lr = args.get_f64("lr", 3e-4)?;
    cfg.seed = args.get_u64("seed", 1234)?;
    cfg.artifacts_dir = args.get_or("artifacts", "artifacts").to_string();
    let k = args.get_u64("k", 1)?;
    anyhow::ensure!(k >= 1, "--k must be >= 1");
    let stages = match (args.get("stages"), args.get("partition")) {
        // --partition alone implies the stage count it spells out.
        (None, Some(spec)) => spec.split(',').filter(|t| !t.trim().is_empty()).count(),
        _ => args.get_usize("stages", 1)?,
    };
    anyhow::ensure!(
        stages >= 1,
        "--stages must be >= 1 (a pipeline with zero stages cannot run anything)"
    );
    let policy = match args.get("policy") {
        Some(name) => chunkflow::pipeline::PolicyKind::by_name(name)?,
        None => chunkflow::pipeline::PolicyKind::default(),
    };
    let dp = args.get_usize("dp", 1)?;
    anyhow::ensure!(dp >= 1, "--dp must be >= 1");
    let sp = args.get_u64("sp", 1)?;
    anyhow::ensure!(sp >= 1, "--sp must be >= 1");
    let offload_budget = match args.get("offload-budget-bytes") {
        Some(s) => Some(
            chunkflow::util::cli::parse_size(s)
                .ok_or_else(|| anyhow::anyhow!("--offload-budget-bytes: invalid size `{s}`"))?,
        ),
        None => None,
    };
    let max_retries = args.get_u64("max-retries", 0)? as u32;
    let handoff_timeout = match args.get("handoff-timeout-secs") {
        Some(s) => {
            let secs: f64 = s.parse().map_err(|_| {
                anyhow::anyhow!("--handoff-timeout-secs: invalid number `{s}`")
            })?;
            anyhow::ensure!(secs > 0.0, "--handoff-timeout-secs must be positive");
            Some(std::time::Duration::from_secs_f64(secs))
        }
        None => None,
    };
    let ckpt = match args.get("checkpoint-dir") {
        Some(dir) => Some(chunkflow::train::CheckpointPolicy {
            dir: std::path::PathBuf::from(dir),
            every: args.get_u64("checkpoint-every", 0)?,
            keep: args.get_usize("checkpoint-keep", 3)?,
        }),
        None => None,
    };
    let resume = args.get_bool("resume");
    anyhow::ensure!(
        !resume || ckpt.is_some(),
        "--resume needs --checkpoint-dir to know where the checkpoints live"
    );

    // Clamp the sampled lengths to backend coverage via a suitable
    // distribution: reuse the evaluation shape truncated at the context.
    // Rows at or beyond the context collapse into the final bucket, so
    // short contexts (< 513) construct a valid CDF instead of tripping
    // `from_cdf`'s bound assertion.
    let mut dist_rows: Vec<(u64, f64)> = [(256, 0.60), (512, 0.85)]
        .into_iter()
        .filter(|&(hi, _)| hi < cfg.context_length)
        .collect();
    dist_rows.push((cfg.context_length, 0.99));
    let dist = LengthDistribution::from_cdf("train", &dist_rows, cfg.context_length);
    match args.get_or("backend", "reference") {
        "reference" => {
            // The reference backend compiles nothing, so --chunk-size is free
            // to choose; the in-memory manifest's buckets cover the context.
            let chunk_size = args.get_u64("chunk-size", 256)?;
            anyhow::ensure!(chunk_size >= 1, "--chunk-size must be >= 1");
            // Degenerate-partition fail-fast: every stage needs at least one
            // layer, and an explicit partition must agree with --stages and
            // cover the model exactly (StagePartition::parse checks the
            // rest, naming the offending stage).
            let num_layers = cfg.model.num_layers as usize;
            anyhow::ensure!(
                stages <= num_layers,
                "--stages {stages} exceeds the {} layers of `{}`: at least one \
                 stage would be left with zero layers",
                num_layers,
                cfg.model.name
            );
            let partition = match args.get("partition") {
                Some(spec) => {
                    let part = chunkflow::runtime::StagePartition::parse(spec, num_layers)?;
                    anyhow::ensure!(
                        part.num_stages() == stages,
                        "--partition `{spec}` describes {} stage(s) but --stages is {stages}",
                        part.num_stages()
                    );
                    Some(part)
                }
                None => None,
            };
            cfg.chunkflow = ChunkFlowParams::new(chunk_size, k);
            let mut parallel =
                ParallelConfig::new(1, stages as u64, RecomputeGranularity::Selective);
            parallel.dp = dp as u64;
            parallel.sp = sp;
            cfg.parallel = parallel;
            // Static pre-flight: build the plan this configuration generates
            // for a probe batch and verify every schedule/memory rule before
            // constructing the backend. A bad strategy fails here with the
            // violated rule id and offending op, not a mid-training error.
            if !args.get_bool("skip-preflight") {
                let probe = BatchSampler::new(
                    dist.clone(),
                    cfg.context_length,
                    cfg.global_batch_size as usize,
                    cfg.seed,
                )
                .next_batch();
                let set = chunkflow::chunk::construct_chunks(&probe, chunk_size);
                let mm = chunkflow::memory::MemoryModel::new(
                    cfg.model.clone(),
                    cfg.parallel.clone(),
                );
                chunkflow::verify::preflight(
                    "train pre-flight",
                    &set,
                    sp,
                    policy,
                    k as usize,
                    stages,
                    &mm,
                    cfg.context_length,
                )?;
            }
            let max_chunks = cfg.context_length.div_ceil(chunk_size) as usize;
            let manifest = Manifest::for_reference(&cfg.model, chunk_size as usize, max_chunks)?;
            let mut backend = ReferenceBackend::new(manifest)?;
            if args.get_bool("fast-path") {
                backend.enable_fast_path();
            }
            let mut trainer = Trainer::with_backend(backend, cfg, dist)?;
            trainer.set_sp(sp);
            trainer.set_partition(partition);
            trainer.set_policy(policy);
            if let Some(budget) = offload_budget {
                trainer.set_offload_budget(Some(budget));
            }
            trainer.set_retry_policy(chunkflow::pipeline::RetryPolicy::with_retries(max_retries));
            trainer.set_handoff_timeout(handoff_timeout);
            let mode = if dp > 1 {
                anyhow::ensure!(
                    offload_budget.is_none(),
                    "--offload-budget-bytes applies to the single-replica path \
                     (replica groups own per-rank KV)"
                );
                chunkflow::train::TrainMode::Dp { dp, stages }
            } else if stages > 1 {
                anyhow::ensure!(
                    offload_budget.is_none(),
                    "--offload-budget-bytes applies to the single-stage path \
                     (the pipeline executor owns per-stage KV)"
                );
                chunkflow::train::TrainMode::Pipelined { stages }
            } else {
                chunkflow::train::TrainMode::Single
            };
            trainer.train_with_recovery(mode, ckpt.as_ref(), resume)?;
            finish_training(&trainer, args)
        }
        "pjrt" => {
            // Fail fast on builds without the PJRT runtime — before any
            // config or artifact-directory work happens.
            if cfg!(not(feature = "pjrt")) {
                anyhow::bail!(
                    "`--backend pjrt` is unavailable: this chunkflow binary was built \
                     without the `pjrt` cargo feature (the stub runtime cannot execute \
                     programs). Rebuild with `cargo build --release --features pjrt` \
                     after vendoring the `xla` crate, or use `--backend reference`."
                );
            }
            anyhow::ensure!(
                stages <= 1,
                "pipeline mode (--stages > 1) requires --backend reference"
            );
            anyhow::ensure!(
                args.get("partition").is_none()
                    && policy == chunkflow::pipeline::PolicyKind::default(),
                "--partition/--policy configure the pipeline executor and \
                 require --backend reference"
            );
            anyhow::ensure!(
                dp <= 1,
                "data-parallel mode (--dp > 1) requires --backend reference"
            );
            anyhow::ensure!(
                sp <= 1,
                "sequence-parallel mode (--sp > 1) requires --backend reference"
            );
            anyhow::ensure!(
                offload_budget.is_none(),
                "--offload-budget-bytes requires --backend reference"
            );
            anyhow::ensure!(
                !args.get_bool("fast-path"),
                "--fast-path applies to the reference backend (PJRT programs are \
                 already compiled)"
            );
            anyhow::ensure!(
                ckpt.is_none() && !resume && max_retries == 0 && handoff_timeout.is_none(),
                "--checkpoint-dir/--resume/--max-retries/--handoff-timeout-secs \
                 require --backend reference"
            );
            // The AOT artifacts own the compiled chunk shape: default
            // --chunk-size to it; an explicit contradicting flag errors in
            // Trainer::with_backend.
            let runtime = chunkflow::runtime::Runtime::load(
                std::path::Path::new(&cfg.artifacts_dir),
                &cfg.model.name,
            )?;
            let chunk_size = args.get_u64("chunk-size", runtime.manifest.chunk_size as u64)?;
            cfg.chunkflow = ChunkFlowParams::new(chunk_size, k);
            let mut trainer = Trainer::with_backend(runtime, cfg, dist)?;
            trainer.train()?;
            finish_training(&trainer, args)
        }
        other => anyhow::bail!("unknown backend `{other}` (have: reference, pjrt)"),
    }
}

fn finish_training<B: Backend>(trainer: &Trainer<B>, args: &Args) -> anyhow::Result<()> {
    let out = args.get_or("out", "target/train_history.json");
    trainer.loss_history_json().write_file(std::path::Path::new(out))?;
    println!("wrote {out}");
    Ok(())
}

fn cmd_report(args: &Args) -> anyhow::Result<()> {
    use chunkflow::report as R;
    let quick = args.get_bool("quick");
    let what = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("all");
    match what {
        "table1" => drop(R::table1()),
        "table2" => drop(R::table2()),
        "table3" => drop(R::table3()),
        "table4" => drop(R::table4(quick)),
        "table5" => drop(R::table5()),
        "table6" => drop(R::table6()),
        "figure1" => drop(R::figure1(args.get_u64("seed", 42)?)),
        "figure2" => drop(R::figure2()),
        "figure4" => drop(R::figure4()),
        "figure5" => drop(R::figure5()),
        "figure6" => drop(R::figure6()),
        "figure7" => drop(R::figure7()),
        "figure8" => drop(R::figure8(
            args.get_usize("iters", if quick { 2 } else { 5 })?,
            args.get_usize("batch", if quick { 128 } else { 256 })?,
            args.get_u64("seed", 42)?,
        )),
        "all" => R::run_all(quick),
        other => anyhow::bail!("unknown report `{other}`"),
    }
    Ok(())
}

fn parallel_from(args: &Args) -> anyhow::Result<ParallelConfig> {
    let mut p = ParallelConfig::new(
        args.get_u64("tp", 4)?,
        args.get_u64("pp", 4)?,
        RecomputeGranularity::parse(args.get_or("recompute", "selective"))?,
    );
    p.sp = args.get_u64("sp", 1)?;
    anyhow::ensure!(p.sp >= 1, "--sp must be >= 1");
    p.dp = args.get_u64("dp", 1)?;
    anyhow::ensure!(p.dp >= 1, "--dp must be >= 1");
    Ok(p)
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    let model = ModelSpec::preset(args.get_or("model", "qwen2.5-7b"))?;
    let ctx = args.get_u64("context", 32 * 1024)?;
    let chunk = args.get_u64("chunk-size", 8 * 1024)?;
    let k = args.get_usize("k", 1)?;
    let iters = args.get_usize("iters", 3)?;
    let batch_n = args.get_usize("batch", 256)?;
    let parallel = parallel_from(args)?;
    let cost = CostModel::new(model.clone(), parallel.clone());
    let mut cf_parallel = parallel.clone();
    cf_parallel.recompute = RecomputeGranularity::Selective;
    let cf_cost = CostModel::new(model, cf_parallel);
    let mut sampler = BatchSampler::new(dataset(args), ctx, batch_n, args.get_u64("seed", 42)?);
    let (mut tb, mut tc, mut bb, mut bc) = (0.0, 0.0, 0.0, 0.0);
    for _ in 0..iters {
        let b = sampler.next_batch();
        let rb = simulate_baseline_iteration(&b, &cost)?;
        let rc = simulate_chunkflow_iteration(&b, &cf_cost, chunk, k)?;
        tb += rb.iteration_seconds;
        tc += rc.iteration_seconds;
        bb += rb.bubble_ratio;
        bc += rc.bubble_ratio;
    }
    let n = iters as f64;
    println!("config {} ctx {} chunk {} K {k}", parallel.paper_format(),
             chunkflow::util::format_tokens(ctx), chunkflow::util::format_tokens(chunk));
    println!("megatron-like : {:.3}s/iter  bubble {:.1}%", tb / n, bb / n * 100.0);
    println!("chunkflow     : {:.3}s/iter  bubble {:.1}%", tc / n, bc / n * 100.0);
    println!("speedup       : {:.2}x", tb / tc);
    Ok(())
}

fn cmd_sweep(args: &Args) -> anyhow::Result<()> {
    if args.get_bool("list") {
        println!("registered scenarios (`--scenario <name>[,<name>...]` | smoke | paper):");
        for s in Scenario::registry().iter().chain(Scenario::smoke().iter()) {
            println!(
                "  {:<28} {} @ {} · {} · batch {} x {} iters · {} candidates",
                s.name,
                s.model.name,
                chunkflow::util::format_tokens(s.context_length),
                s.distribution,
                s.global_batch_size,
                s.iters,
                s.candidates.len()
            );
        }
        return Ok(());
    }
    let mut scenarios = Scenario::select(args.get_or("scenario", "smoke"))?;
    let seed = args.get_u64("seed", chunkflow::sweep::scenario::DEFAULT_SEED)?;
    for s in &mut scenarios {
        s.seed = seed;
    }
    // Static pre-flight: every candidate plan of every selected scenario
    // must verify before any sweep compute (or journal write) happens.
    if !args.get_bool("skip-preflight") {
        for s in &scenarios {
            let report = chunkflow::verify::check_scenario(s)?;
            chunkflow::verify::ensure_clean(
                &format!("sweep pre-flight ({})", s.name),
                &report.diagnostics,
            )?;
        }
    }
    let engine = if args.get_bool("serial") {
        SweepEngine::serial()
    } else if let Some(n) = args.get("threads") {
        SweepEngine::with_threads(
            n.parse().map_err(|_| anyhow::anyhow!("--threads: invalid integer `{n}`"))?,
        )
    } else {
        SweepEngine::auto()
    };
    let units: usize = scenarios.iter().map(|s| s.candidates.len() + 1).sum();
    println!(
        "sweeping {} scenario(s), {units} work units ({:?})\n",
        scenarios.len(),
        engine.parallelism
    );
    let out = args.get_or("out", sweep::DEFAULT_BENCH_PATH);
    let path = std::path::Path::new(out);
    let entries: Vec<Json> = if args.get_bool("measure-exec") {
        // The executor probe mutates results after the sweep (wall-clock
        // measurements, nondeterministic by nature), so this path stays
        // non-journaled: an interrupted probe run simply reruns.
        let mut results = engine.run(&scenarios)?;
        println!("running executor probes (scaled-down reference mirror per scenario)...\n");
        sweep::attach_measured_exec(&mut results)?;
        for r in &results {
            if let Some(me) = &r.measured_exec {
                println!(
                    "  {:<28} stages {} K {} -> bubble {:>5.1}% measured / {:>5.1}% predicted",
                    r.scenario.name,
                    me.stages,
                    me.k,
                    100.0 * me.bubble_ratio_measured,
                    100.0 * me.bubble_ratio_predicted
                );
            }
            if let Some(el) = r.elastic_pipeline.as_ref().and_then(|ep| ep.measured.as_ref()) {
                println!(
                    "  {:<28} elastic {} / {} -> bubble {:>5.1}% equal / {:>5.1}% elastic (measured)",
                    "", // continuation line under the scenario name above
                    el.partition,
                    el.policy,
                    100.0 * el.measured_bubble_equal,
                    100.0 * el.measured_bubble_elastic
                );
            }
        }
        println!();
        results.iter().map(sweep::scenario_json).collect()
    } else {
        // Crash-resumable default path: every completed scenario is
        // journaled (fsynced) to `<out>.partial`; a rerun after a crash
        // skips completed scenarios and still emits byte-identical bytes.
        engine.run_resumable(&scenarios, &journal_path(out))?
    };
    println!(
        "{:<28} {:>12} {:>14} {:>12} {:>9}",
        "scenario", "baseline s", "best (CS,K)", "chunkflow s", "speedup"
    );
    for e in &entries {
        let name = e.req_str("name")?;
        let baseline = e
            .get("baseline")
            .map(|b| b.req_f64("iteration_seconds"))
            .transpose()?
            .unwrap_or(f64::NAN);
        let (best_label, best_secs) = match e.get("best") {
            Some(b) if b.get("chunk_size").is_some() => (
                format!(
                    "({},{})",
                    chunkflow::util::format_tokens(b.req_u64("chunk_size")?),
                    b.req_u64("k")?
                ),
                format!("{:.3}", b.req_f64("iteration_seconds")?),
            ),
            _ => ("-".into(), "-".into()),
        };
        let speedup = e
            .get("speedup")
            .and_then(|v| v.as_f64())
            .map(|s| format!("{s:.2}x"))
            .unwrap_or_else(|| "-".into());
        println!("{name:<28} {baseline:>12.3} {best_label:>14} {best_secs:>12} {speedup:>8}");
    }
    sweep::doc_from_scenarios(entries, None).write_file(path)?;
    // Self-check the artifact against the schema contract before declaring
    // success — CI consumes this file. Only then retire the journal: the
    // finished artifact supersedes it.
    let n = sweep::validate(&Json::parse_file(path)?)?;
    let _ = std::fs::remove_file(journal_path(out));
    println!("\nwrote {out} ({n} scenarios, schema v{})", sweep::SCHEMA_VERSION);
    Ok(())
}

/// Journal location for a sweep writing its artifact to `out`.
fn journal_path(out: &str) -> std::path::PathBuf {
    std::path::PathBuf::from(format!("{out}.partial"))
}

fn cmd_benchdiff(args: &Args) -> anyhow::Result<()> {
    let (old, new) = match (args.positional.first(), args.positional.get(1)) {
        (Some(old), Some(new)) => (old, new),
        _ => anyhow::bail!("usage: chunkflow benchdiff <old.json> <new.json>"),
    };
    let old_doc = Json::parse_file(std::path::Path::new(old))?;
    let new_doc = Json::parse_file(std::path::Path::new(new))?;
    // The new artifact must satisfy the current schema contract; the old one
    // may predate it (a schema bump compares zero scenarios).
    sweep::validate(&new_doc)?;
    let n = sweep::compare_scenarios(&old_doc, &new_doc)?;
    if n == 0 {
        println!(
            "OK: nothing to compare between {old} and {new} \
             (schema version changed, or the old artifact has no scenarios)"
        );
    } else {
        println!("OK: {n} scenario(s) compared, no baseline/best/speedup drift");
    }
    // Schedule-quality report (informational, never gating): per-scenario
    // bubble-ratio movement next to the speedup numbers. The gate above
    // already pins these byte-exactly; this makes movement readable.
    let drift = sweep::bubble_drift(&old_doc, &new_doc);
    if !drift.is_empty() {
        println!(
            "\n{:<28} {:>18} {:>18}",
            "bubble ratio", "baseline old->new", "best old->new"
        );
        let fmt_pair = |old: Option<f64>, new: Option<f64>| match (old, new) {
            (Some(o), Some(w)) => format!("{:>7.1}% ->{:>6.1}%", 100.0 * o, 100.0 * w),
            _ => "-".into(),
        };
        for row in &drift {
            println!(
                "{:<28} {:>18} {:>18}",
                row.name,
                fmt_pair(Some(row.baseline_old), Some(row.baseline_new)),
                fmt_pair(row.best_old, row.best_new)
            );
        }
    }
    if let Some(floor) = args.get("min-fastpath-speedup") {
        let floor: f64 = floor
            .parse()
            .map_err(|_| anyhow::anyhow!("--min-fastpath-speedup: invalid number `{floor}`"))?;
        check_fastpath_floor(&new_doc, floor)?;
    }
    Ok(())
}

/// CI perf-regression gate: the new artifact's `micro_benchmarks` must hold
/// at least one `runtime/<name>` / `runtime/<name>_fast` pair, and the best
/// pair's speedup (scalar mean_ns / fast mean_ns) must reach `floor`.
fn check_fastpath_floor(doc: &Json, floor: f64) -> anyhow::Result<()> {
    let rows = doc
        .get("micro_benchmarks")
        .and_then(|m| m.as_arr())
        .ok_or_else(|| {
            anyhow::anyhow!("--min-fastpath-speedup: new artifact has no `micro_benchmarks`")
        })?;
    let mean_of = |name: &str| -> Option<f64> {
        rows.iter()
            .find(|r| r.get("name").and_then(|n| n.as_str()) == Some(name))
            .and_then(|r| r.get("mean_ns").and_then(|v| v.as_f64()))
    };
    let mut best: Option<(String, f64)> = None;
    for row in rows {
        let Some(name) = row.get("name").and_then(|n| n.as_str()) else { continue };
        let Some(base_name) = name.strip_suffix("_fast") else { continue };
        if !name.starts_with("runtime/") {
            continue;
        }
        let (Some(base), Some(fast)) = (mean_of(base_name), mean_of(name)) else { continue };
        if fast <= 0.0 {
            continue;
        }
        let speedup = base / fast;
        println!("fast-path {base_name}: {speedup:.2}x (scalar {base:.0} ns / fast {fast:.0} ns)");
        if best.as_ref().map_or(true, |(_, s)| speedup > *s) {
            best = Some((base_name.to_string(), speedup));
        }
    }
    let (name, speedup) = best.ok_or_else(|| {
        anyhow::anyhow!(
            "--min-fastpath-speedup: no runtime/<name> + runtime/<name>_fast \
             micro-benchmark pair in the new artifact"
        )
    })?;
    anyhow::ensure!(
        speedup >= floor,
        "fast-path regression: best pair `{name}` is {speedup:.2}x, below the \
         {floor:.2}x floor"
    );
    println!("OK: fast-path floor {floor:.2}x satisfied by `{name}` at {speedup:.2}x");
    Ok(())
}

fn cmd_tune(args: &Args) -> anyhow::Result<()> {
    let model = ModelSpec::preset(args.get_or("model", "qwen2.5-7b"))?;
    let ctx = args.get_u64("context", 256 * 1024)?;
    let mut gs = GridSearch::standard(model, parallel_from(args)?, ctx);
    if args.get_bool("quick") {
        gs.global_batch_size = 64;
        gs.iters = 1;
    }
    if args.get_bool("joint") {
        return tune_joint(&gs, args);
    }
    let points = gs.run();
    println!(
        "{:>10} {:>4} {:>14} {:>10} {:>12} {:>6}",
        "ChunkSize", "K", "iter seconds", "bubble", "peak mem", "fits"
    );
    for p in &points {
        println!(
            "{:>10} {:>4} {:>14.3} {:>9.1}% {:>12} {:>6}",
            chunkflow::util::format_tokens(p.chunk_size),
            p.k,
            p.avg_iteration_seconds,
            p.bubble_ratio * 100.0,
            chunkflow::util::format_bytes(p.peak_memory_bytes),
            if p.feasible { "yes" } else { "OOM" }
        );
    }
    if let Some(best) = points.iter().find(|p| p.feasible) {
        println!(
            "\nbest: ({}, {})",
            chunkflow::util::format_tokens(best.chunk_size),
            best.k
        );
    }
    if let Some(out) = args.get("out") {
        let j = Json::Arr(
            points
                .iter()
                .map(|p| {
                    Json::obj(vec![
                        ("chunk_size", Json::num(p.chunk_size as f64)),
                        ("k", Json::num(p.k as f64)),
                        ("seconds", Json::num(p.avg_iteration_seconds)),
                        ("feasible", Json::Bool(p.feasible)),
                    ])
                })
                .collect(),
        );
        j.write_file(std::path::Path::new(out))?;
    }
    Ok(())
}

/// `tune --joint`: sweep (dp, pp, sp) strategy candidates around the flag
/// values and rank each strategy's best feasible (ChunkSize, K) point.
fn tune_joint(gs: &GridSearch, args: &Args) -> anyhow::Result<()> {
    let axis = |v: u64| -> Vec<u64> {
        let mut c = vec![1, 2, 4];
        if !c.contains(&v) {
            c.push(v);
            c.sort_unstable();
        }
        c
    };
    let dps = axis(gs.parallel.dp);
    let pps = axis(gs.parallel.pp);
    let sps = axis(gs.parallel.sp);
    if !args.get_bool("skip-preflight") {
        gs.preflight()?;
    }
    let ranked = gs.run_joint(&dps, &pps, &sps, &SweepEngine::auto());
    println!(
        "{:>4} {:>4} {:>4} {:>10} {:>4} {:>14} {:>12}  {}",
        "dp", "pp", "sp", "ChunkSize", "K", "iter seconds", "peak mem", "elastic pipeline"
    );
    for jp in &ranked {
        let elastic = match &jp.elastic {
            Some(e) => format!(
                "{} / {} (bubble {:.1}% -> {:.1}%)",
                e.partition_string(),
                e.policy.name(),
                100.0 * e.bubble_equal,
                100.0 * e.bubble_elastic
            ),
            None if jp.parallel.pp > 1 => "equal split optimal".to_string(),
            None => "-".to_string(),
        };
        println!(
            "{:>4} {:>4} {:>4} {:>10} {:>4} {:>14.3} {:>12}  {elastic}",
            jp.parallel.dp,
            jp.parallel.pp,
            jp.parallel.sp,
            chunkflow::util::format_tokens(jp.point.chunk_size),
            jp.point.k,
            jp.point.avg_iteration_seconds,
            chunkflow::util::format_bytes(jp.point.peak_memory_bytes)
        );
    }
    if let Some(best) = ranked.first() {
        println!(
            "\nbest: dp {} pp {} sp {} at ({}, {})",
            best.parallel.dp,
            best.parallel.pp,
            best.parallel.sp,
            chunkflow::util::format_tokens(best.point.chunk_size),
            best.point.k
        );
        if let Some(e) = &best.elastic {
            println!(
                "      with --partition {} --policy {} (simulated bubble {:.1}% -> {:.1}%)",
                e.partition_string(),
                e.policy.name(),
                100.0 * e.bubble_equal,
                100.0 * e.bubble_elastic
            );
        }
    }
    if let Some(out) = args.get("out") {
        let j = Json::Arr(
            ranked
                .iter()
                .map(|jp| {
                    let mut fields = vec![
                        ("dp", Json::num(jp.parallel.dp as f64)),
                        ("pp", Json::num(jp.parallel.pp as f64)),
                        ("sp", Json::num(jp.parallel.sp as f64)),
                        ("chunk_size", Json::num(jp.point.chunk_size as f64)),
                        ("k", Json::num(jp.point.k as f64)),
                        ("seconds", Json::num(jp.point.avg_iteration_seconds)),
                    ];
                    // Additive elastic refinement (pp > 1 strategies with a
                    // strict simulated win only).
                    if let Some(e) = &jp.elastic {
                        fields.push((
                            "elastic",
                            Json::obj(vec![
                                ("partition", Json::str(e.partition_string())),
                                ("policy", Json::str(e.policy.name().to_string())),
                                ("bubble_equal", Json::num(e.bubble_equal)),
                                ("bubble_elastic", Json::num(e.bubble_elastic)),
                            ]),
                        ));
                    }
                    Json::obj(fields)
                })
                .collect(),
        );
        j.write_file(std::path::Path::new(out))?;
    }
    Ok(())
}

fn cmd_check(args: &Args) -> anyhow::Result<()> {
    let scenarios = if args.get_bool("all") {
        let mut all = Scenario::select("all")?;
        all.extend(Scenario::smoke());
        all
    } else {
        Scenario::select(args.get_or("scenario", "smoke"))?
    };
    let mut reports = Vec::new();
    let mut total = 0usize;
    for s in &scenarios {
        let r = chunkflow::verify::check_scenario(s)?;
        println!(
            "{:<28} {:>3} plan(s)  {}",
            r.scenario,
            r.plans,
            if r.is_clean() { "OK" } else { "FAIL" }
        );
        for d in &r.diagnostics {
            println!("  {d}");
        }
        total += r.diagnostics.len();
        reports.push(r);
    }
    if let Some(out) = args.get("out") {
        let j = Json::Arr(
            reports
                .iter()
                .map(|r| {
                    Json::obj(vec![
                        ("scenario", Json::str(r.scenario.clone())),
                        ("plans", Json::num(r.plans as f64)),
                        (
                            "diagnostics",
                            Json::Arr(r.diagnostics.iter().map(|d| d.to_json()).collect()),
                        ),
                    ])
                })
                .collect(),
        );
        j.write_file(std::path::Path::new(out))?;
        println!("wrote {out}");
    }
    anyhow::ensure!(
        total == 0,
        "{total} diagnostic(s) across {} scenario(s)",
        scenarios.len()
    );
    println!(
        "\nOK: {} scenario(s), every candidate plan statically verified",
        scenarios.len()
    );
    Ok(())
}

/// Resolve a default path that must work from both the workspace root
/// (`cargo run` in CI) and the crate directory (test binaries).
fn first_existing(cands: &[&str]) -> std::path::PathBuf {
    cands
        .iter()
        .map(std::path::PathBuf::from)
        .find(|p| p.exists())
        .unwrap_or_else(|| std::path::PathBuf::from(cands[0]))
}

fn cmd_lint_src(args: &Args) -> anyhow::Result<()> {
    let root = match args.get("root") {
        Some(r) => std::path::PathBuf::from(r),
        None => first_existing(&["rust/src", "src"]),
    };
    let allow_path = match args.get("allowlist") {
        Some(p) => std::path::PathBuf::from(p),
        None => first_existing(&["rust/lint-allow.toml", "lint-allow.toml"]),
    };
    let allows = if allow_path.exists() {
        chunkflow::lint::parse_allowlist(&std::fs::read_to_string(&allow_path)?)?
    } else {
        Vec::new()
    };
    let report = chunkflow::lint::lint_tree(&root, &allows)?;
    for (f, reason) in &report.allowed {
        println!("allowed {f}  ({reason})");
    }
    for f in &report.findings {
        println!("{f}");
    }
    for a in &report.unused_allows {
        println!("unused allowlist entry: {} [{}] ({})", a.file, a.rule, a.reason);
    }
    anyhow::ensure!(
        report.is_clean(),
        "{} new determinism hazard(s), {} unused allowlist entr(y/ies) \
         across {} file(s)",
        report.findings.len(),
        report.unused_allows.len(),
        report.files_scanned
    );
    println!(
        "OK: {} file(s) scanned, {} audited exception(s), no new determinism hazards",
        report.files_scanned,
        report.allowed.len()
    );
    Ok(())
}

fn cmd_data(args: &Args) -> anyhow::Result<()> {
    let dist = dataset(args);
    println!("dataset: {}", dist.name);
    for (label, p) in dist.table_rows() {
        println!("{label:<10} {:>8.3}%", p * 100.0);
    }
    let ctx = args.get_u64("context", 256 * 1024)?;
    let mut sampler = BatchSampler::new(dist, ctx, args.get_usize("batch", 256)?, args.get_u64("seed", 42)?);
    let batch = sampler.next_batch();
    let total: u64 = batch.iter().map(|s| s.len).sum();
    let max = batch.iter().map(|s| s.len).max().unwrap_or(0);
    println!(
        "sample batch: {} seqs, {} tokens total, longest {}",
        batch.len(),
        total,
        chunkflow::util::format_tokens(max)
    );
    Ok(())
}
