//! Pure-Rust reference backend: the same chunked transformer as
//! `python/compile/model.py`, with exact analytic gradients in f64.
//!
//! Architecture (must stay in sync with the python model): GPT-style
//! decoder — pre-RMSNorm (eps 1e-6), RoPE (theta 10000) on Q/K, causal MHA
//! with segment masking and KV-prefix state, SwiGLU MLP, tied input/output
//! embeddings, summed next-token cross-entropy over targets >= 0.
//!
//! The three programs of the [`Backend`](super::Backend) contract are
//! implemented directly:
//!
//! - `fwd_kv`:    forward only; returns loss, token count and this chunk's
//!   post-RoPE K / V tensors ([L, 2, C, H, D]);
//! - `chunk_vjp`: forward + hand-derived reverse pass; cotangents are
//!   d(loss_sum) = 1 plus `g_kv_own` flowing into this chunk's KV output —
//!   the explicit chain rule that replaces framework autograd across the
//!   program boundary. Returns parameter grads and `d_kv_in`;
//! - `full_step`: the unchunked oracle over a whole sequence (any length).
//!
//! Everything runs in f64 end to end (parameters are widened once per
//! `set_params`), so the chunked-vs-unchunked gradient-equivalence suite
//! observes only op-reordering noise (~1e-12 relative), far below its 1e-6
//! gate. Execution is single-threaded, allocation-order deterministic, and
//! bitwise reproducible for identical inputs.
//!
//! Masking, per the Layer-1 kernel (`python/compile/kernels/chunk_attn.py`):
//! key `j` is visible to query `i` iff `kpos <= qpos` (causal) AND
//! (`qseg == kseg && qseg >= 0` (same segment) OR `qpos == kpos && qseg ==
//! kseg` (self-token, which keeps padding rows well-defined)). Prefix keys
//! carry positions `0..P` and segment 0 — dependent chunks are
//! single-segment by construction.

#![allow(clippy::too_many_arguments, clippy::needless_range_loop)]

use std::ops::Range;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

use super::fastpath::{self, FastPath};
use super::{Backend, ChunkInputs, ChunkVjpOut, FlatParams, FullStepOut, FwdKvOut, Manifest};

const ROPE_THETA: f64 = 10000.0;
const RMS_EPS: f64 = 1e-6;

// Flat parameter indices (PARAM_ORDER of python/compile/model.py).
const P_EMBED: usize = 0;
const P_LN_F: usize = 1;
const P_WQ: usize = 2;
const P_WK: usize = 3;
const P_WV: usize = 4;
const P_WO: usize = 5;
const P_W_GATE: usize = 6;
const P_W_UP: usize = 7;
const P_W_DOWN: usize = 8;
const P_NORM1: usize = 9;
const P_NORM2: usize = 10;

const PARAM_ORDER: [&str; 11] = [
    "embed", "ln_f", "wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down", "norm1", "norm2",
];

/// Model dimensions derived from the manifest once at construction.
#[derive(Clone, Copy, Debug)]
struct Dims {
    /// Layers.
    l: usize,
    /// Attention heads.
    heads: usize,
    /// Head dimension.
    d: usize,
    /// Hidden size (heads * d).
    hh: usize,
    /// MLP intermediate size.
    ii: usize,
    /// Vocabulary size.
    v: usize,
}

/// Deterministic in-process backend (see module docs).
///
/// Shared-reference execution: every program takes `&self`, and the call
/// counter is atomic, so a `&ReferenceBackend` can be driven concurrently
/// from several pipeline-stage threads (`pipeline::exec`).
pub struct ReferenceBackend {
    pub manifest: Manifest,
    dims: Dims,
    /// Current parameters, widened to f64 (set via `set_params`).
    params: Option<Vec<Vec<f64>>>,
    calls: AtomicU64,
    /// Parallel fast path; None = the serial scalar oracle. The fast path
    /// is bit-identical to serial by construction (see `runtime/fastpath`):
    /// output-row partitioning with per-element op order preserved.
    fast: Option<FastPath>,
    /// Set once if a parallel kernel ever panics (a dead pool worker, or an
    /// injected `fastpath.pool_panic`): all later kernels take the scalar
    /// path. Degrading instead of crashing is safe precisely because the
    /// two paths are bit-identical.
    fast_degraded: AtomicBool,
}

/// Per-layer forward caches consumed by the reverse pass.
struct LayerCache {
    /// [T, hh] layer input (pre-norm1).
    x_in: Vec<f64>,
    /// [T, hh] norm1 output.
    xn1: Vec<f64>,
    /// [T] norm1 rsqrt factors.
    inv1: Vec<f64>,
    /// [H, T, D] post-RoPE queries.
    q: Vec<f64>,
    /// [H, S, D] prefix + own keys (post-RoPE).
    k_full: Vec<f64>,
    /// [H, S, D] prefix + own values.
    v_full: Vec<f64>,
    /// [H, T, S] attention probabilities (masked entries exactly 0).
    probs: Vec<f64>,
    /// [T, hh] heads concatenated, pre-wo.
    attn_flat: Vec<f64>,
    /// [T, hh] after attention residual.
    x_mid: Vec<f64>,
    /// [T, hh] norm2 output.
    xn2: Vec<f64>,
    /// [T] norm2 rsqrt factors.
    inv2: Vec<f64>,
    /// [T, ii] gate pre-activation.
    gate: Vec<f64>,
    /// [T, ii] up projection.
    up: Vec<f64>,
    /// [T, ii] silu(gate) * up.
    act: Vec<f64>,
}

/// Final-norm + tied-head caches consumed by `head_bwd`.
struct HeadCache {
    /// [T, hh] ln_f output.
    xf: Vec<f64>,
    /// [T] ln_f rsqrt factors.
    inv_f: Vec<f64>,
    /// [T, V] vocab softmax per row.
    probs_v: Vec<f64>,
}

/// Whole-forward cache.
struct Cache {
    layers: Vec<LayerCache>,
    /// [T, hh] final hidden states (input to ln_f).
    x_out: Vec<f64>,
    head: HeadCache,
}

/// Per-chunk caches one pipeline stage retains between its forward and
/// backward — the "activations" Algorithm 2 budgets with K, now at stage
/// granularity. Opaque to the executor: it only stores, counts and returns
/// them.
pub struct StageCache {
    layers: Vec<LayerCache>,
    /// Last stage only: [T, hh] input to ln_f.
    x_out: Option<Vec<f64>>,
    /// Last stage only.
    head: Option<HeadCache>,
    /// Last stage only: this chunk's summed loss / trainable-token count.
    loss_sum: f64,
    n_tok: f64,
}

impl StageCache {
    pub fn loss_sum(&self) -> f64 {
        self.loss_sum
    }

    pub fn n_tok(&self) -> f64 {
        self.n_tok
    }
}

/// Output of one stage's forward over one chunk op.
pub struct StageFwdOut {
    /// Activation handed to the next stage ([T, hh]); None on the last.
    pub x_out: Option<Vec<f64>>,
    /// Stage-local own KV ([Lr, 2, T, H, D]).
    pub kv_own: Vec<f64>,
    pub cache: StageCache,
}

/// Output of one stage's backward over one chunk op.
pub struct StageBwdOut {
    /// Activation cotangent handed to the previous stage ([T, hh]); None on
    /// the first stage (it flows into the embedding gradient instead).
    pub d_x_in: Option<Vec<f64>>,
    /// Stage-local prefix-KV cotangent ([Lr, 2, P, H, D]).
    pub d_kv_in: Vec<f64>,
}

impl ReferenceBackend {
    /// Build a backend over an in-memory manifest (see
    /// [`Manifest::for_reference`]). Call `set_params` before executing.
    pub fn new(manifest: Manifest) -> anyhow::Result<Self> {
        anyhow::ensure!(
            manifest.params.len() == PARAM_ORDER.len(),
            "manifest has {} params, reference model needs {}",
            manifest.params.len(),
            PARAM_ORDER.len()
        );
        for (spec, want) in manifest.params.iter().zip(PARAM_ORDER.iter()) {
            anyhow::ensure!(
                spec.name == *want,
                "manifest param `{}` where reference model expects `{want}` \
                 (PARAM_ORDER mismatch)",
                spec.name
            );
        }
        let hh = manifest.hidden_size;
        let heads = manifest.num_heads;
        let d = manifest.head_dim;
        anyhow::ensure!(heads * d == hh, "heads*head_dim {} != hidden {hh}", heads * d);
        let gate_shape = &manifest.params[P_W_GATE].shape;
        anyhow::ensure!(gate_shape.len() == 3, "w_gate must be [L, h, i]");
        let ii = gate_shape[2] as usize;
        let dims = Dims { l: manifest.num_layers, heads, d, hh, ii, v: manifest.vocab_size };
        let expect: [(usize, Vec<usize>); 11] = [
            (P_EMBED, vec![dims.v, hh]),
            (P_LN_F, vec![hh]),
            (P_WQ, vec![dims.l, hh, hh]),
            (P_WK, vec![dims.l, hh, hh]),
            (P_WV, vec![dims.l, hh, hh]),
            (P_WO, vec![dims.l, hh, hh]),
            (P_W_GATE, vec![dims.l, hh, ii]),
            (P_W_UP, vec![dims.l, hh, ii]),
            (P_W_DOWN, vec![dims.l, ii, hh]),
            (P_NORM1, vec![dims.l, hh]),
            (P_NORM2, vec![dims.l, hh]),
        ];
        for (idx, shape) in expect.iter() {
            let got: Vec<usize> = manifest.params[*idx].shape.iter().map(|&x| x as usize).collect();
            anyhow::ensure!(
                got == *shape,
                "param `{}` shape {:?} != expected {:?}",
                manifest.params[*idx].name,
                got,
                shape
            );
            anyhow::ensure!(
                manifest.params[*idx].size == shape.iter().product::<usize>(),
                "param `{}` size mismatch",
                manifest.params[*idx].name
            );
        }
        Ok(Self {
            manifest,
            dims,
            params: None,
            calls: AtomicU64::new(0),
            fast: None,
            fast_degraded: AtomicBool::new(false),
        })
    }

    /// Enable the parallel fast path. Worker count comes from
    /// `RAYON_NUM_THREADS` when set, else available parallelism. Results
    /// stay bit-identical to the serial path: every parallel kernel
    /// partitions by output rows with a split that is a pure function of
    /// the problem size, so per-element arithmetic and reduction order
    /// never change (the CI determinism job enforces this byte-for-byte).
    pub fn enable_fast_path(&mut self) {
        self.fast = Some(FastPath::new());
        self.fast_degraded.store(false, Ordering::Relaxed);
    }

    /// Enable the fast path with an explicit worker count (tests, benches).
    pub fn enable_fast_path_with_threads(&mut self, threads: usize) {
        self.fast = Some(FastPath::with_threads(threads));
        self.fast_degraded.store(false, Ordering::Relaxed);
    }

    /// Has the fast path been permanently disabled by a worker panic?
    pub fn fast_path_degraded(&self) -> bool {
        self.fast_degraded.load(Ordering::Relaxed)
    }

    fn params_ref(&self) -> anyhow::Result<&Vec<Vec<f64>>> {
        self.params.as_ref().ok_or_else(|| anyhow::anyhow!("set_params not called"))
    }

    // --- kernel dispatch: serial oracle or the parallel fast path ---------
    //
    // Every dispatch site is written as "try the fast path, fall back to
    // the serial oracle". A panic escaping a parallel kernel — an injected
    // `fastpath.pool_panic` or a genuinely dead pool worker — is caught in
    // `catch_fast`, degrades the backend to the scalar path for good, and
    // the same call completes serially. Kernels that *accumulate* into
    // caller-owned buffers snapshot them first so a partially-applied
    // parallel region can be rolled back before the serial rerun; the
    // snapshot is one buffer copy per call, ~1/T of the kernel's own work.

    /// Fast path to use for the next kernel call, if any.
    fn active_fast(&self) -> Option<&FastPath> {
        match &self.fast {
            Some(fp) if !self.fast_degraded.load(Ordering::Relaxed) => Some(fp),
            _ => None,
        }
    }

    /// Run one parallel kernel, catching any panic that escapes it. On
    /// panic: log once, set the degraded flag, and return `None` so the
    /// caller reruns the kernel serially. This is memory-safe because
    /// `FastPath::for_parts` joins every spawned job before a panic
    /// propagates out of it — no worker still borrows the kernel's buffers
    /// by the time we catch.
    fn catch_fast<T>(&self, kernel: &'static str, par: impl FnOnce() -> T) -> Option<T> {
        match std::panic::catch_unwind(std::panic::AssertUnwindSafe(par)) {
            Ok(out) => Some(out),
            Err(payload) => {
                let msg = payload
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| payload.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "non-string panic payload".to_string());
                if !self.fast_degraded.swap(true, Ordering::SeqCst) {
                    crate::warn_!(
                        "fast path disabled after panic in kernel `{kernel}`: {msg}; \
                         continuing on the scalar path (bit-identical, slower)"
                    );
                }
                None
            }
        }
    }

    fn mm(&self, x: &[f64], w: &[f64], t: usize, a: usize, b: usize) -> Vec<f64> {
        if let Some(fp) = self.active_fast() {
            if let Some(out) = self.catch_fast("matmul", || fastpath::par_matmul(fp, x, w, t, a, b))
            {
                return out;
            }
        }
        matmul(x, w, t, a, b)
    }

    fn mm_nt(&self, dy: &[f64], w: &[f64], t: usize, a: usize, b: usize) -> Vec<f64> {
        if let Some(fp) = self.active_fast() {
            if let Some(out) =
                self.catch_fast("matmul_nt", || fastpath::par_matmul_nt(fp, dy, w, t, a, b))
            {
                return out;
            }
        }
        matmul_nt(dy, w, t, a, b)
    }

    fn acc_tn(&self, x: &[f64], dy: &[f64], t: usize, a: usize, b: usize, dw: &mut [f64]) {
        if let Some(fp) = self.active_fast() {
            // `+=` accumulator: roll back to the pre-call state if the
            // parallel region died after updating only some parts.
            let snap = dw[..a * b].to_vec();
            if self
                .catch_fast("accum_tn", || fastpath::par_accum_tn(fp, x, dy, t, a, b, dw))
                .is_some()
            {
                return;
            }
            dw[..a * b].copy_from_slice(&snap);
        }
        accum_tn(x, dy, t, a, b, dw);
    }

    fn rope(&self, xs: &mut [f64], pos: &[i32], heads: usize, t: usize, d: usize, inverse: bool) {
        if let Some(fp) = self.active_fast() {
            // In-place rotation is not idempotent: restore before rerunning
            // serially so no row gets rotated twice.
            let snap = xs.to_vec();
            if self
                .catch_fast("rope", || rope_apply_par(fp, xs, pos, heads, t, d, inverse))
                .is_some()
            {
                return;
            }
            xs.copy_from_slice(&snap);
        }
        rope_apply(xs, pos, heads, t, d, inverse);
    }

    /// `act = silu(gate) * up` elementwise over `n` entries.
    fn silu_mul(&self, gate: &[f64], up: &[f64], n: usize) -> Vec<f64> {
        let mut act = vec![0.0f64; n];
        if let Some(fp) = self.active_fast() {
            if self
                .catch_fast("silu_mul", || {
                    fastpath::par_fill(fp, &mut act, 8, |idx| silu(gate[idx]) * up[idx])
                })
                .is_some()
            {
                return act;
            }
            // Write-once buffer: the serial loop overwrites every entry.
        }
        for idx in 0..n {
            act[idx] = silu(gate[idx]) * up[idx];
        }
        act
    }

    /// SwiGLU backward: cotangents on gate pre-activation and up projection.
    fn silu_bwd(&self, gate: &[f64], up: &[f64], d_act: &[f64], n: usize) -> (Vec<f64>, Vec<f64>) {
        let mut d_gate = vec![0.0f64; n];
        let mut d_up = vec![0.0f64; n];
        let f = |idx: usize| {
            let g = gate[idx];
            let sg = sigmoid(g);
            (d_act[idx] * up[idx] * (sg * (1.0 + g * (1.0 - sg))), d_act[idx] * (g * sg))
        };
        if let Some(fp) = self.active_fast() {
            if self
                .catch_fast("silu_bwd", || fastpath::par_fill2(fp, &mut d_gate, &mut d_up, 16, &f))
                .is_some()
            {
                return (d_gate, d_up);
            }
        }
        for idx in 0..n {
            let (dg, du) = f(idx);
            d_gate[idx] = dg;
            d_up[idx] = du;
        }
        (d_gate, d_up)
    }

    /// Validate a chunk call against the manifest contract (fixed chunk
    /// shape, bucketed prefix) — the same checks the PJRT runtime performs.
    fn check_chunk(&self, inputs: &ChunkInputs<f64>) -> anyhow::Result<()> {
        let c = self.manifest.chunk_size;
        anyhow::ensure!(inputs.tokens.len() == c, "tokens len {} != {c}", inputs.tokens.len());
        anyhow::ensure!(inputs.targets.len() == c, "targets len {} != {c}", inputs.targets.len());
        anyhow::ensure!(inputs.pos.len() == c, "pos len {} != {c}", inputs.pos.len());
        anyhow::ensure!(inputs.seg.len() == c, "seg len {} != {c}", inputs.seg.len());
        anyhow::ensure!(
            self.manifest.kv_buckets.contains(&inputs.prefix_len),
            "prefix {} is not an exported bucket",
            inputs.prefix_len
        );
        anyhow::ensure!(
            inputs.kv_in.len() == self.kv_elements(inputs.prefix_len),
            "kv_in len {} != {} for prefix {}",
            inputs.kv_in.len(),
            self.kv_elements(inputs.prefix_len),
            inputs.prefix_len
        );
        Ok(())
    }

    /// Embedding lookup (stage 0's entry point).
    fn embed_fwd(&self, tokens: &[i32]) -> anyhow::Result<Vec<f64>> {
        let params = self.params_ref()?;
        let Dims { hh, v, .. } = self.dims;
        for &tok in tokens {
            anyhow::ensure!(tok >= 0 && (tok as usize) < v, "token {tok} out of vocab {v}");
        }
        let embed = &params[P_EMBED];
        let t = tokens.len();
        let mut x = vec![0.0f64; t * hh];
        for i in 0..t {
            let row = &embed[tokens[i] as usize * hh..(tokens[i] as usize + 1) * hh];
            x[i * hh..(i + 1) * hh].copy_from_slice(row);
        }
        Ok(x)
    }

    /// Forward a contiguous `layers` range over activation `x` with a
    /// range-local KV prefix (`kv_in` is [Lr, 2, P, H, D]). Returns the
    /// range's output activation, its own KV ([Lr, 2, T, H, D]) and the
    /// per-layer caches the matching `layers_bwd` consumes. An empty range
    /// is a passthrough (a stage that only holds the embedding or head).
    fn layers_fwd(
        &self,
        layers: Range<usize>,
        mut x: Vec<f64>,
        pos: &[i32],
        seg: &[i32],
        k_pos: &[i32],
        k_seg: &[i32],
        kv_in: &[f64],
        p: usize,
    ) -> anyhow::Result<(Vec<f64>, Vec<f64>, Vec<LayerCache>)> {
        let params = self.params_ref()?;
        let Dims { heads, d, hh, ii, .. } = self.dims;
        let t = pos.len();
        let s_len = p + t;
        let scale = 1.0 / (d as f64).sqrt();
        let lr = layers.len();
        anyhow::ensure!(x.len() == t * hh, "activation len {} != {}", x.len(), t * hh);
        anyhow::ensure!(
            kv_in.len() == lr * 2 * p * heads * d,
            "stage kv_in len {} != {} for {lr} layers, prefix {p}",
            kv_in.len(),
            lr * 2 * p * heads * d
        );

        let mut caches = Vec::with_capacity(lr);
        let mut s_buf = vec![0.0f64; s_len];
        for (lj, li) in layers.clone().enumerate() {
            let x_in = x.clone();
            let norm1 = &params[P_NORM1][li * hh..(li + 1) * hh];
            let (xn1, inv1) = rmsnorm_fwd(&x_in, norm1, t, hh);

            let wq = &params[P_WQ][li * hh * hh..(li + 1) * hh * hh];
            let wk = &params[P_WK][li * hh * hh..(li + 1) * hh * hh];
            let wv = &params[P_WV][li * hh * hh..(li + 1) * hh * hh];
            let qmat = self.mm(&xn1, wq, t, hh, hh);
            let kmat = self.mm(&xn1, wk, t, hh, hh);
            let vmat = self.mm(&xn1, wv, t, hh, hh);

            // [T, hh] -> [H, T, D], RoPE on q and k.
            let mut q = heads_of(&qmat, heads, t, d);
            let mut k_own = heads_of(&kmat, heads, t, d);
            let v_own = heads_of(&vmat, heads, t, d);
            self.rope(&mut q, pos, heads, t, d, false);
            self.rope(&mut k_own, pos, heads, t, d, false);

            // Full K/V = stored prefix + own.
            let mut k_full = vec![0.0f64; heads * s_len * d];
            let mut v_full = vec![0.0f64; heads * s_len * d];
            for h in 0..heads {
                for j in 0..p {
                    for dd in 0..d {
                        let kidx = (((lj * 2) * p + j) * heads + h) * d + dd;
                        let vidx = (((lj * 2 + 1) * p + j) * heads + h) * d + dd;
                        k_full[(h * s_len + j) * d + dd] = kv_in[kidx];
                        v_full[(h * s_len + j) * d + dd] = kv_in[vidx];
                    }
                }
                for i in 0..t {
                    let src = (h * t + i) * d;
                    let dst = (h * s_len + p + i) * d;
                    k_full[dst..dst + d].copy_from_slice(&k_own[src..src + d]);
                    v_full[dst..dst + d].copy_from_slice(&v_own[src..src + d]);
                }
            }

            // Masked softmax attention with exact-zero masked probabilities.
            let (probs, attn_flat) = self.attn_fwd(
                &q, &k_full, &v_full, pos, seg, k_pos, k_seg, heads, t, s_len, d, scale,
                &mut s_buf,
            );

            let wo = &params[P_WO][li * hh * hh..(li + 1) * hh * hh];
            let attn_proj = self.mm(&attn_flat, wo, t, hh, hh);
            let mut x_mid = x_in.clone();
            for (xm, ap) in x_mid.iter_mut().zip(&attn_proj) {
                *xm += *ap;
            }

            let norm2 = &params[P_NORM2][li * hh..(li + 1) * hh];
            let (xn2, inv2) = rmsnorm_fwd(&x_mid, norm2, t, hh);
            let w_gate = &params[P_W_GATE][li * hh * ii..(li + 1) * hh * ii];
            let w_up = &params[P_W_UP][li * hh * ii..(li + 1) * hh * ii];
            let w_down = &params[P_W_DOWN][li * ii * hh..(li + 1) * ii * hh];
            let gate = self.mm(&xn2, w_gate, t, hh, ii);
            let up = self.mm(&xn2, w_up, t, hh, ii);
            let act = self.silu_mul(&gate, &up, t * ii);
            let mlp = self.mm(&act, w_down, t, ii, hh);
            let mut x_out = x_mid.clone();
            for (xo, mv) in x_out.iter_mut().zip(&mlp) {
                *xo += *mv;
            }

            caches.push(LayerCache {
                x_in,
                xn1,
                inv1,
                q,
                k_full,
                v_full,
                probs,
                attn_flat,
                x_mid,
                xn2,
                inv2,
                gate,
                up,
                act,
            });
            x = x_out;
        }

        // Own KV contribution [Lr, 2, T, H, D] from the per-layer full K/V.
        let mut kv_own = vec![0.0f64; lr * 2 * t * heads * d];
        for (lj, lc) in caches.iter().enumerate() {
            for i in 0..t {
                for h in 0..heads {
                    let src = (h * s_len + p + i) * d;
                    let kdst = (((lj * 2) * t + i) * heads + h) * d;
                    let vdst = (((lj * 2 + 1) * t + i) * heads + h) * d;
                    kv_own[kdst..kdst + d].copy_from_slice(&lc.k_full[src..src + d]);
                    kv_own[vdst..vdst + d].copy_from_slice(&lc.v_full[src..src + d]);
                }
            }
        }

        Ok((x, kv_own, caches))
    }

    /// Masked softmax attention forward for one layer. Returns
    /// (probs [H, T, S], attn_flat [T, hh]); masked probabilities are
    /// exactly zero. `s_buf` is the serial path's scratch row (the fast
    /// path uses per-part scratch instead).
    fn attn_fwd(
        &self,
        q: &[f64],
        k_full: &[f64],
        v_full: &[f64],
        pos: &[i32],
        seg: &[i32],
        k_pos: &[i32],
        k_seg: &[i32],
        heads: usize,
        t: usize,
        s_len: usize,
        d: usize,
        scale: f64,
        s_buf: &mut [f64],
    ) -> (Vec<f64>, Vec<f64>) {
        if let Some(fp) = self.active_fast() {
            if let Some(out) = self.catch_fast("attn_fwd", || {
                attn_fwd_par(fp, q, k_full, v_full, pos, seg, k_pos, k_seg, heads, t, s_len, d, scale)
            }) {
                return out;
            }
        }
        let hh = heads * d;
        let mut probs = vec![0.0f64; heads * t * s_len];
        let mut attn_flat = vec![0.0f64; t * hh];
        for h in 0..heads {
            for i in 0..t {
                let qrow = &q[(h * t + i) * d..(h * t + i + 1) * d];
                let mut mx = f64::NEG_INFINITY;
                for j in 0..s_len {
                    if !attend(pos[i], seg[i], k_pos[j], k_seg[j]) {
                        s_buf[j] = f64::NEG_INFINITY;
                        continue;
                    }
                    let krow = &k_full[(h * s_len + j) * d..(h * s_len + j + 1) * d];
                    let mut dot = 0.0;
                    for dd in 0..d {
                        dot += qrow[dd] * krow[dd];
                    }
                    s_buf[j] = dot * scale;
                    if s_buf[j] > mx {
                        mx = s_buf[j];
                    }
                }
                let prow = &mut probs[(h * t + i) * s_len..(h * t + i + 1) * s_len];
                if mx == f64::NEG_INFINITY {
                    continue; // fully masked row: zero probs, zero output
                }
                let mut sum = 0.0;
                for j in 0..s_len {
                    if s_buf[j] == f64::NEG_INFINITY {
                        prow[j] = 0.0;
                    } else {
                        let e = (s_buf[j] - mx).exp();
                        prow[j] = e;
                        sum += e;
                    }
                }
                let out = &mut attn_flat[i * hh + h * d..i * hh + (h + 1) * d];
                for j in 0..s_len {
                    if prow[j] == 0.0 {
                        continue;
                    }
                    prow[j] /= sum;
                    let vrow = &v_full[(h * s_len + j) * d..(h * s_len + j + 1) * d];
                    for dd in 0..d {
                        out[dd] += prow[j] * vrow[dd];
                    }
                }
            }
        }
        (probs, attn_flat)
    }

    /// Final RMSNorm + tied logits + summed next-token cross-entropy (the
    /// last stage's exit point). Returns (loss_sum, n_tok, head cache).
    fn head_fwd(&self, x_out: &[f64], targets: &[i32]) -> anyhow::Result<(f64, f64, HeadCache)> {
        let params = self.params_ref()?;
        let Dims { hh, v, .. } = self.dims;
        let t = targets.len();
        for &tg in targets {
            anyhow::ensure!(tg < v as i32, "target {tg} out of vocab {v}");
        }
        let embed = &params[P_EMBED];
        let (xf, inv_f) = rmsnorm_fwd(x_out, &params[P_LN_F], t, hh);
        let mut probs_v = vec![0.0f64; t * v];
        let mut fast_out = None;
        if let Some(fp) = self.active_fast() {
            fast_out = self.catch_fast("head_fwd", || {
                head_fwd_rows_par(fp, embed, &xf, targets, t, hh, v, &mut probs_v)
            });
            if fast_out.is_none() {
                // Discard any partially-written rows before the serial rerun.
                for p in probs_v.iter_mut() {
                    *p = 0.0;
                }
            }
        }
        let (loss_sum, n_tok) = match fast_out {
            Some(out) => out,
            None => head_fwd_rows(embed, &xf, targets, t, hh, v, &mut probs_v),
        };
        Ok((loss_sum, n_tok, HeadCache { xf, inv_f, probs_v }))
    }

    /// Forward over `t` tokens with a `p`-token KV prefix — the single-stage
    /// composition of the stage pieces (embed, all layers, head). Returns
    /// (loss_sum, n_tok, kv_own [L, 2, T, H, D], caches).
    fn forward(
        &self,
        tokens: &[i32],
        targets: &[i32],
        pos: &[i32],
        seg: &[i32],
        kv_in: &[f64],
        p: usize,
    ) -> anyhow::Result<(f64, f64, Vec<f64>, Cache)> {
        let l = self.dims.l;
        let (k_pos, k_seg) = key_meta(pos, seg, p);
        let x = self.embed_fwd(tokens)?;
        let (x_out, kv_own, layers) =
            self.layers_fwd(0..l, x, pos, seg, &k_pos, &k_seg, kv_in, p)?;
        let (loss_sum, n_tok, head) = self.head_fwd(&x_out, targets)?;
        Ok((loss_sum, n_tok, kv_own, Cache { layers, x_out, head }))
    }

    /// Head backward: loss cotangent (d loss_sum = 1) through the tied head
    /// and ln_f. Accumulates embed/ln_f grads into `d_params`, returns the
    /// cotangent at the last layer range's output.
    fn head_bwd(
        &self,
        targets: &[i32],
        x_out: &[f64],
        head: &HeadCache,
        d_params: &mut [Vec<f64>],
    ) -> Vec<f64> {
        let params = self.params.as_ref().expect("backward after forward");
        let Dims { hh, v, .. } = self.dims;
        let t = targets.len();

        // Loss -> logits -> (xf, embed). Tied head: logits = xf @ embed^T.
        let embed = &params[P_EMBED];
        let mut d_xf = vec![0.0f64; t * hh];
        let mut done = false;
        if let Some(fp) = self.active_fast() {
            // Both outputs accumulate with `+=`: snapshot the embed-grad
            // section and re-zero the fresh `d_xf` if the region dies.
            let snap = d_params[P_EMBED].clone();
            done = self
                .catch_fast("head_bwd", || {
                    head_bwd_rows_par(
                        fp,
                        embed,
                        head,
                        targets,
                        t,
                        hh,
                        v,
                        &mut d_xf,
                        &mut d_params[P_EMBED],
                    )
                })
                .is_some();
            if !done {
                d_params[P_EMBED].copy_from_slice(&snap);
                for x in d_xf.iter_mut() {
                    *x = 0.0;
                }
            }
        }
        if !done {
            head_bwd_rows(embed, head, targets, t, hh, v, &mut d_xf, &mut d_params[P_EMBED]);
        }

        // ln_f backward. (No key-metadata rebuild is needed anywhere below:
        // the mask is implicit in the cached probs — masked entries are 0.)
        let mut d_x = vec![0.0f64; t * hh];
        rmsnorm_bwd(
            x_out,
            &params[P_LN_F],
            &head.inv_f,
            &d_xf,
            t,
            hh,
            &mut d_x,
            &mut d_params[P_LN_F],
        );
        d_x
    }

    /// Reverse pass over a `layers` range (matching a prior `layers_fwd`).
    /// Cotangents: `d_x` at the range output plus the range-local slice of
    /// `g_kv_own` on the chunk's KV output ([Lr, 2, T, H, D]). Accumulates
    /// parameter grads into `d_params` and returns (cotangent at the range
    /// input, d_kv_in [Lr, 2, P, H, D]). Segment ids are not needed here:
    /// the mask lives implicitly in the cached probabilities (masked
    /// entries are exactly zero).
    fn layers_bwd(
        &self,
        layers: Range<usize>,
        caches: &[LayerCache],
        mut d_x: Vec<f64>,
        pos: &[i32],
        p: usize,
        g_kv_own: Option<&[f64]>,
        d_params: &mut [Vec<f64>],
    ) -> (Vec<f64>, Vec<f64>) {
        let params = self.params.as_ref().expect("backward after forward");
        let Dims { heads, d, hh, ii, .. } = self.dims;
        let t = pos.len();
        let s_len = p + t;
        let scale = 1.0 / (d as f64).sqrt();
        let lr = layers.len();
        debug_assert_eq!(caches.len(), lr);
        let mut d_kv_in = vec![0.0f64; lr * 2 * p * heads * d];

        let mut d_p_buf = vec![0.0f64; s_len];
        for (lj, li) in layers.clone().enumerate().rev() {
            let lc = &caches[lj];
            let w_down = &params[P_W_DOWN][li * ii * hh..(li + 1) * ii * hh];
            let w_gate = &params[P_W_GATE][li * hh * ii..(li + 1) * hh * ii];
            let w_up = &params[P_W_UP][li * hh * ii..(li + 1) * hh * ii];
            let wo = &params[P_WO][li * hh * hh..(li + 1) * hh * hh];
            let wq = &params[P_WQ][li * hh * hh..(li + 1) * hh * hh];
            let wk = &params[P_WK][li * hh * hh..(li + 1) * hh * hh];
            let wv = &params[P_WV][li * hh * hh..(li + 1) * hh * hh];

            // MLP backward: x_out = x_mid + act @ w_down.
            let mut d_x_mid = d_x.clone(); // residual branch
            let d_act = self.mm_nt(&d_x, w_down, t, ii, hh);
            self.acc_tn(&lc.act, &d_x, t, ii, hh, &mut d_params[P_W_DOWN][li * ii * hh..]);
            let (d_gate, d_up) = self.silu_bwd(&lc.gate, &lc.up, &d_act, t * ii);
            let mut d_xn2 = self.mm_nt(&d_gate, w_gate, t, hh, ii);
            let d_xn2_up = self.mm_nt(&d_up, w_up, t, hh, ii);
            for (a, b) in d_xn2.iter_mut().zip(&d_xn2_up) {
                *a += *b;
            }
            self.acc_tn(&lc.xn2, &d_gate, t, hh, ii, &mut d_params[P_W_GATE][li * hh * ii..]);
            self.acc_tn(&lc.xn2, &d_up, t, hh, ii, &mut d_params[P_W_UP][li * hh * ii..]);
            rmsnorm_bwd(
                &lc.x_mid,
                &params[P_NORM2][li * hh..(li + 1) * hh],
                &lc.inv2,
                &d_xn2,
                t,
                hh,
                &mut d_x_mid,
                &mut d_params[P_NORM2][li * hh..(li + 1) * hh],
            );

            // Attention output projection: x_mid = x_in + attn_flat @ wo.
            let mut d_x_in = d_x_mid.clone(); // residual branch
            let d_attn_flat = self.mm_nt(&d_x_mid, wo, t, hh, hh);
            self.acc_tn(&lc.attn_flat, &d_x_mid, t, hh, hh, &mut d_params[P_WO][li * hh * hh..]);

            // Attention core backward (probs cached; masked entries are 0).
            let (mut d_q, mut d_k_full, mut d_v_full) =
                self.attn_bwd(lc, &d_attn_flat, heads, t, s_len, d, hh, scale, &mut d_p_buf);

            // Cotangent from later chunks on this chunk's KV output.
            if let Some(g) = g_kv_own {
                for i in 0..t {
                    for h in 0..heads {
                        let kidx = (((lj * 2) * t + i) * heads + h) * d;
                        let vidx = (((lj * 2 + 1) * t + i) * heads + h) * d;
                        let kdst = (h * s_len + p + i) * d;
                        for dd in 0..d {
                            d_k_full[kdst + dd] += g[kidx + dd];
                            d_v_full[kdst + dd] += g[vidx + dd];
                        }
                    }
                }
            }

            // Split the K/V gradients: prefix slots flow out as d_kv_in,
            // own slots continue through RoPE and the projections.
            for j in 0..p {
                for h in 0..heads {
                    let ksrc = (h * s_len + j) * d;
                    let kdst = (((lj * 2) * p + j) * heads + h) * d;
                    let vdst = (((lj * 2 + 1) * p + j) * heads + h) * d;
                    for dd in 0..d {
                        d_kv_in[kdst + dd] += d_k_full[ksrc + dd];
                        d_kv_in[vdst + dd] += d_v_full[ksrc + dd];
                    }
                }
            }
            let mut d_k_own = vec![0.0f64; heads * t * d];
            let mut d_v_own = vec![0.0f64; heads * t * d];
            for h in 0..heads {
                for i in 0..t {
                    let src = (h * s_len + p + i) * d;
                    let dst = (h * t + i) * d;
                    d_k_own[dst..dst + d].copy_from_slice(&d_k_full[src..src + d]);
                    d_v_own[dst..dst + d].copy_from_slice(&d_v_full[src..src + d]);
                }
            }

            // RoPE is an orthogonal rotation: pull cotangents back with the
            // inverse rotation, then undo the [T, hh] -> [H, T, D] reshape.
            self.rope(&mut d_q, pos, heads, t, d, true);
            self.rope(&mut d_k_own, pos, heads, t, d, true);
            let d_qmat = heads_to(&d_q, heads, t, d);
            let d_kmat = heads_to(&d_k_own, heads, t, d);
            let d_vmat = heads_to(&d_v_own, heads, t, d);

            let mut d_xn1 = self.mm_nt(&d_qmat, wq, t, hh, hh);
            let d_xn1_k = self.mm_nt(&d_kmat, wk, t, hh, hh);
            let d_xn1_v = self.mm_nt(&d_vmat, wv, t, hh, hh);
            for idx in 0..t * hh {
                d_xn1[idx] += d_xn1_k[idx] + d_xn1_v[idx];
            }
            self.acc_tn(&lc.xn1, &d_qmat, t, hh, hh, &mut d_params[P_WQ][li * hh * hh..]);
            self.acc_tn(&lc.xn1, &d_kmat, t, hh, hh, &mut d_params[P_WK][li * hh * hh..]);
            self.acc_tn(&lc.xn1, &d_vmat, t, hh, hh, &mut d_params[P_WV][li * hh * hh..]);
            rmsnorm_bwd(
                &lc.x_in,
                &params[P_NORM1][li * hh..(li + 1) * hh],
                &lc.inv1,
                &d_xn1,
                t,
                hh,
                &mut d_x_in,
                &mut d_params[P_NORM1][li * hh..(li + 1) * hh],
            );
            d_x = d_x_in;
        }

        (d_x, d_kv_in)
    }

    /// Attention core backward for one layer: cotangents on q (pre-RoPE
    /// undo) and the full K/V. Returns (d_q [H, T, D], d_k_full [H, S, D],
    /// d_v_full [H, S, D]). The fast path partitions by heads — K/V rows
    /// accumulate over query rows *within* a head, so per-head serial
    /// execution preserves the serial op order exactly.
    fn attn_bwd(
        &self,
        lc: &LayerCache,
        d_attn_flat: &[f64],
        heads: usize,
        t: usize,
        s_len: usize,
        d: usize,
        hh: usize,
        scale: f64,
        d_p_buf: &mut [f64],
    ) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
        if let Some(fp) = self.active_fast() {
            if let Some(out) = self.catch_fast("attn_bwd", || {
                attn_bwd_par(fp, lc, d_attn_flat, heads, t, s_len, d, hh, scale)
            }) {
                return out;
            }
        }
        let mut d_q = vec![0.0f64; heads * t * d];
        let mut d_k_full = vec![0.0f64; heads * s_len * d];
        let mut d_v_full = vec![0.0f64; heads * s_len * d];
        for h in 0..heads {
            for i in 0..t {
                let d_out = &d_attn_flat[i * hh + h * d..i * hh + (h + 1) * d];
                let prow = &lc.probs[(h * t + i) * s_len..(h * t + i + 1) * s_len];
                let mut rowdot = 0.0f64;
                for j in 0..s_len {
                    if prow[j] == 0.0 {
                        d_p_buf[j] = 0.0;
                        continue;
                    }
                    let vrow = &lc.v_full[(h * s_len + j) * d..(h * s_len + j + 1) * d];
                    let mut acc = 0.0;
                    for dd in 0..d {
                        acc += d_out[dd] * vrow[dd];
                    }
                    d_p_buf[j] = acc;
                    rowdot += prow[j] * acc;
                    let dvrow = &mut d_v_full[(h * s_len + j) * d..(h * s_len + j + 1) * d];
                    for dd in 0..d {
                        dvrow[dd] += prow[j] * d_out[dd];
                    }
                }
                let qrow = &lc.q[(h * t + i) * d..(h * t + i + 1) * d];
                for j in 0..s_len {
                    if prow[j] == 0.0 {
                        continue;
                    }
                    let ds = prow[j] * (d_p_buf[j] - rowdot) * scale;
                    let krow = &lc.k_full[(h * s_len + j) * d..(h * s_len + j + 1) * d];
                    let dqrow = &mut d_q[(h * t + i) * d..(h * t + i + 1) * d];
                    for dd in 0..d {
                        dqrow[dd] += ds * krow[dd];
                    }
                    let dkrow = &mut d_k_full[(h * s_len + j) * d..(h * s_len + j + 1) * d];
                    for dd in 0..d {
                        dkrow[dd] += ds * qrow[dd];
                    }
                }
            }
        }
        (d_q, d_k_full, d_v_full)
    }

    /// Embedding-lookup backward (stage 0's exit point): routes the final
    /// residual cotangent into the embedding rows.
    fn embed_bwd(&self, tokens: &[i32], d_x: &[f64], d_params: &mut [Vec<f64>]) {
        let hh = self.dims.hh;
        for i in 0..tokens.len() {
            let tok = tokens[i] as usize;
            let drow = &mut d_params[P_EMBED][tok * hh..(tok + 1) * hh];
            let dxr = &d_x[i * hh..(i + 1) * hh];
            for c in 0..hh {
                drow[c] += dxr[c];
            }
        }
    }

    /// Fresh zeroed full-arity gradient buffers.
    pub fn zero_grads(&self) -> Vec<Vec<f64>> {
        self.manifest.params.iter().map(|spec| vec![0.0f64; spec.size]).collect()
    }

    /// Reverse pass. Cotangents: d(loss_sum) = 1, d(n_tok) = 0, and
    /// `g_kv_own` on this chunk's KV output (None for the full oracle).
    /// Returns (d_params, d_kv_in [L, 2, P, H, D]) — the single-stage
    /// composition of the stage pieces (head, all layers, embed).
    fn backward(
        &self,
        tokens: &[i32],
        targets: &[i32],
        pos: &[i32],
        p: usize,
        cache: &Cache,
        g_kv_own: Option<&[f64]>,
    ) -> (Vec<Vec<f64>>, Vec<f64>) {
        let l = self.dims.l;
        let mut d_params = self.zero_grads();
        let d_x = self.head_bwd(targets, &cache.x_out, &cache.head, &mut d_params);
        let (d_x, d_kv_in) =
            self.layers_bwd(0..l, &cache.layers, d_x, pos, p, g_kv_own, &mut d_params);
        self.embed_bwd(tokens, &d_x, &mut d_params);
        (d_params, d_kv_in)
    }

    /// One pipeline stage's forward for a chunk op: embedding on the first
    /// stage, the stage's contiguous layer range, LM head + loss on the
    /// last. `inputs.kv_in` must be the *stage-local* prefix KV
    /// ([Lr, 2, P, H, D]); `x_in` is the activation handed over from the
    /// previous stage (None iff `first_stage`). An empty layer range is a
    /// legal passthrough, so P > num_layers still partitions.
    pub fn stage_fwd(
        &self,
        layers: Range<usize>,
        first_stage: bool,
        last_stage: bool,
        inputs: &ChunkInputs<f64>,
        x_in: Option<Vec<f64>>,
    ) -> anyhow::Result<StageFwdOut> {
        anyhow::ensure!(
            first_stage == x_in.is_none(),
            "activation handoff mismatch: stage 0 embeds, later stages receive"
        );
        self.calls.fetch_add(1, Ordering::Relaxed);
        let p = inputs.prefix_len;
        let (k_pos, k_seg) = key_meta(&inputs.pos, &inputs.seg, p);
        let x = match x_in {
            None => self.embed_fwd(&inputs.tokens)?,
            Some(x) => x,
        };
        let (x_out, kv_own, caches) =
            self.layers_fwd(layers, x, &inputs.pos, &inputs.seg, &k_pos, &k_seg, &inputs.kv_in, p)?;
        if last_stage {
            let (loss_sum, n_tok, head) = self.head_fwd(&x_out, &inputs.targets)?;
            Ok(StageFwdOut {
                x_out: None,
                kv_own,
                cache: StageCache {
                    layers: caches,
                    x_out: Some(x_out),
                    head: Some(head),
                    loss_sum,
                    n_tok,
                },
            })
        } else {
            Ok(StageFwdOut {
                x_out: Some(x_out),
                kv_own,
                cache: StageCache {
                    layers: caches,
                    x_out: None,
                    head: None,
                    loss_sum: 0.0,
                    n_tok: 0.0,
                },
            })
        }
    }

    /// One pipeline stage's backward for a chunk op, consuming the cache its
    /// forward (or recompute-forward) produced. `d_x_out` is the cotangent
    /// from the next stage (None iff `last_stage` — the loss cotangent
    /// d(loss_sum) = 1 starts there); `g_kv_own` is the stage-local
    /// accumulated KV cotangent from later chunks ([Lr, 2, T, H, D]).
    /// Parameter gradients accumulate into the caller's full-arity buffers
    /// (each stage only ever touches its own layers' slots, plus embed on
    /// the boundary stages — the tied embedding accumulates from both ends,
    /// exactly like the monolithic backward).
    pub fn stage_bwd(
        &self,
        layers: Range<usize>,
        first_stage: bool,
        last_stage: bool,
        inputs: &ChunkInputs<f64>,
        cache: &StageCache,
        d_x_out: Option<Vec<f64>>,
        g_kv_own: &[f64],
        d_params: &mut [Vec<f64>],
    ) -> anyhow::Result<StageBwdOut> {
        anyhow::ensure!(
            last_stage == d_x_out.is_none(),
            "gradient handoff mismatch: the last stage starts from the loss"
        );
        self.calls.fetch_add(1, Ordering::Relaxed);
        let p = inputs.prefix_len;
        let d_x = match d_x_out {
            None => {
                let x_out = cache.x_out.as_ref().expect("last-stage cache carries x_out");
                let head = cache.head.as_ref().expect("last-stage cache carries head");
                self.head_bwd(&inputs.targets, x_out, head, d_params)
            }
            Some(d) => d,
        };
        let (d_x, d_kv_in) = self.layers_bwd(
            layers,
            &cache.layers,
            d_x,
            &inputs.pos,
            p,
            Some(g_kv_own),
            d_params,
        );
        if first_stage {
            self.embed_bwd(&inputs.tokens, &d_x, d_params);
            Ok(StageBwdOut { d_x_in: None, d_kv_in })
        } else {
            Ok(StageBwdOut { d_x_in: Some(d_x), d_kv_in })
        }
    }
}

/// Key metadata for a chunk with a `p`-token stored prefix: prefix keys
/// carry positions 0..P and segment 0, own keys follow the chunk's pos/seg.
fn key_meta(pos: &[i32], seg: &[i32], p: usize) -> (Vec<i32>, Vec<i32>) {
    let s_len = p + pos.len();
    let mut k_pos = Vec::with_capacity(s_len);
    let mut k_seg = Vec::with_capacity(s_len);
    for j in 0..p {
        k_pos.push(j as i32);
        k_seg.push(0i32);
    }
    k_pos.extend_from_slice(pos);
    k_seg.extend_from_slice(seg);
    (k_pos, k_seg)
}

impl Backend for ReferenceBackend {
    type Elem = f64;

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn set_params(&mut self, params: &FlatParams) -> anyhow::Result<()> {
        anyhow::ensure!(params.0.len() == self.manifest.params.len(), "param arity");
        for (spec, host) in self.manifest.params.iter().zip(&params.0) {
            anyhow::ensure!(
                host.len() == spec.size,
                "param {} size {} != {}",
                spec.name,
                host.len(),
                spec.size
            );
        }
        self.params =
            Some(params.0.iter().map(|p| p.iter().map(|&x| x as f64).collect()).collect());
        Ok(())
    }

    fn fwd_kv(&self, inputs: &ChunkInputs<f64>) -> anyhow::Result<FwdKvOut<f64>> {
        self.check_chunk(inputs)?;
        self.calls.fetch_add(1, Ordering::Relaxed);
        let (loss_sum, n_tok, kv_own, _cache) = self.forward(
            &inputs.tokens,
            &inputs.targets,
            &inputs.pos,
            &inputs.seg,
            &inputs.kv_in,
            inputs.prefix_len,
        )?;
        Ok(FwdKvOut { loss_sum, n_tok, kv_own })
    }

    fn chunk_vjp(
        &self,
        inputs: &ChunkInputs<f64>,
        g_kv_own: &[f64],
    ) -> anyhow::Result<ChunkVjpOut<f64>> {
        self.check_chunk(inputs)?;
        let c = self.manifest.chunk_size;
        anyhow::ensure!(
            g_kv_own.len() == self.kv_elements(c),
            "g_kv_own len {} != {}",
            g_kv_own.len(),
            self.kv_elements(c)
        );
        self.calls.fetch_add(1, Ordering::Relaxed);
        let (loss_sum, n_tok, kv_own, cache) = self.forward(
            &inputs.tokens,
            &inputs.targets,
            &inputs.pos,
            &inputs.seg,
            &inputs.kv_in,
            inputs.prefix_len,
        )?;
        let (d_params, d_kv_in) = self.backward(
            &inputs.tokens,
            &inputs.targets,
            &inputs.pos,
            inputs.prefix_len,
            &cache,
            Some(g_kv_own),
        );
        Ok(ChunkVjpOut { loss_sum, n_tok, kv_own, d_params, d_kv_in })
    }

    fn full_step(
        &self,
        s: usize,
        tokens: &[i32],
        targets: &[i32],
        pos: &[i32],
        seg: &[i32],
    ) -> anyhow::Result<FullStepOut<f64>> {
        anyhow::ensure!(s > 0, "full_step needs at least one token");
        anyhow::ensure!(tokens.len() == s, "tokens len {} != {s}", tokens.len());
        anyhow::ensure!(targets.len() == s, "targets len {} != {s}", targets.len());
        anyhow::ensure!(pos.len() == s, "pos len {} != {s}", pos.len());
        anyhow::ensure!(seg.len() == s, "seg len {} != {s}", seg.len());
        self.calls.fetch_add(1, Ordering::Relaxed);
        let (loss_sum, n_tok, _kv_own, cache) =
            self.forward(tokens, targets, pos, seg, &[], 0)?;
        let (d_params, _d_kv_in) = self.backward(tokens, targets, pos, 0, &cache, None);
        Ok(FullStepOut { loss_sum, n_tok, d_params })
    }

    fn calls(&self) -> u64 {
        self.calls.load(Ordering::Relaxed)
    }

    fn fast_path_active(&self) -> bool {
        self.fast.is_some() && !self.fast_degraded.load(Ordering::Relaxed)
    }
}

// ----- math helpers ---------------------------------------------------------

/// Visibility of key (kpos, kseg) to query (qpos, qseg) — the Layer-1
/// kernel's mask: causal AND (same live segment OR self-token).
fn attend(qpos: i32, qseg: i32, kpos: i32, kseg: i32) -> bool {
    let causal = kpos <= qpos;
    let same_seg = qseg == kseg && qseg >= 0;
    let self_tok = qpos == kpos && qseg == kseg;
    causal && (same_seg || self_tok)
}

fn sigmoid(x: f64) -> f64 {
    1.0 / (1.0 + (-x).exp())
}

fn silu(x: f64) -> f64 {
    x * sigmoid(x)
}

/// RMSNorm forward over [T, N]: returns (x * rsqrt(mean(x^2) + eps) * w,
/// per-row rsqrt factors).
fn rmsnorm_fwd(x: &[f64], w: &[f64], t: usize, n: usize) -> (Vec<f64>, Vec<f64>) {
    let mut out = vec![0.0f64; t * n];
    let mut inv = vec![0.0f64; t];
    for i in 0..t {
        let xr = &x[i * n..(i + 1) * n];
        let mut ms = 0.0;
        for &xv in xr {
            ms += xv * xv;
        }
        ms /= n as f64;
        let iv = 1.0 / (ms + RMS_EPS).sqrt();
        inv[i] = iv;
        let orow = &mut out[i * n..(i + 1) * n];
        for c in 0..n {
            orow[c] = xr[c] * iv * w[c];
        }
    }
    (out, inv)
}

/// RMSNorm backward: accumulates into `dx` ([T, N]) and `dw` ([N]).
fn rmsnorm_bwd(
    x: &[f64],
    w: &[f64],
    inv: &[f64],
    dy: &[f64],
    t: usize,
    n: usize,
    dx: &mut [f64],
    dw: &mut [f64],
) {
    for i in 0..t {
        let xr = &x[i * n..(i + 1) * n];
        let dyr = &dy[i * n..(i + 1) * n];
        let iv = inv[i];
        let mut dot = 0.0;
        for c in 0..n {
            dot += dyr[c] * xr[c] * w[c];
        }
        let coef = iv * iv * iv * dot / n as f64;
        let dxr = &mut dx[i * n..(i + 1) * n];
        for c in 0..n {
            dxr[c] += dyr[c] * w[c] * iv - coef * xr[c];
            dw[c] += dyr[c] * xr[c] * iv;
        }
    }
}

/// Rotary embedding over [H, T, D] in place; `inverse` applies the
/// transpose rotation (exact cotangent pullback — rotations are orthogonal).
fn rope_apply(xs: &mut [f64], pos: &[i32], heads: usize, t: usize, d: usize, inverse: bool) {
    let half = d / 2;
    for i in 0..t {
        let pf = pos[i] as f64;
        for j in 0..half {
            let freq = ROPE_THETA.powf(-(j as f64) / half as f64);
            let angle = pf * freq;
            let (mut sin, cos) = angle.sin_cos();
            if inverse {
                sin = -sin;
            }
            for h in 0..heads {
                let base = (h * t + i) * d;
                let x1 = xs[base + j];
                let x2 = xs[base + half + j];
                xs[base + j] = x1 * cos - x2 * sin;
                xs[base + half + j] = x1 * sin + x2 * cos;
            }
        }
    }
}

/// [T, heads*d] -> [H, T, D].
fn heads_of(mat: &[f64], heads: usize, t: usize, d: usize) -> Vec<f64> {
    let hh = heads * d;
    let mut out = vec![0.0f64; heads * t * d];
    for h in 0..heads {
        for i in 0..t {
            let dst = (h * t + i) * d;
            let src = i * hh + h * d;
            out[dst..dst + d].copy_from_slice(&mat[src..src + d]);
        }
    }
    out
}

/// [H, T, D] -> [T, heads*d].
fn heads_to(hm: &[f64], heads: usize, t: usize, d: usize) -> Vec<f64> {
    let hh = heads * d;
    let mut out = vec![0.0f64; t * hh];
    for h in 0..heads {
        for i in 0..t {
            let src = (h * t + i) * d;
            let dst = i * hh + h * d;
            out[dst..dst + d].copy_from_slice(&hm[src..src + d]);
        }
    }
    out
}

/// [T, A] @ [A, B] -> [T, B].
fn matmul(x: &[f64], w: &[f64], t: usize, a: usize, b: usize) -> Vec<f64> {
    debug_assert_eq!(x.len(), t * a);
    debug_assert!(w.len() >= a * b);
    let mut out = vec![0.0f64; t * b];
    for i in 0..t {
        let xrow = &x[i * a..(i + 1) * a];
        let orow = &mut out[i * b..(i + 1) * b];
        for (r, &xv) in xrow.iter().enumerate() {
            let wrow = &w[r * b..(r + 1) * b];
            for (ov, &wv) in orow.iter_mut().zip(wrow) {
                *ov += xv * wv;
            }
        }
    }
    out
}

/// dy [T, B] @ w[A, B]^T -> [T, A] (gradient through `x @ w`).
fn matmul_nt(dy: &[f64], w: &[f64], t: usize, a: usize, b: usize) -> Vec<f64> {
    debug_assert_eq!(dy.len(), t * b);
    debug_assert!(w.len() >= a * b);
    let mut out = vec![0.0f64; t * a];
    for i in 0..t {
        let dyr = &dy[i * b..(i + 1) * b];
        let orow = &mut out[i * a..(i + 1) * a];
        for r in 0..a {
            let wrow = &w[r * b..(r + 1) * b];
            let mut acc = 0.0;
            for (dv, wv) in dyr.iter().zip(wrow) {
                acc += dv * wv;
            }
            orow[r] = acc;
        }
    }
    out
}

/// dw[A, B] += x[T, A]^T @ dy[T, B] (weight gradient through `x @ w`); `dw`
/// may be a leading slice of a larger stacked buffer.
fn accum_tn(x: &[f64], dy: &[f64], t: usize, a: usize, b: usize, dw: &mut [f64]) {
    debug_assert_eq!(x.len(), t * a);
    debug_assert_eq!(dy.len(), t * b);
    debug_assert!(dw.len() >= a * b);
    for i in 0..t {
        let xrow = &x[i * a..(i + 1) * a];
        let dyr = &dy[i * b..(i + 1) * b];
        for (r, &xv) in xrow.iter().enumerate() {
            let dwrow = &mut dw[r * b..(r + 1) * b];
            for (dwv, &dv) in dwrow.iter_mut().zip(dyr) {
                *dwv += xv * dv;
            }
        }
    }
}

// ----- parallel fast-path bodies -------------------------------------------
//
// Each function mirrors its serial counterpart exactly — same per-row loop
// order, same accumulation expressions — and partitions by *output* rows
// (`fastpath::split_rows`), so results are bit-identical to serial whatever
// the worker count. Scratch buffers (attention scores, logits) are per-part:
// their contents are pure functions of the inputs, so recomputing them per
// part changes nothing.

/// Parallel attention forward: one output row per (head, query) pair.
/// Returns (probs [H, T, S], attn_flat [T, hh]) exactly as the serial loop.
fn attn_fwd_par(
    fp: &FastPath,
    q: &[f64],
    k_full: &[f64],
    v_full: &[f64],
    pos: &[i32],
    seg: &[i32],
    k_pos: &[i32],
    k_seg: &[i32],
    heads: usize,
    t: usize,
    s_len: usize,
    d: usize,
    scale: f64,
) -> (Vec<f64>, Vec<f64>) {
    let rows = heads * t;
    let mut probs = vec![0.0f64; rows * s_len];
    let mut attn_heads = vec![0.0f64; rows * d];
    let parts = fastpath::parts_for(rows, 2 * s_len * d);
    {
        let p_slots = Mutex::new(fastpath::split_rows(&mut probs, rows, s_len, parts));
        let o_slots = Mutex::new(fastpath::split_rows(&mut attn_heads, rows, d, parts));
        fp.for_parts(parts, |pi| {
            let (start, n, probs_p) = fastpath::take_slot(&p_slots, pi);
            let (_o_start, _o_n, out_p) = fastpath::take_slot(&o_slots, pi);
            let mut s_buf = vec![0.0f64; s_len];
            for r in 0..n {
                let row = start + r;
                let h = row / t;
                let i = row % t;
                let qrow = &q[row * d..(row + 1) * d];
                let mut mx = f64::NEG_INFINITY;
                for j in 0..s_len {
                    if !attend(pos[i], seg[i], k_pos[j], k_seg[j]) {
                        s_buf[j] = f64::NEG_INFINITY;
                        continue;
                    }
                    let krow = &k_full[(h * s_len + j) * d..(h * s_len + j + 1) * d];
                    let mut dot = 0.0;
                    for dd in 0..d {
                        dot += qrow[dd] * krow[dd];
                    }
                    s_buf[j] = dot * scale;
                    if s_buf[j] > mx {
                        mx = s_buf[j];
                    }
                }
                let prow = &mut probs_p[r * s_len..(r + 1) * s_len];
                if mx == f64::NEG_INFINITY {
                    continue; // fully masked row: zero probs, zero output
                }
                let mut sum = 0.0;
                for j in 0..s_len {
                    if s_buf[j] == f64::NEG_INFINITY {
                        prow[j] = 0.0;
                    } else {
                        let e = (s_buf[j] - mx).exp();
                        prow[j] = e;
                        sum += e;
                    }
                }
                let out = &mut out_p[r * d..(r + 1) * d];
                for j in 0..s_len {
                    if prow[j] == 0.0 {
                        continue;
                    }
                    prow[j] /= sum;
                    let vrow = &v_full[(h * s_len + j) * d..(h * s_len + j + 1) * d];
                    for dd in 0..d {
                        out[dd] += prow[j] * vrow[dd];
                    }
                }
            }
        });
    }
    let attn_flat = heads_to(&attn_heads, heads, t, d);
    (probs, attn_flat)
}

/// Parallel attention backward, partitioned by heads: K/V gradient rows
/// accumulate over query positions *within* one head, so a per-head serial
/// sweep preserves the serial accumulation order per element.
fn attn_bwd_par(
    fp: &FastPath,
    lc: &LayerCache,
    d_attn_flat: &[f64],
    heads: usize,
    t: usize,
    s_len: usize,
    d: usize,
    hh: usize,
    scale: f64,
) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
    let mut d_q = vec![0.0f64; heads * t * d];
    let mut d_k_full = vec![0.0f64; heads * s_len * d];
    let mut d_v_full = vec![0.0f64; heads * s_len * d];
    let parts = fastpath::parts_for(heads, 4 * t * s_len * d);
    {
        let q_slots = Mutex::new(fastpath::split_rows(&mut d_q, heads, t * d, parts));
        let k_slots = Mutex::new(fastpath::split_rows(&mut d_k_full, heads, s_len * d, parts));
        let v_slots = Mutex::new(fastpath::split_rows(&mut d_v_full, heads, s_len * d, parts));
        fp.for_parts(parts, |pi| {
            let (h0, nh, dq_p) = fastpath::take_slot(&q_slots, pi);
            let (_k0, _kn, dk_p) = fastpath::take_slot(&k_slots, pi);
            let (_v0, _vn, dv_p) = fastpath::take_slot(&v_slots, pi);
            let mut d_p_buf = vec![0.0f64; s_len];
            for hr in 0..nh {
                let h = h0 + hr;
                for i in 0..t {
                    let d_out = &d_attn_flat[i * hh + h * d..i * hh + (h + 1) * d];
                    let prow = &lc.probs[(h * t + i) * s_len..(h * t + i + 1) * s_len];
                    let mut rowdot = 0.0f64;
                    for j in 0..s_len {
                        if prow[j] == 0.0 {
                            d_p_buf[j] = 0.0;
                            continue;
                        }
                        let vrow = &lc.v_full[(h * s_len + j) * d..(h * s_len + j + 1) * d];
                        let mut acc = 0.0;
                        for dd in 0..d {
                            acc += d_out[dd] * vrow[dd];
                        }
                        d_p_buf[j] = acc;
                        rowdot += prow[j] * acc;
                        let dvrow = &mut dv_p[(hr * s_len + j) * d..(hr * s_len + j + 1) * d];
                        for dd in 0..d {
                            dvrow[dd] += prow[j] * d_out[dd];
                        }
                    }
                    let qrow = &lc.q[(h * t + i) * d..(h * t + i + 1) * d];
                    for j in 0..s_len {
                        if prow[j] == 0.0 {
                            continue;
                        }
                        let ds = prow[j] * (d_p_buf[j] - rowdot) * scale;
                        let krow = &lc.k_full[(h * s_len + j) * d..(h * s_len + j + 1) * d];
                        let dqrow = &mut dq_p[(hr * t + i) * d..(hr * t + i + 1) * d];
                        for dd in 0..d {
                            dqrow[dd] += ds * krow[dd];
                        }
                        let dkrow = &mut dk_p[(hr * s_len + j) * d..(hr * s_len + j + 1) * d];
                        for dd in 0..d {
                            dkrow[dd] += ds * qrow[dd];
                        }
                    }
                }
            }
        });
    }
    (d_q, d_k_full, d_v_full)
}

/// Serial tied-head forward rows: per-token logits, vocab softmax into
/// `probs_v`, summed cross-entropy. Returns (loss_sum, n_tok).
fn head_fwd_rows(
    embed: &[f64],
    xf: &[f64],
    targets: &[i32],
    t: usize,
    hh: usize,
    v: usize,
    probs_v: &mut [f64],
) -> (f64, f64) {
    let mut logits = vec![0.0f64; v];
    let mut loss_sum = 0.0f64;
    let mut n_tok = 0.0f64;
    for i in 0..t {
        let xfr = &xf[i * hh..(i + 1) * hh];
        let mut mx = f64::NEG_INFINITY;
        for j in 0..v {
            let erow = &embed[j * hh..(j + 1) * hh];
            let mut dot = 0.0;
            for c in 0..hh {
                dot += xfr[c] * erow[c];
            }
            logits[j] = dot;
            if dot > mx {
                mx = dot;
            }
        }
        let mut sum = 0.0;
        let prow = &mut probs_v[i * v..(i + 1) * v];
        for j in 0..v {
            let e = (logits[j] - mx).exp();
            prow[j] = e;
            sum += e;
        }
        for pv in prow.iter_mut() {
            *pv /= sum;
        }
        if targets[i] >= 0 {
            let lse = mx + sum.ln();
            loss_sum += lse - logits[targets[i] as usize];
            n_tok += 1.0;
        }
    }
    (loss_sum, n_tok)
}

/// Parallel tied-head forward, partitioned over token rows; per-row losses
/// land in a side buffer and fold serially in token order, so the sum sees
/// the exact serial addition sequence.
fn head_fwd_rows_par(
    fp: &FastPath,
    embed: &[f64],
    xf: &[f64],
    targets: &[i32],
    t: usize,
    hh: usize,
    v: usize,
    probs_v: &mut [f64],
) -> (f64, f64) {
    let mut loss_rows = vec![0.0f64; t];
    let parts = fastpath::parts_for(t, 2 * v * hh);
    {
        let p_slots = Mutex::new(fastpath::split_rows(probs_v, t, v, parts));
        let l_slots = Mutex::new(fastpath::split_rows(&mut loss_rows, t, 1, parts));
        fp.for_parts(parts, |pi| {
            let (start, n, probs_p) = fastpath::take_slot(&p_slots, pi);
            let (_l_start, _l_n, loss_p) = fastpath::take_slot(&l_slots, pi);
            let mut logits = vec![0.0f64; v];
            for r in 0..n {
                let i = start + r;
                let xfr = &xf[i * hh..(i + 1) * hh];
                let mut mx = f64::NEG_INFINITY;
                for j in 0..v {
                    let erow = &embed[j * hh..(j + 1) * hh];
                    let mut dot = 0.0;
                    for c in 0..hh {
                        dot += xfr[c] * erow[c];
                    }
                    logits[j] = dot;
                    if dot > mx {
                        mx = dot;
                    }
                }
                let mut sum = 0.0;
                let prow = &mut probs_p[r * v..(r + 1) * v];
                for j in 0..v {
                    let e = (logits[j] - mx).exp();
                    prow[j] = e;
                    sum += e;
                }
                for pv in prow.iter_mut() {
                    *pv /= sum;
                }
                if targets[i] >= 0 {
                    let lse = mx + sum.ln();
                    loss_p[r] = lse - logits[targets[i] as usize];
                }
            }
        });
    }
    let mut loss_sum = 0.0f64;
    let mut n_tok = 0.0f64;
    for i in 0..t {
        if targets[i] >= 0 {
            loss_sum += loss_rows[i];
            n_tok += 1.0;
        }
    }
    (loss_sum, n_tok)
}

/// Serial tied-head backward rows: softmax-minus-onehot through the tied
/// embedding, accumulating `d_xf` and `d_embed`.
fn head_bwd_rows(
    embed: &[f64],
    head: &HeadCache,
    targets: &[i32],
    t: usize,
    hh: usize,
    v: usize,
    d_xf: &mut [f64],
    d_embed: &mut [f64],
) {
    for i in 0..t {
        if targets[i] < 0 {
            continue;
        }
        let tgt = targets[i] as usize;
        let prow = &head.probs_v[i * v..(i + 1) * v];
        let xfr = &head.xf[i * hh..(i + 1) * hh];
        let dxfr = &mut d_xf[i * hh..(i + 1) * hh];
        for j in 0..v {
            let dl = prow[j] - if j == tgt { 1.0 } else { 0.0 };
            let erow = &embed[j * hh..(j + 1) * hh];
            let derow = &mut d_embed[j * hh..(j + 1) * hh];
            for c in 0..hh {
                dxfr[c] += dl * erow[c];
                derow[c] += dl * xfr[c];
            }
        }
    }
}

/// Parallel tied-head backward in two passes: pass 1 over token rows fills
/// `d_xf` (vocab-ascending per element, as serial) and stashes the logit
/// cotangents; pass 2 over vocab rows accumulates `d_embed` token-ascending
/// per element — again the serial order, since the serial loop visits
/// (i, j) lexicographically.
fn head_bwd_rows_par(
    fp: &FastPath,
    embed: &[f64],
    head: &HeadCache,
    targets: &[i32],
    t: usize,
    hh: usize,
    v: usize,
    d_xf: &mut [f64],
    d_embed: &mut [f64],
) {
    let mut dl_mat = vec![0.0f64; t * v];
    let parts = fastpath::parts_for(t, 2 * v * hh);
    {
        let x_slots = Mutex::new(fastpath::split_rows(d_xf, t, hh, parts));
        let dl_slots = Mutex::new(fastpath::split_rows(&mut dl_mat, t, v, parts));
        fp.for_parts(parts, |pi| {
            let (start, n, dxf_p) = fastpath::take_slot(&x_slots, pi);
            let (_dl_start, _dl_n, dl_p) = fastpath::take_slot(&dl_slots, pi);
            for r in 0..n {
                let i = start + r;
                if targets[i] < 0 {
                    continue;
                }
                let tgt = targets[i] as usize;
                let prow = &head.probs_v[i * v..(i + 1) * v];
                let dxfr = &mut dxf_p[r * hh..(r + 1) * hh];
                let dlr = &mut dl_p[r * v..(r + 1) * v];
                for j in 0..v {
                    let dl = prow[j] - if j == tgt { 1.0 } else { 0.0 };
                    dlr[j] = dl;
                    let erow = &embed[j * hh..(j + 1) * hh];
                    for c in 0..hh {
                        dxfr[c] += dl * erow[c];
                    }
                }
            }
        });
    }
    let vparts = fastpath::parts_for(v, 2 * t * hh);
    let de = &mut d_embed[..v * hh];
    let e_slots = Mutex::new(fastpath::split_rows(de, v, hh, vparts));
    fp.for_parts(vparts, |pi| {
        let (j0, nj, de_p) = fastpath::take_slot(&e_slots, pi);
        for i in 0..t {
            if targets[i] < 0 {
                continue;
            }
            let xfr = &head.xf[i * hh..(i + 1) * hh];
            let dlr = &dl_mat[i * v..(i + 1) * v];
            for jr in 0..nj {
                let dl = dlr[j0 + jr];
                let derow = &mut de_p[jr * hh..(jr + 1) * hh];
                for c in 0..hh {
                    derow[c] += dl * xfr[c];
                }
            }
        }
    });
}

/// Parallel RoPE, partitioned over (head, token) rows. Angles depend only
/// on (token, frequency), and each row's rotations touch disjoint element
/// pairs, so per-row recomputation is bit-identical to the serial sweep.
fn rope_apply_par(
    fp: &FastPath,
    xs: &mut [f64],
    pos: &[i32],
    heads: usize,
    t: usize,
    d: usize,
    inverse: bool,
) {
    let half = d / 2;
    let rows = heads * t;
    let parts = fastpath::parts_for(rows, 16 * d);
    if parts <= 1 {
        rope_apply(xs, pos, heads, t, d, inverse);
        return;
    }
    let slots = Mutex::new(fastpath::split_rows(xs, rows, d, parts));
    fp.for_parts(parts, |pi| {
        let (start, n, xs_p) = fastpath::take_slot(&slots, pi);
        for r in 0..n {
            let i = (start + r) % t;
            let pf = pos[i] as f64;
            let base = r * d;
            for j in 0..half {
                let freq = ROPE_THETA.powf(-(j as f64) / half as f64);
                let angle = pf * freq;
                let (mut sin, cos) = angle.sin_cos();
                if inverse {
                    sin = -sin;
                }
                let x1 = xs_p[base + j];
                let x2 = xs_p[base + half + j];
                xs_p[base + j] = x1 * cos - x2 * sin;
                xs_p[base + half + j] = x1 * sin + x2 * cos;
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelSpec;
    use crate::train::init_params;

    fn mini_spec() -> ModelSpec {
        ModelSpec {
            name: "ref-mini".into(),
            hidden_size: 32,
            num_layers: 2,
            num_heads: 2,
            num_kv_heads: 2,
            intermediate_size: 48,
            vocab_size: 64,
            tie_embeddings: true,
        }
    }

    fn backend(chunk: usize, max_chunks: usize) -> ReferenceBackend {
        let manifest = Manifest::for_reference(&mini_spec(), chunk, max_chunks).unwrap();
        let mut b = ReferenceBackend::new(manifest).unwrap();
        let params = init_params(&b.manifest, 42);
        b.set_params(&params).unwrap();
        b
    }

    /// Full-sequence inputs for `len` deterministic tokens.
    fn seq_inputs(len: usize, seed: u64) -> (Vec<i32>, Vec<i32>, Vec<i32>, Vec<i32>) {
        let mut rng = crate::util::rng::Rng::new(seed);
        let tokens: Vec<i32> = (0..len).map(|_| rng.gen_range(64) as i32).collect();
        let mut targets: Vec<i32> = tokens[1..].to_vec();
        targets.push(-1);
        let pos: Vec<i32> = (0..len as i32).collect();
        let seg = vec![0i32; len];
        (tokens, targets, pos, seg)
    }

    /// One standalone chunk holding a complete `len`-token sequence, padded
    /// to the chunk size with the trainer's padding convention.
    fn standalone_chunk(b: &ReferenceBackend, len: usize, seed: u64) -> ChunkInputs<f64> {
        let c = b.manifest.chunk_size;
        assert!(len <= c);
        let (toks, tgts, _pos, _seg) = seq_inputs(len, seed);
        let mut tokens = vec![0i32; c];
        let mut targets = vec![-1i32; c];
        let mut pos = vec![0i32; c];
        let mut seg = vec![-1i32; c];
        for i in 0..len {
            tokens[i] = toks[i];
            targets[i] = tgts[i];
            pos[i] = i as i32;
            seg[i] = 0;
        }
        for (i, sl) in (len..c).enumerate() {
            pos[sl] = 1_000_000 + i as i32;
        }
        ChunkInputs { tokens, targets, pos, seg, kv_in: Vec::new(), prefix_len: 0 }
    }

    #[test]
    fn loss_near_uniform_at_init_and_deterministic() {
        let b = backend(16, 2);
        let (tokens, targets, pos, seg) = seq_inputs(16, 7);
        let a = b.full_step(16, &tokens, &targets, &pos, &seg).unwrap();
        let c = b.full_step(16, &tokens, &targets, &pos, &seg).unwrap();
        assert_eq!(a.n_tok, 15.0);
        let per_tok = a.loss_sum / a.n_tok;
        // Fresh init predicts ~uniform(64) = 4.16 nats.
        assert!((3.0..5.5).contains(&per_tok), "loss/token {per_tok}");
        assert_eq!(a.loss_sum.to_bits(), c.loss_sum.to_bits(), "bitwise deterministic");
        for (x, y) in a.d_params.iter().zip(&c.d_params) {
            assert_eq!(x, y);
        }
        assert_eq!(b.calls(), 2);
    }

    #[test]
    fn padded_standalone_chunk_matches_unpadded_oracle() {
        // Padding slots must contribute nothing: a 10-token sequence inside
        // a 16-token chunk gives the same loss and grads as the raw
        // 10-token full_step.
        let b = backend(16, 2);
        let inputs = standalone_chunk(&b, 10, 3);
        let g_zero = vec![0.0f64; b.kv_elements(16)];
        let chunked = b.chunk_vjp(&inputs, &g_zero).unwrap();
        let (tokens, targets, pos, seg) = seq_inputs(10, 3);
        let oracle = b.full_step(10, &tokens, &targets, &pos, &seg).unwrap();
        assert_eq!(chunked.n_tok, oracle.n_tok);
        assert!(
            (chunked.loss_sum - oracle.loss_sum).abs() < 1e-9,
            "{} vs {}",
            chunked.loss_sum,
            oracle.loss_sum
        );
        for (pi, (gc, go)) in chunked.d_params.iter().zip(&oracle.d_params).enumerate() {
            let max_ref = go.iter().fold(0f64, |a, &x| a.max(x.abs())).max(1e-12);
            let max_err =
                gc.iter().zip(go).map(|(a, b)| (a - b).abs()).fold(0f64, f64::max);
            assert!(max_err / max_ref < 1e-9, "param {pi} rel err {}", max_err / max_ref);
        }
    }

    #[test]
    fn fwd_kv_agrees_with_chunk_vjp_forward() {
        let b = backend(16, 2);
        let inputs = standalone_chunk(&b, 16, 9);
        let f = b.fwd_kv(&inputs).unwrap();
        let g_zero = vec![0.0f64; b.kv_elements(16)];
        let v = b.chunk_vjp(&inputs, &g_zero).unwrap();
        assert_eq!(f.loss_sum.to_bits(), v.loss_sum.to_bits());
        assert_eq!(f.n_tok, v.n_tok);
        assert_eq!(f.kv_own, v.kv_own);
    }

    #[test]
    fn full_step_grads_match_finite_differences() {
        let b = backend(8, 2);
        let (tokens, targets, pos, seg) = seq_inputs(8, 11);
        let analytic = b.full_step(8, &tokens, &targets, &pos, &seg).unwrap();
        let base_params = init_params(&b.manifest, 42);
        // Spot-check one coordinate per parameter tensor.
        let eps = 1e-5f64;
        for pi in 0..base_params.0.len() {
            let coord = base_params.0[pi].len() / 3;
            let probe = |delta: f32| -> f64 {
                let mut p = base_params.clone();
                p.0[pi][coord] += delta;
                let manifest = Manifest::for_reference(&mini_spec(), 8, 2).unwrap();
                let mut b2 = ReferenceBackend::new(manifest).unwrap();
                b2.set_params(&p).unwrap();
                b2.full_step(8, &tokens, &targets, &pos, &seg).unwrap().loss_sum
            };
            let up = probe(eps as f32);
            let down = probe(-(eps as f32));
            let fd = (up - down) / (2.0 * eps);
            let an = analytic.d_params[pi][coord];
            let denom = an.abs().max(fd.abs()).max(1e-4);
            assert!(
                (fd - an).abs() / denom < 1e-2,
                "param {pi} coord {coord}: fd {fd} vs analytic {an}"
            );
        }
    }

    #[test]
    fn bucket_and_shape_contract_enforced() {
        let b = backend(16, 4);
        let mut inputs = standalone_chunk(&b, 16, 1);
        // Non-bucket prefix.
        inputs.prefix_len = 7;
        inputs.kv_in = vec![0.0; b.kv_elements(7)];
        assert!(b.fwd_kv(&inputs).is_err());
        // Bucketed prefix but wrong buffer length.
        inputs.prefix_len = 16;
        inputs.kv_in = vec![0.0; 3];
        assert!(b.fwd_kv(&inputs).is_err());
        // Wrong chunk length.
        let mut short = standalone_chunk(&b, 16, 1);
        short.tokens.pop();
        assert!(b.fwd_kv(&short).is_err());
    }

    #[test]
    fn set_params_required_and_validated() {
        let manifest = Manifest::for_reference(&mini_spec(), 8, 1).unwrap();
        let b = ReferenceBackend::new(manifest.clone()).unwrap();
        let inputs = ChunkInputs::<f64> {
            tokens: vec![0; 8],
            targets: vec![-1; 8],
            pos: (0..8).collect(),
            seg: vec![0; 8],
            kv_in: Vec::new(),
            prefix_len: 0,
        };
        assert!(b.fwd_kv(&inputs).unwrap_err().to_string().contains("set_params"));
        let mut b2 = ReferenceBackend::new(manifest).unwrap();
        let bad = FlatParams(vec![vec![0.0; 3]]);
        assert!(b2.set_params(&bad).is_err());
    }

    #[test]
    fn attend_mask_matches_kernel_semantics() {
        // Causal within a live segment.
        assert!(attend(5, 0, 3, 0));
        assert!(!attend(3, 0, 5, 0));
        // No cross-segment attention.
        assert!(!attend(5, 1, 3, 0));
        // Padding (seg -1) self-attends only.
        assert!(attend(1_000_000, -1, 1_000_000, -1));
        assert!(!attend(1_000_001, -1, 1_000_000, -1));
        assert!(!attend(5, 0, 1_000_000, -1));
    }

    #[test]
    fn rope_inverse_is_exact() {
        let mut xs: Vec<f64> = (0..2 * 3 * 4).map(|i| (i as f64) * 0.37 - 2.0).collect();
        let orig = xs.clone();
        let pos = vec![0, 17, 91234];
        rope_apply(&mut xs, &pos, 2, 3, 4, false);
        rope_apply(&mut xs, &pos, 2, 3, 4, true);
        for (a, b) in xs.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    fn fast_backend(chunk: usize, max_chunks: usize, threads: usize) -> ReferenceBackend {
        let mut b = backend(chunk, max_chunks);
        b.enable_fast_path_with_threads(threads);
        b
    }

    fn assert_grads_close(got: &[Vec<f64>], want: &[Vec<f64>], tol: f64, what: &str) {
        for (pi, (g, w)) in got.iter().zip(want).enumerate() {
            let max_ref = w.iter().fold(0f64, |a, &x| a.max(x.abs())).max(1e-12);
            let max_err = g.iter().zip(w).map(|(a, b)| (a - b).abs()).fold(0f64, f64::max);
            assert!(
                max_err / max_ref < tol,
                "{what}: param {pi} rel err {}",
                max_err / max_ref
            );
        }
    }

    #[test]
    fn fast_path_matches_scalar_oracle() {
        let slow = backend(16, 2);
        let fast = fast_backend(16, 2, 4);
        assert!(!slow.fast_path_active());
        assert!(fast.fast_path_active());

        let inputs = standalone_chunk(&slow, 16, 9);
        let g_zero = vec![0.0f64; slow.kv_elements(16)];
        let vs = slow.chunk_vjp(&inputs, &g_zero).unwrap();
        let vf = fast.chunk_vjp(&inputs, &g_zero).unwrap();
        assert!((vs.loss_sum - vf.loss_sum).abs() < 1e-9);
        assert_eq!(vs.n_tok, vf.n_tok);
        assert_grads_close(&vf.d_params, &vs.d_params, 1e-9, "chunk_vjp");

        let (tokens, targets, pos, seg) = seq_inputs(32, 5);
        let fs = slow.full_step(32, &tokens, &targets, &pos, &seg).unwrap();
        let ff = fast.full_step(32, &tokens, &targets, &pos, &seg).unwrap();
        assert!((fs.loss_sum - ff.loss_sum).abs() < 1e-9);
        assert_grads_close(&ff.d_params, &fs.d_params, 1e-9, "full_step");
    }

    #[test]
    fn fast_path_is_bit_invariant_across_thread_counts() {
        // The partition split is a pure function of the problem size, so
        // 1 worker and 4 workers must produce byte-identical results (the
        // CI determinism job enforces the same property end-to-end).
        let f1 = fast_backend(16, 2, 1);
        let f4 = fast_backend(16, 2, 4);
        let (tokens, targets, pos, seg) = seq_inputs(32, 13);
        let a = f1.full_step(32, &tokens, &targets, &pos, &seg).unwrap();
        let b = f4.full_step(32, &tokens, &targets, &pos, &seg).unwrap();
        assert_eq!(a.loss_sum.to_bits(), b.loss_sum.to_bits());
        for (pi, (x, y)) in a.d_params.iter().zip(&b.d_params).enumerate() {
            assert!(
                x.iter().zip(y).all(|(u, v)| u.to_bits() == v.to_bits()),
                "param {pi} differs between 1 and 4 workers"
            );
        }
    }

    #[test]
    fn fast_path_kv_chain_matches_scalar_chain() {
        // Dependent chunks: chunk 1 consumes chunk 0's KV; the injected
        // g_kv_own cotangent exercises attn_bwd's prefix split on both
        // paths.
        let slow = backend(8, 2);
        let fast = fast_backend(8, 2, 3);
        let (tokens, targets, pos, seg) = seq_inputs(16, 21);
        let mk = |r: std::ops::Range<usize>, kv: Vec<f64>, p: usize| ChunkInputs::<f64> {
            tokens: tokens[r.clone()].to_vec(),
            targets: targets[r.clone()].to_vec(),
            pos: pos[r.clone()].to_vec(),
            seg: seg[r].to_vec(),
            kv_in: kv,
            prefix_len: p,
        };
        let run = |b: &ReferenceBackend| {
            let c0 = mk(0..8, Vec::new(), 0);
            let f0 = b.fwd_kv(&c0).unwrap();
            let c1 = mk(8..16, f0.kv_own.clone(), 8);
            let g_zero = vec![0.0f64; b.kv_elements(8)];
            let v1 = b.chunk_vjp(&c1, &g_zero).unwrap();
            let v0 = b.chunk_vjp(&c0, &v1.d_kv_in).unwrap();
            (v0, v1)
        };
        let (s0, s1) = run(&slow);
        let (f0, f1) = run(&fast);
        assert!((s0.loss_sum - f0.loss_sum).abs() < 1e-9);
        assert!((s1.loss_sum - f1.loss_sum).abs() < 1e-9);
        assert_grads_close(&f0.d_params, &s0.d_params, 1e-9, "chunk 0");
        assert_grads_close(&f1.d_params, &s1.d_params, 1e-9, "chunk 1");
        let max_err = f1
            .d_kv_in
            .iter()
            .zip(&s1.d_kv_in)
            .map(|(a, b)| (a - b).abs())
            .fold(0f64, f64::max);
        assert!(max_err < 1e-9, "d_kv_in err {max_err}");
    }

    /// A fast-path worker panic — at whatever kernel the armed occurrence
    /// lands in — must degrade the backend to the scalar path and still
    /// produce bit-identical results: pure kernels rerun serially, and the
    /// accumulating ones (accum_tn, rope, head_bwd) roll back their
    /// partial writes first.
    #[cfg(feature = "fault-inject")]
    #[test]
    fn injected_pool_panic_degrades_to_scalar_bit_identically() {
        use crate::util::fault;
        let _g = fault::TEST_REGISTRY_LOCK.lock().unwrap_or_else(|p| p.into_inner());
        let slow = backend(16, 2);
        let (tokens, targets, pos, seg) = seq_inputs(32, 5);
        let want = slow.full_step(32, &tokens, &targets, &pos, &seg).unwrap();
        // Early, mid-forward, and mid-backward part evaluations.
        for occurrence in [1u64, 17, 97] {
            fault::install(fault::FaultPlan::new(9).arm(fault::POOL_PANIC, occurrence));
            let fast = fast_backend(16, 2, 4);
            let got = fast.full_step(32, &tokens, &targets, &pos, &seg).unwrap();
            assert!(fast.fast_path_degraded(), "occurrence {occurrence} must fire");
            assert!(!fast.fast_path_active(), "degraded backend reports scalar path");
            assert_eq!(
                want.loss_sum.to_bits(),
                got.loss_sum.to_bits(),
                "occurrence {occurrence}: loss differs from the scalar oracle"
            );
            for (pi, (x, y)) in want.d_params.iter().zip(&got.d_params).enumerate() {
                assert!(
                    x.iter().zip(y).all(|(u, v)| u.to_bits() == v.to_bits()),
                    "occurrence {occurrence}: param {pi} differs from the scalar oracle"
                );
            }
        }
        fault::clear();
    }
}
