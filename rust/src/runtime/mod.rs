//! Execution backends for the trainer.
//!
//! `train::Trainer` consumes exactly three programs per model — the
//! three-program contract captured by the [`Backend`] trait:
//!
//! - `fwd_kv`    — state-only forward for one chunk over a KV-prefix bucket
//!   (Algorithm 2 pass 1: activations discarded, KV + loss returned);
//! - `chunk_vjp` — forward + backward for one chunk with the explicit KV
//!   chain rule (recomputes the forward internally: the AOT realization of
//!   Algorithm 2's "forward executed twice");
//! - `full_step` — unchunked forward + backward over a whole sequence (the
//!   oracle the gradient-equivalence tests compare against).
//!
//! Two implementors exist:
//!
//! - [`Runtime`] — the XLA/PJRT runtime over the AOT artifacts produced by
//!   `python/compile/aot.py` (HLO text compiled by the XLA runtime linked
//!   into this binary via the `xla` crate over the PJRT C API). Compiled
//!   only with the `pjrt` cargo feature; without it, [`Runtime::load`]
//!   returns a descriptive error and everything that does not execute real
//!   chunks works unchanged.
//! - [`ReferenceBackend`] — a pure-Rust, dependency-free, deterministic
//!   implementation of the same transformer (`runtime/reference.rs`) with
//!   exact analytic gradients in f64, so `chunkflow train --backend
//!   reference` runs a full Algorithm-2 optimizer step on any machine and
//!   CI can enforce the paper's gradient-equivalence and memory claims.
//!
//! The KV/gradient element type is an associated type of the backend
//! ([`Backend::Elem`]): f32 on PJRT (device buffers), f64 on the reference
//! backend (so chunked-vs-unchunked comparisons are exact to rounding noise
//! far below the 1e-6 test tolerance).
//!
//! Artifact set per PJRT model (see `manifest_<model>.json`):
//! - `fwd_kv_p{P}.hlo.txt` — state-only forward for KV-prefix bucket `P`;
//! - `chunk_vjp_p{P}.hlo.txt` — forward+backward with explicit KV chain rule;
//! - `full_step_s{S}.hlo.txt` — unchunked oracle (integration tests only).

pub mod fastpath;
mod manifest;
#[cfg(feature = "pjrt")]
mod pjrt;
mod reference;
mod stage;
#[cfg(all(feature = "pjrt", not(feature = "xla-runtime")))]
mod xla_stub;

pub use fastpath::FastPath;
pub use manifest::{Manifest, ParamSpec};
#[cfg(feature = "pjrt")]
pub use pjrt::Runtime;
pub use reference::{ReferenceBackend, StageBwdOut, StageCache, StageFwdOut};
pub use stage::{
    stage_layer_range, ActivationHandoff, GradHandoff, StageBackend, StagePartition,
};

/// Element type of KV-state and gradient buffers: f32 on the PJRT runtime,
/// f64 on the reference backend. The arithmetic bounds (`AddAssign`, `Mul`)
/// let the fast-path kernels (`runtime::fastpath`) be written once and
/// instantiated at either precision.
pub trait Scalar:
    Copy
    + Clone
    + Default
    + PartialEq
    + std::fmt::Debug
    + std::ops::AddAssign
    + std::ops::Mul<Output = Self>
    + Send
    + Sync
    + 'static
{
    const ZERO: Self;
    /// Bytes per element (StateStore accounting).
    const BYTES: u64;
    /// Narrow to f32 (the optimizer state is f32 on every backend).
    fn to_f32(self) -> f32;
    /// Widen to f64 (reference-backend ingestion and test tolerances).
    fn to_f64(self) -> f64;
    /// Narrow/convert from f64 (kernel constants, test fixtures).
    fn from_f64(x: f64) -> Self;
    /// Append this element's little-endian bytes (OffloadStore spill).
    fn write_le(self, out: &mut Vec<u8>);
    /// Read one element back from `BYTES` little-endian bytes.
    fn read_le(bytes: &[u8]) -> Self;
}

impl Scalar for f32 {
    const ZERO: f32 = 0.0;
    const BYTES: u64 = 4;
    fn to_f32(self) -> f32 {
        self
    }
    fn to_f64(self) -> f64 {
        self as f64
    }
    fn from_f64(x: f64) -> f32 {
        x as f32
    }
    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
    fn read_le(bytes: &[u8]) -> f32 {
        f32::from_le_bytes(bytes.try_into().expect("4 bytes per f32"))
    }
}

impl Scalar for f64 {
    const ZERO: f64 = 0.0;
    const BYTES: u64 = 8;
    fn to_f32(self) -> f32 {
        self as f32
    }
    fn to_f64(self) -> f64 {
        self
    }
    fn from_f64(x: f64) -> f64 {
        x
    }
    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
    fn read_le(bytes: &[u8]) -> f64 {
        f64::from_le_bytes(bytes.try_into().expect("8 bytes per f64"))
    }
}

/// Flat parameter buffers in `PARAM_ORDER` (host side).
#[derive(Clone, Debug)]
pub struct FlatParams(pub Vec<Vec<f32>>);

impl FlatParams {
    pub fn zeros_like(manifest: &Manifest) -> Self {
        FlatParams(manifest.params.iter().map(|p| vec![0.0; p.size]).collect())
    }

    pub fn num_elements(&self) -> usize {
        self.0.iter().map(|v| v.len()).sum()
    }
}

/// Inputs for one chunk execution (vector lengths == manifest.chunk_size).
#[derive(Clone, Debug)]
pub struct ChunkInputs<E = f32> {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub pos: Vec<i32>,
    pub seg: Vec<i32>,
    /// Flattened [L, 2, P, H, D]; P = `prefix_len` must be a bucket.
    pub kv_in: Vec<E>,
    pub prefix_len: usize,
}

/// Output of a fwd_kv call.
#[derive(Debug)]
pub struct FwdKvOut<E = f32> {
    pub loss_sum: f64,
    pub n_tok: f64,
    /// Flattened [L, 2, C, H, D].
    pub kv_own: Vec<E>,
}

/// Output of a chunk_vjp call.
#[derive(Debug)]
pub struct ChunkVjpOut<E = f32> {
    pub loss_sum: f64,
    pub n_tok: f64,
    pub kv_own: Vec<E>,
    pub d_params: Vec<Vec<E>>,
    /// Flattened [L, 2, P, H, D].
    pub d_kv_in: Vec<E>,
}

/// Output of the full-sequence oracle.
#[derive(Debug)]
pub struct FullStepOut<E = f32> {
    pub loss_sum: f64,
    pub n_tok: f64,
    pub d_params: Vec<Vec<E>>,
}

/// The three-program contract `train::Trainer` consumes. See the module
/// docs for the program semantics; all buffer layouts are row-major
/// flattenings of the shapes documented on the IO structs.
pub trait Backend {
    /// Element type of KV-state and gradient buffers.
    type Elem: Scalar;

    fn manifest(&self) -> &Manifest;

    /// Set current parameters (call after every optimizer update).
    fn set_params(&mut self, params: &FlatParams) -> anyhow::Result<()>;

    /// Algorithm 2's first-pass forward: discard activations, keep KV.
    fn fwd_kv(&self, inputs: &ChunkInputs<Self::Elem>) -> anyhow::Result<FwdKvOut<Self::Elem>>;

    /// Forward + backward for one chunk (recomputes the forward internally —
    /// the realization of Alg. 2's "forward executed twice").
    fn chunk_vjp(
        &self,
        inputs: &ChunkInputs<Self::Elem>,
        g_kv_own: &[Self::Elem],
    ) -> anyhow::Result<ChunkVjpOut<Self::Elem>>;

    /// Unchunked oracle step over a full sequence of length `s`.
    fn full_step(
        &self,
        s: usize,
        tokens: &[i32],
        targets: &[i32],
        pos: &[i32],
        seg: &[i32],
    ) -> anyhow::Result<FullStepOut<Self::Elem>>;

    /// Program executions since start (metrics).
    fn calls(&self) -> u64;

    /// True when a parallel fast path is active (surfaced in StepMetrics).
    fn fast_path_active(&self) -> bool {
        false
    }

    /// Size in elements of a KV buffer for prefix `p`.
    fn kv_elements(&self, p: usize) -> usize {
        let m = self.manifest();
        m.num_layers * 2 * p * m.num_heads * m.head_dim
    }
}

/// Offline stand-in for the PJRT runtime, compiled when the `pjrt` feature
/// is off. Presents the same API; `load` fails with an actionable message,
/// so callers that gate on artifact presence (the trainer tests, the bench
/// `runtime` suite) skip cleanly and everything else never reaches it.
#[cfg(not(feature = "pjrt"))]
pub struct Runtime {
    pub manifest: Manifest,
    /// Executions since start (metrics).
    pub calls: std::cell::Cell<u64>,
}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    pub fn load(_dir: &std::path::Path, _model: &str) -> anyhow::Result<Self> {
        anyhow::bail!(
            "PJRT runtime is unavailable: this binary was built without the \
             `pjrt` cargo feature (the `xla` crate is not vendored offline). \
             Rebuild with `--features pjrt` after adding the xla dependency \
             to rust/Cargo.toml, or use `--backend reference`."
        )
    }

    fn unavailable<T>(&self) -> anyhow::Result<T> {
        anyhow::bail!("PJRT runtime unavailable (built without the `pjrt` feature)")
    }
}

#[cfg(not(feature = "pjrt"))]
impl Backend for Runtime {
    type Elem = f32;

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn set_params(&mut self, _params: &FlatParams) -> anyhow::Result<()> {
        self.unavailable()
    }

    fn fwd_kv(&self, _inputs: &ChunkInputs) -> anyhow::Result<FwdKvOut> {
        self.unavailable()
    }

    fn chunk_vjp(&self, _inputs: &ChunkInputs, _g_kv_own: &[f32]) -> anyhow::Result<ChunkVjpOut> {
        self.unavailable()
    }

    fn full_step(
        &self,
        _s: usize,
        _tokens: &[i32],
        _targets: &[i32],
        _pos: &[i32],
        _seg: &[i32],
    ) -> anyhow::Result<FullStepOut> {
        self.unavailable()
    }

    fn calls(&self) -> u64 {
        self.calls.get()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_contract() {
        assert_eq!(Scalar::to_f32(1.5f64), 1.5f32);
        assert_eq!(Scalar::to_f32(2.5f32), 2.5f32);
        assert_eq!(<f32 as Scalar>::ZERO, 0.0);
        assert_eq!(<f64 as Scalar>::BYTES, 8);
        assert_eq!(<f32 as Scalar>::BYTES, 4);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_load_errors_with_guidance() {
        let err = Runtime::load(std::path::Path::new("artifacts"), "tiny").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("pjrt"), "{msg}");
        assert!(msg.contains("--backend reference"), "{msg}");
    }
}
