//! PJRT runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! and executes them from the training hot path. Python is never involved —
//! the HLO text is parsed and compiled by the XLA runtime linked into this
//! binary (`xla` crate over the PJRT C API).
//!
//! The XLA-backed implementation lives in [`pjrt`] and is compiled only with
//! the `pjrt` cargo feature (the `xla` crate is not available in the offline
//! registry). Without the feature, [`Runtime::load`] returns a descriptive
//! error and everything that does not execute real chunks — the simulators,
//! memory model, sweep engine and report generators — works unchanged.
//!
//! Artifact set per model (see `manifest_<model>.json`):
//! - `fwd_kv_p{P}.hlo.txt` — state-only forward for KV-prefix bucket `P`;
//! - `chunk_vjp_p{P}.hlo.txt` — forward+backward with explicit KV chain rule;
//! - `full_step_s{S}.hlo.txt` — unchunked oracle (integration tests only).
//!
//! Executables are compiled once per bucket and cached. Parameters are
//! uploaded once per optimizer step as device buffers and reused across
//! chunk calls (`execute_b`), so per-chunk overhead is only the small chunk
//! inputs.

mod manifest;
#[cfg(feature = "pjrt")]
mod pjrt;

pub use manifest::{Manifest, ParamSpec};
#[cfg(feature = "pjrt")]
pub use pjrt::Runtime;

/// Flat parameter buffers in `PARAM_ORDER` (host side).
#[derive(Clone, Debug)]
pub struct FlatParams(pub Vec<Vec<f32>>);

impl FlatParams {
    pub fn zeros_like(manifest: &Manifest) -> Self {
        FlatParams(manifest.params.iter().map(|p| vec![0.0; p.size]).collect())
    }

    pub fn num_elements(&self) -> usize {
        self.0.iter().map(|v| v.len()).sum()
    }
}

/// Inputs for one chunk execution (vector lengths == manifest.chunk_size).
#[derive(Clone, Debug)]
pub struct ChunkInputs {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub pos: Vec<i32>,
    pub seg: Vec<i32>,
    /// Flattened [L, 2, P, H, D]; P = `prefix_len` must be a bucket.
    pub kv_in: Vec<f32>,
    pub prefix_len: usize,
}

/// Output of a fwd_kv call.
#[derive(Debug)]
pub struct FwdKvOut {
    pub loss_sum: f32,
    pub n_tok: f32,
    /// Flattened [L, 2, C, H, D].
    pub kv_own: Vec<f32>,
}

/// Output of a chunk_vjp call.
#[derive(Debug)]
pub struct ChunkVjpOut {
    pub loss_sum: f32,
    pub n_tok: f32,
    pub kv_own: Vec<f32>,
    pub d_params: Vec<Vec<f32>>,
    /// Flattened [L, 2, P, H, D].
    pub d_kv_in: Vec<f32>,
}

/// Output of the full-sequence oracle.
#[derive(Debug)]
pub struct FullStepOut {
    pub loss_sum: f32,
    pub n_tok: f32,
    pub d_params: Vec<Vec<f32>>,
}

/// Offline stand-in for the PJRT runtime, compiled when the `pjrt` feature
/// is off. Presents the same API; `load` fails with an actionable message,
/// so callers that gate on artifact presence (the trainer tests, the bench
/// `runtime` suite) skip cleanly and everything else never reaches it.
#[cfg(not(feature = "pjrt"))]
pub struct Runtime {
    pub manifest: Manifest,
    /// Executions since start (metrics).
    pub calls: std::cell::Cell<u64>,
}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    pub fn load(_dir: &std::path::Path, _model: &str) -> anyhow::Result<Self> {
        anyhow::bail!(
            "PJRT runtime is unavailable: this binary was built without the \
             `pjrt` cargo feature (the `xla` crate is not vendored offline). \
             Rebuild with `--features pjrt` after adding the xla dependency \
             to rust/Cargo.toml."
        )
    }

    fn unavailable<T>(&self) -> anyhow::Result<T> {
        anyhow::bail!("PJRT runtime unavailable (built without the `pjrt` feature)")
    }

    pub fn set_params(&mut self, _params: &FlatParams) -> anyhow::Result<()> {
        self.unavailable()
    }

    pub fn fwd_kv(&self, _inputs: &ChunkInputs) -> anyhow::Result<FwdKvOut> {
        self.unavailable()
    }

    pub fn chunk_vjp(
        &self,
        _inputs: &ChunkInputs,
        _g_kv_own: &[f32],
    ) -> anyhow::Result<ChunkVjpOut> {
        self.unavailable()
    }

    pub fn full_step(
        &self,
        _s: usize,
        _tokens: &[i32],
        _targets: &[i32],
        _pos: &[i32],
        _seg: &[i32],
    ) -> anyhow::Result<FullStepOut> {
        self.unavailable()
    }

    /// Size in f32 elements of a KV buffer for prefix `p`.
    pub fn kv_elements(&self, p: usize) -> usize {
        let m = &self.manifest;
        m.num_layers * 2 * p * m.num_heads * m.head_dim
    }
}
