//! Deterministic parallel fast path for the reference backend.
//!
//! The reference backend's scalar path is the project's bit-exact oracle:
//! single-threaded f64, fixed iteration order. This module provides the
//! machinery to run the same kernels in parallel **without changing a single
//! bit of the output**:
//!
//! - every parallel region partitions its *output* rows, so writes are
//!   disjoint and no reduction ever crosses a part boundary;
//! - per-element accumulation loops keep the serial path's ascending order
//!   inside each part, so each output element sees the exact same sequence
//!   of floating-point operations;
//! - the partition count is a pure function of the problem size
//!   ([`parts_for`]) — never of the worker count — so `RAYON_NUM_THREADS=1`
//!   and `RAYON_NUM_THREADS=16` produce identical artifacts (the CI
//!   determinism job diffs them byte-for-byte).
//!
//! The kernels are generic over [`Scalar`] (rayon is unavailable offline;
//! scheduling runs on the in-tree [`ThreadPool`]). Production uses the f64
//! instantiation; the f32 instantiation is exercised by unit tests so the
//! `Scalar` seam stays honest. Inner loops are written over contiguous
//! slices with no branches in the hot body, so the auto-vectorizer can emit
//! SIMD for either element type.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Mutex};

use super::Scalar;
use crate::util::pool::ThreadPool;

/// Regions with fewer scalar ops than this run serially — below it the
/// fan-out overhead costs more than the parallelism saves.
const MIN_PAR_OPS: usize = 32 * 1024;

/// Cap on parts per region: bounds slot bookkeeping while leaving slack for
/// dynamic load balancing across workers.
const MAX_PARTS: usize = 16;

/// Worker count: `RAYON_NUM_THREADS` when set (the conventional override,
/// honored so CI can force serial execution), else available parallelism.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("RAYON_NUM_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Partition `rows` output rows of roughly `ops_per_row` scalar operations
/// each. A pure function of the problem size — never of the worker count —
/// so the same split (hence the same per-part arithmetic) happens whether
/// the parts run on 1 thread or 16.
pub fn parts_for(rows: usize, ops_per_row: usize) -> usize {
    if rows.saturating_mul(ops_per_row) < MIN_PAR_OPS {
        1
    } else {
        rows.min(MAX_PARTS)
    }
}

/// Row range `[start, end)` of part `pi` out of `parts` over `rows` rows
/// (first `rows % parts` parts get one extra row).
pub fn part_range(rows: usize, parts: usize, pi: usize) -> (usize, usize) {
    let base = rows / parts;
    let rem = rows % parts;
    let start = pi * base + pi.min(rem);
    let end = start + base + usize::from(pi < rem);
    (start, end)
}

/// One part's view of a row-major output buffer: (first row, row count,
/// the part's contiguous slice). `Option` so parts can `take` exclusively.
pub type RowSlot<'b, T> = Option<(usize, usize, &'b mut [T])>;

/// Split `buf` (row-major, `cols` elements per row) into one mutable slice
/// per part, matching [`part_range`].
pub fn split_rows<T>(buf: &mut [T], rows: usize, cols: usize, parts: usize) -> Vec<RowSlot<'_, T>> {
    assert_eq!(buf.len(), rows * cols, "split_rows buffer shape mismatch");
    let mut out = Vec::with_capacity(parts);
    let mut rest = buf;
    for pi in 0..parts {
        let (start, end) = part_range(rows, parts, pi);
        let (head, tail) = rest.split_at_mut((end - start) * cols);
        out.push(Some((start, end - start, head)));
        rest = tail;
    }
    out
}

/// Take part `pi`'s slot (exactly once per part per region).
pub fn take_slot<'b, T>(
    slots: &Mutex<Vec<RowSlot<'b, T>>>,
    pi: usize,
) -> (usize, usize, &'b mut [T]) {
    slots.lock().unwrap()[pi].take().expect("each part slot is taken exactly once")
}

/// Shared state of one parallel region: the work closure plus a dynamic
/// part counter (workers and the caller pull the next part index from it,
/// so load balances itself without affecting *what* each part computes).
struct Shared<'a> {
    f: &'a (dyn Fn(usize) + Sync),
    next: AtomicUsize,
    parts: usize,
}

fn run_parts(s: &Shared<'_>) {
    loop {
        let pi = s.next.fetch_add(1, Ordering::Relaxed);
        if pi >= s.parts {
            break;
        }
        // Fault site: one evaluation per part, on whichever thread pulls
        // it — exercises both the worker-death path (`Pending::drain`
        // panics in the caller) and the direct calling-thread panic.
        crate::util::fault::maybe_panic(crate::util::fault::POOL_PANIC);
        (s.f)(pi);
    }
}

/// Completion tracker for one region. `drain` blocks until every spawned
/// job has finished; the `Drop` impl does the same during unwinding so a
/// panic in the caller's share of the work can never let workers outlive
/// the stack frame their borrows point into.
struct Pending<'a> {
    rx: &'a mpsc::Receiver<()>,
    left: usize,
}

impl Pending<'_> {
    fn drain(&mut self) {
        while self.left > 0 {
            match self.rx.recv() {
                Ok(()) => self.left -= 1,
                Err(_) => {
                    self.left = 0;
                    panic!("fast-path worker panicked");
                }
            }
        }
    }
}

impl Drop for Pending<'_> {
    fn drop(&mut self) {
        while self.left > 0 {
            match self.rx.recv() {
                Ok(()) => self.left -= 1,
                Err(_) => break, // a worker panicked; nothing left to wait on
            }
        }
    }
}

/// Persistent worker pool driving the parallel regions. One per backend,
/// created when the fast path is enabled.
pub struct FastPath {
    /// None when `threads == 1`: every region runs serially in the caller.
    pool: Option<ThreadPool>,
    threads: usize,
}

impl FastPath {
    /// Pool sized by [`default_threads`] (`RAYON_NUM_THREADS` honored).
    pub fn new() -> Self {
        Self::with_threads(default_threads())
    }

    /// Pool with an explicit worker count (tests, benchmarks).
    pub fn with_threads(threads: usize) -> Self {
        let threads = threads.max(1);
        let pool = if threads > 1 { Some(ThreadPool::new(threads)) } else { None };
        Self { pool, threads }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(0), f(1), …, f(parts-1)`, each exactly once, distributed over
    /// the pool plus the calling thread. Parts must touch disjoint output
    /// regions (use [`split_rows`]). With one thread, or one part, this is
    /// a plain serial loop — and because every part performs fixed
    /// arithmetic regardless of where it runs, parallel results are
    /// bit-identical to serial ones.
    pub fn for_parts<F: Fn(usize) + Sync>(&self, parts: usize, f: F) {
        let pool = match &self.pool {
            Some(pool) if parts > 1 => pool,
            _ => {
                for pi in 0..parts {
                    f(pi);
                }
                return;
            }
        };
        let shared = Shared { f: &f, next: AtomicUsize::new(0), parts };
        // SAFETY: the erased lifetime never escapes this frame. Every
        // spawned job sends one completion when it stops pulling parts, and
        // `pending` (declared after `rx`, so dropped first) blocks on — or,
        // when unwinding, drains — all of them before `shared`, `f`, or any
        // buffer they borrow can be dropped.
        let shared_static: &'static Shared<'static> =
            unsafe { std::mem::transmute::<&Shared<'_>, &'static Shared<'static>>(&shared) };
        let jobs = self.threads.min(parts - 1).max(1);
        let (tx, rx) = mpsc::channel::<()>();
        let mut pending = Pending { rx: &rx, left: jobs };
        for _ in 0..jobs {
            let tx = tx.clone();
            pool.execute(move || {
                run_parts(shared_static);
                let _ = tx.send(());
            });
        }
        drop(tx);
        run_parts(&shared);
        pending.drain();
    }
}

impl Default for FastPath {
    fn default() -> Self {
        Self::new()
    }
}

// ----- generic row-parallel kernels ----------------------------------------
//
// These mirror the serial kernels in `runtime/reference.rs` exactly: same
// loop order per output row, same accumulation expressions. Partitioning is
// by output rows only, so each element's op sequence is the serial one.

/// `[T, A] @ [A, B] -> [T, B]`, partitioned over output rows.
pub fn par_matmul<E: Scalar>(
    fp: &FastPath,
    x: &[E],
    w: &[E],
    t: usize,
    a: usize,
    b: usize,
) -> Vec<E> {
    debug_assert_eq!(x.len(), t * a);
    debug_assert!(w.len() >= a * b);
    let mut out = vec![E::ZERO; t * b];
    let parts = parts_for(t, 2 * a * b);
    if parts <= 1 {
        matmul_rows(x, w, 0, t, a, b, &mut out);
        return out;
    }
    {
        let slots = Mutex::new(split_rows(&mut out, t, b, parts));
        fp.for_parts(parts, |pi| {
            let (start, rows, op) = take_slot(&slots, pi);
            matmul_rows(x, w, start, rows, a, b, op);
        });
    }
    out
}

fn matmul_rows<E: Scalar>(
    x: &[E],
    w: &[E],
    start: usize,
    rows: usize,
    a: usize,
    b: usize,
    out: &mut [E],
) {
    for r in 0..rows {
        let i = start + r;
        let xrow = &x[i * a..(i + 1) * a];
        let orow = &mut out[r * b..(r + 1) * b];
        for (k, &xv) in xrow.iter().enumerate() {
            let wrow = &w[k * b..(k + 1) * b];
            for (ov, &wv) in orow.iter_mut().zip(wrow) {
                *ov += xv * wv;
            }
        }
    }
}

/// `dy [T, B] @ w[A, B]^T -> [T, A]`, partitioned over output rows.
pub fn par_matmul_nt<E: Scalar>(
    fp: &FastPath,
    dy: &[E],
    w: &[E],
    t: usize,
    a: usize,
    b: usize,
) -> Vec<E> {
    debug_assert_eq!(dy.len(), t * b);
    debug_assert!(w.len() >= a * b);
    let mut out = vec![E::ZERO; t * a];
    let parts = parts_for(t, 2 * a * b);
    if parts <= 1 {
        matmul_nt_rows(dy, w, 0, t, a, b, &mut out);
        return out;
    }
    {
        let slots = Mutex::new(split_rows(&mut out, t, a, parts));
        fp.for_parts(parts, |pi| {
            let (start, rows, op) = take_slot(&slots, pi);
            matmul_nt_rows(dy, w, start, rows, a, b, op);
        });
    }
    out
}

fn matmul_nt_rows<E: Scalar>(
    dy: &[E],
    w: &[E],
    start: usize,
    rows: usize,
    a: usize,
    b: usize,
    out: &mut [E],
) {
    for r in 0..rows {
        let i = start + r;
        let dyr = &dy[i * b..(i + 1) * b];
        let orow = &mut out[r * a..(r + 1) * a];
        for k in 0..a {
            let wrow = &w[k * b..(k + 1) * b];
            let mut acc = E::ZERO;
            for (&dv, &wv) in dyr.iter().zip(wrow) {
                acc += dv * wv;
            }
            orow[k] = acc;
        }
    }
}

/// `dw[A, B] += x[T, A]^T @ dy[T, B]`, partitioned over `dw` rows. Each
/// part keeps the serial t-ascending accumulation per element; `dw` may be
/// a leading slice of a larger stacked buffer.
pub fn par_accum_tn<E: Scalar>(
    fp: &FastPath,
    x: &[E],
    dy: &[E],
    t: usize,
    a: usize,
    b: usize,
    dw: &mut [E],
) {
    debug_assert_eq!(x.len(), t * a);
    debug_assert_eq!(dy.len(), t * b);
    debug_assert!(dw.len() >= a * b);
    let dwa = &mut dw[..a * b];
    let parts = parts_for(a, 2 * t * b);
    if parts <= 1 {
        accum_tn_rows(x, dy, t, 0, a, a, b, dwa);
        return;
    }
    let slots = Mutex::new(split_rows(dwa, a, b, parts));
    fp.for_parts(parts, |pi| {
        let (start, rows, dwp) = take_slot(&slots, pi);
        accum_tn_rows(x, dy, t, start, rows, a, b, dwp);
    });
}

fn accum_tn_rows<E: Scalar>(
    x: &[E],
    dy: &[E],
    t: usize,
    start: usize,
    rows: usize,
    a: usize,
    b: usize,
    dw: &mut [E],
) {
    for i in 0..t {
        let xrow = &x[i * a..(i + 1) * a];
        let dyr = &dy[i * b..(i + 1) * b];
        for r in 0..rows {
            let xv = xrow[start + r];
            let dwrow = &mut dw[r * b..(r + 1) * b];
            for (dwv, &dv) in dwrow.iter_mut().zip(dyr) {
                *dwv += xv * dv;
            }
        }
    }
}

/// `out[i] = f(i)` in parallel; the split depends only on `out.len()`.
pub fn par_fill<T, F>(fp: &FastPath, out: &mut [T], ops_per_elem: usize, f: F)
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let n = out.len();
    let parts = parts_for(n, ops_per_elem);
    if parts <= 1 {
        for (i, o) in out.iter_mut().enumerate() {
            *o = f(i);
        }
        return;
    }
    let slots = Mutex::new(split_rows(out, n, 1, parts));
    fp.for_parts(parts, |pi| {
        let (start, _rows, op) = take_slot(&slots, pi);
        for (r, o) in op.iter_mut().enumerate() {
            *o = f(start + r);
        }
    });
}

/// `(out_a[i], out_b[i]) = f(i)` in parallel (paired outputs share one pass).
pub fn par_fill2<T, F>(fp: &FastPath, out_a: &mut [T], out_b: &mut [T], ops_per_elem: usize, f: F)
where
    T: Send,
    F: Fn(usize) -> (T, T) + Sync,
{
    let n = out_a.len();
    assert_eq!(n, out_b.len(), "paired outputs must have equal length");
    let parts = parts_for(n, ops_per_elem);
    if parts <= 1 {
        for i in 0..n {
            let (a, b) = f(i);
            out_a[i] = a;
            out_b[i] = b;
        }
        return;
    }
    let a_slots = Mutex::new(split_rows(out_a, n, 1, parts));
    let b_slots = Mutex::new(split_rows(out_b, n, 1, parts));
    fp.for_parts(parts, |pi| {
        let (start, rows, ap) = take_slot(&a_slots, pi);
        let (_start_b, _rows_b, bp) = take_slot(&b_slots, pi);
        for r in 0..rows {
            let (a, b) = f(start + r);
            ap[r] = a;
            bp[r] = b;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn part_range_tiles_rows_exactly() {
        for rows in [0usize, 1, 5, 16, 17, 100] {
            for parts in [1usize, 2, 3, 16] {
                let mut covered = 0;
                let mut expect_start = 0;
                for pi in 0..parts {
                    let (s, e) = part_range(rows, parts, pi);
                    assert_eq!(s, expect_start);
                    assert!(e >= s);
                    covered += e - s;
                    expect_start = e;
                }
                assert_eq!(covered, rows, "rows {rows} parts {parts}");
            }
        }
    }

    #[test]
    fn parts_for_is_thread_independent_and_thresholded() {
        // Tiny regions stay serial; big ones split by rows, capped.
        assert_eq!(parts_for(8, 16), 1);
        assert_eq!(parts_for(4, 100_000), 4);
        assert_eq!(parts_for(1024, 1024), 16);
        // No dependence on worker count anywhere in the signature.
    }

    #[test]
    fn for_parts_runs_every_part_exactly_once() {
        let fp = FastPath::with_threads(4);
        let counts: Vec<AtomicU32> = (0..37).map(|_| AtomicU32::new(0)).collect();
        fp.for_parts(counts.len(), |pi| {
            counts[pi].fetch_add(1, Ordering::SeqCst);
        });
        for (pi, c) in counts.iter().enumerate() {
            assert_eq!(c.load(Ordering::SeqCst), 1, "part {pi}");
        }
    }

    #[test]
    fn for_parts_serial_when_one_thread() {
        let fp = FastPath::with_threads(1);
        let counts: Vec<AtomicU32> = (0..8).map(|_| AtomicU32::new(0)).collect();
        fp.for_parts(counts.len(), |pi| {
            counts[pi].fetch_add(1, Ordering::SeqCst);
        });
        assert!(counts.iter().all(|c| c.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn for_parts_propagates_panics() {
        let fp = FastPath::with_threads(2);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            fp.for_parts(8, |pi| {
                if pi == 3 {
                    panic!("boom");
                }
            });
        }));
        assert!(caught.is_err(), "panic in one part must propagate");
    }

    /// Serial references replicating the reference backend's exact order.
    fn serial_matmul(x: &[f64], w: &[f64], t: usize, a: usize, b: usize) -> Vec<f64> {
        let mut out = vec![0.0f64; t * b];
        for i in 0..t {
            for k in 0..a {
                for j in 0..b {
                    out[i * b + j] += x[i * a + k] * w[k * b + j];
                }
            }
        }
        out
    }

    fn fixture(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = crate::util::rng::Rng::new(seed);
        (0..n).map(|_| rng.gen_f64_range(-1.0, 1.0)).collect()
    }

    #[test]
    fn par_matmul_bit_matches_serial_for_any_thread_count() {
        let (t, a, b) = (33, 17, 29);
        let x = fixture(t * a, 1);
        let w = fixture(a * b, 2);
        let want = serial_matmul(&x, &w, t, a, b);
        for threads in [1usize, 2, 5] {
            let fp = FastPath::with_threads(threads);
            let got = par_matmul(&fp, &x, &w, t, a, b);
            assert!(
                got.iter().zip(&want).all(|(g, w)| g.to_bits() == w.to_bits()),
                "threads {threads}"
            );
        }
    }

    #[test]
    fn par_matmul_nt_bit_matches_serial() {
        let (t, a, b) = (21, 19, 23);
        let dy = fixture(t * b, 3);
        let w = fixture(a * b, 4);
        let mut want = vec![0.0f64; t * a];
        for i in 0..t {
            for r in 0..a {
                let mut acc = 0.0;
                for j in 0..b {
                    acc += dy[i * b + j] * w[r * b + j];
                }
                want[i * a + r] = acc;
            }
        }
        let fp = FastPath::with_threads(3);
        let got = par_matmul_nt(&fp, &dy, &w, t, a, b);
        assert!(got.iter().zip(&want).all(|(g, w)| g.to_bits() == w.to_bits()));
    }

    #[test]
    fn par_accum_tn_bit_matches_serial_and_accumulates() {
        let (t, a, b) = (13, 37, 11);
        let x = fixture(t * a, 5);
        let dy = fixture(t * b, 6);
        // Pre-seeded dw: += must preserve prior contents.
        let mut want = fixture(a * b, 7);
        let mut got = want.clone();
        for i in 0..t {
            for r in 0..a {
                for j in 0..b {
                    want[r * b + j] += x[i * a + r] * dy[i * b + j];
                }
            }
        }
        let fp = FastPath::with_threads(4);
        par_accum_tn(&fp, &x, &dy, t, a, b, &mut got);
        assert!(got.iter().zip(&want).all(|(g, w)| g.to_bits() == w.to_bits()));
    }

    #[test]
    fn f32_instantiation_tracks_f64_loosely() {
        // The Scalar seam must genuinely support f32: same kernel, looser
        // tolerance (single precision accumulates more rounding).
        let (t, a, b) = (24, 31, 18);
        let x64 = fixture(t * a, 8);
        let w64 = fixture(a * b, 9);
        let x32: Vec<f32> = x64.iter().map(|&v| v as f32).collect();
        let w32: Vec<f32> = w64.iter().map(|&v| v as f32).collect();
        let fp = FastPath::with_threads(2);
        let got32 = par_matmul(&fp, &x32, &w32, t, a, b);
        let want64 = serial_matmul(&x64, &w64, t, a, b);
        for (g, w) in got32.iter().zip(&want64) {
            assert!((g.to_f64() - w).abs() < 1e-4, "{g} vs {w}");
        }
    }

    #[test]
    fn par_fill_and_fill2_match_direct_evaluation() {
        let fp = FastPath::with_threads(3);
        let n = 10_000;
        let src = fixture(n, 10);
        let mut out = vec![0.0f64; n];
        par_fill(&fp, &mut out, 8, |i| src[i] * 3.0 + 1.0);
        assert!(out.iter().enumerate().all(|(i, &v)| v == src[i] * 3.0 + 1.0));
        let mut a = vec![0.0f64; n];
        let mut b = vec![0.0f64; n];
        par_fill2(&fp, &mut a, &mut b, 8, |i| (src[i] + 1.0, src[i] - 1.0));
        assert!(a.iter().enumerate().all(|(i, &v)| v == src[i] + 1.0));
        assert!(b.iter().enumerate().all(|(i, &v)| v == src[i] - 1.0));
    }
}
