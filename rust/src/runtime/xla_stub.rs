//! Typed stand-in for the `xla` crate's PJRT surface.
//!
//! The real `xla` crate (PJRT C API bindings) cannot be vendored offline,
//! but `runtime/pjrt.rs` must keep compiling so the feature-gated runtime
//! does not rot unbuilt — CI runs `cargo check --features pjrt` against
//! this stub. It mirrors exactly the types and signatures `pjrt.rs` uses;
//! every fallible call fails with a pointer at the `xla-runtime` feature,
//! and `PjRtClient::cpu()` fails first, so no stubbed runtime can ever be
//! half-constructed. Swapping in the real crate is one feature flag:
//! `--features xla-runtime` bypasses this module entirely.

pub struct PjRtClient;
pub struct PjRtLoadedExecutable;
pub struct PjRtBuffer;
pub struct HloModuleProto;
pub struct XlaComputation;
pub struct Literal;

pub struct XlaError(&'static str);

impl std::fmt::Debug for XlaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "XlaError({})", self.0)
    }
}

fn not_linked() -> XlaError {
    XlaError(
        "XLA runtime not linked: this build uses the typed stub. Add the xla \
         crate to rust/Cargo.toml and build with --features xla-runtime.",
    )
}

impl PjRtClient {
    pub fn cpu() -> Result<Self, XlaError> {
        Err(not_linked())
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(not_linked())
    }
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self, XlaError> {
        Err(not_linked())
    }
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

impl Literal {
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, XlaError> {
        Err(not_linked())
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, XlaError> {
        Err(not_linked())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        Err(not_linked())
    }
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(not_linked())
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(not_linked())
    }
}
