//! The XLA/PJRT-backed [`Runtime`] (requires the `pjrt` cargo feature and
//! the `xla` crate). See the module docs in `runtime` for the artifact
//! layout and call protocol.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

// Without the `xla-runtime` feature the typed stub stands in for the real
// `xla` crate, so this module keeps compiling (and CI keeps checking it)
// offline; see `runtime/xla_stub.rs`.
#[cfg(not(feature = "xla-runtime"))]
use super::xla_stub as xla;

use super::{Backend, ChunkInputs, ChunkVjpOut, FlatParams, FullStepOut, FwdKvOut, Manifest};

pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    dir: PathBuf,
    fwd_kv: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    chunk_vjp: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    full_step: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    /// Host-side current parameters (re-sent per call as literals; the CPU
    /// PJRT client aliases host memory so this is cheap).
    params: Option<Vec<xla::Literal>>,
    /// Executions since start (metrics).
    pub calls: std::cell::Cell<u64>,
}

impl Runtime {
    /// Open the artifact directory and compile all bucket programs.
    pub fn load(dir: &Path, model: &str) -> anyhow::Result<Self> {
        let manifest = Manifest::load(&dir.join(format!("manifest_{model}.json")))?;
        let client =
            xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT client: {e:?}"))?;
        let mut rt = Runtime {
            client,
            manifest,
            dir: dir.to_path_buf(),
            fwd_kv: BTreeMap::new(),
            chunk_vjp: BTreeMap::new(),
            full_step: BTreeMap::new(),
            params: None,
            calls: std::cell::Cell::new(0),
        };
        for p in rt.manifest.kv_buckets.clone() {
            let f = rt.compile_file(&format!("{}_fwd_kv_p{p}.hlo.txt", rt.manifest.model_name))?;
            rt.fwd_kv.insert(p, f);
            let v = rt.compile_file(&format!("{}_chunk_vjp_p{p}.hlo.txt", rt.manifest.model_name))?;
            rt.chunk_vjp.insert(p, v);
        }
        for s in rt.manifest.full_step_lens.clone() {
            let e = rt.compile_file(&format!("{}_full_step_s{s}.hlo.txt", rt.manifest.model_name))?;
            rt.full_step.insert(s, e);
        }
        crate::info!(
            "runtime: compiled {} fwd_kv + {} chunk_vjp executables ({} params)",
            rt.fwd_kv.len(),
            rt.chunk_vjp.len(),
            rt.manifest.model_param_count
        );
        Ok(rt)
    }

    fn compile_file(&self, name: &str) -> anyhow::Result<xla::PjRtLoadedExecutable> {
        let path = self.dir.join(name);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow::anyhow!("bad path"))?,
        )
        .map_err(|e| anyhow::anyhow!("parsing {name}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {name}: {e:?}"))
    }

    fn kv_dims(&self, p: usize) -> Vec<i64> {
        let m = &self.manifest;
        vec![m.num_layers as i64, 2, p as i64, m.num_heads as i64, m.head_dim as i64]
    }

    fn literal_f32(data: &[f32], dims: &[i64]) -> anyhow::Result<xla::Literal> {
        xla::Literal::vec1(data)
            .reshape(dims)
            .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))
    }

    fn chunk_literals(
        &self,
        inputs: &ChunkInputs,
        g_kv_own: Option<&[f32]>,
    ) -> anyhow::Result<Vec<xla::Literal>> {
        let c = self.manifest.chunk_size;
        anyhow::ensure!(inputs.tokens.len() == c, "tokens len {} != {c}", inputs.tokens.len());
        anyhow::ensure!(
            self.manifest.kv_buckets.contains(&inputs.prefix_len),
            "prefix {} is not an exported bucket",
            inputs.prefix_len
        );
        anyhow::ensure!(
            inputs.kv_in.len() == self.kv_elements(inputs.prefix_len),
            "kv_in len"
        );
        let mut lits = vec![
            xla::Literal::vec1(&inputs.tokens),
            xla::Literal::vec1(&inputs.targets),
            xla::Literal::vec1(&inputs.pos),
            xla::Literal::vec1(&inputs.seg),
            Self::literal_f32(&inputs.kv_in, &self.kv_dims(inputs.prefix_len))?,
        ];
        if let Some(g) = g_kv_own {
            anyhow::ensure!(g.len() == self.kv_elements(c), "g_kv_own len");
            lits.push(Self::literal_f32(g, &self.kv_dims(c))?);
        }
        Ok(lits)
    }

    fn run(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        extra: Vec<xla::Literal>,
    ) -> anyhow::Result<Vec<xla::Literal>> {
        let params = self
            .params
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("set_params not called"))?;
        self.calls.set(self.calls.get() + 1);
        let mut args: Vec<&xla::Literal> = params.iter().collect();
        args.extend(extra.iter());
        let out = exe
            .execute::<&xla::Literal>(&args)
            .map_err(|e| anyhow::anyhow!("execute: {e:?}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("download: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow::anyhow!("untuple: {e:?}"))
    }

    fn scalar_f32(lit: &xla::Literal) -> anyhow::Result<f32> {
        lit.to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("scalar: {e:?}"))?
            .first()
            .copied()
            .ok_or_else(|| anyhow::anyhow!("empty scalar"))
    }

    fn vec_f32(lit: &xla::Literal) -> anyhow::Result<Vec<f32>> {
        lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("tensor: {e:?}"))
    }
}

impl Backend for Runtime {
    type Elem = f32;

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Set current parameters (call after every optimizer update).
    fn set_params(&mut self, params: &FlatParams) -> anyhow::Result<()> {
        anyhow::ensure!(params.0.len() == self.manifest.params.len(), "param arity");
        let mut lits = Vec::with_capacity(params.0.len());
        for (spec, host) in self.manifest.params.iter().zip(&params.0) {
            anyhow::ensure!(
                host.len() == spec.size,
                "param {} size {} != {}",
                spec.name,
                host.len(),
                spec.size
            );
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            lits.push(
                xla::Literal::vec1(host)
                    .reshape(&dims)
                    .map_err(|e| anyhow::anyhow!("param {}: {e:?}", spec.name))?,
            );
        }
        self.params = Some(lits);
        Ok(())
    }

    /// Algorithm 2's first-pass forward: discard activations, keep KV.
    fn fwd_kv(&self, inputs: &ChunkInputs) -> anyhow::Result<FwdKvOut> {
        let exe = self
            .fwd_kv
            .get(&inputs.prefix_len)
            .ok_or_else(|| anyhow::anyhow!("no fwd_kv bucket {}", inputs.prefix_len))?;
        let lits = self.chunk_literals(inputs, None)?;
        let out = self.run(exe, lits)?;
        anyhow::ensure!(out.len() == 3, "fwd_kv arity {}", out.len());
        Ok(FwdKvOut {
            loss_sum: Self::scalar_f32(&out[0])? as f64,
            n_tok: Self::scalar_f32(&out[1])? as f64,
            kv_own: Self::vec_f32(&out[2])?,
        })
    }

    /// Forward + backward for one chunk (recomputes the forward internally —
    /// the AOT realization of Alg. 2's "forward executed twice").
    fn chunk_vjp(&self, inputs: &ChunkInputs, g_kv_own: &[f32]) -> anyhow::Result<ChunkVjpOut> {
        let exe = self
            .chunk_vjp
            .get(&inputs.prefix_len)
            .ok_or_else(|| anyhow::anyhow!("no chunk_vjp bucket {}", inputs.prefix_len))?;
        let lits = self.chunk_literals(inputs, Some(g_kv_own))?;
        let out = self.run(exe, lits)?;
        let np = self.manifest.params.len();
        anyhow::ensure!(out.len() == 3 + np + 1, "chunk_vjp arity {}", out.len());
        let mut d_params = Vec::with_capacity(np);
        for lit in &out[3..3 + np] {
            d_params.push(Self::vec_f32(lit)?);
        }
        Ok(ChunkVjpOut {
            loss_sum: Self::scalar_f32(&out[0])? as f64,
            n_tok: Self::scalar_f32(&out[1])? as f64,
            kv_own: Self::vec_f32(&out[2])?,
            d_params,
            d_kv_in: Self::vec_f32(&out[3 + np])?,
        })
    }

    /// Unchunked oracle step over a full sequence of exported length `s`.
    fn full_step(
        &self,
        s: usize,
        tokens: &[i32],
        targets: &[i32],
        pos: &[i32],
        seg: &[i32],
    ) -> anyhow::Result<FullStepOut> {
        let exe = self
            .full_step
            .get(&s)
            .ok_or_else(|| anyhow::anyhow!("no full_step for length {s}"))?;
        let lits = vec![
            xla::Literal::vec1(tokens),
            xla::Literal::vec1(targets),
            xla::Literal::vec1(pos),
            xla::Literal::vec1(seg),
        ];
        let out = self.run(exe, lits)?;
        let np = self.manifest.params.len();
        anyhow::ensure!(out.len() == 2 + np, "full_step arity {}", out.len());
        let mut d_params = Vec::with_capacity(np);
        for lit in &out[2..] {
            d_params.push(Self::vec_f32(lit)?);
        }
        Ok(FullStepOut {
            loss_sum: Self::scalar_f32(&out[0])? as f64,
            n_tok: Self::scalar_f32(&out[1])? as f64,
            d_params,
        })
    }

    fn calls(&self) -> u64 {
        self.calls.get()
    }
}
