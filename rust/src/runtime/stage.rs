//! Layer-partitioned view of the reference backend — the per-stage
//! execution surface of the stage-parallel pipeline executor
//! (`pipeline::exec`).
//!
//! A [`StageBackend`] owns one contiguous layer range of the model. Stage 0
//! additionally owns the embedding lookup; the last stage owns the final
//! norm, the tied LM head and the loss. The tied embedding matrix therefore
//! receives gradient contributions from both boundary stages — summing the
//! per-stage gradient buffers reproduces the monolithic backward exactly
//! (the same accumulation the single-stage `chunk_vjp` performs
//! internally).
//!
//! Stage boundaries exchange exactly two typed messages:
//!
//! - [`ActivationHandoff`] flows downstream (stage s → s+1) after each
//!   forward or recompute-forward of a chunk: the [T, hidden] activation
//!   that is the next stage's layer input.
//! - [`GradHandoff`] flows upstream (stage s+1 → s) after each backward:
//!   the [T, hidden] activation cotangent.
//!
//! Handoff buffers are *moved* across the boundary, never copied: the
//! sender gives up its `Vec`, the channel transfers ownership, and the
//! receiver feeds it straight into its layer range (`stage_fwd` /
//! `stage_bwd` take `Option<Vec<f64>>`). A handoff costs O(1) regardless
//! of the activation size.
//!
//! KV state never crosses a boundary: each stage stores the KV of its own
//! layers for its own chunks (the paper's per-stage StateStore), assembles
//! its own prefixes, and chains its own `d_kv_in` into earlier chunks'
//! pending KV gradients.

use std::ops::Range;

use super::reference::{ReferenceBackend, StageBwdOut, StageCache, StageFwdOut};
use super::{Backend, ChunkInputs};

/// Contiguous, balanced layer partition: stage `s` of `p` owns
/// `[s*L/P, (s+1)*L/P)`. Empty ranges are legal when P > L — such a stage
/// just relays activations (stage 0 still embeds, the last still computes
/// the loss).
pub fn stage_layer_range(num_layers: usize, num_stages: usize, stage: usize) -> Range<usize> {
    (stage * num_layers / num_stages)..((stage + 1) * num_layers / num_stages)
}

/// An arbitrary uneven contiguous stage partition: `ranges[s]` is the layer
/// range stage `s` owns. Invariant (checked at every constructor): the
/// ranges tile `0..num_layers` exactly — `ranges[0].start == 0`, each range
/// starts where the previous ended, and the last ends at `num_layers`.
/// Empty ranges are legal (the equal partition produces them when
/// P > L); *user-specified* partitions reject them with a diagnostic naming
/// the offending stage ([`Self::from_counts`] / [`Self::parse`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StagePartition {
    ranges: Vec<Range<usize>>,
}

impl StagePartition {
    /// Today's balanced partition — stage `s` owns [`stage_layer_range`].
    /// This is the bit-identity anchor: an executor run under
    /// `Some(equal(L, P))` takes the exact layer ranges the pre-elastic
    /// path derived.
    pub fn equal(num_layers: usize, num_stages: usize) -> anyhow::Result<Self> {
        anyhow::ensure!(num_stages >= 1, "need at least one pipeline stage");
        let ranges =
            (0..num_stages).map(|s| stage_layer_range(num_layers, num_stages, s)).collect();
        Self::from_ranges(ranges, num_layers)
    }

    /// Build from per-stage layer counts (`[10, 6, 6, 6]`). Zero counts are
    /// rejected with the stage named — an explicitly requested empty stage
    /// is a configuration error, not a relay.
    pub fn from_counts(counts: &[usize], num_layers: usize) -> anyhow::Result<Self> {
        anyhow::ensure!(!counts.is_empty(), "partition needs at least one stage");
        for (s, &c) in counts.iter().enumerate() {
            anyhow::ensure!(c > 0, "partition leaves stage {s} with zero layers");
        }
        let total: usize = counts.iter().sum();
        anyhow::ensure!(
            total == num_layers,
            "partition layers sum to {total} but the model has {num_layers} layers"
        );
        let mut ranges = Vec::with_capacity(counts.len());
        let mut start = 0usize;
        for &c in counts {
            ranges.push(start..start + c);
            start += c;
        }
        Self::from_ranges(ranges, num_layers)
    }

    /// Parse a `--partition a,b,c` spec against the model's layer count,
    /// with diagnostics naming the offending stage.
    pub fn parse(spec: &str, num_layers: usize) -> anyhow::Result<Self> {
        let counts: Vec<usize> = spec
            .split(',')
            .enumerate()
            .map(|(s, tok)| {
                tok.trim().parse::<usize>().map_err(|_| {
                    anyhow::anyhow!("--partition stage {s}: invalid layer count {tok:?}")
                })
            })
            .collect::<anyhow::Result<_>>()?;
        Self::from_counts(&counts, num_layers)
    }

    /// Validated constructor: the ranges must tile `0..num_layers`.
    pub fn from_ranges(ranges: Vec<Range<usize>>, num_layers: usize) -> anyhow::Result<Self> {
        anyhow::ensure!(!ranges.is_empty(), "partition needs at least one stage");
        let mut expect = 0usize;
        for (s, r) in ranges.iter().enumerate() {
            anyhow::ensure!(
                r.start == expect && r.end >= r.start,
                "partition stage {s} covers {:?} but the previous stage ended at {expect}",
                r
            );
            expect = r.end;
        }
        anyhow::ensure!(
            expect == num_layers,
            "partition covers {expect} layers but the model has {num_layers}"
        );
        Ok(Self { ranges })
    }

    pub fn num_stages(&self) -> usize {
        self.ranges.len()
    }

    pub fn num_layers(&self) -> usize {
        self.ranges.last().map_or(0, |r| r.end)
    }

    pub fn range(&self, stage: usize) -> Range<usize> {
        self.ranges[stage].clone()
    }

    /// Per-stage layer counts.
    pub fn counts(&self) -> Vec<usize> {
        self.ranges.iter().map(|r| r.len()).collect()
    }

    /// `"a,b,c"` — the `--partition` round-trip form.
    pub fn describe(&self) -> String {
        self.counts().iter().map(|c| c.to_string()).collect::<Vec<_>>().join(",")
    }

    /// True iff this is exactly the balanced [`Self::equal`] partition.
    pub fn is_equal(&self) -> bool {
        let (l, p) = (self.num_layers(), self.num_stages());
        self.ranges.iter().enumerate().all(|(s, r)| *r == stage_layer_range(l, p, s))
    }
}

/// Activation handed from stage `s` to `s + 1` for one pipeline op.
#[derive(Clone, Debug)]
pub struct ActivationHandoff {
    /// Chunk (pipeline item) id.
    pub item: usize,
    /// True when this is a recompute-forward (Alg. 2's second forward).
    pub recompute: bool,
    /// [T, hidden] layer input for the receiving stage.
    pub x: Vec<f64>,
}

/// Activation cotangent handed from stage `s + 1` back to `s` for one
/// backward op.
#[derive(Clone, Debug)]
pub struct GradHandoff {
    /// Chunk (pipeline item) id.
    pub item: usize,
    /// [T, hidden] cotangent at the sending stage's layer input.
    pub d_x: Vec<f64>,
}

/// One pipeline stage's view of the reference backend: a contiguous layer
/// range plus the embedding (first stage) / head + loss (last stage).
pub struct StageBackend<'a> {
    backend: &'a ReferenceBackend,
    pub stage: usize,
    pub num_stages: usize,
    pub layers: Range<usize>,
}

impl<'a> StageBackend<'a> {
    pub fn new(
        backend: &'a ReferenceBackend,
        stage: usize,
        num_stages: usize,
    ) -> anyhow::Result<Self> {
        let layers = stage_layer_range(backend.manifest.num_layers, num_stages, stage);
        Self::with_layers(backend, stage, num_stages, layers)
    }

    /// A stage owning an explicit (possibly uneven) layer range — the
    /// elastic-partition entry point. [`Self::new`] is exactly
    /// `with_layers(.., stage_layer_range(..))`.
    pub fn with_layers(
        backend: &'a ReferenceBackend,
        stage: usize,
        num_stages: usize,
        layers: Range<usize>,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(num_stages >= 1, "need at least one stage");
        anyhow::ensure!(stage < num_stages, "stage {stage} out of {num_stages}");
        anyhow::ensure!(
            layers.start <= layers.end && layers.end <= backend.manifest.num_layers,
            "stage {stage} layer range {layers:?} exceeds the model's {} layers",
            backend.manifest.num_layers
        );
        Ok(Self { backend, stage, num_stages, layers })
    }

    /// All stages of a `p`-way equal partition, in order.
    pub fn partition(backend: &'a ReferenceBackend, p: usize) -> anyhow::Result<Vec<Self>> {
        (0..p).map(|s| Self::new(backend, s, p)).collect()
    }

    /// All stages of an explicit [`StagePartition`], in order.
    pub fn partition_with(
        backend: &'a ReferenceBackend,
        part: &StagePartition,
    ) -> anyhow::Result<Vec<Self>> {
        anyhow::ensure!(
            part.num_layers() == backend.manifest.num_layers,
            "partition covers {} layers but the model has {}",
            part.num_layers(),
            backend.manifest.num_layers
        );
        let p = part.num_stages();
        (0..p).map(|s| Self::with_layers(backend, s, p, part.range(s))).collect()
    }

    pub fn is_first(&self) -> bool {
        self.stage == 0
    }

    pub fn is_last(&self) -> bool {
        self.stage == self.num_stages - 1
    }

    /// Elements of a stage-local KV buffer covering `tokens` positions
    /// ([Lr, 2, tokens, H, D]).
    pub fn kv_elements(&self, tokens: usize) -> usize {
        let m = self.backend.manifest();
        self.layers.len() * 2 * tokens * m.num_heads * m.head_dim
    }

    /// This stage's forward for one chunk op. `inputs.kv_in` carries the
    /// stage-local prefix KV; `x_in` is the upstream activation handoff,
    /// consumed by value — zero-copy (None iff this is the first stage).
    pub fn forward(
        &self,
        inputs: &ChunkInputs<f64>,
        x_in: Option<Vec<f64>>,
    ) -> anyhow::Result<StageFwdOut> {
        self.backend.stage_fwd(
            self.layers.clone(),
            self.is_first(),
            self.is_last(),
            inputs,
            x_in,
        )
    }

    /// This stage's backward for one chunk op, consuming the cache its
    /// forward produced. `d_x_out` is the downstream cotangent handoff,
    /// consumed by value — zero-copy (None iff this is the last stage);
    /// parameter grads accumulate into `d_params` (full arity; only this
    /// stage's slots are touched).
    pub fn backward(
        &self,
        inputs: &ChunkInputs<f64>,
        cache: &StageCache,
        d_x_out: Option<Vec<f64>>,
        g_kv_own: &[f64],
        d_params: &mut [Vec<f64>],
    ) -> anyhow::Result<StageBwdOut> {
        self.backend.stage_bwd(
            self.layers.clone(),
            self.is_first(),
            self.is_last(),
            inputs,
            cache,
            d_x_out,
            g_kv_own,
            d_params,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelSpec;
    use crate::runtime::{FlatParams, Manifest};
    use crate::train::init_params;

    fn mini_backend(layers: u64) -> (ReferenceBackend, FlatParams) {
        let spec = ModelSpec {
            name: "stage-mini".into(),
            hidden_size: 16,
            num_layers: layers,
            num_heads: 2,
            num_kv_heads: 2,
            intermediate_size: 24,
            vocab_size: 32,
            tie_embeddings: true,
        };
        let manifest = Manifest::for_reference(&spec, 8, 2).unwrap();
        let mut b = ReferenceBackend::new(manifest).unwrap();
        let params = init_params(&b.manifest, 3);
        b.set_params(&params).unwrap();
        (b, params)
    }

    #[test]
    fn partition_covers_all_layers_contiguously() {
        for (l, p) in [(4usize, 1usize), (4, 2), (4, 4), (2, 4), (5, 3), (1, 1)] {
            let mut covered = Vec::new();
            for s in 0..p {
                let r = stage_layer_range(l, p, s);
                covered.extend(r);
            }
            assert_eq!(covered, (0..l).collect::<Vec<_>>(), "L={l} P={p}");
        }
    }

    #[test]
    fn prop_stage_partition_covers_all_layers_exactly_once() {
        // Any StagePartition — equal or random-uneven counts — tiles
        // 0..L exactly once and contiguously; describe() round-trips
        // through parse().
        use crate::util::prop::{check, ensure, gen_pair, gen_usize, gen_vec};
        let gen = gen_pair(gen_vec(gen_usize(1, 9), 1, 8), gen_usize(1, 8));
        check(200, gen, |(counts, p)| {
            let l: usize = counts.iter().sum();
            for part in [
                StagePartition::from_counts(counts, l).map_err(|e| e.to_string())?,
                StagePartition::equal(l, *p).map_err(|e| e.to_string())?,
            ] {
                let covered: Vec<usize> =
                    (0..part.num_stages()).flat_map(|s| part.range(s)).collect();
                ensure(
                    covered == (0..l).collect::<Vec<_>>(),
                    "partition covers all layers exactly once, contiguously",
                )?;
                ensure(part.num_layers() == l, "num_layers matches the cover")?;
            }
            let part = StagePartition::from_counts(counts, l).map_err(|e| e.to_string())?;
            let reparsed =
                StagePartition::parse(&part.describe(), l).map_err(|e| e.to_string())?;
            ensure(reparsed == part, "describe()/parse() round-trip")?;
            Ok(())
        });
    }

    #[test]
    fn degenerate_partitions_are_rejected_naming_the_stage() {
        let err = StagePartition::parse("2,0,2", 4).unwrap_err().to_string();
        assert!(err.contains("stage 1") && err.contains("zero layers"), "{err}");
        let err = StagePartition::parse("2,x", 4).unwrap_err().to_string();
        assert!(err.contains("stage 1") && err.contains("invalid"), "{err}");
        let err = StagePartition::parse("2,3", 4).unwrap_err().to_string();
        assert!(err.contains("sum to 5") && err.contains("4 layers"), "{err}");
        assert!(StagePartition::parse("", 4).is_err());
    }

    #[test]
    fn equal_partition_matches_stage_layer_range_and_is_equal() {
        for (l, p) in [(28usize, 4usize), (4, 2), (2, 4), (5, 3)] {
            let part = StagePartition::equal(l, p).unwrap();
            for s in 0..p {
                assert_eq!(part.range(s), stage_layer_range(l, p, s), "L={l} P={p} s={s}");
            }
            assert!(part.is_equal());
        }
        assert!(!StagePartition::from_counts(&[3, 1], 4).unwrap().is_equal());
    }

    #[test]
    fn uneven_staged_forward_backward_matches_monolithic_chunk_vjp() {
        // Same bitwise contract as the equal-partition test below, over
        // explicitly uneven partitions: the stage pieces ARE the monolithic
        // program however the layers are split.
        let (b, _params) = mini_backend(4);
        let c = b.manifest.chunk_size;
        let inputs = crate::runtime::ChunkInputs::<f64> {
            tokens: (0..c as i32).map(|i| i % 32).collect(),
            targets: (0..c as i32).map(|i| (i + 1) % 32).collect(),
            pos: (0..c as i32).collect(),
            seg: vec![0; c],
            kv_in: Vec::new(),
            prefix_len: 0,
        };
        let g_zero = vec![0.0f64; b.kv_elements(c)];
        let mono = b.chunk_vjp(&inputs, &g_zero).unwrap();

        for counts in [vec![3usize, 1], vec![1, 3], vec![2, 1, 1], vec![1, 2, 1]] {
            let part = StagePartition::from_counts(&counts, 4).unwrap();
            let stages = StageBackend::partition_with(&b, &part).unwrap();
            let mut x: Option<Vec<f64>> = None;
            let mut caches = Vec::new();
            for st in &stages {
                let stage_inputs = ChunkInputs { kv_in: Vec::new(), ..inputs.clone() };
                let out = st.forward(&stage_inputs, x.take()).unwrap();
                x = out.x_out;
                caches.push(out.cache);
            }
            let loss: f64 = caches.last().unwrap().loss_sum();
            assert_eq!(loss.to_bits(), mono.loss_sum.to_bits(), "{counts:?} loss");

            let mut d_params = b.zero_grads();
            let mut d_x: Option<Vec<f64>> = None;
            for (st, cache) in stages.iter().zip(&caches).rev() {
                let stage_inputs = ChunkInputs { kv_in: Vec::new(), ..inputs.clone() };
                let g_kv = vec![0.0f64; st.kv_elements(c)];
                let out = st
                    .backward(&stage_inputs, cache, d_x.take(), &g_kv, &mut d_params)
                    .unwrap();
                d_x = out.d_x_in;
            }
            for (pi, (got, want)) in d_params.iter().zip(&mono.d_params).enumerate() {
                assert_eq!(got, want, "{counts:?} param {pi} grads");
            }
        }
    }

    #[test]
    fn staged_forward_backward_matches_monolithic_chunk_vjp() {
        // Chain stage forwards/backwards by hand across P ∈ {1, 2, 3, 4}
        // (4 > num_layers exercises the empty-range passthrough) and
        // require bitwise-equal loss and gradients vs the single-stage
        // chunk_vjp — the stage pieces ARE the monolithic program.
        let (b, _params) = mini_backend(3);
        let c = b.manifest.chunk_size;
        let inputs = crate::runtime::ChunkInputs::<f64> {
            tokens: (0..c as i32).map(|i| i % 32).collect(),
            targets: (0..c as i32).map(|i| (i + 1) % 32).collect(),
            pos: (0..c as i32).collect(),
            seg: vec![0; c],
            kv_in: Vec::new(),
            prefix_len: 0,
        };
        let g_zero = vec![0.0f64; b.kv_elements(c)];
        let mono = b.chunk_vjp(&inputs, &g_zero).unwrap();

        for p in [1usize, 2, 3, 4] {
            let stages = StageBackend::partition(&b, p).unwrap();
            // Forward chain.
            let mut x: Option<Vec<f64>> = None;
            let mut caches = Vec::new();
            let mut kv_own_parts = Vec::new();
            for st in &stages {
                let stage_inputs = ChunkInputs { kv_in: Vec::new(), ..inputs.clone() };
                let out = st.forward(&stage_inputs, x.take()).unwrap();
                x = out.x_out;
                caches.push(out.cache);
                kv_own_parts.push(out.kv_own);
            }
            assert!(x.is_none(), "last stage consumes the activation");
            let loss: f64 = caches.last().unwrap().loss_sum();
            assert_eq!(loss.to_bits(), mono.loss_sum.to_bits(), "P={p} loss");
            let kv_cat: Vec<f64> = kv_own_parts.concat();
            assert_eq!(kv_cat, mono.kv_own, "P={p}: stage KV blocks concat to full KV");

            // Backward chain with per-stage grad buffers, then sum.
            let mut d_params = b.zero_grads();
            let mut d_x: Option<Vec<f64>> = None;
            for (st, cache) in stages.iter().zip(&caches).rev() {
                let stage_inputs = ChunkInputs { kv_in: Vec::new(), ..inputs.clone() };
                let g_kv = vec![0.0f64; st.kv_elements(c)];
                let out = st
                    .backward(&stage_inputs, cache, d_x.take(), &g_kv, &mut d_params)
                    .unwrap();
                d_x = out.d_x_in;
                assert!(out.d_kv_in.is_empty(), "no prefix here");
            }
            assert!(d_x.is_none(), "first stage consumes the cotangent");
            for (pi, (got, want)) in d_params.iter().zip(&mono.d_params).enumerate() {
                assert_eq!(got, want, "P={p} param {pi} grads");
            }
        }
    }

    #[test]
    fn handoff_contract_enforced() {
        let (b, _) = mini_backend(2);
        let c = b.manifest.chunk_size;
        let inputs = crate::runtime::ChunkInputs::<f64> {
            tokens: vec![0; c],
            targets: vec![-1; c],
            pos: (0..c as i32).collect(),
            seg: vec![0; c],
            kv_in: Vec::new(),
            prefix_len: 0,
        };
        let stages = StageBackend::partition(&b, 2).unwrap();
        // Stage 1 without an activation handoff is a contract violation.
        assert!(stages[1].forward(&inputs, None).is_err());
        // Stage 0 with one, likewise.
        let x = vec![0.0; c * b.manifest.hidden_size];
        assert!(stages[0].forward(&inputs, Some(x)).is_err());
    }
}
