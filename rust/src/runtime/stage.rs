//! Layer-partitioned view of the reference backend — the per-stage
//! execution surface of the stage-parallel pipeline executor
//! (`pipeline::exec`).
//!
//! A [`StageBackend`] owns one contiguous layer range of the model. Stage 0
//! additionally owns the embedding lookup; the last stage owns the final
//! norm, the tied LM head and the loss. The tied embedding matrix therefore
//! receives gradient contributions from both boundary stages — summing the
//! per-stage gradient buffers reproduces the monolithic backward exactly
//! (the same accumulation the single-stage `chunk_vjp` performs
//! internally).
//!
//! Stage boundaries exchange exactly two typed messages:
//!
//! - [`ActivationHandoff`] flows downstream (stage s → s+1) after each
//!   forward or recompute-forward of a chunk: the [T, hidden] activation
//!   that is the next stage's layer input.
//! - [`GradHandoff`] flows upstream (stage s+1 → s) after each backward:
//!   the [T, hidden] activation cotangent.
//!
//! Handoff buffers are *moved* across the boundary, never copied: the
//! sender gives up its `Vec`, the channel transfers ownership, and the
//! receiver feeds it straight into its layer range (`stage_fwd` /
//! `stage_bwd` take `Option<Vec<f64>>`). A handoff costs O(1) regardless
//! of the activation size.
//!
//! KV state never crosses a boundary: each stage stores the KV of its own
//! layers for its own chunks (the paper's per-stage StateStore), assembles
//! its own prefixes, and chains its own `d_kv_in` into earlier chunks'
//! pending KV gradients.

use std::ops::Range;

use super::reference::{ReferenceBackend, StageBwdOut, StageCache, StageFwdOut};
use super::{Backend, ChunkInputs};

/// Contiguous, balanced layer partition: stage `s` of `p` owns
/// `[s*L/P, (s+1)*L/P)`. Empty ranges are legal when P > L — such a stage
/// just relays activations (stage 0 still embeds, the last still computes
/// the loss).
pub fn stage_layer_range(num_layers: usize, num_stages: usize, stage: usize) -> Range<usize> {
    (stage * num_layers / num_stages)..((stage + 1) * num_layers / num_stages)
}

/// Activation handed from stage `s` to `s + 1` for one pipeline op.
#[derive(Clone, Debug)]
pub struct ActivationHandoff {
    /// Chunk (pipeline item) id.
    pub item: usize,
    /// True when this is a recompute-forward (Alg. 2's second forward).
    pub recompute: bool,
    /// [T, hidden] layer input for the receiving stage.
    pub x: Vec<f64>,
}

/// Activation cotangent handed from stage `s + 1` back to `s` for one
/// backward op.
#[derive(Clone, Debug)]
pub struct GradHandoff {
    /// Chunk (pipeline item) id.
    pub item: usize,
    /// [T, hidden] cotangent at the sending stage's layer input.
    pub d_x: Vec<f64>,
}

/// One pipeline stage's view of the reference backend: a contiguous layer
/// range plus the embedding (first stage) / head + loss (last stage).
pub struct StageBackend<'a> {
    backend: &'a ReferenceBackend,
    pub stage: usize,
    pub num_stages: usize,
    pub layers: Range<usize>,
}

impl<'a> StageBackend<'a> {
    pub fn new(
        backend: &'a ReferenceBackend,
        stage: usize,
        num_stages: usize,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(num_stages >= 1, "need at least one stage");
        anyhow::ensure!(stage < num_stages, "stage {stage} out of {num_stages}");
        let layers = stage_layer_range(backend.manifest.num_layers, num_stages, stage);
        Ok(Self { backend, stage, num_stages, layers })
    }

    /// All stages of a `p`-way partition, in order.
    pub fn partition(backend: &'a ReferenceBackend, p: usize) -> anyhow::Result<Vec<Self>> {
        (0..p).map(|s| Self::new(backend, s, p)).collect()
    }

    pub fn is_first(&self) -> bool {
        self.stage == 0
    }

    pub fn is_last(&self) -> bool {
        self.stage == self.num_stages - 1
    }

    /// Elements of a stage-local KV buffer covering `tokens` positions
    /// ([Lr, 2, tokens, H, D]).
    pub fn kv_elements(&self, tokens: usize) -> usize {
        let m = self.backend.manifest();
        self.layers.len() * 2 * tokens * m.num_heads * m.head_dim
    }

    /// This stage's forward for one chunk op. `inputs.kv_in` carries the
    /// stage-local prefix KV; `x_in` is the upstream activation handoff,
    /// consumed by value — zero-copy (None iff this is the first stage).
    pub fn forward(
        &self,
        inputs: &ChunkInputs<f64>,
        x_in: Option<Vec<f64>>,
    ) -> anyhow::Result<StageFwdOut> {
        self.backend.stage_fwd(
            self.layers.clone(),
            self.is_first(),
            self.is_last(),
            inputs,
            x_in,
        )
    }

    /// This stage's backward for one chunk op, consuming the cache its
    /// forward produced. `d_x_out` is the downstream cotangent handoff,
    /// consumed by value — zero-copy (None iff this is the last stage);
    /// parameter grads accumulate into `d_params` (full arity; only this
    /// stage's slots are touched).
    pub fn backward(
        &self,
        inputs: &ChunkInputs<f64>,
        cache: &StageCache,
        d_x_out: Option<Vec<f64>>,
        g_kv_own: &[f64],
        d_params: &mut [Vec<f64>],
    ) -> anyhow::Result<StageBwdOut> {
        self.backend.stage_bwd(
            self.layers.clone(),
            self.is_first(),
            self.is_last(),
            inputs,
            cache,
            d_x_out,
            g_kv_own,
            d_params,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelSpec;
    use crate::runtime::{FlatParams, Manifest};
    use crate::train::init_params;

    fn mini_backend(layers: u64) -> (ReferenceBackend, FlatParams) {
        let spec = ModelSpec {
            name: "stage-mini".into(),
            hidden_size: 16,
            num_layers: layers,
            num_heads: 2,
            num_kv_heads: 2,
            intermediate_size: 24,
            vocab_size: 32,
            tie_embeddings: true,
        };
        let manifest = Manifest::for_reference(&spec, 8, 2).unwrap();
        let mut b = ReferenceBackend::new(manifest).unwrap();
        let params = init_params(&b.manifest, 3);
        b.set_params(&params).unwrap();
        (b, params)
    }

    #[test]
    fn partition_covers_all_layers_contiguously() {
        for (l, p) in [(4usize, 1usize), (4, 2), (4, 4), (2, 4), (5, 3), (1, 1)] {
            let mut covered = Vec::new();
            for s in 0..p {
                let r = stage_layer_range(l, p, s);
                covered.extend(r);
            }
            assert_eq!(covered, (0..l).collect::<Vec<_>>(), "L={l} P={p}");
        }
    }

    #[test]
    fn staged_forward_backward_matches_monolithic_chunk_vjp() {
        // Chain stage forwards/backwards by hand across P ∈ {1, 2, 3, 4}
        // (4 > num_layers exercises the empty-range passthrough) and
        // require bitwise-equal loss and gradients vs the single-stage
        // chunk_vjp — the stage pieces ARE the monolithic program.
        let (b, _params) = mini_backend(3);
        let c = b.manifest.chunk_size;
        let inputs = crate::runtime::ChunkInputs::<f64> {
            tokens: (0..c as i32).map(|i| i % 32).collect(),
            targets: (0..c as i32).map(|i| (i + 1) % 32).collect(),
            pos: (0..c as i32).collect(),
            seg: vec![0; c],
            kv_in: Vec::new(),
            prefix_len: 0,
        };
        let g_zero = vec![0.0f64; b.kv_elements(c)];
        let mono = b.chunk_vjp(&inputs, &g_zero).unwrap();

        for p in [1usize, 2, 3, 4] {
            let stages = StageBackend::partition(&b, p).unwrap();
            // Forward chain.
            let mut x: Option<Vec<f64>> = None;
            let mut caches = Vec::new();
            let mut kv_own_parts = Vec::new();
            for st in &stages {
                let stage_inputs = ChunkInputs { kv_in: Vec::new(), ..inputs.clone() };
                let out = st.forward(&stage_inputs, x.take()).unwrap();
                x = out.x_out;
                caches.push(out.cache);
                kv_own_parts.push(out.kv_own);
            }
            assert!(x.is_none(), "last stage consumes the activation");
            let loss: f64 = caches.last().unwrap().loss_sum();
            assert_eq!(loss.to_bits(), mono.loss_sum.to_bits(), "P={p} loss");
            let kv_cat: Vec<f64> = kv_own_parts.concat();
            assert_eq!(kv_cat, mono.kv_own, "P={p}: stage KV blocks concat to full KV");

            // Backward chain with per-stage grad buffers, then sum.
            let mut d_params = b.zero_grads();
            let mut d_x: Option<Vec<f64>> = None;
            for (st, cache) in stages.iter().zip(&caches).rev() {
                let stage_inputs = ChunkInputs { kv_in: Vec::new(), ..inputs.clone() };
                let g_kv = vec![0.0f64; st.kv_elements(c)];
                let out = st
                    .backward(&stage_inputs, cache, d_x.take(), &g_kv, &mut d_params)
                    .unwrap();
                d_x = out.d_x_in;
                assert!(out.d_kv_in.is_empty(), "no prefix here");
            }
            assert!(d_x.is_none(), "first stage consumes the cotangent");
            for (pi, (got, want)) in d_params.iter().zip(&mono.d_params).enumerate() {
                assert_eq!(got, want, "P={p} param {pi} grads");
            }
        }
    }

    #[test]
    fn handoff_contract_enforced() {
        let (b, _) = mini_backend(2);
        let c = b.manifest.chunk_size;
        let inputs = crate::runtime::ChunkInputs::<f64> {
            tokens: vec![0; c],
            targets: vec![-1; c],
            pos: (0..c as i32).collect(),
            seg: vec![0; c],
            kv_in: Vec::new(),
            prefix_len: 0,
        };
        let stages = StageBackend::partition(&b, 2).unwrap();
        // Stage 1 without an activation handoff is a contract violation.
        assert!(stages[1].forward(&inputs, None).is_err());
        // Stage 0 with one, likewise.
        let x = vec![0.0; c * b.manifest.hidden_size];
        assert!(stages[0].forward(&inputs, Some(x)).is_err());
    }
}
