//! The artifact manifest written by `python/compile/aot.py`.

use crate::util::json::Json;
use std::path::Path;

/// One flat parameter's layout.
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<u64>,
    pub size: usize,
}

/// Parsed `manifest_<model>.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub model_name: String,
    pub vocab_size: usize,
    pub hidden_size: usize,
    pub num_layers: usize,
    pub num_heads: usize,
    pub head_dim: usize,
    pub model_param_count: u64,
    pub chunk_size: usize,
    pub max_chunks: usize,
    pub kv_buckets: Vec<usize>,
    pub full_step_lens: Vec<usize>,
    pub params: Vec<ParamSpec>,
}

impl Manifest {
    pub fn load(path: &Path) -> anyhow::Result<Manifest> {
        let j = Json::parse_file(path)?;
        Self::from_json(&j)
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Manifest> {
        let model = j.get("model").ok_or_else(|| anyhow::anyhow!("missing model"))?;
        let hidden = model.req_usize("hidden_size")?;
        let heads = model.req_usize("num_heads")?;
        let params = j
            .get("params")
            .and_then(|p| p.as_arr())
            .ok_or_else(|| anyhow::anyhow!("missing params"))?
            .iter()
            .map(|p| {
                Ok(ParamSpec {
                    name: p.req_str("name")?.to_string(),
                    shape: p
                        .get("shape")
                        .and_then(|s| s.as_arr())
                        .ok_or_else(|| anyhow::anyhow!("param shape"))?
                        .iter()
                        .map(|d| d.as_u64().unwrap_or(0))
                        .collect(),
                    size: p.req_usize("size")?,
                })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        let usize_arr = |key: &str| -> anyhow::Result<Vec<usize>> {
            Ok(j.get(key)
                .and_then(|b| b.as_arr())
                .ok_or_else(|| anyhow::anyhow!("missing {key}"))?
                .iter()
                .filter_map(|v| v.as_usize())
                .collect())
        };
        Ok(Manifest {
            model_name: model.req_str("name")?.to_string(),
            vocab_size: model.req_usize("vocab_size")?,
            hidden_size: hidden,
            num_layers: model.req_usize("num_layers")?,
            num_heads: heads,
            head_dim: hidden / heads,
            model_param_count: model.req_u64("param_count")?,
            chunk_size: j.req_usize("chunk_size")?,
            max_chunks: j.req_usize("max_chunks")?,
            kv_buckets: usize_arr("kv_buckets")?,
            full_step_lens: usize_arr("full_step_lens")?,
            params,
        })
    }

    /// Total parameter element count (sum over flat params).
    pub fn total_param_elements(&self) -> usize {
        self.params.iter().map(|p| p.size).sum()
    }

    /// Assemble an in-memory manifest for the pure-Rust reference backend —
    /// no artifact directory involved. Parameter specs follow the flat
    /// `PARAM_ORDER` of `python/compile/model.py` exactly (the reference
    /// backend and the AOT programs share one parameter layout), KV buckets
    /// are every chunk-aligned prefix below `max_chunks`, and
    /// `full_step_lens` covers every whole-chunk sequence length (the
    /// reference oracle actually accepts any length; the list documents the
    /// coverage PJRT would export).
    pub fn for_reference(
        model: &crate::config::ModelSpec,
        chunk_size: usize,
        max_chunks: usize,
    ) -> anyhow::Result<Manifest> {
        anyhow::ensure!(chunk_size > 0, "chunk_size must be positive");
        anyhow::ensure!(max_chunks > 0, "max_chunks must be positive");
        anyhow::ensure!(
            model.num_kv_heads == model.num_heads,
            "reference backend is MHA-only: model `{}` has {} kv heads != {} heads",
            model.name,
            model.num_kv_heads,
            model.num_heads
        );
        anyhow::ensure!(
            model.hidden_size % model.num_heads == 0,
            "hidden_size {} not divisible by num_heads {}",
            model.hidden_size,
            model.num_heads
        );
        let v = model.vocab_size;
        let h = model.hidden_size;
        let l = model.num_layers;
        let i = model.intermediate_size;
        let spec = |name: &str, shape: Vec<u64>| ParamSpec {
            name: name.to_string(),
            size: shape.iter().product::<u64>() as usize,
            shape,
        };
        // PARAM_ORDER from python/compile/model.py.
        let params = vec![
            spec("embed", vec![v, h]),
            spec("ln_f", vec![h]),
            spec("wq", vec![l, h, h]),
            spec("wk", vec![l, h, h]),
            spec("wv", vec![l, h, h]),
            spec("wo", vec![l, h, h]),
            spec("w_gate", vec![l, h, i]),
            spec("w_up", vec![l, h, i]),
            spec("w_down", vec![l, i, h]),
            spec("norm1", vec![l, h]),
            spec("norm2", vec![l, h]),
        ];
        let total: usize = params.iter().map(|p| p.size).sum();
        Ok(Manifest {
            model_name: model.name.clone(),
            vocab_size: v as usize,
            hidden_size: h as usize,
            num_layers: l as usize,
            num_heads: model.num_heads as usize,
            head_dim: (h / model.num_heads) as usize,
            model_param_count: total as u64,
            chunk_size,
            max_chunks,
            kv_buckets: (0..max_chunks).map(|c| c * chunk_size).collect(),
            full_step_lens: (1..=max_chunks).map(|c| c * chunk_size).collect(),
            params,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Json {
        Json::parse(
            r#"{
              "model": {"name": "tiny", "vocab_size": 512, "hidden_size": 128,
                        "num_layers": 2, "num_heads": 4, "intermediate_size": 384,
                        "rope_theta": 10000.0, "param_count": 492160},
              "chunk_size": 256, "max_chunks": 4,
              "kv_buckets": [0, 256, 512, 768],
              "full_step_lens": [512],
              "params": [
                {"name": "embed", "shape": [512, 128], "size": 65536},
                {"name": "ln_f", "shape": [128], "size": 128}
              ]
            }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_fields() {
        let m = Manifest::from_json(&sample()).unwrap();
        assert_eq!(m.model_name, "tiny");
        assert_eq!(m.chunk_size, 256);
        assert_eq!(m.kv_buckets, vec![0, 256, 512, 768]);
        assert_eq!(m.head_dim, 32);
        assert_eq!(m.params.len(), 2);
        assert_eq!(m.params[0].shape, vec![512, 128]);
        assert_eq!(m.total_param_elements(), 65664);
    }

    #[test]
    fn missing_fields_error() {
        let j = Json::parse(r#"{"chunk_size": 4}"#).unwrap();
        assert!(Manifest::from_json(&j).is_err());
    }

    #[test]
    fn reference_manifest_matches_python_param_layout() {
        let model = crate::config::ModelSpec::preset("tiny").unwrap();
        let m = Manifest::for_reference(&model, 256, 4).unwrap();
        assert_eq!(m.chunk_size, 256);
        assert_eq!(m.max_chunks, 4);
        assert_eq!(m.kv_buckets, vec![0, 256, 512, 768]);
        assert_eq!(m.full_step_lens, vec![256, 512, 768, 1024]);
        assert_eq!(m.head_dim, 32);
        let names: Vec<&str> = m.params.iter().map(|p| p.name.as_str()).collect();
        assert_eq!(
            names,
            vec![
                "embed", "ln_f", "wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down", "norm1",
                "norm2"
            ]
        );
        // tiny: v=512 h=128 l=2 i=384. embed 65536; ln_f 128; 4x wqkv/o of
        // 2*128*128 = 32768; gate/up 2*128*384 = 98304 each; down the same;
        // norms 256 each.
        assert_eq!(m.params[0].size, 65536);
        assert_eq!(m.params[2].shape, vec![2, 128, 128]);
        assert_eq!(m.params[6].size, 2 * 128 * 384);
        assert_eq!(m.model_param_count, m.total_param_elements() as u64);
    }

    #[test]
    fn reference_manifest_rejects_gqa() {
        let model = crate::config::ModelSpec::preset("qwen2.5-7b").unwrap();
        assert!(Manifest::for_reference(&model, 1024, 2).is_err());
    }
}
