//! The artifact manifest written by `python/compile/aot.py`.

use crate::util::json::Json;
use std::path::Path;

/// One flat parameter's layout.
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<u64>,
    pub size: usize,
}

/// Parsed `manifest_<model>.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub model_name: String,
    pub vocab_size: usize,
    pub hidden_size: usize,
    pub num_layers: usize,
    pub num_heads: usize,
    pub head_dim: usize,
    pub model_param_count: u64,
    pub chunk_size: usize,
    pub max_chunks: usize,
    pub kv_buckets: Vec<usize>,
    pub full_step_lens: Vec<usize>,
    pub params: Vec<ParamSpec>,
}

impl Manifest {
    pub fn load(path: &Path) -> anyhow::Result<Manifest> {
        let j = Json::parse_file(path)?;
        Self::from_json(&j)
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Manifest> {
        let model = j.get("model").ok_or_else(|| anyhow::anyhow!("missing model"))?;
        let hidden = model.req_usize("hidden_size")?;
        let heads = model.req_usize("num_heads")?;
        let params = j
            .get("params")
            .and_then(|p| p.as_arr())
            .ok_or_else(|| anyhow::anyhow!("missing params"))?
            .iter()
            .map(|p| {
                Ok(ParamSpec {
                    name: p.req_str("name")?.to_string(),
                    shape: p
                        .get("shape")
                        .and_then(|s| s.as_arr())
                        .ok_or_else(|| anyhow::anyhow!("param shape"))?
                        .iter()
                        .map(|d| d.as_u64().unwrap_or(0))
                        .collect(),
                    size: p.req_usize("size")?,
                })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        let usize_arr = |key: &str| -> anyhow::Result<Vec<usize>> {
            Ok(j.get(key)
                .and_then(|b| b.as_arr())
                .ok_or_else(|| anyhow::anyhow!("missing {key}"))?
                .iter()
                .filter_map(|v| v.as_usize())
                .collect())
        };
        Ok(Manifest {
            model_name: model.req_str("name")?.to_string(),
            vocab_size: model.req_usize("vocab_size")?,
            hidden_size: hidden,
            num_layers: model.req_usize("num_layers")?,
            num_heads: heads,
            head_dim: hidden / heads,
            model_param_count: model.req_u64("param_count")?,
            chunk_size: j.req_usize("chunk_size")?,
            max_chunks: j.req_usize("max_chunks")?,
            kv_buckets: usize_arr("kv_buckets")?,
            full_step_lens: usize_arr("full_step_lens")?,
            params,
        })
    }

    /// Total parameter element count (sum over flat params).
    pub fn total_param_elements(&self) -> usize {
        self.params.iter().map(|p| p.size).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Json {
        Json::parse(
            r#"{
              "model": {"name": "tiny", "vocab_size": 512, "hidden_size": 128,
                        "num_layers": 2, "num_heads": 4, "intermediate_size": 384,
                        "rope_theta": 10000.0, "param_count": 492160},
              "chunk_size": 256, "max_chunks": 4,
              "kv_buckets": [0, 256, 512, 768],
              "full_step_lens": [512],
              "params": [
                {"name": "embed", "shape": [512, 128], "size": 65536},
                {"name": "ln_f", "shape": [128], "size": 128}
              ]
            }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_fields() {
        let m = Manifest::from_json(&sample()).unwrap();
        assert_eq!(m.model_name, "tiny");
        assert_eq!(m.chunk_size, 256);
        assert_eq!(m.kv_buckets, vec![0, 256, 512, 768]);
        assert_eq!(m.head_dim, 32);
        assert_eq!(m.params.len(), 2);
        assert_eq!(m.params[0].shape, vec![512, 128]);
        assert_eq!(m.total_param_elements(), 65664);
    }

    #[test]
    fn missing_fields_error() {
        let j = Json::parse(r#"{"chunk_size": 4}"#).unwrap();
        assert!(Manifest::from_json(&j).is_err());
    }
}
