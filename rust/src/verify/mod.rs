//! Static plan verification — `chunkflow check`.
//!
//! The repo's scheduling contracts are enforced *dynamically* elsewhere:
//! the simulator errors on deadlock, the executor asserts agenda
//! conformance, `schedule::validate_group_plan` replays Algorithm-2 plans.
//! This module proves the same properties *statically*, before any compute,
//! over the exact artifacts the runtime consumes — the per-stage agendas,
//! the same-stage precedence edges and the (possibly sp-expanded) chunk
//! set. Five rule families:
//!
//! | rule id                      | property                                          |
//! |------------------------------|---------------------------------------------------|
//! | `schedule/deadlock`          | DAG acyclicity under the executor's channel/inbox |
//! |                              | semantics (warmup-skewed arrivals, same-stage     |
//! |                              | edges), plus op-coverage well-formedness          |
//! | `kv/prefix-order`            | KV-prefix chains: only last sp shards produce     |
//! |                              | prefixes; every producer's forward precedes its   |
//! |                              | consumers' on every stage                         |
//! | `alg2/descending-recompute`  | each dependent group's backward stream follows    |
//! |                              | Algorithm 2's descending order, declared by       |
//! |                              | same-stage edges                                  |
//! | `memory/k-budget`            | ≤ K live activations per group along every        |
//! |                              | stage-local agenda path; K-budget edges present   |
//! | `memory/chunk-size-bound`    | the symbolic peak bound is a function of          |
//! |                              | ChunkSize (Table-5 shape), cross-checked against  |
//! |                              | `MemoryModel::chunkflow_peak_sp`                  |
//!
//! Diagnostics are machine-readable (rule id, offending op/item/stage,
//! suggested fix) and flow through the `train`/`tune --joint`/`sweep`
//! pre-flights so a degenerate strategy is rejected with the violated rule
//! named, not a generic error chain.

use crate::chunk::{ChunkKind, ChunkSet, Segment};
use crate::memory::MemoryModel;
use crate::pipeline::{derive_retain, ExtraEdges, Op, OpKind, PolicyKind};
use crate::schedule::schedule_group;
use crate::sweep::Scenario;
use crate::util::json::Json;

pub const RULE_DEADLOCK: &str = "schedule/deadlock";
pub const RULE_PREFIX: &str = "kv/prefix-order";
pub const RULE_RECOMPUTE: &str = "alg2/descending-recompute";
pub const RULE_KBUDGET: &str = "memory/k-budget";
pub const RULE_MEMBOUND: &str = "memory/chunk-size-bound";

/// One verifier finding: the violated rule, where it happened and what to
/// do about it.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    /// Stable rule id (one of the `RULE_*` constants).
    pub rule: &'static str,
    /// Plan label (scenario candidate / policy), filled by scenario-level
    /// checks; empty for direct plan verification.
    pub plan: String,
    pub stage: Option<usize>,
    pub op: Option<Op>,
    pub detail: String,
    pub fix: String,
}

impl Diagnostic {
    fn new(rule: &'static str, detail: String, fix: &str) -> Self {
        Diagnostic {
            rule,
            plan: String::new(),
            stage: None,
            op: None,
            detail,
            fix: fix.to_string(),
        }
    }

    fn at_stage(mut self, stage: usize) -> Self {
        self.stage = Some(stage);
        self
    }

    fn on_op(mut self, op: Op) -> Self {
        self.op = Some(op);
        self
    }

    /// The offending item (chunk id), when the diagnostic names an op.
    pub fn item(&self) -> Option<usize> {
        self.op.map(|o| o.item)
    }

    /// Machine-readable form (the `check --out` artifact rows).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![("rule", Json::str(self.rule.to_string()))];
        if !self.plan.is_empty() {
            fields.push(("plan", Json::str(self.plan.clone())));
        }
        if let Some(s) = self.stage {
            fields.push(("stage", Json::num(s as f64)));
        }
        if let Some(op) = self.op {
            fields.push(("op", Json::str(op.to_string())));
            fields.push(("item", Json::num(op.item as f64)));
        }
        fields.push(("detail", Json::str(self.detail.clone())));
        fields.push(("fix", Json::str(self.fix.clone())));
        Json::obj(fields)
    }
}

impl std::fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[{}]", self.rule)?;
        if !self.plan.is_empty() {
            write!(f, " {}", self.plan)?;
        }
        if let Some(s) = self.stage {
            write!(f, " stage {s}")?;
        }
        if let Some(op) = self.op {
            write!(f, " op {op}")?;
        }
        write!(f, ": {} (fix: {})", self.detail, self.fix)
    }
}

/// A static plan: everything the verifier analyzes, exactly the artifacts
/// the simulator/executor would consume. `set` is the (possibly
/// sp-expanded) chunk set the agendas index.
#[derive(Clone, Debug)]
pub struct Plan {
    pub set: ChunkSet,
    /// Per item: ids of the same sequence's earlier prefix producers
    /// (ascending) — mirrors `pipeline::exec::ExecItem::prefix_items`.
    pub prefix_items: Vec<Vec<usize>>,
    pub agendas: Vec<Vec<Op>>,
    pub edges: ExtraEdges,
    pub policy: PolicyKind,
    pub k: usize,
}

impl Plan {
    /// Build the plan a (policy, K, stages, sp) strategy generates for a
    /// chunk set — the shape-only mirror of the executor's
    /// `build_exec_items_sp` + `PolicyKind::agendas` path.
    pub fn build(set: &ChunkSet, sp: u64, policy: PolicyKind, k: usize, stages: usize) -> Plan {
        let (expanded, prefix_items) = sp_expand_shape(set, sp);
        let (agendas, mut edges) = policy.agendas(&expanded, k, stages);
        // Deterministic test seam in the spirit of `util::fault`'s env
        // plans: dropping the declared precedence edges lets the CLI
        // fail-fast paths be exercised end to end without a code change.
        if std::env::var("CHUNKFLOW_VERIFY_MUTATE").as_deref() == Ok("drop-edges") {
            edges.clear();
        }
        Plan { set: expanded, prefix_items, agendas, edges, policy, k }
    }
}

/// Shape-only sequence-parallel expansion: the chunks and prefix chains
/// `pipeline::exec::build_exec_items_sp` would produce, without touching
/// token streams. `sp <= 1` returns the set verbatim with the
/// dependent-group prefix chains (the bit-identity contract's shape).
pub fn sp_expand_shape(set: &ChunkSet, sp: u64) -> (ChunkSet, Vec<Vec<usize>>) {
    if sp <= 1 {
        let mut prefix = vec![Vec::new(); set.chunks.len()];
        for group in set.dependent_groups() {
            let ids: Vec<usize> = group.iter().map(|c| c.id).collect();
            for (i, &id) in ids.iter().enumerate() {
                prefix[id] = ids[..i].to_vec();
            }
        }
        return (set.clone(), prefix);
    }
    let mut expanded_count: std::collections::BTreeMap<u64, usize> = Default::default();
    for ch in &set.chunks {
        if let ChunkKind::Dependent { seq_id, .. } = ch.kind {
            let shards = sp.min(ch.total_len().max(1)) as usize;
            *expanded_count.entry(seq_id).or_insert(0) += shards;
        }
    }
    let mut chunks = Vec::new();
    let mut prefix: Vec<Vec<usize>> = Vec::new();
    let mut last_shards: std::collections::BTreeMap<u64, Vec<usize>> = Default::default();
    let mut next_index: std::collections::BTreeMap<u64, usize> = Default::default();
    for ch in &set.chunks {
        match ch.kind {
            ChunkKind::Standalone => {
                chunks.push(crate::chunk::Chunk {
                    id: chunks.len(),
                    kind: ChunkKind::Standalone,
                    segments: ch.segments.clone(),
                });
                prefix.push(Vec::new());
            }
            ChunkKind::Dependent { seq_id, .. } => {
                let total_len = ch.total_len() as usize;
                let shards = (sp as usize).min(total_len.max(1));
                let prefix_items = last_shards.entry(seq_id).or_default().clone();
                let num_chunks = expanded_count[&seq_id];
                let seg0 = ch.segments[0];
                let rows = total_len.div_ceil(shards);
                for s in 0..shards {
                    let lo = s * rows;
                    let hi = ((s + 1) * rows).min(total_len);
                    let index = next_index.entry(seq_id).or_insert(0);
                    chunks.push(crate::chunk::Chunk {
                        id: chunks.len(),
                        kind: ChunkKind::Dependent { seq_id, index: *index, num_chunks },
                        segments: vec![Segment {
                            seq_id,
                            offset: seg0.offset + lo as u64,
                            len: (hi - lo) as u64,
                        }],
                    });
                    *index += 1;
                    prefix.push(prefix_items.clone());
                }
                last_shards.get_mut(&seq_id).unwrap().push(chunks.len() - 1);
            }
        }
    }
    (ChunkSet { chunk_size: set.chunk_size, chunks }, prefix)
}

/// Run every rule family against a plan. Empty result = the plan is
/// statically valid.
pub fn verify_plan(plan: &Plan, mm: &MemoryModel, context_length: u64) -> Vec<Diagnostic> {
    let mut diags = check_schedule(plan);
    diags.extend(check_memory_bound(plan, mm, context_length));
    diags
}

/// The four schedule rules (everything except the memory bound) — usable
/// where no `MemoryModel` is in scope (e.g. the elastic-partition search).
pub fn check_schedule(plan: &Plan) -> Vec<Diagnostic> {
    let mut diags = check_deadlock(&plan.agendas, &plan.edges, plan.set.chunks.len());
    if !diags.is_empty() {
        // Malformed or deadlocked agendas make the path-sensitive rules
        // meaningless; report the root cause alone.
        return diags;
    }
    diags.extend(check_prefix_order(plan));
    diags.extend(check_recompute_order(plan));
    diags.extend(check_k_budget(plan));
    diags
}

const FIX_DEADLOCK: &str =
    "regenerate the agendas with a registered SchedulePolicy so every dependency precedes its consumer";
const FIX_PREFIX: &str =
    "schedule each prefix producer's forward before its consumers on every stage (prefix chains follow chunk-index order; only last sp shards produce)";
const FIX_RECOMPUTE: &str =
    "rebuild the group's backward units with schedule_group (Algorithm 2's descending order and its same-stage edges)";
const FIX_KBUDGET: &str =
    "delay each recompute-forward until a backward frees a retained slot, or raise --k";
const FIX_MEMBOUND: &str =
    "keep retained activations within K so the peak stays the ChunkSize-bound Table-5 shape (shrink --chunk-size/K or raise --sp for headroom)";

#[inline]
fn kidx(k: OpKind) -> usize {
    match k {
        OpKind::Fwd => 0,
        OpKind::RecomputeFwd => 1,
        OpKind::Bwd => 2,
    }
}

/// Rule `schedule/deadlock`: op-coverage well-formedness plus a cost-free
/// fixpoint over the exact dependency semantics of
/// `pipeline::simulate_stagewise` (cross-stage channel order, rfwd-else-fwd
/// at the last stage, same-stage edges). If the fixpoint stalls, each
/// blocked stage's head op is reported with the dependency it waits on.
fn check_deadlock(agendas: &[Vec<Op>], edges: &ExtraEdges, n: usize) -> Vec<Diagnostic> {
    let p = agendas.len();
    let mut diags = Vec::new();
    if p == 0 {
        diags.push(Diagnostic::new(
            RULE_DEADLOCK,
            "plan has zero stages".to_string(),
            FIX_DEADLOCK,
        ));
        return diags;
    }
    for (s, agenda) in agendas.iter().enumerate() {
        for op in agenda {
            if op.item >= n {
                diags.push(
                    Diagnostic::new(
                        RULE_DEADLOCK,
                        format!("agenda references item {} but the set has {n} chunks", op.item),
                        FIX_DEADLOCK,
                    )
                    .at_stage(s)
                    .on_op(*op),
                );
                return diags;
            }
        }
    }
    for (before, after) in edges {
        for op in [before, after] {
            if op.item >= n {
                diags.push(Diagnostic::new(
                    RULE_DEADLOCK,
                    format!("edge references item {} but the set has {n} chunks", op.item),
                    FIX_DEADLOCK,
                ));
                return diags;
            }
        }
    }
    // Coverage: each stage runs every item's forward and backward exactly
    // once (the executor's channels starve otherwise) and recomputes at
    // most once; the recompute set must match stage 0 (retention is derived
    // globally from the agendas).
    let count_kinds = |agenda: &[Op]| -> Vec<[u32; 3]> {
        let mut counts = vec![[0u32; 3]; n];
        for op in agenda {
            counts[op.item][kidx(op.kind)] += 1;
        }
        counts
    };
    let stage0 = count_kinds(&agendas[0]);
    for (s, agenda) in agendas.iter().enumerate() {
        let counts = if s == 0 { stage0.clone() } else { count_kinds(agenda) };
        for (item, c) in counts.iter().enumerate() {
            if c[0] != 1 || c[2] != 1 || c[1] > 1 {
                diags.push(
                    Diagnostic::new(
                        RULE_DEADLOCK,
                        format!(
                            "agenda schedules item {item} as {}x Fwd / {}x RFwd / {}x Bwd \
                             (need exactly one Fwd and one Bwd, at most one RFwd)",
                            c[0], c[1], c[2]
                        ),
                        FIX_DEADLOCK,
                    )
                    .at_stage(s),
                );
            } else if c[1] != stage0[item][1] {
                diags.push(
                    Diagnostic::new(
                        RULE_DEADLOCK,
                        format!(
                            "item {item} is recomputed on stage {s} but not on stage 0 \
                             (the retention set must be identical on every stage)"
                        ),
                        FIX_DEADLOCK,
                    )
                    .at_stage(s)
                    .on_op(Op::rfwd(item)),
                );
            }
        }
    }
    if !diags.is_empty() {
        return diags;
    }

    // Cost-free fixpoint mirroring `simulate_stagewise`.
    let slot = |op: Op, s: usize| (s * 3 + kidx(op.kind)) * n + op.item;
    let mut done = vec![false; p * 3 * n];
    let mut cursor = vec![0usize; p];
    let mut edges_by_after: Vec<Vec<Op>> = vec![Vec::new(); 3 * n];
    for (before, after) in edges {
        edges_by_after[kidx(after.kind) * n + after.item].push(*before);
    }
    let total: usize = agendas.iter().map(|a| a.len()).sum();
    let mut completed = 0usize;
    while completed < total {
        let mut progressed = false;
        for s in 0..p {
            while cursor[s] < agendas[s].len() {
                let op = agendas[s][cursor[s]];
                let dep_ok = match op.kind {
                    OpKind::Fwd | OpKind::RecomputeFwd => s == 0 || done[slot(op, s - 1)],
                    OpKind::Bwd => {
                        if s == p - 1 {
                            done[slot(Op::rfwd(op.item), s)] || done[slot(Op::fwd(op.item), s)]
                        } else {
                            done[slot(op, s + 1)]
                        }
                    }
                };
                if !dep_ok {
                    break;
                }
                if edges_by_after[kidx(op.kind) * n + op.item]
                    .iter()
                    .any(|b| !done[slot(*b, s)])
                {
                    break;
                }
                done[slot(op, s)] = true;
                cursor[s] += 1;
                completed += 1;
                progressed = true;
            }
        }
        if !progressed {
            for s in 0..p {
                if cursor[s] >= agendas[s].len() || diags.len() >= 4 {
                    continue;
                }
                let op = agendas[s][cursor[s]];
                let waits = describe_wait(op, s, p, &edges_by_after, &done, n, &slot);
                diags.push(
                    Diagnostic::new(
                        RULE_DEADLOCK,
                        format!("cannot start {op}: waits on {waits}, which never completes"),
                        FIX_DEADLOCK,
                    )
                    .at_stage(s)
                    .on_op(op),
                );
            }
            break;
        }
    }
    diags
}

fn describe_wait(
    op: Op,
    s: usize,
    p: usize,
    edges_by_after: &[Vec<Op>],
    done: &[bool],
    n: usize,
    slot: &impl Fn(Op, usize) -> usize,
) -> String {
    let cross_unmet = match op.kind {
        OpKind::Fwd | OpKind::RecomputeFwd => {
            (s > 0 && !done[slot(op, s - 1)]).then(|| format!("{op} on stage {}", s - 1))
        }
        OpKind::Bwd => {
            if s == p - 1 {
                (!done[slot(Op::rfwd(op.item), s)] && !done[slot(Op::fwd(op.item), s)])
                    .then(|| format!("a forward of item {} on this stage", op.item))
            } else {
                (!done[slot(op, s + 1)]).then(|| format!("{op} on stage {}", s + 1))
            }
        }
    };
    if let Some(w) = cross_unmet {
        return w;
    }
    for b in &edges_by_after[kidx(op.kind) * n + op.item] {
        if !done[slot(*b, s)] {
            return format!("same-stage edge {b} -> {op}");
        }
    }
    "an unknown dependency".to_string()
}

/// Rule `kv/prefix-order`: structural prefix-chain validity (only last sp
/// shards produce prefixes; consumers list exactly the preceding chunk
/// boundaries) and per-stage ordering (every producer's forward precedes
/// each consumer's forward — the KV state is stored at first forward).
fn check_prefix_order(plan: &Plan) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    let set = &plan.set;
    let c = set.chunk_size;
    for (item, producers) in plan.prefix_items.iter().enumerate() {
        let chunk = &set.chunks[item];
        if !chunk.is_dependent() {
            if !producers.is_empty() {
                diags.push(
                    Diagnostic::new(
                        RULE_PREFIX,
                        format!("standalone chunk {item} must not consume a KV prefix"),
                        FIX_PREFIX,
                    )
                    .on_op(Op::fwd(item)),
                );
            }
            continue;
        }
        let seq = chunk.segments[0].seq_id;
        // The consumer's own offset tells how many prefix blocks precede it.
        let offset = chunk.segments[0].offset;
        let blocks = (producers.len() as u64) * c;
        if offset < blocks || offset >= blocks + c {
            diags.push(
                Diagnostic::new(
                    RULE_PREFIX,
                    format!(
                        "chunk {item} at sequence offset {offset} lists {} prefix producer(s); \
                         expected {} full ChunkSize blocks before it",
                        producers.len(),
                        offset / c.max(1)
                    ),
                    FIX_PREFIX,
                )
                .on_op(Op::fwd(item)),
            );
            continue;
        }
        for (pos, &prod) in producers.iter().enumerate() {
            let Some(pc) = set.chunks.get(prod) else {
                diags.push(Diagnostic::new(
                    RULE_PREFIX,
                    format!("chunk {item} lists unknown prefix producer {prod}"),
                    FIX_PREFIX,
                ));
                continue;
            };
            let seg = &pc.segments[0];
            if !pc.is_dependent() || seg.seq_id != seq || prod >= item {
                diags.push(
                    Diagnostic::new(
                        RULE_PREFIX,
                        format!(
                            "chunk {item} (seq {seq}) lists prefix producer {prod}, which is \
                             not an earlier dependent chunk of the same sequence"
                        ),
                        FIX_PREFIX,
                    )
                    .on_op(Op::fwd(prod)),
                );
                continue;
            }
            // Only a chunk ending on a ChunkSize boundary — the LAST shard
            // of an original chunk — may produce prefix block `pos`.
            if seg.offset + seg.len != (pos as u64 + 1) * c {
                diags.push(
                    Diagnostic::new(
                        RULE_PREFIX,
                        format!(
                            "chunk {item} lists {prod} as prefix block {pos}, but {prod} ends \
                             at sequence offset {} (not the block boundary {}); only the last \
                             sp shard of a chunk enters the prefix chain",
                            seg.offset + seg.len,
                            (pos as u64 + 1) * c
                        ),
                        FIX_PREFIX,
                    )
                    .on_op(Op::fwd(prod)),
                );
            }
        }
    }
    // Per-stage ordering: producer forwards precede consumer forwards.
    for (s, agenda) in plan.agendas.iter().enumerate() {
        let mut fwd_pos = vec![usize::MAX; set.chunks.len()];
        for (i, op) in agenda.iter().enumerate() {
            if op.kind == OpKind::Fwd {
                fwd_pos[op.item] = i;
            }
        }
        for (item, producers) in plan.prefix_items.iter().enumerate() {
            for &prod in producers {
                if prod < fwd_pos.len() && fwd_pos[prod] > fwd_pos[item] {
                    diags.push(
                        Diagnostic::new(
                            RULE_PREFIX,
                            format!(
                                "prefix producer Fwd({prod}) is scheduled after its consumer \
                                 Fwd({item}); the consumer would read KV state that does not \
                                 exist yet"
                            ),
                            FIX_PREFIX,
                        )
                        .at_stage(s)
                        .on_op(Op::fwd(item)),
                    );
                }
            }
        }
        if !diags.is_empty() && s + 1 < plan.agendas.len() {
            // Agendas share the forward order across stages by
            // construction; one stage's report is enough.
            break;
        }
    }
    diags
}

/// Rule `alg2/descending-recompute`: every dependent group's backward
/// stream follows Algorithm 2's order (retained chunks descending, then
/// discarded chunks descending with a recompute-forward glued before each
/// backward), the retention set matches the last-K rule, and the
/// descending order is declared as same-stage edges.
fn check_recompute_order(plan: &Plan) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    let n = plan.set.chunks.len();
    let retain = derive_retain(&plan.agendas, n);
    let edge_set: std::collections::BTreeSet<(Op, Op)> = plan.edges.iter().copied().collect();
    for group in plan.set.dependent_groups() {
        let ids: Vec<usize> = group.iter().map(|ch| ch.id).collect();
        let ng = ids.len();
        let plan_order = schedule_group(&ids, plan.k.max(1)).backward_order();
        // Retention must be the last-min(N,K) rule.
        let retained_from = ng - plan.k.max(1).min(ng);
        for (pos, &id) in ids.iter().enumerate() {
            let expect_retained = pos >= retained_from;
            if retain[id] != expect_retained {
                diags.push(
                    Diagnostic::new(
                        RULE_RECOMPUTE,
                        format!(
                            "chunk {id} (group position {pos}/{ng}) must be {} under \
                             Algorithm 2 with K={}, but the agendas {} it",
                            if expect_retained { "retained" } else { "recomputed" },
                            plan.k,
                            if retain[id] { "retain" } else { "recompute" }
                        ),
                        FIX_RECOMPUTE,
                    )
                    .on_op(Op::bwd(id)),
                );
            }
        }
        // Per-stage backward order must equal the Algorithm-2 plan order.
        let expected: Vec<usize> = plan_order.iter().map(|&(pos, _)| ids[pos]).collect();
        let in_group: std::collections::BTreeSet<usize> = ids.iter().copied().collect();
        for (s, agenda) in plan.agendas.iter().enumerate() {
            let actual: Vec<usize> = agenda
                .iter()
                .filter(|op| op.kind == OpKind::Bwd && in_group.contains(&op.item))
                .map(|op| op.item)
                .collect();
            if actual != expected {
                let bad = actual
                    .iter()
                    .zip(&expected)
                    .find(|(a, e)| a != e)
                    .map(|(a, _)| *a)
                    .or_else(|| actual.first().copied())
                    .unwrap_or(ids[0]);
                diags.push(
                    Diagnostic::new(
                        RULE_RECOMPUTE,
                        format!(
                            "group of seq chunks {ids:?} runs backwards as {actual:?}, but \
                             Algorithm 2's descending order is {expected:?}"
                        ),
                        FIX_RECOMPUTE,
                    )
                    .at_stage(s)
                    .on_op(Op::bwd(bad)),
                );
                break; // one stage's report per group is enough
            }
            // A discarded chunk's recompute must precede its backward.
            let mut pos_of = vec![usize::MAX; n];
            for (i, op) in agenda.iter().enumerate() {
                if op.kind == OpKind::RecomputeFwd {
                    pos_of[op.item] = i;
                }
            }
            let mut violated = false;
            for (i, op) in agenda.iter().enumerate() {
                if op.kind == OpKind::Bwd
                    && in_group.contains(&op.item)
                    && !retain[op.item]
                    && pos_of[op.item] > i
                {
                    diags.push(
                        Diagnostic::new(
                            RULE_RECOMPUTE,
                            format!(
                                "Bwd({}) runs before the recompute-forward restoring its \
                                 discarded activation",
                                op.item
                            ),
                            FIX_RECOMPUTE,
                        )
                        .at_stage(s)
                        .on_op(*op),
                    );
                    violated = true;
                    break;
                }
            }
            if violated {
                break;
            }
        }
        // Descending order must be *declared* as same-stage edges — the
        // executor-enforced contract, not just incidental agenda order.
        for pair in expected.windows(2) {
            let edge = (Op::bwd(pair[0]), Op::bwd(pair[1]));
            if !edge_set.contains(&edge) {
                diags.push(
                    Diagnostic::new(
                        RULE_RECOMPUTE,
                        format!(
                            "missing same-stage edge Bwd({}) -> Bwd({}) declaring the group's \
                             descending backward order",
                            pair[0], pair[1]
                        ),
                        FIX_RECOMPUTE,
                    )
                    .on_op(Op::bwd(pair[1])),
                );
            }
        }
        // K-budget edges: RF(i) waits for the backward freeing its slot.
        for &(pos, rf) in &plan_order {
            if rf && pos + plan.k < ng {
                let edge = (Op::bwd(ids[pos + plan.k]), Op::rfwd(ids[pos]));
                if !edge_set.contains(&edge) {
                    diags.push(
                        Diagnostic::new(
                            RULE_KBUDGET,
                            format!(
                                "missing same-stage edge Bwd({}) -> RFwd({}): the recompute \
                                 must wait for the backward that frees its activation slot",
                                ids[pos + plan.k],
                                ids[pos]
                            ),
                            FIX_KBUDGET,
                        )
                        .on_op(Op::rfwd(ids[pos])),
                    );
                }
            }
        }
    }
    diags
}

/// Rule `memory/k-budget`: walking each stage's agenda in order (the
/// executor runs agendas strictly in order), no dependent group ever holds
/// more than K live activation caches. Standalone chunks are exempt — their
/// warmup-depth residency is the 1F1B pipeline's, not Algorithm 2's.
fn check_k_budget(plan: &Plan) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    let n = plan.set.chunks.len();
    let retain = derive_retain(&plan.agendas, n);
    let mut group_of = vec![usize::MAX; n];
    let groups = plan.set.dependent_groups();
    for (g, group) in groups.iter().enumerate() {
        for ch in group {
            group_of[ch.id] = g;
        }
    }
    for (s, agenda) in plan.agendas.iter().enumerate() {
        let mut live = vec![0i64; groups.len()];
        for op in agenda {
            let g = group_of[op.item];
            if g == usize::MAX {
                continue;
            }
            match op.kind {
                OpKind::Fwd if retain[op.item] => live[g] += 1,
                OpKind::RecomputeFwd => live[g] += 1,
                OpKind::Bwd => live[g] -= 1,
                OpKind::Fwd => {}
            }
            if live[g] > plan.k as i64 {
                diags.push(
                    Diagnostic::new(
                        RULE_KBUDGET,
                        format!(
                            "{op} raises group {g}'s live activations to {} > K={} on this \
                             stage-local path",
                            live[g], plan.k
                        ),
                        FIX_KBUDGET,
                    )
                    .at_stage(s)
                    .on_op(*op),
                );
                return diags; // the first overflow explains the rest
            }
        }
    }
    diags
}

/// Rule `memory/chunk-size-bound`: re-derive the plan's symbolic peak from
/// the live-activation high-water-mark and the `MemoryModel` terms, then
/// cross-check (a) the term sum equals `chunkflow_peak_sp`, (b) the plan
/// stays within the declared K bound, and (c) the Table-5 shape — growing
/// the context moves only the KV term, never the activation term.
fn check_memory_bound(plan: &Plan, mm: &MemoryModel, context_length: u64) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    let cs = plan.set.chunk_size;
    let n = plan.set.chunks.len();
    let retain = derive_retain(&plan.agendas, n);
    // Group-local live HWM over every stage-local path (the K-budget walk).
    let mut group_of = vec![usize::MAX; n];
    let groups = plan.set.dependent_groups();
    for (g, group) in groups.iter().enumerate() {
        for ch in group {
            group_of[ch.id] = g;
        }
    }
    let mut hwm: u64 = 0;
    for agenda in &plan.agendas {
        let mut live = vec![0i64; groups.len()];
        for op in agenda {
            let g = group_of[op.item];
            if g == usize::MAX {
                continue;
            }
            match op.kind {
                OpKind::Fwd if retain[op.item] => live[g] += 1,
                OpKind::RecomputeFwd => live[g] += 1,
                OpKind::Bwd => live[g] -= 1,
                OpKind::Fwd => {}
            }
            hwm = hwm.max(live[g].max(0) as u64);
        }
    }
    let live = hwm.max(1); // a plan with no dependent groups still holds one
    let terms = mm.chunkflow_peak_terms(cs, live, context_length);
    if terms.total() != mm.chunkflow_peak_sp(cs, live, context_length) {
        diags.push(Diagnostic::new(
            RULE_MEMBOUND,
            format!(
                "symbolic terms (fixed {} + act {} + kv {}) disagree with \
                 chunkflow_peak_sp — memory model drift",
                terms.fixed, terms.activation, terms.kv_state
            ),
            FIX_MEMBOUND,
        ));
    }
    let declared = mm.chunkflow_peak_sp(cs, plan.k as u64, context_length);
    if terms.total() > declared {
        diags.push(Diagnostic::new(
            RULE_MEMBOUND,
            format!(
                "plan retains up to {hwm} live chunk activations, so its peak bound \
                 ({} bytes) exceeds the declared ChunkSize bound at K={} ({declared} bytes)",
                terms.total(),
                plan.k
            ),
            FIX_MEMBOUND,
        ));
    }
    // Table-5 shape: context growth must move only the KV term.
    let stretched = mm.chunkflow_peak_terms(cs, live, context_length.saturating_mul(8));
    if stretched.activation != terms.activation || stretched.fixed != terms.fixed {
        diags.push(Diagnostic::new(
            RULE_MEMBOUND,
            "activation term changed with context length: the peak bound must be a \
             function of ChunkSize, not of the max sequence length (Table 5)"
                .to_string(),
            FIX_MEMBOUND,
        ));
    }
    diags
}

/// Scenario-level check result.
#[derive(Clone, Debug)]
pub struct CheckReport {
    pub scenario: String,
    /// Number of (candidate, policy) plans analyzed.
    pub plans: usize,
    pub diagnostics: Vec<Diagnostic>,
}

impl CheckReport {
    pub fn is_clean(&self) -> bool {
        self.diagnostics.is_empty()
    }
}

/// Verify every (ChunkSize, K) candidate of a scenario under every
/// registered schedule policy, on the scenario's first sampled batch (the
/// batch stream is deterministic, and every batch shares the distribution's
/// shape — the plan structure the rules check is batch-independent).
pub fn check_scenario(s: &Scenario) -> anyhow::Result<CheckReport> {
    let parallel = s.chunkflow_parallel();
    let stages = parallel.pp.max(1) as usize;
    let mm = MemoryModel::new(s.model.clone(), parallel.clone());
    let mut sampler = crate::data::BatchSampler::new(
        s.dist()?,
        s.context_length,
        s.global_batch_size,
        s.seed,
    );
    let batch = sampler.next_batch();
    let mut plans = 0usize;
    let mut diagnostics = Vec::new();
    for &(cs, k) in &s.candidates {
        anyhow::ensure!(cs >= 1 && k >= 1, "candidate ({cs}, {k}) is degenerate");
        let set = crate::chunk::construct_chunks(&batch, cs);
        for policy in PolicyKind::ALL {
            let plan = Plan::build(&set, parallel.sp, policy, k as usize, stages);
            plans += 1;
            let label = format!(
                "cs={} k={k} policy={}",
                crate::util::format_tokens(cs),
                policy.name()
            );
            diagnostics.extend(verify_plan(&plan, &mm, s.context_length).into_iter().map(
                |mut d| {
                    d.plan = label.clone();
                    d
                },
            ));
        }
    }
    Ok(CheckReport { scenario: s.name.clone(), plans, diagnostics })
}

/// Fail-fast helper for the `train`/`tune --joint`/`sweep` pre-flights:
/// formats the diagnostics (rule id + offending item) into the error the
/// CLI prints, instead of a generic anyhow chain.
pub fn ensure_clean(label: &str, diagnostics: &[Diagnostic]) -> anyhow::Result<()> {
    if diagnostics.is_empty() {
        return Ok(());
    }
    let mut msg = format!(
        "{label}: static verification failed with {} diagnostic(s):",
        diagnostics.len()
    );
    for d in diagnostics.iter().take(8) {
        msg.push_str("\n  ");
        msg.push_str(&d.to_string());
    }
    if diagnostics.len() > 8 {
        msg.push_str(&format!("\n  ... and {} more", diagnostics.len() - 8));
    }
    anyhow::bail!(msg)
}

/// Pre-flight a single training/tuning strategy: build the plan its
/// configuration generates for `set` and verify every rule.
pub fn preflight(
    label: &str,
    set: &ChunkSet,
    sp: u64,
    policy: PolicyKind,
    k: usize,
    stages: usize,
    mm: &MemoryModel,
    context_length: u64,
) -> anyhow::Result<()> {
    let plan = Plan::build(set, sp, policy, k, stages);
    ensure_clean(label, &verify_plan(&plan, mm, context_length))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chunk::construct_chunks;
    use crate::config::{ModelSpec, ParallelConfig, RecomputeGranularity};
    use crate::data::Sequence;

    fn model() -> MemoryModel {
        MemoryModel::new(
            ModelSpec::preset("qwen2.5-7b").unwrap(),
            ParallelConfig::new(4, 4, RecomputeGranularity::Selective),
        )
    }

    fn mixed_set() -> ChunkSet {
        // One long sequence (5 dependent chunks), several shorts.
        let batch = vec![
            Sequence { id: 0, len: 10 },
            Sequence { id: 1, len: 2 },
            Sequence { id: 2, len: 1 },
            Sequence { id: 3, len: 1 },
        ];
        construct_chunks(&batch, 2)
    }

    fn rules(diags: &[Diagnostic]) -> Vec<&'static str> {
        diags.iter().map(|d| d.rule).collect()
    }

    #[test]
    fn generated_plans_verify_clean() {
        let set = mixed_set();
        for policy in PolicyKind::ALL {
            for (k, p, sp) in [(1usize, 4usize, 1u64), (2, 3, 1), (1, 2, 2), (3, 4, 4)] {
                let plan = Plan::build(&set, sp, policy, k, p);
                let diags = verify_plan(&plan, &model(), 64);
                assert!(
                    diags.is_empty(),
                    "{policy:?} k={k} p={p} sp={sp}: {:?}",
                    diags.iter().map(|d| d.to_string()).collect::<Vec<_>>()
                );
            }
        }
    }

    #[test]
    fn swapped_fwd_bwd_order_deadlocks() {
        let set = mixed_set();
        let mut plan = Plan::build(&set, 1, PolicyKind::default(), 2, 3);
        // Move the last stage's final Bwd in front of every forward: its
        // rfwd-else-fwd dependency can never be satisfied in agenda order.
        let agenda = plan.agendas.last_mut().unwrap();
        let bwd = agenda.pop().unwrap();
        agenda.insert(0, bwd);
        let diags = verify_plan(&plan, &model(), 64);
        assert!(rules(&diags).contains(&RULE_DEADLOCK), "{diags:?}");
        let d = diags.iter().find(|d| d.rule == RULE_DEADLOCK).unwrap();
        assert!(d.stage.is_some() && d.op.is_some(), "diagnostic names stage+op: {d}");
    }

    #[test]
    fn dropped_descending_edge_is_rejected() {
        let set = mixed_set();
        let mut plan = Plan::build(&set, 1, PolicyKind::default(), 2, 3);
        let before = plan.edges.len();
        plan.edges.retain(|(b, a)| {
            !(b.kind == OpKind::Bwd && a.kind == OpKind::Bwd)
        });
        assert!(plan.edges.len() < before, "mutation must drop an edge");
        let diags = check_schedule(&plan);
        assert!(rules(&diags).contains(&RULE_RECOMPUTE), "{diags:?}");
    }

    #[test]
    fn dropped_k_budget_edge_is_rejected() {
        let set = mixed_set();
        let mut plan = Plan::build(&set, 1, PolicyKind::default(), 1, 3);
        let before = plan.edges.len();
        plan.edges.retain(|(_, a)| a.kind != OpKind::RecomputeFwd);
        assert!(plan.edges.len() < before, "mutation must drop an RF edge");
        let diags = check_schedule(&plan);
        assert!(rules(&diags).contains(&RULE_KBUDGET), "{diags:?}");
    }

    #[test]
    fn prefix_consumer_before_producer_is_rejected() {
        let set = mixed_set();
        let mut plan = Plan::build(&set, 1, PolicyKind::default(), 2, 3);
        // Swap the forwards of the first two dependent chunks on stage 0:
        // the consumer now runs before its prefix producer.
        let ids: Vec<usize> =
            plan.set.dependent_groups()[0].iter().map(|c| c.id).collect();
        let agenda = &mut plan.agendas[0];
        let p0 = agenda.iter().position(|o| *o == Op::fwd(ids[0])).unwrap();
        let p1 = agenda.iter().position(|o| *o == Op::fwd(ids[1])).unwrap();
        agenda.swap(p0, p1);
        let diags = check_schedule(&plan);
        assert!(rules(&diags).contains(&RULE_PREFIX), "{diags:?}");
    }

    #[test]
    fn k_budget_overflow_is_rejected() {
        let set = mixed_set();
        let mut plan = Plan::build(&set, 1, PolicyKind::default(), 1, 1);
        // Hoist the first recompute-forward to run right after the retained
        // chunk's forward, before any backward frees a slot: the group then
        // holds 2 live activations against K=1.
        let ids: Vec<usize> =
            plan.set.dependent_groups()[0].iter().map(|c| c.id).collect();
        let retained = *ids.last().unwrap();
        let agenda = &mut plan.agendas[0];
        let rf_pos = agenda.iter().position(|o| o.kind == OpKind::RecomputeFwd).unwrap();
        let rf = agenda.remove(rf_pos);
        let f_last = agenda.iter().position(|o| *o == Op::fwd(retained)).unwrap();
        agenda.insert(f_last + 1, rf);
        // Drop the edge that would (correctly) deadlock the hoisted RF so
        // the budget walk is what catches it.
        plan.edges.retain(|(_, a)| *a != rf);
        let walk = check_k_budget(&plan);
        assert!(rules(&walk).contains(&RULE_KBUDGET), "{walk:?}");
        let d = walk.iter().find(|d| d.rule == RULE_KBUDGET).unwrap();
        assert_eq!(d.op.map(|o| o.kind), Some(OpKind::RecomputeFwd));
        // The full rule set flags it too (walk + missing K-budget edge).
        let diags = check_schedule(&plan);
        assert!(rules(&diags).contains(&RULE_KBUDGET), "{diags:?}");
    }

    #[test]
    fn retention_not_matching_last_k_is_rejected() {
        let set = mixed_set();
        let mut plan = Plan::build(&set, 1, PolicyKind::default(), 2, 2);
        // Claim an extra recompute for a chunk Algorithm 2 retains.
        let ids: Vec<usize> =
            plan.set.dependent_groups()[0].iter().map(|c| c.id).collect();
        let retained = *ids.last().unwrap();
        for agenda in &mut plan.agendas {
            let bwd = agenda.iter().position(|o| *o == Op::bwd(retained)).unwrap();
            agenda.insert(bwd, Op::rfwd(retained));
        }
        let diags = check_schedule(&plan);
        assert!(rules(&diags).contains(&RULE_RECOMPUTE), "{diags:?}");
    }

    #[test]
    fn sp_expansion_shape_matches_executor_contract() {
        let set = mixed_set();
        let (expanded, prefix) = sp_expand_shape(&set, 2);
        // 5 dependent chunks x 2 shards + 2 standalone bins.
        let dep = expanded.chunks.iter().filter(|c| c.is_dependent()).count();
        assert_eq!(dep, 10);
        assert_eq!(prefix.len(), expanded.chunks.len());
        // Every shard of original chunk j consumes exactly j producers, and
        // every producer ends on a ChunkSize boundary.
        for (i, ch) in expanded.chunks.iter().enumerate() {
            if !ch.is_dependent() {
                assert!(prefix[i].is_empty());
                continue;
            }
            let blocks = ch.segments[0].offset / expanded.chunk_size;
            assert_eq!(prefix[i].len() as u64, blocks, "chunk {i}");
            for (pos, &p) in prefix[i].iter().enumerate() {
                let seg = &expanded.chunks[p].segments[0];
                assert_eq!(seg.offset + seg.len, (pos as u64 + 1) * expanded.chunk_size);
            }
        }
    }

    #[test]
    fn diagnostics_are_machine_readable() {
        let d = Diagnostic::new(RULE_KBUDGET, "over budget".into(), FIX_KBUDGET)
            .at_stage(2)
            .on_op(Op::rfwd(7));
        let j = d.to_json();
        assert_eq!(j.get("rule").and_then(|v| v.as_str()), Some(RULE_KBUDGET));
        assert_eq!(j.get("item").and_then(|v| v.as_f64()), Some(7.0));
        assert_eq!(j.get("op").and_then(|v| v.as_str()), Some("RFwd(7)"));
        let text = d.to_string();
        assert!(text.contains("memory/k-budget") && text.contains("stage 2"), "{text}");
        assert!(text.contains("fix:"), "{text}");
    }

    #[test]
    fn empty_set_verifies_clean() {
        let set = construct_chunks(&[], 8);
        for policy in PolicyKind::ALL {
            let plan = Plan::build(&set, 1, policy, 1, 4);
            assert!(verify_plan(&plan, &model(), 64).is_empty());
        }
    }

    #[test]
    fn ensure_clean_formats_rule_and_item() {
        let d = Diagnostic::new(RULE_DEADLOCK, "stuck".into(), FIX_DEADLOCK)
            .at_stage(1)
            .on_op(Op::bwd(3));
        let err = ensure_clean("train pre-flight", &[d]).unwrap_err().to_string();
        assert!(err.contains("schedule/deadlock"), "{err}");
        assert!(err.contains("Bwd(3)"), "{err}");
        assert!(err.contains("train pre-flight"), "{err}");
    }
}
