//! Paper-artifact regeneration: every table and figure of the evaluation,
//! printed with the paper's number next to ours and dumped as JSON under
//! `target/report/` (see DESIGN.md §4 for the experiment index).

use crate::baseline::{self, paper_table3, paper_table4};
use crate::config::{ModelSpec, ParallelConfig, RecomputeGranularity};
use crate::data::{BatchSampler, LengthDistribution, Sequence};
use crate::memory::MemoryModel;
use crate::pipeline::onef1b::{self, PipelineItem};
use crate::sweep::{Scenario, SweepEngine};
use crate::tune::GridSearch;
use crate::util::json::Json;

const GIB: f64 = (1u64 << 30) as f64;

/// Where JSON dumps land.
fn report_dir() -> std::path::PathBuf {
    std::path::PathBuf::from("target/report")
}

fn dump(name: &str, j: &Json) {
    let path = report_dir().join(format!("{name}.json"));
    if let Err(e) = j.write_file(&path) {
        crate::warn_!("could not write {}: {e}", path.display());
    }
}

/// Table 1: LMSysChat1M length distribution.
pub fn table1() -> Json {
    distribution_table("table1", LengthDistribution::lmsys_chat_1m(), &[
        0.90499, 0.99539, 0.99908, 0.99987, 0.99996,
    ])
}

/// Table 2: evaluation-dataset length distribution.
pub fn table2() -> Json {
    distribution_table("table2", LengthDistribution::evaluation_dataset(), &[
        0.9817, 0.9972, 0.9983, 0.9992, 0.9998,
    ])
}

fn distribution_table(name: &str, dist: LengthDistribution, paper: &[f64]) -> Json {
    println!("\n== {name}: sequence length distribution ({}) ==", dist.name);
    println!("{:<12} {:>12} {:>12}", "bucket", "paper", "ours(model)");
    let mut rows = Vec::new();
    for ((label, ours), p) in dist.table_rows().into_iter().zip(paper) {
        println!("{label:<12} {:>11.3}% {:>11.3}%", p * 100.0, ours * 100.0);
        rows.push(Json::obj(vec![
            ("bucket", Json::str(label)),
            ("paper", Json::num(*p)),
            ("ours", Json::num(ours)),
        ]));
    }
    println!("{:<12} {:>12} {:>12}", "Longest", "-", crate::util::format_tokens(dist.longest));
    let j = Json::Arr(rows);
    dump(name, &j);
    j
}

/// Figure 1: per-micro-step memory footprint, Megatron 7B/32K/selective.
pub fn figure1(seed: u64) -> Json {
    let spec = ModelSpec::preset("qwen2.5-7b").unwrap();
    let mm = MemoryModel::new(
        spec,
        ParallelConfig::new(4, 1, RecomputeGranularity::Selective),
    );
    let mut sampler =
        BatchSampler::new(LengthDistribution::lmsys_chat_1m(), 32 * 1024, 1000, seed);
    let batch = sampler.next_batch();
    let trace = baseline::microstep_memory_trace(&batch, &mm);
    let (peak, under45) = baseline::trace_stats(&trace, 45 * (1u64 << 30));
    println!("\n== figure1: Megatron micro-step memory (7B, 32K, selective) ==");
    println!("peak memory:          paper ~75 GB   ours {:.1} GiB", peak as f64 / GIB);
    println!(
        "micro-steps < 45 GB:  paper 97.7%    ours {:.1}%",
        under45 * 100.0
    );
    // Histogram rows (8 GiB buckets) for the figure shape.
    let mut hist = vec![0usize; 12];
    for &b in &trace {
        hist[((b as f64 / GIB / 8.0) as usize).min(11)] += 1;
    }
    for (i, n) in hist.iter().enumerate() {
        if *n > 0 {
            println!(
                "  {:>3}-{:<3} GiB | {}",
                i * 8,
                (i + 1) * 8,
                "#".repeat(1 + n * 60 / trace.len())
            );
        }
    }
    let j = Json::obj(vec![
        ("peak_gib", Json::num(peak as f64 / GIB)),
        ("frac_under_45gb", Json::num(under45)),
        ("paper_peak_gb", Json::num(75.0)),
        ("paper_frac_under_45gb", Json::num(0.977)),
        (
            "trace_gib",
            Json::Arr(trace.iter().map(|&b| Json::num(b as f64 / GIB)).collect()),
        ),
    ]);
    dump("figure1", &j);
    j
}

/// The Figure 2/6/7 toy scenario: sequences of 1, 1, 2, 4 Units on 4 stages.
fn toy_batch() -> Vec<Sequence> {
    vec![
        Sequence { id: 0, len: 1 },
        Sequence { id: 1, len: 1 },
        Sequence { id: 2, len: 2 },
        Sequence { id: 3, len: 4 },
    ]
}

/// Figure 2: standard 1F1B over variable-length sequences -> 57.14% bubbles.
pub fn figure2() -> Json {
    let items: Vec<PipelineItem> = toy_batch()
        .iter()
        .map(|s| PipelineItem { fwd_cost: s.len as f64, bwd_cost: 2.0 * s.len as f64 })
        .collect();
    let t = onef1b::simulate_standard(&items, 4).unwrap();
    println!("\n== figure2: standard 1F1B on [1,1,2,4]·Unit, PP=4 ==");
    println!(
        "bubble ratio: paper 57.14%   ours {:.2}%  (makespan {} units)",
        t.bubble_ratio() * 100.0,
        t.makespan
    );
    println!("{}", t.gantt(72));
    let j = Json::obj(vec![
        ("paper_bubble", Json::num(0.5714)),
        ("ours_bubble", Json::num(t.bubble_ratio())),
        ("makespan_units", Json::num(t.makespan)),
    ]);
    dump("figure2", &j);
    j
}

/// Figure 4: chunk construction example on a 16-sequence batch.
pub fn figure4() -> Json {
    use crate::chunk::construct_chunks;
    let k = 1024;
    let mut batch: Vec<Sequence> = Vec::new();
    for i in 0..6 {
        batch.push(Sequence { id: i, len: 1 * k });
    }
    for i in 6..15 {
        batch.push(Sequence { id: i, len: 2 * k });
    }
    batch.push(Sequence { id: 15, len: 32 * k }); // "Sequence 6" of the paper
    let set = construct_chunks(&batch, 8 * k);
    let dep = set.chunks.iter().filter(|c| c.is_dependent()).count();
    let sta = set.chunks.len() - dep;
    println!("\n== figure4: chunk construction (16 seqs, ChunkSize 8K) ==");
    println!("paper: 1 long seq -> 4 chunks, 15 short seqs -> 3 chunks (7 total)");
    println!("ours:  long -> {dep} chunks, short -> {sta} chunks ({} total)", set.chunks.len());
    for c in &set.chunks {
        println!(
            "  chunk {} [{}] {} tokens, {} segment(s)",
            c.id,
            if c.is_dependent() { "dependent " } else { "standalone" },
            c.total_len(),
            c.segments.len()
        );
    }
    let j = Json::obj(vec![
        ("dependent_chunks", Json::num(dep as f64)),
        ("standalone_chunks", Json::num(sta as f64)),
        ("paper_dependent", Json::num(4.0)),
        ("paper_standalone", Json::num(3.0)),
    ]);
    dump("figure4", &j);
    j
}

/// Figure 5: Algorithm-2 schedules for a 4-chunk group at K=1 and K=2.
pub fn figure5() -> Json {
    use crate::schedule::{schedule_group, validate_group_plan};
    println!("\n== figure5: state-aware chunk schedule (4 dependent chunks) ==");
    let mut out = Vec::new();
    for k in [1usize, 2] {
        let plan = schedule_group(&[0, 1, 2, 3], k);
        let stats = validate_group_plan(&plan).unwrap();
        let ops: Vec<String> = plan
            .ops
            .iter()
            .map(|op| match op {
                crate::schedule::ChunkOp::Forward { chunk, retain } => {
                    format!("F{}{}", chunk, if *retain { "*" } else { "" })
                }
                crate::schedule::ChunkOp::RecomputeForward { chunk } => format!("rF{chunk}"),
                crate::schedule::ChunkOp::Backward { chunk } => format!("B{chunk}"),
            })
            .collect();
        println!(
            "K={k}: {}   (recomputed {}, peak live activations {})",
            ops.join(" "),
            stats.n_recompute,
            stats.peak_live_activations
        );
        out.push(Json::obj(vec![
            ("k", Json::num(k as f64)),
            ("ops", Json::Arr(ops.into_iter().map(Json::str).collect())),
            ("recomputes", Json::num(stats.n_recompute as f64)),
            ("peak_live", Json::num(stats.peak_live_activations as f64)),
        ]));
    }
    println!("paper: K=1 re-executes one chunk per discarded chunk, <=1 live;");
    println!("       K=2 retains two activations, fewer recomputes.");
    let j = Json::Arr(out);
    dump("figure5", &j);
    j
}

/// Figure 6: state-aware 1F1B on the toy scenario (ChunkSize=2·Unit).
pub fn figure6() -> Json {
    use crate::chunk::construct_chunks;
    let set = construct_chunks(&toy_batch(), 2);
    println!("\n== figure6: state-aware 1F1B, ChunkSize=2·Unit, PP=4 ==");
    let mut rows = Vec::new();
    for (k, paper) in [(1usize, 0.541), (2usize, 0.478)] {
        let t = onef1b::simulate_state_aware(&set, k, 4, |id| {
            let len = set.chunks[id].total_len() as f64;
            crate::pipeline::OpCosts { fwd: len, bwd: 2.0 * len }
        })
        .unwrap();
        println!(
            "K={k}: bubble paper {:.1}%   ours {:.2}%  (makespan {} units)",
            paper * 100.0,
            t.bubble_ratio() * 100.0,
            t.makespan
        );
        println!("{}", t.gantt(72));
        rows.push(Json::obj(vec![
            ("k", Json::num(k as f64)),
            ("paper_bubble", Json::num(paper)),
            ("ours_bubble", Json::num(t.bubble_ratio())),
            ("makespan_units", Json::num(t.makespan)),
        ]));
    }
    let j = Json::Arr(rows);
    dump("figure6", &j);
    j
}

/// Figure 7: too-large ChunkSize (4·Unit) degrades to 60% bubbles.
pub fn figure7() -> Json {
    use crate::chunk::construct_chunks;
    let set = construct_chunks(&toy_batch(), 4);
    let t = onef1b::simulate_state_aware(&set, 1, 4, |id| {
        let len = set.chunks[id].total_len() as f64;
        crate::pipeline::OpCosts { fwd: len, bwd: 2.0 * len }
    })
    .unwrap();
    println!("\n== figure7: ChunkSize=4·Unit, K=1 (2 chunks) ==");
    println!(
        "bubble ratio: paper 60%   ours {:.2}%  — worse than the 57.14% baseline,",
        t.bubble_ratio() * 100.0
    );
    println!("confirming §5: oversized chunks reduce pipeline utilization.");
    println!("{}", t.gantt(72));
    let j = Json::obj(vec![
        ("paper_bubble", Json::num(0.60)),
        ("ours_bubble", Json::num(t.bubble_ratio())),
        ("makespan_units", Json::num(t.makespan)),
    ]);
    dump("figure7", &j);
    j
}

/// Table 3: baseline parallel strategies — the paper's choices validated
/// against our memory model, plus the configs our own search derives.
pub fn table3() -> Json {
    println!("\n== table3: Megatron parallel strategies <TP,SP,PP,recompute> ==");
    println!(
        "{:<14} {:>6} {:>22} {:>22}",
        "model", "ctx", "paper", "our-search"
    );
    let mut rows = Vec::new();
    for m in ["qwen2.5-7b", "qwen2.5-14b", "qwen2.5-32b", "qwen2.5-72b"] {
        for ctx in [32 * 1024u64, 256 * 1024] {
            let paper = paper_table3(m, ctx).unwrap();
            let spec = ModelSpec::preset(m).unwrap();
            let derived = baseline::derive_baseline_config(&spec, ctx);
            let ours = derived
                .as_ref()
                .map(|c| c.paper_format())
                .unwrap_or_else(|| "OOM".into());
            println!(
                "{m:<14} {:>5}K {:>22} {:>22}",
                ctx / 1024,
                paper.paper_format(),
                ours
            );
            rows.push(Json::obj(vec![
                ("model", Json::str(m)),
                ("context", Json::num(ctx as f64)),
                ("paper", Json::str(paper.paper_format())),
                ("ours", Json::str(ours)),
            ]));
        }
    }
    let j = Json::Arr(rows);
    dump("table3", &j);
    j
}

/// Table 4 + Table 6: ChunkFlow (ChunkSize, K) tuning.
pub fn table4(quick: bool) -> Json {
    println!("\n== table4: best (ChunkSize, K) by grid search ==");
    println!("{:<14} {:>6} {:>12} {:>12}", "model", "ctx", "paper", "ours");
    let mut rows = Vec::new();
    for m in ["qwen2.5-7b", "qwen2.5-14b", "qwen2.5-32b", "qwen2.5-72b"] {
        for ctx in [32 * 1024u64, 256 * 1024] {
            let (pc, pk) = paper_table4(m, ctx).unwrap();
            let mut cfg = paper_table3(m, ctx).unwrap();
            cfg.recompute = RecomputeGranularity::Selective;
            let mut gs = GridSearch::standard(ModelSpec::preset(m).unwrap(), cfg, ctx);
            if quick {
                gs.global_batch_size = 64;
                gs.iters = 1;
            }
            let best = gs.best().expect("some feasible point");
            let ours = format!(
                "({}, {})",
                crate::util::format_tokens(best.chunk_size),
                best.k
            );
            let paper = format!("({}, {})", crate::util::format_tokens(pc), pk);
            println!("{m:<14} {:>5}K {:>12} {:>12}", ctx / 1024, paper, ours);
            rows.push(Json::obj(vec![
                ("model", Json::str(m)),
                ("context", Json::num(ctx as f64)),
                ("paper", Json::str(paper)),
                ("ours", Json::str(ours)),
                ("ours_seconds", Json::num(best.avg_iteration_seconds)),
            ]));
        }
    }
    let j = Json::Arr(rows);
    dump("table4", &j);
    j
}

/// Table 5: ChunkFlow peak memory vs ChunkSize (7B, <4,4,1,selective>, K=1).
pub fn table5() -> Json {
    let spec = ModelSpec::preset("qwen2.5-7b").unwrap();
    let mm = MemoryModel::new(
        spec,
        ParallelConfig::new(4, 1, RecomputeGranularity::Selective),
    );
    let rows_paper: [(u64, u64, f64); 6] = [
        (32, 2, 41.6),
        (256, 2, 45.6),
        (32, 4, 47.5),
        (256, 4, 50.8),
        (32, 8, 59.3),
        (256, 8, 63.8),
    ];
    println!("\n== table5: ChunkFlow peak memory (7B, K=1) ==");
    println!("{:>6} {:>10} {:>12} {:>12} {:>8}", "ctx", "ChunkSize", "paper GiB", "ours GiB", "err");
    let mut rows = Vec::new();
    for (ctx_k, cs_k, paper) in rows_paper {
        let ours = mm.chunkflow_peak(cs_k * 1024, 1, ctx_k * 1024) as f64 / GIB;
        println!(
            "{:>5}K {:>9}K {:>12.1} {:>12.1} {:>7.1}%",
            ctx_k,
            cs_k,
            paper,
            ours,
            (ours - paper) / paper * 100.0
        );
        rows.push(Json::obj(vec![
            ("context_k", Json::num(ctx_k as f64)),
            ("chunk_k", Json::num(cs_k as f64)),
            ("paper_gib", Json::num(paper)),
            ("ours_gib", Json::num(ours)),
        ]));
    }
    let j = Json::Arr(rows);
    dump("table5", &j);
    j
}

/// Table 6: (ChunkSize, K) at constant ChunkSize·K = 32K (7B, 256K ctx).
pub fn table6() -> Json {
    let spec = ModelSpec::preset("qwen2.5-7b").unwrap();
    let cfg = ParallelConfig::new(4, 4, RecomputeGranularity::Selective);
    let gs = GridSearch::standard(spec, cfg, 256 * 1024);
    let points = [(2048u64, 16u64, 29810.0), (8192, 4, 23774.0), (32 * 1024, 1, 28942.0)];
    println!("\n== table6: (ChunkSize, K) sweep at ChunkSize*K = 32K (7B, 256K) ==");
    println!("{:>14} {:>14} {:>14} {:>10}", "(ChunkSize,K)", "paper ms", "ours s", "ours norm");
    let mut rows = Vec::new();
    let mut results = Vec::new();
    for (cs, k, paper_ms) in points {
        let p = gs.evaluate(cs, k);
        results.push((cs, k, paper_ms, p.avg_iteration_seconds));
    }
    let best = results
        .iter()
        .map(|r| r.3)
        .fold(f64::INFINITY, f64::min);
    for (cs, k, paper_ms, ours) in &results {
        println!(
            "{:>13} {:>14.0} {:>14.3} {:>10.3}",
            format!("({},{})", crate::util::format_tokens(*cs), k),
            paper_ms,
            ours,
            ours / best
        );
        rows.push(Json::obj(vec![
            ("chunk_size", Json::num(*cs as f64)),
            ("k", Json::num(*k as f64)),
            ("paper_ms", Json::num(*paper_ms)),
            ("ours_seconds", Json::num(*ours)),
        ]));
    }
    println!("paper shape: (8K,4) optimal; extremes degrade. ours: see norm column.");
    let j = Json::Arr(rows);
    dump("table6", &j);
    j
}

/// Figure 8: end-to-end ChunkFlow vs Megatron-LM across models and contexts.
/// Each (model, context) cell is one sweep-engine scenario with the paper's
/// tuned (ChunkSize, K) as its single candidate; the engine fans the cells
/// out at (scenario × batch × unit) granularity — every sampled batch of
/// every cell is its own work unit — so the figure saturates the pool even
/// though each cell has a single candidate.
pub fn figure8(iters: usize, batch: usize, seed: u64) -> Json {
    println!("\n== figure8: end-to-end speedup (normalized iteration time) ==");
    println!(
        "{:<14} {:>6} {:>12} {:>12} {:>9}",
        "model", "ctx", "megatron s", "chunkflow s", "speedup"
    );
    let mut scenarios = Vec::new();
    for m in ["qwen2.5-7b", "qwen2.5-14b", "qwen2.5-32b", "qwen2.5-72b"] {
        for ctx in [32 * 1024u64, 256 * 1024] {
            scenarios.push(Scenario {
                name: format!("figure8-{m}-{}", crate::util::format_tokens(ctx)),
                model: ModelSpec::preset(m).unwrap(),
                parallel: paper_table3(m, ctx).unwrap(),
                context_length: ctx,
                distribution: "eval".to_string(),
                global_batch_size: batch,
                iters,
                seed,
                candidates: vec![paper_table4(m, ctx).unwrap()],
            });
        }
    }
    let results = SweepEngine::auto()
        .run(&scenarios)
        .expect("figure8 sweep cannot fail on registry scenarios");
    let mut rows = Vec::new();
    let mut max_speedup: f64 = 0.0;
    for r in &results {
        let cf = &r.candidates[0].metrics;
        let speedup = r.baseline.iteration_seconds / cf.iteration_seconds;
        max_speedup = max_speedup.max(speedup);
        println!(
            "{:<14} {:>5}K {:>12.2} {:>12.2} {:>8.2}x",
            r.scenario.model.name,
            r.scenario.context_length / 1024,
            r.baseline.iteration_seconds,
            cf.iteration_seconds,
            speedup
        );
        rows.push(Json::obj(vec![
            ("model", Json::str(r.scenario.model.name.clone())),
            ("context", Json::num(r.scenario.context_length as f64)),
            ("megatron_seconds", Json::num(r.baseline.iteration_seconds)),
            ("chunkflow_seconds", Json::num(cf.iteration_seconds)),
            ("speedup", Json::num(speedup)),
        ]));
    }
    println!("paper: up to 4.53x; ours: up to {max_speedup:.2}x (same winner everywhere)");
    let j = Json::Arr(rows);
    dump("figure8", &j);
    j
}

/// Run everything (the `report all` subcommand).
pub fn run_all(quick: bool) {
    table1();
    table2();
    figure1(42);
    figure2();
    figure4();
    figure5();
    figure6();
    figure7();
    table3();
    table5();
    table6();
    figure8(if quick { 2 } else { 5 }, if quick { 128 } else { 256 }, 42);
    table4(quick);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distribution_tables_match_paper_exactly_at_bucket_edges() {
        let t1 = table1();
        for row in t1.as_arr().unwrap() {
            let paper = row.req_f64("paper").unwrap();
            let ours = row.req_f64("ours").unwrap();
            assert!((paper - ours).abs() < 1e-6);
        }
    }

    #[test]
    fn figure2_report_matches_paper() {
        let j = figure2();
        let ours = j.req_f64("ours_bubble").unwrap();
        assert!((ours - 0.5714).abs() < 0.002);
    }

    #[test]
    fn figure7_report_matches_paper() {
        let j = figure7();
        assert!((j.req_f64("ours_bubble").unwrap() - 0.60).abs() < 0.005);
    }

    #[test]
    fn table5_report_within_tolerance() {
        let j = table5();
        for row in j.as_arr().unwrap() {
            let paper = row.req_f64("paper_gib").unwrap();
            let ours = row.req_f64("ours_gib").unwrap();
            assert!((ours - paper).abs() / paper < 0.03);
        }
    }

    #[test]
    fn figure8_chunkflow_wins_everywhere() {
        let j = figure8(1, 64, 7);
        for row in j.as_arr().unwrap() {
            assert!(row.req_f64("speedup").unwrap() > 1.0);
        }
    }
}
