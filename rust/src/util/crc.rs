//! CRC-32 (IEEE 802.3 polynomial, the zlib/`cksum -o3` variant), implemented
//! in-tree because the crates.io registry is unavailable. Used for checkpoint
//! section integrity (`train::checkpoint` format v3) and sweep-journal
//! fingerprints (`sweep::journal`).

/// Table-driven CRC-32 with the reflected polynomial `0xEDB88320`.
///
/// Incremental: feed bytes with [`Crc32::update`], read the digest with
/// [`Crc32::finalize`]. One-shot callers can use [`crc32`].
pub struct Crc32 {
    state: u32,
}

/// 256-entry lookup table for the reflected IEEE polynomial, built at
/// compile time so the runtime cost is one table index per byte.
const TABLE: [u32; 256] = build_table();

const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut bit = 0;
        while bit < 8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ 0xEDB8_8320 } else { crc >> 1 };
            bit += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
}

impl Crc32 {
    pub fn new() -> Self {
        Crc32 { state: 0xFFFF_FFFF }
    }

    pub fn update(&mut self, bytes: &[u8]) {
        let mut s = self.state;
        for &b in bytes {
            s = TABLE[((s ^ b as u32) & 0xFF) as usize] ^ (s >> 8);
        }
        self.state = s;
    }

    pub fn finalize(&self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

impl Default for Crc32 {
    fn default() -> Self {
        Self::new()
    }
}

/// One-shot CRC-32 of a byte slice.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.finalize()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // The canonical check value for CRC-32/ISO-HDLC.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn incremental_matches_one_shot() {
        let data = b"chunkflow checkpoint section";
        let whole = crc32(data);
        let mut inc = Crc32::new();
        for chunk in data.chunks(5) {
            inc.update(chunk);
        }
        assert_eq!(inc.finalize(), whole);
    }

    #[test]
    fn detects_single_bit_flips() {
        let data: Vec<u8> = (0u16..512).map(|i| (i % 251) as u8).collect();
        let base = crc32(&data);
        for pos in [0usize, 1, 255, 511] {
            for bit in 0..8 {
                let mut corrupt = data.clone();
                corrupt[pos] ^= 1 << bit;
                assert_ne!(crc32(&corrupt), base, "flip at byte {pos} bit {bit} undetected");
            }
        }
    }
}
