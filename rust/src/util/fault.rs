//! Deterministic, seeded fault injection.
//!
//! A [`FaultPlan`] arms named *sites* — places in the runtime that have
//! volunteered to fail — at specific occurrence counts. Sites call
//! [`fire`] (or the [`maybe_panic`] / [`maybe_sleep_ms`] / [`maybe_abort`]
//! conveniences) every time execution passes them; the global registry
//! counts occurrences per site and reports a hit when the armed count is
//! reached. Firing decisions and any random choices made by the fault
//! (byte positions for a bit flip, truncation points) derive from the
//! plan's seed, so a fault run is exactly reproducible.
//!
//! **Zero-cost when disabled:** unless the crate is built with the
//! `fault-inject` feature, every function here is an `#[inline(always)]`
//! no-op (`fire` returns `None` unconditionally), so production builds
//! carry no locks, no counters, and no branches at the injection sites.
//!
//! Known sites (see the README "Fault tolerance" section for the table):
//!
//! | site                  | effect when fired                              |
//! |-----------------------|------------------------------------------------|
//! | `exec.stage_panic`    | panics the pipeline stage thread mid-op        |
//! | `exec.handoff_delay`  | sleeps before a stage handoff send             |
//! | `ckpt.truncate`       | truncates the checkpoint file after rename     |
//! | `ckpt.bitflip`        | flips one bit of the checkpoint after rename   |
//! | `fastpath.pool_panic` | panics inside a fast-path worker part          |
//! | `sweep.kill`          | aborts the process after a sweep journal write |

/// Pipeline stage-thread panic, evaluated once per agenda op.
pub const STAGE_PANIC: &str = "exec.stage_panic";
/// Delay before a stage handoff send; `param` is the delay in millis.
pub const HANDOFF_DELAY: &str = "exec.handoff_delay";
/// Truncate the checkpoint file post-rename; `param` is bytes to keep
/// (seeded choice when absent).
pub const CKPT_TRUNCATE: &str = "ckpt.truncate";
/// Flip one bit of the checkpoint file post-rename at a seeded position.
pub const CKPT_BITFLIP: &str = "ckpt.bitflip";
/// Panic inside a fast-path worker, evaluated once per part execution.
pub const POOL_PANIC: &str = "fastpath.pool_panic";
/// `std::process::abort()` after a sweep journal append (kill -9 stand-in).
pub const SWEEP_KILL: &str = "sweep.kill";

/// Env var holding a fault plan spec for CLI-driven injection, e.g.
/// `CHUNKFLOW_FAULT_PLAN="exec.stage_panic@2;ckpt.truncate@1:64"`.
pub const ENV_PLAN: &str = "CHUNKFLOW_FAULT_PLAN";
/// Env var overriding the plan seed (default [`DEFAULT_SEED`]).
pub const ENV_SEED: &str = "CHUNKFLOW_FAULT_SEED";
/// Seed used when none is given explicitly.
pub const DEFAULT_SEED: u64 = 0xC0FF_EE00;

/// One armed fault: fire at the `occurrence`-th (1-based) evaluation of
/// `site`, with an optional site-specific parameter.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FaultSpec {
    pub site: String,
    pub occurrence: u64,
    pub param: Option<u64>,
}

/// A deterministic set of armed faults plus the seed their random choices
/// derive from. Parsing and construction are always compiled (they are
/// cheap and keep CLI/plan handling testable); only the *registry* that
/// makes sites actually fire is feature-gated.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    pub seed: u64,
    pub specs: Vec<FaultSpec>,
}

impl FaultPlan {
    pub fn new(seed: u64) -> Self {
        FaultPlan { seed, specs: Vec::new() }
    }

    /// Arm `site` to fire at its `occurrence`-th (1-based) evaluation.
    pub fn arm(mut self, site: &str, occurrence: u64) -> Self {
        self.specs.push(FaultSpec { site: site.to_string(), occurrence, param: None });
        self
    }

    /// Like [`FaultPlan::arm`] with a site-specific parameter (delay
    /// millis, truncation length, ...).
    pub fn arm_with(mut self, site: &str, occurrence: u64, param: u64) -> Self {
        self.specs.push(FaultSpec { site: site.to_string(), occurrence, param: Some(param) });
        self
    }

    /// Does this plan arm `site` at exactly this `occurrence`?
    pub fn should_fire(&self, site: &str, occurrence: u64) -> Option<&FaultSpec> {
        self.specs.iter().find(|s| s.site == site && s.occurrence == occurrence)
    }

    /// Parse `"site@occurrence[:param];..."`, e.g.
    /// `"exec.stage_panic@2;exec.handoff_delay@1:250"`.
    pub fn parse(spec: &str, seed: u64) -> anyhow::Result<Self> {
        let mut plan = FaultPlan::new(seed);
        for part in spec.split(';').map(str::trim).filter(|p| !p.is_empty()) {
            let (site, rest) = part
                .split_once('@')
                .ok_or_else(|| anyhow::anyhow!("fault spec `{part}`: expected site@occurrence"))?;
            let (occ_str, param) = match rest.split_once(':') {
                Some((o, p)) => {
                    let p = p
                        .parse::<u64>()
                        .map_err(|_| anyhow::anyhow!("fault spec `{part}`: bad param `{p}`"))?;
                    (o, Some(p))
                }
                None => (rest, None),
            };
            let occurrence = occ_str
                .parse::<u64>()
                .map_err(|_| anyhow::anyhow!("fault spec `{part}`: bad occurrence `{occ_str}`"))?;
            anyhow::ensure!(occurrence >= 1, "fault spec `{part}`: occurrence is 1-based");
            anyhow::ensure!(!site.is_empty(), "fault spec `{part}`: empty site");
            plan.specs.push(FaultSpec { site: site.to_string(), occurrence, param });
        }
        Ok(plan)
    }
}

/// Details of a fault that just fired, handed to the injection site so it
/// can act deterministically.
#[derive(Clone, Copy, Debug)]
pub struct Fired {
    /// Which evaluation of the site this was (1-based).
    pub occurrence: u64,
    /// The spec's optional parameter.
    pub param: Option<u64>,
    /// Seed derived from (plan seed, site, occurrence) for any random
    /// choice the fault makes (e.g. which byte to flip).
    pub seed: u64,
}

#[cfg(feature = "fault-inject")]
mod active {
    use super::{FaultPlan, Fired};
    use std::collections::BTreeMap;
    use std::sync::Mutex;

    struct Registry {
        plan: FaultPlan,
        counts: BTreeMap<String, u64>,
    }

    static REGISTRY: Mutex<Option<Registry>> = Mutex::new(None);

    /// Install `plan` as the process-global fault plan, resetting all
    /// occurrence counters.
    pub fn install(plan: FaultPlan) {
        let mut reg = REGISTRY.lock().unwrap_or_else(|p| p.into_inner());
        *reg = Some(Registry { plan, counts: BTreeMap::new() });
    }

    /// Disarm all faults and reset counters.
    pub fn clear() {
        let mut reg = REGISTRY.lock().unwrap_or_else(|p| p.into_inner());
        *reg = None;
    }

    /// Install a plan from `CHUNKFLOW_FAULT_PLAN` / `CHUNKFLOW_FAULT_SEED`
    /// if set; no-op otherwise. Lets CI drive the `chunkflow` binary.
    pub fn install_from_env() -> anyhow::Result<()> {
        let Ok(spec) = std::env::var(super::ENV_PLAN) else { return Ok(()) };
        let seed = match std::env::var(super::ENV_SEED) {
            Ok(s) => s
                .parse::<u64>()
                .map_err(|_| anyhow::anyhow!("{}: bad seed `{s}`", super::ENV_SEED))?,
            Err(_) => super::DEFAULT_SEED,
        };
        let plan = FaultPlan::parse(&spec, seed)?;
        crate::info!("fault injection armed from {}: {:?}", super::ENV_PLAN, plan.specs);
        install(plan);
        Ok(())
    }

    /// Count one evaluation of `site`; returns `Some` when an armed
    /// occurrence is reached.
    pub fn fire(site: &str) -> Option<Fired> {
        let mut guard = REGISTRY.lock().unwrap_or_else(|p| p.into_inner());
        let reg = guard.as_mut()?;
        let count = reg.counts.entry(site.to_string()).or_insert(0);
        *count += 1;
        let occurrence = *count;
        let spec = reg.plan.should_fire(site, occurrence)?;
        let param = spec.param;
        // Mix (seed, site, occurrence) through SplitMix64 so every fired
        // fault gets an independent, reproducible random stream.
        let mixed = reg.plan.seed
            ^ ((crate::util::crc::crc32(site.as_bytes()) as u64) << 32)
            ^ occurrence;
        let seed = crate::util::rng::SplitMix64::new(mixed).next_u64();
        Some(Fired { occurrence, param, seed })
    }
}

#[cfg(not(feature = "fault-inject"))]
mod active {
    use super::{FaultPlan, Fired};

    #[inline(always)]
    pub fn install(_plan: FaultPlan) {}

    #[inline(always)]
    pub fn clear() {}

    pub fn install_from_env() -> anyhow::Result<()> {
        if std::env::var(super::ENV_PLAN).is_ok() {
            crate::warn_!(
                "{} is set but this build has no fault injection; \
                 rebuild with --features fault-inject",
                super::ENV_PLAN
            );
        }
        Ok(())
    }

    #[inline(always)]
    pub fn fire(_site: &str) -> Option<Fired> {
        None
    }
}

pub use active::{clear, fire, install, install_from_env};

/// Serializes unit tests — in any module of this crate — that install the
/// process-global registry. Integration tests get their own process each,
/// but unit tests share one binary and run on parallel threads.
#[cfg(all(test, feature = "fault-inject"))]
pub(crate) static TEST_REGISTRY_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

/// Is fault injection compiled into this build?
pub const fn enabled() -> bool {
    cfg!(feature = "fault-inject")
}

/// Panic with a recognizable message if `site` fires.
#[inline(always)]
pub fn maybe_panic(site: &str) {
    if let Some(f) = fire(site) {
        panic!("injected fault: {site} (occurrence {})", f.occurrence);
    }
}

/// Sleep `param` millis (or `default_ms`) if `site` fires.
#[inline(always)]
pub fn maybe_sleep_ms(site: &str, default_ms: u64) {
    if let Some(f) = fire(site) {
        let ms = f.param.unwrap_or(default_ms);
        crate::warn_!("injected fault: {site} sleeping {ms}ms (occurrence {})", f.occurrence);
        std::thread::sleep(std::time::Duration::from_millis(ms));
    }
}

/// Abort the process (no unwinding, no cleanup — a `kill -9` stand-in) if
/// `site` fires.
#[inline(always)]
pub fn maybe_abort(site: &str) {
    if let Some(f) = fire(site) {
        eprintln!("injected fault: {site} aborting process (occurrence {})", f.occurrence);
        std::process::abort();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_plan_specs() {
        let plan = FaultPlan::parse("exec.stage_panic@2; ckpt.truncate@1:64", 7).unwrap();
        assert_eq!(plan.seed, 7);
        assert_eq!(plan.specs.len(), 2);
        assert_eq!(plan.specs[0].site, "exec.stage_panic");
        assert_eq!(plan.specs[0].occurrence, 2);
        assert_eq!(plan.specs[0].param, None);
        assert_eq!(plan.specs[1].site, "ckpt.truncate");
        assert_eq!(plan.specs[1].occurrence, 1);
        assert_eq!(plan.specs[1].param, Some(64));
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        assert!(FaultPlan::parse("no-at-sign", 0).is_err());
        assert!(FaultPlan::parse("site@zero:5", 0).is_err());
        assert!(FaultPlan::parse("site@0", 0).is_err());
        assert!(FaultPlan::parse("@1", 0).is_err());
        assert!(FaultPlan::parse("site@1:notanum", 0).is_err());
        // Empty plans are fine (nothing armed).
        assert!(FaultPlan::parse("", 0).unwrap().specs.is_empty());
    }

    #[test]
    fn should_fire_matches_exact_occurrence() {
        let plan = FaultPlan::new(0).arm("a", 2).arm_with("b", 1, 9);
        assert!(plan.should_fire("a", 1).is_none());
        assert!(plan.should_fire("a", 2).is_some());
        assert!(plan.should_fire("a", 3).is_none());
        assert_eq!(plan.should_fire("b", 1).unwrap().param, Some(9));
        assert!(plan.should_fire("c", 1).is_none());
    }

    // Registry-backed tests live here (not in integration tests) so the
    // process-global state is exercised under the same lock.
    #[cfg(feature = "fault-inject")]
    mod registry {
        use super::super::*;

        // The registry is process-global; serialize tests that touch it.
        use super::super::TEST_REGISTRY_LOCK as LOCK;

        #[test]
        fn fires_on_nth_evaluation_only() {
            let _g = LOCK.lock().unwrap_or_else(|p| p.into_inner());
            install(FaultPlan::new(1).arm("t.site", 3));
            assert!(fire("t.site").is_none());
            assert!(fire("t.site").is_none());
            let f = fire("t.site").expect("third evaluation fires");
            assert_eq!(f.occurrence, 3);
            assert!(fire("t.site").is_none());
            clear();
        }

        #[test]
        fn cleared_registry_never_fires() {
            let _g = LOCK.lock().unwrap_or_else(|p| p.into_inner());
            clear();
            for _ in 0..4 {
                assert!(fire("t.other").is_none());
            }
        }

        #[test]
        fn fired_seed_is_deterministic() {
            let _g = LOCK.lock().unwrap_or_else(|p| p.into_inner());
            install(FaultPlan::new(42).arm("t.seeded", 1));
            let a = fire("t.seeded").unwrap();
            install(FaultPlan::new(42).arm("t.seeded", 1));
            let b = fire("t.seeded").unwrap();
            assert_eq!(a.seed, b.seed);
            // A different plan seed gives a different stream.
            install(FaultPlan::new(43).arm("t.seeded", 1));
            let c = fire("t.seeded").unwrap();
            assert_ne!(a.seed, c.seed);
            clear();
        }
    }
}
