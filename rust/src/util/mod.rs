//! Infrastructure substrates built in-tree because the crates.io registry is
//! unavailable in this environment: JSON, CLI parsing, PRNG + distributions,
//! property testing, micro-benchmarking, logging, and a thread pool.

pub mod bench;
pub mod cli;
pub mod crc;
pub mod fault;
pub mod json;
pub mod log;
pub mod pool;
pub mod prop;
pub mod rng;

/// Format a byte count with binary units, e.g. "45.6 GiB".
pub fn format_bytes(bytes: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut val = bytes as f64;
    let mut unit = 0;
    while val >= 1024.0 && unit < UNITS.len() - 1 {
        val /= 1024.0;
        unit += 1;
    }
    if unit == 0 {
        format!("{bytes} B")
    } else {
        format!("{val:.1} {}", UNITS[unit])
    }
}

/// Format a token count the way the paper writes lengths, e.g. "32K", "1M".
pub fn format_tokens(tokens: u64) -> String {
    if tokens >= 1024 * 1024 && tokens % (1024 * 1024) == 0 {
        format!("{}M", tokens / (1024 * 1024))
    } else if tokens >= 1024 && tokens % 1024 == 0 {
        format!("{}K", tokens / 1024)
    } else {
        format!("{tokens}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(format_bytes(512), "512 B");
        assert_eq!(format_bytes(2048), "2.0 KiB");
        assert_eq!(format_bytes(45_600_000_000), "42.5 GiB");
    }

    #[test]
    fn token_formatting() {
        assert_eq!(format_tokens(32 * 1024), "32K");
        assert_eq!(format_tokens(256 * 1024), "256K");
        assert_eq!(format_tokens(1024 * 1024), "1M");
        assert_eq!(format_tokens(1000), "1000");
    }
}
