//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` uses `harness = false` with `rust/benches/bench_main.rs` as
//! the entrypoint; that binary drives suites built on this module. The
//! harness does warmup, adaptive iteration-count calibration toward a target
//! measurement time, and reports mean / p50 / p95 / min plus throughput.

use std::time::{Duration, Instant};

/// One benchmark measurement summary.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
    /// Optional items-per-iteration for throughput reporting.
    pub items_per_iter: Option<f64>,
}

impl BenchResult {
    pub fn throughput(&self) -> Option<f64> {
        self.items_per_iter
            .map(|n| n / self.mean.as_secs_f64())
    }
}

/// Benchmark runner configuration.
#[derive(Clone, Debug)]
pub struct Bencher {
    pub warmup: Duration,
    pub measure: Duration,
    /// Max samples collected (each sample may batch several iterations).
    pub max_samples: usize,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(300),
            measure: Duration::from_secs(1),
            max_samples: 200,
            results: Vec::new(),
        }
    }
}

/// Prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

impl Bencher {
    pub fn new(warmup_ms: u64, measure_ms: u64) -> Self {
        Self {
            warmup: Duration::from_millis(warmup_ms),
            measure: Duration::from_millis(measure_ms),
            ..Default::default()
        }
    }

    /// Run a benchmark; `f` is one iteration.
    pub fn bench<F: FnMut()>(&mut self, name: &str, f: F) -> BenchResult {
        self.bench_items(name, None, f)
    }

    /// Run a benchmark where each iteration processes `items` units
    /// (tokens, chunks, events, …) for throughput reporting.
    pub fn bench_items<F: FnMut()>(
        &mut self,
        name: &str,
        items: Option<f64>,
        mut f: F,
    ) -> BenchResult {
        // Warmup and single-shot calibration.
        let cal_start = Instant::now();
        let mut warm_iters = 0u64;
        while cal_start.elapsed() < self.warmup {
            f();
            warm_iters += 1;
        }
        let per_iter = cal_start.elapsed().as_secs_f64() / warm_iters.max(1) as f64;

        // Choose a batch size so each sample is ≥ ~100µs (clock noise floor).
        let batch = ((100e-6 / per_iter).ceil() as u64).max(1);
        let target_samples = ((self.measure.as_secs_f64() / (per_iter * batch as f64)).ceil()
            as usize)
            .clamp(5, self.max_samples);

        let mut samples: Vec<Duration> = Vec::with_capacity(target_samples);
        let mut total_iters = 0u64;
        for _ in 0..target_samples {
            let t = Instant::now();
            for _ in 0..batch {
                f();
            }
            samples.push(t.elapsed() / batch as u32);
            total_iters += batch;
        }
        samples.sort();

        let mean_nanos =
            samples.iter().map(|d| d.as_nanos()).sum::<u128>() / samples.len() as u128;
        let result = BenchResult {
            name: name.to_string(),
            iters: total_iters,
            mean: Duration::from_nanos(mean_nanos as u64),
            p50: samples[samples.len() / 2],
            p95: samples[(samples.len() * 95 / 100).min(samples.len() - 1)],
            min: samples[0],
            items_per_iter: items,
        };
        self.report(&result);
        self.results.push(result.clone());
        result
    }

    fn report(&self, r: &BenchResult) {
        let tput = match r.throughput() {
            Some(t) if t >= 1e9 => format!("  {:8.2} Gitem/s", t / 1e9),
            Some(t) if t >= 1e6 => format!("  {:8.2} Mitem/s", t / 1e6),
            Some(t) if t >= 1e3 => format!("  {:8.2} Kitem/s", t / 1e3),
            Some(t) => format!("  {t:8.2} item/s"),
            None => String::new(),
        };
        println!(
            "{:<52} mean {:>12?}  p50 {:>12?}  p95 {:>12?}  min {:>12?}{}",
            r.name, r.mean, r.p50, r.p95, r.min, tput
        );
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Dump all results as JSON (used by `cargo bench` to leave a record
    /// under target/ for EXPERIMENTS.md).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::Arr(
            self.results
                .iter()
                .map(|r| {
                    Json::obj(vec![
                        ("name", Json::str(r.name.clone())),
                        ("mean_ns", Json::num(r.mean.as_nanos() as f64)),
                        ("p50_ns", Json::num(r.p50.as_nanos() as f64)),
                        ("p95_ns", Json::num(r.p95.as_nanos() as f64)),
                        ("min_ns", Json::num(r.min.as_nanos() as f64)),
                        (
                            "throughput_items_per_s",
                            r.throughput().map(Json::num).unwrap_or(Json::Null),
                        ),
                    ])
                })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let mut b = Bencher::new(10, 50);
        let r = b.bench("noop-ish", || {
            black_box((0..100u64).sum::<u64>());
        });
        assert!(r.iters > 0);
        assert!(r.min <= r.p50 && r.p50 <= r.p95);
        assert!(r.mean.as_nanos() > 0);
    }

    #[test]
    fn throughput_reported() {
        let mut b = Bencher::new(10, 30);
        let r = b.bench_items("items", Some(1000.0), || {
            black_box((0..1000u64).sum::<u64>());
        });
        assert!(r.throughput().unwrap() > 0.0);
    }

    #[test]
    fn json_dump_has_all_results() {
        let mut b = Bencher::new(5, 20);
        b.bench("a", || {
            black_box(1 + 1);
        });
        b.bench("b", || {
            black_box(2 + 2);
        });
        let j = b.to_json();
        assert_eq!(j.as_arr().unwrap().len(), 2);
    }
}
