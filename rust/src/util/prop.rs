//! A small property-based testing harness (proptest is unavailable offline).
//!
//! Provides seeded random-input generation, a configurable number of cases,
//! and greedy input shrinking on failure. Property tests across the crate
//! (`chunk`, `schedule`, `pipeline`, `memory`, …) are built on this.
//!
//! Usage:
//! ```ignore
//! check(200, gen_vec(gen_u64(1, 100_000), 0, 64), |lens| {
//!     let chunks = construct(lens, 8192)?;
//!     ensure(total(&chunks) == lens.iter().sum(), "tokens preserved")
//! });
//! ```

use crate::util::rng::Rng;

/// Outcome of a single property evaluation.
pub type PropResult = Result<(), String>;

/// Assert helper for property bodies.
pub fn ensure(cond: bool, msg: &str) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.to_string())
    }
}

/// A generator produces a value from the RNG and knows how to shrink it.
pub trait Gen {
    type Value: Clone + std::fmt::Debug;
    fn generate(&self, rng: &mut Rng) -> Self::Value;
    /// Candidate smaller versions of `v`, in decreasing aggressiveness.
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let _ = v;
        Vec::new()
    }
}

/// Run `cases` random cases of `prop` over inputs from `gen`. On failure,
/// greedily shrink the counterexample and panic with both the original and
/// the minimized input. Seed is fixed (env `CHUNKFLOW_PROP_SEED` overrides)
/// so CI is deterministic.
pub fn check<G: Gen>(cases: usize, gen: G, prop: impl Fn(&G::Value) -> PropResult) {
    let seed = std::env::var("CHUNKFLOW_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE_u64);
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen.generate(&mut rng);
        if let Err(msg) = prop(&input) {
            let minimized = shrink_loop(&gen, &prop, input.clone());
            panic!(
                "property failed (case {case}/{cases}, seed {seed}): {msg}\n\
                 original input: {input:?}\n\
                 minimized input: {minimized:?}"
            );
        }
    }
}

fn shrink_loop<G: Gen>(
    gen: &G,
    prop: &impl Fn(&G::Value) -> PropResult,
    mut current: G::Value,
) -> G::Value {
    // Bounded greedy shrink: accept the first failing candidate each round.
    for _ in 0..1000 {
        let mut advanced = false;
        for cand in gen.shrink(&current) {
            if prop(&cand).is_err() {
                current = cand;
                advanced = true;
                break;
            }
        }
        if !advanced {
            break;
        }
    }
    current
}

// ----- concrete generators --------------------------------------------------

/// Uniform u64 in [lo, hi].
pub struct GenU64 {
    pub lo: u64,
    pub hi: u64,
}

pub fn gen_u64(lo: u64, hi: u64) -> GenU64 {
    GenU64 { lo, hi }
}

impl Gen for GenU64 {
    type Value = u64;
    fn generate(&self, rng: &mut Rng) -> u64 {
        rng.gen_range_inclusive(self.lo, self.hi)
    }
    fn shrink(&self, v: &u64) -> Vec<u64> {
        let mut out = Vec::new();
        if *v > self.lo {
            out.push(self.lo);
            out.push(self.lo + (*v - self.lo) / 2);
            out.push(*v - 1);
        }
        out.dedup();
        out
    }
}

/// Uniform usize in [lo, hi].
pub struct GenUsize {
    pub lo: usize,
    pub hi: usize,
}

pub fn gen_usize(lo: usize, hi: usize) -> GenUsize {
    GenUsize { lo, hi }
}

impl Gen for GenUsize {
    type Value = usize;
    fn generate(&self, rng: &mut Rng) -> usize {
        rng.gen_range_inclusive(self.lo as u64, self.hi as u64) as usize
    }
    fn shrink(&self, v: &usize) -> Vec<usize> {
        GenU64 { lo: self.lo as u64, hi: self.hi as u64 }
            .shrink(&(*v as u64))
            .into_iter()
            .map(|x| x as usize)
            .collect()
    }
}

/// Vec of inner-generated values with length in [min_len, max_len].
pub struct GenVec<G> {
    pub inner: G,
    pub min_len: usize,
    pub max_len: usize,
}

pub fn gen_vec<G: Gen>(inner: G, min_len: usize, max_len: usize) -> GenVec<G> {
    GenVec { inner, min_len, max_len }
}

impl<G: Gen> Gen for GenVec<G> {
    type Value = Vec<G::Value>;
    fn generate(&self, rng: &mut Rng) -> Vec<G::Value> {
        let len = rng.gen_range_inclusive(self.min_len as u64, self.max_len as u64) as usize;
        (0..len).map(|_| self.inner.generate(rng)).collect()
    }
    fn shrink(&self, v: &Vec<G::Value>) -> Vec<Vec<G::Value>> {
        let mut out = Vec::new();
        // Remove halves, then single elements, then shrink elements.
        if v.len() > self.min_len {
            let half = (v.len() / 2).max(self.min_len);
            out.push(v[..half].to_vec());
            for i in 0..v.len().min(8) {
                let mut smaller = v.clone();
                smaller.remove(i);
                if smaller.len() >= self.min_len {
                    out.push(smaller);
                }
            }
        }
        for i in 0..v.len().min(8) {
            for cand in self.inner.shrink(&v[i]) {
                let mut next = v.clone();
                next[i] = cand;
                out.push(next);
            }
        }
        out
    }
}

/// Pair generator.
pub struct GenPair<A, B> {
    pub a: A,
    pub b: B,
}

pub fn gen_pair<A: Gen, B: Gen>(a: A, b: B) -> GenPair<A, B> {
    GenPair { a, b }
}

impl<A: Gen, B: Gen> Gen for GenPair<A, B> {
    type Value = (A::Value, B::Value);
    fn generate(&self, rng: &mut Rng) -> Self::Value {
        (self.a.generate(rng), self.b.generate(rng))
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> = self
            .a
            .shrink(&v.0)
            .into_iter()
            .map(|a| (a, v.1.clone()))
            .collect();
        out.extend(self.b.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
        out
    }
}

/// Mixture generator for long-tail-like inputs: with probability `p_long`
/// draw from the `long` generator, else from `short`. Mirrors the SFT
/// dataset shape and gives property tests realistic skew.
pub struct GenMix<G> {
    pub short: G,
    pub long: G,
    pub p_long: f64,
}

pub fn gen_mix<G: Gen>(short: G, long: G, p_long: f64) -> GenMix<G> {
    GenMix { short, long, p_long }
}

impl<G: Gen> Gen for GenMix<G> {
    type Value = G::Value;
    fn generate(&self, rng: &mut Rng) -> G::Value {
        if rng.gen_bool(self.p_long) {
            self.long.generate(rng)
        } else {
            self.short.generate(rng)
        }
    }
    fn shrink(&self, v: &G::Value) -> Vec<G::Value> {
        self.short.shrink(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0usize;
        let counter = std::cell::RefCell::new(&mut count);
        check(50, gen_u64(0, 10), |v| {
            **counter.borrow_mut() += 1;
            ensure(*v <= 10, "bound")
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        check(100, gen_u64(0, 1000), |v| ensure(*v < 500, "v < 500"));
    }

    #[test]
    fn shrinking_minimizes_scalar() {
        // Shrink v>=500 counterexample toward 500 via the shrink loop directly.
        let gen = gen_u64(0, 1000);
        let prop = |v: &u64| ensure(*v < 500, "v < 500");
        let minimized = shrink_loop(&gen, &prop, 999);
        assert_eq!(minimized, 500);
    }

    #[test]
    fn vec_generator_respects_length_bounds() {
        let mut rng = Rng::new(5);
        let gen = gen_vec(gen_u64(1, 9), 2, 6);
        for _ in 0..200 {
            let v = gen.generate(&mut rng);
            assert!((2..=6).contains(&v.len()));
            assert!(v.iter().all(|&x| (1..=9).contains(&x)));
        }
    }

    #[test]
    fn vec_shrink_keeps_min_len() {
        let gen = gen_vec(gen_u64(0, 10), 2, 8);
        let v = vec![5, 6, 7, 8];
        for cand in gen.shrink(&v) {
            assert!(cand.len() >= 2);
        }
    }

    #[test]
    fn mix_generator_draws_from_both() {
        let mut rng = Rng::new(3);
        let gen = gen_mix(gen_u64(0, 10), gen_u64(1000, 2000), 0.3);
        let vals: Vec<u64> = (0..500).map(|_| gen.generate(&mut rng)).collect();
        assert!(vals.iter().any(|&v| v <= 10));
        assert!(vals.iter().any(|&v| v >= 1000));
    }
}
