//! Minimal JSON parser and serializer.
//!
//! serde/serde_json are unavailable offline, and the system needs JSON in
//! three places: the AOT `artifacts/manifest.json` written by `aot.py`,
//! configuration files for the launcher, and machine-readable experiment
//! dumps under `target/report/`. This module implements the subset of JSON
//! we rely on (full spec minus `\u` surrogate-pair edge legality checks),
//! with precise error positions.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept in sorted order (BTreeMap) so that
/// serialization is deterministic — important for golden-file tests.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ----- accessors -------------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|f| {
            if f >= 0.0 && f.fract() == 0.0 && f <= u64::MAX as f64 {
                Some(f as u64)
            } else {
                None
            }
        })
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().and_then(|f| {
            if f.fract() == 0.0 && f >= i64::MIN as f64 && f <= i64::MAX as f64 {
                Some(f as i64)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; returns Json::Null for missing keys on non-objects.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Required-field helpers with contextual errors (used by config loading).
    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow::anyhow!("missing/invalid string field `{key}`"))
    }

    pub fn req_u64(&self, key: &str) -> anyhow::Result<u64> {
        self.get(key)
            .and_then(|v| v.as_u64())
            .ok_or_else(|| anyhow::anyhow!("missing/invalid integer field `{key}`"))
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        Ok(self.req_u64(key)? as usize)
    }

    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .and_then(|v| v.as_f64())
            .ok_or_else(|| anyhow::anyhow!("missing/invalid number field `{key}`"))
    }

    pub fn opt_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.as_u64()).unwrap_or(default)
    }

    pub fn opt_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn opt_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(|v| v.as_str()).unwrap_or(default)
    }

    pub fn opt_bool(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    // ----- constructors ----------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num<N: Into<f64>>(n: N) -> Json {
        Json::Num(n.into())
    }

    pub fn str<S: Into<String>>(s: S) -> Json {
        Json::Str(s.into())
    }

    // ----- parsing ---------------------------------------------------------

    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> anyhow::Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Ok(Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?)
    }

    // ----- serialization ---------------------------------------------------

    /// Compact serialization.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with 2-space indent.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    pub fn write_file(&self, path: &std::path::Path) -> anyhow::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.pretty())?;
        Ok(())
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.dump())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, lit: &str, value: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(value)
        } else {
            Err(self.err(&format!("expected `{lit}`")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(&format!("unexpected character '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume a full UTF-8 scalar.
                    let rest = &self.bytes[self.pos..];
                    let text = std::str::from_utf8(rest).map_err(|_| self.err("invalid utf-8"))?;
                    let c = text.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn parse_unicode_escape() {
        let v = Json::parse(r#""é""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é");
    }

    #[test]
    fn parse_errors_have_positions() {
        let e = Json::parse("[1, 2").unwrap_err();
        assert!(e.pos >= 5);
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"model":"qwen-7b","layers":28,"lr":0.0003,"flags":[true,false,null],"nested":{"k":"v"}}"#;
        let v = Json::parse(src).unwrap();
        let dumped = v.dump();
        assert_eq!(Json::parse(&dumped).unwrap(), v);
        let pretty = v.pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(8192.0).dump(), "8192");
        assert_eq!(Json::Num(0.5).dump(), "0.5");
    }

    #[test]
    fn escaping_roundtrip() {
        let s = "quote\" backslash\\ newline\n tab\t ctrl\u{1}";
        let v = Json::Str(s.to_string());
        assert_eq!(Json::parse(&v.dump()).unwrap().as_str().unwrap(), s);
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n": 3, "s": "x", "b": true, "f": 1.5}"#).unwrap();
        assert_eq!(v.req_u64("n").unwrap(), 3);
        assert_eq!(v.req_str("s").unwrap(), "x");
        assert_eq!(v.opt_bool("b", false), true);
        assert_eq!(v.req_f64("f").unwrap(), 1.5);
        assert!(v.req_u64("missing").is_err());
        assert!(v.req_u64("f").is_err(), "1.5 is not an integer");
        assert_eq!(v.opt_u64("missing", 7), 7);
    }

    #[test]
    fn deep_nesting() {
        let mut src = String::new();
        for _ in 0..100 {
            src.push('[');
        }
        src.push('1');
        for _ in 0..100 {
            src.push(']');
        }
        assert!(Json::parse(&src).is_ok());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
        assert_eq!(Json::Arr(vec![]).pretty(), "[]");
    }
}
