//! A small scoped thread pool (tokio is unavailable offline) and a buffer
//! arena for steady-state allocation reuse.
//!
//! The coordinator uses the pool for parallel experiment sweeps (grid search
//! runs thousands of independent pipeline simulations) and for overlapping
//! host work with PJRT execution in the trainer. The stage-parallel executor
//! uses [`BufferPool`] so per-op KV-prefix and gradient scratch buffers are
//! recycled instead of freshly allocated every op.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size worker pool with a shared MPMC queue (Mutex<Receiver> pattern).
pub struct ThreadPool {
    sender: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    /// Create a pool with `n` workers (n >= 1).
    pub fn new(n: usize) -> Self {
        assert!(n >= 1);
        let (sender, receiver) = mpsc::channel::<Job>();
        let receiver = Arc::new(Mutex::new(receiver));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&receiver);
                thread::Builder::new()
                    .name(format!("chunkflow-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // channel closed
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Self { sender: Some(sender), workers }
    }

    /// Pool sized to available parallelism.
    pub fn with_default_size() -> Self {
        let n = thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        Self::new(n)
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.sender
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("worker pool hung up");
    }

    /// Map `f` over `items` in parallel, preserving order of results.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (tx, rx) = mpsc::channel::<(usize, R)>();
        let n = items.len();
        for (i, item) in items.into_iter().enumerate() {
            let tx = tx.clone();
            let f = Arc::clone(&f);
            self.execute(move || {
                let r = f(item);
                let _ = tx.send((i, r));
            });
        }
        drop(tx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in rx {
            out[i] = Some(r);
        }
        out.into_iter().map(|r| r.expect("worker panicked")).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        // Close the queue, then join workers.
        self.sender.take();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Arena of reusable `Vec<f64>` buffers (single-owner, no locking: each
/// executor stage thread owns one).
///
/// `acquire(len)` hands out a zeroed buffer of exactly `len` elements,
/// reusing a retained allocation when one is available; `release` returns a
/// buffer to the arena. At most `max_retained` buffers are kept — releases
/// beyond that bound free the allocation, so the arena's footprint stays
/// bounded under churn. Checked-out buffers are plain owned `Vec`s, so two
/// concurrent checkouts can never alias.
pub struct BufferPool {
    free: Vec<Vec<f64>>,
    max_retained: usize,
    /// Highest number of simultaneously retained buffers ever observed.
    high_water: usize,
    acquires: u64,
    reuse_hits: u64,
}

impl BufferPool {
    pub fn new(max_retained: usize) -> Self {
        Self { free: Vec::new(), max_retained, high_water: 0, acquires: 0, reuse_hits: 0 }
    }

    /// Check out a zeroed buffer of exactly `len` elements.
    pub fn acquire(&mut self, len: usize) -> Vec<f64> {
        self.acquires += 1;
        match self.free.pop() {
            Some(mut buf) => {
                self.reuse_hits += 1;
                // Reset-on-acquire: callers always see zeroed contents,
                // whatever the previous checkout wrote.
                buf.clear();
                buf.resize(len, 0.0);
                buf
            }
            None => vec![0.0; len],
        }
    }

    /// Return a buffer to the arena (dropped if the arena is full).
    pub fn release(&mut self, mut buf: Vec<f64>) {
        if self.free.len() < self.max_retained {
            buf.clear();
            self.free.push(buf);
            self.high_water = self.high_water.max(self.free.len());
        }
    }

    /// Buffers currently retained and idle.
    pub fn retained(&self) -> usize {
        self.free.len()
    }

    /// Peak retained-buffer count (never exceeds `max_retained`).
    pub fn high_water(&self) -> usize {
        self.high_water
    }

    /// Total acquires, and how many were served from a retained buffer.
    pub fn stats(&self) -> (u64, u64) {
        (self.acquires, self.reuse_hits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = mpsc::channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        drop(tx);
        let done = rx.iter().count();
        assert_eq!(done, 100);
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(8);
        let input: Vec<u64> = (0..256).collect();
        let out = pool.map(input.clone(), |x| x * x);
        assert_eq!(out, input.iter().map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        pool.execute(|| {
            std::thread::sleep(std::time::Duration::from_millis(10));
        });
        drop(pool); // must not hang or panic
    }

    #[test]
    fn buffer_pool_no_aliasing_across_checkouts() {
        let mut arena = BufferPool::new(8);
        // Seed the arena with retained buffers, then check two out at once.
        arena.release(vec![0.0; 16]);
        arena.release(vec![0.0; 16]);
        let mut a = arena.acquire(16);
        let mut b = arena.acquire(16);
        assert_ne!(a.as_ptr(), b.as_ptr(), "concurrent checkouts must not alias");
        a[0] = 1.0;
        b[0] = 2.0;
        assert_eq!(a[0], 1.0);
        assert_eq!(b[0], 2.0);
        arena.release(a);
        arena.release(b);
    }

    #[test]
    fn buffer_pool_resets_on_reuse() {
        let mut arena = BufferPool::new(4);
        let mut buf = arena.acquire(32);
        for v in buf.iter_mut() {
            *v = 7.25;
        }
        arena.release(buf);
        // Same capacity class comes back zeroed, at the requested length.
        let again = arena.acquire(32);
        assert!(again.iter().all(|&v| v == 0.0), "reused buffer must be zeroed");
        assert_eq!(again.len(), 32);
        arena.release(again);
        // Length changes are honored too (grow and shrink).
        let grown = arena.acquire(64);
        assert_eq!(grown.len(), 64);
        assert!(grown.iter().all(|&v| v == 0.0));
        arena.release(grown);
        let shrunk = arena.acquire(8);
        assert_eq!(shrunk.len(), 8);
        assert!(shrunk.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn buffer_pool_high_water_bounded_under_churn() {
        let cap = 3usize;
        let mut arena = BufferPool::new(cap);
        for round in 0..50 {
            let n = 1 + round % 7;
            let bufs: Vec<Vec<f64>> = (0..n).map(|i| arena.acquire(16 * (i + 1))).collect();
            for b in bufs {
                arena.release(b);
            }
            assert!(arena.retained() <= cap, "retained {} > cap {cap}", arena.retained());
        }
        assert!(arena.high_water() <= cap, "high water {} > cap {cap}", arena.high_water());
        let (acquires, hits) = arena.stats();
        assert!(acquires > 0 && hits > 0, "churn must exercise reuse ({acquires}, {hits})");
    }
}
