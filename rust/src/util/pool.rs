//! A small scoped thread pool (tokio is unavailable offline).
//!
//! The coordinator uses it for parallel experiment sweeps (grid search runs
//! thousands of independent pipeline simulations) and for overlapping host
//! work with PJRT execution in the trainer.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size worker pool with a shared MPMC queue (Mutex<Receiver> pattern).
pub struct ThreadPool {
    sender: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    /// Create a pool with `n` workers (n >= 1).
    pub fn new(n: usize) -> Self {
        assert!(n >= 1);
        let (sender, receiver) = mpsc::channel::<Job>();
        let receiver = Arc::new(Mutex::new(receiver));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&receiver);
                thread::Builder::new()
                    .name(format!("chunkflow-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // channel closed
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Self { sender: Some(sender), workers }
    }

    /// Pool sized to available parallelism.
    pub fn with_default_size() -> Self {
        let n = thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        Self::new(n)
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.sender
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("worker pool hung up");
    }

    /// Map `f` over `items` in parallel, preserving order of results.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (tx, rx) = mpsc::channel::<(usize, R)>();
        let n = items.len();
        for (i, item) in items.into_iter().enumerate() {
            let tx = tx.clone();
            let f = Arc::clone(&f);
            self.execute(move || {
                let r = f(item);
                let _ = tx.send((i, r));
            });
        }
        drop(tx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in rx {
            out[i] = Some(r);
        }
        out.into_iter().map(|r| r.expect("worker panicked")).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        // Close the queue, then join workers.
        self.sender.take();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = mpsc::channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        drop(tx);
        let done = rx.iter().count();
        assert_eq!(done, 100);
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(8);
        let input: Vec<u64> = (0..256).collect();
        let out = pool.map(input.clone(), |x| x * x);
        assert_eq!(out, input.iter().map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        pool.execute(|| {
            std::thread::sleep(std::time::Duration::from_millis(10));
        });
        drop(pool); // must not hang or panic
    }
}
