//! Deterministic pseudo-random number generation and sampling distributions.
//!
//! The crates.io `rand` family is unavailable in this offline environment, so
//! this module provides the PRNG substrate the rest of the system needs:
//! a SplitMix64 seeder, a xoshiro256** generator, and the distributions used
//! by the synthetic long-tail dataset generator (uniform, normal via
//! Box-Muller, lognormal, categorical/weighted choice).
//!
//! Everything is deterministic given a seed, which the experiment harness
//! relies on: every table/figure regeneration uses a fixed seed so results
//! are reproducible run-to-run.

/// SplitMix64: used to expand a single `u64` seed into the 4-word xoshiro
/// state. Reference: Steele, Lea, Flood — "Fast Splittable Pseudorandom
/// Number Generators" (the standard seeding recommendation for xoshiro).
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — fast, high-quality 64-bit PRNG (Blackman & Vigna).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal variate from Box-Muller.
    cached_normal: Option<f64>,
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Self { s, cached_normal: None }
    }

    /// Derive an independent child generator (for per-worker streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xA5A5_5A5A_DEAD_BEEF)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in [0, n). Uses Lemire's multiply-shift rejection
    /// method to avoid modulo bias.
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0, "gen_range: n must be > 0");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn gen_range_inclusive(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.gen_range(hi - lo + 1)
    }

    /// Uniform f64 in [lo, hi).
    pub fn gen_f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Bernoulli trial with probability `p`.
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box-Muller (caches the second variate).
    pub fn next_normal(&mut self) -> f64 {
        if let Some(z) = self.cached_normal.take() {
            return z;
        }
        // Avoid log(0).
        let u1 = loop {
            let u = self.next_f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.cached_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with given mean and standard deviation.
    pub fn normal(&mut self, mean: f64, std_dev: f64) -> f64 {
        mean + std_dev * self.next_normal()
    }

    /// Lognormal: exp(N(mu, sigma)).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal(mu, sigma).exp()
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "categorical: weights must sum > 0");
        let mut x = self.next_f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            if x < w {
                return i;
            }
            x -= w;
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.gen_range((i + 1) as u64) as usize;
            items.swap(i, j);
        }
    }

    /// Pick a uniformly random element reference.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        assert!(!items.is_empty());
        &items[self.gen_range(items.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let x = r.gen_range(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets should be hit");
    }

    #[test]
    fn gen_range_inclusive_hits_endpoints() {
        let mut r = Rng::new(11);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..2_000 {
            match r.gen_range_inclusive(3, 6) {
                3 => lo_seen = true,
                6 => hi_seen = true,
                x => assert!((3..=6).contains(&x)),
            }
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let samples: Vec<f64> = (0..n).map(|_| r.next_normal()).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn lognormal_is_positive_and_skewed() {
        let mut r = Rng::new(17);
        let samples: Vec<f64> = (0..50_000).map(|_| r.lognormal(6.0, 1.5)).collect();
        assert!(samples.iter().all(|&x| x > 0.0));
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let mut sorted = samples.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let median = sorted[sorted.len() / 2];
        // Lognormal: mean > median (right-skew).
        assert!(mean > median);
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(19);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio {ratio}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
        assert_ne!(v, (0..100).collect::<Vec<u32>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut parent = Rng::new(31);
        let mut c1 = parent.fork();
        let mut c2 = parent.fork();
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 2);
    }
}
