//! Tiny command-line argument parser (clap is unavailable offline).
//!
//! Supports the launcher's needs: a subcommand word followed by
//! `--flag value`, `--flag=value`, boolean `--flag`, and positional args.
//! Unknown flags are an error so typos fail fast.

use std::collections::BTreeMap;

/// Parsed arguments: subcommand, flags, positionals.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    flags: BTreeMap<String, String>,
    bools: Vec<String>,
    pub positional: Vec<String>,
}

/// Declarative spec of accepted flags, for validation + help text.
#[derive(Clone, Debug)]
pub struct FlagSpec {
    pub name: &'static str,
    pub takes_value: bool,
    pub help: &'static str,
}

pub const fn flag(name: &'static str, takes_value: bool, help: &'static str) -> FlagSpec {
    FlagSpec { name, takes_value, help }
}

impl Args {
    /// Parse raw argv (without the program name) against a flag spec.
    /// The first non-flag token becomes the subcommand; later non-flag
    /// tokens are positional.
    pub fn parse(argv: &[String], spec: &[FlagSpec]) -> anyhow::Result<Args> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(stripped) = tok.strip_prefix("--") {
                let (name, inline_val) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let fs = spec
                    .iter()
                    .find(|f| f.name == name)
                    .ok_or_else(|| anyhow::anyhow!("unknown flag --{name}"))?;
                if fs.takes_value {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| anyhow::anyhow!("--{name} requires a value"))?
                        }
                    };
                    out.flags.insert(name, val);
                } else {
                    if inline_val.is_some() {
                        anyhow::bail!("--{name} does not take a value");
                    }
                    out.bools.push(name);
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(tok.clone());
            } else {
                out.positional.push(tok.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> anyhow::Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => parse_size(s)
                .ok_or_else(|| anyhow::anyhow!("--{name}: invalid integer `{s}`")),
        }
    }

    pub fn get_usize(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        Ok(self.get_u64(name, default as u64)? as usize)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name}: invalid number `{s}`")),
        }
    }

    pub fn get_bool(&self, name: &str) -> bool {
        self.bools.iter().any(|b| b == name)
    }
}

/// Parse integers with optional K/M/G suffix (binary-ish, 1K = 1024) —
/// sequence lengths like `32K`, `256K` read exactly as the paper writes them.
pub fn parse_size(s: &str) -> Option<u64> {
    let s = s.trim();
    let (digits, mult) = match s.chars().last()? {
        'k' | 'K' => (&s[..s.len() - 1], 1024),
        'm' | 'M' => (&s[..s.len() - 1], 1024 * 1024),
        'g' | 'G' => (&s[..s.len() - 1], 1024 * 1024 * 1024),
        _ => (s, 1),
    };
    digits.trim().parse::<u64>().ok().map(|v| v * mult)
}

/// Render help text from a flag spec.
pub fn render_help(prog: &str, subcommands: &[(&str, &str)], spec: &[FlagSpec]) -> String {
    let mut out = format!("usage: {prog} <subcommand> [flags]\n\nsubcommands:\n");
    for (name, help) in subcommands {
        out.push_str(&format!("  {name:<14} {help}\n"));
    }
    out.push_str("\nflags:\n");
    for f in spec {
        let val = if f.takes_value { " <value>" } else { "" };
        out.push_str(&format!("  --{}{val:<10} {}\n", f.name, f.help));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> Vec<FlagSpec> {
        vec![
            flag("chunk-size", true, "chunk size in tokens"),
            flag("k", true, "retained chunks"),
            flag("verbose", false, "verbose output"),
            flag("model", true, "model name"),
        ]
    }

    fn parse(toks: &[&str]) -> anyhow::Result<Args> {
        let argv: Vec<String> = toks.iter().map(|s| s.to_string()).collect();
        Args::parse(&argv, &spec())
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse(&["train", "--chunk-size", "8192", "--verbose"]).unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get_u64("chunk-size", 0).unwrap(), 8192);
        assert!(a.get_bool("verbose"));
        assert!(!a.get_bool("missing-doesnt-panic"));
    }

    #[test]
    fn equals_form() {
        let a = parse(&["sim", "--k=4", "--model=qwen-7b"]).unwrap();
        assert_eq!(a.get_u64("k", 1).unwrap(), 4);
        assert_eq!(a.get("model"), Some("qwen-7b"));
    }

    #[test]
    fn size_suffixes() {
        assert_eq!(parse_size("32K"), Some(32 * 1024));
        assert_eq!(parse_size("256k"), Some(256 * 1024));
        assert_eq!(parse_size("2M"), Some(2 * 1024 * 1024));
        assert_eq!(parse_size("17"), Some(17));
        assert_eq!(parse_size("x"), None);
        let a = parse(&["train", "--chunk-size", "8K"]).unwrap();
        assert_eq!(a.get_u64("chunk-size", 0).unwrap(), 8192);
    }

    #[test]
    fn unknown_flag_rejected() {
        assert!(parse(&["train", "--nope"]).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(parse(&["train", "--chunk-size"]).is_err());
    }

    #[test]
    fn bool_with_value_rejected() {
        assert!(parse(&["train", "--verbose=yes"]).is_err());
    }

    #[test]
    fn positionals_collected() {
        let a = parse(&["report", "table5", "figure8"]).unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("report"));
        assert_eq!(a.positional, vec!["table5", "figure8"]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["train"]).unwrap();
        assert_eq!(a.get_u64("k", 1).unwrap(), 1);
        assert_eq!(a.get_or("model", "tiny"), "tiny");
        assert_eq!(a.get_f64("chunk-size", 2.5).unwrap(), 2.5);
    }

    #[test]
    fn help_renders() {
        let h = render_help("chunkflow", &[("train", "run training")], &spec());
        assert!(h.contains("chunk-size"));
        assert!(h.contains("train"));
    }
}
