//! Minimal leveled logger with wall-clock-relative timestamps.
//!
//! The coordinator logs scheduling decisions, per-step losses and metrics;
//! `CHUNKFLOW_LOG=debug|info|warn|error` controls verbosity (default info).

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);
static START: OnceLock<Instant> = OnceLock::new();

/// Initialize from the CHUNKFLOW_LOG env var; idempotent.
pub fn init() {
    START.get_or_init(Instant::now);
    if let Ok(v) = std::env::var("CHUNKFLOW_LOG") {
        let lvl = match v.to_ascii_lowercase().as_str() {
            "debug" => Level::Debug,
            "info" => Level::Info,
            "warn" => Level::Warn,
            "error" => Level::Error,
            _ => Level::Info,
        };
        LEVEL.store(lvl as u8, Ordering::Relaxed);
    }
}

pub fn set_level(lvl: Level) {
    START.get_or_init(Instant::now);
    LEVEL.store(lvl as u8, Ordering::Relaxed);
}

pub fn enabled(lvl: Level) -> bool {
    lvl as u8 >= LEVEL.load(Ordering::Relaxed)
}

pub fn log(lvl: Level, args: std::fmt::Arguments<'_>) {
    if !enabled(lvl) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed();
    let tag = match lvl {
        Level::Debug => "DBG",
        Level::Info => "INF",
        Level::Warn => "WRN",
        Level::Error => "ERR",
    };
    eprintln!("[{:>9.3}s {tag}] {args}", t.as_secs_f64());
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Debug, format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! warn_ {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Error, format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_filtering() {
        set_level(Level::Warn);
        assert!(!enabled(Level::Debug));
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Warn));
        assert!(enabled(Level::Error));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
    }

    #[test]
    fn macros_compile_and_run() {
        set_level(Level::Error);
        crate::debug!("hidden {}", 1);
        crate::info!("hidden {}", 2);
        crate::error!("visible {}", 3);
    }
}
