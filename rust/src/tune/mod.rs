//! (ChunkSize, K) grid search — the paper's §5 tuning procedure.
//!
//! For a training configuration, sweep the 2D grid of candidate ChunkSizes
//! and retention budgets K, simulate the average iteration time over a few
//! sampled batches, reject memory-infeasible points via the memory model,
//! and return the ranked feasible grid (Table 4 / Table 6 generators).
//!
//! The sweep is memoized: batches are sampled once per search (not once per
//! grid point), Algorithm 1 runs once per (batch, ChunkSize) work unit, and
//! each resulting [`ChunkSet`](crate::chunk::ChunkSet) — plus, under
//! dp > 1, its K-invariant rank sharding ([`dp_rank_sets`]) — is shared
//! across all K candidates via [`simulate_chunkset_sharded`]; chunk
//! construction and DP assignment are independent of K. On the standard
//! grid (5 ChunkSizes × 6 Ks) this cuts Algorithm-1 invocations 6×.
//! Results are bit-identical to evaluating each point in isolation with
//! [`GridSearch::evaluate`]; a test asserts it.

use std::sync::Arc;

use crate::chunk::construct_chunks;
use crate::config::ModelSpec;
use crate::config::ParallelConfig;
use crate::data::{BatchSampler, LengthDistribution, Sequence};
use crate::memory::{MemoryModel, GPU_CAPACITY};
use crate::sim::{
    dp_rank_sets, search_elastic, simulate_chunkflow_iteration, simulate_chunkset_sharded,
    CostModel, ElasticChoice, IterationResult,
};
use crate::sweep::SweepEngine;

/// One evaluated grid point.
#[derive(Clone, Debug)]
pub struct GridPoint {
    pub chunk_size: u64,
    pub k: u64,
    pub avg_iteration_seconds: f64,
    pub bubble_ratio: f64,
    pub peak_memory_bytes: u64,
    pub feasible: bool,
}

/// Grid-search configuration.
#[derive(Clone, Debug)]
pub struct GridSearch {
    pub model: ModelSpec,
    pub parallel: ParallelConfig,
    pub context_length: u64,
    pub global_batch_size: usize,
    /// Batches averaged per grid point.
    pub iters: usize,
    pub seed: u64,
    pub chunk_sizes: Vec<u64>,
    pub ks: Vec<u64>,
}

impl GridSearch {
    pub fn standard(
        model: ModelSpec,
        parallel: ParallelConfig,
        context_length: u64,
    ) -> Self {
        let k = 1024;
        Self {
            model,
            parallel,
            context_length,
            global_batch_size: 256,
            iters: 3,
            seed: 20250710,
            chunk_sizes: vec![2 * k, 4 * k, 8 * k, 16 * k, 32 * k],
            ks: vec![1, 2, 4, 6, 8, 16],
        }
    }

    /// Evaluate every grid point (in parallel, on the default sweep engine)
    /// and return them sorted by iteration time, infeasible points last.
    pub fn run(&self) -> Vec<GridPoint> {
        self.run_on(&SweepEngine::auto())
    }

    /// Evaluate the grid on a specific [`SweepEngine`] (serial engines give
    /// bit-identical results to parallel ones; see `sweep::engine`).
    ///
    /// Work units are (batch, ChunkSize) pairs — finer than a grid point in
    /// the batch dimension, coarser in K: each unit runs Algorithm 1 once
    /// and simulates every K on the shared chunk set.
    pub fn run_on(&self, engine: &SweepEngine) -> Vec<GridPoint> {
        // Sample the batches once. Every per-point sampler used to be seeded
        // identically, so all grid points saw the same batch stream anyway.
        let mut sampler = BatchSampler::new(
            LengthDistribution::evaluation_dataset(),
            self.context_length,
            self.global_batch_size,
            self.seed,
        );
        let batches: Arc<Vec<Vec<Sequence>>> =
            Arc::new((0..self.iters).map(|_| sampler.next_batch()).collect());
        let cost = Arc::new(CostModel::new(self.model.clone(), self.parallel.clone()));
        let ks = Arc::new(self.ks.clone());

        let mut units: Vec<(usize, u64)> =
            Vec::with_capacity(self.chunk_sizes.len() * self.iters);
        for &cs in &self.chunk_sizes {
            for b in 0..self.iters {
                units.push((b, cs));
            }
        }
        let per_unit: Vec<Vec<IterationResult>> = engine.map(units, move |(b, chunk_size)| {
            let set = construct_chunks(&batches[b], chunk_size);
            // The dp rank sharding is K-invariant: compute it once per
            // (batch, ChunkSize) unit and share it across the K values,
            // like the chunk set itself (empty for dp = 1).
            let shards = dp_rank_sets(&set, &cost);
            ks.iter()
                .map(|&k| {
                    simulate_chunkset_sharded(&set, &shards, &cost, k as usize)
                        .expect("simulation cannot fail on valid chunk sets")
                })
                .collect()
        });

        // Reduce per grid point, accumulating over batches in sample order
        // so the averages are bit-identical to the per-point path.
        let mm = MemoryModel::new(self.model.clone(), self.parallel.clone());
        let mut results: Vec<GridPoint> =
            Vec::with_capacity(self.chunk_sizes.len() * self.ks.len());
        for (ci, &chunk_size) in self.chunk_sizes.iter().enumerate() {
            for (ki, &k) in self.ks.iter().enumerate() {
                let peak = mm.chunkflow_peak_sp(chunk_size, k, self.context_length);
                let (mut total, mut bubbles) = (0.0, 0.0);
                for b in 0..self.iters {
                    let r = &per_unit[ci * self.iters + b][ki];
                    total += r.iteration_seconds;
                    bubbles += r.bubble_ratio;
                }
                results.push(GridPoint {
                    chunk_size,
                    k,
                    avg_iteration_seconds: total / self.iters as f64,
                    bubble_ratio: bubbles / self.iters as f64,
                    peak_memory_bytes: peak,
                    feasible: peak <= GPU_CAPACITY,
                });
            }
        }
        rank_points(&mut results);
        results
    }

    /// Statically verify every (ChunkSize, K) candidate plan of this grid
    /// under every registered schedule policy — the `tune --joint`
    /// pre-flight. Runs on the search's first sampled batch (the same
    /// stream every grid point averages over); failures name the violated
    /// rule id and offending op (see [`crate::verify`]).
    pub fn preflight(&self) -> anyhow::Result<()> {
        let mut sampler = BatchSampler::new(
            LengthDistribution::evaluation_dataset(),
            self.context_length,
            self.global_batch_size,
            self.seed,
        );
        let batch = sampler.next_batch();
        let mm = MemoryModel::new(self.model.clone(), self.parallel.clone());
        let stages = self.parallel.pp.max(1) as usize;
        for &cs in &self.chunk_sizes {
            let set = construct_chunks(&batch, cs);
            for &k in &self.ks {
                for policy in crate::pipeline::PolicyKind::ALL {
                    crate::verify::preflight(
                        &format!(
                            "tune pre-flight (cs={} k={k} policy={})",
                            crate::util::format_tokens(cs),
                            policy.name()
                        ),
                        &set,
                        self.parallel.sp,
                        policy,
                        k as usize,
                        stages,
                        &mm,
                        self.context_length,
                    )?;
                }
            }
        }
        Ok(())
    }

    /// Evaluate a single (ChunkSize, K) point in isolation.
    ///
    /// This is the un-memoized reference path: it re-samples the batch
    /// stream and re-runs Algorithm 1 itself. [`GridSearch::run_on`] must
    /// produce bit-identical numbers for every grid point (asserted by
    /// `memoized_grid_matches_per_point_evaluate`); benchmarks loop this to
    /// measure the memoization win.
    pub fn evaluate(&self, chunk_size: u64, k: u64) -> GridPoint {
        let mm = MemoryModel::new(self.model.clone(), self.parallel.clone());
        let peak = mm.chunkflow_peak_sp(chunk_size, k, self.context_length);
        let feasible = peak <= GPU_CAPACITY;
        let cost = CostModel::new(self.model.clone(), self.parallel.clone());
        let mut sampler = BatchSampler::new(
            LengthDistribution::evaluation_dataset(),
            self.context_length,
            self.global_batch_size,
            self.seed,
        );
        let mut total = 0.0;
        let mut bubbles = 0.0;
        for _ in 0..self.iters {
            let batch = sampler.next_batch();
            let r = simulate_chunkflow_iteration(&batch, &cost, chunk_size, k as usize)
                .expect("simulation cannot fail on valid chunk sets");
            total += r.iteration_seconds;
            bubbles += r.bubble_ratio;
        }
        GridPoint {
            chunk_size,
            k,
            avg_iteration_seconds: total / self.iters as f64,
            bubble_ratio: bubbles / self.iters as f64,
            peak_memory_bytes: peak,
            feasible,
        }
    }

    /// Best feasible point.
    pub fn best(&self) -> Option<GridPoint> {
        self.run().into_iter().find(|p| p.feasible)
    }

    /// Elastic partition/policy search for this configuration at a chosen
    /// grid point — None when pp <= 1 or the equal partition under the
    /// default policy is already optimal. Evaluated on the search's first
    /// sampled batch (the same stream every grid point averaged over).
    pub fn elastic_at(&self, point: &GridPoint) -> Option<ElasticChoice> {
        if self.parallel.pp <= 1 {
            return None;
        }
        let mut sampler = BatchSampler::new(
            LengthDistribution::evaluation_dataset(),
            self.context_length,
            self.global_batch_size,
            self.seed,
        );
        let batch = sampler.next_batch();
        let cost = CostModel::new(self.model.clone(), self.parallel.clone());
        let set = construct_chunks(&batch, point.chunk_size);
        search_elastic(&cost, &set, point.k as usize)
            .expect("elastic search cannot fail on valid chunk sets")
    }

    /// Sweep the joint (ChunkSize, K, dp, pp, sp) space: run the full
    /// (ChunkSize, K) grid once per parallel-strategy candidate and return
    /// each strategy's best feasible point, ranked by iteration time.
    ///
    /// Strategies whose entire grid is memory-infeasible are dropped — they
    /// have no point worth reporting. The per-strategy grids reuse the
    /// memoized [`GridSearch::run_on`] path, so every returned point is
    /// bit-identical to evaluating it in isolation under that strategy.
    pub fn run_joint(
        &self,
        dps: &[u64],
        pps: &[u64],
        sps: &[u64],
        engine: &SweepEngine,
    ) -> Vec<JointPoint> {
        let mut out = Vec::new();
        for &dp in dps {
            for &pp in pps {
                for &sp in sps {
                    let mut g = self.clone();
                    g.parallel.dp = dp.max(1);
                    g.parallel.pp = pp.max(1);
                    g.parallel.sp = sp.max(1);
                    if let Some(point) =
                        g.run_on(engine).into_iter().find(|p| p.feasible)
                    {
                        // Co-optimize the pipeline axes at the strategy's
                        // best (ChunkSize, K): uneven partition + schedule
                        // policy, kept out of the ranking (the elastic win
                        // refines a strategy, it does not reorder them).
                        let elastic = g.elastic_at(&point);
                        out.push(JointPoint {
                            parallel: g.parallel.clone(),
                            point,
                            elastic,
                        });
                    }
                }
            }
        }
        // NaN-safe ranking (see `run_on`): a strategy with a NaN time sorts
        // last instead of panicking the whole joint sweep.
        out.sort_by(|a, b| {
            a.point
                .avg_iteration_seconds
                .total_cmp(&b.point.avg_iteration_seconds)
        });
        out
    }
}

/// NaN-safe grid ranking: feasible points first, then ascending iteration
/// time. `total_cmp` instead of `partial_cmp(..).unwrap()`: a NaN time
/// (degenerate cost-model input) must not panic mid-rank — it sorts after
/// every finite time within its feasibility class.
fn rank_points(points: &mut [GridPoint]) {
    points.sort_by(|a, b| {
        (!a.feasible)
            .cmp(&!b.feasible)
            .then(a.avg_iteration_seconds.total_cmp(&b.avg_iteration_seconds))
    });
}

/// One parallel-strategy candidate from [`GridSearch::run_joint`]: the
/// (dp, pp, sp) combination plus the best feasible (ChunkSize, K) point its
/// grid produced.
#[derive(Clone, Debug)]
pub struct JointPoint {
    pub parallel: ParallelConfig,
    pub point: GridPoint,
    /// Elastic pipeline refinement for pp > 1 strategies: the uneven
    /// partition + schedule policy that strictly beats the equal-partition
    /// default on this strategy's best point, when one exists. Never
    /// affects the ranking (strategies stay ordered by iteration time).
    pub elastic: Option<ElasticChoice>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RecomputeGranularity;

    fn search() -> GridSearch {
        let mut g = GridSearch::standard(
            ModelSpec::preset("qwen2.5-7b").unwrap(),
            ParallelConfig::new(4, 4, RecomputeGranularity::Selective),
            256 * 1024,
        );
        // Keep the test fast.
        g.global_batch_size = 64;
        g.iters = 1;
        g.chunk_sizes = vec![2048, 8192, 32 * 1024];
        g.ks = vec![1, 4, 16];
        g
    }

    #[test]
    fn grid_evaluates_all_points_sorted() {
        let g = search();
        let pts = g.run();
        assert_eq!(pts.len(), 9);
        // Feasible points sorted ascending by time.
        let feas: Vec<&GridPoint> = pts.iter().filter(|p| p.feasible).collect();
        for w in feas.windows(2) {
            assert!(w[0].avg_iteration_seconds <= w[1].avg_iteration_seconds);
        }
        assert!(!feas.is_empty(), "some point must be feasible");
    }

    #[test]
    fn infeasible_points_flagged_by_memory() {
        let g = search();
        // Huge ChunkSize x K blows the activation budget.
        let p = g.evaluate(32 * 1024, 16);
        assert!(!p.feasible, "32K x K=16 must exceed 80 GiB");
        let q = g.evaluate(2048, 1);
        assert!(q.feasible);
    }

    #[test]
    fn serial_and_parallel_grids_are_identical() {
        let g = search();
        let serial = g.run_on(&SweepEngine::serial());
        let parallel = g.run_on(&SweepEngine::with_threads(4));
        assert_eq!(serial.len(), parallel.len());
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.chunk_size, b.chunk_size);
            assert_eq!(a.k, b.k);
            assert_eq!(a.avg_iteration_seconds, b.avg_iteration_seconds);
            assert_eq!(a.peak_memory_bytes, b.peak_memory_bytes);
        }
    }

    #[test]
    fn memoized_grid_matches_per_point_evaluate() {
        // The memoization contract: sampling batches once and sharing each
        // (batch, ChunkSize) chunk set across every K must be *bit-identical*
        // to evaluating each grid point in isolation.
        let g = GridSearch { iters: 2, ..search() };
        let pts = g.run_on(&SweepEngine::serial());
        assert_eq!(pts.len(), g.chunk_sizes.len() * g.ks.len());
        for p in &pts {
            let q = g.evaluate(p.chunk_size, p.k);
            assert_eq!(
                p.avg_iteration_seconds, q.avg_iteration_seconds,
                "({}, {}) seconds drifted",
                p.chunk_size, p.k
            );
            assert_eq!(p.bubble_ratio, q.bubble_ratio);
            assert_eq!(p.peak_memory_bytes, q.peak_memory_bytes);
            assert_eq!(p.feasible, q.feasible);
        }
    }

    #[test]
    fn dp_grid_keeps_memoization_bit_identical_and_speeds_up() {
        // The tuner is DP-aware through `simulate_chunkset_sharded`: a dp > 1 grid
        // must (a) still satisfy the memoization contract (memoized ==
        // per-point bit-for-bit — the dp assignment is a pure function of
        // the chunk set, shared across K), and (b) predict faster
        // iterations than the same grid at dp = 1.
        let mut g = search();
        g.parallel.dp = 2;
        let pts = g.run_on(&SweepEngine::serial());
        for p in &pts {
            let q = g.evaluate(p.chunk_size, p.k);
            assert_eq!(
                p.avg_iteration_seconds, q.avg_iteration_seconds,
                "dp=2 ({}, {}) drifted",
                p.chunk_size, p.k
            );
        }
        let g1 = search();
        for p in &pts {
            let q1 = g1.evaluate(p.chunk_size, p.k);
            assert!(
                p.avg_iteration_seconds < q1.avg_iteration_seconds,
                "dp=2 ({}, {}) {} not faster than dp=1 {}",
                p.chunk_size,
                p.k,
                p.avg_iteration_seconds,
                q1.avg_iteration_seconds
            );
        }
    }

    #[test]
    fn sp_grid_keeps_memoization_bit_identical_and_speeds_up() {
        // The tuner is SP-aware through `CostModel::sp_stage_costs` (long
        // dependent chunks shard across the ring) and
        // `MemoryModel::chunkflow_peak_sp` (activation rows and held KV
        // shard by sp). An sp > 1 grid must (a) still satisfy the
        // memoization contract, and (b) predict faster iterations than the
        // same grid at sp = 1 wherever long chunks dominate.
        let mut g = search();
        g.parallel.sp = 4;
        let pts = g.run_on(&SweepEngine::serial());
        for p in &pts {
            let q = g.evaluate(p.chunk_size, p.k);
            assert_eq!(
                p.avg_iteration_seconds, q.avg_iteration_seconds,
                "sp=4 ({}, {}) drifted",
                p.chunk_size, p.k
            );
            assert_eq!(p.peak_memory_bytes, q.peak_memory_bytes);
            assert_eq!(p.feasible, q.feasible);
        }
        // At 256K context every sequence longer than ChunkSize yields
        // dependent chunks, so sharding them must win on every point.
        let g1 = search();
        for p in &pts {
            let q1 = g1.evaluate(p.chunk_size, p.k);
            assert!(
                p.avg_iteration_seconds < q1.avg_iteration_seconds,
                "sp=4 ({}, {}) {} not faster than sp=1 {}",
                p.chunk_size,
                p.k,
                p.avg_iteration_seconds,
                q1.avg_iteration_seconds
            );
            // Sharding also lowers the modeled peak: more points fit.
            assert!(p.peak_memory_bytes <= q1.peak_memory_bytes);
        }
    }

    #[test]
    fn sp1_grid_is_bit_identical_to_pre_sp_path() {
        // sp = 1 must not perturb the tuner at all: chunkflow_peak_sp
        // delegates to chunkflow_peak and sp_stage_costs to stage_costs.
        let g = search();
        assert_eq!(g.parallel.sp, 1);
        let pts = g.run_on(&SweepEngine::serial());
        let mm = MemoryModel::new(g.model.clone(), g.parallel.clone());
        for p in &pts {
            assert_eq!(
                p.peak_memory_bytes,
                mm.chunkflow_peak(p.chunk_size, p.k, g.context_length)
            );
        }
    }

    #[test]
    fn joint_search_ranks_strategies_and_prefers_sp_for_long_context() {
        let g = search();
        let ranked = g.run_joint(&[1], &[4], &[1, 4], &SweepEngine::serial());
        assert_eq!(ranked.len(), 2, "both strategies have feasible points");
        for w in ranked.windows(2) {
            assert!(
                w[0].point.avg_iteration_seconds <= w[1].point.avg_iteration_seconds
            );
        }
        assert_eq!(
            ranked[0].parallel.sp, 4,
            "at 256K context the sp=4 strategy must rank first"
        );
        // Each strategy's point matches an isolated evaluation under it.
        for jp in &ranked {
            let mut gj = g.clone();
            gj.parallel = jp.parallel.clone();
            let q = gj.evaluate(jp.point.chunk_size, jp.point.k);
            assert_eq!(jp.point.avg_iteration_seconds, q.avg_iteration_seconds);
        }
    }

    #[test]
    fn joint_search_attaches_elastic_refinement_on_pp_strategies() {
        let g = search();
        let ranked = g.run_joint(&[1], &[1, 4], &[1], &SweepEngine::serial());
        // pp = 1 strategies (when feasible at all) never carry a block.
        for jp in &ranked {
            if jp.parallel.pp <= 1 {
                assert!(jp.elastic.is_none(), "pp=1 strategy carries elastic block");
            }
        }
        let deep = ranked
            .iter()
            .find(|jp| jp.parallel.pp == 4)
            .expect("the <4,4> strategy has feasible points");
        // qwen2.5-7b's untied LM head costs ~2 layer-equivalents, so the
        // equal 7,7,7,7 split leaves the last stage on the critical path
        // and the search must find a strictly better uneven partition.
        let e = deep.elastic.as_ref().expect("elastic win at <4,4>");
        assert!(e.is_win());
        assert_eq!(e.pp, 4);
        assert_eq!(e.partition.iter().sum::<usize>(), 28, "{e:?}");
        assert!(e.partition.iter().all(|&c| c >= 1), "{e:?}");
        assert!(
            *e.partition.last().unwrap() < 7,
            "the head-bearing last stage must shed layers: {e:?}"
        );
    }

    #[test]
    fn nan_iteration_time_ranks_last_without_panicking() {
        // Regression: ranking used `partial_cmp(..).unwrap()`, which panics
        // the moment a degenerate cost model yields a NaN time. `total_cmp`
        // must instead sort the NaN point last within its feasibility class.
        let point = |secs: f64, feasible: bool| GridPoint {
            chunk_size: 8192,
            k: 4,
            avg_iteration_seconds: secs,
            bubble_ratio: 0.1,
            peak_memory_bytes: 1,
            feasible,
        };
        let mut pts = vec![
            point(f64::NAN, true),
            point(2.0, true),
            point(f64::NAN, false),
            point(1.0, true),
            point(3.0, false),
        ];
        rank_points(&mut pts);
        let times: Vec<f64> = pts.iter().map(|p| p.avg_iteration_seconds).collect();
        assert_eq!(times[0], 1.0);
        assert_eq!(times[1], 2.0);
        assert!(times[2].is_nan(), "feasible NaN ranks after finite feasible");
        assert!(pts[2].feasible);
        assert_eq!(times[3], 3.0, "infeasible block follows every feasible point");
        assert!(times[4].is_nan());
    }

    #[test]
    fn preflight_accepts_the_standard_candidate_grid() {
        let g = search();
        g.preflight().expect("every standard grid plan must verify");
        let mut sp = search();
        sp.parallel.sp = 4;
        sp.preflight().expect("sp-expanded plans must verify too");
    }

    #[test]
    fn best_is_feasible() {
        let g = search();
        let best = g.best().unwrap();
        assert!(best.feasible);
        assert!(best.avg_iteration_seconds > 0.0);
    }

    #[test]
    fn table6_shape_middle_chunk_wins() {
        // Paper Table 6 (7B, 256K, <4,4,4,selective>, ChunkSize*K = 32K):
        // (8K,4) beats both (2K,16) and (32K,1).
        let g = GridSearch {
            global_batch_size: 128,
            iters: 2,
            ..search()
        };
        let p_2k = g.evaluate(2048, 16);
        let p_8k = g.evaluate(8192, 4);
        let p_32k = g.evaluate(32 * 1024, 1);
        assert!(
            p_8k.avg_iteration_seconds < p_2k.avg_iteration_seconds,
            "(8K,4) {} vs (2K,16) {}",
            p_8k.avg_iteration_seconds,
            p_2k.avg_iteration_seconds
        );
        assert!(
            p_8k.avg_iteration_seconds < p_32k.avg_iteration_seconds,
            "(8K,4) {} vs (32K,1) {}",
            p_8k.avg_iteration_seconds,
            p_32k.avg_iteration_seconds
        );
    }
}
