//! Synthetic token corpus for the real trainer.
//!
//! We cannot ship LMSysChat1M, so the end-to-end example trains on synthetic
//! byte-level sequences with learnable structure: a seeded order-2 Markov
//! chain over a small alphabet with long-range "topic" tokens, so the loss
//! curve shows real learning (the model can beat the unigram entropy) while
//! the data remains fully self-contained and deterministic.

use crate::util::rng::Rng;

/// Generates token sequences (u32 ids < vocab_size) of requested lengths.
#[derive(Clone, Debug)]
pub struct SyntheticCorpus {
    pub vocab_size: u32,
    /// Number of distinct "topics"; each topic biases the Markov transitions.
    pub num_topics: u32,
    seed: u64,
}

impl SyntheticCorpus {
    pub fn new(vocab_size: u32, seed: u64) -> Self {
        assert!(vocab_size >= 16, "need at least 16 tokens of vocab");
        Self { vocab_size, num_topics: 8, seed }
    }

    /// Deterministically generate sequence `seq_id` with `len` tokens.
    /// Different ids give different sequences; the same id always gives the
    /// same sequence (so dataloader epochs are reproducible).
    pub fn generate(&self, seq_id: u64, len: u64) -> Vec<u32> {
        let mut rng = Rng::new(self.seed ^ seq_id.wrapping_mul(0x9E3779B97F4A7C15));
        let topic = rng.gen_range(self.num_topics as u64) as u32;
        let v = self.vocab_size as u64;
        let mut out = Vec::with_capacity(len as usize);
        // Order-2 chain: next = f(prev1, prev2, topic) + noise. The "f" is a
        // fixed mixing hash, so conditional entropy is low (learnable) while
        // unigram entropy stays high.
        let mut p1 = topic % self.vocab_size;
        let mut p2 = (topic / 2) % self.vocab_size;
        for i in 0..len {
            let tok = if rng.gen_bool(0.15) {
                // Noise token: uniform.
                rng.gen_range(v) as u32
            } else if i % 257 == 0 {
                // Periodic topic marker: long-range structure the model can
                // exploit once context spans multiple chunks.
                topic
            } else {
                let mix = (p1 as u64)
                    .wrapping_mul(6364136223846793005)
                    .wrapping_add((p2 as u64).wrapping_mul(1442695040888963407))
                    .wrapping_add(topic as u64);
                ((mix >> 33) % v) as u32
            };
            out.push(tok);
            p2 = p1;
            p1 = tok;
        }
        out
    }

    /// Unigram cross-entropy (nats) of a generated sample — the "no model"
    /// baseline the training loss should beat.
    pub fn unigram_entropy(&self, n_seqs: u64, len: u64) -> f64 {
        let mut counts = vec![0u64; self.vocab_size as usize];
        let mut total = 0u64;
        for id in 0..n_seqs {
            for t in self.generate(id, len) {
                counts[t as usize] += 1;
                total += 1;
            }
        }
        counts
            .iter()
            .filter(|&&c| c > 0)
            .map(|&c| {
                let p = c as f64 / total as f64;
                -p * p.ln()
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_id() {
        let c = SyntheticCorpus::new(512, 99);
        assert_eq!(c.generate(5, 1000), c.generate(5, 1000));
        assert_ne!(c.generate(5, 1000), c.generate(6, 1000));
    }

    #[test]
    fn tokens_in_vocab() {
        let c = SyntheticCorpus::new(64, 1);
        for id in 0..20 {
            assert!(c.generate(id, 500).iter().all(|&t| t < 64));
        }
    }

    #[test]
    fn requested_length() {
        let c = SyntheticCorpus::new(512, 1);
        assert_eq!(c.generate(0, 12345).len(), 12345);
        assert_eq!(c.generate(0, 1).len(), 1);
    }

    #[test]
    fn has_learnable_structure() {
        // Conditional (bigram-hash) predictability: the same (p1, p2, topic)
        // always maps to the same next token (when not noise), so the
        // top-conditional-choice accuracy must far exceed uniform 1/64.
        let c = SyntheticCorpus::new(64, 7);
        let seq = c.generate(3, 20_000);
        // BTreeMap, not HashMap: the determinism lint (`chunkflow lint-src`)
        // bans map types with nondeterministic iteration order everywhere in
        // src/ so a hazard can never migrate into a bit-identity path.
        use std::collections::BTreeMap;
        let mut table: BTreeMap<(u32, u32), BTreeMap<u32, u32>> = BTreeMap::new();
        for w in seq.windows(3) {
            *table
                .entry((w[0], w[1]))
                .or_default()
                .entry(w[2])
                .or_insert(0) += 1;
        }
        let (mut correct, mut total) = (0u64, 0u64);
        for dist in table.values() {
            let best: u32 = *dist.values().max().unwrap();
            let sum: u32 = dist.values().sum();
            correct += best as u64;
            total += sum as u64;
        }
        let acc = correct as f64 / total as f64;
        assert!(acc > 0.5, "conditional predictability too low: {acc}");
    }

    #[test]
    fn unigram_entropy_positive() {
        let c = SyntheticCorpus::new(64, 2);
        let h = c.unigram_entropy(10, 2000);
        assert!(h > 1.0 && h < (64f64).ln() + 0.01, "h = {h}");
    }
}
