//! Long-tail sequence-length distributions.
//!
//! The paper's observation 1: SFT datasets are extremely long-tailed —
//! LMSysChat1M has >90% of sequences under 1K tokens but a 303K-token
//! maximum. We model a length distribution as CDF buckets taken directly
//! from the paper's tables, sampling a bucket by probability and a length
//! log-uniformly within the bucket (log-uniform matches the heavy-tail
//! within-bucket behaviour of the real dataset far better than uniform).

use crate::util::rng::Rng;

/// A half-open length bucket `[lo, hi)` holding `prob` mass.
#[derive(Clone, Copy, Debug)]
pub struct LengthBucket {
    pub lo: u64,
    pub hi: u64,
    pub prob: f64,
}

/// A bucketed sequence-length distribution.
#[derive(Clone, Debug)]
pub struct LengthDistribution {
    pub name: String,
    pub buckets: Vec<LengthBucket>,
    /// The single longest sequence the dataset contains (paper's "Longest").
    pub longest: u64,
}

const K: u64 = 1024;

impl LengthDistribution {
    /// Table 1: LMSysChat1M. CDF rows: <1K 90.499%, <4K 99.539%,
    /// <8K 99.908%, <32K 99.987%, <128K 99.996%, longest 303K.
    pub fn lmsys_chat_1m() -> Self {
        Self::from_cdf(
            "lmsys-chat-1m",
            &[
                (1 * K, 0.90499),
                (4 * K, 0.99539),
                (8 * K, 0.99908),
                (32 * K, 0.99987),
                (128 * K, 0.99996),
            ],
            303 * K,
        )
    }

    /// Table 2: the paper's constructed evaluation dataset. CDF rows:
    /// <1K 98.17%, <4K 99.72%, <8K 99.83%, <32K 99.92%, <128K 99.98%,
    /// longest 256K.
    pub fn evaluation_dataset() -> Self {
        Self::from_cdf(
            "evaluation",
            &[
                (1 * K, 0.9817),
                (4 * K, 0.9972),
                (8 * K, 0.9983),
                (32 * K, 0.9992),
                (128 * K, 0.9998),
            ],
            256 * K,
        )
    }

    /// Long-tail supervised fine-tuning workload: the LMSysChat1M shape,
    /// which is the paper's motivating SFT dataset (Table 1). First-class
    /// sweep scenario name: `longtail-sft`.
    pub fn longtail_sft() -> Self {
        let mut d = Self::lmsys_chat_1m();
        d.name = "longtail-sft".to_string();
        d
    }

    /// Continual pre-training workload: documents concentrated toward the
    /// context limit rather than long-tailed — most mass sits in the
    /// 16K-128K range (FlexSP-style "homogeneous long" regime).
    pub fn continual_pretraining() -> Self {
        Self::from_cdf(
            "continual-pretrain",
            &[
                (4 * K, 0.05),
                (16 * K, 0.30),
                (32 * K, 0.65),
                (64 * K, 0.90),
            ],
            128 * K,
        )
    }

    /// Degenerate uniform-length workload: every sequence has exactly `len`
    /// tokens (the classic fixed-shape pre-training batch; the baseline's
    /// best case, so speedups here lower-bound ChunkFlow's advantage).
    pub fn uniform_length(len: u64) -> Self {
        assert!(len >= 1, "uniform length must be positive");
        Self {
            name: format!("uniform-{}", crate::util::format_tokens(len)),
            buckets: vec![LengthBucket { lo: len, hi: len + 1, prob: 1.0 }],
            longest: len,
        }
    }

    /// Look up a distribution by scenario-registry name.
    pub fn by_name(name: &str) -> anyhow::Result<Self> {
        match name {
            "lmsys" | "lmsys-chat-1m" => Ok(Self::lmsys_chat_1m()),
            "eval" | "evaluation" => Ok(Self::evaluation_dataset()),
            "longtail-sft" => Ok(Self::longtail_sft()),
            "continual-pretrain" => Ok(Self::continual_pretraining()),
            other => {
                if let Some(size) = other
                    .strip_prefix("uniform-")
                    .and_then(crate::util::cli::parse_size)
                {
                    return Ok(Self::uniform_length(size));
                }
                anyhow::bail!(
                    "unknown length distribution `{other}` (have: lmsys, eval, \
                     longtail-sft, continual-pretrain, uniform-<len>)"
                )
            }
        }
    }

    /// Build from cumulative rows `(upper_bound, cdf)`; mass above the last
    /// row extends to `longest`.
    pub fn from_cdf(name: &str, rows: &[(u64, f64)], longest: u64) -> Self {
        let mut buckets = Vec::with_capacity(rows.len() + 1);
        let mut lo = 1u64;
        let mut cdf_prev = 0.0;
        for &(hi, cdf) in rows {
            assert!(cdf >= cdf_prev && cdf <= 1.0, "CDF must be nondecreasing");
            buckets.push(LengthBucket { lo, hi, prob: cdf - cdf_prev });
            lo = hi;
            cdf_prev = cdf;
        }
        assert!(longest >= lo, "longest must exceed last bucket bound");
        buckets.push(LengthBucket { lo, hi: longest + 1, prob: 1.0 - cdf_prev });
        Self { name: name.to_string(), buckets, longest }
    }

    /// Sample one sequence length.
    pub fn sample(&self, rng: &mut Rng) -> u64 {
        let weights: Vec<f64> = self.buckets.iter().map(|b| b.prob).collect();
        let b = &self.buckets[rng.categorical(&weights)];
        // Log-uniform within the bucket.
        let (lo, hi) = (b.lo.max(1) as f64, b.hi as f64);
        let x = (lo.ln() + rng.next_f64() * (hi.ln() - lo.ln())).exp();
        (x as u64).clamp(b.lo.max(1), b.hi - 1)
    }

    /// Sample `n` lengths, truncating everything above `context_length`
    /// to be *excluded* (the paper excludes, not truncates, over-length
    /// sequences for each experiment) — resample until under the limit.
    pub fn sample_batch(&self, rng: &mut Rng, n: usize, context_length: u64) -> Vec<u64> {
        assert!(
            context_length >= self.buckets[0].hi,
            "context_length below first bucket would loop forever"
        );
        (0..n)
            .map(|_| loop {
                let len = self.sample(rng);
                if len <= context_length {
                    break len;
                }
            })
            .collect()
    }

    /// Empirical CDF at `x` from the bucket model (exact at bucket edges).
    pub fn cdf(&self, x: u64) -> f64 {
        let mut acc = 0.0;
        for b in &self.buckets {
            if x >= b.hi {
                acc += b.prob;
            } else if x > b.lo {
                // Log-linear interpolation inside the bucket.
                let frac = ((x as f64).ln() - (b.lo.max(1) as f64).ln())
                    / ((b.hi as f64).ln() - (b.lo.max(1) as f64).ln());
                acc += b.prob * frac.clamp(0.0, 1.0);
            }
        }
        acc.min(1.0)
    }

    /// Render the paper-style table rows: proportion under each bound.
    pub fn table_rows(&self) -> Vec<(String, f64)> {
        [1 * K, 4 * K, 8 * K, 32 * K, 128 * K]
            .iter()
            .map(|&b| (format!("< {}", crate::util::format_tokens(b)), self.cdf(b)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probabilities_sum_to_one() {
        for d in [LengthDistribution::lmsys_chat_1m(), LengthDistribution::evaluation_dataset()] {
            let total: f64 = d.buckets.iter().map(|b| b.prob).sum();
            assert!((total - 1.0).abs() < 1e-9, "{}: {total}", d.name);
        }
    }

    #[test]
    fn empirical_matches_table1() {
        let d = LengthDistribution::lmsys_chat_1m();
        let mut rng = Rng::new(42);
        let n = 200_000;
        let lens: Vec<u64> = (0..n).map(|_| d.sample(&mut rng)).collect();
        let under = |b: u64| lens.iter().filter(|&&l| l < b).count() as f64 / n as f64;
        assert!((under(1024) - 0.90499).abs() < 0.005, "<1K: {}", under(1024));
        assert!((under(4096) - 0.99539).abs() < 0.002, "<4K: {}", under(4096));
        assert!((under(32 * 1024) - 0.99987).abs() < 0.001);
        assert!(lens.iter().all(|&l| l >= 1 && l <= 303 * 1024));
    }

    #[test]
    fn empirical_matches_table2() {
        let d = LengthDistribution::evaluation_dataset();
        let mut rng = Rng::new(7);
        let n = 200_000;
        let lens: Vec<u64> = (0..n).map(|_| d.sample(&mut rng)).collect();
        let under = |b: u64| lens.iter().filter(|&&l| l < b).count() as f64 / n as f64;
        assert!((under(1024) - 0.9817).abs() < 0.005, "<1K: {}", under(1024));
        assert!(lens.iter().all(|&l| l <= 256 * 1024));
    }

    #[test]
    fn context_length_filter_respected() {
        let d = LengthDistribution::evaluation_dataset();
        let mut rng = Rng::new(3);
        let lens = d.sample_batch(&mut rng, 5_000, 32 * 1024);
        assert!(lens.iter().all(|&l| l <= 32 * 1024));
        assert_eq!(lens.len(), 5_000);
    }

    #[test]
    fn cdf_is_monotone() {
        let d = LengthDistribution::lmsys_chat_1m();
        let mut prev = 0.0;
        for x in [1, 512, 1024, 2048, 8192, 100_000, 310_000] {
            let c = d.cdf(x);
            assert!(c >= prev, "cdf not monotone at {x}");
            prev = c;
        }
        assert!((d.cdf(400_000) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn table_rows_match_source() {
        let d = LengthDistribution::lmsys_chat_1m();
        let rows = d.table_rows();
        assert_eq!(rows[0].0, "< 1K");
        assert!((rows[0].1 - 0.90499).abs() < 1e-6);
        assert!((rows[3].1 - 0.99987).abs() < 1e-6);
    }

    #[test]
    fn uniform_length_yields_constant_lengths() {
        let d = LengthDistribution::uniform_length(8 * K);
        let mut rng = Rng::new(11);
        for _ in 0..1000 {
            assert_eq!(d.sample(&mut rng), 8 * K);
        }
        let total: f64 = d.buckets.iter().map(|b| b.prob).sum();
        assert!((total - 1.0).abs() < 1e-12);
    }

    #[test]
    fn continual_pretraining_is_heavier_than_sft() {
        let cp = LengthDistribution::continual_pretraining();
        let sft = LengthDistribution::longtail_sft();
        let total: f64 = cp.buckets.iter().map(|b| b.prob).sum();
        assert!((total - 1.0).abs() < 1e-9);
        // Continual pre-training has far more mass above 16K than SFT.
        assert!(1.0 - cp.cdf(16 * K) > 10.0 * (1.0 - sft.cdf(16 * K)));
    }

    #[test]
    fn by_name_resolves_all_registry_names() {
        for name in ["lmsys", "eval", "longtail-sft", "continual-pretrain", "uniform-8K"] {
            let d = LengthDistribution::by_name(name).unwrap();
            assert!(!d.buckets.is_empty(), "{name}");
        }
        assert_eq!(
            LengthDistribution::by_name("uniform-8K").unwrap().longest,
            8 * K
        );
        assert!(LengthDistribution::by_name("nope").is_err());
    }

    #[test]
    fn custom_cdf_validation() {
        // Decreasing CDF must panic.
        let r = std::panic::catch_unwind(|| {
            LengthDistribution::from_cdf("bad", &[(1024, 0.9), (2048, 0.5)], 4096)
        });
        assert!(r.is_err());
    }
}
