//! Batch sampling: draws global batches of variable-length sequences from a
//! length distribution, excluding over-context-length sequences exactly as
//! the paper's evaluation does, and supports Megatron-style sequence packing
//! (§2.2) for the baseline.

use super::longtail::LengthDistribution;
use crate::util::rng::Rng;

/// A training sequence: id + token length. Token *content* is produced
/// lazily by `SyntheticCorpus` only on the real-training path; schedulers
/// and simulators operate on lengths alone.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Sequence {
    pub id: u64,
    pub len: u64,
}

/// Draws batches deterministically given a seed.
#[derive(Clone, Debug)]
pub struct BatchSampler {
    dist: LengthDistribution,
    pub context_length: u64,
    pub global_batch_size: usize,
    rng: Rng,
    next_id: u64,
}

impl BatchSampler {
    pub fn new(
        dist: LengthDistribution,
        context_length: u64,
        global_batch_size: usize,
        seed: u64,
    ) -> Self {
        Self { dist, context_length, global_batch_size, rng: Rng::new(seed), next_id: 0 }
    }

    /// Next global batch of sequences.
    pub fn next_batch(&mut self) -> Vec<Sequence> {
        let lens =
            self.dist
                .sample_batch(&mut self.rng, self.global_batch_size, self.context_length);
        lens.into_iter()
            .map(|len| {
                let id = self.next_id;
                self.next_id += 1;
                Sequence { id, len }
            })
            .collect()
    }

    /// Draw batches until one contains a sequence of at least `min_len`
    /// tokens. Fully deterministic given the sampler seed; returns an error
    /// (with actionable context) after `max_batches` draws instead of
    /// panicking, so callers — tests, CI smoke runs — fail loudly rather
    /// than flake on an opaque panic.
    pub fn next_batch_with_min_len(
        &mut self,
        min_len: u64,
        max_batches: usize,
    ) -> anyhow::Result<Vec<Sequence>> {
        for _ in 0..max_batches {
            let batch = self.next_batch();
            if batch.iter().any(|s| s.len >= min_len) {
                return Ok(batch);
            }
        }
        anyhow::bail!(
            "no sequence >= {min_len} tokens in {max_batches} batches of {} from `{}` \
             (deterministic for this seed; raise max_batches or pick a heavier tail)",
            self.global_batch_size,
            self.dist.name
        )
    }

    /// Megatron-style sequence packing (§2.2): greedily concatenate
    /// sequences into packed buffers of at most `pack_len` tokens,
    /// preserving arrival order (first-fit into the open buffer, flush when
    /// the next sequence doesn't fit). Long sequences (> pack_len) get a
    /// buffer of their own — they are NOT split (that is ChunkFlow's job).
    /// An oversized sequence flushes the open buffer first, so packs come
    /// out in arrival order and sequences it separates are never packed
    /// together (the documented contract; previously violated).
    pub fn pack(batch: &[Sequence], pack_len: u64) -> Vec<Vec<Sequence>> {
        let mut packs: Vec<Vec<Sequence>> = Vec::new();
        let mut open: Vec<Sequence> = Vec::new();
        let mut open_len = 0u64;
        for &seq in batch {
            if seq.len >= pack_len {
                // Oversized: flush whatever was open, then its own pack.
                if !open.is_empty() {
                    packs.push(std::mem::take(&mut open));
                    open_len = 0;
                }
                packs.push(vec![seq]);
                continue;
            }
            if open_len + seq.len > pack_len && !open.is_empty() {
                packs.push(std::mem::take(&mut open));
                open_len = 0;
            }
            open_len += seq.len;
            open.push(seq);
        }
        if !open.is_empty() {
            packs.push(open);
        }
        packs
    }

    /// Partition a batch across `dp` data-parallel ranks round-robin — the
    /// naive split whose load imbalance the paper's Obs. 3 mentions.
    pub fn split_dp(batch: &[Sequence], dp: usize) -> Vec<Vec<Sequence>> {
        let mut out = vec![Vec::new(); dp];
        for (i, &s) in batch.iter().enumerate() {
            out[i % dp].push(s);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sampler(ctx: u64, n: usize) -> BatchSampler {
        BatchSampler::new(LengthDistribution::evaluation_dataset(), ctx, n, 17)
    }

    #[test]
    fn batch_has_right_size_and_bounds() {
        let mut s = sampler(32 * 1024, 256);
        let b = s.next_batch();
        assert_eq!(b.len(), 256);
        assert!(b.iter().all(|s| s.len >= 1 && s.len <= 32 * 1024));
    }

    #[test]
    fn ids_are_unique_across_batches() {
        let mut s = sampler(8192, 64);
        let b1 = s.next_batch();
        let b2 = s.next_batch();
        let mut ids: Vec<u64> = b1.iter().chain(b2.iter()).map(|s| s.id).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), 128);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = sampler(8192, 32);
        let mut b = sampler(8192, 32);
        assert_eq!(a.next_batch(), b.next_batch());
    }

    #[test]
    fn packing_respects_limit_and_preserves_all() {
        let mut s = sampler(32 * 1024, 256);
        let batch = s.next_batch();
        let packs = BatchSampler::pack(&batch, 4096);
        // Every sequence appears exactly once.
        let packed: u64 = packs.iter().flatten().map(|s| s.len).sum();
        assert_eq!(packed, batch.iter().map(|s| s.len).sum::<u64>());
        for p in &packs {
            let total: u64 = p.iter().map(|s| s.len).sum();
            // Either within limit, or a single oversized sequence.
            assert!(total <= 4096 || p.len() == 1, "pack of {} seqs, {total} tokens", p.len());
        }
    }

    #[test]
    fn packing_empty_batch() {
        assert!(BatchSampler::pack(&[], 1024).is_empty());
    }

    #[test]
    fn oversized_sequence_flushes_open_buffer_first() {
        // Regression: an oversized sequence used to be emitted as its own
        // pack *before* the open buffer flushed, so packs left arrival
        // order and the sequences it separated (ids 0 and 2 here, which
        // fit one buffer together) were packed into one buffer.
        let batch = [
            Sequence { id: 0, len: 400 },
            Sequence { id: 1, len: 5000 }, // oversized
            Sequence { id: 2, len: 400 },
        ];
        let packs = BatchSampler::pack(&batch, 1024);
        let ids: Vec<Vec<u64>> =
            packs.iter().map(|p| p.iter().map(|s| s.id).collect()).collect();
        assert_eq!(ids, vec![vec![0], vec![1], vec![2]]);
    }

    #[test]
    fn packs_preserve_arrival_order() {
        // First-appearance order of packs matches arrival order of their
        // first sequences, for a mixed batch with several oversized runs.
        let lens = [100u64, 2000, 300, 300, 4000, 4000, 200, 900, 50];
        let batch: Vec<Sequence> = lens
            .iter()
            .enumerate()
            .map(|(i, &len)| Sequence { id: i as u64, len })
            .collect();
        let packs = BatchSampler::pack(&batch, 1024);
        let firsts: Vec<u64> = packs.iter().map(|p| p[0].id).collect();
        let mut sorted = firsts.clone();
        sorted.sort_unstable();
        assert_eq!(firsts, sorted, "packs out of arrival order: {firsts:?}");
        // Within each pack, sequences stay in arrival order too.
        for p in &packs {
            let ids: Vec<u64> = p.iter().map(|s| s.id).collect();
            let mut s = ids.clone();
            s.sort_unstable();
            assert_eq!(ids, s);
        }
        let total: u64 = packs.iter().flatten().map(|s| s.len).sum();
        assert_eq!(total, lens.iter().sum::<u64>());
    }

    #[test]
    fn dp_split_round_robin() {
        let batch: Vec<Sequence> = (0..10).map(|i| Sequence { id: i, len: 100 + i }).collect();
        let parts = BatchSampler::split_dp(&batch, 4);
        assert_eq!(parts.len(), 4);
        assert_eq!(parts[0].len(), 3); // ids 0, 4, 8
        assert_eq!(parts[1].len(), 3);
        assert_eq!(parts[2].len(), 2);
        assert_eq!(parts[3].len(), 2);
        assert_eq!(parts[0][1].id, 4);
    }

    #[test]
    fn dp_imbalance_exists_with_long_tail() -> anyhow::Result<()> {
        // With a long-tail batch, round-robin DP splits have unequal token
        // loads — the imbalance Obs. 3 describes. The helper is
        // deterministic for the fixed seed, so this cannot flake.
        let mut s = sampler(256 * 1024, 256);
        let batch = s.next_batch_with_min_len(32 * 1024 + 1, 200)?;
        let parts = BatchSampler::split_dp(&batch, 4);
        let loads: Vec<u64> = parts.iter().map(|p| p.iter().map(|s| s.len).sum()).collect();
        let max = *loads.iter().max().unwrap() as f64;
        let min = *loads.iter().min().unwrap() as f64;
        assert!(max / min > 1.2, "expected imbalance, loads {loads:?}");
        Ok(())
    }

    #[test]
    fn min_len_search_is_deterministic_and_errors_cleanly() {
        let batch_a = sampler(256 * 1024, 64)
            .next_batch_with_min_len(64 * 1024, 500)
            .unwrap();
        let batch_b = sampler(256 * 1024, 64)
            .next_batch_with_min_len(64 * 1024, 500)
            .unwrap();
        assert_eq!(batch_a, batch_b, "same seed must yield the same batch");
        // An impossible request errors instead of panicking.
        let err = sampler(8192, 8).next_batch_with_min_len(100_000, 3).unwrap_err();
        assert!(err.to_string().contains("3 batches"), "{err}");
    }
}
