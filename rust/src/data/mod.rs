//! Dataset machinery: the long-tail sequence-length distributions from the
//! paper's Table 1 (LMSysChat1M) and Table 2 (evaluation dataset), a
//! synthetic token corpus for the real trainer, batch sampling, and
//! sequence packing (§2.2).

mod corpus;
mod longtail;
mod sampler;

pub use corpus::SyntheticCorpus;
pub use longtail::{LengthBucket, LengthDistribution};
pub use sampler::{BatchSampler, Sequence};
