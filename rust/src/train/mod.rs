//! The real ChunkFlow trainer: Algorithm 2 executed over any [`Backend`]
//! implementation, end to end in Rust.
//!
//! The trainer is generic over the three-program contract
//! (`runtime::Backend`): the PJRT runtime executes AOT-compiled XLA
//! programs, the pure-Rust [`ReferenceBackend`](crate::runtime::ReferenceBackend)
//! executes the same transformer with exact f64 gradients so training runs
//! (and is tested) on any machine.
//!
//! One optimizer step:
//! 1. sample a global batch of variable-length sequences (long-tail);
//! 2. Algorithm 1: reorganize into chunks (`chunk::construct_chunks`);
//! 3. for each dependent-chunk group, build the Algorithm-2 plan
//!    (`schedule::schedule_group` with the configured retention budget `K`)
//!    and execute it:
//!    - `Forward` ops run `fwd_kv` ascending, KV into the StateStore
//!      (activations are discarded by construction — each call retains
//!      nothing), losses recorded;
//!    - `Backward` ops run `chunk_vjp` descending (the program recomputes
//!      the forward internally — the realization of Alg. 2's "executed
//!      twice", so `RecomputeForward` ops carry no separate call);
//!      parameter grads accumulate, `d_kv_in` scatters into the pending
//!      `g_kv` of earlier chunks;
//!    the plan's peak live-activation count (`<= K` by construction,
//!    re-validated every step) is surfaced as `act_peak_chunks`;
//! 4. standalone chunks run a single `chunk_vjp` with an empty prefix;
//! 5. grads scaled by 1/total_tokens, clipped, Adam update, params re-sent.
//!
//! Peak memory is `O(K * ChunkSize)` activations inside the backend plus
//! the `O(context)` KV StateStore — exactly the paper's Table 5 shape; both
//! components are reported per step and CI-asserted by the integration
//! suites.

mod adam;
pub mod checkpoint;

pub use adam::{Adam, AdamState};

use std::collections::BTreeMap;
use std::path::Path;
use std::time::{Duration, Instant};

use crate::chunk::{construct_chunks, Chunk, ChunkKind, ChunkSet};
use crate::config::TrainConfig;
use crate::data::{BatchSampler, LengthDistribution, SyntheticCorpus};
use crate::pipeline::{ExecOptions, PolicyKind, RetryPolicy};
use crate::runtime::{
    Backend, ChunkInputs, FlatParams, ReferenceBackend, Runtime, Scalar, StagePartition,
};
use crate::schedule::{schedule_group, validate_group_plan, ChunkOp};
use crate::state::{OffloadStore, StateKey, StateStore};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Unified view of the trainer's retained-KV backing: the plain in-memory
/// [`StateStore`] or the budgeted, disk-spilling [`OffloadStore`]
/// (`--offload-budget-bytes`). `prefix` assembles the [L, 2, upto·C, H, D]
/// buffer a dependent chunk's forward consumes; on the offload backing this
/// transparently restores spilled chunk KV — the "restore on recompute"
/// path, since the recompute-forward inside `chunk_vjp` is exactly what
/// consumes the prefix again.
pub trait KvBacking<E: Scalar> {
    fn store(&mut self, key: StateKey, data: Vec<E>, bytes: u64) -> anyhow::Result<()>;
    fn prefix(
        &mut self,
        seq_id: u64,
        upto: usize,
        num_layers: usize,
        chunk: usize,
        hd: usize,
    ) -> anyhow::Result<Vec<E>>;
    /// High-water mark of the logical KV footprint (Table 5's component).
    fn logical_peak_bytes(&self) -> u64;
    /// High-water mark of host-resident bytes (== logical when nothing
    /// spills; bounded by the budget on the offload backing).
    fn resident_peak_bytes(&self) -> u64;
}

impl<E: Scalar> KvBacking<E> for StateStore<Vec<E>> {
    fn store(&mut self, key: StateKey, data: Vec<E>, bytes: u64) -> anyhow::Result<()> {
        self.put(key, data, bytes);
        Ok(())
    }

    fn prefix(
        &mut self,
        seq_id: u64,
        upto: usize,
        num_layers: usize,
        chunk: usize,
        hd: usize,
    ) -> anyhow::Result<Vec<E>> {
        let parts: Vec<&Vec<E>> =
            self.prefix_of(seq_id, upto).into_iter().map(|(_, v)| v).collect();
        anyhow::ensure!(parts.len() == upto, "missing KV state");
        Ok(concat_prefix_with(&parts, num_layers, chunk, hd))
    }

    fn logical_peak_bytes(&self) -> u64 {
        self.peak_bytes()
    }

    fn resident_peak_bytes(&self) -> u64 {
        self.peak_bytes()
    }
}

impl<E: Scalar> KvBacking<E> for OffloadStore<E> {
    fn store(&mut self, key: StateKey, data: Vec<E>, _bytes: u64) -> anyhow::Result<()> {
        self.put(key, data)
    }

    fn prefix(
        &mut self,
        seq_id: u64,
        upto: usize,
        num_layers: usize,
        chunk: usize,
        hd: usize,
    ) -> anyhow::Result<Vec<E>> {
        let mut owned: Vec<Vec<E>> = Vec::with_capacity(upto);
        for i in 0..upto {
            let v = self
                .get(&StateKey { seq_id, chunk_index: i })?
                .ok_or_else(|| anyhow::anyhow!("missing KV state for chunk {i}"))?;
            owned.push(v);
        }
        let parts: Vec<&Vec<E>> = owned.iter().collect();
        Ok(concat_prefix_with(&parts, num_layers, chunk, hd))
    }

    fn logical_peak_bytes(&self) -> u64 {
        self.peak_total_bytes()
    }

    fn resident_peak_bytes(&self) -> u64 {
        self.peak_resident_bytes()
    }
}

/// Per-step metrics.
#[derive(Clone, Debug)]
pub struct StepMetrics {
    pub step: u64,
    pub loss_per_token: f64,
    pub tokens: u64,
    pub chunks: usize,
    /// Backend program executions during the step.
    pub backend_calls: u64,
    pub seconds: f64,
    pub grad_norm: f64,
    /// Peak StateStore bytes during the step (KV state).
    pub kv_peak_bytes: u64,
    /// Peak retained-activation budget used across all Algorithm-2 plans
    /// this step, in chunks (never exceeds the configured K).
    pub act_peak_chunks: usize,
    /// Pipeline stages this step executed on (1 = the classic single-stage
    /// Algorithm-2 path).
    pub stages: usize,
    /// Data-parallel replica groups this step executed on (1 = no DP).
    pub dp: usize,
    /// Chunk-aware sequence-parallel degree this step ran under (1 = off).
    pub sp: u64,
    /// DP mode only: max/mean token-load ratio of the chunk-balanced rank
    /// assignment this step ran under (1.0 = perfectly balanced).
    pub dp_imbalance: Option<f64>,
    /// Pipeline mode only: wall-clock bubble ratio measured by the
    /// stage-parallel executor (`pipeline::exec`).
    pub measured_bubble_ratio: Option<f64>,
    /// Pipeline mode only: the simulator's predicted bubble ratio for the
    /// same chunk set and schedule (`pipeline::simulate`).
    pub predicted_bubble_ratio: Option<f64>,
    /// Uneven stage partition this step ran under (`--partition` layer
    /// counts, e.g. `"3,1"`); None on the equal-partition default, so
    /// pre-elastic history bytes are unchanged.
    pub partition: Option<String>,
    /// Non-default schedule policy this step ran under (`--policy`); None
    /// under state-aware 1F1B, keeping pre-elastic history bytes unchanged.
    pub policy: Option<String>,
    /// Whether the backend ran its parallel fast path this step (the
    /// reference backend's `--fast-path`; always false on PJRT).
    pub fast_path: bool,
    /// Supervised-executor retries this step took to complete (0 on the
    /// fault-free path; nonzero only under `--max-retries` recovery).
    pub retries: u64,
}

/// Result of gradient accumulation over one batch (`compute_gradients`).
#[derive(Clone, Debug)]
pub struct GradAccum<E> {
    pub loss_sum: f64,
    pub tok_sum: f64,
    /// Summed (unscaled) parameter gradients in the backend element type.
    pub grads: Vec<Vec<E>>,
    pub chunks: usize,
    /// Peak logical KV bytes across the batch's chunk groups (resident +
    /// spilled when offloading).
    pub kv_peak_bytes: u64,
    /// Peak host-resident KV bytes; equals `kv_peak_bytes` without an
    /// offload budget, and never exceeds the budget with one.
    pub kv_resident_peak_bytes: u64,
    /// Peak live-activation count across all group plans (<= K).
    pub act_peak_chunks: usize,
}

/// The trainer owns the backend, parameters, optimizer and data pipeline.
pub struct Trainer<B: Backend = Runtime> {
    pub backend: B,
    pub params: FlatParams,
    pub adam: Adam,
    pub config: TrainConfig,
    dist: LengthDistribution,
    sampler: BatchSampler,
    corpus: SyntheticCorpus,
    step: u64,
    /// KV residency budget: when set, dependent groups run over a
    /// disk-spilling [`OffloadStore`] instead of the in-memory StateStore.
    offload_budget: Option<u64>,
    /// Supervisor policy for the threaded execution paths (`--max-retries`).
    /// The default fails fast, exactly as before supervision existed.
    retry: RetryPolicy,
    /// Stage-handoff deadline override (`--handoff-timeout-secs`); `None`
    /// derives one from the cost model.
    handoff_timeout: Option<Duration>,
    /// Chunk-aware sequence-parallel degree (`--sp`): dependent chunks'
    /// backward query rows split across this many shard calls over the
    /// KV-prefix seam. 1 = off (the pre-SP code path, bit for bit).
    sp: u64,
    /// Uneven stage partition for the pipelined paths (`--partition`);
    /// `None` = equal split, today's code path bit for bit.
    partition: Option<StagePartition>,
    /// Schedule policy for the pipelined paths (`--policy`); the default
    /// state-aware 1F1B is bit-identical to the pre-policy path.
    policy: PolicyKind,
    pub history: Vec<StepMetrics>,
}

impl Trainer<Runtime> {
    /// Load the PJRT runtime from `config.artifacts_dir` (requires the
    /// `pjrt` cargo feature; use [`Trainer::with_backend`] with a
    /// [`crate::runtime::ReferenceBackend`] otherwise).
    pub fn new(config: TrainConfig, dist: LengthDistribution) -> anyhow::Result<Self> {
        let runtime = Runtime::load(Path::new(&config.artifacts_dir), &config.model.name)?;
        Self::with_backend(runtime, config, dist)
    }
}

impl<B: Backend> Trainer<B> {
    /// Build a trainer over an already-constructed backend.
    pub fn with_backend(
        mut backend: B,
        config: TrainConfig,
        dist: LengthDistribution,
    ) -> anyhow::Result<Self> {
        let c = backend.manifest().chunk_size as u64;
        let max_ctx = c * backend.manifest().max_chunks as u64;
        anyhow::ensure!(
            config.context_length <= max_ctx,
            "context {} exceeds backend coverage {max_ctx}",
            config.context_length
        );
        anyhow::ensure!(
            config.chunkflow.chunk_size == c,
            "configured ChunkSize {} != backend chunk size {c} (the backend's \
             compiled chunk shape is authoritative)",
            config.chunkflow.chunk_size
        );
        let params = init_params(backend.manifest(), config.seed);
        backend.set_params(&params)?;
        let adam = fresh_adam(&config, backend.manifest());
        let sampler = BatchSampler::new(
            dist.clone(),
            config.context_length,
            config.global_batch_size as usize,
            config.seed,
        );
        let corpus =
            SyntheticCorpus::new(backend.manifest().vocab_size as u32, config.seed ^ 0xDA7A);
        Ok(Self {
            backend,
            params,
            adam,
            config,
            dist,
            sampler,
            corpus,
            step: 0,
            offload_budget: None,
            retry: RetryPolicy::none(),
            handoff_timeout: None,
            sp: 1,
            partition: None,
            policy: PolicyKind::default(),
            history: Vec::new(),
        })
    }

    /// Optimizer steps completed so far (restored by checkpoints).
    pub fn step(&self) -> u64 {
        self.step
    }

    /// Supervisor retry budget for the threaded execution paths
    /// (`--max-retries`): a stage/replica panic or a handoff timeout tears
    /// the micro-step down cleanly and reruns it, up to this many times.
    /// Retries are bit-identical to an untroubled run because gradient
    /// computation is a pure function of (params, batch).
    pub fn set_retry_policy(&mut self, retry: RetryPolicy) {
        self.retry = retry;
    }

    /// Override the stage-handoff deadline (`--handoff-timeout-secs`);
    /// `None` restores the cost-model-derived default.
    pub fn set_handoff_timeout(&mut self, timeout: Option<Duration>) {
        self.handoff_timeout = timeout;
    }

    /// Chunk-aware sequence-parallel degree (`--sp`): long (dependent)
    /// chunks' backward calls split their query rows across `sp` shards
    /// over the existing KV-prefix seam (the single-rule sharding decision
    /// lives in [`crate::config::ParallelConfig::sp_shards`]: short chunks
    /// never shard, shards never exceed a chunk's live rows). Each shard's
    /// loss-row and KV-cotangent ownership partitions the unsharded call,
    /// so the summed gradients match up to float re-association (gated at
    /// 1e-6 by `tests/integration_sp.rs`); `sp = 1` takes today's code
    /// path bit for bit.
    pub fn set_sp(&mut self, sp: u64) {
        self.sp = sp.max(1);
    }

    /// The configured sequence-parallel degree (1 = off).
    pub fn sp(&self) -> u64 {
        self.sp
    }

    /// Uneven stage partition for the pipelined paths (`--partition`): the
    /// executor splits layers per these counts instead of the equal
    /// `stage_layer_range` split. `None` (or an explicitly equal partition)
    /// keeps the pre-elastic path bit for bit.
    pub fn set_partition(&mut self, partition: Option<StagePartition>) {
        self.partition = partition;
    }

    /// Schedule policy for the pipelined paths (`--policy`). The default
    /// [`PolicyKind::StateAware1F1B`] is bit-identical to the pre-policy
    /// code path; every policy's executed order is agenda-conformant.
    pub fn set_policy(&mut self, policy: PolicyKind) {
        self.policy = policy;
    }

    fn exec_options(&self) -> ExecOptions {
        ExecOptions {
            handoff_timeout: self.handoff_timeout,
            partition: self.partition.clone(),
            policy: self.policy,
        }
    }

    /// `--partition` layer counts for the history row; None when running
    /// the (explicit or implicit) equal split, so default history bytes
    /// are unchanged.
    fn partition_label(&self) -> Option<String> {
        self.partition.as_ref().filter(|p| !p.is_equal()).map(|p| p.describe())
    }

    /// Non-default `--policy` name for the history row.
    fn policy_label(&self) -> Option<String> {
        (self.policy != PolicyKind::default()).then(|| self.policy.name().to_string())
    }

    /// Bound resident KV bytes (`--offload-budget-bytes`): when set, each
    /// dependent group's retained KV runs over an [`OffloadStore`] — the
    /// coldest chunk KV spills to disk when the budget is exceeded and is
    /// restored transparently when a later backward/recompute consumes it.
    /// Spill round trips are bit-exact, so gradients are unchanged.
    pub fn set_offload_budget(&mut self, budget: Option<u64>) {
        self.offload_budget = budget;
    }

    /// Batch prep shared by every gradient path: Algorithm 1 plus this
    /// step's token cache and sequence-length map.
    fn prepare_batch(
        &self,
        batch: &[crate::data::Sequence],
    ) -> (ChunkSet, BTreeMap<u64, Vec<u32>>, BTreeMap<u64, u64>) {
        let set = construct_chunks(batch, self.backend.manifest().chunk_size as u64);
        let mut tokens: BTreeMap<u64, Vec<u32>> = BTreeMap::new();
        for s in batch {
            tokens.insert(s.id, self.corpus.generate(s.id, s.len));
        }
        let seq_len: BTreeMap<u64, u64> = batch.iter().map(|s| (s.id, s.len)).collect();
        (set, tokens, seq_len)
    }

    /// Gradient accumulation over one batch: Algorithm 1 + Algorithm 2 over
    /// the backend programs. Public so integration tests can compare
    /// against the unchunked `full_step` oracle.
    pub fn compute_gradients(
        &self,
        batch: &[crate::data::Sequence],
    ) -> anyhow::Result<GradAccum<B::Elem>> {
        let (set, tokens, seq_len) = self.prepare_batch(batch);

        let mut grads: Vec<Vec<B::Elem>> = self
            .backend
            .manifest()
            .params
            .iter()
            .map(|p| vec![B::Elem::ZERO; p.size])
            .collect();
        let mut loss_sum = 0.0f64;
        let mut tok_sum = 0.0f64;
        let mut kv_peak = 0u64;
        let mut kv_resident_peak = 0u64;
        let mut act_peak = 0usize;

        // Dependent groups: Algorithm 2 under the configured K budget. The
        // retained-KV backing is per group: in-memory, or the disk-spilling
        // OffloadStore under an `--offload-budget-bytes` residency bound.
        for group in set.dependent_groups() {
            let (l, t) = if let Some(budget) = self.offload_budget {
                let mut store: OffloadStore<B::Elem> = OffloadStore::new(budget)?;
                let r = self
                    .run_group(&group, &tokens, &seq_len, &mut grads, &mut store, &mut act_peak)?;
                kv_peak = kv_peak.max(store.peak_total_bytes());
                kv_resident_peak = kv_resident_peak.max(store.peak_resident_bytes());
                r
            } else {
                let mut store: StateStore<Vec<B::Elem>> = StateStore::new();
                let r = self
                    .run_group(&group, &tokens, &seq_len, &mut grads, &mut store, &mut act_peak)?;
                kv_peak = kv_peak.max(store.peak_bytes());
                kv_resident_peak = kv_resident_peak.max(store.peak_bytes());
                r
            };
            loss_sum += l;
            tok_sum += t;
        }
        // Standalone chunks: the N = 1 plan degenerates to a single vjp
        // with an empty prefix (one retained activation).
        let c = self.backend.manifest().chunk_size;
        let g_zero = vec![B::Elem::ZERO; self.backend.kv_elements(c)];
        for chunk in set.standalone_chunks() {
            let inputs = self.chunk_inputs(chunk, &tokens, &seq_len, 0);
            let out = self.backend.chunk_vjp(&inputs, &g_zero)?;
            accumulate(&mut grads, &out.d_params);
            loss_sum += out.loss_sum;
            tok_sum += out.n_tok;
            act_peak = act_peak.max(1);
        }
        Ok(GradAccum {
            loss_sum,
            tok_sum,
            grads,
            chunks: set.chunks.len(),
            kv_peak_bytes: kv_peak,
            kv_resident_peak_bytes: kv_resident_peak,
            act_peak_chunks: act_peak,
        })
    }

    /// Token ids the trainer will use for a sequence (exposed for the
    /// oracle comparison in integration tests).
    pub fn sequence_tokens(&self, seq: &crate::data::Sequence) -> Vec<u32> {
        self.corpus.generate(seq.id, seq.len)
    }

    /// Scale the summed grads to mean-token loss, clip, Adam-update and
    /// re-send parameters; returns the pre-clip gradient norm. Shared by
    /// the single-stage and pipelined step paths.
    fn apply_update(&mut self, grads_raw: &[Vec<B::Elem>], tok_sum: f64) -> anyhow::Result<f64> {
        // Mean-token loss: scale the summed grads (f32 from here on — the
        // optimizer state is f32 on every backend).
        let inv = (1.0 / tok_sum) as f32;
        let mut grads: Vec<Vec<f32>> = grads_raw
            .iter()
            .map(|g| g.iter().map(|&x| x.to_f32() * inv).collect())
            .collect();
        let grad_norm = Adam::clip_global_norm(&mut grads, self.config.grad_clip);
        self.adam.update(&mut self.params.0, &grads);
        self.backend.set_params(&self.params)?;
        Ok(grad_norm)
    }

    /// Run one optimizer step; returns its metrics.
    pub fn train_step(&mut self) -> anyhow::Result<StepMetrics> {
        let t0 = Instant::now();
        let calls0 = self.backend.calls();
        let batch = self.sampler.next_batch();
        let acc = self.compute_gradients(&batch)?;

        anyhow::ensure!(acc.tok_sum > 0.0, "no trainable tokens in batch");
        let grad_norm = self.apply_update(&acc.grads, acc.tok_sum)?;

        self.step += 1;
        let metrics = StepMetrics {
            step: self.step,
            loss_per_token: acc.loss_sum / acc.tok_sum,
            tokens: acc.tok_sum as u64,
            chunks: acc.chunks,
            backend_calls: self.backend.calls() - calls0,
            seconds: t0.elapsed().as_secs_f64(),
            grad_norm,
            kv_peak_bytes: acc.kv_peak_bytes,
            act_peak_chunks: acc.act_peak_chunks,
            stages: 1,
            dp: 1,
            sp: self.sp,
            dp_imbalance: None,
            measured_bubble_ratio: None,
            predicted_bubble_ratio: None,
            partition: None,
            policy: None,
            fast_path: self.backend.fast_path_active(),
            retries: 0,
        };
        crate::info!(
            "step {:>4} | loss/tok {:.4} | tokens {:>6} | chunks {:>3} | {:>5.2}s | gnorm {:.3}",
            metrics.step,
            metrics.loss_per_token,
            metrics.tokens,
            metrics.chunks,
            metrics.seconds,
            metrics.grad_norm
        );
        self.history.push(metrics.clone());
        Ok(metrics)
    }

    /// Algorithm 2 over one dependent-chunk group, driven by the
    /// `schedule::` plan for the configured retention budget K (see
    /// DESIGN.md §Chunked-Backward and the module docs). The retained-KV
    /// backing is injected so the same path runs in-memory or budgeted
    /// (`KvBacking`).
    fn run_group<S: KvBacking<B::Elem>>(
        &self,
        group: &[&Chunk],
        tokens: &BTreeMap<u64, Vec<u32>>,
        seq_len: &BTreeMap<u64, u64>,
        grads: &mut [Vec<B::Elem>],
        store: &mut S,
        act_peak: &mut usize,
    ) -> anyhow::Result<(f64, f64)> {
        let c = self.backend.manifest().chunk_size;
        let kv_unit_bytes = self.backend.kv_elements(c) as u64 * B::Elem::BYTES;
        let n = group.len();
        let seq_id = match group[0].kind {
            ChunkKind::Dependent { seq_id, .. } => seq_id,
            _ => anyhow::bail!("not a dependent group"),
        };
        let k = (self.config.chunkflow.k.max(1)) as usize;

        // Build and re-validate the Algorithm-2 plan; its peak live count
        // is the activation high-water mark this group will ever need.
        let positions: Vec<usize> = (0..n).collect();
        let plan = schedule_group(&positions, k);
        let stats = validate_group_plan(&plan)
            .map_err(|e| anyhow::anyhow!("invalid Algorithm-2 plan (N={n}, K={k}): {e}"))?;
        *act_peak = (*act_peak).max(stats.peak_live_activations);

        let kv_elems = self.backend.kv_elements(c);
        let mut g_kv: Vec<Vec<B::Elem>> =
            (0..n).map(|_| vec![B::Elem::ZERO; kv_elems]).collect();
        let mut loss = 0.0f64;
        let mut toks = 0.0f64;
        let hd = self.backend.manifest().num_heads * self.backend.manifest().head_dim;
        let num_layers = self.backend.manifest().num_layers;
        for op in &plan.ops {
            match *op {
                ChunkOp::Forward { chunk: i, .. } => {
                    // The final chunk's KV is never consumed as a prefix, but
                    // its forward still runs and its KV is still stored: the
                    // store deliberately accounts the whole sequence's KV
                    // (the paper's Table-5 "KV state ~ context" component).
                    let prefix = i * c;
                    let kv_in = store.prefix(seq_id, i, num_layers, c, hd)?;
                    let inputs = self.chunk_inputs(group[i], tokens, seq_len, prefix);
                    let inputs = ChunkInputs { kv_in, ..inputs };
                    let out = self.backend.fwd_kv(&inputs)?;
                    store.store(StateKey { seq_id, chunk_index: i }, out.kv_own, kv_unit_bytes)?;
                }
                // The three-program contract fuses the recompute-forward
                // into `chunk_vjp`; the plan op only gates the budget.
                ChunkOp::RecomputeForward { .. } => {}
                ChunkOp::Backward { chunk: i } => {
                    let prefix = i * c;
                    // On the offload backing this restores any spilled
                    // prefix KV just in time for the fused recompute.
                    let kv_in = store.prefix(seq_id, i, num_layers, c, hd)?;
                    let inputs = self.chunk_inputs(group[i], tokens, seq_len, prefix);
                    let total_len = group[i].total_len() as usize;
                    let shards =
                        self.sp.max(1).min(total_len.max(1) as u64) as usize;
                    if shards <= 1 {
                        let inputs = ChunkInputs { kv_in, ..inputs };
                        let out = self.backend.chunk_vjp(&inputs, &g_kv[i])?;
                        accumulate(grads, &out.d_params);
                        loss += out.loss_sum;
                        toks += out.n_tok;
                        // Scatter d_kv_in ([L, 2, prefix, H, D]) into earlier
                        // chunks' pending gradients ([L, 2, C, H, D] each).
                        scatter_kv_grad(&out.d_kv_in, &mut g_kv[..i], num_layers, prefix, c, hd);
                    } else {
                        // Chunk-aware SP: shard the backward's query rows.
                        // Shard s owns live rows [lo, hi): its inputs keep
                        // rows [0, hi) verbatim (causality — those rows'
                        // activations are what the unsharded call computes
                        // for them) with loss masked to the owned rows, and
                        // its KV cotangent is the owned rows' slice of
                        // g_kv[i]. Loss rows and cotangent rows partition
                        // across shards, so the ascending-order sum equals
                        // the unsharded call up to float re-association.
                        let rows = total_len.div_ceil(shards);
                        for s in 0..shards {
                            let lo = s * rows;
                            let hi = ((s + 1) * rows).min(total_len);
                            let mut si = sp_shard_inputs(&inputs, total_len, lo, hi);
                            si.kv_in = kv_in.clone();
                            let g_own = sp_shard_g_kv(&g_kv[i], num_layers, c, hd, lo, hi);
                            let out = self.backend.chunk_vjp(&si, &g_own)?;
                            accumulate(grads, &out.d_params);
                            loss += out.loss_sum;
                            toks += out.n_tok;
                            scatter_kv_grad(
                                &out.d_kv_in,
                                &mut g_kv[..i],
                                num_layers,
                                prefix,
                                c,
                                hd,
                            );
                        }
                    }
                }
            }
        }
        Ok((loss, toks))
    }

    /// Build fixed-shape chunk inputs from a chunk's segments (L3 input
    /// conventions documented in python/compile/model.py).
    fn chunk_inputs(
        &self,
        chunk: &Chunk,
        tokens: &BTreeMap<u64, Vec<u32>>,
        seq_len: &BTreeMap<u64, u64>,
        prefix: usize,
    ) -> ChunkInputs<B::Elem> {
        chunk_inputs_for(chunk, self.backend.manifest().chunk_size, tokens, seq_len, prefix)
    }

    /// Run the configured number of steps.
    pub fn train(&mut self) -> anyhow::Result<()> {
        for _ in 0..self.config.steps {
            self.train_step()?;
        }
        Ok(())
    }

    /// Save parameters + step counter + Adam state. No topology provenance
    /// is recorded here — this ad-hoc save path has no [`TrainMode`] in
    /// hand; the recovery loop ([`Trainer::train_with_recovery`]) records it.
    pub fn save_checkpoint(&self, path: &std::path::Path) -> anyhow::Result<()> {
        checkpoint::save(path, &self.params, self.step, Some(&self.adam.export_state()), None)
    }

    /// Restore parameters, step counter, Adam moments (when the checkpoint
    /// carries them; v1 checkpoints restart the optimizer), and the data
    /// pipeline: batches are deterministic given the seed, so replaying
    /// `step` draws puts the sampler exactly where it was at save time —
    /// continuation is bit-identical to the uninterrupted run.
    pub fn load_checkpoint(&mut self, path: &std::path::Path) -> anyhow::Result<()> {
        let state = checkpoint::load(path)?;
        self.apply_checkpoint_state(state)
    }

    /// Install an already-loaded checkpoint (see [`Trainer::load_checkpoint`];
    /// split out so `--resume` can apply whatever generation
    /// [`checkpoint::latest_valid`] found).
    pub fn apply_checkpoint_state(&mut self, state: checkpoint::TrainState) -> anyhow::Result<()> {
        anyhow::ensure!(
            state.params.0.len() == self.params.0.len(),
            "checkpoint param arity mismatch"
        );
        for (have, want) in state.params.0.iter().zip(self.backend.manifest().params.iter()) {
            anyhow::ensure!(
                have.len() == want.size,
                "checkpoint param size {} != manifest {} for `{}`",
                have.len(),
                want.size,
                want.name
            );
        }
        self.params = state.params;
        self.step = state.step;
        // Restoring an earlier checkpoint into a used trainer must not leave
        // future-step metrics behind in the history.
        self.history.retain(|m| m.step <= state.step);
        match state.adam {
            Some(st) => self.adam.import_state(st)?,
            None => self.adam = fresh_adam(&self.config, self.backend.manifest()),
        }
        let mut sampler = BatchSampler::new(
            self.dist.clone(),
            self.config.context_length,
            self.config.global_batch_size as usize,
            self.config.seed,
        );
        for _ in 0..self.step {
            let _ = sampler.next_batch();
        }
        self.sampler = sampler;
        self.backend.set_params(&self.params)
    }

    pub fn loss_history_json(&self) -> Json {
        Json::Arr(
            self.history
                .iter()
                .map(|m| {
                    let mut fields = vec![
                        ("step", Json::num(m.step as f64)),
                        ("loss_per_token", Json::num(m.loss_per_token)),
                        ("tokens", Json::num(m.tokens as f64)),
                        ("chunks", Json::num(m.chunks as f64)),
                        ("backend_calls", Json::num(m.backend_calls as f64)),
                        ("seconds", Json::num(m.seconds)),
                        ("grad_norm", Json::num(m.grad_norm)),
                        ("kv_peak_bytes", Json::num(m.kv_peak_bytes as f64)),
                        ("act_peak_chunks", Json::num(m.act_peak_chunks as f64)),
                        ("stages", Json::num(m.stages as f64)),
                        ("dp", Json::num(m.dp as f64)),
                        ("fast_path", Json::Bool(m.fast_path)),
                        ("retries", Json::num(m.retries as f64)),
                    ];
                    if m.sp > 1 {
                        fields.push(("sp", Json::num(m.sp as f64)));
                    }
                    if let Some(i) = m.dp_imbalance {
                        fields.push(("dp_imbalance", Json::num(i)));
                    }
                    if let Some(b) = m.measured_bubble_ratio {
                        fields.push(("measured_bubble_ratio", Json::num(b)));
                    }
                    if let Some(b) = m.predicted_bubble_ratio {
                        fields.push(("predicted_bubble_ratio", Json::num(b)));
                    }
                    if let Some(p) = &m.partition {
                        fields.push(("partition", Json::str(p.clone())));
                    }
                    if let Some(p) = &m.policy {
                        fields.push(("policy", Json::str(p.clone())));
                    }
                    Json::obj(fields)
                })
                .collect(),
        )
    }
}

/// Executor-vs-simulator statistics for one pipelined step.
#[derive(Clone, Copy, Debug)]
pub struct PipelineStepReport {
    pub stages: usize,
    /// Wall-clock bubble ratio measured by `pipeline::exec`.
    pub measured_bubble_ratio: f64,
    /// Bubble ratio `pipeline::simulate` predicts for the same chunk set
    /// under token-proportional costs (fwd = len, bwd = 2·len, §3).
    pub predicted_bubble_ratio: f64,
    pub act_peak_chunks: usize,
    pub kv_peak_bytes: u64,
    /// Supervisor retries the micro-step needed (0 when fault-free).
    pub retries: u32,
}

impl Trainer<ReferenceBackend> {
    /// The simulator's prediction for one pipelined chunk set under the
    /// configured (partition, policy). The equal-partition default-policy
    /// path is the exact pre-elastic `simulate_state_aware` call (bit
    /// identity); an uneven partition scales each stage's
    /// token-proportional cost by its layer share relative to the equal
    /// split, and a non-default policy simulates that policy's agendas —
    /// the same agendas the executor runs.
    fn predicted_timeline(
        &self,
        set: &ChunkSet,
        k: usize,
        stages: usize,
    ) -> anyhow::Result<crate::pipeline::Timeline> {
        let default_path = self.policy == PolicyKind::default()
            && self.partition.as_ref().map_or(true, |p| p.is_equal());
        if default_path {
            return crate::pipeline::onef1b::simulate_state_aware(set, k, stages, |id| {
                let len = set.chunks[id].total_len() as f64;
                crate::pipeline::OpCosts { fwd: len, bwd: 2.0 * len }
            });
        }
        let num_layers = self.backend.manifest().num_layers;
        let part = match &self.partition {
            Some(p) => p.clone(),
            None => StagePartition::equal(num_layers, stages)?,
        };
        anyhow::ensure!(
            part.num_stages() == stages,
            "partition `{}` has {} stages but the pipeline runs {stages}",
            part.describe(),
            part.num_stages()
        );
        let scale: Vec<f64> = (0..stages)
            .map(|s| stages as f64 * part.range(s).len() as f64 / num_layers as f64)
            .collect();
        crate::pipeline::simulate_policy(self.policy, set, k, stages, |s, id| {
            let len = set.chunks[id].total_len() as f64;
            crate::pipeline::OpCosts { fwd: len * scale[s], bwd: 2.0 * len * scale[s] }
        })
    }

    /// Gradient accumulation over one batch through the stage-parallel
    /// pipeline executor: Algorithm 1 chunks the batch, the state-aware
    /// 1F1B agendas schedule it, and `pipeline::exec` runs those agendas
    /// for real on `stages` layer-partitioned threads. Gradients match
    /// [`Trainer::compute_gradients`] up to float re-association (the
    /// accumulation order differs; everything is f64, so the difference is
    /// far below the suites' 1e-6 gate).
    pub fn compute_gradients_pipelined(
        &self,
        batch: &[crate::data::Sequence],
        stages: usize,
    ) -> anyhow::Result<(GradAccum<f64>, PipelineStepReport)> {
        anyhow::ensure!(stages >= 1, "need at least one pipeline stage");
        let (set, tokens, seq_len) = self.prepare_batch(batch);
        let k = (self.config.chunkflow.k.max(1)) as usize;
        let orig_chunks = set.chunks.len();

        // Under `--sp`, long chunks expand into shard items (see
        // `pipeline::build_exec_items_sp`); the executor and the simulator
        // both run the expanded set. sp=1 takes the pre-SP builder verbatim.
        let (set, items) = if self.sp > 1 {
            crate::pipeline::build_exec_items_sp(&self.backend, &set, &tokens, &seq_len, self.sp)
        } else {
            let items =
                crate::pipeline::build_exec_items(&self.backend, &set, &tokens, &seq_len);
            (set, items)
        };
        let (out, retries) = crate::pipeline::execute_state_aware_supervised(
            &self.backend,
            &set,
            &items,
            k,
            stages,
            self.exec_options(),
            &self.retry,
        )?;
        // The simulator's prediction for the exact same chunk set and
        // schedule, under the paper's cost assumptions.
        let predicted = self.predicted_timeline(&set, k, stages)?;
        let report = PipelineStepReport {
            stages,
            measured_bubble_ratio: out.timeline.bubble_ratio(),
            predicted_bubble_ratio: predicted.bubble_ratio(),
            act_peak_chunks: out.act_peak_chunks,
            kv_peak_bytes: out.kv_peak_bytes,
            retries,
        };
        let acc = GradAccum {
            loss_sum: out.loss_sum,
            tok_sum: out.tok_sum,
            grads: out.grads,
            chunks: orig_chunks,
            kv_peak_bytes: out.kv_peak_bytes,
            kv_resident_peak_bytes: out.kv_peak_bytes,
            act_peak_chunks: out.act_peak_chunks,
        };
        Ok((acc, report))
    }

    /// One optimizer step through the pipeline executor (`--stages P`).
    pub fn train_step_pipelined(&mut self, stages: usize) -> anyhow::Result<StepMetrics> {
        let t0 = Instant::now();
        let calls0 = self.backend.calls();
        let batch = self.sampler.next_batch();
        let (acc, report) = self.compute_gradients_pipelined(&batch, stages)?;

        anyhow::ensure!(acc.tok_sum > 0.0, "no trainable tokens in batch");
        let grad_norm = self.apply_update(&acc.grads, acc.tok_sum)?;

        self.step += 1;
        let metrics = StepMetrics {
            step: self.step,
            loss_per_token: acc.loss_sum / acc.tok_sum,
            tokens: acc.tok_sum as u64,
            chunks: acc.chunks,
            backend_calls: self.backend.calls() - calls0,
            seconds: t0.elapsed().as_secs_f64(),
            grad_norm,
            kv_peak_bytes: acc.kv_peak_bytes,
            act_peak_chunks: acc.act_peak_chunks,
            stages,
            dp: 1,
            sp: self.sp,
            dp_imbalance: None,
            measured_bubble_ratio: Some(report.measured_bubble_ratio),
            predicted_bubble_ratio: Some(report.predicted_bubble_ratio),
            partition: self.partition_label(),
            policy: self.policy_label(),
            fast_path: self.backend.fast_path_active(),
            retries: report.retries as u64,
        };
        crate::info!(
            "step {:>4} | loss/tok {:.4} | stages {} | bubble {:>5.1}% measured / {:>5.1}% predicted | {:>5.2}s",
            metrics.step,
            metrics.loss_per_token,
            stages,
            100.0 * report.measured_bubble_ratio,
            100.0 * report.predicted_bubble_ratio,
            metrics.seconds
        );
        self.history.push(metrics.clone());
        Ok(metrics)
    }

    /// Run the configured number of steps in pipeline mode.
    pub fn train_pipelined(&mut self, stages: usize) -> anyhow::Result<()> {
        for _ in 0..self.config.steps {
            self.train_step_pipelined(stages)?;
        }
        Ok(())
    }

    /// One unit's gradient contribution (a dependent group or a standalone
    /// chunk), into a *fresh* buffer — a pure function of the unit, so any
    /// rank computes the identical bits.
    fn unit_gradients(
        &self,
        set: &ChunkSet,
        unit: &crate::sim::dp::DpUnit,
        tokens: &BTreeMap<u64, Vec<u32>>,
        seq_len: &BTreeMap<u64, u64>,
    ) -> anyhow::Result<UnitGrad> {
        let mut grads = self.backend.zero_grads();
        let mut act_peak = 0usize;
        if set.chunks[unit.chunk_ids[0]].is_dependent() {
            let group: Vec<&Chunk> =
                unit.chunk_ids.iter().map(|&i| &set.chunks[i]).collect();
            let mut store: StateStore<Vec<f64>> = StateStore::new();
            let (loss, toks) = self
                .run_group(&group, tokens, seq_len, &mut grads, &mut store, &mut act_peak)?;
            Ok(UnitGrad { grads, loss, toks, kv_peak: store.peak_bytes(), act_peak })
        } else {
            let c = self.backend.manifest().chunk_size;
            let g_zero = vec![0.0f64; self.backend.kv_elements(c)];
            let chunk = &set.chunks[unit.chunk_ids[0]];
            let inputs = self.chunk_inputs(chunk, tokens, seq_len, 0);
            let out = self.backend.chunk_vjp(&inputs, &g_zero)?;
            accumulate(&mut grads, &out.d_params);
            Ok(UnitGrad {
                grads,
                loss: out.loss_sum,
                toks: out.n_tok,
                kv_peak: 0,
                act_peak: 1,
            })
        }
    }

    /// Gradient accumulation over one batch across `dp` data-parallel
    /// replica groups (the tentpole's execution path).
    ///
    /// The chunk-balanced assignment (`sim::dp::assign_chunks`) maps whole
    /// units — dependent groups and standalone chunks — to ranks, so KV
    /// state never crosses a rank. Two execution modes:
    ///
    /// - `stages == 1`: each rank computes an independent gradient buffer
    ///   *per unit*; the reduction then re-folds unit contributions in
    ///   global unit order. The fold is invariant to how units were dealt
    ///   to ranks, so gradients are **bit-identical for every dp** — the
    ///   conformance property `tests/integration_dp.rs` pins.
    /// - `stages > 1`: R replica groups of the stage-parallel executor run
    ///   concurrently (`pipeline::execute_replica_groups`), each over its
    ///   rank-local chunk set; rank partials are combined by a
    ///   deterministic fixed-order tree sum in rank order. Reduction at
    ///   rank granularity re-associates float adds, so this mode is gated
    ///   (like the executor itself) at 1e-6 against the unchunked oracle.
    ///
    /// The offload budget is a single-replica feature and is ignored here
    /// (the CLI rejects the combination).
    pub fn compute_gradients_dp(
        &self,
        batch: &[crate::data::Sequence],
        dp: usize,
        stages: usize,
    ) -> anyhow::Result<(GradAccum<f64>, DpStepReport)> {
        anyhow::ensure!(dp >= 1, "need at least one data-parallel rank");
        anyhow::ensure!(stages >= 1, "need at least one pipeline stage");
        let (set, tokens, seq_len) = self.prepare_batch(batch);
        let k = (self.config.chunkflow.k.max(1)) as usize;
        let assign =
            crate::sim::dp::assign_chunks(&set, dp, crate::sim::dp::DpPolicy::ChunkBalanced);

        if stages == 1 {
            // Rank threads stream each unit's gradient buffer to the
            // coordinator as soon as it's done; the coordinator folds
            // strictly in global unit order (dp-invariant bits), buffering
            // only units that arrive out of order — peak memory is the
            // pending set, not one buffer per unit.
            let n_units = assign.units.len();
            // Supervised: a rank-thread panic (or poisoned send) surfaces
            // as an error here, the scope has already joined every thread,
            // and the whole micro-step reruns from pristine inputs — unit
            // gradients are pure functions, so the retry is bit-identical.
            let (folded, retries) = crate::pipeline::supervise(
                "dp unit executor",
                &self.retry,
                || {
                    std::thread::scope(|scope| {
                    let (assign, set, tokens, seq_len) = (&assign, &set, &tokens, &seq_len);
                    let (tx, rx) = std::sync::mpsc::channel::<(usize, UnitGrad)>();
                    let mut handles = Vec::with_capacity(dp);
                    for r in 0..dp {
                        let tx = tx.clone();
                        handles.push(scope.spawn(move || -> anyhow::Result<()> {
                            for u in assign.rank_units(r) {
                                let g = self.unit_gradients(
                                    set,
                                    &assign.units[u],
                                    tokens,
                                    seq_len,
                                )?;
                                if tx.send((u, g)).is_err() {
                                    break; // coordinator gone; its error wins
                                }
                            }
                            Ok(())
                        }));
                    }
                    drop(tx);
                    let mut pending: BTreeMap<usize, UnitGrad> = BTreeMap::new();
                    let mut next = 0usize;
                    let mut grads = self.backend.zero_grads();
                    let (mut loss_sum, mut tok_sum) = (0.0f64, 0.0f64);
                    let (mut kv_peak, mut act_peak) = (0u64, 0usize);
                    for (u, g) in rx {
                        pending.insert(u, g);
                        while let Some(g) = pending.remove(&next) {
                            accumulate(&mut grads, &g.grads);
                            loss_sum += g.loss;
                            tok_sum += g.toks;
                            kv_peak = kv_peak.max(g.kv_peak);
                            act_peak = act_peak.max(g.act_peak);
                            next += 1;
                        }
                    }
                    for (r, h) in handles.into_iter().enumerate() {
                        h.join()
                            .unwrap_or_else(|_| {
                                Err(anyhow::anyhow!("dp rank thread panicked"))
                            })
                            .map_err(|e| e.context(format!("dp rank {r}")))?;
                    }
                    anyhow::ensure!(next == n_units, "unit assigned to no rank");
                    Ok((grads, loss_sum, tok_sum, kv_peak, act_peak))
                    })
                },
            )?;
            let (grads, loss_sum, tok_sum, kv_peak, act_peak) = folded;
            let acc = GradAccum {
                loss_sum,
                tok_sum,
                grads,
                chunks: set.chunks.len(),
                kv_peak_bytes: kv_peak,
                kv_resident_peak_bytes: kv_peak,
                act_peak_chunks: act_peak,
            };
            let report = DpStepReport {
                dp,
                stages,
                dp_imbalance: assign.imbalance(),
                measured_bubble_ratio: None,
                predicted_bubble_ratio: None,
                retries,
            };
            return Ok((acc, report));
        }

        // stages > 1: replica groups of the pipeline executor.
        let replicas: Vec<crate::pipeline::ReplicaSpec> = (0..dp)
            .map(|r| {
                let rank_set = assign.rank_chunk_set(&set, r);
                if self.sp > 1 {
                    let (rank_set, items) = crate::pipeline::build_exec_items_sp(
                        &self.backend,
                        &rank_set,
                        &tokens,
                        &seq_len,
                        self.sp,
                    );
                    crate::pipeline::ReplicaSpec { set: rank_set, items }
                } else {
                    let items = crate::pipeline::build_exec_items(
                        &self.backend,
                        &rank_set,
                        &tokens,
                        &seq_len,
                    );
                    crate::pipeline::ReplicaSpec { set: rank_set, items }
                }
            })
            .collect();
        let (outcomes, retries) = crate::pipeline::execute_replica_groups_supervised(
            &self.backend,
            &replicas,
            k,
            stages,
            self.exec_options(),
            &self.retry,
        )?;
        let (mut loss_sum, mut tok_sum) = (0.0f64, 0.0f64);
        let (mut kv_peak, mut act_peak) = (0u64, 0usize);
        let (mut measured, mut predicted) = (0.0f64, 0.0f64);
        let mut partials: Vec<Vec<Vec<f64>>> = Vec::with_capacity(dp);
        for (r, out) in outcomes.into_iter().enumerate() {
            loss_sum += out.loss_sum;
            tok_sum += out.tok_sum;
            kv_peak = kv_peak.max(out.kv_peak_bytes);
            act_peak = act_peak.max(out.act_peak_chunks);
            measured = measured.max(out.timeline.bubble_ratio());
            let pred = self.predicted_timeline(&replicas[r].set, k, stages)?;
            predicted = predicted.max(pred.bubble_ratio());
            partials.push(out.grads);
        }
        let grads = tree_reduce_grads(partials);
        let acc = GradAccum {
            loss_sum,
            tok_sum,
            grads,
            chunks: set.chunks.len(),
            kv_peak_bytes: kv_peak,
            kv_resident_peak_bytes: kv_peak,
            act_peak_chunks: act_peak,
        };
        let report = DpStepReport {
            dp,
            stages,
            dp_imbalance: assign.imbalance(),
            measured_bubble_ratio: Some(measured),
            predicted_bubble_ratio: Some(predicted),
            retries,
        };
        Ok((acc, report))
    }

    /// One optimizer step across `dp` replica groups (`--dp R --stages P`).
    pub fn train_step_dp(&mut self, dp: usize, stages: usize) -> anyhow::Result<StepMetrics> {
        let t0 = Instant::now();
        let calls0 = self.backend.calls();
        let batch = self.sampler.next_batch();
        let (acc, report) = self.compute_gradients_dp(&batch, dp, stages)?;

        anyhow::ensure!(acc.tok_sum > 0.0, "no trainable tokens in batch");
        let grad_norm = self.apply_update(&acc.grads, acc.tok_sum)?;

        self.step += 1;
        let metrics = StepMetrics {
            step: self.step,
            loss_per_token: acc.loss_sum / acc.tok_sum,
            tokens: acc.tok_sum as u64,
            chunks: acc.chunks,
            backend_calls: self.backend.calls() - calls0,
            seconds: t0.elapsed().as_secs_f64(),
            grad_norm,
            kv_peak_bytes: acc.kv_peak_bytes,
            act_peak_chunks: acc.act_peak_chunks,
            stages,
            dp,
            sp: self.sp,
            dp_imbalance: Some(report.dp_imbalance),
            measured_bubble_ratio: report.measured_bubble_ratio,
            predicted_bubble_ratio: report.predicted_bubble_ratio,
            partition: self.partition_label(),
            policy: self.policy_label(),
            fast_path: self.backend.fast_path_active(),
            retries: report.retries as u64,
        };
        crate::info!(
            "step {:>4} | loss/tok {:.4} | dp {} x stages {} | imbalance {:.3} | {:>5.2}s | gnorm {:.3}",
            metrics.step,
            metrics.loss_per_token,
            dp,
            stages,
            report.dp_imbalance,
            metrics.seconds,
            metrics.grad_norm
        );
        self.history.push(metrics.clone());
        Ok(metrics)
    }

    /// Run the configured number of steps across `dp` replica groups.
    pub fn train_dp(&mut self, dp: usize, stages: usize) -> anyhow::Result<()> {
        for _ in 0..self.config.steps {
            self.train_step_dp(dp, stages)?;
        }
        Ok(())
    }

    /// The [`crate::config::ParallelConfig`] a [`TrainMode`] plus the
    /// configured `--sp` degree describe — recorded into checkpoints as
    /// provenance and validated against it on `--resume`. The reference
    /// trainer has no tensor parallelism and its recompute behavior is
    /// fixed by Algorithm 2, so `tp`/`recompute` are the defaults; only
    /// `dp`/`pp`/`sp` vary with the CLI flags (and only they are compared).
    fn topology_for(&self, mode: TrainMode) -> crate::config::ParallelConfig {
        let (dp, stages) = match mode {
            TrainMode::Single => (1, 1),
            TrainMode::Pipelined { stages } => (1, stages),
            TrainMode::Dp { dp, stages } => (dp, stages),
        };
        let mut p = crate::config::ParallelConfig::new(
            1,
            stages as u64,
            crate::config::RecomputeGranularity::Selective,
        );
        p.dp = dp as u64;
        p.sp = self.sp;
        p
    }

    /// Run training in `mode`, checkpointing on the `ckpt` cadence and —
    /// when `resume` is set — first restoring the newest *valid* generation
    /// in `ckpt.dir` (corrupt or torn files are skipped; see
    /// [`checkpoint::latest_valid`]). Steps already covered by the restored
    /// checkpoint are not re-run; because batches, optimizer state, and the
    /// executor are all deterministic, the resumed run's parameters are
    /// bit-identical to an uninterrupted run of the same config.
    pub fn train_with_recovery(
        &mut self,
        mode: TrainMode,
        ckpt: Option<&CheckpointPolicy>,
        resume: bool,
    ) -> anyhow::Result<()> {
        let topology = self.topology_for(mode);
        if resume {
            let policy = ckpt.ok_or_else(|| {
                anyhow::anyhow!("--resume needs a checkpoint directory to resume from")
            })?;
            match checkpoint::latest_valid(&policy.dir)? {
                Some((path, state)) => {
                    // Fail fast on a topology change: the checkpoint records
                    // the `ParallelConfig` it was written under, and resuming
                    // under different --dp/--stages/--sp would silently
                    // change the training trajectory. Pre-provenance
                    // checkpoints (no `parallel` header) skip the check.
                    if let Some(prev) = &state.parallel {
                        anyhow::ensure!(
                            prev.dp == topology.dp
                                && prev.pp == topology.pp
                                && prev.sp == topology.sp,
                            "checkpoint {} was written under --dp {} --stages {} --sp {}, \
                             but this run is --dp {} --stages {} --sp {}; rerun with the \
                             matching flags (or point --checkpoint-dir at a fresh \
                             directory) instead of resuming under a different topology",
                            path.display(),
                            prev.dp,
                            prev.pp,
                            prev.sp,
                            topology.dp,
                            topology.pp,
                            topology.sp
                        );
                    }
                    crate::info!("resuming from {} (step {})", path.display(), state.step);
                    self.apply_checkpoint_state(state)?;
                }
                None => {
                    crate::info!(
                        "no valid checkpoint under {}; starting from scratch",
                        policy.dir.display()
                    );
                }
            }
        }
        let total = self.config.steps;
        while self.step < total {
            match mode {
                TrainMode::Single => self.train_step()?,
                TrainMode::Pipelined { stages } => self.train_step_pipelined(stages)?,
                TrainMode::Dp { dp, stages } => self.train_step_dp(dp, stages)?,
            };
            if let Some(policy) = ckpt {
                let due = policy.every > 0 && self.step % policy.every == 0;
                if due || self.step >= total {
                    let path = checkpoint::save_rotating(
                        &policy.dir,
                        &self.params,
                        self.step,
                        Some(&self.adam.export_state()),
                        Some(&topology),
                        policy.keep,
                    )?;
                    crate::info!("checkpointed step {} -> {}", self.step, path.display());
                }
            }
        }
        Ok(())
    }
}

/// Where and how often [`Trainer::train_with_recovery`] checkpoints.
#[derive(Clone, Debug)]
pub struct CheckpointPolicy {
    /// Directory holding rotating `step-*.ckpt` generations.
    pub dir: std::path::PathBuf,
    /// Checkpoint every N steps (0 = only at the end of training).
    pub every: u64,
    /// Generations to keep; older ones are pruned after each save.
    pub keep: usize,
}

/// Which step function [`Trainer::train_with_recovery`] drives.
#[derive(Clone, Copy, Debug)]
pub enum TrainMode {
    Single,
    Pipelined { stages: usize },
    Dp { dp: usize, stages: usize },
}

/// One unit's independent gradient contribution (see
/// [`Trainer::compute_gradients_dp`]).
struct UnitGrad {
    grads: Vec<Vec<f64>>,
    loss: f64,
    toks: f64,
    kv_peak: u64,
    act_peak: usize,
}

/// Replica-group statistics for one data-parallel step.
#[derive(Clone, Copy, Debug)]
pub struct DpStepReport {
    pub dp: usize,
    pub stages: usize,
    /// Max/mean token-load ratio of the chunk-balanced rank assignment.
    pub dp_imbalance: f64,
    /// Worst per-rank measured bubble ratio (stages > 1 only).
    pub measured_bubble_ratio: Option<f64>,
    /// Worst per-rank predicted bubble ratio (stages > 1 only).
    pub predicted_bubble_ratio: Option<f64>,
    /// Supervisor retries the micro-step needed (0 when fault-free).
    pub retries: u32,
}

/// Deterministic fixed-order gradient all-reduce: a binary tree sum in rank
/// order (rank r absorbs rank r + stride for stride = 1, 2, 4, ...). The
/// reduction shape depends only on the rank count, never on timing, so
/// replica runs are reproducible bit for bit.
fn tree_reduce_grads(mut partials: Vec<Vec<Vec<f64>>>) -> Vec<Vec<f64>> {
    assert!(!partials.is_empty(), "tree reduce needs at least one partial");
    let mut stride = 1;
    while stride < partials.len() {
        let mut i = 0;
        while i + stride < partials.len() {
            let right = std::mem::take(&mut partials[i + stride]);
            let left = &mut partials[i];
            for (a, b) in left.iter_mut().zip(&right) {
                for (x, y) in a.iter_mut().zip(b) {
                    *x += *y;
                }
            }
            i += 2 * stride;
        }
        stride *= 2;
    }
    partials.swap_remove(0)
}

fn fresh_adam(config: &TrainConfig, manifest: &crate::runtime::Manifest) -> Adam {
    Adam::new(
        config.lr,
        config.adam_beta1,
        config.adam_beta2,
        config.adam_eps,
        config.weight_decay,
        &manifest.params.iter().map(|p| p.size).collect::<Vec<_>>(),
    )
}

/// Deterministic parameter init mirroring python's scheme closely enough for
/// training from scratch (scaled normals; ones for norm weights).
pub fn init_params(manifest: &crate::runtime::Manifest, seed: u64) -> FlatParams {
    let mut rng = Rng::new(seed ^ 0x1217);
    let mut out = Vec::with_capacity(manifest.params.len());
    for spec in &manifest.params {
        let is_norm = spec.name.starts_with("norm") || spec.name == "ln_f";
        let v: Vec<f32> = if is_norm {
            vec![1.0; spec.size]
        } else if spec.name == "embed" {
            (0..spec.size).map(|_| 0.02 * rng.next_normal() as f32).collect()
        } else {
            let fan_in = spec.shape[spec.shape.len() - 2] as f64;
            let scale = 1.0 / fan_in.sqrt();
            (0..spec.size).map(|_| (scale * rng.next_normal()) as f32).collect()
        };
        out.push(v);
    }
    FlatParams(out)
}

fn accumulate<E: Scalar>(acc: &mut [Vec<E>], delta: &[Vec<E>]) {
    for (a, d) in acc.iter_mut().zip(delta) {
        for (x, y) in a.iter_mut().zip(d) {
            *x += *y;
        }
    }
}

/// Build fixed-shape chunk inputs from a chunk's segments (L3 input
/// conventions documented in python/compile/model.py): padding slots get
/// unique large positions (1_000_000+i) and segment -1; targets cross chunk
/// boundaries within a sequence. Free function so the pipeline executor
/// (`pipeline::exec`) shares the trainer's exact assembly.
pub fn chunk_inputs_for<E>(
    chunk: &Chunk,
    chunk_size: usize,
    tokens: &BTreeMap<u64, Vec<u32>>,
    seq_len: &BTreeMap<u64, u64>,
    prefix: usize,
) -> ChunkInputs<E> {
    let c = chunk_size;
    let mut toks = vec![0i32; c];
    let mut targets = vec![-1i32; c];
    let mut pos = vec![0i32; c];
    let mut seg = vec![-1i32; c];
    let mut slot = 0usize;
    for (seg_idx, s) in chunk.segments.iter().enumerate() {
        let data = &tokens[&s.seq_id];
        let total = seq_len[&s.seq_id] as usize;
        for j in 0..s.len as usize {
            let gp = s.offset as usize + j;
            toks[slot] = data[gp] as i32;
            targets[slot] = if gp + 1 < total { data[gp + 1] as i32 } else { -1 };
            pos[slot] = gp as i32;
            seg[slot] = seg_idx as i32;
            slot += 1;
        }
    }
    // Padding convention: unique large positions, segment -1.
    for (i, sl) in (slot..c).enumerate() {
        pos[sl] = 1_000_000 + i as i32;
    }
    ChunkInputs { tokens: toks, targets, pos, seg, kv_in: Vec::new(), prefix_len: prefix }
}

/// One SP shard's view of a chunk backward: live rows `[0, hi)` are kept
/// verbatim (causal attention means the backend computes the exact same
/// activations for them as the unsharded call), loss is masked to the owned
/// rows `[lo, hi)`, and — on non-last shards — rows beyond `hi` are
/// re-padded exactly like [`chunk_inputs_for`] pads a partial chunk, so the
/// shard is a valid fixed-shape chunk whose live extent is `[0, hi)`.
/// `kv_in` is left empty for the caller to attach (the prefix is shared by
/// every shard — the "ring" all shards read around).
pub fn sp_shard_inputs<E>(
    full: &ChunkInputs<E>,
    total_len: usize,
    lo: usize,
    hi: usize,
) -> ChunkInputs<E> {
    let c = full.tokens.len();
    debug_assert!(lo < hi && hi <= total_len && total_len <= c);
    let mut tokens = full.tokens.clone();
    let mut targets = full.targets.clone();
    let mut pos = full.pos.clone();
    let mut seg = full.seg.clone();
    for t in targets[..lo].iter_mut() {
        *t = -1;
    }
    for t in targets[hi..].iter_mut() {
        *t = -1;
    }
    if hi < total_len {
        for (j, sl) in (hi..c).enumerate() {
            tokens[sl] = 0;
            pos[sl] = 1_000_000 + j as i32;
            seg[sl] = -1;
        }
    }
    ChunkInputs { tokens, targets, pos, seg, kv_in: Vec::new(), prefix_len: full.prefix_len }
}

/// One SP shard's slice of a chunk's pending KV cotangent: rows `[lo, hi)`
/// of every `[L, 2, C, H, D]` block kept, everything else zero — each shard
/// owns its rows' cotangent, so the shards' `<g_own, kv_own>` terms
/// partition the unsharded one.
pub fn sp_shard_g_kv<E: Scalar>(
    g_kv: &[E],
    num_layers: usize,
    chunk: usize,
    hd: usize,
    lo: usize,
    hi: usize,
) -> Vec<E> {
    let block = chunk * hd;
    let l2 = num_layers * 2;
    debug_assert_eq!(g_kv.len(), l2 * block);
    debug_assert!(lo <= hi && hi <= chunk);
    let mut out = vec![E::ZERO; g_kv.len()];
    for b in 0..l2 {
        let off = b * block;
        out[off + lo * hd..off + hi * hd]
            .copy_from_slice(&g_kv[off + lo * hd..off + hi * hd]);
    }
    out
}

/// Layout-aware prefix concat: interleaves per-chunk [L, 2, C, H, D] blocks
/// into [L, 2, upto*C, H, D].
pub fn concat_prefix_with<E: Scalar>(
    parts: &[&Vec<E>],
    num_layers: usize,
    chunk: usize,
    hd: usize,
) -> Vec<E> {
    let upto = parts.len();
    if upto == 0 {
        return Vec::new();
    }
    let mut out = vec![E::ZERO; num_layers * 2 * upto * chunk * hd];
    concat_prefix_into(parts, num_layers, chunk, hd, &mut out);
    out
}

/// [`concat_prefix_with`] into a caller-provided buffer of exactly
/// `L * 2 * parts.len() * C * H * D` elements — the allocation-free variant
/// the pipeline executor feeds from its per-stage [`crate::util::pool::BufferPool`].
pub fn concat_prefix_into<E: Scalar>(
    parts: &[&Vec<E>],
    num_layers: usize,
    chunk: usize,
    hd: usize,
    out: &mut [E],
) {
    let upto = parts.len();
    let block = chunk * hd; // C*H*D elements per (layer, k/v) pair
    let l2 = num_layers * 2;
    debug_assert!(parts.iter().all(|p| p.len() == l2 * block));
    debug_assert_eq!(out.len(), l2 * upto * block);
    for (ci, part) in parts.iter().enumerate() {
        for b in 0..l2 {
            let src = &part[b * block..(b + 1) * block];
            let dst_off = (b * upto + ci) * block;
            out[dst_off..dst_off + block].copy_from_slice(src);
        }
    }
}

/// Scatter `d_kv_in` ([L, 2, prefix, H, D]) into per-chunk pending gradients
/// ([L, 2, C, H, D] each, chunks 0..prefix/C).
pub fn scatter_kv_grad<E: Scalar>(
    d_kv_in: &[E],
    g_kv: &mut [Vec<E>],
    num_layers: usize,
    prefix: usize,
    chunk: usize,
    hd: usize,
) {
    if prefix == 0 {
        return;
    }
    let n_prev = prefix / chunk;
    debug_assert_eq!(n_prev, g_kv.len());
    let block = chunk * hd;
    let l2 = num_layers * 2;
    debug_assert_eq!(d_kv_in.len(), l2 * n_prev * block);
    for b in 0..l2 {
        for ci in 0..n_prev {
            let src_off = (b * n_prev + ci) * block;
            let dst_off = b * block;
            let dst = &mut g_kv[ci][dst_off..dst_off + block];
            let src = &d_kv_in[src_off..src_off + block];
            for (x, y) in dst.iter_mut().zip(src) {
                *x += *y;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn concat_prefix_interleaves_blocks() {
        // 1 layer, C=2, H*D=1: per-chunk = [L2=2][C*HD=2] = 4 elems.
        // part A = [a0 a1 | a2 a3] (K block | V block), part B likewise.
        let a = vec![1.0f32, 2.0, 3.0, 4.0];
        let b = vec![5.0f32, 6.0, 7.0, 8.0];
        let out = concat_prefix_with(&[&a, &b], 1, 2, 1);
        // Expected [L,2,4,1,1]: K = a0 a1 b0 b1, V = a2 a3 b2 b3.
        assert_eq!(out, vec![1.0, 2.0, 5.0, 6.0, 3.0, 4.0, 7.0, 8.0]);
    }

    #[test]
    fn concat_prefix_empty() {
        assert!(concat_prefix_with::<f32>(&[], 2, 4, 8).is_empty());
    }

    #[test]
    fn concat_prefix_generic_over_f64() {
        let a = vec![1.0f64, 2.0, 3.0, 4.0];
        let out = concat_prefix_with(&[&a], 1, 2, 1);
        assert_eq!(out, a);
    }

    #[test]
    fn scatter_is_inverse_of_concat() {
        // Scattering a gradient laid out like the concat result must route
        // each block back to its chunk.
        let d_kv: Vec<f32> = (0..8).map(|x| x as f32).collect(); // [1,2,4,1,1]
        let mut g = vec![vec![0.0f32; 4], vec![0.0f32; 4]];
        scatter_kv_grad(&d_kv, &mut g, 1, 4, 2, 1);
        assert_eq!(g[0], vec![0.0, 1.0, 4.0, 5.0]); // K a-slots + V a-slots
        assert_eq!(g[1], vec![2.0, 3.0, 6.0, 7.0]);
    }

    #[test]
    fn scatter_accumulates() {
        let d_kv = vec![1.0f32; 4]; // [1,2,2,1,1], one previous chunk (C=2)
        let mut g = vec![vec![1.0f32; 4]];
        scatter_kv_grad(&d_kv, &mut g, 1, 2, 2, 1);
        assert_eq!(g[0], vec![2.0; 4]);
        scatter_kv_grad(&d_kv, &mut g, 1, 2, 2, 1);
        assert_eq!(g[0], vec![3.0; 4]);
    }

    #[test]
    fn scatter_empty_prefix_noop() {
        let mut g: Vec<Vec<f32>> = vec![];
        scatter_kv_grad(&[], &mut g, 2, 0, 4, 8);
    }

    #[test]
    fn accumulate_adds_elementwise() {
        let mut acc = vec![vec![1.0f32, 2.0], vec![3.0f32]];
        accumulate(&mut acc, &[vec![0.5, 0.5], vec![-3.0]]);
        assert_eq!(acc, vec![vec![1.5, 2.5], vec![0.0]]);
    }

    #[test]
    fn init_params_deterministic_and_scaled() {
        use crate::runtime::{Manifest, ParamSpec};
        let man = Manifest {
            model_name: "t".into(),
            vocab_size: 16,
            hidden_size: 8,
            num_layers: 1,
            num_heads: 2,
            head_dim: 4,
            model_param_count: 0,
            chunk_size: 4,
            max_chunks: 1,
            kv_buckets: vec![0],
            full_step_lens: vec![],
            params: vec![
                ParamSpec { name: "embed".into(), shape: vec![16, 8], size: 128 },
                ParamSpec { name: "norm1".into(), shape: vec![1, 8], size: 8 },
                ParamSpec { name: "wq".into(), shape: vec![1, 8, 8], size: 64 },
            ],
        };
        let a = init_params(&man, 7);
        let b = init_params(&man, 7);
        for (x, y) in a.0.iter().zip(&b.0) {
            assert_eq!(x, y);
        }
        assert!(a.0[1].iter().all(|&v| v == 1.0), "norms init to one");
        let std: f32 = (a.0[2].iter().map(|v| v * v).sum::<f32>() / 64.0).sqrt();
        assert!((std - 1.0 / (8f32).sqrt()).abs() < 0.15, "wq std {std}");
    }
}
