//! The real ChunkFlow trainer: Algorithm 2 executed over any [`Backend`]
//! implementation, end to end in Rust.
//!
//! The trainer is generic over the three-program contract
//! (`runtime::Backend`): the PJRT runtime executes AOT-compiled XLA
//! programs, the pure-Rust [`ReferenceBackend`](crate::runtime::ReferenceBackend)
//! executes the same transformer with exact f64 gradients so training runs
//! (and is tested) on any machine.
//!
//! One optimizer step:
//! 1. sample a global batch of variable-length sequences (long-tail);
//! 2. Algorithm 1: reorganize into chunks (`chunk::construct_chunks`);
//! 3. for each dependent-chunk group, build the Algorithm-2 plan
//!    (`schedule::schedule_group` with the configured retention budget `K`)
//!    and execute it:
//!    - `Forward` ops run `fwd_kv` ascending, KV into the StateStore
//!      (activations are discarded by construction — each call retains
//!      nothing), losses recorded;
//!    - `Backward` ops run `chunk_vjp` descending (the program recomputes
//!      the forward internally — the realization of Alg. 2's "executed
//!      twice", so `RecomputeForward` ops carry no separate call);
//!      parameter grads accumulate, `d_kv_in` scatters into the pending
//!      `g_kv` of earlier chunks;
//!    the plan's peak live-activation count (`<= K` by construction,
//!    re-validated every step) is surfaced as `act_peak_chunks`;
//! 4. standalone chunks run a single `chunk_vjp` with an empty prefix;
//! 5. grads scaled by 1/total_tokens, clipped, Adam update, params re-sent.
//!
//! Peak memory is `O(K * ChunkSize)` activations inside the backend plus
//! the `O(context)` KV StateStore — exactly the paper's Table 5 shape; both
//! components are reported per step and CI-asserted by the integration
//! suites.

mod adam;
pub mod checkpoint;

pub use adam::{Adam, AdamState};

use std::collections::BTreeMap;
use std::path::Path;
use std::time::Instant;

use crate::chunk::{construct_chunks, Chunk, ChunkKind};
use crate::config::TrainConfig;
use crate::data::{BatchSampler, LengthDistribution, SyntheticCorpus};
use crate::runtime::{Backend, ChunkInputs, FlatParams, Runtime, Scalar};
use crate::schedule::{schedule_group, validate_group_plan, ChunkOp};
use crate::state::{StateKey, StateStore};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Per-step metrics.
#[derive(Clone, Debug)]
pub struct StepMetrics {
    pub step: u64,
    pub loss_per_token: f64,
    pub tokens: u64,
    pub chunks: usize,
    /// Backend program executions during the step.
    pub backend_calls: u64,
    pub seconds: f64,
    pub grad_norm: f64,
    /// Peak StateStore bytes during the step (KV state).
    pub kv_peak_bytes: u64,
    /// Peak retained-activation budget used across all Algorithm-2 plans
    /// this step, in chunks (never exceeds the configured K).
    pub act_peak_chunks: usize,
}

/// Result of gradient accumulation over one batch (`compute_gradients`).
#[derive(Clone, Debug)]
pub struct GradAccum<E> {
    pub loss_sum: f64,
    pub tok_sum: f64,
    /// Summed (unscaled) parameter gradients in the backend element type.
    pub grads: Vec<Vec<E>>,
    pub chunks: usize,
    /// Peak KV StateStore bytes across the batch's chunk groups.
    pub kv_peak_bytes: u64,
    /// Peak live-activation count across all group plans (<= K).
    pub act_peak_chunks: usize,
}

/// The trainer owns the backend, parameters, optimizer and data pipeline.
pub struct Trainer<B: Backend = Runtime> {
    pub backend: B,
    pub params: FlatParams,
    pub adam: Adam,
    pub config: TrainConfig,
    dist: LengthDistribution,
    sampler: BatchSampler,
    corpus: SyntheticCorpus,
    step: u64,
    pub history: Vec<StepMetrics>,
}

impl Trainer<Runtime> {
    /// Load the PJRT runtime from `config.artifacts_dir` (requires the
    /// `pjrt` cargo feature; use [`Trainer::with_backend`] with a
    /// [`crate::runtime::ReferenceBackend`] otherwise).
    pub fn new(config: TrainConfig, dist: LengthDistribution) -> anyhow::Result<Self> {
        let runtime = Runtime::load(Path::new(&config.artifacts_dir), &config.model.name)?;
        Self::with_backend(runtime, config, dist)
    }
}

impl<B: Backend> Trainer<B> {
    /// Build a trainer over an already-constructed backend.
    pub fn with_backend(
        mut backend: B,
        config: TrainConfig,
        dist: LengthDistribution,
    ) -> anyhow::Result<Self> {
        let c = backend.manifest().chunk_size as u64;
        let max_ctx = c * backend.manifest().max_chunks as u64;
        anyhow::ensure!(
            config.context_length <= max_ctx,
            "context {} exceeds backend coverage {max_ctx}",
            config.context_length
        );
        anyhow::ensure!(
            config.chunkflow.chunk_size == c,
            "configured ChunkSize {} != backend chunk size {c} (the backend's \
             compiled chunk shape is authoritative)",
            config.chunkflow.chunk_size
        );
        let params = init_params(backend.manifest(), config.seed);
        backend.set_params(&params)?;
        let adam = fresh_adam(&config, backend.manifest());
        let sampler = BatchSampler::new(
            dist.clone(),
            config.context_length,
            config.global_batch_size as usize,
            config.seed,
        );
        let corpus =
            SyntheticCorpus::new(backend.manifest().vocab_size as u32, config.seed ^ 0xDA7A);
        Ok(Self {
            backend,
            params,
            adam,
            config,
            dist,
            sampler,
            corpus,
            step: 0,
            history: Vec::new(),
        })
    }

    /// Gradient accumulation over one batch: Algorithm 1 + Algorithm 2 over
    /// the backend programs. Public so integration tests can compare
    /// against the unchunked `full_step` oracle.
    pub fn compute_gradients(
        &self,
        batch: &[crate::data::Sequence],
    ) -> anyhow::Result<GradAccum<B::Elem>> {
        let set = construct_chunks(batch, self.backend.manifest().chunk_size as u64);

        // Token cache for this step's sequences.
        let mut tokens: BTreeMap<u64, Vec<u32>> = BTreeMap::new();
        for s in batch {
            tokens.insert(s.id, self.corpus.generate(s.id, s.len));
        }
        let seq_len: BTreeMap<u64, u64> = batch.iter().map(|s| (s.id, s.len)).collect();

        let mut grads: Vec<Vec<B::Elem>> = self
            .backend
            .manifest()
            .params
            .iter()
            .map(|p| vec![B::Elem::ZERO; p.size])
            .collect();
        let mut loss_sum = 0.0f64;
        let mut tok_sum = 0.0f64;
        let mut kv_peak = 0u64;
        let mut act_peak = 0usize;

        // Dependent groups: Algorithm 2 under the configured K budget.
        for group in set.dependent_groups() {
            let (l, t) =
                self.run_group(&group, &tokens, &seq_len, &mut grads, &mut kv_peak, &mut act_peak)?;
            loss_sum += l;
            tok_sum += t;
        }
        // Standalone chunks: the N = 1 plan degenerates to a single vjp
        // with an empty prefix (one retained activation).
        let c = self.backend.manifest().chunk_size;
        let g_zero = vec![B::Elem::ZERO; self.backend.kv_elements(c)];
        for chunk in set.standalone_chunks() {
            let inputs = self.chunk_inputs(chunk, &tokens, &seq_len, 0);
            let out = self.backend.chunk_vjp(&inputs, &g_zero)?;
            accumulate(&mut grads, &out.d_params);
            loss_sum += out.loss_sum;
            tok_sum += out.n_tok;
            act_peak = act_peak.max(1);
        }
        Ok(GradAccum {
            loss_sum,
            tok_sum,
            grads,
            chunks: set.chunks.len(),
            kv_peak_bytes: kv_peak,
            act_peak_chunks: act_peak,
        })
    }

    /// Token ids the trainer will use for a sequence (exposed for the
    /// oracle comparison in integration tests).
    pub fn sequence_tokens(&self, seq: &crate::data::Sequence) -> Vec<u32> {
        self.corpus.generate(seq.id, seq.len)
    }

    /// Run one optimizer step; returns its metrics.
    pub fn train_step(&mut self) -> anyhow::Result<StepMetrics> {
        let t0 = Instant::now();
        let calls0 = self.backend.calls();
        let batch = self.sampler.next_batch();
        let acc = self.compute_gradients(&batch)?;

        anyhow::ensure!(acc.tok_sum > 0.0, "no trainable tokens in batch");
        // Mean-token loss: scale the summed grads (f32 from here on — the
        // optimizer state is f32 on every backend).
        let inv = (1.0 / acc.tok_sum) as f32;
        let mut grads: Vec<Vec<f32>> = acc
            .grads
            .iter()
            .map(|g| g.iter().map(|&x| x.to_f32() * inv).collect())
            .collect();
        let grad_norm = Adam::clip_global_norm(&mut grads, self.config.grad_clip);
        self.adam.update(&mut self.params.0, &grads);
        self.backend.set_params(&self.params)?;

        self.step += 1;
        let metrics = StepMetrics {
            step: self.step,
            loss_per_token: acc.loss_sum / acc.tok_sum,
            tokens: acc.tok_sum as u64,
            chunks: acc.chunks,
            backend_calls: self.backend.calls() - calls0,
            seconds: t0.elapsed().as_secs_f64(),
            grad_norm,
            kv_peak_bytes: acc.kv_peak_bytes,
            act_peak_chunks: acc.act_peak_chunks,
        };
        crate::info!(
            "step {:>4} | loss/tok {:.4} | tokens {:>6} | chunks {:>3} | {:>5.2}s | gnorm {:.3}",
            metrics.step,
            metrics.loss_per_token,
            metrics.tokens,
            metrics.chunks,
            metrics.seconds,
            metrics.grad_norm
        );
        self.history.push(metrics.clone());
        Ok(metrics)
    }

    /// Algorithm 2 over one dependent-chunk group, driven by the
    /// `schedule::` plan for the configured retention budget K (see
    /// DESIGN.md §Chunked-Backward and the module docs).
    fn run_group(
        &self,
        group: &[&Chunk],
        tokens: &BTreeMap<u64, Vec<u32>>,
        seq_len: &BTreeMap<u64, u64>,
        grads: &mut [Vec<B::Elem>],
        kv_peak: &mut u64,
        act_peak: &mut usize,
    ) -> anyhow::Result<(f64, f64)> {
        let c = self.backend.manifest().chunk_size;
        let kv_unit_bytes = self.backend.kv_elements(c) as u64 * B::Elem::BYTES;
        let n = group.len();
        let seq_id = match group[0].kind {
            ChunkKind::Dependent { seq_id, .. } => seq_id,
            _ => anyhow::bail!("not a dependent group"),
        };
        let k = (self.config.chunkflow.k.max(1)) as usize;

        // Build and re-validate the Algorithm-2 plan; its peak live count
        // is the activation high-water mark this group will ever need.
        let positions: Vec<usize> = (0..n).collect();
        let plan = schedule_group(&positions, k);
        let stats = validate_group_plan(&plan)
            .map_err(|e| anyhow::anyhow!("invalid Algorithm-2 plan (N={n}, K={k}): {e}"))?;
        *act_peak = (*act_peak).max(stats.peak_live_activations);

        let kv_elems = self.backend.kv_elements(c);
        let mut store: StateStore<Vec<B::Elem>> = StateStore::new();
        let mut g_kv: Vec<Vec<B::Elem>> =
            (0..n).map(|_| vec![B::Elem::ZERO; kv_elems]).collect();
        let mut loss = 0.0f64;
        let mut toks = 0.0f64;
        let hd = self.backend.manifest().num_heads * self.backend.manifest().head_dim;
        let num_layers = self.backend.manifest().num_layers;
        for op in &plan.ops {
            match *op {
                ChunkOp::Forward { chunk: i, .. } => {
                    // The final chunk's KV is never consumed as a prefix, but
                    // its forward still runs and its KV is still stored: the
                    // StateStore deliberately accounts the whole sequence's
                    // KV (the paper's Table-5 "KV state ~ context" component).
                    let prefix = i * c;
                    let kv_in = self.prefix_kv(&store, seq_id, i);
                    let inputs = self.chunk_inputs(group[i], tokens, seq_len, prefix);
                    let inputs = ChunkInputs { kv_in, ..inputs };
                    let out = self.backend.fwd_kv(&inputs)?;
                    store.put(StateKey { seq_id, chunk_index: i }, out.kv_own, kv_unit_bytes);
                    *kv_peak = (*kv_peak).max(store.peak_bytes());
                }
                // The three-program contract fuses the recompute-forward
                // into `chunk_vjp`; the plan op only gates the budget.
                ChunkOp::RecomputeForward { .. } => {}
                ChunkOp::Backward { chunk: i } => {
                    let prefix = i * c;
                    let kv_in = self.prefix_kv(&store, seq_id, i);
                    let inputs = self.chunk_inputs(group[i], tokens, seq_len, prefix);
                    let inputs = ChunkInputs { kv_in, ..inputs };
                    let out = self.backend.chunk_vjp(&inputs, &g_kv[i])?;
                    accumulate(grads, &out.d_params);
                    loss += out.loss_sum;
                    toks += out.n_tok;
                    // Scatter d_kv_in ([L, 2, prefix, H, D]) into earlier
                    // chunks' pending gradients ([L, 2, C, H, D] each).
                    scatter_kv_grad(&out.d_kv_in, &mut g_kv[..i], num_layers, prefix, c, hd);
                }
            }
        }
        Ok((loss, toks))
    }

    /// Assemble the KV prefix for chunk `upto` of `seq_id` from the
    /// StateStore ([L, 2, upto*C, H, D], interleaved from per-chunk blocks).
    fn prefix_kv(
        &self,
        store: &StateStore<Vec<B::Elem>>,
        seq_id: u64,
        upto: usize,
    ) -> Vec<B::Elem> {
        let parts: Vec<&Vec<B::Elem>> = store
            .prefix_of(seq_id, upto)
            .into_iter()
            .map(|(_, v)| v)
            .collect();
        assert_eq!(parts.len(), upto, "missing KV state");
        concat_prefix_with(
            &parts,
            self.backend.manifest().num_layers,
            self.backend.manifest().chunk_size,
            self.backend.manifest().num_heads * self.backend.manifest().head_dim,
        )
    }

    /// Build fixed-shape chunk inputs from a chunk's segments (L3 input
    /// conventions documented in python/compile/model.py).
    fn chunk_inputs(
        &self,
        chunk: &Chunk,
        tokens: &BTreeMap<u64, Vec<u32>>,
        seq_len: &BTreeMap<u64, u64>,
        prefix: usize,
    ) -> ChunkInputs<B::Elem> {
        let c = self.backend.manifest().chunk_size;
        let mut toks = vec![0i32; c];
        let mut targets = vec![-1i32; c];
        let mut pos = vec![0i32; c];
        let mut seg = vec![-1i32; c];
        let mut slot = 0usize;
        for (seg_idx, s) in chunk.segments.iter().enumerate() {
            let data = &tokens[&s.seq_id];
            let total = seq_len[&s.seq_id] as usize;
            for j in 0..s.len as usize {
                let gp = s.offset as usize + j;
                toks[slot] = data[gp] as i32;
                targets[slot] = if gp + 1 < total { data[gp + 1] as i32 } else { -1 };
                pos[slot] = gp as i32;
                seg[slot] = seg_idx as i32;
                slot += 1;
            }
        }
        // Padding convention: unique large positions, segment -1.
        for (i, sl) in (slot..c).enumerate() {
            pos[sl] = 1_000_000 + i as i32;
        }
        ChunkInputs { tokens: toks, targets, pos, seg, kv_in: Vec::new(), prefix_len: prefix }
    }

    /// Run the configured number of steps.
    pub fn train(&mut self) -> anyhow::Result<()> {
        for _ in 0..self.config.steps {
            self.train_step()?;
        }
        Ok(())
    }

    /// Save parameters + step counter + Adam state.
    pub fn save_checkpoint(&self, path: &std::path::Path) -> anyhow::Result<()> {
        checkpoint::save(path, &self.params, self.step, Some(&self.adam.export_state()))
    }

    /// Restore parameters, step counter, Adam moments (when the checkpoint
    /// carries them; v1 checkpoints restart the optimizer), and the data
    /// pipeline: batches are deterministic given the seed, so replaying
    /// `step` draws puts the sampler exactly where it was at save time —
    /// continuation is bit-identical to the uninterrupted run.
    pub fn load_checkpoint(&mut self, path: &std::path::Path) -> anyhow::Result<()> {
        let state = checkpoint::load(path)?;
        anyhow::ensure!(
            state.params.0.len() == self.params.0.len(),
            "checkpoint param arity mismatch"
        );
        for (have, want) in state.params.0.iter().zip(self.backend.manifest().params.iter()) {
            anyhow::ensure!(
                have.len() == want.size,
                "checkpoint param size {} != manifest {} for `{}`",
                have.len(),
                want.size,
                want.name
            );
        }
        self.params = state.params;
        self.step = state.step;
        // Restoring an earlier checkpoint into a used trainer must not leave
        // future-step metrics behind in the history.
        self.history.retain(|m| m.step <= state.step);
        match state.adam {
            Some(st) => self.adam.import_state(st)?,
            None => self.adam = fresh_adam(&self.config, self.backend.manifest()),
        }
        let mut sampler = BatchSampler::new(
            self.dist.clone(),
            self.config.context_length,
            self.config.global_batch_size as usize,
            self.config.seed,
        );
        for _ in 0..self.step {
            let _ = sampler.next_batch();
        }
        self.sampler = sampler;
        self.backend.set_params(&self.params)
    }

    pub fn loss_history_json(&self) -> Json {
        Json::Arr(
            self.history
                .iter()
                .map(|m| {
                    Json::obj(vec![
                        ("step", Json::num(m.step as f64)),
                        ("loss_per_token", Json::num(m.loss_per_token)),
                        ("tokens", Json::num(m.tokens as f64)),
                        ("chunks", Json::num(m.chunks as f64)),
                        ("backend_calls", Json::num(m.backend_calls as f64)),
                        ("seconds", Json::num(m.seconds)),
                        ("grad_norm", Json::num(m.grad_norm)),
                        ("kv_peak_bytes", Json::num(m.kv_peak_bytes as f64)),
                        ("act_peak_chunks", Json::num(m.act_peak_chunks as f64)),
                    ])
                })
                .collect(),
        )
    }
}

fn fresh_adam(config: &TrainConfig, manifest: &crate::runtime::Manifest) -> Adam {
    Adam::new(
        config.lr,
        config.adam_beta1,
        config.adam_beta2,
        config.adam_eps,
        config.weight_decay,
        &manifest.params.iter().map(|p| p.size).collect::<Vec<_>>(),
    )
}

/// Deterministic parameter init mirroring python's scheme closely enough for
/// training from scratch (scaled normals; ones for norm weights).
pub fn init_params(manifest: &crate::runtime::Manifest, seed: u64) -> FlatParams {
    let mut rng = Rng::new(seed ^ 0x1217);
    let mut out = Vec::with_capacity(manifest.params.len());
    for spec in &manifest.params {
        let is_norm = spec.name.starts_with("norm") || spec.name == "ln_f";
        let v: Vec<f32> = if is_norm {
            vec![1.0; spec.size]
        } else if spec.name == "embed" {
            (0..spec.size).map(|_| 0.02 * rng.next_normal() as f32).collect()
        } else {
            let fan_in = spec.shape[spec.shape.len() - 2] as f64;
            let scale = 1.0 / fan_in.sqrt();
            (0..spec.size).map(|_| (scale * rng.next_normal()) as f32).collect()
        };
        out.push(v);
    }
    FlatParams(out)
}

fn accumulate<E: Scalar>(acc: &mut [Vec<E>], delta: &[Vec<E>]) {
    for (a, d) in acc.iter_mut().zip(delta) {
        for (x, y) in a.iter_mut().zip(d) {
            *x += *y;
        }
    }
}

/// Layout-aware prefix concat: interleaves per-chunk [L, 2, C, H, D] blocks
/// into [L, 2, upto*C, H, D].
pub fn concat_prefix_with<E: Scalar>(
    parts: &[&Vec<E>],
    num_layers: usize,
    chunk: usize,
    hd: usize,
) -> Vec<E> {
    let upto = parts.len();
    if upto == 0 {
        return Vec::new();
    }
    let block = chunk * hd; // C*H*D elements per (layer, k/v) pair
    let l2 = num_layers * 2;
    debug_assert!(parts.iter().all(|p| p.len() == l2 * block));
    let mut out = vec![E::ZERO; l2 * upto * block];
    for (ci, part) in parts.iter().enumerate() {
        for b in 0..l2 {
            let src = &part[b * block..(b + 1) * block];
            let dst_off = (b * upto + ci) * block;
            out[dst_off..dst_off + block].copy_from_slice(src);
        }
    }
    out
}

/// Scatter `d_kv_in` ([L, 2, prefix, H, D]) into per-chunk pending gradients
/// ([L, 2, C, H, D] each, chunks 0..prefix/C).
pub fn scatter_kv_grad<E: Scalar>(
    d_kv_in: &[E],
    g_kv: &mut [Vec<E>],
    num_layers: usize,
    prefix: usize,
    chunk: usize,
    hd: usize,
) {
    if prefix == 0 {
        return;
    }
    let n_prev = prefix / chunk;
    debug_assert_eq!(n_prev, g_kv.len());
    let block = chunk * hd;
    let l2 = num_layers * 2;
    debug_assert_eq!(d_kv_in.len(), l2 * n_prev * block);
    for b in 0..l2 {
        for ci in 0..n_prev {
            let src_off = (b * n_prev + ci) * block;
            let dst_off = b * block;
            let dst = &mut g_kv[ci][dst_off..dst_off + block];
            let src = &d_kv_in[src_off..src_off + block];
            for (x, y) in dst.iter_mut().zip(src) {
                *x += *y;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn concat_prefix_interleaves_blocks() {
        // 1 layer, C=2, H*D=1: per-chunk = [L2=2][C*HD=2] = 4 elems.
        // part A = [a0 a1 | a2 a3] (K block | V block), part B likewise.
        let a = vec![1.0f32, 2.0, 3.0, 4.0];
        let b = vec![5.0f32, 6.0, 7.0, 8.0];
        let out = concat_prefix_with(&[&a, &b], 1, 2, 1);
        // Expected [L,2,4,1,1]: K = a0 a1 b0 b1, V = a2 a3 b2 b3.
        assert_eq!(out, vec![1.0, 2.0, 5.0, 6.0, 3.0, 4.0, 7.0, 8.0]);
    }

    #[test]
    fn concat_prefix_empty() {
        assert!(concat_prefix_with::<f32>(&[], 2, 4, 8).is_empty());
    }

    #[test]
    fn concat_prefix_generic_over_f64() {
        let a = vec![1.0f64, 2.0, 3.0, 4.0];
        let out = concat_prefix_with(&[&a], 1, 2, 1);
        assert_eq!(out, a);
    }

    #[test]
    fn scatter_is_inverse_of_concat() {
        // Scattering a gradient laid out like the concat result must route
        // each block back to its chunk.
        let d_kv: Vec<f32> = (0..8).map(|x| x as f32).collect(); // [1,2,4,1,1]
        let mut g = vec![vec![0.0f32; 4], vec![0.0f32; 4]];
        scatter_kv_grad(&d_kv, &mut g, 1, 4, 2, 1);
        assert_eq!(g[0], vec![0.0, 1.0, 4.0, 5.0]); // K a-slots + V a-slots
        assert_eq!(g[1], vec![2.0, 3.0, 6.0, 7.0]);
    }

    #[test]
    fn scatter_accumulates() {
        let d_kv = vec![1.0f32; 4]; // [1,2,2,1,1], one previous chunk (C=2)
        let mut g = vec![vec![1.0f32; 4]];
        scatter_kv_grad(&d_kv, &mut g, 1, 2, 2, 1);
        assert_eq!(g[0], vec![2.0; 4]);
        scatter_kv_grad(&d_kv, &mut g, 1, 2, 2, 1);
        assert_eq!(g[0], vec![3.0; 4]);
    }

    #[test]
    fn scatter_empty_prefix_noop() {
        let mut g: Vec<Vec<f32>> = vec![];
        scatter_kv_grad(&[], &mut g, 2, 0, 4, 8);
    }

    #[test]
    fn accumulate_adds_elementwise() {
        let mut acc = vec![vec![1.0f32, 2.0], vec![3.0f32]];
        accumulate(&mut acc, &[vec![0.5, 0.5], vec![-3.0]]);
        assert_eq!(acc, vec![vec![1.5, 2.5], vec![0.0]]);
    }

    #[test]
    fn init_params_deterministic_and_scaled() {
        use crate::runtime::{Manifest, ParamSpec};
        let man = Manifest {
            model_name: "t".into(),
            vocab_size: 16,
            hidden_size: 8,
            num_layers: 1,
            num_heads: 2,
            head_dim: 4,
            model_param_count: 0,
            chunk_size: 4,
            max_chunks: 1,
            kv_buckets: vec![0],
            full_step_lens: vec![],
            params: vec![
                ParamSpec { name: "embed".into(), shape: vec![16, 8], size: 128 },
                ParamSpec { name: "norm1".into(), shape: vec![1, 8], size: 8 },
                ParamSpec { name: "wq".into(), shape: vec![1, 8, 8], size: 64 },
            ],
        };
        let a = init_params(&man, 7);
        let b = init_params(&man, 7);
        for (x, y) in a.0.iter().zip(&b.0) {
            assert_eq!(x, y);
        }
        assert!(a.0[1].iter().all(|&v| v == 1.0), "norms init to one");
        let std: f32 = (a.0[2].iter().map(|v| v * v).sum::<f32>() / 64.0).sqrt();
        assert!((std - 1.0 / (8f32).sqrt()).abs() < 0.15, "wq std {std}");
    }
}
