//! The real ChunkFlow trainer: Algorithm 2 executed over AOT-compiled PJRT
//! programs, end to end in Rust.
//!
//! One optimizer step:
//! 1. sample a global batch of variable-length sequences (long-tail);
//! 2. Algorithm 1: reorganize into chunks (`chunk::construct_chunks`);
//! 3. for each dependent-chunk group, run Algorithm 2 with the explicit KV
//!    chain rule (DESIGN.md §Chunked-Backward):
//!    - pass 1 ascending: `fwd_kv` per chunk, KV into the StateStore
//!      (activations are discarded by construction — each call retains
//!      nothing), losses recorded;
//!    - pass 2 descending: `chunk_vjp` per chunk (recomputes the forward:
//!      "executed twice"), parameter grads accumulated, `d_kv_in` scattered
//!      into the pending `g_kv` of earlier chunks;
//! 4. standalone chunks run a single `chunk_vjp` with an empty prefix;
//! 5. grads scaled by 1/total_tokens, clipped, Adam update, params re-sent.
//!
//! Peak memory is `O(ChunkSize)` activations inside one PJRT call plus the
//! `O(context)` KV StateStore — exactly the paper's Table 5 shape.

mod adam;
pub mod checkpoint;

pub use adam::Adam;

use std::collections::BTreeMap;
use std::path::Path;
use std::time::Instant;

use crate::chunk::{construct_chunks, Chunk, ChunkKind};
use crate::config::TrainConfig;
use crate::data::{BatchSampler, LengthDistribution, SyntheticCorpus};
use crate::runtime::{ChunkInputs, FlatParams, Runtime};
use crate::state::{StateKey, StateStore};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Per-step metrics.
#[derive(Clone, Debug)]
pub struct StepMetrics {
    pub step: u64,
    pub loss_per_token: f64,
    pub tokens: u64,
    pub chunks: usize,
    pub pjrt_calls: u64,
    pub seconds: f64,
    pub grad_norm: f64,
    /// Peak StateStore bytes during the step (KV state).
    pub kv_peak_bytes: u64,
}

/// The trainer owns the runtime, parameters, optimizer and data pipeline.
pub struct Trainer {
    pub runtime: Runtime,
    pub params: FlatParams,
    pub adam: Adam,
    pub config: TrainConfig,
    sampler: BatchSampler,
    corpus: SyntheticCorpus,
    step: u64,
    pub history: Vec<StepMetrics>,
}

impl Trainer {
    pub fn new(config: TrainConfig, dist: LengthDistribution) -> anyhow::Result<Self> {
        let mut runtime = Runtime::load(Path::new(&config.artifacts_dir), &config.model.name)?;
        let c = runtime.manifest.chunk_size as u64;
        let max_ctx = c * runtime.manifest.max_chunks as u64;
        anyhow::ensure!(
            config.context_length <= max_ctx,
            "context {} exceeds artifact coverage {max_ctx}",
            config.context_length
        );
        let params = init_params(&runtime.manifest, config.seed);
        runtime.set_params(&params)?;
        let adam = Adam::new(
            config.lr,
            config.adam_beta1,
            config.adam_beta2,
            config.adam_eps,
            config.weight_decay,
            &runtime.manifest.params.iter().map(|p| p.size).collect::<Vec<_>>(),
        );
        let sampler = BatchSampler::new(
            dist,
            config.context_length,
            config.global_batch_size as usize,
            config.seed,
        );
        let corpus =
            SyntheticCorpus::new(runtime.manifest.vocab_size as u32, config.seed ^ 0xDA7A);
        Ok(Self { runtime, params, adam, config, sampler, corpus, step: 0, history: Vec::new() })
    }

    /// Gradient accumulation over one batch: Algorithm 1 + Algorithm 2 over
    /// the PJRT programs. Returns (loss_sum, token_count, summed grads,
    /// chunk count, peak KV bytes). Public so integration tests can compare
    /// against the AOT full-sequence oracle.
    pub fn compute_gradients(
        &self,
        batch: &[crate::data::Sequence],
    ) -> anyhow::Result<(f64, f64, Vec<Vec<f32>>, usize, u64)> {
        let set = construct_chunks(batch, self.runtime.manifest.chunk_size as u64);

        // Token cache for this step's sequences.
        let mut tokens: BTreeMap<u64, Vec<u32>> = BTreeMap::new();
        for s in batch {
            tokens.insert(s.id, self.corpus.generate(s.id, s.len));
        }
        let seq_len: BTreeMap<u64, u64> = batch.iter().map(|s| (s.id, s.len)).collect();

        let mut grads: Vec<Vec<f32>> =
            self.runtime.manifest.params.iter().map(|p| vec![0.0; p.size]).collect();
        let mut loss_sum = 0.0f64;
        let mut tok_sum = 0.0f64;
        let mut kv_peak = 0u64;

        // Dependent groups: Algorithm 2.
        for group in set.dependent_groups() {
            let (l, t) = self.run_group(&group, &tokens, &seq_len, &mut grads, &mut kv_peak)?;
            loss_sum += l;
            tok_sum += t;
        }
        // Standalone chunks: single vjp with empty prefix.
        let c = self.runtime.manifest.chunk_size;
        let g_zero = vec![0.0f32; self.runtime.kv_elements(c)];
        for chunk in set.standalone_chunks() {
            let inputs = self.chunk_inputs(chunk, &tokens, &seq_len, 0);
            let out = self.runtime.chunk_vjp(&inputs, &g_zero)?;
            accumulate(&mut grads, &out.d_params);
            loss_sum += out.loss_sum as f64;
            tok_sum += out.n_tok as f64;
        }
        Ok((loss_sum, tok_sum, grads, set.chunks.len(), kv_peak))
    }

    /// Token ids the trainer will use for a sequence (exposed for the
    /// oracle comparison in integration tests).
    pub fn sequence_tokens(&self, seq: &crate::data::Sequence) -> Vec<u32> {
        self.corpus.generate(seq.id, seq.len)
    }

    /// Run one optimizer step; returns its metrics.
    pub fn train_step(&mut self) -> anyhow::Result<StepMetrics> {
        let t0 = Instant::now();
        let calls0 = self.runtime.calls.get();
        let batch = self.sampler.next_batch();
        let (loss_sum, tok_sum, mut grads, n_chunks, kv_peak) =
            self.compute_gradients(&batch)?;

        anyhow::ensure!(tok_sum > 0.0, "no trainable tokens in batch");
        // Mean-token loss: scale the summed grads.
        let inv = (1.0 / tok_sum) as f32;
        for g in grads.iter_mut() {
            for x in g.iter_mut() {
                *x *= inv;
            }
        }
        let grad_norm = Adam::clip_global_norm(&mut grads, self.config.grad_clip);
        self.adam.update(&mut self.params.0, &grads);
        self.runtime.set_params(&self.params)?;

        self.step += 1;
        let metrics = StepMetrics {
            step: self.step,
            loss_per_token: loss_sum / tok_sum,
            tokens: tok_sum as u64,
            chunks: n_chunks,
            pjrt_calls: self.runtime.calls.get() - calls0,
            seconds: t0.elapsed().as_secs_f64(),
            grad_norm,
            kv_peak_bytes: kv_peak,
        };
        crate::info!(
            "step {:>4} | loss/tok {:.4} | tokens {:>6} | chunks {:>3} | {:>5.2}s | gnorm {:.3}",
            metrics.step,
            metrics.loss_per_token,
            metrics.tokens,
            metrics.chunks,
            metrics.seconds,
            metrics.grad_norm
        );
        self.history.push(metrics.clone());
        Ok(metrics)
    }

    /// Algorithm 2 over one dependent-chunk group (K=1 semantics across the
    /// AOT boundary; see DESIGN.md §Chunked-Backward).
    fn run_group(
        &self,
        group: &[&Chunk],
        tokens: &BTreeMap<u64, Vec<u32>>,
        seq_len: &BTreeMap<u64, u64>,
        grads: &mut [Vec<f32>],
        kv_peak: &mut u64,
    ) -> anyhow::Result<(f64, f64)> {
        let c = self.runtime.manifest.chunk_size;
        let kv_unit_bytes = (self.runtime.kv_elements(c) * 4) as u64;
        let n = group.len();
        let seq_id = match group[0].kind {
            ChunkKind::Dependent { seq_id, .. } => seq_id,
            _ => anyhow::bail!("not a dependent group"),
        };

        // Pass 1 (ascending): state-only forwards.
        let mut store: StateStore<Vec<f32>> = StateStore::new();
        for (i, chunk) in group.iter().enumerate() {
            let prefix = i * c;
            let kv_in = self.prefix_kv(&store, seq_id, i);
            let inputs = self.chunk_inputs(chunk, tokens, seq_len, prefix);
            let inputs = ChunkInputs { kv_in, ..inputs };
            let out = self.runtime.fwd_kv(&inputs)?;
            store.put(
                StateKey { seq_id, chunk_index: i },
                out.kv_own,
                kv_unit_bytes,
            );
            *kv_peak = (*kv_peak).max(store.peak_bytes());
        }

        // Pass 2 (descending): vjp with KV-gradient chaining.
        let kv_elems = self.runtime.kv_elements(c);
        let mut g_kv: Vec<Vec<f32>> = (0..n).map(|_| vec![0.0f32; kv_elems]).collect();
        let mut loss = 0.0f64;
        let mut toks = 0.0f64;
        for i in (0..n).rev() {
            let prefix = i * c;
            let kv_in = self.prefix_kv(&store, seq_id, i);
            let inputs = self.chunk_inputs(group[i], tokens, seq_len, prefix);
            let inputs = ChunkInputs { kv_in, ..inputs };
            let out = self.runtime.chunk_vjp(&inputs, &g_kv[i])?;
            accumulate(grads, &out.d_params);
            loss += out.loss_sum as f64;
            toks += out.n_tok as f64;
            // Scatter d_kv_in ([L, 2, prefix, H, D]) into earlier chunks'
            // pending gradients ([L, 2, C, H, D] each).
            scatter_kv_grad(
                &out.d_kv_in,
                &mut g_kv[..i],
                self.runtime.manifest.num_layers,
                prefix,
                c,
                self.runtime.manifest.num_heads * self.runtime.manifest.head_dim,
            );
        }
        Ok((loss, toks))
    }

    /// Assemble the KV prefix for chunk `upto` of `seq_id` from the
    /// StateStore ([L, 2, upto*C, H, D], interleaved from per-chunk blocks).
    fn prefix_kv(&self, store: &StateStore<Vec<f32>>, seq_id: u64, upto: usize) -> Vec<f32> {
        let parts: Vec<&Vec<f32>> = store
            .prefix_of(seq_id, upto)
            .into_iter()
            .map(|(_, v)| v)
            .collect();
        assert_eq!(parts.len(), upto, "missing KV state");
        concat_prefix_with(
            &parts,
            self.runtime.manifest.num_layers,
            self.runtime.manifest.chunk_size,
            self.runtime.manifest.num_heads * self.runtime.manifest.head_dim,
        )
    }

    /// Build fixed-shape chunk inputs from a chunk's segments (L3 input
    /// conventions documented in python/compile/model.py).
    fn chunk_inputs(
        &self,
        chunk: &Chunk,
        tokens: &BTreeMap<u64, Vec<u32>>,
        seq_len: &BTreeMap<u64, u64>,
        prefix: usize,
    ) -> ChunkInputs {
        let c = self.runtime.manifest.chunk_size;
        let mut toks = vec![0i32; c];
        let mut targets = vec![-1i32; c];
        let mut pos = vec![0i32; c];
        let mut seg = vec![-1i32; c];
        let mut slot = 0usize;
        for (seg_idx, s) in chunk.segments.iter().enumerate() {
            let data = &tokens[&s.seq_id];
            let total = seq_len[&s.seq_id] as usize;
            for j in 0..s.len as usize {
                let gp = s.offset as usize + j;
                toks[slot] = data[gp] as i32;
                targets[slot] = if gp + 1 < total { data[gp + 1] as i32 } else { -1 };
                pos[slot] = gp as i32;
                seg[slot] = seg_idx as i32;
                slot += 1;
            }
        }
        // Padding convention: unique large positions, segment -1.
        for (i, sl) in (slot..c).enumerate() {
            pos[sl] = 1_000_000 + i as i32;
        }
        ChunkInputs { tokens: toks, targets, pos, seg, kv_in: Vec::new(), prefix_len: prefix }
    }

    /// Run the configured number of steps.
    pub fn train(&mut self) -> anyhow::Result<()> {
        for _ in 0..self.config.steps {
            self.train_step()?;
        }
        Ok(())
    }

    /// Save parameters + step counter.
    pub fn save_checkpoint(&self, path: &std::path::Path) -> anyhow::Result<()> {
        checkpoint::save(path, &self.params, self.step)
    }

    /// Restore parameters + step counter (optimizer moments restart).
    pub fn load_checkpoint(&mut self, path: &std::path::Path) -> anyhow::Result<()> {
        let (params, step) = checkpoint::load(path)?;
        anyhow::ensure!(
            params.0.len() == self.params.0.len(),
            "checkpoint param arity mismatch"
        );
        self.params = params;
        self.step = step;
        self.runtime.set_params(&self.params)
    }

    pub fn loss_history_json(&self) -> Json {
        Json::Arr(
            self.history
                .iter()
                .map(|m| {
                    Json::obj(vec![
                        ("step", Json::num(m.step as f64)),
                        ("loss_per_token", Json::num(m.loss_per_token)),
                        ("tokens", Json::num(m.tokens as f64)),
                        ("chunks", Json::num(m.chunks as f64)),
                        ("seconds", Json::num(m.seconds)),
                        ("grad_norm", Json::num(m.grad_norm)),
                        ("kv_peak_bytes", Json::num(m.kv_peak_bytes as f64)),
                    ])
                })
                .collect(),
        )
    }
}

/// Deterministic parameter init mirroring python's scheme closely enough for
/// training from scratch (scaled normals; ones for norm weights).
pub fn init_params(manifest: &crate::runtime::Manifest, seed: u64) -> FlatParams {
    let mut rng = Rng::new(seed ^ 0x1217);
    let mut out = Vec::with_capacity(manifest.params.len());
    for spec in &manifest.params {
        let is_norm = spec.name.starts_with("norm") || spec.name == "ln_f";
        let v: Vec<f32> = if is_norm {
            vec![1.0; spec.size]
        } else if spec.name == "embed" {
            (0..spec.size).map(|_| 0.02 * rng.next_normal() as f32).collect()
        } else {
            let fan_in = spec.shape[spec.shape.len() - 2] as f64;
            let scale = 1.0 / fan_in.sqrt();
            (0..spec.size).map(|_| (scale * rng.next_normal()) as f32).collect()
        };
        out.push(v);
    }
    FlatParams(out)
}

fn accumulate(acc: &mut [Vec<f32>], delta: &[Vec<f32>]) {
    for (a, d) in acc.iter_mut().zip(delta) {
        for (x, y) in a.iter_mut().zip(d) {
            *x += *y;
        }
    }
}

/// Layout-aware prefix concat: interleaves per-chunk [L, 2, C, H, D] blocks
/// into [L, 2, upto*C, H, D].
pub fn concat_prefix_with(
    parts: &[&Vec<f32>],
    num_layers: usize,
    chunk: usize,
    hd: usize,
) -> Vec<f32> {
    let upto = parts.len();
    if upto == 0 {
        return Vec::new();
    }
    let block = chunk * hd; // C*H*D elements per (layer, k/v) pair
    let l2 = num_layers * 2;
    debug_assert!(parts.iter().all(|p| p.len() == l2 * block));
    let mut out = vec![0.0f32; l2 * upto * block];
    for (ci, part) in parts.iter().enumerate() {
        for b in 0..l2 {
            let src = &part[b * block..(b + 1) * block];
            let dst_off = (b * upto + ci) * block;
            out[dst_off..dst_off + block].copy_from_slice(src);
        }
    }
    out
}

/// Scatter `d_kv_in` ([L, 2, prefix, H, D]) into per-chunk pending gradients
/// ([L, 2, C, H, D] each, chunks 0..prefix/C).
pub fn scatter_kv_grad(
    d_kv_in: &[f32],
    g_kv: &mut [Vec<f32>],
    num_layers: usize,
    prefix: usize,
    chunk: usize,
    hd: usize,
) {
    if prefix == 0 {
        return;
    }
    let n_prev = prefix / chunk;
    debug_assert_eq!(n_prev, g_kv.len());
    let block = chunk * hd;
    let l2 = num_layers * 2;
    debug_assert_eq!(d_kv_in.len(), l2 * n_prev * block);
    for b in 0..l2 {
        for ci in 0..n_prev {
            let src_off = (b * n_prev + ci) * block;
            let dst_off = b * block;
            let dst = &mut g_kv[ci][dst_off..dst_off + block];
            let src = &d_kv_in[src_off..src_off + block];
            for (x, y) in dst.iter_mut().zip(src) {
                *x += *y;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn concat_prefix_interleaves_blocks() {
        // 1 layer, C=2, H*D=1: per-chunk = [L2=2][C*HD=2] = 4 elems.
        // part A = [a0 a1 | a2 a3] (K block | V block), part B likewise.
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let b = vec![5.0, 6.0, 7.0, 8.0];
        let out = concat_prefix_with(&[&a, &b], 1, 2, 1);
        // Expected [L,2,4,1,1]: K = a0 a1 b0 b1, V = a2 a3 b2 b3.
        assert_eq!(out, vec![1.0, 2.0, 5.0, 6.0, 3.0, 4.0, 7.0, 8.0]);
    }

    #[test]
    fn concat_prefix_empty() {
        assert!(concat_prefix_with(&[], 2, 4, 8).is_empty());
    }

    #[test]
    fn scatter_is_inverse_of_concat() {
        // Scattering a gradient laid out like the concat result must route
        // each block back to its chunk.
        let d_kv: Vec<f32> = (0..8).map(|x| x as f32).collect(); // [1,2,4,1,1]
        let mut g = vec![vec![0.0f32; 4], vec![0.0f32; 4]];
        scatter_kv_grad(&d_kv, &mut g, 1, 4, 2, 1);
        assert_eq!(g[0], vec![0.0, 1.0, 4.0, 5.0]); // K a-slots + V a-slots
        assert_eq!(g[1], vec![2.0, 3.0, 6.0, 7.0]);
    }

    #[test]
    fn scatter_accumulates() {
        let d_kv = vec![1.0f32; 4]; // [1,2,2,1,1], one previous chunk (C=2)
        let mut g = vec![vec![1.0f32; 4]];
        scatter_kv_grad(&d_kv, &mut g, 1, 2, 2, 1);
        assert_eq!(g[0], vec![2.0; 4]);
        scatter_kv_grad(&d_kv, &mut g, 1, 2, 2, 1);
        assert_eq!(g[0], vec![3.0; 4]);
    }

    #[test]
    fn scatter_empty_prefix_noop() {
        let mut g: Vec<Vec<f32>> = vec![];
        scatter_kv_grad(&[], &mut g, 2, 0, 4, 8);
    }

    #[test]
    fn accumulate_adds_elementwise() {
        let mut acc = vec![vec![1.0f32, 2.0], vec![3.0f32]];
        accumulate(&mut acc, &[vec![0.5, 0.5], vec![-3.0]]);
        assert_eq!(acc, vec![vec![1.5, 2.5], vec![0.0]]);
    }

    #[test]
    fn init_params_deterministic_and_scaled() {
        use crate::runtime::{Manifest, ParamSpec};
        let man = Manifest {
            model_name: "t".into(),
            vocab_size: 16,
            hidden_size: 8,
            num_layers: 1,
            num_heads: 2,
            head_dim: 4,
            model_param_count: 0,
            chunk_size: 4,
            max_chunks: 1,
            kv_buckets: vec![0],
            full_step_lens: vec![],
            params: vec![
                ParamSpec { name: "embed".into(), shape: vec![16, 8], size: 128 },
                ParamSpec { name: "norm1".into(), shape: vec![1, 8], size: 8 },
                ParamSpec { name: "wq".into(), shape: vec![1, 8, 8], size: 64 },
            ],
        };
        let a = init_params(&man, 7);
        let b = init_params(&man, 7);
        for (x, y) in a.0.iter().zip(&b.0) {
            assert_eq!(x, y);
        }
        assert!(a.0[1].iter().all(|&v| v == 1.0), "norms init to one");
        let std: f32 = (a.0[2].iter().map(|v| v * v).sum::<f32>() / 64.0).sqrt();
        assert!((std - 1.0 / (8f32).sqrt()).abs() < 0.15, "wq std {std}");
    }
}
