//! Adam optimizer over flat f32 parameter buffers (runs in Rust; no AOT
//! program needed — the update is memory-bound host work).

/// Serializable optimizer state: first/second moments plus the step
/// counter. Checkpoints carry this so resumed runs continue the exact loss
/// trajectory instead of restarting the moments.
#[derive(Clone, Debug, PartialEq)]
pub struct AdamState {
    pub m: Vec<Vec<f32>>,
    pub v: Vec<Vec<f32>>,
    pub t: u64,
}

/// Adam with optional decoupled weight decay and global-norm clipping.
#[derive(Clone, Debug)]
pub struct Adam {
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    pub weight_decay: f64,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    t: u64,
}

impl Adam {
    pub fn new(lr: f64, beta1: f64, beta2: f64, eps: f64, weight_decay: f64,
               shapes: &[usize]) -> Self {
        Self {
            lr,
            beta1,
            beta2,
            eps,
            weight_decay,
            m: shapes.iter().map(|&n| vec![0.0; n]).collect(),
            v: shapes.iter().map(|&n| vec![0.0; n]).collect(),
            t: 0,
        }
    }

    pub fn step_count(&self) -> u64 {
        self.t
    }

    /// Clone out the optimizer state for checkpointing.
    pub fn export_state(&self) -> AdamState {
        AdamState { m: self.m.clone(), v: self.v.clone(), t: self.t }
    }

    /// Restore checkpointed optimizer state (shape-checked against the
    /// moments this Adam was constructed with).
    pub fn import_state(&mut self, state: AdamState) -> anyhow::Result<()> {
        anyhow::ensure!(
            state.m.len() == self.m.len() && state.v.len() == self.v.len(),
            "Adam state arity mismatch: {} / {} moments vs {} params",
            state.m.len(),
            state.v.len(),
            self.m.len()
        );
        for (i, ((sm, sv), cm)) in state.m.iter().zip(&state.v).zip(&self.m).enumerate() {
            anyhow::ensure!(
                sm.len() == cm.len() && sv.len() == cm.len(),
                "Adam state size mismatch at param {i}: {} / {} vs {}",
                sm.len(),
                sv.len(),
                cm.len()
            );
        }
        self.m = state.m;
        self.v = state.v;
        self.t = state.t;
        Ok(())
    }

    /// Global L2 norm of the gradient set.
    pub fn global_norm(grads: &[Vec<f32>]) -> f64 {
        grads
            .iter()
            .flat_map(|g| g.iter())
            .map(|&x| (x as f64) * (x as f64))
            .sum::<f64>()
            .sqrt()
    }

    /// Clip gradients to `max_norm` in place; returns the pre-clip norm.
    pub fn clip_global_norm(grads: &mut [Vec<f32>], max_norm: f64) -> f64 {
        let norm = Self::global_norm(grads);
        if norm > max_norm && norm > 0.0 {
            let scale = (max_norm / norm) as f32;
            for g in grads.iter_mut() {
                for x in g.iter_mut() {
                    *x *= scale;
                }
            }
        }
        norm
    }

    /// One update: params <- params - lr * m_hat / (sqrt(v_hat) + eps).
    pub fn update(&mut self, params: &mut [Vec<f32>], grads: &[Vec<f32>]) {
        assert_eq!(params.len(), grads.len());
        self.t += 1;
        let b1 = self.beta1;
        let b2 = self.beta2;
        let bc1 = 1.0 - b1.powi(self.t as i32);
        let bc2 = 1.0 - b2.powi(self.t as i32);
        for ((p, g), (m, v)) in params
            .iter_mut()
            .zip(grads)
            .zip(self.m.iter_mut().zip(self.v.iter_mut()))
        {
            assert_eq!(p.len(), g.len());
            for i in 0..p.len() {
                let gi = g[i] as f64;
                m[i] = (b1 * m[i] as f64 + (1.0 - b1) * gi) as f32;
                v[i] = (b2 * v[i] as f64 + (1.0 - b2) * gi * gi) as f32;
                let m_hat = m[i] as f64 / bc1;
                let v_hat = v[i] as f64 / bc2;
                let mut upd = m_hat / (v_hat.sqrt() + self.eps);
                if self.weight_decay > 0.0 {
                    upd += self.weight_decay * p[i] as f64;
                }
                p[i] = (p[i] as f64 - self.lr * upd) as f32;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quad_grad(params: &[Vec<f32>]) -> Vec<Vec<f32>> {
        // f = sum((p - 3)^2) => grad = 2 (p - 3)
        params
            .iter()
            .map(|p| p.iter().map(|&x| 2.0 * (x - 3.0)).collect())
            .collect()
    }

    #[test]
    fn converges_on_quadratic() {
        let mut params = vec![vec![0.0f32; 8], vec![10.0f32; 4]];
        let mut adam = Adam::new(0.1, 0.9, 0.999, 1e-8, 0.0, &[8, 4]);
        for _ in 0..500 {
            let g = quad_grad(&params);
            adam.update(&mut params, &g);
        }
        for p in params.iter().flat_map(|v| v.iter()) {
            assert!((p - 3.0).abs() < 0.05, "param {p}");
        }
    }

    #[test]
    fn first_step_moves_by_about_lr() {
        // Adam's bias correction makes the first step ~= lr * sign(grad).
        let mut params = vec![vec![1.0f32]];
        let mut adam = Adam::new(0.01, 0.9, 0.999, 1e-8, 0.0, &[1]);
        adam.update(&mut params, &[vec![5.0]]);
        assert!((params[0][0] - (1.0 - 0.01)).abs() < 1e-4);
    }

    #[test]
    fn weight_decay_shrinks_params() {
        let mut a = vec![vec![1.0f32]];
        let mut b = vec![vec![1.0f32]];
        let zero_grad = vec![vec![0.0f32]];
        let mut adam_wd = Adam::new(0.1, 0.9, 0.999, 1e-8, 0.1, &[1]);
        let mut adam_no = Adam::new(0.1, 0.9, 0.999, 1e-8, 0.0, &[1]);
        adam_wd.update(&mut a, &zero_grad);
        adam_no.update(&mut b, &zero_grad);
        assert!(a[0][0] < b[0][0]);
    }

    #[test]
    fn clipping() {
        let mut g = vec![vec![3.0f32, 4.0]];
        let norm = Adam::clip_global_norm(&mut g, 1.0);
        assert!((norm - 5.0).abs() < 1e-6);
        let new_norm = Adam::global_norm(&g);
        assert!((new_norm - 1.0).abs() < 1e-6);
        // Under the limit: untouched.
        let mut g2 = vec![vec![0.3f32, 0.4]];
        Adam::clip_global_norm(&mut g2, 1.0);
        assert_eq!(g2[0], vec![0.3, 0.4]);
    }

    #[test]
    fn step_counter() {
        let mut adam = Adam::new(0.1, 0.9, 0.999, 1e-8, 0.0, &[1]);
        let mut p = vec![vec![0.0f32]];
        assert_eq!(adam.step_count(), 0);
        adam.update(&mut p, &[vec![1.0]]);
        adam.update(&mut p, &[vec![1.0]]);
        assert_eq!(adam.step_count(), 2);
    }

    #[test]
    fn state_roundtrip_resumes_identically() {
        // Two optimizers: A runs 5 updates straight; B runs 2, exports,
        // imports into a fresh Adam, runs 3 more. Trajectories must match
        // bit for bit.
        let grads = |i: u64| vec![vec![(i as f32 * 0.7 - 1.0).sin(), 0.5]];
        let mut a = Adam::new(0.05, 0.9, 0.999, 1e-8, 0.01, &[2]);
        let mut pa = vec![vec![1.0f32, -2.0]];
        for i in 0..5 {
            a.update(&mut pa, &grads(i));
        }
        let mut b1 = Adam::new(0.05, 0.9, 0.999, 1e-8, 0.01, &[2]);
        let mut pb = vec![vec![1.0f32, -2.0]];
        for i in 0..2 {
            b1.update(&mut pb, &grads(i));
        }
        let state = b1.export_state();
        let mut b2 = Adam::new(0.05, 0.9, 0.999, 1e-8, 0.01, &[2]);
        b2.import_state(state).unwrap();
        assert_eq!(b2.step_count(), 2);
        for i in 2..5 {
            b2.update(&mut pb, &grads(i));
        }
        assert_eq!(pa, pb, "resumed trajectory must be bit-identical");
    }

    #[test]
    fn import_rejects_mismatched_shapes() {
        let mut adam = Adam::new(0.1, 0.9, 0.999, 1e-8, 0.0, &[3]);
        let bad = AdamState { m: vec![vec![0.0; 2]], v: vec![vec![0.0; 2]], t: 1 };
        assert!(adam.import_state(bad).is_err());
        let bad_arity = AdamState { m: vec![], v: vec![], t: 0 };
        assert!(adam.import_state(bad_arity).is_err());
    }
}
