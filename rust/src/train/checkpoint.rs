//! Parameter/optimizer checkpointing: a simple versioned binary format
//! (header JSON + raw little-endian f32 payloads) so long fine-tuning runs
//! can resume — standard launcher functionality.
//!
//! Format v2 (current): header carries `version: 2` and `adam_t`, and the
//! payload is params followed by the Adam first and second moments (same
//! sizes as the params), so a restored run continues the exact optimizer
//! trajectory. v1 files (params only) still load — the optimizer restarts.

use std::io::{Read, Write};
use std::path::Path;

use super::adam::AdamState;
use crate::runtime::FlatParams;
use crate::util::json::Json;

const MAGIC: &[u8; 8] = b"CHKFLOW1";
const VERSION: u64 = 2;

/// Everything a checkpoint restores.
#[derive(Clone, Debug)]
pub struct TrainState {
    pub params: FlatParams,
    pub step: u64,
    /// Present on v2 checkpoints saved with optimizer state.
    pub adam: Option<AdamState>,
}

fn write_bufs(f: &mut impl Write, bufs: &[Vec<f32>]) -> anyhow::Result<()> {
    for p in bufs {
        for v in p {
            f.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

fn read_bufs(f: &mut impl Read, sizes: &[usize]) -> anyhow::Result<Vec<Vec<f32>>> {
    let mut out = Vec::with_capacity(sizes.len());
    for &n in sizes {
        let mut bytes = vec![0u8; n * 4];
        f.read_exact(&mut bytes)?;
        out.push(
            bytes
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect(),
        );
    }
    Ok(out)
}

/// Write params (+ step counter + optional Adam state) to `path` atomically
/// (tmp + rename).
pub fn save(
    path: &Path,
    params: &FlatParams,
    step: u64,
    adam: Option<&AdamState>,
) -> anyhow::Result<()> {
    if let Some(st) = adam {
        anyhow::ensure!(
            st.m.len() == params.0.len() && st.v.len() == params.0.len(),
            "Adam state arity {} / {} != param arity {}",
            st.m.len(),
            st.v.len(),
            params.0.len()
        );
        for ((m, v), p) in st.m.iter().zip(&st.v).zip(&params.0) {
            anyhow::ensure!(
                m.len() == p.len() && v.len() == p.len(),
                "Adam moment sizes must match param sizes"
            );
        }
    }
    let header = Json::obj(vec![
        ("version", Json::num(VERSION as f64)),
        ("step", Json::num(step as f64)),
        (
            "param_sizes",
            Json::Arr(params.0.iter().map(|p| Json::num(p.len() as f64)).collect()),
        ),
        ("has_adam", Json::Bool(adam.is_some())),
        ("adam_t", Json::num(adam.map(|a| a.t).unwrap_or(0) as f64)),
    ])
    .dump();
    let tmp = path.with_extension("tmp");
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    {
        let mut f = std::io::BufWriter::new(std::fs::File::create(&tmp)?);
        f.write_all(MAGIC)?;
        f.write_all(&(header.len() as u64).to_le_bytes())?;
        f.write_all(header.as_bytes())?;
        write_bufs(&mut f, &params.0)?;
        if let Some(st) = adam {
            write_bufs(&mut f, &st.m)?;
            write_bufs(&mut f, &st.v)?;
        }
        f.flush()?;
    }
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Load a checkpoint (v1 or v2).
pub fn load(path: &Path) -> anyhow::Result<TrainState> {
    let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    anyhow::ensure!(&magic == MAGIC, "not a chunkflow checkpoint");
    let mut len8 = [0u8; 8];
    f.read_exact(&mut len8)?;
    let hlen = u64::from_le_bytes(len8) as usize;
    anyhow::ensure!(hlen < 1 << 20, "header too large");
    let mut hbuf = vec![0u8; hlen];
    f.read_exact(&mut hbuf)?;
    let header = Json::parse(std::str::from_utf8(&hbuf)?)
        .map_err(|e| anyhow::anyhow!("checkpoint header: {e}"))?;
    let version = header.opt_u64("version", 1);
    anyhow::ensure!(
        version <= VERSION,
        "checkpoint version {version} is newer than supported {VERSION}"
    );
    let step = header.req_u64("step")?;
    let sizes: Vec<usize> = header
        .get("param_sizes")
        .and_then(|s| s.as_arr())
        .ok_or_else(|| anyhow::anyhow!("missing param_sizes"))?
        .iter()
        .filter_map(|v| v.as_usize())
        .collect();
    let params = FlatParams(read_bufs(&mut f, &sizes)?);
    let adam = if header.opt_bool("has_adam", false) {
        let m = read_bufs(&mut f, &sizes)?;
        let v = read_bufs(&mut f, &sizes)?;
        Some(AdamState { m, v, t: header.opt_u64("adam_t", 0) })
    } else {
        None
    };
    Ok(TrainState { params, step, adam })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> FlatParams {
        FlatParams(vec![
            (0..100).map(|i| i as f32 * 0.5).collect(),
            vec![-1.25; 7],
        ])
    }

    fn adam_state() -> AdamState {
        AdamState {
            m: vec![(0..100).map(|i| i as f32 * -0.01).collect(), vec![0.5; 7]],
            v: vec![(0..100).map(|i| i as f32 * 0.001).collect(), vec![0.25; 7]],
            t: 17,
        }
    }

    #[test]
    fn roundtrip_params_only() {
        let dir = std::env::temp_dir().join("chunkflow_ckpt_test");
        let path = dir.join("a.ckpt");
        let p = params();
        save(&path, &p, 42, None).unwrap();
        let state = load(&path).unwrap();
        assert_eq!(state.step, 42);
        assert_eq!(p.0, state.params.0);
        assert!(state.adam.is_none());
    }

    #[test]
    fn roundtrip_with_adam_state() {
        let dir = std::env::temp_dir().join("chunkflow_ckpt_test");
        let path = dir.join("b.ckpt");
        let p = params();
        let st = adam_state();
        save(&path, &p, 7, Some(&st)).unwrap();
        let state = load(&path).unwrap();
        assert_eq!(state.step, 7);
        assert_eq!(p.0, state.params.0);
        let restored = state.adam.expect("adam state");
        assert_eq!(restored, st);
    }

    #[test]
    fn v1_files_load_without_adam() {
        // A v1 checkpoint: same magic + header without version/has_adam.
        let dir = std::env::temp_dir().join("chunkflow_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("v1.ckpt");
        let p = params();
        let header = Json::obj(vec![
            ("step", Json::num(3.0)),
            (
                "param_sizes",
                Json::Arr(p.0.iter().map(|q| Json::num(q.len() as f64)).collect()),
            ),
        ])
        .dump();
        let mut f = std::io::BufWriter::new(std::fs::File::create(&path).unwrap());
        f.write_all(MAGIC).unwrap();
        f.write_all(&(header.len() as u64).to_le_bytes()).unwrap();
        f.write_all(header.as_bytes()).unwrap();
        write_bufs(&mut f, &p.0).unwrap();
        f.flush().unwrap();
        drop(f);
        let state = load(&path).unwrap();
        assert_eq!(state.step, 3);
        assert_eq!(state.params.0, p.0);
        assert!(state.adam.is_none(), "v1 checkpoints restart the optimizer");
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("chunkflow_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ckpt");
        std::fs::write(&path, b"definitely not a checkpoint").unwrap();
        assert!(load(&path).is_err());
    }

    #[test]
    fn rejects_future_version() {
        let dir = std::env::temp_dir().join("chunkflow_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("future.ckpt");
        let header = Json::obj(vec![
            ("version", Json::num(99.0)),
            ("step", Json::num(0.0)),
            ("param_sizes", Json::Arr(vec![])),
        ])
        .dump();
        let mut f = std::io::BufWriter::new(std::fs::File::create(&path).unwrap());
        f.write_all(MAGIC).unwrap();
        f.write_all(&(header.len() as u64).to_le_bytes()).unwrap();
        f.write_all(header.as_bytes()).unwrap();
        f.flush().unwrap();
        drop(f);
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");
    }

    #[test]
    fn mismatched_adam_state_rejected_at_save() {
        let dir = std::env::temp_dir().join("chunkflow_ckpt_test");
        let path = dir.join("mismatch.ckpt");
        let p = params();
        let mut st = adam_state();
        st.m.pop();
        assert!(save(&path, &p, 1, Some(&st)).is_err());
    }

    #[test]
    fn overwrite_is_atomic_and_latest_wins() {
        let dir = std::env::temp_dir().join("chunkflow_ckpt_test");
        let path = dir.join("c.ckpt");
        save(&path, &params(), 1, None).unwrap();
        let mut p2 = params();
        p2.0[0][0] = 999.0;
        save(&path, &p2, 2, Some(&adam_state())).unwrap();
        let state = load(&path).unwrap();
        assert_eq!(state.step, 2);
        assert_eq!(state.params.0[0][0], 999.0);
        assert!(state.adam.is_some());
    }
}
