//! Parameter/optimizer checkpointing: a simple versioned binary format
//! (header JSON + raw little-endian f32 payloads) so long fine-tuning runs
//! can resume — standard launcher functionality.
//!
//! Format v3 (current): on top of v2 (Adam moments + `adam_t`), the header
//! is followed by a 4-byte CRC-32 of the header bytes, and the header
//! carries `section_crcs` — one CRC-32 per payload section (params, Adam
//! m, Adam v) — so a torn or bit-rotted file is detected at load instead
//! of silently corrupting a resumed run. Writes are crash-atomic: the tmp
//! file is fsynced before `rename`, and the parent directory is fsynced
//! after, so a power cut leaves either the old generation or the new one,
//! never a hybrid. [`save_rotating`] keeps the last N generations in a
//! directory and [`latest_valid`] walks them newest-first, skipping any
//! that fail integrity checks — the recovery path `--resume` uses.
//!
//! v1 (params only) and v2 files still load; they simply have no CRCs to
//! verify.
//!
//! Layout:
//!
//! ```text
//! MAGIC "CHKFLOW1" | header_len u64 LE | header JSON | header CRC-32 (v3+)
//!   | params f32 LE | [adam_m f32 LE | adam_v f32 LE]
//! ```

use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use super::adam::AdamState;
use crate::config::ParallelConfig;
use crate::runtime::FlatParams;
use crate::util::crc::{crc32, Crc32};
use crate::util::fault;
use crate::util::json::Json;
use crate::util::rng::Rng;

const MAGIC: &[u8; 8] = b"CHKFLOW1";
const VERSION: u64 = 3;

/// Everything a checkpoint restores.
#[derive(Clone, Debug)]
pub struct TrainState {
    pub params: FlatParams,
    pub step: u64,
    /// Present on v2+ checkpoints saved with optimizer state.
    pub adam: Option<AdamState>,
    /// Topology provenance: the parallel configuration the run that wrote
    /// this checkpoint executed under. Additive header field — older
    /// checkpoints load as `None` and skip the `--resume` topology check.
    pub parallel: Option<ParallelConfig>,
}

fn write_bufs(f: &mut impl Write, bufs: &[Vec<f32>]) -> anyhow::Result<()> {
    for p in bufs {
        for v in p {
            f.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

/// CRC-32 of a section's on-disk byte stream (the little-endian f32s).
fn crc_of_bufs(bufs: &[Vec<f32>]) -> u32 {
    let mut c = Crc32::new();
    for p in bufs {
        for v in p {
            c.update(&v.to_le_bytes());
        }
    }
    c.finalize()
}

/// Read one section; returns the buffers plus the CRC-32 of the raw bytes.
fn read_bufs(f: &mut impl Read, sizes: &[usize]) -> anyhow::Result<(Vec<Vec<f32>>, u32)> {
    let mut out = Vec::with_capacity(sizes.len());
    let mut crc = Crc32::new();
    for &n in sizes {
        let mut bytes = vec![0u8; n * 4];
        f.read_exact(&mut bytes)?;
        crc.update(&bytes);
        out.push(
            bytes
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect(),
        );
    }
    Ok((out, crc.finalize()))
}

/// Write params (+ step counter + optional Adam state) to `path`
/// crash-atomically: write tmp, fsync tmp, rename over `path`, fsync the
/// parent directory (making the rename itself durable).
pub fn save(
    path: &Path,
    params: &FlatParams,
    step: u64,
    adam: Option<&AdamState>,
    parallel: Option<&ParallelConfig>,
) -> anyhow::Result<()> {
    if let Some(st) = adam {
        anyhow::ensure!(
            st.m.len() == params.0.len() && st.v.len() == params.0.len(),
            "Adam state arity {} / {} != param arity {}",
            st.m.len(),
            st.v.len(),
            params.0.len()
        );
        for ((m, v), p) in st.m.iter().zip(&st.v).zip(&params.0) {
            anyhow::ensure!(
                m.len() == p.len() && v.len() == p.len(),
                "Adam moment sizes must match param sizes"
            );
        }
    }
    // Section CRCs are computed in a pre-pass (cheap: pure memory reads) so
    // the header can be written before the payload in a single stream.
    let mut section_crcs = vec![crc_of_bufs(&params.0)];
    if let Some(st) = adam {
        section_crcs.push(crc_of_bufs(&st.m));
        section_crcs.push(crc_of_bufs(&st.v));
    }
    let mut header_fields = vec![
        ("version", Json::num(VERSION as f64)),
        ("step", Json::num(step as f64)),
        (
            "param_sizes",
            Json::Arr(params.0.iter().map(|p| Json::num(p.len() as f64)).collect()),
        ),
        ("has_adam", Json::Bool(adam.is_some())),
        ("adam_t", Json::num(adam.map(|a| a.t).unwrap_or(0) as f64)),
        (
            "section_crcs",
            Json::Arr(section_crcs.iter().map(|&c| Json::num(c as f64)).collect()),
        ),
    ];
    // Topology provenance is additive: readers that predate it ignore the
    // field, and its absence loads as `None` (no `--resume` check).
    if let Some(p) = parallel {
        header_fields.push(("parallel", p.to_json()));
    }
    let header = Json::obj(header_fields).dump();
    let tmp = path.with_extension("tmp");
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    {
        let mut f = std::io::BufWriter::new(std::fs::File::create(&tmp)?);
        f.write_all(MAGIC)?;
        f.write_all(&(header.len() as u64).to_le_bytes())?;
        f.write_all(header.as_bytes())?;
        f.write_all(&crc32(header.as_bytes()).to_le_bytes())?;
        write_bufs(&mut f, &params.0)?;
        if let Some(st) = adam {
            write_bufs(&mut f, &st.m)?;
            write_bufs(&mut f, &st.v)?;
        }
        f.flush()?;
        // fsync the tmp file before the rename: rename-then-crash must not
        // expose a named checkpoint whose blocks never hit the disk.
        f.into_inner()?.sync_all()?;
    }
    std::fs::rename(&tmp, path)?;
    // fsync the directory so the rename (the commit point) is durable too.
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::File::open(parent)?.sync_all()?;
        }
    }
    apply_write_faults(path)?;
    Ok(())
}

/// Fault-injection hook simulating torn writes / media corruption on the
/// just-committed checkpoint. Compiles to nothing without `fault-inject`.
fn apply_write_faults(path: &Path) -> anyhow::Result<()> {
    if let Some(f) = fault::fire(fault::CKPT_TRUNCATE) {
        let len = std::fs::metadata(path)?.len();
        let keep = f.param.unwrap_or_else(|| Rng::new(f.seed).gen_range(len.max(1))).min(len);
        let file = std::fs::OpenOptions::new().write(true).open(path)?;
        file.set_len(keep)?;
        file.sync_all()?;
        crate::warn_!(
            "injected fault: truncated checkpoint {} from {len} to {keep} bytes",
            path.display()
        );
    }
    if let Some(f) = fault::fire(fault::CKPT_BITFLIP) {
        let len = std::fs::metadata(path)?.len();
        if len > 0 {
            let mut rng = Rng::new(f.seed);
            let pos = f.param.unwrap_or_else(|| rng.gen_range(len)).min(len - 1);
            let bit = (rng.gen_range(8)) as u8;
            let mut bytes = std::fs::read(path)?;
            bytes[pos as usize] ^= 1 << bit;
            std::fs::write(path, &bytes)?;
            crate::warn_!(
                "injected fault: flipped bit {bit} of byte {pos} in checkpoint {}",
                path.display()
            );
        }
    }
    Ok(())
}

/// Load a checkpoint (v1, v2, or v3). Corrupt or torn files of any
/// version return a clean `Err` — this function never panics on bad
/// input, which is what lets [`latest_valid`] probe candidates safely.
pub fn load(path: &Path) -> anyhow::Result<TrainState> {
    let file = std::fs::File::open(path)?;
    let file_len = file.metadata()?.len();
    let mut f = std::io::BufReader::new(file);
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    anyhow::ensure!(&magic == MAGIC, "not a chunkflow checkpoint");
    let mut len8 = [0u8; 8];
    f.read_exact(&mut len8)?;
    let hlen = u64::from_le_bytes(len8) as usize;
    anyhow::ensure!(hlen < 1 << 20, "header too large");
    let mut hbuf = vec![0u8; hlen];
    f.read_exact(&mut hbuf)?;
    let header = Json::parse(std::str::from_utf8(&hbuf)?)
        .map_err(|e| anyhow::anyhow!("checkpoint header: {e}"))?;
    let version = header.opt_u64("version", 1);
    anyhow::ensure!(
        version <= VERSION,
        "checkpoint version {version} is newer than supported {VERSION}"
    );
    let mut consumed = 8 + 8 + hlen as u64;
    if version >= 3 {
        // Verify the header's own CRC before trusting any field in it —
        // in particular before allocating payload buffers from its sizes.
        let mut crc4 = [0u8; 4];
        f.read_exact(&mut crc4)?;
        consumed += 4;
        let want = u32::from_le_bytes(crc4);
        let got = crc32(&hbuf);
        anyhow::ensure!(
            got == want,
            "checkpoint header CRC mismatch (stored {want:#010x}, computed {got:#010x})"
        );
    }
    let step = header.req_u64("step")?;
    let sizes_arr = header
        .get("param_sizes")
        .and_then(|s| s.as_arr())
        .ok_or_else(|| anyhow::anyhow!("missing param_sizes"))?;
    let sizes: Vec<usize> = sizes_arr.iter().filter_map(|v| v.as_usize()).collect();
    anyhow::ensure!(sizes.len() == sizes_arr.len(), "non-numeric entry in param_sizes");
    let has_adam = header.opt_bool("has_adam", false);
    // Bound the payload by the actual file size before allocating, so a
    // garbage v1/v2 header (no CRC to catch it) cannot demand an absurd
    // allocation or a long doomed read.
    let section_bytes: u64 = sizes.iter().map(|&n| n as u64 * 4).sum();
    let num_sections = if has_adam { 3 } else { 1 };
    anyhow::ensure!(
        consumed + section_bytes * num_sections <= file_len,
        "checkpoint truncated: header promises {} payload bytes but only {} remain",
        section_bytes * num_sections,
        file_len - consumed.min(file_len)
    );
    let expected_crcs: Option<Vec<u32>> = header.get("section_crcs").and_then(|s| s.as_arr()).map(
        |arr| arr.iter().filter_map(|v| v.as_u64().map(|c| c as u32)).collect(),
    );
    let check = |section: usize, name: &str, got: u32| -> anyhow::Result<()> {
        if let Some(crcs) = &expected_crcs {
            let want = *crcs
                .get(section)
                .ok_or_else(|| anyhow::anyhow!("missing section_crcs[{section}] ({name})"))?;
            anyhow::ensure!(
                got == want,
                "checkpoint section `{name}` CRC mismatch (stored {want:#010x}, computed {got:#010x})"
            );
        }
        Ok(())
    };
    let (params, crc) = read_bufs(&mut f, &sizes)?;
    check(0, "params", crc)?;
    let params = FlatParams(params);
    let adam = if has_adam {
        let (m, crc_m) = read_bufs(&mut f, &sizes)?;
        check(1, "adam_m", crc_m)?;
        let (v, crc_v) = read_bufs(&mut f, &sizes)?;
        check(2, "adam_v", crc_v)?;
        Some(AdamState { m, v, t: header.opt_u64("adam_t", 0) })
    } else {
        None
    };
    let parallel = match header.get("parallel") {
        Some(p) => Some(
            ParallelConfig::from_json(p)
                .map_err(|e| anyhow::anyhow!("checkpoint `parallel` provenance: {e}"))?,
        ),
        None => None,
    };
    Ok(TrainState { params, step, adam, parallel })
}

/// Filename for a rotation generation, ordered lexicographically by step.
fn generation_name(step: u64) -> String {
    format!("step-{step:010}.ckpt")
}

/// Enumerate rotation generations in `dir`, sorted ascending by step.
fn generations(dir: &Path) -> anyhow::Result<Vec<(u64, PathBuf)>> {
    let mut out = Vec::new();
    if !dir.exists() {
        return Ok(out);
    }
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        let Some(step) = name
            .strip_prefix("step-")
            .and_then(|s| s.strip_suffix(".ckpt"))
            .and_then(|s| s.parse::<u64>().ok())
        else {
            continue;
        };
        out.push((step, entry.path()));
    }
    out.sort();
    Ok(out)
}

/// Save a rotation generation `step-NNNNNNNNNN.ckpt` under `dir`, then
/// prune the oldest generations so at most `keep` remain. Returns the
/// path written.
pub fn save_rotating(
    dir: &Path,
    params: &FlatParams,
    step: u64,
    adam: Option<&AdamState>,
    parallel: Option<&ParallelConfig>,
    keep: usize,
) -> anyhow::Result<PathBuf> {
    anyhow::ensure!(keep >= 1, "checkpoint rotation must keep at least 1 generation");
    std::fs::create_dir_all(dir)?;
    let path = dir.join(generation_name(step));
    save(&path, params, step, adam, parallel)?;
    let gens = generations(dir)?;
    if gens.len() > keep {
        for (_, old) in &gens[..gens.len() - keep] {
            std::fs::remove_file(old)?;
        }
        std::fs::File::open(dir)?.sync_all()?;
    }
    Ok(path)
}

/// Find the newest generation in `dir` that loads cleanly, skipping (with
/// a logged warning) any that are corrupt or torn. Returns `None` when no
/// valid checkpoint exists.
pub fn latest_valid(dir: &Path) -> anyhow::Result<Option<(PathBuf, TrainState)>> {
    for (_, path) in generations(dir)?.into_iter().rev() {
        match load(&path) {
            Ok(state) => return Ok(Some((path, state))),
            Err(e) => {
                crate::warn_!(
                    "checkpoint {} failed integrity checks, falling back a generation: {e}",
                    path.display()
                );
            }
        }
    }
    Ok(None)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> FlatParams {
        FlatParams(vec![
            (0..100).map(|i| i as f32 * 0.5).collect(),
            vec![-1.25; 7],
        ])
    }

    fn adam_state() -> AdamState {
        AdamState {
            m: vec![(0..100).map(|i| i as f32 * -0.01).collect(), vec![0.5; 7]],
            v: vec![(0..100).map(|i| i as f32 * 0.001).collect(), vec![0.25; 7]],
            t: 17,
        }
    }

    fn tmp_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("chunkflow_ckpt_test").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn roundtrip_params_only() {
        let path = tmp_dir("roundtrip_a").join("a.ckpt");
        let p = params();
        save(&path, &p, 42, None, None).unwrap();
        let state = load(&path).unwrap();
        assert_eq!(state.step, 42);
        assert_eq!(p.0, state.params.0);
        assert!(state.adam.is_none());
    }

    #[test]
    fn roundtrip_with_adam_state() {
        let path = tmp_dir("roundtrip_b").join("b.ckpt");
        let p = params();
        let st = adam_state();
        save(&path, &p, 7, Some(&st), None).unwrap();
        let state = load(&path).unwrap();
        assert_eq!(state.step, 7);
        assert_eq!(p.0, state.params.0);
        let restored = state.adam.expect("adam state");
        assert_eq!(restored, st);
    }

    #[test]
    fn roundtrip_with_parallel_provenance() {
        use crate::config::RecomputeGranularity;
        let path = tmp_dir("roundtrip_p").join("p.ckpt");
        let p = params();
        let mut topo = ParallelConfig::new(1, 2, RecomputeGranularity::Selective);
        topo.dp = 2;
        topo.sp = 4;
        save(&path, &p, 5, None, Some(&topo)).unwrap();
        let state = load(&path).unwrap();
        assert_eq!(state.parallel.as_ref(), Some(&topo));
        // Provenance-free saves (and pre-provenance files) load as None.
        save(&path, &p, 6, None, None).unwrap();
        assert!(load(&path).unwrap().parallel.is_none());
    }

    #[test]
    fn v1_files_load_without_adam() {
        // A v1 checkpoint: same magic + header without version/has_adam,
        // and no header CRC trailer.
        let path = tmp_dir("v1").join("v1.ckpt");
        let p = params();
        let header = Json::obj(vec![
            ("step", Json::num(3.0)),
            (
                "param_sizes",
                Json::Arr(p.0.iter().map(|q| Json::num(q.len() as f64)).collect()),
            ),
        ])
        .dump();
        let mut f = std::io::BufWriter::new(std::fs::File::create(&path).unwrap());
        f.write_all(MAGIC).unwrap();
        f.write_all(&(header.len() as u64).to_le_bytes()).unwrap();
        f.write_all(header.as_bytes()).unwrap();
        write_bufs(&mut f, &p.0).unwrap();
        f.flush().unwrap();
        drop(f);
        let state = load(&path).unwrap();
        assert_eq!(state.step, 3);
        assert_eq!(state.params.0, p.0);
        assert!(state.adam.is_none(), "v1 checkpoints restart the optimizer");
    }

    #[test]
    fn v2_files_load_without_crc_checks() {
        // A v2 checkpoint: version 2, Adam payload, no CRCs anywhere.
        let path = tmp_dir("v2").join("v2.ckpt");
        let p = params();
        let st = adam_state();
        let header = Json::obj(vec![
            ("version", Json::num(2.0)),
            ("step", Json::num(11.0)),
            (
                "param_sizes",
                Json::Arr(p.0.iter().map(|q| Json::num(q.len() as f64)).collect()),
            ),
            ("has_adam", Json::Bool(true)),
            ("adam_t", Json::num(st.t as f64)),
        ])
        .dump();
        let mut f = std::io::BufWriter::new(std::fs::File::create(&path).unwrap());
        f.write_all(MAGIC).unwrap();
        f.write_all(&(header.len() as u64).to_le_bytes()).unwrap();
        f.write_all(header.as_bytes()).unwrap();
        write_bufs(&mut f, &p.0).unwrap();
        write_bufs(&mut f, &st.m).unwrap();
        write_bufs(&mut f, &st.v).unwrap();
        f.flush().unwrap();
        drop(f);
        let state = load(&path).unwrap();
        assert_eq!(state.step, 11);
        assert_eq!(state.params.0, p.0);
        assert_eq!(state.adam.expect("adam"), st);
    }

    #[test]
    fn rejects_garbage() {
        let path = tmp_dir("garbage").join("bad.ckpt");
        std::fs::write(&path, b"definitely not a checkpoint").unwrap();
        assert!(load(&path).is_err());
    }

    #[test]
    fn rejects_future_version() {
        let path = tmp_dir("future").join("future.ckpt");
        let header = Json::obj(vec![
            ("version", Json::num(99.0)),
            ("step", Json::num(0.0)),
            ("param_sizes", Json::Arr(vec![])),
        ])
        .dump();
        let mut f = std::io::BufWriter::new(std::fs::File::create(&path).unwrap());
        f.write_all(MAGIC).unwrap();
        f.write_all(&(header.len() as u64).to_le_bytes()).unwrap();
        f.write_all(header.as_bytes()).unwrap();
        f.flush().unwrap();
        drop(f);
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");
    }

    #[test]
    fn rejects_payload_larger_than_file() {
        // A v1-style header promising a petabyte of params must fail the
        // size sanity check, not attempt the allocation.
        let path = tmp_dir("huge").join("huge.ckpt");
        let header = Json::obj(vec![
            ("step", Json::num(0.0)),
            ("param_sizes", Json::Arr(vec![Json::num(1e15)])),
        ])
        .dump();
        let mut f = std::io::BufWriter::new(std::fs::File::create(&path).unwrap());
        f.write_all(MAGIC).unwrap();
        f.write_all(&(header.len() as u64).to_le_bytes()).unwrap();
        f.write_all(header.as_bytes()).unwrap();
        f.flush().unwrap();
        drop(f);
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("truncated"), "{err}");
    }

    #[test]
    fn mismatched_adam_state_rejected_at_save() {
        let path = tmp_dir("mismatch").join("mismatch.ckpt");
        let p = params();
        let mut st = adam_state();
        st.m.pop();
        assert!(save(&path, &p, 1, Some(&st), None).is_err());
    }

    #[test]
    fn overwrite_is_atomic_and_latest_wins() {
        let path = tmp_dir("overwrite").join("c.ckpt");
        save(&path, &params(), 1, None, None).unwrap();
        let mut p2 = params();
        p2.0[0][0] = 999.0;
        save(&path, &p2, 2, Some(&adam_state()), None).unwrap();
        let state = load(&path).unwrap();
        assert_eq!(state.step, 2);
        assert_eq!(state.params.0[0][0], 999.0);
        assert!(state.adam.is_some());
    }

    #[test]
    fn payload_corruption_is_detected() {
        let path = tmp_dir("corrupt_payload").join("c.ckpt");
        save(&path, &params(), 5, Some(&adam_state()), None).unwrap();
        let clean = std::fs::read(&path).unwrap();
        // Flip a payload byte in each section; the per-section CRC must
        // name the right section.
        let header_end = clean.len() - 3 * (100 + 7) * 4;
        for (section, name) in [(0usize, "params"), (1, "adam_m"), (2, "adam_v")] {
            let mut bytes = clean.clone();
            let pos = header_end + section * (100 + 7) * 4 + 13;
            bytes[pos] ^= 0x40;
            std::fs::write(&path, &bytes).unwrap();
            let err = load(&path).unwrap_err().to_string();
            assert!(err.contains(name), "section {section}: {err}");
        }
    }

    #[test]
    fn fuzz_truncations_and_bitflips_never_panic() {
        // Satellite: truncate at every section boundary (and a sweep of
        // other lengths), and flip seeded random bits; `load` must always
        // return a clean Err, never panic, never succeed on corrupt data.
        let dir = tmp_dir("fuzz");
        let path = dir.join("f.ckpt");
        save(&path, &params(), 9, Some(&adam_state()), None).unwrap();
        let clean = std::fs::read(&path).unwrap();
        let section = (100 + 7) * 4;
        let header_end = clean.len() - 3 * section;
        let boundaries = [
            0,
            8,                   // after magic
            16,                  // after header length
            header_end - 4,      // after header JSON (before header CRC)
            header_end,          // after header CRC
            header_end + section,
            header_end + 2 * section,
        ];
        for &cut in &boundaries {
            std::fs::write(&path, &clean[..cut]).unwrap();
            assert!(load(&path).is_err(), "truncation at {cut} must fail");
        }
        // Sweep every 37th length too, to hit mid-section tears.
        for cut in (0..clean.len()).step_by(37) {
            std::fs::write(&path, &clean[..cut]).unwrap();
            assert!(load(&path).is_err(), "truncation at {cut} must fail");
        }
        // Seeded single-bit flips across the whole file.
        let mut rng = Rng::new(0xFA57_F00D);
        for _ in 0..200 {
            let pos = rng.gen_range(clean.len() as u64) as usize;
            let bit = rng.gen_range(8) as u8;
            let mut bytes = clean.clone();
            bytes[pos] ^= 1 << bit;
            std::fs::write(&path, &bytes).unwrap();
            assert!(load(&path).is_err(), "bit flip at byte {pos} bit {bit} must fail");
        }
        // The pristine bytes still load.
        std::fs::write(&path, &clean).unwrap();
        assert!(load(&path).is_ok());
    }

    #[test]
    fn rotation_keeps_last_n_generations() {
        let dir = tmp_dir("rotate");
        for step in 1..=5 {
            save_rotating(&dir, &params(), step, None, None, 3).unwrap();
        }
        let gens = generations(&dir).unwrap();
        let steps: Vec<u64> = gens.iter().map(|(s, _)| *s).collect();
        assert_eq!(steps, vec![3, 4, 5]);
        let (path, state) = latest_valid(&dir).unwrap().expect("some generation");
        assert_eq!(state.step, 5);
        assert!(path.ends_with("step-0000000005.ckpt"));
    }

    #[test]
    fn latest_valid_falls_back_over_corrupt_generations() {
        let dir = tmp_dir("fallback");
        for step in 1..=3 {
            save_rotating(&dir, &params(), step, Some(&adam_state()), None, 3).unwrap();
        }
        // Tear the newest generation and bit-rot the middle one.
        let newest = dir.join(generation_name(3));
        let bytes = std::fs::read(&newest).unwrap();
        std::fs::write(&newest, &bytes[..bytes.len() / 2]).unwrap();
        let mid = dir.join(generation_name(2));
        let mut bytes = std::fs::read(&mid).unwrap();
        let last = bytes.len() - 10;
        bytes[last] ^= 0x01;
        std::fs::write(&mid, &bytes).unwrap();
        let (path, state) = latest_valid(&dir).unwrap().expect("generation 1 survives");
        assert_eq!(state.step, 1);
        assert!(path.ends_with(generation_name(1).as_str()));
        // With every generation corrupt, resume reports none rather than
        // loading garbage.
        let oldest = dir.join(generation_name(1));
        std::fs::write(&oldest, b"CHKFLOW1 but not really").unwrap();
        assert!(latest_valid(&dir).unwrap().is_none());
    }

    #[test]
    fn latest_valid_on_missing_dir_is_none() {
        let dir = tmp_dir("missing").join("never_created");
        assert!(latest_valid(&dir).unwrap().is_none());
    }
}
