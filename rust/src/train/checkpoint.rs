//! Parameter/optimizer checkpointing: a simple versioned binary format
//! (header JSON + raw little-endian f32 payloads) so long fine-tuning runs
//! can resume — standard launcher functionality.

use std::io::{Read, Write};
use std::path::Path;

use crate::runtime::FlatParams;
use crate::util::json::Json;

const MAGIC: &[u8; 8] = b"CHKFLOW1";

/// Write params (+ step counter) to `path` atomically (tmp + rename).
pub fn save(path: &Path, params: &FlatParams, step: u64) -> anyhow::Result<()> {
    let header = Json::obj(vec![
        ("step", Json::num(step as f64)),
        (
            "param_sizes",
            Json::Arr(params.0.iter().map(|p| Json::num(p.len() as f64)).collect()),
        ),
    ])
    .dump();
    let tmp = path.with_extension("tmp");
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    {
        let mut f = std::io::BufWriter::new(std::fs::File::create(&tmp)?);
        f.write_all(MAGIC)?;
        f.write_all(&(header.len() as u64).to_le_bytes())?;
        f.write_all(header.as_bytes())?;
        for p in &params.0 {
            for v in p {
                f.write_all(&v.to_le_bytes())?;
            }
        }
        f.flush()?;
    }
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Load a checkpoint; returns (params, step).
pub fn load(path: &Path) -> anyhow::Result<(FlatParams, u64)> {
    let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    anyhow::ensure!(&magic == MAGIC, "not a chunkflow checkpoint");
    let mut len8 = [0u8; 8];
    f.read_exact(&mut len8)?;
    let hlen = u64::from_le_bytes(len8) as usize;
    anyhow::ensure!(hlen < 1 << 20, "header too large");
    let mut hbuf = vec![0u8; hlen];
    f.read_exact(&mut hbuf)?;
    let header = Json::parse(std::str::from_utf8(&hbuf)?)
        .map_err(|e| anyhow::anyhow!("checkpoint header: {e}"))?;
    let step = header.req_u64("step")?;
    let sizes: Vec<usize> = header
        .get("param_sizes")
        .and_then(|s| s.as_arr())
        .ok_or_else(|| anyhow::anyhow!("missing param_sizes"))?
        .iter()
        .filter_map(|v| v.as_usize())
        .collect();
    let mut params = Vec::with_capacity(sizes.len());
    for n in sizes {
        let mut bytes = vec![0u8; n * 4];
        f.read_exact(&mut bytes)?;
        params.push(
            bytes
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect(),
        );
    }
    Ok((FlatParams(params), step))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> FlatParams {
        FlatParams(vec![
            (0..100).map(|i| i as f32 * 0.5).collect(),
            vec![-1.25; 7],
        ])
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("chunkflow_ckpt_test");
        let path = dir.join("a.ckpt");
        let p = params();
        save(&path, &p, 42).unwrap();
        let (q, step) = load(&path).unwrap();
        assert_eq!(step, 42);
        assert_eq!(p.0, q.0);
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("chunkflow_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ckpt");
        std::fs::write(&path, b"definitely not a checkpoint").unwrap();
        assert!(load(&path).is_err());
    }

    #[test]
    fn overwrite_is_atomic_and_latest_wins() {
        let dir = std::env::temp_dir().join("chunkflow_ckpt_test");
        let path = dir.join("c.ckpt");
        save(&path, &params(), 1).unwrap();
        let mut p2 = params();
        p2.0[0][0] = 999.0;
        save(&path, &p2, 2).unwrap();
        let (q, step) = load(&path).unwrap();
        assert_eq!(step, 2);
        assert_eq!(q.0[0][0], 999.0);
    }
}
