//! The Megatron-LM-like baseline execution model.
//!
//! Encodes the paper's Table 3: for each (model, context length), the
//! parallel strategy `<TP, SP, PP, Recompute>` used by the baseline — chosen
//! as the best-performing configuration that does not OOM when a micro-batch
//! holds the longest admitted sequence. Also provides the config *search*
//! that derives such a table from the memory model, and the Figure 1
//! micro-step memory trace.

use crate::config::{ModelSpec, ParallelConfig, RecomputeGranularity};
use crate::data::Sequence;
use crate::memory::{MemoryModel, GPU_CAPACITY};

/// Paper Table 3, verbatim.
pub fn paper_table3(model: &str, context: u64) -> Option<ParallelConfig> {
    use RecomputeGranularity::{Full, Selective};
    let k256 = 256 * 1024;
    let cfg = match (model, context) {
        ("qwen2.5-7b", c) if c < k256 => ParallelConfig::new(4, 1, Selective),
        ("qwen2.5-7b", _) => ParallelConfig::new(4, 4, Full),
        ("qwen2.5-14b", c) if c < k256 => ParallelConfig::new(4, 4, Selective),
        ("qwen2.5-14b", _) => ParallelConfig::new(4, 4, Full),
        ("qwen2.5-32b", c) if c < k256 => ParallelConfig::new(4, 4, Selective),
        ("qwen2.5-32b", _) => ParallelConfig::new(4, 4, Full),
        ("qwen2.5-72b", _) => ParallelConfig::new(8, 4, Selective),
        _ => return None,
    };
    Some(cfg)
}

/// Paper Table 4: ChunkFlow's best (ChunkSize, K) per (model, context).
pub fn paper_table4(model: &str, context: u64) -> Option<(u64, u64)> {
    let k = 1024;
    let k256 = 256 * k;
    Some(match (model, context) {
        ("qwen2.5-7b", c) if c < k256 => (32 * k, 1),
        ("qwen2.5-7b", _) => (8 * k, 16),
        ("qwen2.5-14b", _) => (8 * k, 8),
        ("qwen2.5-32b", _) => (8 * k, 6),
        ("qwen2.5-72b", _) => (8 * k, 16),
        _ => return None,
    })
}

/// Candidate strategies the search sweeps (TP within a node, PP across).
fn candidate_configs() -> Vec<(u64, u64)> {
    // (tp, pp) pairs; SP always on.
    vec![(1, 1), (2, 1), (4, 1), (8, 1), (2, 2), (4, 2), (4, 4), (8, 2), (8, 4), (8, 8)]
}

/// Derive a baseline config from the memory model: the fewest GPUs (then
/// cheapest recompute) that fits the longest admitted sequence as one
/// micro-batch, mirroring how the paper picked Table 3.
pub fn derive_baseline_config(model: &ModelSpec, context: u64) -> Option<ParallelConfig> {
    use RecomputeGranularity::{Full, Selective};
    let mut best: Option<ParallelConfig> = None;
    for (tp, pp) in candidate_configs() {
        for rec in [Selective, Full] {
            let cfg = ParallelConfig::new(tp, pp, rec);
            let mm = MemoryModel::new(model.clone(), cfg.clone());
            // In-flight set for 1F1B at stage 0: the long sequence plus
            // (PP-1) typical short ones.
            let mut in_flight = vec![context];
            in_flight.extend(std::iter::repeat(1024).take(pp as usize - 1));
            let peak = mm.baseline_pipeline_peak(&in_flight);
            if peak <= GPU_CAPACITY {
                let better = match &best {
                    None => true,
                    Some(b) => {
                        let (gb, gc) = (b.world_size(), cfg.world_size());
                        gc < gb
                            || (gc == gb
                                && rec == Selective
                                && b.recompute == Full)
                    }
                };
                if better {
                    best = Some(cfg);
                }
            }
        }
    }
    best
}

/// Figure 1: per-micro-step peak memory trace for the baseline (micro-batch
/// = one sequence), in bytes per GPU.
pub fn microstep_memory_trace(batch: &[Sequence], mm: &MemoryModel) -> Vec<u64> {
    batch.iter().map(|s| mm.baseline_peak(s.len)).collect()
}

/// Summary statistics for the Figure 1 narrative: peak and the fraction of
/// micro-steps under a threshold.
pub fn trace_stats(trace: &[u64], threshold: u64) -> (u64, f64) {
    let peak = trace.iter().copied().max().unwrap_or(0);
    let under = trace.iter().filter(|&&b| b < threshold).count() as f64
        / trace.len().max(1) as f64;
    (peak, under)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{BatchSampler, LengthDistribution};

    #[test]
    fn table3_entries_exist_for_all_eval_points() {
        for m in ["qwen2.5-7b", "qwen2.5-14b", "qwen2.5-32b", "qwen2.5-72b"] {
            for ctx in [32 * 1024, 256 * 1024] {
                let cfg = paper_table3(m, ctx).unwrap();
                assert!(cfg.world_size() >= 4);
                assert!(paper_table4(m, ctx).is_some());
            }
        }
    }

    #[test]
    fn table3_paper_configs_fit_in_memory_model_at_32k() {
        // The 32K-context Table 3 strategies must be OOM-free under our
        // memory model (calibration sanity check). The 256K rows are NOT
        // asserted: under Megatron's own published activation accounting, a
        // single unchunked 256K micro-batch through 72B at <8,8,4,selective>
        // exceeds 80 GB; the paper's feasibility there must rest on
        // unstated optimizations (see EXPERIMENTS.md §Deviations).
        for m in ["qwen2.5-7b", "qwen2.5-14b", "qwen2.5-32b", "qwen2.5-72b"] {
            for ctx in [32 * 1024u64] {
                let spec = ModelSpec::preset(m).unwrap();
                let cfg = paper_table3(m, ctx).unwrap();
                let mm = MemoryModel::new(spec, cfg.clone());
                let mut in_flight = vec![ctx];
                in_flight.extend(std::iter::repeat(1024).take(cfg.pp as usize - 1));
                let peak = mm.baseline_pipeline_peak(&in_flight);
                assert!(
                    peak <= GPU_CAPACITY,
                    "{m}@{ctx}: paper config {} peaks at {} GiB",
                    cfg.paper_format(),
                    peak / (1 << 30)
                );
            }
        }
    }

    #[test]
    fn derived_config_fits_and_is_minimal() {
        let spec = ModelSpec::preset("qwen2.5-7b").unwrap();
        let cfg = derive_baseline_config(&spec, 32 * 1024).unwrap();
        // 7B/32K should need only a single node's worth of GPUs.
        assert!(cfg.world_size() <= 8, "got {}", cfg.paper_format());
        // 256K needs more GPUs or heavier recompute.
        let cfg256 = derive_baseline_config(&spec, 256 * 1024).unwrap();
        assert!(
            cfg256.world_size() > cfg.world_size()
                || cfg256.recompute == RecomputeGranularity::Full,
            "256K must cost more: {} vs {}",
            cfg256.paper_format(),
            cfg.paper_format()
        );
    }

    #[test]
    fn derived_configs_pinned_under_per_stage_peak_accounting() {
        // Re-pin after the `baseline_pipeline_peak` fix (stage-0 window vs
        // last-stage activations+logits are a max, not a sum, for PP > 1;
        // PP = 1 unchanged): the 7B derivations the paper's Table 3 rests
        // on stay put. 32K fits a single node at <4,4,1,selective> — the
        // same strategy Table 3 lists; 256K needs full recompute.
        let spec = ModelSpec::preset("qwen2.5-7b").unwrap();
        let c32 = derive_baseline_config(&spec, 32 * 1024).unwrap();
        assert_eq!(c32.world_size(), 4, "got {}", c32.paper_format());
        assert_eq!(c32.recompute, RecomputeGranularity::Selective);
        let c256 = derive_baseline_config(&spec, 256 * 1024).unwrap();
        assert_eq!(c256.recompute, RecomputeGranularity::Full, "got {}", c256.paper_format());
        // The fix can only shrink modelled peaks, so anything that fit
        // before still fits: the paper's own 32K strategies in particular.
        for m in ["qwen2.5-7b", "qwen2.5-14b", "qwen2.5-32b", "qwen2.5-72b"] {
            let spec = ModelSpec::preset(m).unwrap();
            let cfg = paper_table3(m, 32 * 1024).unwrap();
            let mm = MemoryModel::new(spec, cfg.clone());
            let mut in_flight = vec![32 * 1024];
            in_flight.extend(std::iter::repeat(1024).take(cfg.pp as usize - 1));
            assert!(mm.baseline_pipeline_peak(&in_flight) <= GPU_CAPACITY, "{m}");
        }
    }

    #[test]
    fn trace_reproduces_figure1_shape() {
        // 7B/32K/selective micro-steps: peak ~75 GB, vast majority < 45 GB.
        let spec = ModelSpec::preset("qwen2.5-7b").unwrap();
        let mm = MemoryModel::new(
            spec,
            ParallelConfig::new(4, 1, RecomputeGranularity::Selective),
        );
        let mut sampler = BatchSampler::new(
            LengthDistribution::lmsys_chat_1m(),
            32 * 1024,
            1000,
            42,
        );
        let batch = sampler.next_batch();
        let trace = microstep_memory_trace(&batch, &mm);
        let (peak, under45) = trace_stats(&trace, 45 * (1 << 30));
        let peak_gib = peak as f64 / (1 << 30) as f64;
        assert!(peak_gib < 80.0, "no OOM: {peak_gib:.1}");
        assert!(under45 > 0.9, "most micro-steps are small: {under45:.3}");
    }

    #[test]
    fn bigger_model_derives_bigger_world() {
        let w7 = derive_baseline_config(&ModelSpec::preset("qwen2.5-7b").unwrap(), 32 * 1024)
            .unwrap()
            .world_size();
        let w72 =
            derive_baseline_config(&ModelSpec::preset("qwen2.5-72b").unwrap(), 32 * 1024)
                .unwrap()
                .world_size();
        assert!(w72 > w7);
    }
}
