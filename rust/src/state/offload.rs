//! Tiered StateStore with host-memory budget and disk spill — the paper's
//! explicitly-deferred optimization (§6.3.1: "we directly save all key/value
//! tensors in memory without further offloading optimizations. We leave this
//! optimization for future work.").
//!
//! `OffloadStore` keeps the most recently used KV buffers resident up to a
//! byte budget and spills the excess to a temp file; `get` transparently
//! reloads (and re-evicts something else if needed). For ChunkFlow's access
//! pattern — ascending-forward then descending-backward over a sequence's
//! chunks — LRU is within one fetch of optimal on the backward sweep: the
//! coldest chunk KV spills first and is restored exactly when its
//! recompute/backward consumes it.
//!
//! The store is generic over the element type ([`Scalar`]): f64 buffers on
//! the reference backend, f32 on PJRT. Spill serialization is the element's
//! little-endian byte image, so a spill/reload round trip is bit-exact and
//! the trainer's gradients are unchanged by any budget.
//!
//! Two accounting views: `resident` (bytes currently in host memory —
//! bounded by the budget at every stable point, tracked as
//! `peak_resident_bytes`) and `total` (resident + spilled — the logical KV
//! footprint the paper's Table 5 charges).
//!
//! The spill file is created lazily on the first spill (a store whose
//! budget never triggers does zero filesystem work) and freed slots are
//! recycled, so repeated re-spills of the same keys keep the file bounded
//! by the peak number of concurrently spilled buffers.

use std::collections::BTreeMap;
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

use super::StateKey;
use crate::runtime::Scalar;

/// Distinguishes spill files of stores created in the same process.
static SPILL_SEQ: AtomicU64 = AtomicU64::new(0);

struct Resident<E> {
    data: Vec<E>,
    /// Monotone access stamp for LRU.
    stamp: u64,
}

struct Spilled {
    offset: u64,
    len: usize,
}

/// KV store with bounded residency.
pub struct OffloadStore<E: Scalar = f32> {
    budget_bytes: u64,
    resident: BTreeMap<StateKey, Resident<E>>,
    spilled: BTreeMap<StateKey, Spilled>,
    /// Created lazily on the first spill: a store whose budget never
    /// triggers pays no filesystem syscalls at all.
    file: Option<std::fs::File>,
    path: PathBuf,
    file_len: u64,
    /// Reusable spill slots (element count -> offsets), recycled when a
    /// spilled entry is reloaded, replaced or removed. Without this the
    /// append-only file would grow O(N²) under the trainer's repeated
    /// prefix-fetch pattern; with it the file is bounded by the peak number
    /// of concurrently spilled buffers.
    free_slots: BTreeMap<usize, Vec<u64>>,
    clock: u64,
    resident_bytes: u64,
    peak_resident_bytes: u64,
    total_bytes: u64,
    peak_total_bytes: u64,
    pub spill_count: u64,
    pub fetch_count: u64,
}

impl<E: Scalar> OffloadStore<E> {
    /// Create with a residency budget (bytes). The spill file lives in the
    /// OS temp dir, is unique per store, is created only when the first
    /// spill actually happens, and is removed on drop.
    pub fn new(budget_bytes: u64) -> anyhow::Result<Self> {
        let path = std::env::temp_dir().join(format!(
            "chunkflow-kv-spill-{}-{}.bin",
            std::process::id(),
            SPILL_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        Ok(Self {
            budget_bytes,
            resident: BTreeMap::new(),
            spilled: BTreeMap::new(),
            file: None,
            path,
            file_len: 0,
            free_slots: BTreeMap::new(),
            clock: 0,
            resident_bytes: 0,
            peak_resident_bytes: 0,
            total_bytes: 0,
            peak_total_bytes: 0,
            spill_count: 0,
            fetch_count: 0,
        })
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    /// Return a spilled entry's slot to the free list.
    fn recycle_slot(&mut self, sp: Spilled) {
        self.free_slots.entry(sp.len).or_default().push(sp.offset);
    }

    /// Insert a KV buffer (takes ownership; may evict older buffers).
    /// Replacing an existing key adjusts both accounting views.
    pub fn put(&mut self, key: StateKey, data: Vec<E>) -> anyhow::Result<()> {
        let bytes = data.len() as u64 * E::BYTES;
        let stamp = self.tick();
        if let Some(old) = self.resident.insert(key, Resident { data, stamp }) {
            let old_bytes = old.data.len() as u64 * E::BYTES;
            self.resident_bytes -= old_bytes;
            self.total_bytes -= old_bytes;
        }
        if let Some(old) = self.spilled.remove(&key) {
            self.total_bytes -= old.len as u64 * E::BYTES;
            self.recycle_slot(old);
        }
        self.resident_bytes += bytes;
        self.total_bytes += bytes;
        self.peak_total_bytes = self.peak_total_bytes.max(self.total_bytes);
        self.enforce_budget(Some(key))?;
        self.peak_resident_bytes = self.peak_resident_bytes.max(self.resident_bytes);
        Ok(())
    }

    /// Fetch a buffer (reloading from disk if spilled). Returns a clone of
    /// the data (callers assemble prefixes from several entries anyway).
    pub fn get(&mut self, key: &StateKey) -> anyhow::Result<Option<Vec<E>>> {
        let stamp = self.tick();
        if let Some(r) = self.resident.get_mut(key) {
            r.stamp = stamp;
            return Ok(Some(r.data.clone()));
        }
        let Some(sp) = self.spilled.get(key) else {
            return Ok(None);
        };
        let (offset, len) = (sp.offset, sp.len);
        self.fetch_count += 1;
        let elem = E::BYTES as usize;
        let mut buf = vec![0u8; len * elem];
        let file = self
            .file
            .as_mut()
            .ok_or_else(|| anyhow::anyhow!("spilled entry without a spill file"))?;
        file.seek(SeekFrom::Start(offset))?;
        file.read_exact(&mut buf)?;
        let data: Vec<E> = buf.chunks_exact(elem).map(E::read_le).collect();
        let key = *key;
        if let Some(sp) = self.spilled.remove(&key) {
            self.recycle_slot(sp);
        }
        self.resident_bytes += data.len() as u64 * E::BYTES;
        self.resident.insert(key, Resident { data: data.clone(), stamp });
        self.enforce_budget(Some(key))?;
        self.peak_resident_bytes = self.peak_resident_bytes.max(self.resident_bytes);
        Ok(Some(data))
    }

    /// Remove an entry entirely (sequence finished backward).
    pub fn remove(&mut self, key: &StateKey) {
        if let Some(r) = self.resident.remove(key) {
            let bytes = r.data.len() as u64 * E::BYTES;
            self.resident_bytes -= bytes;
            self.total_bytes -= bytes;
        }
        if let Some(sp) = self.spilled.remove(key) {
            self.total_bytes -= sp.len as u64 * E::BYTES;
            self.recycle_slot(sp);
        }
    }

    /// Spill least-recently-used residents until within budget, never
    /// evicting `protect`.
    fn enforce_budget(&mut self, protect: Option<StateKey>) -> anyhow::Result<()> {
        while self.resident_bytes > self.budget_bytes && self.resident.len() > 1 {
            let victim = self
                .resident
                .iter()
                .filter(|(k, _)| Some(**k) != protect)
                .min_by_key(|(_, r)| r.stamp)
                .map(|(k, _)| *k);
            let Some(victim) = victim else { break };
            let r = self.resident.remove(&victim).unwrap();
            self.resident_bytes -= r.data.len() as u64 * E::BYTES;
            let mut bytes = Vec::with_capacity(r.data.len() * E::BYTES as usize);
            for v in &r.data {
                v.write_le(&mut bytes);
            }
            // Reuse a freed same-size slot when one exists; append only
            // when the file has no hole to fill.
            let offset = match self.free_slots.get_mut(&r.data.len()).and_then(|v| v.pop()) {
                Some(off) => off,
                None => {
                    let off = self.file_len;
                    self.file_len += bytes.len() as u64;
                    off
                }
            };
            if self.file.is_none() {
                self.file = Some(
                    std::fs::OpenOptions::new()
                        .create(true)
                        .truncate(true)
                        .read(true)
                        .write(true)
                        .open(&self.path)?,
                );
            }
            let file = self.file.as_mut().expect("spill file just ensured");
            file.seek(SeekFrom::Start(offset))?;
            file.write_all(&bytes)?;
            self.spilled.insert(victim, Spilled { offset, len: r.data.len() });
            self.spill_count += 1;
        }
        Ok(())
    }

    /// Current spill-file length in bytes (slot reuse keeps this bounded by
    /// the peak number of concurrently spilled buffers, not the spill
    /// count).
    pub fn spill_file_len(&self) -> u64 {
        self.file_len
    }

    pub fn resident_bytes(&self) -> u64 {
        self.resident_bytes
    }

    /// High-water mark of resident bytes at stable points (after each
    /// put/get finished enforcing the budget) — the number the
    /// `--offload-budget-bytes` contract bounds.
    pub fn peak_resident_bytes(&self) -> u64 {
        self.peak_resident_bytes
    }

    /// Resident + spilled bytes right now (logical KV footprint).
    pub fn total_bytes(&self) -> u64 {
        self.total_bytes
    }

    /// High-water mark of the logical KV footprint (Table 5's component).
    pub fn peak_total_bytes(&self) -> u64 {
        self.peak_total_bytes
    }

    pub fn len(&self) -> usize {
        self.resident.len() + self.spilled.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<E: Scalar> Drop for OffloadStore<E> {
    fn drop(&mut self) {
        if self.file.is_some() {
            let _ = std::fs::remove_file(&self.path);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(i: usize) -> StateKey {
        StateKey { seq_id: 0, chunk_index: i }
    }

    fn payload(i: usize, n: usize) -> Vec<f32> {
        (0..n).map(|j| (i * 1000 + j) as f32).collect()
    }

    #[test]
    fn within_budget_no_spill() {
        let mut s = OffloadStore::new(10_000).unwrap();
        for i in 0..4 {
            s.put(key(i), payload(i, 100)).unwrap(); // 400 B each
        }
        assert_eq!(s.spill_count, 0);
        for i in 0..4 {
            assert_eq!(s.get(&key(i)).unwrap().unwrap(), payload(i, 100));
        }
        assert_eq!(s.fetch_count, 0);
        assert_eq!(s.total_bytes(), 1600);
        assert_eq!(s.peak_resident_bytes(), 1600);
    }

    #[test]
    fn spills_and_reloads_exactly() {
        // Budget fits 2 buffers of 1000 floats (4000 B each).
        let mut s = OffloadStore::new(9_000).unwrap();
        for i in 0..6 {
            s.put(key(i), payload(i, 1000)).unwrap();
        }
        assert!(s.spill_count >= 4, "spilled {}", s.spill_count);
        assert!(s.resident_bytes() <= 9_000);
        assert!(s.peak_resident_bytes() <= 9_000, "budget bounds the stable peak");
        assert_eq!(s.peak_total_bytes(), 24_000, "logical footprint is all 6 buffers");
        // All data still retrievable, bit-exact.
        for i in 0..6 {
            assert_eq!(s.get(&key(i)).unwrap().unwrap(), payload(i, 1000), "chunk {i}");
        }
        assert!(s.fetch_count >= 4);
    }

    #[test]
    fn f64_roundtrip_is_bit_exact() {
        let mut s: OffloadStore<f64> = OffloadStore::new(40).unwrap(); // ~1 tiny buffer
        let a: Vec<f64> = vec![std::f64::consts::PI, -0.0, 1e-300, f64::MAX];
        let b: Vec<f64> = vec![std::f64::consts::E, 2.0f64.powi(-1074), -1.5, 0.125];
        s.put(key(0), a.clone()).unwrap();
        s.put(key(1), b.clone()).unwrap(); // evicts key(0) to disk
        assert!(s.spill_count >= 1);
        let got = s.get(&key(0)).unwrap().unwrap();
        for (x, y) in got.iter().zip(&a) {
            assert_eq!(x.to_bits(), y.to_bits(), "spill round trip must be bit-exact");
        }
        assert_eq!(s.get(&key(1)).unwrap().unwrap(), b);
    }

    #[test]
    fn pooled_buffers_spill_and_restore_bit_exact() {
        // The pipeline executor's per-stage arena (`util::pool::BufferPool`)
        // recycles KV buffers through acquire/release; a buffer that has
        // lived several arena generations must still spill and restore
        // bit-exactly — pooling must be invisible to the offload tier.
        let mut arena = crate::util::pool::BufferPool::new(4);
        let first = arena.acquire(512);
        arena.release(first);
        let mut buf = arena.acquire(512); // recycled allocation
        for (j, v) in buf.iter_mut().enumerate() {
            *v = (j as f64 + 0.5).sqrt() * if j % 3 == 0 { -1.0 } else { 1.0 };
        }
        let want = buf.clone();

        // Budget fits one 512-f64 buffer (4096 B); the fillers force `buf`
        // through an actual disk round trip.
        let mut s: OffloadStore<f64> = OffloadStore::new(4_100).unwrap();
        s.put(key(0), buf).unwrap();
        s.put(key(1), arena.acquire(512)).unwrap();
        s.put(key(2), arena.acquire(512)).unwrap();
        assert!(s.spill_count >= 1, "pooled buffer must have spilled");

        let got = s.get(&key(0)).unwrap().unwrap();
        assert_eq!(got.len(), want.len());
        for (j, (x, y)) in got.iter().zip(&want).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "element {j} changed across spill");
        }
        // And the restored buffer can flow back into the arena.
        arena.release(got);
        assert!(arena.retained() >= 1);
    }

    #[test]
    fn backward_sweep_access_pattern() {
        // Forward puts 0..8, backward gets 7..0 — the Alg. 2 pattern.
        let mut s = OffloadStore::new(8_200).unwrap(); // ~2 buffers resident
        for i in 0..8 {
            s.put(key(i), payload(i, 1000)).unwrap();
        }
        for i in (0..8).rev() {
            assert_eq!(s.get(&key(i)).unwrap().unwrap(), payload(i, 1000));
            s.remove(&key(i));
        }
        assert!(s.is_empty());
        assert_eq!(s.total_bytes(), 0);
    }

    #[test]
    fn missing_key_is_none() {
        let mut s: OffloadStore<f32> = OffloadStore::new(1000).unwrap();
        assert!(s.get(&key(9)).unwrap().is_none());
    }

    #[test]
    fn remove_frees_residency() {
        let mut s = OffloadStore::new(100_000).unwrap();
        s.put(key(0), payload(0, 1000)).unwrap();
        assert_eq!(s.resident_bytes(), 4000);
        s.remove(&key(0));
        assert_eq!(s.resident_bytes(), 0);
        assert_eq!(s.total_bytes(), 0);
        assert!(s.is_empty());
    }

    #[test]
    fn overwrite_same_key_adjusts_accounting() {
        let mut s = OffloadStore::new(100_000).unwrap();
        s.put(key(1), payload(1, 10)).unwrap();
        s.put(key(1), payload(2, 20)).unwrap();
        assert_eq!(s.get(&key(1)).unwrap().unwrap(), payload(2, 20));
        assert_eq!(s.len(), 1);
        assert_eq!(s.resident_bytes(), 80, "replaced entry must not leak bytes");
        assert_eq!(s.total_bytes(), 80);
    }

    #[test]
    fn spill_file_stays_bounded_under_repeated_respills() {
        // The trainer's prefix-fetch pattern re-spills the same keys over
        // and over; slot reuse must keep the file at (peak concurrently
        // spilled) slots, not (spill count) slots.
        let mut s = OffloadStore::new(4_000).unwrap(); // 1 buffer resident
        for i in 0..4 {
            s.put(key(i), payload(i, 1000)).unwrap(); // 4000 B each
        }
        for round in 0..10 {
            for i in 0..4 {
                assert_eq!(
                    s.get(&key(i)).unwrap().unwrap(),
                    payload(i, 1000),
                    "round {round} chunk {i}"
                );
            }
        }
        assert!(s.spill_count > 10, "re-spills must actually have happened");
        assert!(
            s.spill_file_len() <= 4 * 4_000,
            "spill file {} B exceeds the 4-slot bound",
            s.spill_file_len()
        );
    }

    #[test]
    fn no_spill_means_no_spill_file() {
        let s: OffloadStore<f32> = OffloadStore::new(1_000_000).unwrap();
        assert_eq!(s.spill_file_len(), 0);
        assert!(s.file.is_none(), "file must be created lazily");
    }

    #[test]
    fn concurrent_stores_use_distinct_spill_files() {
        // Two stores in one process with the same budget must not clobber
        // each other's spill data.
        let mut a = OffloadStore::new(4_000).unwrap();
        let mut b = OffloadStore::new(4_000).unwrap();
        for i in 0..3 {
            a.put(key(i), payload(i, 1000)).unwrap();
            b.put(key(i), payload(i + 100, 1000)).unwrap();
        }
        for i in 0..3 {
            assert_eq!(a.get(&key(i)).unwrap().unwrap(), payload(i, 1000));
            assert_eq!(b.get(&key(i)).unwrap().unwrap(), payload(i + 100, 1000));
        }
    }
}
