//! Tiered StateStore with host-memory budget and disk spill — the paper's
//! explicitly-deferred optimization (§6.3.1: "we directly save all key/value
//! tensors in memory without further offloading optimizations. We leave this
//! optimization for future work.").
//!
//! `OffloadStore` keeps the most recently used KV buffers resident up to a
//! byte budget and spills the excess to a temp file; `get` transparently
//! reloads (and re-evicts something else if needed). For ChunkFlow's access
//! pattern — ascending-forward then descending-backward over a sequence's
//! chunks — LRU is within one fetch of optimal on the backward sweep.

use std::collections::BTreeMap;
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::PathBuf;

use super::StateKey;

struct Resident {
    data: Vec<f32>,
    /// Monotone access stamp for LRU.
    stamp: u64,
}

struct Spilled {
    offset: u64,
    len: usize,
}

/// KV store with bounded residency.
pub struct OffloadStore {
    budget_bytes: u64,
    resident: BTreeMap<StateKey, Resident>,
    spilled: BTreeMap<StateKey, Spilled>,
    file: std::fs::File,
    path: PathBuf,
    file_len: u64,
    clock: u64,
    resident_bytes: u64,
    pub spill_count: u64,
    pub fetch_count: u64,
}

impl OffloadStore {
    /// Create with a residency budget (bytes). Spill file lives in the OS
    /// temp dir and is removed on drop.
    pub fn new(budget_bytes: u64) -> anyhow::Result<Self> {
        let path = std::env::temp_dir().join(format!(
            "chunkflow-kv-spill-{}-{:x}.bin",
            std::process::id(),
            &budget_bytes ^ 0x5eed
        ));
        let file = std::fs::OpenOptions::new()
            .create(true)
            .truncate(true)
            .read(true)
            .write(true)
            .open(&path)?;
        Ok(Self {
            budget_bytes,
            resident: BTreeMap::new(),
            spilled: BTreeMap::new(),
            file,
            path,
            file_len: 0,
            clock: 0,
            resident_bytes: 0,
            spill_count: 0,
            fetch_count: 0,
        })
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    /// Insert a KV buffer (takes ownership; may evict older buffers).
    pub fn put(&mut self, key: StateKey, data: Vec<f32>) -> anyhow::Result<()> {
        let bytes = (data.len() * 4) as u64;
        let stamp = self.tick();
        self.resident.insert(key, Resident { data, stamp });
        self.resident_bytes += bytes;
        self.spilled.remove(&key);
        self.enforce_budget(Some(key))?;
        Ok(())
    }

    /// Fetch a buffer (reloading from disk if spilled). Returns a clone of
    /// the data (callers assemble prefixes from several entries anyway).
    pub fn get(&mut self, key: &StateKey) -> anyhow::Result<Option<Vec<f32>>> {
        let stamp = self.tick();
        if let Some(r) = self.resident.get_mut(key) {
            r.stamp = stamp;
            return Ok(Some(r.data.clone()));
        }
        let Some(sp) = self.spilled.get(key) else {
            return Ok(None);
        };
        self.fetch_count += 1;
        let mut buf = vec![0u8; sp.len * 4];
        self.file.seek(SeekFrom::Start(sp.offset))?;
        self.file.read_exact(&mut buf)?;
        let data: Vec<f32> = buf
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        let key = *key;
        self.spilled.remove(&key);
        self.resident_bytes += (data.len() * 4) as u64;
        self.resident.insert(key, Resident { data: data.clone(), stamp });
        self.enforce_budget(Some(key))?;
        Ok(Some(data))
    }

    /// Remove an entry entirely (sequence finished backward).
    pub fn remove(&mut self, key: &StateKey) {
        if let Some(r) = self.resident.remove(key) {
            self.resident_bytes -= (r.data.len() * 4) as u64;
        }
        self.spilled.remove(key);
    }

    /// Spill least-recently-used residents until within budget, never
    /// evicting `protect`.
    fn enforce_budget(&mut self, protect: Option<StateKey>) -> anyhow::Result<()> {
        while self.resident_bytes > self.budget_bytes && self.resident.len() > 1 {
            let victim = self
                .resident
                .iter()
                .filter(|(k, _)| Some(**k) != protect)
                .min_by_key(|(_, r)| r.stamp)
                .map(|(k, _)| *k);
            let Some(victim) = victim else { break };
            let r = self.resident.remove(&victim).unwrap();
            self.resident_bytes -= (r.data.len() * 4) as u64;
            // Append to spill file.
            let mut bytes = Vec::with_capacity(r.data.len() * 4);
            for v in &r.data {
                bytes.extend_from_slice(&v.to_le_bytes());
            }
            self.file.seek(SeekFrom::Start(self.file_len))?;
            self.file.write_all(&bytes)?;
            self.spilled
                .insert(victim, Spilled { offset: self.file_len, len: r.data.len() });
            self.file_len += bytes.len() as u64;
            self.spill_count += 1;
        }
        Ok(())
    }

    pub fn resident_bytes(&self) -> u64 {
        self.resident_bytes
    }

    pub fn len(&self) -> usize {
        self.resident.len() + self.spilled.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Drop for OffloadStore {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(i: usize) -> StateKey {
        StateKey { seq_id: 0, chunk_index: i }
    }

    fn payload(i: usize, n: usize) -> Vec<f32> {
        (0..n).map(|j| (i * 1000 + j) as f32).collect()
    }

    #[test]
    fn within_budget_no_spill() {
        let mut s = OffloadStore::new(10_000).unwrap();
        for i in 0..4 {
            s.put(key(i), payload(i, 100)).unwrap(); // 400 B each
        }
        assert_eq!(s.spill_count, 0);
        for i in 0..4 {
            assert_eq!(s.get(&key(i)).unwrap().unwrap(), payload(i, 100));
        }
        assert_eq!(s.fetch_count, 0);
    }

    #[test]
    fn spills_and_reloads_exactly() {
        // Budget fits 2 buffers of 1000 floats (4000 B each).
        let mut s = OffloadStore::new(9_000).unwrap();
        for i in 0..6 {
            s.put(key(i), payload(i, 1000)).unwrap();
        }
        assert!(s.spill_count >= 4, "spilled {}", s.spill_count);
        assert!(s.resident_bytes() <= 9_000);
        // All data still retrievable, bit-exact.
        for i in 0..6 {
            assert_eq!(s.get(&key(i)).unwrap().unwrap(), payload(i, 1000), "chunk {i}");
        }
        assert!(s.fetch_count >= 4);
    }

    #[test]
    fn backward_sweep_access_pattern() {
        // Forward puts 0..8, backward gets 7..0 — the Alg. 2 pattern.
        let mut s = OffloadStore::new(8_200).unwrap(); // ~2 buffers resident
        for i in 0..8 {
            s.put(key(i), payload(i, 1000)).unwrap();
        }
        for i in (0..8).rev() {
            assert_eq!(s.get(&key(i)).unwrap().unwrap(), payload(i, 1000));
            s.remove(&key(i));
        }
        assert!(s.is_empty());
    }

    #[test]
    fn missing_key_is_none() {
        let mut s = OffloadStore::new(1000).unwrap();
        assert!(s.get(&key(9)).unwrap().is_none());
    }

    #[test]
    fn remove_frees_residency() {
        let mut s = OffloadStore::new(100_000).unwrap();
        s.put(key(0), payload(0, 1000)).unwrap();
        assert_eq!(s.resident_bytes(), 4000);
        s.remove(&key(0));
        assert_eq!(s.resident_bytes(), 0);
        assert!(s.is_empty());
    }

    #[test]
    fn overwrite_same_key() {
        let mut s = OffloadStore::new(100_000).unwrap();
        s.put(key(1), payload(1, 10)).unwrap();
        s.put(key(1), payload(2, 20)).unwrap();
        assert_eq!(s.get(&key(1)).unwrap().unwrap(), payload(2, 20));
        assert_eq!(s.len(), 1);
    }
}
