//! StateStore — the shared state that Algorithm 2 threads between chunk
//! executions: per-sequence key/value tensors from the causal-attention
//! modules (forward) and the accumulated gradients w.r.t. those tensors
//! (backward).
//!
//! The store is generic over the payload `T`: the real trainer stores host
//! buffers of KV values (`Vec<f32>`), the simulator stores `()` and only
//! uses the byte accounting. Byte accounting feeds Table 5 (peak memory vs
//! ChunkSize) and the Fig. 1 style traces.

pub mod offload;

pub use offload::OffloadStore;

use std::collections::BTreeMap;

/// Key for one chunk's contribution to a sequence's KV state.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct StateKey {
    pub seq_id: u64,
    pub chunk_index: usize,
}

/// One stored entry: payload plus its size in bytes.
#[derive(Clone, Debug)]
struct Entry<T> {
    payload: T,
    bytes: u64,
}

/// KV state shared across a chunk group's execution (paper Alg. 2 line 2).
#[derive(Clone, Debug)]
pub struct StateStore<T> {
    entries: BTreeMap<StateKey, Entry<T>>,
    current_bytes: u64,
    peak_bytes: u64,
}

impl<T> Default for StateStore<T> {
    fn default() -> Self {
        Self { entries: BTreeMap::new(), current_bytes: 0, peak_bytes: 0 }
    }
}

impl<T> StateStore<T> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Store chunk `chunk_index` of `seq_id`'s KV (or KV-gradient) payload.
    /// Replacing an existing entry adjusts accounting.
    pub fn put(&mut self, key: StateKey, payload: T, bytes: u64) {
        if let Some(old) = self.entries.insert(key, Entry { payload, bytes }) {
            self.current_bytes -= old.bytes;
        }
        self.current_bytes += bytes;
        self.peak_bytes = self.peak_bytes.max(self.current_bytes);
    }

    pub fn get(&self, key: &StateKey) -> Option<&T> {
        self.entries.get(key).map(|e| &e.payload)
    }

    pub fn get_mut(&mut self, key: &StateKey) -> Option<&mut T> {
        self.entries.get_mut(key).map(|e| &mut e.payload)
    }

    pub fn remove(&mut self, key: &StateKey) -> Option<T> {
        self.entries.remove(key).map(|e| {
            self.current_bytes -= e.bytes;
            e.payload
        })
    }

    /// All stored chunk indices for a sequence, ascending — the KV prefix a
    /// dependent chunk's forward consumes.
    pub fn prefix_of(&self, seq_id: u64, before_index: usize) -> Vec<(&StateKey, &T)> {
        self.entries
            .range(
                StateKey { seq_id, chunk_index: 0 }
                    ..StateKey { seq_id, chunk_index: before_index },
            )
            .map(|(k, e)| (k, &e.payload))
            .collect()
    }

    /// Drop every entry belonging to `seq_id` (sequence finished backward).
    pub fn release_sequence(&mut self, seq_id: u64) -> usize {
        let keys: Vec<StateKey> = self
            .entries
            .range(
                StateKey { seq_id, chunk_index: 0 }
                    ..StateKey { seq_id: seq_id + 1, chunk_index: 0 },
            )
            .map(|(k, _)| *k)
            .collect();
        let n = keys.len();
        for k in keys {
            self.remove(&k);
        }
        n
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn current_bytes(&self) -> u64 {
        self.current_bytes
    }

    pub fn peak_bytes(&self) -> u64 {
        self.peak_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(seq: u64, idx: usize) -> StateKey {
        StateKey { seq_id: seq, chunk_index: idx }
    }

    #[test]
    fn put_get_remove() {
        let mut s: StateStore<Vec<f32>> = StateStore::new();
        s.put(key(1, 0), vec![1.0, 2.0], 8);
        assert_eq!(s.get(&key(1, 0)).unwrap(), &vec![1.0, 2.0]);
        assert_eq!(s.current_bytes(), 8);
        assert_eq!(s.remove(&key(1, 0)).unwrap(), vec![1.0, 2.0]);
        assert_eq!(s.current_bytes(), 0);
        assert!(s.get(&key(1, 0)).is_none());
    }

    #[test]
    fn peak_tracks_high_water_mark() {
        let mut s: StateStore<()> = StateStore::new();
        s.put(key(0, 0), (), 100);
        s.put(key(0, 1), (), 200);
        assert_eq!(s.peak_bytes(), 300);
        s.remove(&key(0, 0));
        assert_eq!(s.current_bytes(), 200);
        assert_eq!(s.peak_bytes(), 300, "peak is sticky");
        s.put(key(0, 2), (), 50);
        assert_eq!(s.peak_bytes(), 300);
    }

    #[test]
    fn replace_adjusts_accounting() {
        let mut s: StateStore<u32> = StateStore::new();
        s.put(key(2, 0), 1, 64);
        s.put(key(2, 0), 2, 32);
        assert_eq!(s.current_bytes(), 32);
        assert_eq!(*s.get(&key(2, 0)).unwrap(), 2);
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn prefix_query_is_ordered_and_bounded() {
        let mut s: StateStore<usize> = StateStore::new();
        for i in 0..5 {
            s.put(key(7, i), i, 10);
        }
        s.put(key(8, 0), 99, 10); // different sequence must not leak in
        let prefix = s.prefix_of(7, 3);
        assert_eq!(prefix.len(), 3);
        assert_eq!(
            prefix.iter().map(|(k, _)| k.chunk_index).collect::<Vec<_>>(),
            vec![0, 1, 2]
        );
        assert!(prefix.iter().all(|(k, _)| k.seq_id == 7));
    }

    #[test]
    fn release_sequence_clears_only_that_sequence() {
        let mut s: StateStore<()> = StateStore::new();
        for i in 0..4 {
            s.put(key(1, i), (), 25);
        }
        s.put(key(2, 0), (), 25);
        assert_eq!(s.release_sequence(1), 4);
        assert_eq!(s.len(), 1);
        assert_eq!(s.current_bytes(), 25);
        assert!(s.get(&key(2, 0)).is_some());
    }

    #[test]
    fn kv_bytes_grow_linearly_with_stored_chunks() {
        // Matches the paper's Table 5 note: KV state is the component that
        // grows with context length (no offloading in v1).
        let mut s: StateStore<()> = StateStore::new();
        let per_chunk = 1024;
        for i in 0..32 {
            s.put(key(0, i), (), per_chunk);
        }
        assert_eq!(s.current_bytes(), 32 * per_chunk);
    }
}
