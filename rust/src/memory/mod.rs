//! Analytic GPU-memory model.
//!
//! Reproduces the paper's memory observations without A100s: per-GPU peak
//! memory as a function of model shape, parallel strategy, recompute
//! granularity, and either (baseline) the micro-batch sequence length or
//! (ChunkFlow) the `(ChunkSize, K)` pair and context length.
//!
//! The component formulas follow Megatron's published accounting
//! (Korthikanti et al.) with two scalar calibration constants fitted once
//! against the paper's own numbers and then *held fixed* for every
//! prediction in EXPERIMENTS.md:
//!
//! - `C_ACT_BASE` (baseline activation bytes per token per hidden×layer):
//!   fitted so the Megatron 7B/32K/selective micro-step peak is ≈75 GB
//!   (paper Figure 1).
//! - `C_ACT_CF` (ChunkFlow activation bytes per token): fitted to the
//!   ChunkSize slope of Table 5 row pairs (≈2.95 MiB/token/GPU for 7B at
//!   TP=4; the constant absorbs the per-chunk logits / bookkeeping buffers
//!   ChunkFlow keeps that plain Megatron's activation formula does not).
//! - `KV_OVERHEAD`: Table 5's context-length slope is ~1.3× the raw
//!   bf16 K/V byte count (allocator slack + stored grad stubs); fitted to
//!   the 32K→256K row deltas.
//!
//! With those three constants the model reproduces all six Table 5 rows
//! within ~2% (see tests) and the Figure 1 histogram shape.

use crate::config::{ModelSpec, ParallelConfig, RecomputeGranularity};

/// A100-80GB usable capacity (bytes) for OOM decisions.
pub const GPU_CAPACITY: u64 = 80 * GIB;

const GIB: u64 = 1024 * 1024 * 1024;
const MIB: u64 = 1024 * 1024;

/// Calibrated constants (see module docs).
const C_ACT_BASE: f64 = 48.0; // bytes per token per (hidden × layer), /TP·PP
const C_ACT_CF: f64 = 123.0; // ChunkFlow variant
const KV_OVERHEAD: f64 = 1.3;
/// Bytes per parameter for weights(bf16) + grads(fp32) + Adam m/v(fp32) +
/// fp32 master copy.
const BYTES_PER_PARAM: f64 = 18.0;
/// Per-GPU framework overhead (CUDA context, NCCL, workspace).
const FIXED_OVERHEAD: u64 = 3 * GIB + 205 * MIB; // 3.2 GiB
/// Full recompute stores layer-boundary checkpoints only: 2h of the
/// retained-activation bytes per layer (Korthikanti: s·b·h·2 bytes per
/// layer), i.e. 2/48 of our calibrated selective constant.
const FULL_CHECKPOINT_RATIO: f64 = 2.0 / C_ACT_BASE;
/// lm-head logits bytes per token per vocab entry (bf16) on the last stage
/// when a sequence is processed unchunked.
const LOGITS_BYTES: f64 = 2.0;

/// Per-GPU memory model for one (model, parallel strategy) pair.
#[derive(Clone, Debug)]
pub struct MemoryModel {
    pub model: ModelSpec,
    pub parallel: ParallelConfig,
}

impl MemoryModel {
    pub fn new(model: ModelSpec, parallel: ParallelConfig) -> Self {
        Self { model, parallel }
    }

    fn tp(&self) -> f64 {
        self.parallel.tp as f64
    }

    fn pp(&self) -> f64 {
        self.parallel.pp as f64
    }

    /// Weights + optimizer state + framework overhead, per GPU.
    pub fn fixed_bytes(&self) -> u64 {
        let params = self.model.param_count() as f64;
        (params * BYTES_PER_PARAM / (self.tp() * self.pp())) as u64 + FIXED_OVERHEAD
    }

    /// Baseline (Megatron) activation bytes per GPU for one in-flight
    /// micro-batch of `tokens`, under this strategy's recompute granularity.
    pub fn baseline_activation_bytes(&self, tokens: u64) -> u64 {
        let h = self.model.hidden_size as f64;
        let l = self.model.num_layers as f64;
        let a = self.model.num_heads as f64;
        let per_stage_layers = l / self.pp();
        let selective = C_ACT_BASE * h * per_stage_layers / self.tp() * tokens as f64;
        let bytes = match self.parallel.recompute {
            RecomputeGranularity::Selective => selective,
            RecomputeGranularity::Full => {
                // Layer-boundary checkpoints + one live layer (Megatron's 34h
                // per-layer term, uninflated) during the backward recompute.
                selective * FULL_CHECKPOINT_RATIO
                    + 34.0 * h / self.tp() * tokens as f64
            }
            RecomputeGranularity::None => {
                // Retains the attention score matrices too: O(a · s) extra
                // per token (the 5as term of Korthikanti).
                selective
                    + 5.0 * a * tokens as f64 / self.tp() * per_stage_layers * tokens as f64
            }
        };
        bytes as u64
    }

    /// Logits + loss buffers on the last pipeline stage for an unchunked
    /// sequence of `tokens` (ChunkFlow bounds this by ChunkSize instead).
    /// Full recomputation recomputes the logits chunk-wise too, so the
    /// buffer does not persist.
    pub fn lm_head_bytes(&self, tokens: u64) -> u64 {
        if self.parallel.recompute == RecomputeGranularity::Full {
            return 0;
        }
        (LOGITS_BYTES * tokens as f64 * self.model.vocab_size as f64 / self.tp()) as u64
    }

    /// KV-state bytes per GPU for `context_tokens` of stored prefix
    /// (ChunkFlow's StateStore; paper keeps it un-offloaded).
    pub fn kv_state_bytes(&self, context_tokens: u64) -> u64 {
        (self.model.kv_bytes_per_token() as f64 * KV_OVERHEAD / (self.tp() * self.pp())
            * context_tokens as f64) as u64
    }

    /// ChunkFlow activation bytes per GPU with `live_chunks` chunk
    /// activations retained (Alg. 2 bounds live_chunks <= K).
    pub fn chunkflow_activation_bytes(&self, chunk_size: u64, live_chunks: u64) -> u64 {
        let h = self.model.hidden_size as f64;
        let l = self.model.num_layers as f64;
        (C_ACT_CF * h * (l / self.pp()) / self.tp()
            * (chunk_size * live_chunks) as f64) as u64
    }

    /// Peak per-GPU bytes for a baseline micro-step processing one
    /// micro-batch of `tokens` (Figure 1's per-iteration footprint).
    pub fn baseline_peak(&self, tokens: u64) -> u64 {
        self.fixed_bytes() + self.baseline_activation_bytes(tokens) + self.lm_head_bytes(tokens)
    }

    /// Peak per-GPU bytes for a baseline 1F1B pipeline whose in-flight
    /// micro-batches have the given lengths, accounted per stage: stage 0
    /// holds the full in-flight activation window but no logits; the last
    /// stage holds at most one micro-batch's activations (its 1F1B depth
    /// is 1) plus that micro-batch's lm-head logits. For PP > 1 those live
    /// on different GPUs, so the peak is the max of the two footprints —
    /// not their sum (the old accounting, which overstated the peak and
    /// let `derive_baseline_config` over-provision). PP = 1 is unchanged:
    /// everything coexists on the single stage.
    pub fn baseline_pipeline_peak(&self, in_flight: &[u64]) -> u64 {
        let acts: u64 = in_flight.iter().map(|&t| self.baseline_activation_bytes(t)).sum();
        if self.parallel.pp <= 1 {
            let lm = in_flight.iter().map(|&t| self.lm_head_bytes(t)).max().unwrap_or(0);
            return self.fixed_bytes() + acts + lm;
        }
        let last_stage = in_flight
            .iter()
            .map(|&t| self.baseline_activation_bytes(t) + self.lm_head_bytes(t))
            .max()
            .unwrap_or(0);
        self.fixed_bytes() + acts.max(last_stage)
    }

    /// Peak per-GPU bytes for ChunkFlow with the given tunables and the
    /// maximum admitted context length (Table 5 rows).
    pub fn chunkflow_peak(&self, chunk_size: u64, k: u64, context_length: u64) -> u64 {
        self.fixed_bytes()
            + self.chunkflow_activation_bytes(chunk_size, k)
            + self.kv_state_bytes(context_length.saturating_sub(chunk_size))
    }

    /// [`Self::chunkflow_peak`] under this strategy's sequence-parallel
    /// degree. `sp <= 1` delegates verbatim (the sp=1 bit-identity
    /// contract). For `sp > 1` the peak case is a dependent group filling
    /// the context — exactly the chunks the shard rule
    /// ([`ParallelConfig::sp_shards`]) ring-shards — so each rank retains
    /// `1/sp` of a live chunk's query-row activations and `1/sp` of the KV
    /// state (ring attention keeps KV sharded; blocks stream through
    /// transiently during the exchange).
    pub fn chunkflow_peak_sp(&self, chunk_size: u64, k: u64, context_length: u64) -> u64 {
        let sp = self.parallel.sp.max(1);
        if sp <= 1 {
            return self.chunkflow_peak(chunk_size, k, context_length);
        }
        let shard_rows = chunk_size.div_ceil(sp);
        let kv_tokens = context_length.saturating_sub(chunk_size).div_ceil(sp);
        self.fixed_bytes()
            + self.chunkflow_activation_bytes(shard_rows, k)
            + self.kv_state_bytes(kv_tokens)
    }

    /// The three named components of [`Self::chunkflow_peak_sp`]. The
    /// static verifier (`verify`) re-derives the Table-5 bound per plan
    /// from these terms — only the activation term depends on ChunkSize
    /// and the live-chunk count, only the KV term depends on the context —
    /// and cross-checks that their sum equals the model's own peak.
    pub fn chunkflow_peak_terms(
        &self,
        chunk_size: u64,
        live_chunks: u64,
        context_length: u64,
    ) -> PeakTerms {
        let sp = self.parallel.sp.max(1);
        let (rows, kv_tokens) = if sp <= 1 {
            (chunk_size, context_length.saturating_sub(chunk_size))
        } else {
            (
                chunk_size.div_ceil(sp),
                context_length.saturating_sub(chunk_size).div_ceil(sp),
            )
        };
        PeakTerms {
            fixed: self.fixed_bytes(),
            activation: self.chunkflow_activation_bytes(rows, live_chunks),
            kv_state: self.kv_state_bytes(kv_tokens),
        }
    }

    /// Does a peak fit on the GPU?
    pub fn fits(&self, peak_bytes: u64) -> bool {
        peak_bytes <= GPU_CAPACITY
    }
}

/// Named components of a ChunkFlow peak-memory bound
/// (see [`MemoryModel::chunkflow_peak_terms`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PeakTerms {
    /// Parameters, gradients, optimizer state and framework overhead.
    pub fixed: u64,
    /// Live chunk activations: a function of ChunkSize (per-rank rows under
    /// sp) and the retained-chunk count, never of the max sequence length.
    pub activation: u64,
    /// Stored KV prefix state for the admitted context.
    pub kv_state: u64,
}

impl PeakTerms {
    pub fn total(&self) -> u64 {
        self.fixed + self.activation + self.kv_state
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelSpec, ParallelConfig, RecomputeGranularity};

    fn table5_model() -> MemoryModel {
        // Table 5 config: 7B, <4,4,1,selective>, K=1.
        MemoryModel::new(
            ModelSpec::preset("qwen2.5-7b").unwrap(),
            ParallelConfig::new(4, 1, RecomputeGranularity::Selective),
        )
    }

    fn gib(b: u64) -> f64 {
        b as f64 / GIB as f64
    }

    #[test]
    fn reproduces_table5_within_tolerance() {
        // Paper Table 5: (ctx, chunk) -> GiB.
        let rows: [(u64, u64, f64); 6] = [
            (32 * 1024, 2 * 1024, 41.6),
            (256 * 1024, 2 * 1024, 45.6),
            (32 * 1024, 4 * 1024, 47.5),
            (256 * 1024, 4 * 1024, 50.8),
            (32 * 1024, 8 * 1024, 59.3),
            (256 * 1024, 8 * 1024, 63.8),
        ];
        let m = table5_model();
        for (ctx, chunk, paper) in rows {
            let ours = gib(m.chunkflow_peak(chunk, 1, ctx));
            let rel = (ours - paper).abs() / paper;
            assert!(
                rel < 0.03,
                "ctx {ctx} chunk {chunk}: ours {ours:.1} GiB vs paper {paper} GiB ({rel:.3})"
            );
        }
    }

    #[test]
    fn figure1_peak_near_75gb() {
        // Megatron 7B/32K/selective, micro-batch = one 32K sequence.
        let m = table5_model();
        let peak = gib(m.baseline_peak(32 * 1024));
        assert!((peak - 75.0).abs() < 4.0, "peak {peak:.1} GiB, paper ~75 GB");
        assert!(m.fits(m.baseline_peak(32 * 1024)));
    }

    #[test]
    fn figure1_short_sequences_underutilize() {
        // Obs. 2: ~90% of micro-steps (len < 1K) use far less than peak.
        let m = table5_model();
        let short = gib(m.baseline_peak(1024));
        assert!(short < 45.0, "short-seq footprint {short:.1} GiB must be < 45 GB");
    }

    #[test]
    fn chunkflow_memory_nearly_ctx_independent() {
        // Table 5's headline: peak driven by ChunkSize, only weakly by
        // context (the KV term).
        let m = table5_model();
        let p32 = m.chunkflow_peak(4096, 1, 32 * 1024) as f64;
        let p256 = m.chunkflow_peak(4096, 1, 256 * 1024) as f64;
        assert!(p256 / p32 < 1.10, "256K adds only the KV slope: {}", p256 / p32);
    }

    #[test]
    fn chunkflow_scales_with_k() {
        let m = table5_model();
        let k1 = m.chunkflow_peak(4096, 1, 32 * 1024);
        let k4 = m.chunkflow_peak(4096, 4, 32 * 1024);
        let act1 = m.chunkflow_activation_bytes(4096, 1);
        let act4 = m.chunkflow_activation_bytes(4096, 4);
        assert_eq!(act4, 4 * act1);
        assert!(k4 > k1);
    }

    #[test]
    fn baseline_256k_oom_on_4_gpus_selective() {
        // Obs. 2: a 256K sequence cannot be trained on TP=4/PP=1 with
        // selective recompute — the motivation for 16-GPU configs.
        let m = table5_model();
        let peak = m.baseline_peak(256 * 1024);
        assert!(!m.fits(peak), "256K selective on 4 GPUs must OOM ({:.0} GiB)", gib(peak));
    }

    #[test]
    fn full_recompute_reduces_activation_memory() {
        let spec = ModelSpec::preset("qwen2.5-7b").unwrap();
        let sel = MemoryModel::new(
            spec.clone(),
            ParallelConfig::new(4, 4, RecomputeGranularity::Selective),
        );
        let full =
            MemoryModel::new(spec, ParallelConfig::new(4, 4, RecomputeGranularity::Full));
        let s = sel.baseline_activation_bytes(256 * 1024);
        let f = full.baseline_activation_bytes(256 * 1024);
        assert!(f < s / 3, "full recompute must slash activations: {f} vs {s}");
    }

    #[test]
    fn none_recompute_quadratic_in_sequence() {
        let m = MemoryModel::new(
            ModelSpec::preset("qwen2.5-7b").unwrap(),
            ParallelConfig::new(4, 1, RecomputeGranularity::None),
        );
        let a = m.baseline_activation_bytes(8 * 1024) as f64;
        let b = m.baseline_activation_bytes(16 * 1024) as f64;
        assert!(b / a > 2.5, "attention-score retention grows superlinearly: {}", b / a);
    }

    #[test]
    fn pipeline_peak_accounts_per_stage() {
        let m = MemoryModel::new(
            ModelSpec::preset("qwen2.5-7b").unwrap(),
            ParallelConfig::new(4, 4, RecomputeGranularity::Selective),
        );
        let act = m.baseline_activation_bytes(1024);
        let lm = m.lm_head_bytes(1024);
        // A single in-flight micro-batch peaks on whichever stage is
        // heavier: its activations alone (stage 0) vs activations + logits
        // (last stage).
        let single = m.baseline_pipeline_peak(&[1024]);
        assert_eq!(single, m.fixed_bytes() + act.max(act + lm));
        // A full in-flight window sums activations on stage 0 but never
        // adds the last stage's logits on top of that sum.
        let four = m.baseline_pipeline_peak(&[1024, 1024, 1024, 1024]);
        assert!(four > single);
        assert_eq!(four, m.fixed_bytes() + (4 * act).max(act + lm));
        assert!(
            four < m.fixed_bytes() + 4 * act + lm,
            "stage-0 and last-stage footprints must not be summed for PP > 1"
        );
    }

    #[test]
    fn pipeline_peak_pp1_unchanged() {
        // Single stage: everything coexists — the original accounting.
        let m = table5_model(); // PP = 1
        let act = m.baseline_activation_bytes(2048);
        let lm = m.lm_head_bytes(2048);
        assert_eq!(m.baseline_pipeline_peak(&[2048]), m.fixed_bytes() + act + lm);
        assert_eq!(
            m.baseline_pipeline_peak(&[2048, 2048]),
            m.fixed_bytes() + 2 * act + lm
        );
    }

    #[test]
    fn pipeline_peak_long_sequence_dominated_by_last_stage_or_stage0() {
        // A 32K in-flight head with short companions: the fix can only
        // shrink (or preserve) the old sum-everything accounting.
        let m = MemoryModel::new(
            ModelSpec::preset("qwen2.5-7b").unwrap(),
            ParallelConfig::new(4, 4, RecomputeGranularity::Selective),
        );
        let in_flight = [32 * 1024, 1024, 1024, 1024];
        let acts: u64 =
            in_flight.iter().map(|&t| m.baseline_activation_bytes(t)).sum();
        let lm_max = in_flight.iter().map(|&t| m.lm_head_bytes(t)).max().unwrap();
        let peak = m.baseline_pipeline_peak(&in_flight);
        assert!(peak <= m.fixed_bytes() + acts + lm_max, "never above the old sum");
        assert!(peak >= m.fixed_bytes() + acts, "stage 0 holds the full window");
    }

    #[test]
    fn sp_peak_identity_at_sp1_and_shrinks_at_sp4() {
        let m = table5_model(); // sp = 1
        for (ctx, chunk) in [(32 * 1024u64, 2 * 1024u64), (256 * 1024, 8 * 1024)] {
            assert_eq!(
                m.chunkflow_peak_sp(chunk, 2, ctx),
                m.chunkflow_peak(chunk, 2, ctx),
                "sp=1 must be the exact pre-SP peak"
            );
        }
        let mut sharded = table5_model();
        sharded.parallel.sp = 4;
        let p1 = m.chunkflow_peak_sp(8 * 1024, 2, 256 * 1024);
        let p4 = sharded.chunkflow_peak_sp(8 * 1024, 2, 256 * 1024);
        assert!(p4 < p1, "ring shards split activations and KV: {p4} vs {p1}");
        // The variable components shard; the fixed bytes do not.
        assert!(p4 > sharded.fixed_bytes());
    }

    #[test]
    fn bigger_models_need_more_gpus_for_weights() {
        // 72B at TP=8, PP=1 cannot even hold optimizer state; PP=4 helps.
        let spec = ModelSpec::preset("qwen2.5-72b").unwrap();
        let flat = MemoryModel::new(
            spec.clone(),
            ParallelConfig::new(8, 1, RecomputeGranularity::Selective),
        );
        assert!(flat.fixed_bytes() > GPU_CAPACITY);
        let pp4 =
            MemoryModel::new(spec, ParallelConfig::new(8, 4, RecomputeGranularity::Selective));
        assert!(pp4.fixed_bytes() < GPU_CAPACITY);
    }

    #[test]
    fn peak_terms_sum_to_the_model_peak_for_all_sp() {
        let spec = ModelSpec::preset("qwen2.5-7b").unwrap();
        for sp in [1u64, 2, 4] {
            let mut parallel = ParallelConfig::new(4, 2, RecomputeGranularity::Selective);
            parallel.sp = sp;
            let m = MemoryModel::new(spec.clone(), parallel);
            for (cs, k, ctx) in [(2048u64, 1u64, 32 * 1024u64), (8192, 4, 256 * 1024)] {
                let t = m.chunkflow_peak_terms(cs, k, ctx);
                assert_eq!(t.total(), m.chunkflow_peak_sp(cs, k, ctx), "sp={sp} cs={cs}");
            }
        }
    }

    #[test]
    fn activation_term_is_context_independent() {
        let m = table5_model();
        let a = m.chunkflow_peak_terms(4096, 2, 32 * 1024);
        let b = m.chunkflow_peak_terms(4096, 2, 256 * 1024);
        assert_eq!(a.fixed, b.fixed);
        assert_eq!(a.activation, b.activation, "Table-5 shape: activations track ChunkSize");
        assert!(b.kv_state > a.kv_state, "only KV state grows with context");
    }
}
