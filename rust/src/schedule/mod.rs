//! State-aware chunk scheduling — the paper's Algorithm 2.
//!
//! Given the dependent chunks of one long sequence (indexed 0..N-1) and the
//! retention budget `K`, produce an execution plan whose peak activation
//! memory is `K * ChunkSize` tokens instead of the full sequence length:
//!
//! - `N <= K`: forward 0..N retaining activations, then backward N-1..0.
//! - `N > K`: forward 0..N, *discarding* activations of the first `N-K`
//!   chunks (their attention key/value tensors are still written to the
//!   StateStore, and their losses are recorded); backward the retained last
//!   `K` chunks in reverse; then for each of the first `N-K` chunks in
//!   *descending* order, re-run the forward (reading KV from the StateStore
//!   — the "executed twice" forward of §4.2) and immediately backward.
//!
//! Note on the paper's listing: Algorithm 2 lines 24-29 iterate the
//! recompute pass in ascending index order. Chunk `i`'s backward needs the
//! KV-gradient contributions of every later chunk `j > i` (the paper's own
//! §4.2: "preceding chunks rely on the gradients of the key/value tensors
//! from subsequent chunks"), so the recompute+backward pass must run in
//! descending order; we implement it that way and treat the listing's loop
//! header as a typo. Peak retained activations stay ≤ K chunks either way.
//!
//! Standalone chunks are the `N = 1` special case: forward retaining, then
//! backward.

use crate::chunk::ChunkSet;

/// One operation in a chunk execution plan.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChunkOp {
    /// Forward pass; `retain` = keep activations for a later backward
    /// (false = discard, KV still saved — will require a recompute-forward).
    Forward { chunk: usize, retain: bool },
    /// Second forward of a discarded chunk, reading KV from the StateStore.
    RecomputeForward { chunk: usize },
    /// Backward pass (activations for `chunk` must currently be live).
    Backward { chunk: usize },
}

impl ChunkOp {
    pub fn chunk(&self) -> usize {
        match *self {
            ChunkOp::Forward { chunk, .. }
            | ChunkOp::RecomputeForward { chunk }
            | ChunkOp::Backward { chunk } => chunk,
        }
    }
}

/// Plan for one dependent-chunk group (or one standalone chunk).
#[derive(Clone, Debug)]
pub struct GroupPlan {
    /// Chunk ids (into the owning ChunkSet) in sequence order.
    pub chunk_ids: Vec<usize>,
    pub k: usize,
    pub ops: Vec<ChunkOp>,
}

impl GroupPlan {
    /// The plan's backward stream as `(position within group,
    /// needs_recompute)` pairs, in execution order — Algorithm 2's
    /// descending order. Shared by the 1F1B agenda builders
    /// (`pipeline::onef1b`) and the static verifier (`verify`), so the
    /// generated schedule and the checked contract come from one place.
    pub fn backward_order(&self) -> Vec<(usize, bool)> {
        let mut order = Vec::with_capacity(self.chunk_ids.len());
        let mut pending_rf = vec![false; self.chunk_ids.len()];
        for op in &self.ops {
            match *op {
                ChunkOp::RecomputeForward { chunk } => pending_rf[chunk] = true,
                ChunkOp::Backward { chunk } => order.push((chunk, pending_rf[chunk])),
                ChunkOp::Forward { .. } => {}
            }
        }
        order
    }
}

/// Algorithm 2 for one group of `n` dependent chunks. Chunk ids in `ops`
/// are *positions within the group* (0..n); `GroupPlan::chunk_ids` maps
/// them back to ChunkSet ids.
pub fn schedule_group(chunk_ids: &[usize], k: usize) -> GroupPlan {
    assert!(k >= 1, "K must be >= 1");
    let n = chunk_ids.len();
    assert!(n >= 1);
    let mut ops = Vec::with_capacity(3 * n);

    if n <= k {
        // Lines 4-11: all activations fit in the budget.
        for i in 0..n {
            ops.push(ChunkOp::Forward { chunk: i, retain: true });
        }
        for i in (0..n).rev() {
            ops.push(ChunkOp::Backward { chunk: i });
        }
    } else {
        // Lines 13-20: forward all, retaining only the last K.
        for i in 0..n {
            ops.push(ChunkOp::Forward { chunk: i, retain: i >= n - k });
        }
        // Lines 21-23: backward the retained chunks in reverse.
        for i in ((n - k)..n).rev() {
            ops.push(ChunkOp::Backward { chunk: i });
        }
        // Lines 24-29 (order corrected, see module docs): recompute + backward
        // the discarded chunks in descending order.
        for i in (0..(n - k)).rev() {
            ops.push(ChunkOp::RecomputeForward { chunk: i });
            ops.push(ChunkOp::Backward { chunk: i });
        }
    }
    GroupPlan { chunk_ids: chunk_ids.to_vec(), k, ops }
}

/// Full-step plan: every dependent group scheduled by Algorithm 2, plus each
/// standalone chunk as a trivial group. Groups are ordered long-to-short so
/// pipeline integration (state-aware 1F1B) can interleave standalone chunks
/// into dependent-chunk stalls.
#[derive(Clone, Debug)]
pub struct StepPlan {
    pub groups: Vec<GroupPlan>,
}

pub fn schedule_step(set: &ChunkSet, k: usize) -> StepPlan {
    let mut groups = Vec::new();
    for group in set.dependent_groups() {
        let ids: Vec<usize> = group.iter().map(|c| c.id).collect();
        groups.push(schedule_group(&ids, k));
    }
    for c in set.standalone_chunks() {
        groups.push(schedule_group(&[c.id], k));
    }
    StepPlan { groups }
}

/// Statistics of a plan used by tests, the simulator and EXPERIMENTS.md.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PlanStats {
    pub n_forward: usize,
    pub n_recompute: usize,
    pub n_backward: usize,
    /// Max number of chunk-activations simultaneously live.
    pub peak_live_activations: usize,
}

/// Validate plan legality and compute stats. Checks:
/// 1. forward order ascending within the group (KV dependency);
/// 2. every chunk's backward happens exactly once, with activations live;
/// 3. backward order descending (KV-gradient dependency);
/// 4. peak live activations <= K.
pub fn validate_group_plan(plan: &GroupPlan) -> anyhow::Result<PlanStats> {
    let n = plan.chunk_ids.len();
    let mut stats = PlanStats::default();
    let mut fwd_done = vec![false; n];
    let mut live = vec![false; n];
    let mut bwd_done = vec![false; n];
    let mut last_bwd: Option<usize> = None;
    let mut next_fwd = 0usize;
    let mut live_count = 0usize;

    for op in &plan.ops {
        match *op {
            ChunkOp::Forward { chunk, retain } => {
                anyhow::ensure!(chunk == next_fwd, "forward out of order: {chunk}");
                anyhow::ensure!(!fwd_done[chunk], "duplicate forward {chunk}");
                fwd_done[chunk] = true;
                next_fwd += 1;
                stats.n_forward += 1;
                if retain {
                    live[chunk] = true;
                    live_count += 1;
                }
            }
            ChunkOp::RecomputeForward { chunk } => {
                anyhow::ensure!(fwd_done[chunk], "recompute before first forward {chunk}");
                anyhow::ensure!(!live[chunk], "recompute of live chunk {chunk}");
                live[chunk] = true;
                live_count += 1;
                stats.n_recompute += 1;
            }
            ChunkOp::Backward { chunk } => {
                anyhow::ensure!(live[chunk], "backward without live activations {chunk}");
                anyhow::ensure!(!bwd_done[chunk], "duplicate backward {chunk}");
                if let Some(prev) = last_bwd {
                    anyhow::ensure!(
                        chunk < prev,
                        "backward order must be descending ({prev} then {chunk})"
                    );
                }
                last_bwd = Some(chunk);
                bwd_done[chunk] = true;
                live[chunk] = false;
                live_count -= 1;
                stats.n_backward += 1;
            }
        }
        stats.peak_live_activations = stats.peak_live_activations.max(live_count);
    }
    anyhow::ensure!(bwd_done.iter().all(|&b| b), "every chunk must run backward");
    anyhow::ensure!(live_count == 0, "activations leaked");
    Ok(stats)
}

impl StepPlan {
    /// Total ops across groups.
    pub fn total_ops(&self) -> usize {
        self.groups.iter().map(|g| g.ops.len()).sum()
    }

    /// Fraction of forward work executed twice (the recompute overhead the
    /// paper trades for constant memory).
    pub fn recompute_fraction(&self) -> f64 {
        let fwd: usize = self.groups.iter().map(|g| {
            g.ops.iter().filter(|o| matches!(o, ChunkOp::Forward { .. })).count()
        }).sum();
        let rec: usize = self.groups.iter().map(|g| {
            g.ops.iter().filter(|o| matches!(o, ChunkOp::RecomputeForward { .. })).count()
        }).sum();
        if fwd == 0 {
            0.0
        } else {
            rec as f64 / fwd as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chunk::construct_chunks;
    use crate::data::Sequence;
    use crate::util::prop::{check, ensure, gen_pair, gen_usize};

    #[test]
    fn small_group_all_retained() {
        // N=3, K=4: plain forward-then-reverse-backward, no recompute.
        let plan = schedule_group(&[10, 11, 12], 4);
        let stats = validate_group_plan(&plan).unwrap();
        assert_eq!(stats.n_forward, 3);
        assert_eq!(stats.n_recompute, 0);
        assert_eq!(stats.n_backward, 3);
        assert_eq!(stats.peak_live_activations, 3);
    }

    #[test]
    fn paper_figure5_k1() {
        // Figure 5(a): 4 dependent chunks, K=1 — one chunk re-executed per
        // discarded chunk and at most ONE live activation at any time.
        let plan = schedule_group(&[0, 1, 2, 3], 1);
        let stats = validate_group_plan(&plan).unwrap();
        assert_eq!(stats.n_forward, 4);
        assert_eq!(stats.n_recompute, 3, "first N-K=3 chunks forwarded twice");
        assert_eq!(stats.peak_live_activations, 1);
    }

    #[test]
    fn paper_figure5_k2() {
        // Figure 5(b): K=2 — two live activations, fewer recomputes.
        let plan = schedule_group(&[0, 1, 2, 3], 2);
        let stats = validate_group_plan(&plan).unwrap();
        assert_eq!(stats.n_recompute, 2);
        assert_eq!(stats.peak_live_activations, 2);
    }

    #[test]
    fn exact_op_sequence_k1_n3() {
        let plan = schedule_group(&[0, 1, 2], 1);
        use ChunkOp::*;
        assert_eq!(
            plan.ops,
            vec![
                Forward { chunk: 0, retain: false },
                Forward { chunk: 1, retain: false },
                Forward { chunk: 2, retain: true },
                Backward { chunk: 2 },
                RecomputeForward { chunk: 1 },
                Backward { chunk: 1 },
                RecomputeForward { chunk: 0 },
                Backward { chunk: 0 },
            ]
        );
    }

    #[test]
    fn standalone_is_trivial_group() {
        let plan = schedule_group(&[7], 1);
        let stats = validate_group_plan(&plan).unwrap();
        assert_eq!(stats.n_forward, 1);
        assert_eq!(stats.n_recompute, 0);
        assert_eq!(stats.peak_live_activations, 1);
    }

    #[test]
    fn step_plan_covers_all_chunks() {
        let batch = vec![
            Sequence { id: 0, len: 10_000 }, // 5 dependent chunks @2048
            Sequence { id: 1, len: 500 },
            Sequence { id: 2, len: 600 },
            Sequence { id: 3, len: 3_000 }, // 2 dependent chunks
        ];
        let set = construct_chunks(&batch, 2048);
        let plan = schedule_step(&set, 2);
        let mut bwd_chunks: Vec<usize> = Vec::new();
        for g in &plan.groups {
            validate_group_plan(g).unwrap();
            for op in &g.ops {
                if let ChunkOp::Backward { chunk } = op {
                    bwd_chunks.push(g.chunk_ids[*chunk]);
                }
            }
        }
        bwd_chunks.sort();
        assert_eq!(bwd_chunks, (0..set.chunks.len()).collect::<Vec<_>>());
    }

    #[test]
    fn recompute_fraction() {
        let batch = vec![Sequence { id: 0, len: 8192 }];
        let set = construct_chunks(&batch, 2048); // 4 chunks
        let plan = schedule_step(&set, 1);
        assert!((plan.recompute_fraction() - 0.75).abs() < 1e-9);
        let plan = schedule_step(&set, 4);
        assert_eq!(plan.recompute_fraction(), 0.0);
    }

    #[test]
    #[should_panic(expected = "K must be >= 1")]
    fn k_zero_rejected() {
        schedule_group(&[0], 0);
    }

    #[test]
    fn prop_plan_always_valid_and_memory_bounded() {
        let gen = gen_pair(gen_usize(1, 64), gen_usize(1, 20));
        check(500, gen, |(n, k)| {
            let ids: Vec<usize> = (0..*n).collect();
            let plan = schedule_group(&ids, *k);
            let stats =
                validate_group_plan(&plan).map_err(|e| format!("invalid plan: {e}"))?;
            ensure(stats.peak_live_activations <= *k, "peak live <= K")?;
            ensure(stats.n_forward == *n, "each chunk forwarded once initially")?;
            ensure(stats.n_backward == *n, "each chunk backwarded once")?;
            ensure(
                stats.n_recompute == n.saturating_sub(*k),
                "exactly max(N-K,0) recomputes",
            )?;
            Ok(())
        });
    }

    #[test]
    fn prop_schedule_conformance_pins_descending_recompute() {
        // Pins the documented Algorithm-2 line 24-29 typo fix: the
        // recompute+backward pass over the discarded chunks must run in
        // strictly DESCENDING index order (chunk i's backward needs the
        // KV-gradients of every later chunk), each recompute is immediately
        // consumed by its own backward, and initial forwards stay strictly
        // ascending. N up to 64, any K.
        let gen = gen_pair(gen_usize(1, 64), gen_usize(1, 64));
        check(500, gen, |(n, k)| {
            let ids: Vec<usize> = (0..*n).collect();
            let plan = schedule_group(&ids, *k);
            validate_group_plan(&plan).map_err(|e| format!("invalid plan: {e}"))?;
            let fwd: Vec<usize> = plan
                .ops
                .iter()
                .filter_map(|o| match o {
                    ChunkOp::Forward { chunk, .. } => Some(*chunk),
                    _ => None,
                })
                .collect();
            ensure(fwd.windows(2).all(|w| w[0] < w[1]), "forwards strictly ascending")?;
            let rec: Vec<usize> = plan
                .ops
                .iter()
                .filter_map(|o| match o {
                    ChunkOp::RecomputeForward { chunk } => Some(*chunk),
                    _ => None,
                })
                .collect();
            ensure(
                rec.windows(2).all(|w| w[0] > w[1]),
                "recompute pass strictly descending (Alg. 2 line 24-29 fix)",
            )?;
            ensure(
                rec == (0..n.saturating_sub(*k)).rev().collect::<Vec<_>>(),
                "recompute covers exactly the discarded chunks, high to low",
            )?;
            // Every recompute is immediately followed by that chunk's
            // backward: recomputed activations never accumulate.
            for (idx, op) in plan.ops.iter().enumerate() {
                if let ChunkOp::RecomputeForward { chunk } = op {
                    let next = plan.ops.get(idx + 1);
                    let consumed =
                        matches!(next, Some(ChunkOp::Backward { chunk: b }) if b == chunk);
                    ensure(consumed, "recompute immediately consumed by its backward")?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_memory_never_scales_with_sequence_length() {
        // The paper's core claim: with fixed K, growing N leaves peak
        // activation memory flat.
        let gen = gen_usize(1, 200);
        check(100, gen, |n| {
            let ids: Vec<usize> = (0..*n).collect();
            let plan = schedule_group(&ids, 2);
            let stats = validate_group_plan(&plan).map_err(|e| e.to_string())?;
            ensure(stats.peak_live_activations <= 2, "peak bounded by K=2 for any N")?;
            Ok(())
        });
    }
}
