//! Stage-parallel pipeline executor: run state-aware 1F1B for real.
//!
//! The simulator (`pipeline::simulate`) predicts what the paper's schedules
//! *should* do; this module actually does it. One OS thread per pipeline
//! stage drives a [`StageBackend`] (a contiguous layer range of the
//! reference backend, embedding on stage 0, LM head + loss on the last)
//! through the **same `Op` agendas** `onef1b::standard_1f1b_agendas` /
//! `state_aware_1f1b_agendas` produce — the executor and the simulator
//! share one scheduling source of truth. Stage boundaries exchange the two
//! typed handoffs of `runtime::stage`:
//! [`ActivationHandoff`] downstream after every (recompute-)forward,
//! [`GradHandoff`] upstream after every backward.
//!
//! Execution semantics mirror the simulator exactly: each stage executes
//! its agenda strictly in order, an op starting once its cross-stage inputs
//! have arrived. Arrival order on a boundary can differ from the receiving
//! stage's agenda order (warmup depth differs per stage, so one stage may
//! emit a recompute-forward earlier relative to plain forwards than its
//! neighbor consumes it); an [`Inbox`] buffers early messages so execution
//! order stays agenda order regardless.
//!
//! Per stage, the executor owns the paper's per-stage state:
//!
//! - a KV store of its own layers' K/V per forwarded chunk (prefixes are
//!   assembled stage-locally — KV never crosses a boundary);
//! - pending KV cotangents chained from later chunks' `d_kv_in`
//!   (Algorithm 2's explicit chain rule, at stage granularity);
//! - retained activation caches: a chunk whose agenda carries a
//!   recompute-forward is discarded at first forward and rebuilt by the
//!   recompute — the K-budget shows up as the per-stage cache high-water
//!   mark;
//! - its slice of the parameter gradients (full-arity buffers; the tied
//!   embedding accumulates on both boundary stages and the final sum
//!   reproduces the monolithic backward).
//!
//! Every op records wall-clock start/end against a shared epoch, so the
//! result carries a *measured* [`Timeline`] whose bubble ratio can sit next
//! to the simulator's predicted one.

use std::collections::BTreeMap;
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

use super::policy::PolicyKind;
use super::{Op, OpKind, ScheduledOp, Timeline};
use crate::chunk::{Chunk, ChunkKind, ChunkSet, Segment};
use crate::runtime::{
    ActivationHandoff, Backend, ChunkInputs, GradHandoff, Manifest, ReferenceBackend,
    StageBackend, StageCache, StagePartition,
};
use crate::util::fault;
use crate::util::pool::BufferPool;

/// Handoff deadlines never drop below this, however small the problem —
/// a loaded CI box must not produce false wedge reports.
const HANDOFF_TIMEOUT_FLOOR: Duration = Duration::from_secs(60);
/// And never above this: a genuinely wedged pipeline should fail within
/// the hour even for huge configurations.
const HANDOFF_TIMEOUT_CAP: Duration = Duration::from_secs(3600);

/// Tuning knobs for one executor run.
#[derive(Clone, Debug, Default)]
pub struct ExecOptions {
    /// How long a stage waits on a boundary channel before declaring the
    /// pipeline wedged. `None` derives a deadline from the cost model via
    /// [`derived_handoff_timeout`] (floor 60s); the CLI exposes an
    /// override as `--handoff-timeout-secs`.
    pub handoff_timeout: Option<Duration>,
    /// Uneven stage partition (`--partition a,b,c`). `None` runs the equal
    /// partition — the exact pre-elastic layer ranges, bit for bit.
    pub partition: Option<StagePartition>,
    /// Agenda-generating schedule policy for `execute_state_aware*`. The
    /// default ([`PolicyKind::StateAware1F1B`]) produces agendas
    /// bit-identical to the pre-policy path.
    pub policy: PolicyKind,
}

/// Bounded-backoff retry for supervised execution. The default policy
/// (`max_retries: 0`) fails fast; `--max-retries` opts into recovery.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Retries after the first failed attempt (0 = fail fast).
    pub max_retries: u32,
    /// Sleep before the first retry; doubles per retry up to the cap.
    pub backoff: Duration,
    pub backoff_cap: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_retries: 0,
            backoff: Duration::from_millis(50),
            backoff_cap: Duration::from_secs(2),
        }
    }
}

impl RetryPolicy {
    /// Fail-fast convenience used by non-CLI callers.
    pub fn none() -> Self {
        Self::default()
    }

    pub fn with_retries(max_retries: u32) -> Self {
        RetryPolicy { max_retries, ..Self::default() }
    }
}

/// Handoff deadline scaled from the cost model's view of the work between
/// two handoffs: every pipeline item costs at most one forward + backward
/// + recompute over all layers (~3 · 24·h² FLOPs per token-layer), and a
/// stage blocked on a neighbor can at worst be waiting behind the whole
/// batch's worth of such ops. Dividing by an intentionally pessimistic
/// 100 MFLOP/s floor rate keeps the deadline generous on slow shared CI
/// hardware; the [`HANDOFF_TIMEOUT_FLOOR`]/[`HANDOFF_TIMEOUT_CAP`] clamps
/// bound it to [60s, 1h].
pub fn derived_handoff_timeout(m: &Manifest, num_items: usize) -> Duration {
    let h = m.hidden_size as f64;
    let per_token_layer = 24.0 * h * h;
    let flops = 3.0 * per_token_layer
        * m.num_layers as f64
        * m.chunk_size as f64
        * num_items.max(1) as f64;
    let secs = (flops / 1e8)
        .clamp(HANDOFF_TIMEOUT_FLOOR.as_secs_f64(), HANDOFF_TIMEOUT_CAP.as_secs_f64());
    Duration::from_secs_f64(secs)
}

/// Render a panic payload for error messages.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Run `f` under supervision: a failed attempt (error *or* panic) is
/// retried with bounded exponential backoff up to `retry.max_retries`
/// times. Returns the value plus how many retries were consumed.
///
/// Recovery is exact by construction: the executor's attempts are pure
/// functions of (params, chunk set, items) — stage threads are joined by
/// `std::thread::scope` before an attempt returns and channels die with
/// it, so a retry starts from a clean slate and the recovered result is
/// bit-identical to a fault-free run (the determinism-lattice contract).
pub fn supervise<T>(
    label: &str,
    retry: &RetryPolicy,
    mut f: impl FnMut() -> anyhow::Result<T>,
) -> anyhow::Result<(T, u32)> {
    let mut backoff = retry.backoff;
    let mut retries = 0u32;
    loop {
        let attempt = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f()))
            .unwrap_or_else(|payload| {
                Err(anyhow::anyhow!("{label} panicked: {}", panic_message(payload.as_ref())))
            });
        match attempt {
            Ok(v) => return Ok((v, retries)),
            Err(e) if retries < retry.max_retries => {
                retries += 1;
                crate::warn_!(
                    "{label}: attempt {retries}/{} failed ({e:#}); retrying in {:?}",
                    retry.max_retries + 1,
                    backoff
                );
                std::thread::sleep(backoff);
                backoff = (backoff * 2).min(retry.backoff_cap);
            }
            Err(e) => {
                return Err(e.context(format!(
                    "{label}: failed after {} attempt(s)",
                    retries + 1
                )))
            }
        }
    }
}

/// Everything the executor needs to run one chunk (pipeline item) besides
/// the KV plumbing it owns.
#[derive(Clone, Debug)]
pub struct ExecItem {
    /// Fixed-shape chunk inputs. `kv_in` is ignored (each stage assembles
    /// its local prefix itself); `prefix_len` must equal
    /// `prefix_items.len() * chunk_size`.
    pub inputs: ChunkInputs<f64>,
    /// Item ids of the same sequence's earlier chunks, ascending (empty for
    /// standalone chunks).
    pub prefix_items: Vec<usize>,
}

/// Result of one pipelined execution over a chunk set.
pub struct ExecOutcome {
    /// Parameter gradients summed over stages — same unscaled convention as
    /// `Trainer::compute_gradients`.
    pub grads: Vec<Vec<f64>>,
    pub loss_sum: f64,
    pub tok_sum: f64,
    /// Measured wall-clock Gantt (seconds from the executor epoch); its
    /// `bubble_ratio()` is the *measured* counterpart of the simulator's
    /// predicted one.
    pub timeline: Timeline,
    /// Per-stage executed op order — conformance evidence against the
    /// agendas the run was driven by.
    pub op_log: Vec<Vec<Op>>,
    /// Peak live activation caches on any single stage.
    pub act_peak_chunks: usize,
    /// Peak stage-local KV bytes, summed over stages. Unlike the
    /// single-stage trainer's per-group metric this spans the whole batch
    /// (groups execute concurrently in the pipeline).
    pub kv_peak_bytes: u64,
}

/// Execute a chunk set under the state-aware 1F1B schedule on `p` stages
/// with retention budget `k`. Agendas come from
/// [`state_aware_1f1b_agendas`] — the exact lists the simulator runs.
pub fn execute_state_aware(
    backend: &ReferenceBackend,
    set: &ChunkSet,
    items: &[ExecItem],
    k: usize,
    p: usize,
) -> anyhow::Result<ExecOutcome> {
    execute_state_aware_with(backend, set, items, k, p, ExecOptions::default())
}

/// [`execute_state_aware`] with explicit [`ExecOptions`].
pub fn execute_state_aware_with(
    backend: &ReferenceBackend,
    set: &ChunkSet,
    items: &[ExecItem],
    k: usize,
    p: usize,
    opts: ExecOptions,
) -> anyhow::Result<ExecOutcome> {
    anyhow::ensure!(
        set.chunks.len() == items.len(),
        "chunk set has {} chunks but {} exec items were given",
        set.chunks.len(),
        items.len()
    );
    let (agendas, _edges) = opts.policy.agendas(set, k, p);
    // Same-stage precedence edges are satisfied by construction: each stage
    // executes its agenda strictly in order, and every policy emits units
    // in an edge-consistent order (the simulator relies on the same fact
    // for progress).
    execute_agendas_with(backend, &agendas, items, opts)
}

/// Supervised [`execute_state_aware_with`]: stage failures (panic or
/// handoff deadline) retry the whole micro-step under `retry`. Returns
/// the outcome plus the number of retries consumed.
pub fn execute_state_aware_supervised(
    backend: &ReferenceBackend,
    set: &ChunkSet,
    items: &[ExecItem],
    k: usize,
    p: usize,
    opts: ExecOptions,
    retry: &RetryPolicy,
) -> anyhow::Result<(ExecOutcome, u32)> {
    supervise("pipeline executor", retry, || {
        execute_state_aware_with(backend, set, items, k, p, opts.clone())
    })
}

/// Execute explicit per-stage agendas (the executor's core). Exposed so
/// conformance tests can drive hand-built or standard-1F1B agendas too.
pub fn execute_agendas(
    backend: &ReferenceBackend,
    agendas: &[Vec<Op>],
    items: &[ExecItem],
) -> anyhow::Result<ExecOutcome> {
    execute_agendas_with(backend, agendas, items, ExecOptions::default())
}

/// [`execute_agendas`] with explicit [`ExecOptions`].
pub fn execute_agendas_with(
    backend: &ReferenceBackend,
    agendas: &[Vec<Op>],
    items: &[ExecItem],
    opts: ExecOptions,
) -> anyhow::Result<ExecOutcome> {
    let p = agendas.len();
    anyhow::ensure!(p >= 1, "need at least one stage");
    for op in agendas.iter().flatten() {
        anyhow::ensure!(
            op.item < items.len(),
            "agenda op {op:?} references item {} but only {} items were given",
            op.item,
            items.len()
        );
    }
    // Resolve the stage partition: explicit (elastic) or equal. The equal
    // resolution produces the exact `stage_layer_range` ranges
    // `StageBackend::new` derived before partitions were pluggable.
    let num_layers = backend.manifest().num_layers;
    let partition = match &opts.partition {
        Some(part) => {
            anyhow::ensure!(
                part.num_stages() == p,
                "partition has {} stages but {p} agendas were given",
                part.num_stages()
            );
            anyhow::ensure!(
                part.num_layers() == num_layers,
                "partition covers {} layers but the model has {num_layers}",
                part.num_layers()
            );
            part.clone()
        }
        None => StagePartition::equal(num_layers, p)?,
    };
    // Retention policy, derived from the agendas themselves (shared with
    // the static verifier — `pipeline::derive_retain`).
    let retain = super::derive_retain(agendas, items.len());

    // Boundary channels: activations flow s -> s+1, gradients s+1 -> s.
    let mut act_tx: Vec<Option<Sender<ActivationHandoff>>> = (0..p).map(|_| None).collect();
    let mut act_rx: Vec<Option<Receiver<ActivationHandoff>>> = (0..p).map(|_| None).collect();
    let mut grad_tx: Vec<Option<Sender<GradHandoff>>> = (0..p).map(|_| None).collect();
    let mut grad_rx: Vec<Option<Receiver<GradHandoff>>> = (0..p).map(|_| None).collect();
    for s in 0..p.saturating_sub(1) {
        let (tx, rx) = std::sync::mpsc::channel();
        act_tx[s] = Some(tx);
        act_rx[s + 1] = Some(rx);
        let (tx, rx) = std::sync::mpsc::channel();
        grad_tx[s + 1] = Some(tx);
        grad_rx[s] = Some(rx);
    }

    let retain = &retain;
    let epoch = Instant::now();
    let handoff_timeout = opts
        .handoff_timeout
        .unwrap_or_else(|| derived_handoff_timeout(backend.manifest(), items.len()));
    // `thread::scope` is the teardown guarantee the supervisor builds on:
    // every stage thread is joined before this function returns, however
    // it failed, and the boundary channels die with the scope — a retry
    // never races a leaked thread from a previous attempt.
    let results: Vec<anyhow::Result<StageResult>> = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(p);
        let chans = act_tx.into_iter().zip(act_rx).zip(grad_tx).zip(grad_rx);
        for (s, (((atx, arx), gtx), grx)) in chans.enumerate() {
            let agenda = &agendas[s];
            let layers = partition.range(s);
            handles.push(scope.spawn(move || {
                run_stage(
                    backend,
                    s,
                    p,
                    layers,
                    agenda,
                    items,
                    retain,
                    atx,
                    arx,
                    gtx,
                    grx,
                    epoch,
                    handoff_timeout,
                )
            }));
        }
        handles
            .into_iter()
            .map(|h| {
                h.join().unwrap_or_else(|payload| {
                    Err(anyhow::anyhow!(
                        "stage thread panicked: {}",
                        panic_message(payload.as_ref())
                    ))
                })
            })
            .collect()
    });

    // Aggregate: sum per-stage grads (slots are disjoint except the tied
    // embedding, which legitimately accumulates from both boundary stages).
    let mut grads = backend.zero_grads();
    let (mut loss, mut toks) = (0.0f64, 0.0f64);
    let mut op_log = Vec::with_capacity(p);
    let mut ops_all: Vec<ScheduledOp> = Vec::new();
    let mut act_peak = 0usize;
    let mut kv_peak = 0u64;
    for (s, r) in results.into_iter().enumerate() {
        let r = r.map_err(|e| e.context(format!("pipeline stage {s}")))?;
        for (g, d) in grads.iter_mut().zip(&r.d_params) {
            for (x, y) in g.iter_mut().zip(d) {
                *x += *y;
            }
        }
        loss += r.loss_sum;
        toks += r.tok_sum;
        op_log.push(r.ops.iter().map(|o| o.op).collect());
        act_peak = act_peak.max(r.act_peak);
        kv_peak += r.kv_peak_bytes;
        ops_all.extend(r.ops);
    }
    let makespan = ops_all.iter().map(|o| o.end).fold(0.0, f64::max);
    let busy = ops_all.iter().map(|o| o.end - o.start).sum();
    Ok(ExecOutcome {
        grads,
        loss_sum: loss,
        tok_sum: toks,
        timeline: Timeline { num_stages: p, ops: ops_all, makespan, busy },
        op_log,
        act_peak_chunks: act_peak,
        kv_peak_bytes: kv_peak,
    })
}

/// One data-parallel replica group's work: its rank-local chunk set and the
/// exec items built against the rank-local (re-densified) chunk ids.
pub struct ReplicaSpec {
    pub set: ChunkSet,
    pub items: Vec<ExecItem>,
}

/// Execute data-parallel replica groups concurrently: each rank runs the
/// state-aware 1F1B executor ([`execute_state_aware`] — its own `p` stage
/// threads) over its rank-local chunk assignment. Outcomes come back in
/// rank order; the gradient reduction (the trainer's deterministic
/// rank-ordered sum) is the caller's job, mirroring how a real DP group
/// separates compute from the all-reduce.
pub fn execute_replica_groups(
    backend: &ReferenceBackend,
    replicas: &[ReplicaSpec],
    k: usize,
    p: usize,
) -> anyhow::Result<Vec<ExecOutcome>> {
    execute_replica_groups_with(backend, replicas, k, p, ExecOptions::default())
}

/// [`execute_replica_groups`] with explicit [`ExecOptions`].
pub fn execute_replica_groups_with(
    backend: &ReferenceBackend,
    replicas: &[ReplicaSpec],
    k: usize,
    p: usize,
    opts: ExecOptions,
) -> anyhow::Result<Vec<ExecOutcome>> {
    anyhow::ensure!(!replicas.is_empty(), "need at least one replica group");
    let opts = &opts;
    let results: Vec<anyhow::Result<ExecOutcome>> = std::thread::scope(|scope| {
        let handles: Vec<_> = replicas
            .iter()
            .map(|r| {
                scope.spawn(move || {
                    execute_state_aware_with(backend, &r.set, &r.items, k, p, opts.clone())
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| {
                h.join().unwrap_or_else(|payload| {
                    Err(anyhow::anyhow!(
                        "replica thread panicked: {}",
                        panic_message(payload.as_ref())
                    ))
                })
            })
            .collect()
    });
    results
        .into_iter()
        .enumerate()
        .map(|(r, res)| res.map_err(|e| e.context(format!("dp rank {r}"))))
        .collect()
}

/// Supervised [`execute_replica_groups_with`]: any rank failing (panic or
/// handoff deadline) retries the whole replica micro-step under `retry`.
/// All ranks rerun together so the deterministic rank-ordered reduction
/// sees a consistent set of outcomes — recovered gradients stay
/// bit-identical to a fault-free run.
pub fn execute_replica_groups_supervised(
    backend: &ReferenceBackend,
    replicas: &[ReplicaSpec],
    k: usize,
    p: usize,
    opts: ExecOptions,
    retry: &RetryPolicy,
) -> anyhow::Result<(Vec<ExecOutcome>, u32)> {
    supervise("replica group executor", retry, || {
        execute_replica_groups_with(backend, replicas, k, p, opts.clone())
    })
}

/// Per-stage results funneled back to the coordinator.
struct StageResult {
    d_params: Vec<Vec<f64>>,
    loss_sum: f64,
    tok_sum: f64,
    ops: Vec<ScheduledOp>,
    act_peak: usize,
    kv_peak_bytes: u64,
}

/// Order-tolerant boundary receiver: messages can arrive earlier than the
/// receiving stage's agenda consumes them (neighbor stages interleave
/// forwards and backward units differently — warmup depth is per-stage), so
/// early arrivals are stashed by key until the agenda asks for them.
struct Inbox<K: Ord, T> {
    rx: Option<Receiver<T>>,
    pending: BTreeMap<K, T>,
}

impl<K: Ord + Copy + std::fmt::Debug, T> Inbox<K, T> {
    fn new(rx: Option<Receiver<T>>) -> Self {
        Self { rx, pending: BTreeMap::new() }
    }

    /// Receive the message with key `want`, buffering everything else.
    /// `op` is the waiting stage's current agenda op, so a timeout names
    /// exactly who is stuck on what.
    #[allow(clippy::too_many_arguments)]
    fn recv_for(
        &mut self,
        want: K,
        key_of: impl Fn(&T) -> K,
        stage: usize,
        what: &str,
        op: Op,
        timeout: Duration,
    ) -> anyhow::Result<T> {
        if let Some(msg) = self.pending.remove(&want) {
            return Ok(msg);
        }
        let rx = self
            .rx
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("stage {stage}: no {what} channel for {want:?}"))?;
        loop {
            let msg = rx.recv_timeout(timeout).map_err(|e| match e {
                RecvTimeoutError::Timeout => anyhow::anyhow!(
                    "stage {stage}: timed out after {timeout:?} waiting for the {what} of \
                     item {} at op {op:?} (deadlocked agendas or a wedged neighbor?)",
                    op.item
                ),
                RecvTimeoutError::Disconnected => anyhow::anyhow!(
                    "stage {stage}: neighbor exited before sending the {what} of item {} \
                     at op {op:?}",
                    op.item
                ),
            })?;
            let key = key_of(&msg);
            if key == want {
                return Ok(msg);
            }
            anyhow::ensure!(
                self.pending.insert(key, msg).is_none(),
                "stage {stage}: duplicate {what} for {key:?}"
            );
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn run_stage(
    backend: &ReferenceBackend,
    s: usize,
    p: usize,
    layers: std::ops::Range<usize>,
    agenda: &[Op],
    items: &[ExecItem],
    retain: &[bool],
    act_tx: Option<Sender<ActivationHandoff>>,
    act_rx: Option<Receiver<ActivationHandoff>>,
    grad_tx: Option<Sender<GradHandoff>>,
    grad_rx: Option<Receiver<GradHandoff>>,
    epoch: Instant,
    handoff_timeout: Duration,
) -> anyhow::Result<StageResult> {
    let stage = StageBackend::with_layers(backend, s, p, layers)?;
    let m = backend.manifest();
    let c = m.chunk_size;
    let hd = m.num_heads * m.head_dim;
    let lr = stage.layers.len();
    let kv_unit_elems = stage.kv_elements(c);
    let kv_unit_bytes = (kv_unit_elems * std::mem::size_of::<f64>()) as u64;

    // Stage-local state (see module docs).
    let mut kv_store: BTreeMap<usize, Vec<f64>> = BTreeMap::new();
    let (mut kv_bytes, mut kv_peak) = (0u64, 0u64);
    let mut g_kv: BTreeMap<usize, Vec<f64>> = BTreeMap::new();
    let mut caches: BTreeMap<usize, StageCache> = BTreeMap::new();
    let mut act_peak = 0usize;
    let mut d_params = backend.zero_grads();
    let (mut loss, mut toks) = (0.0f64, 0.0f64);
    let mut ops: Vec<ScheduledOp> = Vec::with_capacity(agenda.len());

    let mut act_in: Inbox<(usize, bool), ActivationHandoff> = Inbox::new(act_rx);
    let mut grad_in: Inbox<usize, GradHandoff> = Inbox::new(grad_rx);

    // Per-op scratch (KV-prefix concat buffers, zero KV cotangents, pending
    // KV accumulators) recycles through a stage-local arena instead of
    // hitting the allocator every op. Single-owner: this thread only.
    let mut arena = BufferPool::new(4);

    for &op in agenda {
        // Fault site: one evaluation per agenda op on every stage, so an
        // armed occurrence kills exactly one op mid-step.
        fault::maybe_panic(fault::STAGE_PANIC);
        let item = &items[op.item];
        match op.kind {
            OpKind::Fwd | OpKind::RecomputeFwd => {
                let recompute = op.kind == OpKind::RecomputeFwd;
                let x_in = if stage.is_first() {
                    None
                } else {
                    let h = act_in.recv_for(
                        (op.item, recompute),
                        |h| (h.item, h.recompute),
                        s,
                        "activation",
                        op,
                        handoff_timeout,
                    )?;
                    Some(h.x)
                };
                let start = epoch.elapsed().as_secs_f64();
                anyhow::ensure!(
                    item.inputs.prefix_len == item.prefix_items.len() * c,
                    "item {}: prefix_len {} != {} prefix chunks x {c}",
                    op.item,
                    item.inputs.prefix_len,
                    item.prefix_items.len()
                );
                // Assemble the stage-local KV prefix from this stage's own
                // store ([Lr, 2, P, H, D] from per-chunk [Lr, 2, C, H, D]).
                let parts: Vec<&Vec<f64>> = item
                    .prefix_items
                    .iter()
                    .map(|i| {
                        kv_store.get(i).ok_or_else(|| {
                            anyhow::anyhow!("stage {s}: missing KV of chunk {i} for {op:?}")
                        })
                    })
                    .collect::<anyhow::Result<_>>()?;
                let mut kv_in = arena.acquire(lr * 2 * item.prefix_items.len() * c * hd);
                crate::train::concat_prefix_into(&parts, lr, c, hd, &mut kv_in);
                let inputs = ChunkInputs { kv_in, ..item.inputs.clone() };
                // Zero-copy: the upstream activation Vec moves straight into
                // the stage's layer range.
                let out = stage.forward(&inputs, x_in)?;
                arena.release(inputs.kv_in);
                if !recompute {
                    anyhow::ensure!(
                        kv_store.insert(op.item, out.kv_own).is_none(),
                        "stage {s}: duplicate forward of chunk {}",
                        op.item
                    );
                    kv_bytes += kv_unit_bytes;
                    kv_peak = kv_peak.max(kv_bytes);
                }
                // Retain the cache unless Algorithm 2 discards it (it will
                // come back through this chunk's recompute-forward).
                if retain[op.item] || recompute {
                    caches.insert(op.item, out.cache);
                    act_peak = act_peak.max(caches.len());
                }
                // End before the send so cross-stage timestamps are a
                // dataflow proof: the receiver's start can never precede
                // the sender's recorded end.
                let end = epoch.elapsed().as_secs_f64();
                ops.push(ScheduledOp { op, stage: s, start, end });
                if let Some(tx) = &act_tx {
                    let x = out.x_out.ok_or_else(|| {
                        anyhow::anyhow!("stage {s}: interior stage produced no activation")
                    })?;
                    // Fault site: delay a handoff to simulate a straggler
                    // stage (drives the timeout path in tests).
                    fault::maybe_sleep_ms(fault::HANDOFF_DELAY, 100);
                    tx.send(ActivationHandoff { item: op.item, recompute, x })
                        .map_err(|_| anyhow::anyhow!("stage {s}: downstream stage hung up"))?;
                }
            }
            OpKind::Bwd => {
                let d_x_out = if stage.is_last() {
                    None
                } else {
                    let h = grad_in.recv_for(
                        op.item,
                        |h| h.item,
                        s,
                        "gradient",
                        op,
                        handoff_timeout,
                    )?;
                    Some(h.d_x)
                };
                let start = epoch.elapsed().as_secs_f64();
                let cache = caches.remove(&op.item).ok_or_else(|| {
                    anyhow::anyhow!(
                        "stage {s}: backward of chunk {} without live activations",
                        op.item
                    )
                })?;
                let g_own = g_kv
                    .remove(&op.item)
                    .unwrap_or_else(|| arena.acquire(kv_unit_elems));
                let inputs = ChunkInputs { kv_in: Vec::new(), ..item.inputs.clone() };
                // Zero-copy: the downstream cotangent Vec moves straight in.
                let out = stage.backward(&inputs, &cache, d_x_out, &g_own, &mut d_params)?;
                arena.release(g_own);
                // Chain d_kv_in into earlier chunks' pending KV cotangents —
                // Algorithm 2's explicit chain rule at stage granularity.
                scatter_stage_kv_grad(
                    &out.d_kv_in,
                    &item.prefix_items,
                    &mut g_kv,
                    lr,
                    c,
                    hd,
                    kv_unit_elems,
                    &mut arena,
                );
                if stage.is_last() {
                    loss += cache.loss_sum();
                    toks += cache.n_tok();
                }
                // Backwards run in descending dependency order, so once a
                // chunk backed up its own KV can never be a prefix again.
                if kv_store.remove(&op.item).is_some() {
                    kv_bytes -= kv_unit_bytes;
                }
                let end = epoch.elapsed().as_secs_f64();
                ops.push(ScheduledOp { op, stage: s, start, end });
                if let Some(tx) = &grad_tx {
                    let d_x = out.d_x_in.ok_or_else(|| {
                        anyhow::anyhow!("stage {s}: interior stage produced no input cotangent")
                    })?;
                    fault::maybe_sleep_ms(fault::HANDOFF_DELAY, 100);
                    tx.send(GradHandoff { item: op.item, d_x })
                        .map_err(|_| anyhow::anyhow!("stage {s}: upstream stage hung up"))?;
                }
            }
        }
    }
    Ok(StageResult {
        d_params,
        loss_sum: loss,
        tok_sum: toks,
        ops,
        act_peak,
        kv_peak_bytes: kv_peak,
    })
}

/// Build the fixed-shape exec items for a chunk set from per-sequence
/// token streams — the trainer's exact input assembly
/// ([`crate::train::chunk_inputs_for`]: padding positions 1_000_000+i,
/// segment -1, cross-chunk targets) plus each chunk's prefix chain.
pub fn build_exec_items(
    backend: &ReferenceBackend,
    set: &ChunkSet,
    tokens: &BTreeMap<u64, Vec<u32>>,
    seq_len: &BTreeMap<u64, u64>,
) -> Vec<ExecItem> {
    let c = backend.manifest().chunk_size;
    let mut prefix_of: Vec<Vec<usize>> = vec![Vec::new(); set.chunks.len()];
    for group in set.dependent_groups() {
        let ids: Vec<usize> = group.iter().map(|ch| ch.id).collect();
        for (i, &id) in ids.iter().enumerate() {
            prefix_of[id] = ids[..i].to_vec();
        }
    }
    set.chunks
        .iter()
        .map(|chunk| {
            let prefix_items = std::mem::take(&mut prefix_of[chunk.id]);
            let inputs = crate::train::chunk_inputs_for::<f64>(
                chunk,
                c,
                tokens,
                seq_len,
                prefix_items.len() * c,
            );
            ExecItem { inputs, prefix_items }
        })
        .collect()
}

/// [`build_exec_items`] under chunk-aware sequence parallelism: every
/// dependent chunk with more than one shard (the
/// [`crate::config::ParallelConfig::sp_shards`] rule — short/standalone
/// chunks never shard) expands into `shards` consecutive exec items, each a
/// full fixed-shape chunk whose live extent is the unsharded chunk's rows
/// `[0, hi)` with loss masked to the shard's owned rows `[lo, hi)`
/// ([`crate::train::sp_shard_inputs`]). Returns the *expanded* chunk set
/// (shard chunks re-indexed within their group; each shard chunk's segment
/// is the owned row range, so schedules and cost proxies see the sharded
/// work) alongside its items, so the executor runs unchanged.
///
/// Why this is exact: only the LAST shard of each chunk appears in any
/// prefix chain — its forward input equals the unsharded chunk's (targets
/// never affect KV), so its stored per-stage KV is the exact prefix block,
/// `prefix_len = index·C` stays bucket-valid, and every later chunk's KV
/// cotangent routes to that one full-row item. Non-last shards get a zero
/// KV cotangent automatically (nothing scatters to them) and contribute
/// exactly their owned loss rows' gradients. Loss rows thus partition and
/// the KV chain is untouched; the sum matches the unsharded run up to
/// float re-association (gated at 1e-6). `sp <= 1` returns the original
/// set and [`build_exec_items`]'s items verbatim — the bit-identity
/// contract.
pub fn build_exec_items_sp(
    backend: &ReferenceBackend,
    set: &ChunkSet,
    tokens: &BTreeMap<u64, Vec<u32>>,
    seq_len: &BTreeMap<u64, u64>,
    sp: u64,
) -> (ChunkSet, Vec<ExecItem>) {
    if sp <= 1 {
        return (set.clone(), build_exec_items(backend, set, tokens, seq_len));
    }
    let c = backend.manifest().chunk_size;
    // Expanded per-sequence chunk counts (for the shard chunks' re-indexed
    // `num_chunks`).
    let mut expanded_count: BTreeMap<u64, usize> = BTreeMap::new();
    for ch in &set.chunks {
        if let ChunkKind::Dependent { seq_id, .. } = ch.kind {
            let shards = sp.min(ch.total_len().max(1)) as usize;
            *expanded_count.entry(seq_id).or_insert(0) += shards;
        }
    }
    let mut chunks: Vec<Chunk> = Vec::new();
    let mut items: Vec<ExecItem> = Vec::new();
    // Per sequence: new ids of the last shards of chunks 0..i (the prefix
    // chain every shard of chunk i consumes) and the running shard index.
    let mut last_shards: BTreeMap<u64, Vec<usize>> = BTreeMap::new();
    let mut next_index: BTreeMap<u64, usize> = BTreeMap::new();
    for ch in &set.chunks {
        match ch.kind {
            ChunkKind::Standalone => {
                let inputs =
                    crate::train::chunk_inputs_for::<f64>(ch, c, tokens, seq_len, 0);
                chunks.push(Chunk {
                    id: chunks.len(),
                    kind: ChunkKind::Standalone,
                    segments: ch.segments.clone(),
                });
                items.push(ExecItem { inputs, prefix_items: Vec::new() });
            }
            ChunkKind::Dependent { seq_id, .. } => {
                let total_len = ch.total_len() as usize;
                let shards = (sp as usize).min(total_len.max(1));
                let prefix_items = last_shards.entry(seq_id).or_default().clone();
                let full = crate::train::chunk_inputs_for::<f64>(
                    ch,
                    c,
                    tokens,
                    seq_len,
                    prefix_items.len() * c,
                );
                let num_chunks = expanded_count[&seq_id];
                let seg0 = ch.segments[0];
                let rows = total_len.div_ceil(shards);
                for s in 0..shards {
                    let lo = s * rows;
                    let hi = ((s + 1) * rows).min(total_len);
                    let id = chunks.len();
                    let index = next_index.entry(seq_id).or_insert(0);
                    chunks.push(Chunk {
                        id,
                        kind: ChunkKind::Dependent { seq_id, index: *index, num_chunks },
                        segments: vec![Segment {
                            seq_id,
                            offset: seg0.offset + lo as u64,
                            len: (hi - lo) as u64,
                        }],
                    });
                    *index += 1;
                    let inputs = if shards == 1 {
                        full.clone()
                    } else {
                        crate::train::sp_shard_inputs(&full, total_len, lo, hi)
                    };
                    items.push(ExecItem { inputs, prefix_items: prefix_items.clone() });
                }
                last_shards.get_mut(&seq_id).unwrap().push(chunks.len() - 1);
            }
        }
    }
    (ChunkSet { chunk_size: set.chunk_size, chunks }, items)
}

/// Scatter a stage-local `d_kv_in` ([Lr, 2, P, H, D]) into the pending KV
/// cotangents of the prefix chunks ([Lr, 2, C, H, D] each) — the per-stage
/// slice of `train::scatter_kv_grad`. Fresh accumulators come zeroed from
/// the stage arena.
#[allow(clippy::too_many_arguments)]
fn scatter_stage_kv_grad(
    d_kv_in: &[f64],
    prefix_items: &[usize],
    g_kv: &mut BTreeMap<usize, Vec<f64>>,
    lr: usize,
    c: usize,
    hd: usize,
    kv_unit_elems: usize,
    arena: &mut BufferPool,
) {
    let n_prev = prefix_items.len();
    if n_prev == 0 {
        return;
    }
    let block = c * hd;
    debug_assert_eq!(d_kv_in.len(), lr * 2 * n_prev * block);
    for (ci, &it) in prefix_items.iter().enumerate() {
        let dst = g_kv.entry(it).or_insert_with(|| arena.acquire(kv_unit_elems));
        for b in 0..lr * 2 {
            let src_off = (b * n_prev + ci) * block;
            let dst_off = b * block;
            for (x, y) in dst[dst_off..dst_off + block]
                .iter_mut()
                .zip(&d_kv_in[src_off..src_off + block])
            {
                *x += *y;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chunk::construct_chunks;
    use crate::config::ModelSpec;
    use crate::data::Sequence;
    use crate::pipeline::standard_1f1b_agendas;
    use crate::runtime::Manifest;
    use crate::train::init_params;

    fn backend(chunk: usize, max_chunks: usize) -> ReferenceBackend {
        let spec = ModelSpec {
            name: "exec-mini".into(),
            hidden_size: 16,
            num_layers: 2,
            num_heads: 2,
            num_kv_heads: 2,
            intermediate_size: 24,
            vocab_size: 32,
            tie_embeddings: true,
        };
        let manifest = Manifest::for_reference(&spec, chunk, max_chunks).unwrap();
        let mut b = ReferenceBackend::new(manifest).unwrap();
        let params = init_params(&b.manifest, 11);
        b.set_params(&params).unwrap();
        b
    }

    /// Exec items for a chunk set over deterministic synthetic tokens.
    fn exec_items(b: &ReferenceBackend, set: &ChunkSet, batch: &[Sequence]) -> Vec<ExecItem> {
        let corpus = crate::data::SyntheticCorpus::new(b.manifest.vocab_size as u32, 99);
        let tokens: BTreeMap<u64, Vec<u32>> =
            batch.iter().map(|q| (q.id, corpus.generate(q.id, q.len))).collect();
        let seq_len: BTreeMap<u64, u64> = batch.iter().map(|q| (q.id, q.len)).collect();
        build_exec_items(b, set, &tokens, &seq_len)
    }

    #[test]
    fn single_stage_single_chunk_runs() {
        let b = backend(8, 1);
        let batch = vec![Sequence { id: 0, len: 8 }];
        let set = construct_chunks(&batch, 8);
        let items = exec_items(&b, &set, &batch);
        let out = execute_state_aware(&b, &set, &items, 1, 1).unwrap();
        assert!(out.loss_sum > 0.0);
        assert_eq!(out.tok_sum, 7.0);
        assert_eq!(out.op_log.len(), 1);
        assert_eq!(out.op_log[0], vec![Op::fwd(0), Op::bwd(0)]);
        assert_eq!(out.timeline.ops.len(), 2);
    }

    #[test]
    fn empty_agenda_is_a_noop() {
        let b = backend(8, 1);
        let out = execute_agendas(&b, &[Vec::new(), Vec::new()], &[]).unwrap();
        assert_eq!(out.tok_sum, 0.0);
        assert_eq!(out.timeline.ops.len(), 0);
        assert_eq!(out.timeline.bubble_ratio(), 0.0);
        assert!(out.grads.iter().all(|g| g.iter().all(|&x| x == 0.0)));
    }

    #[test]
    fn standard_agendas_execute_on_two_stages() {
        // Two standalone chunks under plain 1F1B (no recompute, no
        // dependent state): the executor must drive standard agendas too.
        let b = backend(8, 1);
        let batch =
            vec![Sequence { id: 0, len: 8 }, Sequence { id: 1, len: 8 }];
        let set = construct_chunks(&batch, 8);
        let items = exec_items(&b, &set, &batch);
        let agendas = standard_1f1b_agendas(items.len(), 2);
        let out = execute_agendas(&b, &agendas, &items).unwrap();
        assert_eq!(out.tok_sum, 14.0);
        for (s, log) in out.op_log.iter().enumerate() {
            assert_eq!(log, &agendas[s], "stage {s} executed its agenda in order");
        }
    }

    #[test]
    fn cross_stage_timestamps_respect_dataflow() {
        // Fwd(i) at stage s starts only after Fwd(i) at s-1 ended; Bwd(i)
        // at s only after Bwd(i) at s+1 ended — measured, not simulated.
        let b = backend(8, 2);
        let batch = vec![
            Sequence { id: 0, len: 16 }, // 2 dependent chunks
            Sequence { id: 1, len: 8 },
            Sequence { id: 2, len: 8 },
        ];
        let set = construct_chunks(&batch, 8);
        let items = exec_items(&b, &set, &batch);
        let p = 2;
        let out = execute_state_aware(&b, &set, &items, 1, p).unwrap();
        let find = |stage: usize, op: Op| {
            out.timeline
                .ops
                .iter()
                .find(|o| o.stage == stage && o.op == op)
                .copied()
                .unwrap_or_else(|| panic!("missing {op:?} at stage {stage}"))
        };
        for i in 0..items.len() {
            let f0 = find(0, Op::fwd(i));
            let f1 = find(1, Op::fwd(i));
            assert!(f1.start >= f0.end - 1e-9, "item {i}: fwd flowed 0 -> 1");
            let b1 = find(1, Op::bwd(i));
            let b0 = find(0, Op::bwd(i));
            assert!(b0.start >= b1.end - 1e-9, "item {i}: bwd flowed 1 -> 0");
        }
    }

    #[test]
    fn recompute_schedule_matches_single_stage_gradients() {
        // A K < N dependent group through the real pipeline must reproduce
        // the monolithic chunk_vjp chain: compare against the same batch's
        // single-stage execution (P = 1), which the trainer suites already
        // pin to the unchunked oracle.
        let b = backend(8, 4);
        let batch = vec![Sequence { id: 7, len: 32 }]; // 4 dependent chunks
        let set = construct_chunks(&batch, 8);
        let items = exec_items(&b, &set, &batch);
        let base = execute_state_aware(&b, &set, &items, 1, 1).unwrap();
        for p in [2usize, 3] {
            let out = execute_state_aware(&b, &set, &items, 1, p).unwrap();
            assert!(
                (out.loss_sum - base.loss_sum).abs() < 1e-9,
                "P={p} loss {} vs {}",
                out.loss_sum,
                base.loss_sum
            );
            assert_eq!(out.tok_sum, base.tok_sum);
            for (pi, (got, want)) in out.grads.iter().zip(&base.grads).enumerate() {
                let max_ref =
                    want.iter().fold(0.0f64, |a, &x| a.max(x.abs())).max(1e-12);
                let max_err = got
                    .iter()
                    .zip(want)
                    .map(|(x, y)| (x - y).abs())
                    .fold(0.0f64, f64::max);
                assert!(
                    max_err / max_ref < 1e-9,
                    "P={p} param {pi} rel err {}",
                    max_err / max_ref
                );
            }
        }
    }

    #[test]
    fn act_peak_is_bounded_by_k_for_a_single_group() {
        let b = backend(8, 8);
        let batch = vec![Sequence { id: 3, len: 48 }]; // 6 dependent chunks
        let set = construct_chunks(&batch, 8);
        let items = exec_items(&b, &set, &batch);
        for k in [1usize, 2, 3] {
            let out = execute_state_aware(&b, &set, &items, k, 2).unwrap();
            assert!(
                out.act_peak_chunks <= k,
                "K={k}: act peak {} exceeds the budget",
                out.act_peak_chunks
            );
        }
    }

    #[test]
    fn bad_agenda_fails_instead_of_hanging() {
        // Backward before forward: the stage finds no live activations.
        let b = backend(8, 1);
        let batch = vec![Sequence { id: 0, len: 8 }];
        let set = construct_chunks(&batch, 8);
        let items = exec_items(&b, &set, &batch);
        let agendas = vec![vec![Op::bwd(0), Op::fwd(0)]];
        let err = execute_agendas(&b, &agendas, &items).unwrap_err();
        assert!(err.to_string().contains("stage 0"), "{err:#}");
    }

    #[test]
    fn deadlocked_agendas_time_out_naming_stage_op_and_item() {
        // Stage 0 sends item 0 downstream then waits for its gradient;
        // stage 1 waits for item 1's activation, which never comes. Both
        // directions are wedged — the deadline must fire with a message
        // naming the waiting stage, its op, and the item.
        let b = backend(8, 1);
        let batch =
            vec![Sequence { id: 0, len: 8 }, Sequence { id: 1, len: 8 }];
        let set = construct_chunks(&batch, 8);
        let items = exec_items(&b, &set, &batch);
        let agendas = vec![vec![Op::fwd(0), Op::bwd(0)], vec![Op::fwd(1)]];
        let opts = ExecOptions {
            handoff_timeout: Some(Duration::from_millis(200)),
            ..Default::default()
        };
        let err = execute_agendas_with(&b, &agendas, &items, opts).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("timed out"), "{msg}");
        assert!(msg.contains("stage"), "{msg}");
        assert!(msg.contains("item"), "{msg}");
        assert!(msg.contains("Bwd") || msg.contains("Fwd"), "{msg}");
    }

    #[test]
    fn derived_timeout_has_a_floor_and_a_cap() {
        let b = backend(8, 1);
        let m = b.manifest();
        // A tiny problem sits on the 60s floor.
        assert_eq!(derived_handoff_timeout(m, 1), Duration::from_secs(60));
        // An absurdly large one is capped at an hour.
        assert_eq!(
            derived_handoff_timeout(m, usize::MAX / 2),
            Duration::from_secs(3600)
        );
    }

    #[test]
    fn supervise_retries_until_success_and_counts_attempts() {
        let mut calls = 0u32;
        let (value, retries) =
            supervise("flaky", &RetryPolicy::with_retries(3), || {
                calls += 1;
                if calls < 3 {
                    anyhow::bail!("transient failure {calls}");
                }
                Ok(41 + 1)
            })
            .unwrap();
        assert_eq!(value, 42);
        assert_eq!(retries, 2);
        assert_eq!(calls, 3);
    }

    #[test]
    fn supervise_recovers_from_panics_too() {
        let mut calls = 0u32;
        let (value, retries) =
            supervise("panicky", &RetryPolicy::with_retries(1), || {
                calls += 1;
                if calls == 1 {
                    panic!("injected chaos");
                }
                Ok("ok")
            })
            .unwrap();
        assert_eq!(value, "ok");
        assert_eq!(retries, 1);
    }

    #[test]
    fn supervise_exhausts_retries_with_context() {
        let err = supervise("doomed", &RetryPolicy::with_retries(2), || {
            Err::<(), _>(anyhow::anyhow!("always fails"))
        })
        .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("doomed"), "{msg}");
        assert!(msg.contains("3 attempt"), "{msg}");
        assert!(msg.contains("always fails"), "{msg}");
    }

    #[test]
    fn supervise_fail_fast_by_default() {
        let mut calls = 0u32;
        let err = supervise("no-retry", &RetryPolicy::default(), || {
            calls += 1;
            Err::<(), _>(anyhow::anyhow!("boom"))
        })
        .unwrap_err();
        assert_eq!(calls, 1);
        assert!(format!("{err:#}").contains("1 attempt"));
    }

    /// Like [`backend`] but with 4 layers, so 2-stage partitions can be
    /// genuinely uneven.
    fn deep_backend(chunk: usize, max_chunks: usize) -> ReferenceBackend {
        let spec = ModelSpec {
            name: "exec-deep".into(),
            hidden_size: 16,
            num_layers: 4,
            num_heads: 2,
            num_kv_heads: 2,
            intermediate_size: 24,
            vocab_size: 32,
            tie_embeddings: true,
        };
        let manifest = Manifest::for_reference(&spec, chunk, max_chunks).unwrap();
        let mut b = ReferenceBackend::new(manifest).unwrap();
        let params = init_params(&b.manifest, 11);
        b.set_params(&params).unwrap();
        b
    }

    #[test]
    fn explicit_equal_partition_is_bit_identical_to_default() {
        let b = deep_backend(8, 2);
        let batch = vec![Sequence { id: 0, len: 16 }, Sequence { id: 1, len: 8 }];
        let set = construct_chunks(&batch, 8);
        let items = exec_items(&b, &set, &batch);
        let base = execute_state_aware(&b, &set, &items, 1, 2).unwrap();
        let opts = ExecOptions {
            partition: Some(StagePartition::equal(4, 2).unwrap()),
            ..Default::default()
        };
        let out = execute_state_aware_with(&b, &set, &items, 1, 2, opts).unwrap();
        assert_eq!(out.grads, base.grads, "equal partition must be the default path, bit for bit");
        assert_eq!(out.loss_sum.to_bits(), base.loss_sum.to_bits());
        assert_eq!(out.op_log, base.op_log);
    }

    #[test]
    fn uneven_partition_reproduces_single_stage_gradients() {
        // Real uneven stages through the executor: [3,1], [1,3] and
        // [2,1,1] splits must reproduce the monolithic K < N chain.
        let b = deep_backend(8, 4);
        let batch = vec![Sequence { id: 7, len: 32 }]; // 4 dependent chunks
        let set = construct_chunks(&batch, 8);
        let items = exec_items(&b, &set, &batch);
        let base = execute_state_aware(&b, &set, &items, 1, 1).unwrap();
        for counts in [vec![3usize, 1], vec![1, 3], vec![2, 1, 1]] {
            let p = counts.len();
            let opts = ExecOptions {
                partition: Some(StagePartition::from_counts(&counts, 4).unwrap()),
                ..Default::default()
            };
            let out = execute_state_aware_with(&b, &set, &items, 1, p, opts).unwrap();
            assert!(
                (out.loss_sum - base.loss_sum).abs() < 1e-9,
                "{counts:?} loss {} vs {}",
                out.loss_sum,
                base.loss_sum
            );
            for (pi, (got, want)) in out.grads.iter().zip(&base.grads).enumerate() {
                let max_ref = want.iter().fold(0.0f64, |a, &x| a.max(x.abs())).max(1e-12);
                let max_err =
                    got.iter().zip(want).map(|(x, y)| (x - y).abs()).fold(0.0f64, f64::max);
                assert!(
                    max_err / max_ref < 1e-9,
                    "{counts:?} param {pi} rel err {}",
                    max_err / max_ref
                );
            }
        }
    }

    #[test]
    fn partition_stage_count_mismatch_fails_fast() {
        let b = deep_backend(8, 1);
        let batch = vec![Sequence { id: 0, len: 8 }];
        let set = construct_chunks(&batch, 8);
        let items = exec_items(&b, &set, &batch);
        let opts = ExecOptions {
            partition: Some(StagePartition::from_counts(&[3, 1], 4).unwrap()),
            ..Default::default()
        };
        let err = execute_state_aware_with(&b, &set, &items, 1, 3, opts).unwrap_err();
        assert!(format!("{err:#}").contains("2 stages"), "{err:#}");
    }

    #[test]
    fn every_policy_executes_in_agenda_order_with_matching_gradients() {
        // The policy conformance suite: for each registered policy the
        // executor's per-stage op log equals the policy's agendas, and the
        // gradients match the single-stage run.
        use crate::pipeline::policy::PolicyKind;
        let b = deep_backend(8, 4);
        let batch = vec![
            Sequence { id: 7, len: 24 }, // 3 dependent chunks
            Sequence { id: 8, len: 8 },
            Sequence { id: 9, len: 8 },
        ];
        let set = construct_chunks(&batch, 8);
        let items = exec_items(&b, &set, &batch);
        let base = execute_state_aware(&b, &set, &items, 1, 1).unwrap();
        for kind in PolicyKind::ALL {
            for p in [2usize, 3] {
                let (agendas, _) = kind.agendas(&set, 1, p);
                let opts = ExecOptions { policy: kind, ..Default::default() };
                let out = execute_state_aware_with(&b, &set, &items, 1, p, opts).unwrap();
                for (s, log) in out.op_log.iter().enumerate() {
                    assert_eq!(
                        log, &agendas[s],
                        "{kind:?} p={p}: stage {s} executed its agenda in order"
                    );
                }
                assert!(
                    (out.loss_sum - base.loss_sum).abs() < 1e-9,
                    "{kind:?} p={p} loss"
                );
                for (pi, (got, want)) in out.grads.iter().zip(&base.grads).enumerate() {
                    let max_ref =
                        want.iter().fold(0.0f64, |a, &x| a.max(x.abs())).max(1e-12);
                    let max_err = got
                        .iter()
                        .zip(want)
                        .map(|(x, y)| (x - y).abs())
                        .fold(0.0f64, f64::max);
                    assert!(
                        max_err / max_ref < 1e-9,
                        "{kind:?} p={p} param {pi} rel err {}",
                        max_err / max_ref
                    );
                }
            }
        }
    }

    #[test]
    fn supervised_execution_matches_unsupervised_bit_for_bit() {
        let b = backend(8, 2);
        let batch = vec![
            Sequence { id: 0, len: 16 },
            Sequence { id: 1, len: 8 },
        ];
        let set = construct_chunks(&batch, 8);
        let items = exec_items(&b, &set, &batch);
        let base = execute_state_aware(&b, &set, &items, 1, 2).unwrap();
        let (sup, retries) = execute_state_aware_supervised(
            &b,
            &set,
            &items,
            1,
            2,
            ExecOptions::default(),
            &RetryPolicy::with_retries(2),
        )
        .unwrap();
        assert_eq!(retries, 0, "no fault, no retries");
        assert_eq!(sup.grads, base.grads, "supervision must not perturb results");
        assert_eq!(sup.loss_sum.to_bits(), base.loss_sum.to_bits());
    }
}
