//! Pluggable pipeline schedule policies.
//!
//! A [`SchedulePolicy`] is an *agenda generator*: given a chunk set, the
//! retention budget K and a stage count P it produces the per-stage ordered
//! op lists plus same-stage precedence edges — the exact format both
//! `pipeline::simulate` and `pipeline::exec::execute_agendas` consume
//! (the standing "agendas are the single scheduling source of truth"
//! contract). The executor and the simulator therefore run ANY policy
//! without modification, and the executed-order == agenda conformance
//! property holds for every implementation by construction.
//!
//! Shipped policies:
//!
//! - [`StateAware1F1B`] — the paper's §4.3 schedule, delegating to
//!   [`state_aware_1f1b_agendas`] verbatim (the default; agendas are
//!   bit-identical to the pre-policy path).
//! - [`ChunkInterleaved`] — a ZB-style bubble-filling variant over the same
//!   Algorithm-2 backward units: every stage warms up
//!   `P - s + DEPTH` forwards instead of `P - s`, pulling more forwards
//!   ahead of the backward stream. On variable-length chunk streams this
//!   fills the stalls upstream stages spend waiting for a long chunk's
//!   backward cotangent, at the price of `DEPTH` extra live activation
//!   caches per stage — exactly the memory-for-bubbles trade InfiniPipe
//!   and the zero-bubble schedules make. Whether it wins is
//!   workload-dependent; the tuner decides per scenario.

use super::onef1b::{build_agendas_with_depth, state_aware_1f1b_agendas, state_aware_units};
use super::{ExtraEdges, Op, OpCosts, Timeline};
use crate::chunk::ChunkSet;

/// An agenda generator: one pipeline schedule, consumable by both the
/// simulator and the executor.
pub trait SchedulePolicy {
    /// Stable identifier (the `--policy` flag value and the JSON field).
    fn name(&self) -> &'static str;

    /// Per-stage agendas + same-stage precedence edges for a chunk set
    /// under retention budget `k` on `p` stages.
    fn agendas(&self, set: &ChunkSet, k: usize, p: usize) -> (Vec<Vec<Op>>, ExtraEdges);
}

/// The paper's state-aware 1F1B (§4.3) — the default policy. Delegates to
/// [`state_aware_1f1b_agendas`], so its agendas are bit-identical to the
/// pre-policy code path.
pub struct StateAware1F1B;

impl SchedulePolicy for StateAware1F1B {
    fn name(&self) -> &'static str {
        "state-aware-1f1b"
    }

    fn agendas(&self, set: &ChunkSet, k: usize, p: usize) -> (Vec<Vec<Op>>, ExtraEdges) {
        state_aware_1f1b_agendas(set, k, p)
    }
}

/// ZB-style chunk-interleaved variant: same forward order, same
/// Algorithm-2 backward units and edges, deeper warmup (see module docs).
pub struct ChunkInterleaved;

/// Extra warmup forwards per stage for [`ChunkInterleaved`]. Two is the
/// smallest depth that lets a stage ride out one long chunk's backward
/// stall without going idle on typical longtail streams.
pub const CHUNK_INTERLEAVE_DEPTH: usize = 2;

impl SchedulePolicy for ChunkInterleaved {
    fn name(&self) -> &'static str {
        "chunk-interleaved"
    }

    fn agendas(&self, set: &ChunkSet, k: usize, p: usize) -> (Vec<Vec<Op>>, ExtraEdges) {
        let (fwd_list, bwd_units, edges) = state_aware_units(set, k);
        (build_agendas_with_depth(&fwd_list, &bwd_units, p, CHUNK_INTERLEAVE_DEPTH), edges)
    }
}

/// Value-type handle for the registered policies — what flows through
/// `ExecOptions`, the tuner's search space and the sweep artifact.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord)]
pub enum PolicyKind {
    #[default]
    StateAware1F1B,
    ChunkInterleaved,
}

impl PolicyKind {
    /// Every registered policy, in search order (default first).
    pub const ALL: [PolicyKind; 2] = [PolicyKind::StateAware1F1B, PolicyKind::ChunkInterleaved];

    pub fn as_policy(self) -> &'static dyn SchedulePolicy {
        match self {
            PolicyKind::StateAware1F1B => &StateAware1F1B,
            PolicyKind::ChunkInterleaved => &ChunkInterleaved,
        }
    }

    pub fn name(self) -> &'static str {
        self.as_policy().name()
    }

    /// Inverse of [`Self::name`] — the `--policy` flag parser.
    pub fn by_name(name: &str) -> anyhow::Result<Self> {
        Self::ALL
            .into_iter()
            .find(|k| k.name() == name)
            .ok_or_else(|| {
                let names: Vec<&str> = Self::ALL.iter().map(|k| k.name()).collect();
                anyhow::anyhow!("unknown schedule policy {name:?} (valid: {})", names.join(", "))
            })
    }

    pub fn agendas(self, set: &ChunkSet, k: usize, p: usize) -> (Vec<Vec<Op>>, ExtraEdges) {
        self.as_policy().agendas(set, k, p)
    }
}

/// Simulate a policy's schedule with per-(stage, chunk) costs — the
/// stage-aware generalization of `onef1b::simulate_state_aware` that
/// uneven partitions need (a stage's cost now depends on its layer share).
pub fn simulate_policy(
    policy: PolicyKind,
    set: &ChunkSet,
    k: usize,
    p: usize,
    cost_of: impl Fn(usize, usize) -> OpCosts,
) -> anyhow::Result<Timeline> {
    let (agendas, edges) = policy.agendas(set, k, p);
    super::simulate_stagewise(&agendas, set.chunks.len(), |s, op| cost_of(s, op.item), &edges)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chunk::construct_chunks;
    use crate::data::Sequence;

    fn unit_costs(set: &ChunkSet) -> impl Fn(usize, usize) -> OpCosts + '_ {
        |_s, id| {
            let len = set.chunks[id].total_len() as f64;
            OpCosts { fwd: len, bwd: 2.0 * len }
        }
    }

    #[test]
    fn default_policy_agendas_are_bit_identical_to_state_aware() {
        let batch = vec![
            Sequence { id: 0, len: 17 },
            Sequence { id: 1, len: 4 },
            Sequence { id: 2, len: 30 },
        ];
        let set = construct_chunks(&batch, 8);
        for (k, p) in [(1usize, 1usize), (1, 3), (2, 4)] {
            let (a, e) = PolicyKind::StateAware1F1B.agendas(&set, k, p);
            let (a0, e0) = state_aware_1f1b_agendas(&set, k, p);
            assert_eq!(a, a0, "k={k} p={p}");
            assert_eq!(e, e0, "k={k} p={p}");
        }
    }

    #[test]
    fn policy_names_round_trip() {
        for kind in PolicyKind::ALL {
            assert_eq!(PolicyKind::by_name(kind.name()).unwrap(), kind);
        }
        let err = PolicyKind::by_name("zb-2p").unwrap_err().to_string();
        assert!(err.contains("state-aware-1f1b"), "{err}");
        assert_eq!(PolicyKind::default(), PolicyKind::StateAware1F1B);
    }

    #[test]
    fn interleaved_policy_executes_every_op_once_per_stage() {
        let batch = vec![
            Sequence { id: 0, len: 16 }, // 2 dependent chunks
            Sequence { id: 1, len: 8 },
            Sequence { id: 2, len: 8 },
        ];
        let set = construct_chunks(&batch, 8);
        for p in [1usize, 2, 4] {
            let t = simulate_policy(PolicyKind::ChunkInterleaved, &set, 1, p, unit_costs(&set))
                .unwrap();
            for s in 0..p {
                for c in 0..set.chunks.len() {
                    let fwd = t
                        .ops
                        .iter()
                        .filter(|o| {
                            o.stage == s
                                && o.op.item == c
                                && o.op.kind == crate::pipeline::OpKind::Fwd
                        })
                        .count();
                    let bwd = t
                        .ops
                        .iter()
                        .filter(|o| {
                            o.stage == s
                                && o.op.item == c
                                && o.op.kind == crate::pipeline::OpKind::Bwd
                        })
                        .count();
                    assert_eq!(fwd, 1, "p={p} chunk {c} fwd on stage {s}");
                    assert_eq!(bwd, 1, "p={p} chunk {c} bwd on stage {s}");
                }
            }
        }
    }

    // Degenerate cases, mirroring `simulate_interleaved`'s suite.

    #[test]
    fn p1_single_microbatch_degenerates_to_sequential() {
        let batch = vec![Sequence { id: 0, len: 8 }];
        let set = construct_chunks(&batch, 8);
        for kind in PolicyKind::ALL {
            let t = simulate_policy(kind, &set, 1, 1, unit_costs(&set)).unwrap();
            assert_eq!(t.ops.len(), 2, "{kind:?}: one fwd + one bwd");
            assert_eq!(t.makespan, 8.0 + 16.0, "{kind:?}");
            assert_eq!(t.bubble_ratio(), 0.0, "{kind:?}: single stage has no bubbles");
        }
    }

    #[test]
    fn single_microbatch_multi_stage_is_valid() {
        let batch = vec![Sequence { id: 0, len: 8 }];
        let set = construct_chunks(&batch, 8);
        for kind in PolicyKind::ALL {
            let t = simulate_policy(kind, &set, 1, 4, unit_costs(&set)).unwrap();
            assert_eq!(t.ops.len(), 8, "{kind:?}: fwd+bwd on each of 4 stages");
            assert!(t.bubble_ratio() > 0.0, "{kind:?}: one micro-batch cannot fill 4 stages");
        }
    }

    #[test]
    fn empty_chunkset_yields_empty_timeline() {
        let set = construct_chunks(&[], 8);
        for kind in PolicyKind::ALL {
            let t = simulate_policy(kind, &set, 1, 3, unit_costs(&set)).unwrap();
            assert_eq!(t.ops.len(), 0, "{kind:?}");
            assert_eq!(t.makespan, 0.0, "{kind:?}");
            assert_eq!(t.bubble_ratio(), 0.0, "{kind:?}");
        }
    }

    #[test]
    fn interleaved_warmup_is_deeper_but_op_multiset_matches() {
        let batch: Vec<Sequence> = (0..6).map(|i| Sequence { id: i, len: 8 }).collect();
        let set = construct_chunks(&batch, 8);
        let (default_a, _) = PolicyKind::StateAware1F1B.agendas(&set, 1, 3);
        let (deep_a, _) = PolicyKind::ChunkInterleaved.agendas(&set, 1, 3);
        for s in 0..3 {
            // Same ops overall, different interleaving.
            let mut d: Vec<Op> = default_a[s].clone();
            let mut z: Vec<Op> = deep_a[s].clone();
            d.sort();
            z.sort();
            assert_eq!(d, z, "stage {s} op multiset");
            // Deeper warmup: the interleaved agenda front-loads forwards.
            let lead = |a: &[Op]| {
                a.iter().take_while(|o| o.kind == crate::pipeline::OpKind::Fwd).count()
            };
            assert!(
                lead(&deep_a[s]) >= lead(&default_a[s]),
                "stage {s}: interleaved warmup at least as deep"
            );
        }
        assert!(
            deep_a.iter().zip(&default_a).any(|(z, d)| {
                let lead = |a: &Vec<Op>| {
                    a.iter().take_while(|o| o.kind == crate::pipeline::OpKind::Fwd).count()
                };
                lead(z) > lead(d)
            }),
            "some stage actually warms up deeper"
        );
    }
}
