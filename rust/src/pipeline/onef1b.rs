//! 1F1B agenda construction: the standard schedule (paper §3 baseline) and
//! ChunkFlow's state-aware variant (§4.3).
//!
//! An *agenda* is the ordered op list one pipeline stage executes. The
//! standard 1F1B pattern for stage `s` of `P` over `M` micro-batches, in the
//! convention the paper's Figure 2 numbers imply (stage s keeps `P - s`
//! micro-batches in flight):
//!
//! ```text
//! warmup(s) = min(P - s, M) forwards,
//! then alternate (backward, forward) until forwards are exhausted,
//! then the remaining backwards.
//! ```
//!
//! The state-aware variant runs the same skeleton over *chunks*, but the
//! backward stream is reordered so dependent chunks of one sequence run
//! backward in descending index order, recompute-forwards are injected for
//! chunks whose activations were discarded (N > K groups), and same-stage
//! precedence edges enforce (a) descending backward order within a group and
//! (b) a chunk's recompute-forward waiting for the backward that frees an
//! activation slot (the K-budget of Algorithm 2, applied per stage).

use super::{ExtraEdges, Op, OpCosts};
use crate::chunk::{ChunkKind, ChunkSet};
use crate::schedule::schedule_group;

/// A pipeline work item: `cost` is the *per-stage* forward cost.
#[derive(Clone, Copy, Debug)]
pub struct PipelineItem {
    pub fwd_cost: f64,
    pub bwd_cost: f64,
}

impl PipelineItem {
    pub fn costs(&self) -> OpCosts {
        OpCosts { fwd: self.fwd_cost, bwd: self.bwd_cost }
    }
}

/// Standard 1F1B agendas for `m` micro-batches on `p` stages.
pub fn standard_1f1b_agendas(m: usize, p: usize) -> Vec<Vec<Op>> {
    let bwd_units: Vec<Vec<Op>> = (0..m).map(|i| vec![Op::bwd(i)]).collect();
    let fwd_list: Vec<Op> = (0..m).map(Op::fwd).collect();
    build_agendas(&fwd_list, &bwd_units, p)
}

/// State-aware 1F1B agendas + precedence edges for a chunk set under
/// retention budget `k`. Items are the chunks of `set` in id order.
///
/// Returns `(agendas, extra_edges)`.
pub fn state_aware_1f1b_agendas(
    set: &ChunkSet,
    k: usize,
    p: usize,
) -> (Vec<Vec<Op>>, ExtraEdges) {
    let (fwd_list, bwd_units, edges) = state_aware_units(set, k);
    (build_agendas(&fwd_list, &bwd_units, p), edges)
}

/// The state-aware schedule's stage-independent ingredients: the forward
/// stream, the backward units ([B] or [RF, B], Algorithm-2 ordered within
/// each dependent group), and the same-stage precedence edges. Every
/// schedule policy built on the state-aware backward semantics
/// (`pipeline::policy`) shares these and differs only in how a stage
/// interleaves them.
pub(crate) fn state_aware_units(
    set: &ChunkSet,
    k: usize,
) -> (Vec<Op>, Vec<Vec<Op>>, ExtraEdges) {
    let m = set.chunks.len();
    let fwd_list: Vec<Op> = (0..m).map(Op::fwd).collect();

    // Build the backward stream as "units": each unit is either [B] or
    // [RF, B]. Order: follow forward (chunk-id) order, but within a
    // dependent group emit the group's Algorithm-2 backward order, anchored
    // at the position of the group's LAST chunk (its backward is the first
    // that can run).
    let mut edges: ExtraEdges = Vec::new();
    let mut unit_of_chunk: Vec<Option<Vec<Op>>> = vec![None; m];
    let mut anchor: Vec<usize> = (0..m).collect(); // emission position

    for group in set.dependent_groups() {
        let ids: Vec<usize> = group.iter().map(|c| c.id).collect();
        let plan = schedule_group(&ids, k);
        let n = ids.len();
        // Backward order from the plan (positions within group).
        let order = plan.backward_order();
        // Anchor all group backwards at the last chunk's position; emit in
        // plan order.
        let last_id = *ids.last().unwrap();
        for (emit_idx, &(pos, rf)) in order.iter().enumerate() {
            let id = ids[pos];
            let mut unit = Vec::new();
            if rf {
                unit.push(Op::rfwd(id));
            }
            unit.push(Op::bwd(id));
            unit_of_chunk[id] = Some(unit);
            // Stable order: anchor position with sub-priority.
            anchor[id] = last_id * (m + 1) + emit_idx;
            // Precedence: descending backward order within the group.
            if emit_idx > 0 {
                let prev_id = ids[order[emit_idx - 1].0];
                edges.push((Op::bwd(prev_id), Op::bwd(id)));
            }
            // RF(i) waits for the backward freeing its activation slot:
            // B(chunk at pos+K) if it exists (Alg. 2's K-budget per stage).
            if rf && pos + k < n {
                edges.push((Op::bwd(ids[pos + k]), Op::rfwd(id)));
            }
        }
    }
    // Standalone chunks: plain [B] unit anchored at own position.
    for c in &set.chunks {
        if matches!(c.kind, ChunkKind::Standalone) {
            unit_of_chunk[c.id] = Some(vec![Op::bwd(c.id)]);
            anchor[c.id] = c.id * (m + 1);
        }
    }

    // Flatten backward units by anchor.
    let mut order: Vec<usize> = (0..m).collect();
    order.sort_by_key(|&i| anchor[i]);
    let bwd_units: Vec<Vec<Op>> =
        order.into_iter().map(|i| unit_of_chunk[i].take().unwrap()).collect();

    (fwd_list, bwd_units, edges)
}

/// Shared skeleton: warmup forwards, then 1F1B alternation, then drain.
/// `bwd_units` are emitted whole (an RF stays glued before its B). When the
/// backward stream is group-reordered, a backward unit may reference a chunk
/// whose forward has not been emitted yet on this stage (the group's last
/// chunk backs up first); in that case forwards are pulled ahead — the
/// state-aware schedule's deviation from plain 1F1B.
pub(crate) fn build_agendas(fwd_list: &[Op], bwd_units: &[Vec<Op>], p: usize) -> Vec<Vec<Op>> {
    build_agendas_with_depth(fwd_list, bwd_units, p, 0)
}

/// [`build_agendas`] with `extra` additional warmup forwards per stage —
/// the ZB-style bubble-filling knob of `pipeline::policy`'s
/// chunk-interleaved policy. `extra = 0` is the plain 1F1B skeleton, op
/// for op. Warmup depth stays monotone decreasing in the stage index
/// (`p - s + extra`), which is what keeps the cross-stage dependency chain
/// deadlock-free for any `extra`; the price of depth is `extra` more live
/// activation caches per stage.
pub(crate) fn build_agendas_with_depth(
    fwd_list: &[Op],
    bwd_units: &[Vec<Op>],
    p: usize,
    extra: usize,
) -> Vec<Vec<Op>> {
    let m = fwd_list.len();
    // Position of each item's forward in fwd_list (identity here, but keep
    // it explicit for clarity).
    let fwd_pos: Vec<usize> = (0..m).collect();
    // A unit is emittable once every item it references has been forwarded.
    let unit_requirement = |unit: &[Op]| -> usize {
        unit.iter().map(|o| fwd_pos[o.item]).max().unwrap_or(0)
    };
    (0..p)
        .map(|s| {
            let warmup = (p - s + extra).min(m);
            let mut agenda: Vec<Op> = fwd_list[..warmup].to_vec();
            let mut fi = warmup;
            let mut bi = 0;
            // Steady state: alternate one forward, one backward-unit, pulling
            // extra forwards ahead when the next unit still needs them.
            while fi < m {
                agenda.push(fwd_list[fi]);
                fi += 1;
                if bi < bwd_units.len() && unit_requirement(&bwd_units[bi]) < fi {
                    agenda.extend(bwd_units[bi].iter().copied());
                    bi += 1;
                }
            }
            // Drain remaining backward units.
            while bi < bwd_units.len() {
                agenda.extend(bwd_units[bi].iter().copied());
                bi += 1;
            }
            agenda
        })
        .collect()
}

/// Simulate a standard 1F1B run over items with the given per-stage costs.
pub fn simulate_standard(
    items: &[PipelineItem],
    p: usize,
) -> anyhow::Result<super::Timeline> {
    let agendas = standard_1f1b_agendas(items.len(), p);
    let costs: Vec<OpCosts> = items.iter().map(|i| i.costs()).collect();
    super::simulate(&agendas, &costs, &vec![])
}

/// Simulate the state-aware 1F1B run for a chunk set. `cost_of` maps a chunk
/// id to its per-stage costs.
pub fn simulate_state_aware(
    set: &ChunkSet,
    k: usize,
    p: usize,
    cost_of: impl Fn(usize) -> OpCosts,
) -> anyhow::Result<super::Timeline> {
    let (agendas, edges) = state_aware_1f1b_agendas(set, k, p);
    let costs: Vec<OpCosts> = (0..set.chunks.len()).map(cost_of).collect();
    super::simulate(&agendas, &costs, &edges)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chunk::construct_chunks;
    use crate::data::Sequence;

    /// The paper's running example (Figure 2a): sequences of 1, 1, 2, 4
    /// Units; fwd time = length, bwd = 2x.
    fn paper_items() -> Vec<PipelineItem> {
        [1.0, 1.0, 2.0, 4.0]
            .iter()
            .map(|&l| PipelineItem { fwd_cost: l, bwd_cost: 2.0 * l })
            .collect()
    }

    #[test]
    fn figure2b_standard_1f1b_bubble_is_57_14_percent() {
        let t = simulate_standard(&paper_items(), 4).unwrap();
        let bubble = t.bubble_ratio();
        assert!(
            (bubble - 0.5714).abs() < 0.002,
            "bubble {bubble:.4} vs paper 57.14% (makespan {})",
            t.makespan
        );
        assert!((t.makespan - 56.0).abs() < 1e-9);
    }

    #[test]
    fn equal_lengths_match_theory() {
        // Paper §3: equal-length microbatches under this config give 42.8%.
        let items: Vec<PipelineItem> =
            (0..4).map(|_| PipelineItem { fwd_cost: 2.0, bwd_cost: 4.0 }).collect();
        let t = simulate_standard(&items, 4).unwrap();
        assert!((t.bubble_ratio() - 0.428).abs() < 0.005, "got {}", t.bubble_ratio());
    }

    /// The paper's Figure 6 scenario: ChunkSize = 2·Unit over the Figure 2
    /// sequences gives 4 chunks: pack(1,1), (2), and the 4-Unit sequence
    /// split in two dependent chunks.
    fn figure6_chunkset() -> ChunkSet {
        let batch = vec![
            Sequence { id: 0, len: 1 },
            Sequence { id: 1, len: 1 },
            Sequence { id: 2, len: 2 },
            Sequence { id: 3, len: 4 },
        ];
        construct_chunks(&batch, 2)
    }

    fn unit_costs(set: &ChunkSet) -> impl Fn(usize) -> OpCosts + '_ {
        |id| {
            let len = set.chunks[id].total_len() as f64;
            OpCosts { fwd: len, bwd: 2.0 * len }
        }
    }

    #[test]
    fn figure6_chunk_construction() {
        let set = figure6_chunkset();
        assert_eq!(set.chunks.len(), 4);
        assert!(set.chunks.iter().all(|c| c.total_len() == 2));
        assert_eq!(set.dependent_groups().len(), 1);
        assert_eq!(set.dependent_groups()[0].len(), 2);
    }

    #[test]
    fn figure6_state_aware_k1() {
        // Paper: bubble 54.1% with K=1 (our discrete sim: 53.6%; the
        // recompute forward of the first dependent chunk flows through the
        // cooldown phase). Assert the paper band.
        let set = figure6_chunkset();
        let t = simulate_state_aware(&set, 1, 4, unit_costs(&set)).unwrap();
        let bubble = t.bubble_ratio();
        assert!(
            (bubble - 0.541).abs() < 0.03,
            "bubble {bubble:.4} vs paper 54.1% (makespan {})",
            t.makespan
        );
        // Better than the unchunked baseline of Figure 2(b).
        assert!(bubble < 0.5714);
    }

    #[test]
    fn figure6_state_aware_k2() {
        // K=2 retains both dependent chunks: no recompute, fewer bubbles
        // than K=1 (paper: 47.8%; our sim settles lower since no comm cost
        // is modeled — assert ordering + a generous band).
        let set = figure6_chunkset();
        let t1 = simulate_state_aware(&set, 1, 4, unit_costs(&set)).unwrap();
        let t2 = simulate_state_aware(&set, 2, 4, unit_costs(&set)).unwrap();
        assert!(t2.bubble_ratio() < t1.bubble_ratio());
        assert!(
            (t2.bubble_ratio() - 0.478).abs() < 0.06,
            "bubble {:.4} vs paper 47.8%",
            t2.bubble_ratio()
        );
        assert!(t2.makespan < t1.makespan);
    }

    #[test]
    fn figure7_too_large_chunksize_degrades() {
        // ChunkSize = 4·Unit: only 2 chunks -> bubble 60% (paper Figure 7),
        // *worse* than the 57.14% unchunked baseline.
        let batch = vec![
            Sequence { id: 0, len: 1 },
            Sequence { id: 1, len: 1 },
            Sequence { id: 2, len: 2 },
            Sequence { id: 3, len: 4 },
        ];
        let set = construct_chunks(&batch, 4);
        assert_eq!(set.chunks.len(), 2);
        let t = simulate_state_aware(&set, 1, 4, unit_costs(&set)).unwrap();
        let bubble = t.bubble_ratio();
        assert!((bubble - 0.60).abs() < 0.005, "bubble {bubble:.4} vs paper 60%");
        assert!(bubble > 0.5714, "larger chunks must be worse than baseline here");
    }

    #[test]
    fn state_aware_executes_every_chunk_fwd_and_bwd_once_per_stage() {
        let set = figure6_chunkset();
        let t = simulate_state_aware(&set, 1, 4, unit_costs(&set)).unwrap();
        for s in 0..4 {
            for c in 0..set.chunks.len() {
                let fwd = t
                    .ops
                    .iter()
                    .filter(|o| {
                        o.stage == s
                            && o.op.item == c
                            && o.op.kind == super::super::OpKind::Fwd
                    })
                    .count();
                let bwd = t
                    .ops
                    .iter()
                    .filter(|o| {
                        o.stage == s
                            && o.op.item == c
                            && o.op.kind == super::super::OpKind::Bwd
                    })
                    .count();
                assert_eq!(fwd, 1, "chunk {c} fwd on stage {s}");
                assert_eq!(bwd, 1, "chunk {c} bwd on stage {s}");
            }
        }
    }

    #[test]
    fn dependent_backwards_run_in_descending_order() {
        let batch = vec![Sequence { id: 9, len: 10 }];
        let set = construct_chunks(&batch, 2); // 5 dependent chunks
        let t = simulate_state_aware(&set, 2, 3, unit_costs(&set)).unwrap();
        for s in 0..3 {
            let mut bwd_times: Vec<(usize, f64)> = t
                .ops
                .iter()
                .filter(|o| o.stage == s && o.op.kind == super::super::OpKind::Bwd)
                .map(|o| (o.op.item, o.start))
                .collect();
            bwd_times.sort_by(|a, b| a.1.total_cmp(&b.1));
            let order: Vec<usize> = bwd_times.iter().map(|x| x.0).collect();
            assert_eq!(order, vec![4, 3, 2, 1, 0], "stage {s}");
        }
    }

    #[test]
    fn single_stage_degenerates_to_alg2_order() {
        let batch = vec![Sequence { id: 0, len: 6 }];
        let set = construct_chunks(&batch, 2); // 3 chunks
        let t = simulate_state_aware(&set, 1, 1, unit_costs(&set)).unwrap();
        // ops: F0 F1 F2 B2 RF1 B1 RF0 B0 -> makespan = 3*2 + 6 + 2+6+2+6 wait:
        // fwd 3x2=6, B2=4, RF1=2,B1=4, RF0=2,B0=4 => 22... bwd=2*len=4 each.
        assert!((t.makespan - (6.0 + 4.0 + 2.0 + 4.0 + 2.0 + 4.0)).abs() < 1e-9);
        assert_eq!(t.bubble_ratio(), 0.0, "single stage has no bubbles");
    }

    #[test]
    fn more_chunks_reduce_bubbles_with_equal_work() {
        // Splitting the same (independent) work into more equal chunks
        // shrinks bubbles: 8 short sequences packed into 2 vs 8 chunks.
        let batch: Vec<Sequence> =
            (0..8).map(|i| Sequence { id: i, len: 4 }).collect();
        let coarse = construct_chunks(&batch, 16); // 2 chunks of 16
        let fine = construct_chunks(&batch, 4); // 8 chunks of 4
        assert_eq!(coarse.chunks.len(), 2);
        assert_eq!(fine.chunks.len(), 8);
        let t_coarse = simulate_state_aware(&coarse, 2, 4, unit_costs(&coarse)).unwrap();
        let t_fine = simulate_state_aware(&fine, 2, 4, unit_costs(&fine)).unwrap();
        assert!(t_fine.bubble_ratio() < t_coarse.bubble_ratio());
    }
}
