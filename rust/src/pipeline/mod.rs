//! Pipeline-parallel execution simulator.
//!
//! A discrete-event simulator for PP-stage pipelines executing micro-batch
//! operations (forward / recompute-forward / backward) under the paper's
//! cost assumptions: execution time proportional to micro-batch token count,
//! backward = 2x forward (§3). It reproduces the paper's bubble-ratio
//! analyses exactly where the paper states them:
//!
//! - Figure 2(b): standard 1F1B over sequences [1,1,2,4]·Unit on 4 stages
//!   → 57.14% bubble ratio;
//! - Figure 7: ChunkSize = 4·Unit, K = 1 (2 chunks) → 60% bubble ratio;
//! - Figure 6: state-aware 1F1B, ChunkSize = 2·Unit, K = 1 / K = 2.
//!
//! The simulator is deterministic: each stage executes its *agenda* (an
//! ordered op list produced by a scheduling policy in `onef1b`) in order,
//! each op starting when the stage is free and its cross-stage dependencies
//! are met:
//!
//! - `Fwd(i)`/`RecomputeFwd(i)` at stage s>0 waits for the same op at s-1;
//! - `Bwd(i)` at stage s<P-1 waits for `Bwd(i)` at s+1; at the last stage it
//!   waits for `Fwd(i)` (or its recompute) there;
//! - policy-injected extra edges (state-aware ordering within chunk groups).

pub mod exec;
pub mod interleaved;
pub mod onef1b;
pub mod policy;

pub use exec::{
    build_exec_items, build_exec_items_sp, derived_handoff_timeout, execute_agendas,
    execute_agendas_with,
    execute_replica_groups, execute_replica_groups_supervised, execute_replica_groups_with,
    execute_state_aware, execute_state_aware_supervised, execute_state_aware_with, supervise,
    ExecItem, ExecOptions, ExecOutcome, ReplicaSpec, RetryPolicy,
};
pub use interleaved::simulate_interleaved;

pub use onef1b::{standard_1f1b_agendas, state_aware_1f1b_agendas, PipelineItem};
pub use policy::{simulate_policy, ChunkInterleaved, PolicyKind, SchedulePolicy, StateAware1F1B};

/// Operation kinds on the pipeline.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum OpKind {
    Fwd,
    /// Second forward of a discarded chunk (Alg. 2) — costs like Fwd.
    RecomputeFwd,
    Bwd,
}

/// An op on one micro-batch item (identified by dense index).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct Op {
    pub kind: OpKind,
    pub item: usize,
}

impl Op {
    pub fn fwd(item: usize) -> Op {
        Op { kind: OpKind::Fwd, item }
    }
    pub fn rfwd(item: usize) -> Op {
        Op { kind: OpKind::RecomputeFwd, item }
    }
    pub fn bwd(item: usize) -> Op {
        Op { kind: OpKind::Bwd, item }
    }
}

impl std::fmt::Display for Op {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let kind = match self.kind {
            OpKind::Fwd => "Fwd",
            OpKind::RecomputeFwd => "RFwd",
            OpKind::Bwd => "Bwd",
        };
        write!(f, "{kind}({})", self.item)
    }
}

/// Retention policy derived from the agendas themselves: a chunk whose
/// agenda carries a recompute-forward was discarded at first forward. (The
/// recompute set is identical on every stage by construction.) Shared by
/// the executor and the static verifier so both read the same contract.
pub fn derive_retain(agendas: &[Vec<Op>], num_items: usize) -> Vec<bool> {
    let mut retain = vec![true; num_items];
    for op in agendas.iter().flatten() {
        if op.kind == OpKind::RecomputeFwd && op.item < num_items {
            retain[op.item] = false;
        }
    }
    retain
}

/// Per-item op costs on one stage (seconds, or abstract units).
#[derive(Clone, Copy, Debug)]
pub struct OpCosts {
    pub fwd: f64,
    pub bwd: f64,
}

/// A scheduled op instance in the simulation result.
#[derive(Clone, Copy, Debug)]
pub struct ScheduledOp {
    pub op: Op,
    pub stage: usize,
    pub start: f64,
    pub end: f64,
}

/// Simulation output: the full Gantt plus summary metrics.
#[derive(Clone, Debug)]
pub struct Timeline {
    pub num_stages: usize,
    pub ops: Vec<ScheduledOp>,
    pub makespan: f64,
    /// Busy time summed over stages.
    pub busy: f64,
}

impl Timeline {
    /// Equation 1: bubble ratio = total bubble time / total execution time,
    /// where total execution time = makespan × stages.
    pub fn bubble_ratio(&self) -> f64 {
        let total = self.makespan * self.num_stages as f64;
        if total == 0.0 {
            0.0
        } else {
            (total - self.busy) / total
        }
    }

    /// Busy time of one stage.
    pub fn stage_busy(&self, stage: usize) -> f64 {
        self.ops
            .iter()
            .filter(|o| o.stage == stage)
            .map(|o| o.end - o.start)
            .sum()
    }

    /// ASCII Gantt chart (one row per stage) for reports and debugging.
    pub fn gantt(&self, width: usize) -> String {
        let mut out = String::new();
        let scale = width as f64 / self.makespan.max(1e-12);
        for s in 0..self.num_stages {
            let mut row = vec![' '; width + 1];
            for o in self.ops.iter().filter(|o| o.stage == s) {
                let a = (o.start * scale) as usize;
                let b = ((o.end * scale) as usize).min(width);
                let c = match o.op.kind {
                    OpKind::Fwd => char::from_digit((o.op.item % 10) as u32, 10).unwrap(),
                    OpKind::RecomputeFwd => 'r',
                    OpKind::Bwd => 'B',
                };
                for cell in row.iter_mut().take(b.max(a + 1)).skip(a) {
                    *cell = c;
                }
            }
            out.push_str(&format!("stage {s}: |{}|\n", row.into_iter().collect::<String>()));
        }
        out
    }
}

/// Extra precedence edges: (before, after) pairs applied *within each
/// stage's dependency check* — `after` on any stage cannot start until
/// `before` has completed on that same stage.
pub type ExtraEdges = Vec<(Op, Op)>;

/// Dense index of an op kind (completion-table stride).
#[inline]
fn kind_idx(k: OpKind) -> usize {
    match k {
        OpKind::Fwd => 0,
        OpKind::RecomputeFwd => 1,
        OpKind::Bwd => 2,
    }
}

/// Simulate per-stage agendas. `costs[i]` gives item i's per-stage fwd/bwd
/// cost (uniform across stages — layers are split evenly). Returns an error
/// on deadlock (malformed agendas) or on an op referencing an item without
/// costs.
///
/// The completion table is a dense `Vec<f64>` indexed by
/// `(stage, op kind, item)` with NaN as the not-done sentinel: the inner
/// scheduling loop probes it for every dependency check, and the flat
/// lookups replace the previous `BTreeMap<(Op, usize), f64>` — the sweep's
/// single hottest data structure — while visiting ops in exactly the same
/// order, so timelines are bit-identical.
pub fn simulate(
    agendas: &[Vec<Op>],
    costs: &[OpCosts],
    extra_edges: &ExtraEdges,
) -> anyhow::Result<Timeline> {
    simulate_stagewise(agendas, costs.len(), |_s, op| costs[op.item], extra_edges)
}

/// [`simulate`] with per-(stage, op) costs — the generalization uneven
/// stage partitions need: a stage's time for an op depends on its layer
/// share (and the head/embedding it may carry), not only on the item.
/// `simulate` delegates here with the stage-uniform closure
/// `|_, op| costs[op.item]`; the op visit order, dependency checks and
/// float operations are identical, so stage-uniform timelines are
/// bit-identical to the pre-generalization simulator.
pub fn simulate_stagewise(
    agendas: &[Vec<Op>],
    num_items: usize,
    cost_of: impl Fn(usize, Op) -> OpCosts,
    extra_edges: &ExtraEdges,
) -> anyhow::Result<Timeline> {
    let p = agendas.len();
    anyhow::ensure!(p >= 1, "need at least one stage");
    let n = num_items;
    for op in agendas.iter().flatten() {
        anyhow::ensure!(
            op.item < n,
            "agenda op {op:?} references item {} but only {n} costs were given",
            op.item
        );
    }
    for (before, after) in extra_edges {
        for op in [before, after] {
            anyhow::ensure!(
                op.item < n,
                "edge op {op:?} references item {} but only {n} costs were given",
                op.item
            );
        }
    }
    let slot = |op: Op, s: usize| -> usize { (s * 3 + kind_idx(op.kind)) * n + op.item };

    // completion[slot(op, stage)] = end time; NaN = not executed yet.
    let mut done: Vec<f64> = vec![f64::NAN; p * 3 * n];
    let mut cursor = vec![0usize; p]; // next agenda index per stage
    let mut stage_free = vec![0.0f64; p];
    let total_ops: usize = agendas.iter().map(|a| a.len()).sum();
    let mut ops_out: Vec<ScheduledOp> = Vec::with_capacity(total_ops);

    // Edges indexed by the dependent op (stage-independent) for O(1) lookup.
    let mut edges_by_after: Vec<Vec<Op>> = vec![Vec::new(); 3 * n];
    for (before, after) in extra_edges {
        edges_by_after[kind_idx(after.kind) * n + after.item].push(*before);
    }

    while ops_out.len() < total_ops {
        let mut progressed = false;
        for s in 0..p {
            // Greedily run every currently-runnable op at stage s.
            while cursor[s] < agendas[s].len() {
                let op = agendas[s][cursor[s]];
                // Cross-stage dependency.
                let dep_ready: Option<f64> = match op.kind {
                    OpKind::Fwd | OpKind::RecomputeFwd => {
                        if s == 0 {
                            Some(0.0)
                        } else {
                            not_nan(done[slot(op, s - 1)])
                        }
                    }
                    OpKind::Bwd => {
                        if s == p - 1 {
                            // Needs the (latest) forward of this item here.
                            not_nan(done[slot(Op::rfwd(op.item), s)])
                                .or_else(|| not_nan(done[slot(Op::fwd(op.item), s)]))
                        } else {
                            not_nan(done[slot(op, s + 1)])
                        }
                    }
                };
                let Some(mut ready) = dep_ready else { break };
                // Policy edges (same-stage).
                let mut blocked = false;
                for b in &edges_by_after[kind_idx(op.kind) * n + op.item] {
                    match not_nan(done[slot(*b, s)]) {
                        Some(t) => ready = ready.max(t),
                        None => {
                            blocked = true;
                            break;
                        }
                    }
                }
                if blocked {
                    break;
                }
                let start = ready.max(stage_free[s]);
                let c = cost_of(s, op);
                let cost = match op.kind {
                    OpKind::Fwd | OpKind::RecomputeFwd => c.fwd,
                    OpKind::Bwd => c.bwd,
                };
                let end = start + cost;
                stage_free[s] = end;
                done[slot(op, s)] = end;
                ops_out.push(ScheduledOp { op, stage: s, start, end });
                cursor[s] += 1;
                progressed = true;
            }
        }
        anyhow::ensure!(progressed, "pipeline deadlock: agendas have a dependency cycle");
    }

    let makespan = ops_out.iter().map(|o| o.end).fold(0.0, f64::max);
    let busy = ops_out.iter().map(|o| o.end - o.start).sum();
    Ok(Timeline { num_stages: p, ops: ops_out, makespan, busy })
}

/// NaN-sentinel read: `Some(t)` iff the op has completed.
#[inline]
fn not_nan(t: f64) -> Option<f64> {
    if t.is_nan() {
        None
    } else {
        Some(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn uniform_costs(lens: &[f64]) -> Vec<OpCosts> {
        lens.iter().map(|&l| OpCosts { fwd: l, bwd: 2.0 * l }).collect()
    }

    #[test]
    fn single_stage_single_item() {
        let agendas = vec![vec![Op::fwd(0), Op::bwd(0)]];
        let t = simulate(&agendas, &uniform_costs(&[1.0]), &vec![]).unwrap();
        assert_eq!(t.makespan, 3.0);
        assert_eq!(t.busy, 3.0);
        assert_eq!(t.bubble_ratio(), 0.0);
    }

    #[test]
    fn two_stage_dependency_chain() {
        // F must flow 0 -> 1; B must flow 1 -> 0.
        let agendas = vec![vec![Op::fwd(0), Op::bwd(0)], vec![Op::fwd(0), Op::bwd(0)]];
        let t = simulate(&agendas, &uniform_costs(&[1.0]), &vec![]).unwrap();
        // F@0 [0,1], F@1 [1,2], B@1 [2,4], B@0 [4,6].
        assert_eq!(t.makespan, 6.0);
        let f1 = t.ops.iter().find(|o| o.stage == 1 && o.op.kind == OpKind::Fwd).unwrap();
        assert_eq!(f1.start, 1.0);
        let b0 = t.ops.iter().find(|o| o.stage == 0 && o.op.kind == OpKind::Bwd).unwrap();
        assert_eq!(b0.start, 4.0);
    }

    #[test]
    fn deadlock_detected() {
        // Stage 0 waits for B which waits for F on stage 1 which is after B
        // in stage 1's agenda but B@1 needs... construct a cycle: agenda on
        // the only stage lists Bwd before Fwd (bwd needs fwd at last stage).
        let agendas = vec![vec![Op::bwd(0), Op::fwd(0)]];
        assert!(simulate(&agendas, &uniform_costs(&[1.0]), &vec![]).is_err());
    }

    #[test]
    fn extra_edges_enforced() {
        // Two independent items on one stage; force B(0) after B(1).
        let agendas = vec![vec![
            Op::fwd(0),
            Op::fwd(1),
            Op::bwd(1),
            Op::bwd(0),
        ]];
        let edges = vec![(Op::bwd(1), Op::bwd(0))];
        let t = simulate(&agendas, &uniform_costs(&[1.0, 1.0]), &edges).unwrap();
        let b0 = t
            .ops
            .iter()
            .find(|o| o.op == Op::bwd(0))
            .unwrap();
        let b1 = t.ops.iter().find(|o| o.op == Op::bwd(1)).unwrap();
        assert!(b0.start >= b1.end);
    }

    #[test]
    fn recompute_fwd_satisfies_backward() {
        let agendas = vec![vec![Op::fwd(0), Op::rfwd(0), Op::bwd(0)]];
        let t = simulate(&agendas, &uniform_costs(&[2.0]), &vec![]).unwrap();
        assert_eq!(t.makespan, 2.0 + 2.0 + 4.0);
    }

    #[test]
    fn busy_equals_sum_of_costs() {
        let lens = [1.0, 3.0, 2.0];
        let mut agendas = vec![Vec::new(); 2];
        for s in 0..2 {
            for i in 0..3 {
                agendas[s].push(Op::fwd(i));
            }
            for i in (0..3).rev() {
                agendas[s].push(Op::bwd(i));
            }
        }
        let t = simulate(&agendas, &uniform_costs(&lens), &vec![]).unwrap();
        let expect: f64 = lens.iter().map(|l| 3.0 * l).sum::<f64>() * 2.0;
        assert!((t.busy - expect).abs() < 1e-9);
    }

    #[test]
    fn empty_agendas_yield_empty_timeline() {
        let t = simulate(&[Vec::new(), Vec::new()], &[], &vec![]).unwrap();
        assert_eq!(t.ops.len(), 0);
        assert_eq!(t.makespan, 0.0);
        assert_eq!(t.bubble_ratio(), 0.0);
    }

    #[test]
    fn prop_simulated_stage_order_equals_agenda_order() {
        // The conformance property the executor relies on: the simulator
        // executes each stage's agenda strictly in order, for random
        // (sequence lengths, P, K) under EVERY registered schedule policy.
        use crate::chunk::construct_chunks;
        use crate::data::Sequence;
        use crate::util::prop::{check, ensure, gen_pair, gen_u64, gen_usize, gen_vec};
        let gen = gen_pair(
            gen_vec(gen_u64(1, 40), 1, 12),
            gen_pair(gen_usize(1, 6), gen_usize(1, 4)),
        );
        check(150, gen, |(lens, (p, k))| {
            let batch: Vec<Sequence> = lens
                .iter()
                .enumerate()
                .map(|(i, &len)| Sequence { id: i as u64, len })
                .collect();
            let set = construct_chunks(&batch, 8);
            let costs: Vec<OpCosts> = set
                .chunks
                .iter()
                .map(|c| {
                    let len = c.total_len() as f64;
                    OpCosts { fwd: len, bwd: 2.0 * len }
                })
                .collect();
            for kind in policy::PolicyKind::ALL {
                let (agendas, edges) = kind.agendas(&set, *k, *p);
                let t = simulate(&agendas, &costs, &edges).map_err(|e| e.to_string())?;
                for s in 0..*p {
                    let executed: Vec<Op> =
                        t.ops.iter().filter(|o| o.stage == s).map(|o| o.op).collect();
                    ensure(
                        executed == agendas[s],
                        "per-stage executed op order equals the agenda",
                    )?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn gantt_renders() {
        let agendas =
            vec![vec![Op::fwd(0), Op::bwd(0)], vec![Op::fwd(0), Op::bwd(0)]];
        let t = simulate(&agendas, &uniform_costs(&[1.0]), &vec![]).unwrap();
        let g = t.gantt(40);
        assert!(g.contains("stage 0"));
        assert!(g.contains("stage 1"));
        assert!(g.contains('B'));
    }
}
