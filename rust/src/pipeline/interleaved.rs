//! Interleaved-1F1B (virtual pipeline stages) with state-aware chunk
//! scheduling — the paper's named future-work direction ("we plan to
//! incorporate ChunkFlow's idea into more advanced pipeline scheduling
//! algorithms").
//!
//! In Megatron's interleaved schedule, each physical stage hosts `v`
//! *virtual* stages (model chunks), so a micro-batch makes `v` passes
//! around the pipeline; warmup bubbles shrink by ~`1/v` at the cost of more
//! communication. We model it by expanding every (item, virtual-stage) pair
//! into a pipeline op with cost divided by `v`.
//!
//! CAVEAT (documented limitation): the cross-pass dependency is applied as
//! a conservative same-stage edge (`Fwd(i, vs)` waits for `Fwd(i, vs-1)` on
//! the same stage, and symmetrically for backward), which over-serializes
//! the passes relative to Megatron's ring placement; v > 1 results are
//! therefore *pessimistic* bounds, useful for schedule-validity studies
//! (dependent-chunk ordering under interleaving) rather than bubble-ratio
//! claims. Tightening this to the true ring dependency is future work,
//! mirroring the paper's own deferral of advanced pipeline schedules.
//!
//! This file intentionally reuses the event simulator with a widened item
//! space (item' = item * v + vs) rather than forking it — one more policy,
//! same engine.

use super::{simulate, ExtraEdges, Op, OpCosts, Timeline};
use crate::chunk::ChunkSet;
use crate::schedule::{schedule_group, ChunkOp};

/// Build interleaved agendas for `m` micro-batches over `p` physical
/// stages with `v` virtual stages each, honoring state-aware backward
/// ordering for dependent chunk groups (if `set` is given).
pub fn simulate_interleaved(
    set: &ChunkSet,
    k: usize,
    p: usize,
    v: usize,
    cost_of: impl Fn(usize) -> OpCosts,
) -> anyhow::Result<Timeline> {
    assert!(v >= 1 && p >= 1);
    let m = set.chunks.len();
    let vitem = |item: usize, vs: usize| item * v + vs;

    // Backward order (state-aware): same unit construction as plain 1F1B.
    let mut bwd_order: Vec<(usize, bool)> = Vec::new(); // (chunk, recompute?)
    {
        let mut emitted = vec![false; m];
        for group in set.dependent_groups() {
            let ids: Vec<usize> = group.iter().map(|c| c.id).collect();
            let plan = schedule_group(&ids, k);
            let mut pending_rf = vec![false; ids.len()];
            for op in &plan.ops {
                match *op {
                    ChunkOp::RecomputeForward { chunk } => pending_rf[chunk] = true,
                    ChunkOp::Backward { chunk } => {
                        bwd_order.push((ids[chunk], pending_rf[chunk]));
                        emitted[ids[chunk]] = true;
                    }
                    _ => {}
                }
            }
        }
        for id in 0..m {
            if !emitted[id] {
                bwd_order.push((id, false));
            }
        }
        // Keep overall order anchored to forward order of the trigger chunk.
        // (Groups were appended in seq order; standalone appended after —
        // sort stably by the max chunk id in each contiguous run is
        // unnecessary: ordering only affects drain order.)
    }

    // Agendas: per physical stage, forwards of all (item, vs) in vs-major
    // order with warmup p - s, then interleave backward units (reverse vs).
    let mut agendas: Vec<Vec<Op>> = vec![Vec::new(); p];
    let mut edges: ExtraEdges = Vec::new();

    // Forward list per stage: (vs, item) lexicographic — each virtual pass
    // sweeps all items before the next pass (Megatron's grouping).
    let fwd_list: Vec<Op> = (0..v)
        .flat_map(|vs| (0..m).map(move |i| Op::fwd(vitem(i, vs))))
        .collect();
    // Backward units grouped by virtual pass (Megatron order): all chunks'
    // backwards at vs = v-1, then vs = v-2, ... Each unit is one op so the
    // 1F1B interleave never stalls a stage waiting on a glued chain.
    let bwd_units: Vec<Vec<Op>> = (0..v)
        .rev()
        .flat_map(|vs| {
            bwd_order.iter().map(move |&(id, rf)| {
                let mut unit = Vec::new();
                if rf && vs == v - 1 {
                    unit.push(Op::rfwd(vitem(id, vs)));
                }
                unit.push(Op::bwd(vitem(id, vs)));
                unit
            })
        })
        .collect();

    for s in 0..p {
        let warmup = (p - s).min(fwd_list.len());
        let mut agenda: Vec<Op> = fwd_list[..warmup].to_vec();
        let mut fi = warmup;
        let mut bi = 0;
        let emitted_fwd = |fi: usize, op: &Op| -> bool {
            // An op's forward is emitted if its position in fwd_list < fi.
            fwd_list
                .iter()
                .position(|f| f.item == op.item)
                .map(|pos| pos < fi)
                .unwrap_or(false)
        };
        while fi < fwd_list.len() {
            agenda.push(fwd_list[fi]);
            fi += 1;
            if bi < bwd_units.len()
                && bwd_units[bi].iter().all(|op| emitted_fwd(fi, op))
            {
                agenda.extend(bwd_units[bi].iter().copied());
                bi += 1;
            }
        }
        while bi < bwd_units.len() {
            agenda.extend(bwd_units[bi].iter().copied());
            bi += 1;
        }
        agendas[s] = agenda;
    }

    // Ring dependency: Fwd(i, vs) anywhere requires Fwd(i, vs-1) completed
    // on the SAME stage (conservative stand-in for "previous pass finished
    // its loop"); backward mirrors it upward.
    for i in 0..m {
        for vs in 1..v {
            edges.push((Op::fwd(vitem(i, vs - 1)), Op::fwd(vitem(i, vs))));
            edges.push((Op::bwd(vitem(i, vs)), Op::bwd(vitem(i, vs - 1))));
        }
    }
    // State-aware backward precedence between chunks (first virtual stage
    // to run backward is vs = v-1).
    for w in bwd_order.windows(2) {
        let (prev, _) = w[0];
        let (next, _) = w[1];
        edges.push((Op::bwd(vitem(prev, v - 1)), Op::bwd(vitem(next, v - 1))));
    }

    let costs: Vec<OpCosts> = (0..m)
        .flat_map(|i| {
            let c = cost_of(i);
            (0..v).map(move |_| OpCosts { fwd: c.fwd / v as f64, bwd: c.bwd / v as f64 })
        })
        .collect();
    simulate(&agendas, &costs, &edges)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chunk::construct_chunks;
    use crate::data::Sequence;
    use crate::pipeline::onef1b;

    fn chunkset(lens: &[u64], chunk: u64) -> ChunkSet {
        let batch: Vec<Sequence> = lens
            .iter()
            .enumerate()
            .map(|(i, &len)| Sequence { id: i as u64, len })
            .collect();
        construct_chunks(&batch, chunk)
    }

    fn unit_costs(set: &ChunkSet) -> impl Fn(usize) -> OpCosts + '_ {
        |id| {
            let len = set.chunks[id].total_len() as f64;
            OpCosts { fwd: len, bwd: 2.0 * len }
        }
    }

    #[test]
    fn v1_matches_plain_state_aware() {
        let set = chunkset(&[1, 1, 2, 4], 2);
        let plain = onef1b::simulate_state_aware(&set, 1, 4, unit_costs(&set)).unwrap();
        let inter = simulate_interleaved(&set, 1, 4, 1, unit_costs(&set)).unwrap();
        assert!((plain.busy - inter.busy).abs() < 1e-9, "same total work");
        assert!(
            (plain.makespan - inter.makespan).abs() / plain.makespan < 0.15,
            "v=1 should be close to plain ({} vs {})",
            inter.makespan,
            plain.makespan
        );
    }

    #[test]
    fn work_is_conserved_across_v() {
        let set = chunkset(&[8, 4, 4], 4);
        let t1 = simulate_interleaved(&set, 2, 4, 1, unit_costs(&set)).unwrap();
        let t2 = simulate_interleaved(&set, 2, 4, 2, unit_costs(&set)).unwrap();
        let t4 = simulate_interleaved(&set, 2, 4, 4, unit_costs(&set)).unwrap();
        assert!((t1.busy - t2.busy).abs() < 1e-9);
        assert!((t2.busy - t4.busy).abs() < 1e-9);
    }

    #[test]
    fn interleaving_is_valid_and_bounded() {
        // With the conservative same-stage cross-pass edges (module docs),
        // v > 1 is a pessimistic bound: still deadlock-free, work-conserving
        // and within v x the v=1 makespan.
        let set = chunkset(&[4; 12], 4);
        let t1 = simulate_interleaved(&set, 1, 4, 1, unit_costs(&set)).unwrap();
        let t2 = simulate_interleaved(&set, 1, 4, 2, unit_costs(&set)).unwrap();
        assert!((t1.busy - t2.busy).abs() < 1e-9);
        assert!(t2.makespan <= 2.0 * t1.makespan + 1e-9);
        assert!(t2.bubble_ratio() < 1.0);
    }

    #[test]
    fn every_virtual_op_scheduled_once_per_stage() {
        let set = chunkset(&[2, 6], 2);
        let (p, v) = (3usize, 2usize);
        let t = simulate_interleaved(&set, 1, p, v, unit_costs(&set)).unwrap();
        let m = set.chunks.len();
        for s in 0..p {
            let fwd = t
                .ops
                .iter()
                .filter(|o| o.stage == s && o.op.kind == crate::pipeline::OpKind::Fwd)
                .count();
            let bwd = t
                .ops
                .iter()
                .filter(|o| o.stage == s && o.op.kind == crate::pipeline::OpKind::Bwd)
                .count();
            assert_eq!(fwd, m * v, "stage {s} fwd");
            assert_eq!(bwd, m * v, "stage {s} bwd");
        }
    }

    #[test]
    fn p1_v1_single_microbatch_degenerates_to_sequential() {
        // One chunk on one stage, one virtual pass: F then B, no bubbles.
        let set = chunkset(&[2], 2);
        let t = simulate_interleaved(&set, 1, 1, 1, unit_costs(&set)).unwrap();
        assert_eq!(t.ops.len(), 2);
        assert!((t.makespan - 6.0).abs() < 1e-9, "fwd 2 + bwd 4");
        assert_eq!(t.bubble_ratio(), 0.0);
    }

    #[test]
    fn empty_chunkset_yields_empty_timeline() {
        // Zero micro-batches => empty agendas on every stage: legal, with a
        // zero-makespan, zero-bubble timeline (matches `simulate`'s own
        // empty-agenda degenerate case).
        let set = chunkset(&[], 4);
        assert!(set.chunks.is_empty());
        let t = simulate_interleaved(&set, 1, 3, 2, unit_costs(&set)).unwrap();
        assert_eq!(t.ops.len(), 0);
        assert_eq!(t.makespan, 0.0);
        assert_eq!(t.bubble_ratio(), 0.0);
        assert_eq!(t.num_stages, 3);
    }

    #[test]
    fn single_microbatch_multi_stage_is_valid() {
        let set = chunkset(&[4], 4); // one standalone chunk
        let t = simulate_interleaved(&set, 1, 4, 2, unit_costs(&set)).unwrap();
        // 1 item x 2 virtual stages x (fwd + bwd) on each of 4 stages.
        assert_eq!(t.ops.len(), 4 * 2 * 2);
        assert!(t.makespan > 0.0);
    }

    #[test]
    fn dependent_group_order_respected_under_interleaving() {
        let set = chunkset(&[8], 2); // 4 dependent chunks
        let t = simulate_interleaved(&set, 1, 2, 2, unit_costs(&set)).unwrap();
        // On each stage, chunk 3's (vs=1) backward precedes chunk 2's, etc.
        for s in 0..2 {
            let starts: Vec<(usize, f64)> = t
                .ops
                .iter()
                .filter(|o| {
                    o.stage == s
                        && o.op.kind == crate::pipeline::OpKind::Bwd
                        && o.op.item % 2 == 1 // vs = 1 (first bwd pass)
                })
                .map(|o| (o.op.item / 2, o.start))
                .collect();
            let mut sorted = starts.clone();
            sorted.sort_by(|a, b| a.1.total_cmp(&b.1));
            let order: Vec<usize> = sorted.iter().map(|x| x.0).collect();
            assert_eq!(order, vec![3, 2, 1, 0], "stage {s}");
        }
    }
}
