//! Executor probe: attach *measured* bubble ratios to sweep scenarios.
//!
//! Sweep scenarios describe 7B/14B-class models that cannot execute in CI,
//! so their `bubble_ratio` metrics are simulator predictions. The probe
//! runs a scaled-down mirror of each scenario — same length distribution
//! and seed, CI-sized context/ChunkSize, the reference mini model — through
//! the stage-parallel pipeline executor (`pipeline::exec`) and records the
//! wall-clock bubble ratio next to the simulator's prediction for the
//! *same* probe-sized chunk set and schedule.
//!
//! The resulting `measured_exec` block is additive and opt-in
//! (`chunkflow sweep --measure-exec`): wall-clock is inherently
//! nondeterministic, so the default artifact stays byte-deterministic and
//! `benchdiff` never compares the field (it only diffs
//! baseline/best/speedup).

use std::collections::BTreeMap;

use crate::chunk::construct_chunks;
use crate::config::{ModelSpec, ParallelConfig, RecomputeGranularity};
use crate::data::{BatchSampler, SyntheticCorpus};
use crate::pipeline::{
    build_exec_items, execute_state_aware, execute_state_aware_with, onef1b, ExecOptions,
    OpCosts,
};
use crate::runtime::{Backend, Manifest, ReferenceBackend, StagePartition};
use crate::sim::{search_elastic, CostModel};
use crate::train::init_params;

use super::engine::ScenarioResult;
use super::scenario::Scenario;

/// Probe scale: small enough for CI seconds, structured enough that the
/// state-aware schedule is non-trivial (dependent groups + short-sequence
/// packing under any long-tail distribution). The probe backend runs the
/// parallel fast path, so the envelope is ~10x wider than the scalar one
/// and the probe can afford a real 1K context.
const PROBE_CONTEXT: u64 = 1024;
const PROBE_CHUNK: usize = 128;
const PROBE_BATCH_CAP: usize = 8;
const PROBE_STAGE_CAP: u64 = 4;

/// Measured-vs-predicted execution stats for one scenario's probe.
#[derive(Clone, Debug, PartialEq)]
pub struct MeasuredExec {
    /// Pipeline stages executed (scenario PP clamped to the probe cap).
    pub stages: usize,
    pub chunk_size: u64,
    /// Retention budget (best feasible candidate's K, clamped).
    pub k: u64,
    pub context_length: u64,
    pub global_batch_size: usize,
    /// Wall-clock bubble ratio from the executor's measured timeline.
    pub bubble_ratio_measured: f64,
    /// The simulator's prediction for the same chunk set and schedule.
    pub bubble_ratio_predicted: f64,
    /// Peak live activation caches on any single stage.
    pub act_peak_chunks: usize,
}

/// Measured elastic-pipeline stats for one scenario's probe: the same
/// probe workload executed twice on a deliberately head-heavy mini model —
/// once under the equal partition + default policy, once under the
/// (partition, policy) the elastic search picks *at probe scale* — with the
/// wall-clock bubble ratio of each side. The acceptance contract is
/// directional (the measured bubble moves the way the simulator predicted),
/// never numeric, because wall-clock is machine-dependent.
#[derive(Clone, Debug, PartialEq)]
pub struct MeasuredElastic {
    /// Probe-scale chosen per-stage layer counts, `--partition` form.
    pub partition: String,
    /// Probe-scale chosen schedule policy name.
    pub policy: String,
    /// Wall-clock bubble of the equal partition + default policy run.
    pub measured_bubble_equal: f64,
    /// Wall-clock bubble of the elastic (partition, policy) run.
    pub measured_bubble_elastic: f64,
}

/// The reference mini model the probe executes (4 layers so stage
/// partitions up to the cap are non-degenerate).
fn probe_model() -> ModelSpec {
    ModelSpec {
        name: "exec-probe".into(),
        hidden_size: 32,
        num_layers: 4,
        num_heads: 2,
        num_kv_heads: 2,
        intermediate_size: 48,
        vocab_size: 64,
        tie_embeddings: true,
    }
}

/// Run the probe for one scenario. `best_k` is the scenario's best feasible
/// candidate's K (the schedule actually worth measuring), clamped to the
/// probe's chunk count.
pub fn measure_scenario(s: &Scenario, best_k: Option<u64>) -> anyhow::Result<MeasuredExec> {
    let stages = s.parallel.pp.clamp(1, PROBE_STAGE_CAP) as usize;
    let k = best_k.unwrap_or(1).clamp(1, 4);
    let max_chunks = PROBE_CONTEXT as usize / PROBE_CHUNK;
    let manifest = Manifest::for_reference(&probe_model(), PROBE_CHUNK, max_chunks)?;
    let mut backend = ReferenceBackend::new(manifest)?;
    // Probes measure wall-clock anyway (never diffed), so they default to
    // the parallel fast path; it is bit-identical to serial regardless.
    backend.enable_fast_path();
    backend.set_params(&init_params(&backend.manifest, s.seed ^ 0xE5EC))?;

    let batch_n = s.global_batch_size.min(PROBE_BATCH_CAP).max(1);
    let mut sampler = BatchSampler::new(s.dist()?, PROBE_CONTEXT, batch_n, s.seed);
    let batch = sampler.next_batch();
    let set = construct_chunks(&batch, PROBE_CHUNK as u64);
    let corpus = SyntheticCorpus::new(backend.manifest.vocab_size as u32, s.seed ^ 0xDA7A);
    let tokens: BTreeMap<u64, Vec<u32>> =
        batch.iter().map(|q| (q.id, corpus.generate(q.id, q.len))).collect();
    let seq_len: BTreeMap<u64, u64> = batch.iter().map(|q| (q.id, q.len)).collect();
    let items = build_exec_items(&backend, &set, &tokens, &seq_len);

    let out = execute_state_aware(&backend, &set, &items, k as usize, stages)?;
    let predicted = onef1b::simulate_state_aware(&set, k as usize, stages, |id| {
        let len = set.chunks[id].total_len() as f64;
        OpCosts { fwd: len, bwd: 2.0 * len }
    })?;
    Ok(MeasuredExec {
        stages,
        chunk_size: PROBE_CHUNK as u64,
        k,
        context_length: PROBE_CONTEXT,
        global_batch_size: batch_n,
        bubble_ratio_measured: out.timeline.bubble_ratio(),
        bubble_ratio_predicted: predicted.bubble_ratio(),
        act_peak_chunks: out.act_peak_chunks,
    })
}

/// The mini model the *elastic* probe executes: same 4-layer skeleton as
/// [`probe_model`] but with a 2048-entry vocabulary, so the LM head on the
/// last stage costs ~4 layer-equivalents of compute. That reproduces, at
/// probe scale, the exact asymmetry the elastic search exists to fix — an
/// equal layer split leaves the head-bearing stage on the critical path —
/// and it does so in *real* executor wall-clock, not just in the cost
/// model, because the reference backend genuinely pays the logits matmul
/// and vocab-wide softmax on the last stage.
fn elastic_probe_model() -> ModelSpec {
    ModelSpec { name: "elastic-probe".into(), vocab_size: 2048, ..probe_model() }
}

/// Pipeline stages the elastic probe runs. Two, not the scenario's pp: the
/// probe model has 4 layers, so 2 stages is the deepest pipeline where an
/// uneven partition is non-degenerate (4 stages would force 1,1,1,1).
const ELASTIC_PROBE_STAGES: usize = 2;

/// Run the elastic probe for one scenario: search at probe scale, then
/// execute the equal and elastic schedules back to back on the same
/// backend and batch. Returns None when the scenario has pp <= 1 or the
/// probe-scale search finds no strict win (nothing to measure against).
pub fn measure_elastic(s: &Scenario, best_k: Option<u64>) -> anyhow::Result<Option<MeasuredElastic>> {
    if s.parallel.pp <= 1 {
        return Ok(None);
    }
    let stages = ELASTIC_PROBE_STAGES;
    let k = best_k.unwrap_or(1).clamp(1, 4) as usize;
    let model = elastic_probe_model();
    let num_layers = model.num_layers as usize;

    let batch_n = s.global_batch_size.min(PROBE_BATCH_CAP).max(1);
    let mut sampler = BatchSampler::new(s.dist()?, PROBE_CONTEXT, batch_n, s.seed);
    let batch = sampler.next_batch();
    let set = construct_chunks(&batch, PROBE_CHUNK as u64);

    // Search on the probe-scale cost model (probe model, probe pipeline
    // depth) so the choice being measured is self-consistent with the
    // workload being executed.
    let parallel =
        ParallelConfig::new(1, stages as u64, RecomputeGranularity::Selective);
    let cost = CostModel::new(model.clone(), parallel);
    let choice = match search_elastic(&cost, &set, k)? {
        Some(c) => c,
        None => return Ok(None),
    };

    let max_chunks = PROBE_CONTEXT as usize / PROBE_CHUNK;
    let manifest = Manifest::for_reference(&model, PROBE_CHUNK, max_chunks)?;
    let mut backend = ReferenceBackend::new(manifest)?;
    backend.enable_fast_path();
    backend.set_params(&init_params(&backend.manifest, s.seed ^ 0xE5EC))?;
    let corpus = SyntheticCorpus::new(backend.manifest.vocab_size as u32, s.seed ^ 0xDA7A);
    let tokens: BTreeMap<u64, Vec<u32>> =
        batch.iter().map(|q| (q.id, corpus.generate(q.id, q.len))).collect();
    let seq_len: BTreeMap<u64, u64> = batch.iter().map(|q| (q.id, q.len)).collect();
    let items = build_exec_items(&backend, &set, &tokens, &seq_len);

    let equal = execute_state_aware(&backend, &set, &items, k, stages)?;
    let elastic_opts = ExecOptions {
        partition: Some(StagePartition::from_counts(&choice.partition, num_layers)?),
        policy: choice.policy,
        ..Default::default()
    };
    let elastic =
        execute_state_aware_with(&backend, &set, &items, k, stages, elastic_opts)?;
    Ok(Some(MeasuredElastic {
        partition: choice.partition_string(),
        policy: choice.policy.name().to_string(),
        measured_bubble_equal: equal.timeline.bubble_ratio(),
        measured_bubble_elastic: elastic.timeline.bubble_ratio(),
    }))
}

/// Attach probes to already-evaluated results — the `--measure-exec` pass.
pub fn attach_measured_exec(results: &mut [ScenarioResult]) -> anyhow::Result<()> {
    for r in results.iter_mut() {
        let best_k = r.best().map(|b| b.k);
        r.measured_exec = Some(
            measure_scenario(&r.scenario, best_k)
                .map_err(|e| e.context(format!("executor probe for `{}`", r.scenario.name)))?,
        );
        // The elastic probe rides along only where the full-scale search
        // emitted a block (keeps the artifact additive and the pass cheap).
        if r.elastic_pipeline.is_some() {
            let me = measure_elastic(&r.scenario, best_k).map_err(|e| {
                e.context(format!("elastic probe for `{}`", r.scenario.name))
            })?;
            if let Some(ep) = r.elastic_pipeline.as_mut() {
                ep.measured = me;
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probe_runs_on_a_smoke_scenario() {
        let s = &Scenario::smoke()[0];
        let me = measure_scenario(s, Some(4)).unwrap();
        assert!(me.stages >= 1);
        assert!((0.0..=1.0).contains(&me.bubble_ratio_measured), "{me:?}");
        assert!((0.0..=1.0).contains(&me.bubble_ratio_predicted), "{me:?}");
        assert!(me.act_peak_chunks >= 1, "{me:?}");
        assert_eq!(me.chunk_size, PROBE_CHUNK as u64);
    }

    #[test]
    fn elastic_probe_none_on_pp1_and_some_on_pp_scenarios() {
        let smoke = Scenario::smoke();
        let flat = smoke.iter().find(|s| s.parallel.pp <= 1).unwrap();
        assert_eq!(measure_elastic(flat, Some(2)).unwrap(), None);

        let deep = smoke.iter().find(|s| s.parallel.pp > 1).expect("smoke has a pp scenario");
        let me = measure_elastic(deep, Some(2))
            .unwrap()
            .expect("the head-heavy probe model must admit an uneven win");
        assert!((0.0..=1.0).contains(&me.measured_bubble_equal), "{me:?}");
        assert!((0.0..=1.0).contains(&me.measured_bubble_elastic), "{me:?}");
        let counts = StagePartition::parse(&me.partition, 4).unwrap().counts();
        assert_eq!(counts.len(), ELASTIC_PROBE_STAGES);
        assert!(
            counts[0] > counts[1],
            "the probe's LM head costs ~4 layer-equivalents, so the search \
             must shed layers from the head-bearing last stage: {me:?}"
        );
    }

    #[test]
    fn attach_fills_every_scenario() {
        let scenarios = Scenario::smoke();
        let mut results =
            crate::sweep::SweepEngine::serial().run(&scenarios).unwrap();
        attach_measured_exec(&mut results).unwrap();
        assert!(results.iter().all(|r| r.measured_exec.is_some()));
        // The artifact with probes attached still validates.
        let j = crate::sweep::to_json(&results, None);
        assert_eq!(crate::sweep::validate(&j).unwrap(), results.len());
    }
}
