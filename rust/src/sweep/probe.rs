//! Executor probe: attach *measured* bubble ratios to sweep scenarios.
//!
//! Sweep scenarios describe 7B/14B-class models that cannot execute in CI,
//! so their `bubble_ratio` metrics are simulator predictions. The probe
//! runs a scaled-down mirror of each scenario — same length distribution
//! and seed, CI-sized context/ChunkSize, the reference mini model — through
//! the stage-parallel pipeline executor (`pipeline::exec`) and records the
//! wall-clock bubble ratio next to the simulator's prediction for the
//! *same* probe-sized chunk set and schedule.
//!
//! The resulting `measured_exec` block is additive and opt-in
//! (`chunkflow sweep --measure-exec`): wall-clock is inherently
//! nondeterministic, so the default artifact stays byte-deterministic and
//! `benchdiff` never compares the field (it only diffs
//! baseline/best/speedup).

use std::collections::BTreeMap;

use crate::chunk::construct_chunks;
use crate::config::ModelSpec;
use crate::data::{BatchSampler, SyntheticCorpus};
use crate::pipeline::{build_exec_items, execute_state_aware, onef1b, OpCosts};
use crate::runtime::{Backend, Manifest, ReferenceBackend};
use crate::train::init_params;

use super::engine::ScenarioResult;
use super::scenario::Scenario;

/// Probe scale: small enough for CI seconds, structured enough that the
/// state-aware schedule is non-trivial (dependent groups + short-sequence
/// packing under any long-tail distribution). The probe backend runs the
/// parallel fast path, so the envelope is ~10x wider than the scalar one
/// and the probe can afford a real 1K context.
const PROBE_CONTEXT: u64 = 1024;
const PROBE_CHUNK: usize = 128;
const PROBE_BATCH_CAP: usize = 8;
const PROBE_STAGE_CAP: u64 = 4;

/// Measured-vs-predicted execution stats for one scenario's probe.
#[derive(Clone, Debug, PartialEq)]
pub struct MeasuredExec {
    /// Pipeline stages executed (scenario PP clamped to the probe cap).
    pub stages: usize,
    pub chunk_size: u64,
    /// Retention budget (best feasible candidate's K, clamped).
    pub k: u64,
    pub context_length: u64,
    pub global_batch_size: usize,
    /// Wall-clock bubble ratio from the executor's measured timeline.
    pub bubble_ratio_measured: f64,
    /// The simulator's prediction for the same chunk set and schedule.
    pub bubble_ratio_predicted: f64,
    /// Peak live activation caches on any single stage.
    pub act_peak_chunks: usize,
}

/// The reference mini model the probe executes (4 layers so stage
/// partitions up to the cap are non-degenerate).
fn probe_model() -> ModelSpec {
    ModelSpec {
        name: "exec-probe".into(),
        hidden_size: 32,
        num_layers: 4,
        num_heads: 2,
        num_kv_heads: 2,
        intermediate_size: 48,
        vocab_size: 64,
        tie_embeddings: true,
    }
}

/// Run the probe for one scenario. `best_k` is the scenario's best feasible
/// candidate's K (the schedule actually worth measuring), clamped to the
/// probe's chunk count.
pub fn measure_scenario(s: &Scenario, best_k: Option<u64>) -> anyhow::Result<MeasuredExec> {
    let stages = s.parallel.pp.clamp(1, PROBE_STAGE_CAP) as usize;
    let k = best_k.unwrap_or(1).clamp(1, 4);
    let max_chunks = PROBE_CONTEXT as usize / PROBE_CHUNK;
    let manifest = Manifest::for_reference(&probe_model(), PROBE_CHUNK, max_chunks)?;
    let mut backend = ReferenceBackend::new(manifest)?;
    // Probes measure wall-clock anyway (never diffed), so they default to
    // the parallel fast path; it is bit-identical to serial regardless.
    backend.enable_fast_path();
    backend.set_params(&init_params(&backend.manifest, s.seed ^ 0xE5EC))?;

    let batch_n = s.global_batch_size.min(PROBE_BATCH_CAP).max(1);
    let mut sampler = BatchSampler::new(s.dist()?, PROBE_CONTEXT, batch_n, s.seed);
    let batch = sampler.next_batch();
    let set = construct_chunks(&batch, PROBE_CHUNK as u64);
    let corpus = SyntheticCorpus::new(backend.manifest.vocab_size as u32, s.seed ^ 0xDA7A);
    let tokens: BTreeMap<u64, Vec<u32>> =
        batch.iter().map(|q| (q.id, corpus.generate(q.id, q.len))).collect();
    let seq_len: BTreeMap<u64, u64> = batch.iter().map(|q| (q.id, q.len)).collect();
    let items = build_exec_items(&backend, &set, &tokens, &seq_len);

    let out = execute_state_aware(&backend, &set, &items, k as usize, stages)?;
    let predicted = onef1b::simulate_state_aware(&set, k as usize, stages, |id| {
        let len = set.chunks[id].total_len() as f64;
        OpCosts { fwd: len, bwd: 2.0 * len }
    })?;
    Ok(MeasuredExec {
        stages,
        chunk_size: PROBE_CHUNK as u64,
        k,
        context_length: PROBE_CONTEXT,
        global_batch_size: batch_n,
        bubble_ratio_measured: out.timeline.bubble_ratio(),
        bubble_ratio_predicted: predicted.bubble_ratio(),
        act_peak_chunks: out.act_peak_chunks,
    })
}

/// Attach probes to already-evaluated results — the `--measure-exec` pass.
pub fn attach_measured_exec(results: &mut [ScenarioResult]) -> anyhow::Result<()> {
    for r in results.iter_mut() {
        let best_k = r.best().map(|b| b.k);
        r.measured_exec = Some(
            measure_scenario(&r.scenario, best_k)
                .map_err(|e| e.context(format!("executor probe for `{}`", r.scenario.name)))?,
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probe_runs_on_a_smoke_scenario() {
        let s = &Scenario::smoke()[0];
        let me = measure_scenario(s, Some(4)).unwrap();
        assert!(me.stages >= 1);
        assert!((0.0..=1.0).contains(&me.bubble_ratio_measured), "{me:?}");
        assert!((0.0..=1.0).contains(&me.bubble_ratio_predicted), "{me:?}");
        assert!(me.act_peak_chunks >= 1, "{me:?}");
        assert_eq!(me.chunk_size, PROBE_CHUNK as u64);
    }

    #[test]
    fn attach_fills_every_scenario() {
        let scenarios = Scenario::smoke();
        let mut results =
            crate::sweep::SweepEngine::serial().run(&scenarios).unwrap();
        attach_measured_exec(&mut results).unwrap();
        assert!(results.iter().all(|r| r.measured_exec.is_some()));
        // The artifact with probes attached still validates.
        let j = crate::sweep::to_json(&results, None);
        assert_eq!(crate::sweep::validate(&j).unwrap(), results.len());
    }
}
