//! The scenario-sweep subsystem: the measurement backbone of the repo.
//!
//! Four pieces:
//! - [`scenario`] — the registry of named workloads (paper Table 6 model ×
//!   context matrix plus long-tail SFT / continual pre-training /
//!   uniform-length distributions);
//! - [`engine`] — the parallel fan-out engine over
//!   [`crate::util::pool::ThreadPool`] that evaluates baselines and
//!   `(ChunkSize, K)` candidates as independent, deterministic work units
//!   (the same primitive `tune::GridSearch` and the `report` generators run
//!   on);
//! - [`output`] — deterministic, schema-versioned `BENCH_chunkflow.json`
//!   emission, the machine-readable perf trajectory CI archives;
//! - [`journal`] — the crash-resumable per-scenario journal behind
//!   [`SweepEngine::run_resumable`]: an interrupted sweep reruns only the
//!   missing scenarios and still emits byte-identical artifact bytes.
//!
//! `cargo run --release -- sweep --scenario smoke` is the CI entrypoint.

pub mod engine;
pub mod journal;
pub mod output;
pub mod probe;
pub mod scenario;

pub use engine::{
    CandidateResult, DpImbalance, ElasticPipeline, Parallelism, ScenarioResult, SpSharding,
    SweepEngine, UnitMetrics,
};
pub use output::{
    bubble_drift, compare_scenarios, doc_from_scenarios, scenario_json, to_json, validate,
    write_bench_json, BubbleDrift, DEFAULT_BENCH_PATH, SCHEMA_VERSION,
};
pub use probe::{
    attach_measured_exec, measure_elastic, measure_scenario, MeasuredElastic, MeasuredExec,
};
pub use scenario::Scenario;
