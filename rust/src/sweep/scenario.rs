//! Scenario registry for the sweep engine.
//!
//! A [`Scenario`] is one named workload the perf trajectory tracks: a model,
//! a parallel strategy, a context length, a sequence-length distribution and
//! a grid of `(ChunkSize, K)` candidates. The registry covers the paper's
//! Table 6 / Figure 8 configurations (7B/14B-class models at 32K/128K/256K
//! context) plus the workload-shape scenarios that related systems (Skrull's
//! dynamic data scheduling, FlexSP's workload-adaptive sequence parallelism)
//! evaluate: long-tail SFT, continual pre-training and uniform lengths.

use crate::baseline::{paper_table3, paper_table4};
use crate::config::{ModelSpec, ParallelConfig, RecomputeGranularity};
use crate::data::LengthDistribution;

const K: u64 = 1024;

/// One named sweep workload. Everything needed to evaluate it is derivable
/// deterministically from this description (no hidden state), which is what
/// makes parallel and serial sweeps bit-identical.
#[derive(Clone, Debug)]
pub struct Scenario {
    pub name: String,
    pub model: ModelSpec,
    /// Baseline (Megatron-like) parallel strategy; ChunkFlow candidates run
    /// the same `<TP, PP>` with selective recompute (the paper's setup).
    pub parallel: ParallelConfig,
    pub context_length: u64,
    /// Registry name of the length distribution (see
    /// [`LengthDistribution::by_name`]).
    pub distribution: String,
    pub global_batch_size: usize,
    /// Batches averaged per evaluation.
    pub iters: usize,
    pub seed: u64,
    /// `(ChunkSize, K)` grid evaluated for this scenario.
    pub candidates: Vec<(u64, u64)>,
}

impl Scenario {
    /// Resolve this scenario's length distribution.
    pub fn dist(&self) -> anyhow::Result<LengthDistribution> {
        LengthDistribution::by_name(&self.distribution)
    }

    /// ChunkFlow always runs selective recompute (peak memory is bounded by
    /// ChunkSize, so full recompute is never needed).
    pub fn chunkflow_parallel(&self) -> ParallelConfig {
        let mut p = self.parallel.clone();
        p.recompute = RecomputeGranularity::Selective;
        p
    }

    fn paper(
        model: &str,
        ctx: u64,
        dist: &str,
        batch: usize,
        iters: usize,
        candidates: Vec<(u64, u64)>,
    ) -> Scenario {
        let spec = ModelSpec::preset(model).expect("registry model preset");
        let parallel = paper_table3(model, ctx).expect("registry table3 config");
        Scenario {
            name: format!(
                "{}-{}-{dist}",
                model.trim_start_matches("qwen2.5-"),
                crate::util::format_tokens(ctx)
            ),
            model: spec,
            parallel,
            context_length: ctx,
            distribution: dist.to_string(),
            global_batch_size: batch,
            iters,
            seed: DEFAULT_SEED,
            candidates,
        }
    }

    /// Derive a dp > 1 variant of a scenario: same workload, `dp` replica
    /// groups (so the sweep exercises the DP-aware simulation path and the
    /// artifact carries the additive `dp_imbalance` block).
    fn with_dp(mut s: Scenario, dp: u64) -> Scenario {
        s.name = format!("{}-dp{dp}", s.name);
        s.parallel.dp = dp;
        s
    }

    /// Derive an sp > 1 variant of a scenario: same workload, ring
    /// sequence parallelism sharding the long (dependent) chunks (so the
    /// sweep exercises the SP-aware cost path and the artifact carries the
    /// additive `sp_sharding` block).
    fn with_sp(mut s: Scenario, sp: u64) -> Scenario {
        s.name = format!("{}-sp{sp}", s.name);
        s.parallel.sp = sp;
        s
    }

    /// The default candidate grid around the paper's tuned point: the tuned
    /// `(ChunkSize, K)` itself plus the constant-`ChunkSize*K` extremes of
    /// Table 6, deduplicated.
    fn default_candidates(model: &str, ctx: u64) -> Vec<(u64, u64)> {
        let (cs, k) = paper_table4(model, ctx).expect("registry table4 point");
        let mut grid = vec![(cs, k), (2 * K, 16), (8 * K, 4), (32 * K, 1)];
        grid.sort();
        grid.dedup();
        grid
    }

    /// Full registry: paper Table 6 model/context matrix on the evaluation
    /// distribution, plus the three workload-shape scenarios.
    pub fn registry() -> Vec<Scenario> {
        let mut out = Vec::new();
        for model in ["qwen2.5-7b", "qwen2.5-14b"] {
            for ctx in [32 * K, 128 * K, 256 * K] {
                out.push(Self::paper(
                    model,
                    ctx,
                    "eval",
                    128,
                    2,
                    Self::default_candidates(model, ctx),
                ));
            }
        }
        // Workload-shape scenarios (7B @ 32K so they stay minutes-fast).
        out.push(Self::paper(
            "qwen2.5-7b",
            32 * K,
            "longtail-sft",
            128,
            2,
            Self::default_candidates("qwen2.5-7b", 32 * K),
        ));
        out.push(Self::paper(
            "qwen2.5-7b",
            32 * K,
            "continual-pretrain",
            64,
            2,
            Self::default_candidates("qwen2.5-7b", 32 * K),
        ));
        out.push(Self::paper(
            "qwen2.5-7b",
            32 * K,
            "uniform-8K",
            128,
            2,
            Self::default_candidates("qwen2.5-7b", 32 * K),
        ));
        // Data-parallel variants (Obs. 3): the same workloads across dp
        // replica groups — iteration gated on the slowest rank + all-reduce.
        out.push(Self::with_dp(
            Self::paper(
                "qwen2.5-7b",
                32 * K,
                "eval",
                128,
                2,
                Self::default_candidates("qwen2.5-7b", 32 * K),
            ),
            4,
        ));
        out.push(Self::with_dp(
            Self::paper(
                "qwen2.5-7b",
                32 * K,
                "longtail-sft",
                128,
                2,
                Self::default_candidates("qwen2.5-7b", 32 * K),
            ),
            8,
        ));
        // Sequence-parallel variants (FlexSP/FPDT): long chunks shard sp
        // ways across a KV ring while short chunks stay whole.
        out.push(Self::with_sp(
            Self::paper(
                "qwen2.5-7b",
                32 * K,
                "longtail-sft",
                128,
                2,
                Self::default_candidates("qwen2.5-7b", 32 * K),
            ),
            4,
        ));
        out.push(Self::with_sp(
            Self::paper(
                "qwen2.5-7b",
                256 * K,
                "eval",
                128,
                2,
                Self::default_candidates("qwen2.5-7b", 256 * K),
            ),
            4,
        ));
        // Elastic-pipeline scenario (InfiniPipe): a deep-pipeline long-tail
        // workload — 7B @ 256K runs <4, 4> per Table 3, and the equal layer
        // split leaves the head-bearing last stage on the critical path, so
        // this is where the uneven-partition + policy search should emit
        // the additive `elastic_pipeline` block.
        out.push(Self::paper(
            "qwen2.5-7b",
            256 * K,
            "longtail-sft",
            128,
            2,
            Self::default_candidates("qwen2.5-7b", 256 * K),
        ));
        out
    }

    /// CI smoke set: three small scenarios (seconds, not minutes) spanning
    /// the three distribution families.
    pub fn smoke() -> Vec<Scenario> {
        let shrink = |mut s: Scenario| {
            s.name = format!("smoke-{}", s.name);
            s.global_batch_size = 32;
            s.iters = 1;
            s.candidates = vec![(8 * K, 1), (8 * K, 4)];
            s
        };
        vec![
            shrink(Self::paper("qwen2.5-7b", 32 * K, "eval", 32, 1, vec![])),
            shrink(Self::paper("qwen2.5-7b", 32 * K, "longtail-sft", 32, 1, vec![])),
            shrink(Self::paper("qwen2.5-7b", 32 * K, "uniform-8K", 32, 1, vec![])),
            // Additive dp scenario: exercises the DP-aware simulation and
            // the `dp_imbalance` artifact block; the three original smoke
            // scenarios above keep byte-identical artifact entries.
            shrink(Self::with_dp(
                Self::paper("qwen2.5-7b", 32 * K, "eval", 32, 1, vec![]),
                2,
            )),
            // Additive sp scenario: exercises the SP-aware cost path and
            // the `sp_sharding` artifact block; earlier smoke scenarios
            // keep byte-identical artifact entries.
            shrink(Self::with_sp(
                Self::paper("qwen2.5-7b", 32 * K, "eval", 32, 1, vec![]),
                2,
            )),
            // Additive pp scenario: 14B @ 32K runs <4, 4> per Table 3, so
            // the smoke sweep exercises the pipeline-aware paths (and the
            // elastic partition/policy search) on a long-tail workload too;
            // earlier smoke scenarios keep byte-identical artifact entries.
            shrink(Self::paper("qwen2.5-14b", 32 * K, "longtail-sft", 32, 1, vec![])),
        ]
    }

    /// Resolve a `--scenario` argument: `smoke`, `paper`/`all`, or a
    /// comma-separated list of registry names.
    pub fn select(which: &str) -> anyhow::Result<Vec<Scenario>> {
        match which {
            "smoke" => Ok(Self::smoke()),
            "paper" | "all" | "full" => Ok(Self::registry()),
            names => {
                let known: Vec<Scenario> =
                    Self::registry().into_iter().chain(Self::smoke()).collect();
                let mut picked = Vec::new();
                for name in names.split(',').map(str::trim).filter(|s| !s.is_empty()) {
                    let s = known.iter().find(|s| s.name == name).ok_or_else(|| {
                        anyhow::anyhow!(
                            "unknown scenario `{name}` (try `smoke`, `paper`, or one of: {})",
                            known.iter().map(|s| s.name.as_str()).collect::<Vec<_>>().join(", ")
                        )
                    })?;
                    picked.push(s.clone());
                }
                anyhow::ensure!(!picked.is_empty(), "no scenarios selected");
                Ok(picked)
            }
        }
    }
}

/// Fixed default seed: the perf trajectory compares like against like.
pub const DEFAULT_SEED: u64 = 20250710;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_is_well_formed() {
        let all = Scenario::registry();
        assert!(all.len() >= 9, "expected >=9 scenarios, got {}", all.len());
        let mut names = std::collections::BTreeSet::new();
        for s in &all {
            assert!(names.insert(s.name.clone()), "duplicate scenario {}", s.name);
            assert!(!s.candidates.is_empty());
            s.dist().expect("distribution resolves");
            // Uniform scenarios must sample below the context limit.
            assert!(s.context_length > 0 && s.global_batch_size > 0 && s.iters > 0);
        }
    }

    #[test]
    fn smoke_has_at_least_three_scenarios() {
        let smoke = Scenario::smoke();
        assert!(smoke.len() >= 3);
        for s in &smoke {
            assert!(s.name.starts_with("smoke-"));
            assert!(s.global_batch_size <= 64, "smoke must stay fast");
        }
    }

    #[test]
    fn select_resolves_names_and_rejects_unknown() {
        assert_eq!(Scenario::select("smoke").unwrap().len(), 6);
        assert!(Scenario::select("paper").unwrap().len() >= 14);
        let one = Scenario::select("7b-32K-eval").unwrap();
        assert_eq!(one.len(), 1);
        assert!(Scenario::select("not-a-scenario").is_err());
    }

    #[test]
    fn dp_scenarios_registered_with_dp_strategy() {
        let all = Scenario::registry();
        let dp4 = all.iter().find(|s| s.name == "7b-32K-eval-dp4").expect("dp4 scenario");
        assert_eq!(dp4.parallel.dp, 4);
        assert_eq!(dp4.parallel.world_size(), dp4.parallel.tp * dp4.parallel.pp * 4);
        let dp8 = all
            .iter()
            .find(|s| s.name == "7b-32K-longtail-sft-dp8")
            .expect("dp8 scenario");
        assert_eq!(dp8.parallel.dp, 8);
        // Non-dp scenarios stay at dp = 1 (artifact bytes unchanged).
        assert!(all
            .iter()
            .filter(|s| !s.name.contains("-dp"))
            .all(|s| s.parallel.dp == 1));
        // The smoke set carries exactly one dp scenario (fourth slot, after
        // the three original distribution-family scenarios).
        let smoke = Scenario::smoke();
        assert_eq!(smoke[3].name, "smoke-7b-32K-eval-dp2");
        assert_eq!(smoke[3].parallel.dp, 2);
        assert!(smoke[..3].iter().all(|s| s.parallel.dp == 1));
    }

    #[test]
    fn sp_scenarios_registered_with_sp_strategy() {
        let all = Scenario::registry();
        let sp4 = all
            .iter()
            .find(|s| s.name == "7b-32K-longtail-sft-sp4")
            .expect("sp4 longtail scenario");
        assert_eq!(sp4.parallel.sp, 4);
        assert_eq!(
            sp4.parallel.world_size(),
            sp4.parallel.tp * sp4.parallel.pp * 4
        );
        let sp4_long = all
            .iter()
            .find(|s| s.name == "7b-256K-eval-sp4")
            .expect("sp4 256K scenario");
        assert_eq!(sp4_long.parallel.sp, 4);
        // Non-sp scenarios stay at sp = 1 (artifact bytes unchanged).
        assert!(all
            .iter()
            .filter(|s| !s.name.contains("-sp"))
            .all(|s| s.parallel.sp == 1));
        // The smoke set carries exactly one sp scenario (fifth slot).
        let smoke = Scenario::smoke();
        assert_eq!(smoke[4].name, "smoke-7b-32K-eval-sp2");
        assert_eq!(smoke[4].parallel.sp, 2);
        assert!(smoke[..4].iter().all(|s| s.parallel.sp == 1));
    }

    #[test]
    fn pp_scenarios_registered_for_the_elastic_search() {
        // Registry: the deep-pipeline long-tail scenario the elastic search
        // targets runs <TP, PP> = <4, 4> (Table 3, 7B @ 256K).
        let all = Scenario::registry();
        let deep = all
            .iter()
            .find(|s| s.name == "7b-256K-longtail-sft")
            .expect("deep-pipeline longtail scenario");
        assert_eq!(deep.parallel.pp, 4);
        assert_eq!(deep.distribution, "longtail-sft");
        // Smoke: exactly one pp > 1 scenario, appended last so the earlier
        // smoke scenarios keep byte-identical artifact entries.
        let smoke = Scenario::smoke();
        assert_eq!(smoke.last().unwrap().name, "smoke-14b-32K-longtail-sft");
        assert_eq!(smoke.last().unwrap().parallel.pp, 4);
        assert!(smoke[..5].iter().all(|s| s.parallel.pp == 1));
    }

    #[test]
    fn chunkflow_parallel_is_always_selective() {
        for s in Scenario::registry() {
            assert_eq!(
                s.chunkflow_parallel().recompute,
                RecomputeGranularity::Selective
            );
        }
    }
}
