//! Schema-versioned `BENCH_*.json` emission — the machine-readable perf
//! trajectory CI archives from every run.
//!
//! The schema is a contract (see ROADMAP.md "Open items"): bump
//! [`SCHEMA_VERSION`] on any breaking change so downstream tooling that
//! diffs trajectories across commits can detect incompatibility instead of
//! misreading fields. Serialization is deterministic: object keys are
//! sorted (`Json::Obj` is a BTreeMap), floats use Rust's shortest
//! round-trip formatting, and no timestamps or host identifiers are
//! embedded, so identical runs produce identical bytes.

use std::path::Path;

use crate::util::json::Json;

use super::engine::{ScenarioResult, UnitMetrics};

/// Version of the `BENCH_chunkflow.json` schema.
pub const SCHEMA_VERSION: u64 = 1;

/// Default artifact filename.
pub const DEFAULT_BENCH_PATH: &str = "BENCH_chunkflow.json";

fn metrics_json(m: &UnitMetrics) -> Json {
    Json::obj(vec![
        ("iteration_seconds", Json::num(m.iteration_seconds)),
        ("bubble_ratio", Json::num(m.bubble_ratio)),
        ("num_microbatches", Json::num(m.num_microbatches)),
        ("peak_memory_bytes", Json::num(m.peak_memory_bytes as f64)),
    ])
}

/// Render one scenario's result as its artifact entry. Split out of
/// [`to_json`] so the crash-resumable sweep can journal each scenario's
/// *rendered* entry the moment it finishes — reassembling journaled entries
/// with [`doc_from_scenarios`] is then byte-identical to an uninterrupted
/// [`to_json`] run.
pub fn scenario_json(r: &ScenarioResult) -> Json {
    let s = &r.scenario;
    let candidates: Vec<Json> = r
        .candidates
        .iter()
        .map(|c| {
            Json::obj(vec![
                ("chunk_size", Json::num(c.chunk_size as f64)),
                ("k", Json::num(c.k as f64)),
                ("feasible", Json::Bool(c.feasible)),
                ("metrics", metrics_json(&c.metrics)),
            ])
        })
        .collect();
    let best = r
        .best()
        .map(|b| {
            Json::obj(vec![
                ("chunk_size", Json::num(b.chunk_size as f64)),
                ("k", Json::num(b.k as f64)),
                ("iteration_seconds", Json::num(b.metrics.iteration_seconds)),
            ])
        })
        .unwrap_or(Json::Null);
    let mut fields = vec![
        ("name", Json::str(s.name.clone())),
        ("model", Json::str(s.model.name.clone())),
        ("parallel", Json::str(s.parallel.paper_format())),
        ("context_length", Json::num(s.context_length as f64)),
        ("distribution", Json::str(s.distribution.clone())),
        ("global_batch_size", Json::num(s.global_batch_size as f64)),
        ("iters", Json::num(s.iters as f64)),
        ("seed", Json::num(s.seed as f64)),
        ("baseline", metrics_json(&r.baseline)),
        ("candidates", Json::Arr(candidates)),
        ("best", best),
        (
            "speedup",
            r.speedup().map(Json::num).unwrap_or(Json::Null),
        ),
    ];
    // Optional executor probe (`--measure-exec`): measured bubble
    // ratio next to the predicted one. Additive — absent in the
    // default artifact, and never compared by `benchdiff` (its
    // wall-clock component is nondeterministic by nature).
    if let Some(me) = &r.measured_exec {
        fields.push((
            "measured_exec",
            Json::obj(vec![
                ("stages", Json::num(me.stages as f64)),
                ("chunk_size", Json::num(me.chunk_size as f64)),
                ("k", Json::num(me.k as f64)),
                ("context_length", Json::num(me.context_length as f64)),
                ("global_batch_size", Json::num(me.global_batch_size as f64)),
                ("bubble_ratio_measured", Json::num(me.bubble_ratio_measured)),
                ("bubble_ratio_predicted", Json::num(me.bubble_ratio_predicted)),
                ("act_peak_chunks", Json::num(me.act_peak_chunks as f64)),
            ]),
        ));
    }
    // Additive DP load-imbalance block: present only for dp > 1
    // scenarios, so every existing scenario's bytes are unchanged;
    // `benchdiff` ignores it (it only diffs baseline/best/speedup).
    if let Some(di) = &r.dp_imbalance {
        fields.push((
            "dp_imbalance",
            Json::obj(vec![
                ("dp", Json::num(di.dp as f64)),
                ("round_robin", Json::num(di.round_robin)),
                ("chunk_balanced", Json::num(di.chunk_balanced)),
            ]),
        ));
    }
    // Additive SP sharding block: present only for sp > 1 scenarios, so
    // every existing scenario's bytes are unchanged; `benchdiff` ignores
    // it (it only diffs baseline/best/speedup).
    if let Some(sh) = &r.sp_sharding {
        fields.push((
            "sp_sharding",
            Json::obj(vec![
                ("sp", Json::num(sh.sp as f64)),
                ("sharded_chunks", Json::num(sh.sharded_chunks)),
                ("total_chunks", Json::num(sh.total_chunks)),
                ("ring_comm_seconds", Json::num(sh.ring_comm_seconds)),
            ]),
        ));
    }
    // Additive elastic-pipeline block: present only when the partition/
    // policy search strictly beat the equal split on a pp > 1 scenario, so
    // every equal-partition scenario's bytes are unchanged; `benchdiff`
    // ignores it (it only diffs baseline/best/speedup) — `bubble_drift`
    // is the report that reads bubble fields.
    if let Some(ep) = &r.elastic_pipeline {
        let mut ef = vec![
            ("pp", Json::num(ep.pp as f64)),
            ("partition", Json::str(ep.partition.clone())),
            ("policy", Json::str(ep.policy.clone())),
            ("predicted_bubble_equal", Json::num(ep.predicted_bubble_equal)),
            ("predicted_bubble_elastic", Json::num(ep.predicted_bubble_elastic)),
        ];
        if let Some(me) = &ep.measured {
            ef.push((
                "measured",
                Json::obj(vec![
                    ("partition", Json::str(me.partition.clone())),
                    ("policy", Json::str(me.policy.clone())),
                    ("measured_bubble_equal", Json::num(me.measured_bubble_equal)),
                    ("measured_bubble_elastic", Json::num(me.measured_bubble_elastic)),
                ]),
            ));
        }
        fields.push(("elastic_pipeline", Json::obj(ef)));
    }
    Json::obj(fields)
}

/// Assemble the versioned document from already-rendered scenario entries
/// (in scenario order). [`to_json`] is `doc_from_scenarios` over fresh
/// [`scenario_json`] renders; the resumable sweep calls it over a mix of
/// journaled and fresh entries instead.
pub fn doc_from_scenarios(scenarios: Vec<Json>, micro_benchmarks: Option<Json>) -> Json {
    let mut fields = vec![
        ("schema_version", Json::num(SCHEMA_VERSION as f64)),
        ("generator", Json::str("chunkflow-sweep")),
        ("scenarios", Json::Arr(scenarios)),
    ];
    if let Some(micro) = micro_benchmarks {
        fields.push(("micro_benchmarks", micro));
    }
    Json::obj(fields)
}

/// Render sweep results (plus optional micro-benchmark rows from
/// [`crate::util::bench::Bencher::to_json`]) as the versioned document.
pub fn to_json(results: &[ScenarioResult], micro_benchmarks: Option<Json>) -> Json {
    doc_from_scenarios(results.iter().map(scenario_json).collect(), micro_benchmarks)
}

/// Write the versioned document to `path`.
pub fn write_bench_json(
    path: &Path,
    results: &[ScenarioResult],
    micro_benchmarks: Option<Json>,
) -> anyhow::Result<()> {
    to_json(results, micro_benchmarks).write_file(path)
}

/// Validate a parsed `BENCH_chunkflow.json` against the contract this
/// module emits; returns the scenario count. Used by CI smoke and tests.
pub fn validate(doc: &Json) -> anyhow::Result<usize> {
    let version = doc.req_u64("schema_version")?;
    anyhow::ensure!(
        version == SCHEMA_VERSION,
        "schema_version {version} != supported {SCHEMA_VERSION}"
    );
    let scenarios = doc
        .get("scenarios")
        .and_then(|s| s.as_arr())
        .ok_or_else(|| anyhow::anyhow!("missing `scenarios` array"))?;
    for s in scenarios {
        let name = s.req_str("name")?;
        let baseline = s
            .get("baseline")
            .ok_or_else(|| anyhow::anyhow!("{name}: missing baseline"))?;
        anyhow::ensure!(
            baseline.req_f64("iteration_seconds")? > 0.0,
            "{name}: baseline iteration_seconds must be positive"
        );
        let cands = s
            .get("candidates")
            .and_then(|c| c.as_arr())
            .ok_or_else(|| anyhow::anyhow!("{name}: missing candidates"))?;
        anyhow::ensure!(!cands.is_empty(), "{name}: no candidates");
        for c in cands {
            c.req_u64("chunk_size")?;
            c.req_u64("k")?;
            let m = c
                .get("metrics")
                .ok_or_else(|| anyhow::anyhow!("{name}: candidate missing metrics"))?;
            anyhow::ensure!(
                m.req_f64("iteration_seconds")? > 0.0,
                "{name}: candidate iteration_seconds must be positive"
            );
        }
        // Optional DP load-imbalance block (schema v1 addition, dp > 1
        // scenarios only): both ratios are max/mean loads, so >= 1.
        if let Some(di) = s.get("dp_imbalance") {
            anyhow::ensure!(
                di.req_u64("dp")? >= 2,
                "{name}: dp_imbalance.dp must be >= 2"
            );
            for field in ["round_robin", "chunk_balanced"] {
                let v = di.req_f64(field)?;
                anyhow::ensure!(
                    v >= 1.0,
                    "{name}: dp_imbalance.{field} = {v} below 1.0 (max/mean ratio)"
                );
            }
        }
        // Optional SP sharding block (schema v1 addition, sp > 1 scenarios
        // only): sharded chunks are a subset of all chunks, and the ring
        // exchange costs real time whenever anything shards.
        if let Some(sh) = s.get("sp_sharding") {
            anyhow::ensure!(
                sh.req_u64("sp")? >= 2,
                "{name}: sp_sharding.sp must be >= 2"
            );
            let sharded = sh.req_f64("sharded_chunks")?;
            let total = sh.req_f64("total_chunks")?;
            anyhow::ensure!(
                sharded >= 0.0 && total > 0.0 && sharded <= total,
                "{name}: sp_sharding chunk counts malformed ({sharded} of {total})"
            );
            anyhow::ensure!(
                sh.req_f64("ring_comm_seconds")? >= 0.0,
                "{name}: sp_sharding.ring_comm_seconds must be non-negative"
            );
        }
        // Optional elastic-pipeline block (schema v1 addition, pp > 1
        // scenarios only): emitted only on a strict simulated win, so the
        // elastic bubble must be strictly below the equal one; the
        // partition string must be non-empty comma-joined positive counts.
        if let Some(ep) = s.get("elastic_pipeline") {
            anyhow::ensure!(
                ep.req_u64("pp")? >= 2,
                "{name}: elastic_pipeline.pp must be >= 2"
            );
            let part = ep.req_str("partition")?;
            let counts_ok = !part.is_empty()
                && part
                    .split(',')
                    .all(|t| t.trim().parse::<u64>().map(|c| c >= 1).unwrap_or(false));
            anyhow::ensure!(
                counts_ok,
                "{name}: elastic_pipeline.partition `{part}` is not a comma-joined \
                 list of positive layer counts"
            );
            anyhow::ensure!(
                !ep.req_str("policy")?.is_empty(),
                "{name}: elastic_pipeline.policy must be non-empty"
            );
            let eq = ep.req_f64("predicted_bubble_equal")?;
            let el = ep.req_f64("predicted_bubble_elastic")?;
            for (field, v) in [("predicted_bubble_equal", eq), ("predicted_bubble_elastic", el)] {
                anyhow::ensure!(
                    (0.0..=1.0).contains(&v),
                    "{name}: elastic_pipeline.{field} = {v} outside [0, 1]"
                );
            }
            anyhow::ensure!(
                el < eq,
                "{name}: elastic_pipeline block without a strict win \
                 (elastic {el} vs equal {eq}) — equal-partition wins must omit the block"
            );
            // Probe measurements are wall-clock: range-checked only, never
            // compared (the direction contract is asserted by tests, not
            // by artifact validation — a loaded machine can invert it).
            if let Some(me) = ep.get("measured") {
                me.req_str("partition")?;
                me.req_str("policy")?;
                for field in ["measured_bubble_equal", "measured_bubble_elastic"] {
                    let v = me.req_f64(field)?;
                    anyhow::ensure!(
                        (0.0..=1.0).contains(&v),
                        "{name}: elastic_pipeline.measured.{field} = {v} outside [0, 1]"
                    );
                }
            }
        }
        // Optional executor-probe block (schema v1 addition): when present
        // it must carry the measured/predicted bubble pair and a sane
        // stage count. Old artifacts without it remain valid.
        if let Some(me) = s.get("measured_exec") {
            anyhow::ensure!(
                me.req_u64("stages")? >= 1,
                "{name}: measured_exec.stages must be >= 1"
            );
            me.req_u64("chunk_size")?;
            me.req_u64("k")?;
            for field in ["bubble_ratio_measured", "bubble_ratio_predicted"] {
                let v = me.req_f64(field)?;
                anyhow::ensure!(
                    (0.0..=1.0).contains(&v),
                    "{name}: measured_exec.{field} = {v} outside [0, 1]"
                );
            }
        }
    }
    // `micro_benchmarks` is optional, but when present it must hold the
    // `util::bench` row shape schema v1 reserves for it.
    if let Some(micro) = doc.get("micro_benchmarks") {
        let rows = micro
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("`micro_benchmarks` must be an array"))?;
        for row in rows {
            let name = row.req_str("name")?;
            // Zero is legal: sub-nanosecond iterations truncate to 0 ns in
            // `util::bench` — only negatives and non-numbers are malformed.
            for field in ["mean_ns", "p50_ns", "p95_ns", "min_ns"] {
                anyhow::ensure!(
                    row.req_f64(field)? >= 0.0,
                    "micro-benchmark `{name}`: {field} must be non-negative"
                );
            }
        }
    }
    Ok(scenarios.len())
}

/// Compare two artifacts' scenario metrics for drift. Under the same
/// `schema_version`, every scenario of the *old* artifact must still exist
/// in the new one and agree exactly on `baseline`, `best` and `speedup`:
/// the sweep is deterministic (fixed seeds, sorted-key serialization), so
/// any metric difference — or a scenario silently disappearing — is a
/// correctness bug, not noise. Scenarios that only exist in the new
/// artifact are fine (additions). Returns the number of scenarios compared.
/// Both documents must carry a `schema_version` (a corrupt artifact fails
/// loudly instead of silently disabling the guard); *different* versions
/// compare zero scenarios, so CI survives intentional schema bumps.
pub fn compare_scenarios(old: &Json, new: &Json) -> anyhow::Result<usize> {
    let old_version = old.req_u64("schema_version")?;
    if old_version != new.req_u64("schema_version")? {
        return Ok(0);
    }
    let scenario_map = |doc: &Json| -> Vec<(String, Json)> {
        doc.get("scenarios")
            .and_then(|s| s.as_arr())
            .map(|arr| {
                arr.iter()
                    .filter_map(|s| {
                        s.req_str("name").ok().map(|n| (n.to_string(), s.clone()))
                    })
                    .collect()
            })
            .unwrap_or_default()
    };
    let new_scenarios = scenario_map(new);
    let mut compared = 0usize;
    for (name, old_s) in scenario_map(old) {
        let Some((_, new_s)) = new_scenarios.iter().find(|(n, _)| *n == name) else {
            anyhow::bail!(
                "scenario `{name}` present in the old artifact is missing from the new one \
                 (dropped or renamed scenarios count as drift)"
            );
        };
        for field in ["baseline", "best", "speedup"] {
            let (o, w) = (old_s.get(field), new_s.get(field));
            anyhow::ensure!(
                o == w,
                "scenario `{name}`: `{field}` drifted\n  old: {}\n  new: {}",
                o.map(|j| j.dump()).unwrap_or_else(|| "<missing>".into()),
                w.map(|j| j.dump()).unwrap_or_else(|| "<missing>".into()),
            );
        }
        compared += 1;
    }
    Ok(compared)
}

/// One scenario's bubble-ratio drift between two artifacts — the
/// informational report behind `chunkflow benchdiff` (the *gate* stays
/// [`compare_scenarios`]'s exact equality on baseline/best/speedup).
#[derive(Clone, Debug, PartialEq)]
pub struct BubbleDrift {
    pub name: String,
    /// Baseline bubble ratio, old artifact then new.
    pub baseline_old: f64,
    pub baseline_new: f64,
    /// Best-candidate bubble ratio (the candidate the `best` block names),
    /// old artifact then new; None when a side has no feasible best.
    pub best_old: Option<f64>,
    pub best_new: Option<f64>,
}

/// Per-scenario bubble-ratio drift for every scenario present in *both*
/// artifacts, in the old artifact's order. Purely informational: bubble
/// ratios are already pinned byte-exactly by [`compare_scenarios`] (they
/// live inside `baseline` and `candidates`), so this report exists to make
/// schedule-quality movement visible next to the speedup numbers rather
/// than buried in a byte diff. Malformed or missing fields simply drop the
/// row — a report must never out-strict the gate.
pub fn bubble_drift(old: &Json, new: &Json) -> Vec<BubbleDrift> {
    let scenarios = |doc: &Json| -> Vec<Json> {
        doc.get("scenarios")
            .and_then(|s| s.as_arr())
            .map(|a| a.to_vec())
            .unwrap_or_default()
    };
    // The bubble of the candidate the scenario's `best` block points at.
    let best_bubble = |s: &Json| -> Option<f64> {
        let best = s.get("best")?;
        let (cs, k) = (best.req_u64("chunk_size").ok()?, best.req_u64("k").ok()?);
        s.get("candidates")?.as_arr()?.iter().find_map(|c| {
            (c.req_u64("chunk_size").ok()? == cs && c.req_u64("k").ok()? == k)
                .then(|| c.get("metrics")?.req_f64("bubble_ratio").ok())
                .flatten()
        })
    };
    let news = scenarios(new);
    scenarios(old)
        .iter()
        .filter_map(|old_s| {
            let name = old_s.req_str("name").ok()?.to_string();
            let new_s = news
                .iter()
                .find(|s| s.req_str("name").ok() == Some(name.as_str()))?;
            Some(BubbleDrift {
                baseline_old: old_s.get("baseline")?.req_f64("bubble_ratio").ok()?,
                baseline_new: new_s.get("baseline")?.req_f64("bubble_ratio").ok()?,
                best_old: best_bubble(old_s),
                best_new: best_bubble(new_s),
                name,
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sweep::{Scenario, SweepEngine};

    #[test]
    fn emitted_json_validates_and_roundtrips() {
        let results = SweepEngine::serial().run(&Scenario::smoke()).unwrap();
        let j = to_json(&results, None);
        assert_eq!(validate(&j).unwrap(), results.len());
        assert!(validate(&j).unwrap() >= 3, "smoke must cover >= 3 scenarios");
        // Byte-exact roundtrip through the parser.
        let reparsed = Json::parse(&j.pretty()).unwrap();
        assert_eq!(reparsed, j);
        assert_eq!(validate(&reparsed).unwrap(), results.len());
    }

    #[test]
    fn parallel_sweep_produces_bit_identical_json() {
        let scenarios = Scenario::smoke();
        let serial = SweepEngine::serial().run(&scenarios).unwrap();
        let parallel = SweepEngine::with_threads(6).run(&scenarios).unwrap();
        assert_eq!(
            to_json(&serial, None).pretty(),
            to_json(&parallel, None).pretty(),
            "parallel sweep must be bit-identical to serial"
        );
    }

    #[test]
    fn validate_checks_micro_benchmark_rows() {
        let results = SweepEngine::serial()
            .run(&Scenario::smoke()[..1].to_vec())
            .unwrap();
        // Well-formed rows (the util::bench shape) validate.
        let mut b = crate::util::bench::Bencher::new(5, 20);
        b.bench("row", || {
            crate::util::bench::black_box(1 + 1);
        });
        let j = to_json(&results, Some(b.to_json()));
        assert_eq!(validate(&j).unwrap(), 1);
        // Malformed rows are rejected.
        let bad = to_json(&results, Some(Json::Arr(vec![Json::obj(vec![(
            "name",
            Json::str("no-mean"),
        )])])));
        let err = validate(&bad).unwrap_err().to_string();
        assert!(err.contains("mean_ns"), "{err}");
        // A non-array field is rejected.
        let not_arr = to_json(&results, Some(Json::str("oops")));
        assert!(validate(&not_arr).is_err());
    }

    #[test]
    fn compare_scenarios_accepts_identical_and_rejects_drift() {
        let results = SweepEngine::serial().run(&Scenario::smoke()).unwrap();
        let a = to_json(&results, None);
        let b = to_json(&results, None);
        assert_eq!(compare_scenarios(&a, &b).unwrap(), results.len());

        // Perturb one scenario's speedup: must be flagged as drift.
        let mut drifted = b.clone();
        if let Json::Obj(o) = &mut drifted {
            if let Some(Json::Arr(scenarios)) = o.get_mut("scenarios") {
                if let Some(Json::Obj(s0)) = scenarios.first_mut() {
                    s0.insert("speedup".into(), Json::num(999.0));
                }
            }
        }
        let err = compare_scenarios(&a, &drifted).unwrap_err().to_string();
        assert!(err.contains("speedup"), "{err}");

        // A schema bump compares zero scenarios; a new artifact that only
        // *adds* scenarios is fine.
        let mut bumped = b.clone();
        if let Json::Obj(o) = &mut bumped {
            o.insert("schema_version".into(), Json::num(99.0));
        }
        assert_eq!(compare_scenarios(&bumped, &a).unwrap(), 0);
        assert_eq!(compare_scenarios(&to_json(&[], None), &a).unwrap(), 0);

        // But a scenario disappearing from the new artifact is drift.
        let err = compare_scenarios(&a, &to_json(&[], None))
            .unwrap_err()
            .to_string();
        assert!(err.contains("missing"), "{err}");
    }

    #[test]
    fn dp_imbalance_block_is_additive_and_validated() {
        let results = SweepEngine::serial().run(&Scenario::smoke()).unwrap();
        let j = to_json(&results, None);
        assert_eq!(validate(&j).unwrap(), results.len());
        // dp scenarios carry the block; dp=1 scenarios must not (their
        // serialized bytes are what the bench-smoke drift check pins).
        for (r, s) in results.iter().zip(j.get("scenarios").unwrap().as_arr().unwrap()) {
            assert_eq!(
                s.get("dp_imbalance").is_some(),
                r.scenario.parallel.dp > 1,
                "{}",
                r.scenario.name
            );
        }
        // benchdiff never compares the block: two identical artifacts pass,
        // and stripping the block from one side still passes (it only diffs
        // baseline/best/speedup).
        let mut stripped = j.clone();
        if let Json::Obj(o) = &mut stripped {
            if let Some(Json::Arr(scenarios)) = o.get_mut("scenarios") {
                for s in scenarios.iter_mut() {
                    if let Json::Obj(so) = s {
                        so.remove("dp_imbalance");
                    }
                }
            }
        }
        assert_eq!(compare_scenarios(&j, &stripped).unwrap(), results.len());
        // A malformed block (ratio below 1.0) is rejected by validate.
        let mut bad = j.clone();
        if let Json::Obj(o) = &mut bad {
            if let Some(Json::Arr(scenarios)) = o.get_mut("scenarios") {
                for s in scenarios.iter_mut() {
                    if let Json::Obj(so) = s {
                        if let Some(block) = so.get_mut("dp_imbalance") {
                            *block = Json::obj(vec![
                                ("dp", Json::num(2.0)),
                                ("round_robin", Json::num(0.5)),
                                ("chunk_balanced", Json::num(1.0)),
                            ]);
                        }
                    }
                }
            }
        }
        let err = validate(&bad).unwrap_err().to_string();
        assert!(err.contains("round_robin"), "{err}");
    }

    #[test]
    fn sp_sharding_block_is_additive_and_validated() {
        let results = SweepEngine::serial().run(&Scenario::smoke()).unwrap();
        let j = to_json(&results, None);
        assert_eq!(validate(&j).unwrap(), results.len());
        // sp scenarios carry the block; sp=1 scenarios must not (their
        // serialized bytes are what the bench-smoke drift check pins).
        for (r, s) in results.iter().zip(j.get("scenarios").unwrap().as_arr().unwrap()) {
            assert_eq!(
                s.get("sp_sharding").is_some(),
                r.scenario.parallel.sp > 1,
                "{}",
                r.scenario.name
            );
        }
        // benchdiff never compares the block: stripping it from one side
        // still passes (it only diffs baseline/best/speedup).
        let mut stripped = j.clone();
        if let Json::Obj(o) = &mut stripped {
            if let Some(Json::Arr(scenarios)) = o.get_mut("scenarios") {
                for s in scenarios.iter_mut() {
                    if let Json::Obj(so) = s {
                        so.remove("sp_sharding");
                    }
                }
            }
        }
        assert_eq!(compare_scenarios(&j, &stripped).unwrap(), results.len());
        // A malformed block (more sharded than total chunks) is rejected.
        let mut bad = j.clone();
        if let Json::Obj(o) = &mut bad {
            if let Some(Json::Arr(scenarios)) = o.get_mut("scenarios") {
                for s in scenarios.iter_mut() {
                    if let Json::Obj(so) = s {
                        if let Some(block) = so.get_mut("sp_sharding") {
                            *block = Json::obj(vec![
                                ("sp", Json::num(2.0)),
                                ("sharded_chunks", Json::num(9.0)),
                                ("total_chunks", Json::num(4.0)),
                                ("ring_comm_seconds", Json::num(0.001)),
                            ]);
                        }
                    }
                }
            }
        }
        let err = validate(&bad).unwrap_err().to_string();
        assert!(err.contains("chunk counts"), "{err}");
    }

    #[test]
    fn elastic_pipeline_block_is_additive_and_validated() {
        // Inject a synthetic block so the test pins the schema contract
        // regardless of which smoke scenarios the search wins on.
        let mut results = SweepEngine::serial().run(&Scenario::smoke()).unwrap();
        let i = results
            .iter()
            .position(|r| r.scenario.parallel.pp > 1)
            .expect("smoke must register a pp scenario");
        results[i].elastic_pipeline = Some(crate::sweep::ElasticPipeline {
            pp: results[i].scenario.parallel.pp,
            partition: "14,12,12,10".into(),
            policy: "state-aware-1f1b".into(),
            predicted_bubble_equal: 0.30,
            predicted_bubble_elastic: 0.22,
            measured: Some(crate::sweep::MeasuredElastic {
                partition: "3,1".into(),
                policy: "state-aware-1f1b".into(),
                measured_bubble_equal: 0.4,
                measured_bubble_elastic: 0.3,
            }),
        });
        let j = to_json(&results, None);
        assert_eq!(validate(&j).unwrap(), results.len());
        // Only pp > 1 scenarios may carry the block, and only as a win.
        for (r, s) in results.iter().zip(j.get("scenarios").unwrap().as_arr().unwrap()) {
            if s.get("elastic_pipeline").is_some() {
                assert!(r.scenario.parallel.pp > 1, "{}", r.scenario.name);
            }
        }
        // benchdiff never compares the block: stripping it from one side
        // still passes (it only diffs baseline/best/speedup).
        let mut stripped = j.clone();
        if let Json::Obj(o) = &mut stripped {
            if let Some(Json::Arr(scenarios)) = o.get_mut("scenarios") {
                for s in scenarios.iter_mut() {
                    if let Json::Obj(so) = s {
                        so.remove("elastic_pipeline");
                    }
                }
            }
        }
        assert_eq!(compare_scenarios(&j, &stripped).unwrap(), results.len());
        // A block without a strict win is rejected by validate: equal-
        // partition outcomes must omit the block, not emit a zero delta.
        let mut bad = j.clone();
        if let Json::Obj(o) = &mut bad {
            if let Some(Json::Arr(scenarios)) = o.get_mut("scenarios") {
                for s in scenarios.iter_mut() {
                    if let Json::Obj(so) = s {
                        if let Some(block) = so.get_mut("elastic_pipeline") {
                            *block = Json::obj(vec![
                                ("pp", Json::num(4.0)),
                                ("partition", Json::str("12,12,12,12")),
                                ("policy", Json::str("state-aware-1f1b")),
                                ("predicted_bubble_equal", Json::num(0.25)),
                                ("predicted_bubble_elastic", Json::num(0.25)),
                            ]);
                        }
                    }
                }
            }
        }
        let err = validate(&bad).unwrap_err().to_string();
        assert!(err.contains("strict win"), "{err}");
        // A malformed partition string is rejected too.
        let mut bad_part = j.clone();
        if let Json::Obj(o) = &mut bad_part {
            if let Some(Json::Arr(scenarios)) = o.get_mut("scenarios") {
                for s in scenarios.iter_mut() {
                    if let Json::Obj(so) = s {
                        if let Some(Json::Obj(block)) = so.get_mut("elastic_pipeline") {
                            block.insert("partition".into(), Json::str("14,0,12"));
                        }
                    }
                }
            }
        }
        let err = validate(&bad_part).unwrap_err().to_string();
        assert!(err.contains("partition"), "{err}");
    }

    #[test]
    fn bubble_drift_reports_per_scenario_rows_without_gating() {
        let results = SweepEngine::serial().run(&Scenario::smoke()).unwrap();
        let j = to_json(&results, None);
        let rows = bubble_drift(&j, &j);
        assert_eq!(rows.len(), results.len());
        for (row, r) in rows.iter().zip(&results) {
            assert_eq!(row.name, r.scenario.name);
            assert_eq!(row.baseline_old, row.baseline_new);
            assert_eq!(row.best_old, row.best_new);
            assert!(row.best_old.is_some(), "{}: smoke best must exist", row.name);
            assert_eq!(row.baseline_old, r.baseline.bubble_ratio);
        }
        // Disjoint artifacts produce no rows — and crucially no error: the
        // drift report never out-stricts the compare_scenarios gate.
        assert!(bubble_drift(&j, &to_json(&[], None)).is_empty());
        assert!(bubble_drift(&to_json(&[], None), &j).is_empty());
    }

    #[test]
    fn validate_rejects_wrong_version() {
        let mut doc = to_json(&[], None);
        if let Json::Obj(o) = &mut doc {
            o.insert("schema_version".into(), Json::num(99.0));
        }
        assert!(validate(&doc).is_err());
    }

    #[test]
    fn committed_smoke_artifact_stays_fresh() {
        // Auto-blessing snapshot of the committed perf baseline: the root
        // BENCH_chunkflow.json is what CI's bench-smoke job benchdiffs a
        // fresh sweep against. The smoke sweep is deterministic, so the
        // canonical bytes are reproducible on any machine; when they drift
        // legitimately (new scenario, cost-model change) this test
        // refreshes the file — review and commit the new bytes together
        // with the change that moved them. It never fails the suite: the
        // gate against *unintended* drift is CI's benchdiff against the
        // committed bytes, not this bless step.
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .expect("package lives under the workspace root")
            .join(DEFAULT_BENCH_PATH);
        let results = SweepEngine::serial().run(&Scenario::smoke()).unwrap();
        let fresh = to_json(&results, None);
        if Json::parse_file(&path).ok().as_ref() != Some(&fresh) {
            fresh.write_file(&path).unwrap();
            eprintln!(
                "refreshed {} from the smoke sweep — commit the new bytes",
                path.display()
            );
        }
        let doc = Json::parse_file(&path).unwrap();
        assert_eq!(validate(&doc).unwrap(), results.len());
        assert_eq!(compare_scenarios(&doc, &fresh).unwrap(), results.len());
    }

    #[test]
    fn write_creates_parent_dirs_and_file() {
        let results = SweepEngine::serial()
            .run(&Scenario::smoke()[..1].to_vec())
            .unwrap();
        let dir = std::env::temp_dir().join("chunkflow_sweep_test");
        let path = dir.join("BENCH_chunkflow.json");
        write_bench_json(&path, &results, None).unwrap();
        let doc = Json::parse_file(&path).unwrap();
        assert_eq!(validate(&doc).unwrap(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
