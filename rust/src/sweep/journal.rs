//! Crash-resumable sweep journal.
//!
//! [`SweepEngine::run_resumable`](super::SweepEngine::run_resumable) appends
//! one JSON line per *completed* scenario — its config fingerprint plus the
//! fully rendered [`output::scenario_json`](super::output::scenario_json)
//! entry — to a `.partial` file, fsyncing after each append. A rerun after a
//! crash loads the journal, skips every scenario whose fingerprint is
//! present, and reuses the journaled render verbatim, so the reassembled
//! `BENCH_chunkflow.json` is byte-identical to an uninterrupted run.
//!
//! The journal is append-only, so the only damage a crash can inflict is a
//! torn *last* line: [`load`] drops it (that scenario just re-runs) but
//! refuses files with damage anywhere else — those are not journals.

use std::io::Write;
use std::path::Path;

use crate::util::crc::crc32;
use crate::util::json::Json;

use super::scenario::Scenario;

/// One completed scenario: its config fingerprint, its name (for logs), and
/// its rendered artifact entry.
#[derive(Clone, Debug)]
pub struct JournalEntry {
    pub fingerprint: String,
    pub name: String,
    pub scenario: Json,
}

/// Deterministic fingerprint of everything a scenario's result depends on
/// (sweeps are pure functions of this description — the engine's
/// determinism contract). Any config change — a different seed, an edited
/// candidate grid — changes the fingerprint, so a stale journal entry is
/// never reused for a different workload.
pub fn fingerprint(s: &Scenario) -> String {
    let candidates: Vec<Json> = s
        .candidates
        .iter()
        .map(|&(cs, k)| Json::Arr(vec![Json::num(cs as f64), Json::num(k as f64)]))
        .collect();
    let desc = Json::obj(vec![
        ("name", Json::str(s.name.clone())),
        ("model", Json::str(s.model.name.clone())),
        ("parallel", Json::str(s.parallel.paper_format())),
        ("dp", Json::num(s.parallel.dp as f64)),
        ("context_length", Json::num(s.context_length as f64)),
        ("distribution", Json::str(s.distribution.clone())),
        ("global_batch_size", Json::num(s.global_batch_size as f64)),
        ("iters", Json::num(s.iters as f64)),
        ("seed", Json::num(s.seed as f64)),
        ("candidates", Json::Arr(candidates)),
    ]);
    format!("{:08x}", crc32(desc.dump().as_bytes()))
}

fn parse_entry(line: &str) -> anyhow::Result<JournalEntry> {
    let j = Json::parse(line)?;
    let fingerprint = j.req_str("fingerprint")?.to_string();
    let name = j.req_str("name")?.to_string();
    let scenario = j
        .get("scenario")
        .cloned()
        .ok_or_else(|| anyhow::anyhow!("missing `scenario`"))?;
    Ok(JournalEntry { fingerprint, name, scenario })
}

/// Load a journal. A missing file is an empty journal; a torn last line is
/// dropped with a warning (its scenario re-runs); damage anywhere *before*
/// the last line is an error — append-only writes cannot produce it.
pub fn load(path: &Path) -> anyhow::Result<Vec<JournalEntry>> {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
        Err(e) => return Err(anyhow::Error::from(e).context(format!("reading {}", path.display()))),
    };
    let lines: Vec<&str> = text.split('\n').collect();
    let mut entries = Vec::new();
    for (i, raw) in lines.iter().enumerate() {
        let line = raw.trim();
        if line.is_empty() {
            continue;
        }
        match parse_entry(line) {
            Ok(entry) => entries.push(entry),
            Err(e) => {
                let is_last = lines[i + 1..].iter().all(|l| l.trim().is_empty());
                anyhow::ensure!(
                    is_last,
                    "corrupt sweep journal {} at line {}: {e:#}",
                    path.display(),
                    i + 1
                );
                crate::warn_!(
                    "dropping torn last line of sweep journal {} ({e:#}); \
                     its scenario will re-run",
                    path.display()
                );
            }
        }
    }
    Ok(entries)
}

/// Append one entry as a single JSON line and fsync, so a completed
/// scenario survives any later crash.
pub fn append(path: &Path, entry: &JournalEntry) -> anyhow::Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    let line = Json::obj(vec![
        ("fingerprint", Json::str(entry.fingerprint.clone())),
        ("name", Json::str(entry.name.clone())),
        ("scenario", entry.scenario.clone()),
    ])
    .dump();
    let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
    f.write_all(line.as_bytes())?;
    f.write_all(b"\n")?;
    f.flush()?;
    f.sync_all()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("chunkflow_journal_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn entry(fp: &str, name: &str) -> JournalEntry {
        JournalEntry {
            fingerprint: fp.to_string(),
            name: name.to_string(),
            scenario: Json::obj(vec![("name", Json::str(name.to_string()))]),
        }
    }

    #[test]
    fn roundtrips_appended_entries_in_order() {
        let path = tmp("roundtrip.journal");
        let _ = std::fs::remove_file(&path);
        assert!(load(&path).unwrap().is_empty(), "missing file = empty journal");
        append(&path, &entry("aaaa", "first")).unwrap();
        append(&path, &entry("bbbb", "second")).unwrap();
        let got = load(&path).unwrap();
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].fingerprint, "aaaa");
        assert_eq!(got[1].name, "second");
        assert_eq!(got[1].scenario.req_str("name").unwrap(), "second");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn torn_last_line_is_dropped_but_earlier_damage_errors() {
        let path = tmp("torn.journal");
        let _ = std::fs::remove_file(&path);
        append(&path, &entry("aaaa", "first")).unwrap();
        // Simulate a crash mid-append: a second line missing its tail.
        let mut text = std::fs::read_to_string(&path).unwrap();
        text.push_str("{\"fingerprint\": \"bbbb\", \"name\": \"sec");
        std::fs::write(&path, &text).unwrap();
        let got = load(&path).unwrap();
        assert_eq!(got.len(), 1, "torn tail dropped, intact prefix kept");
        assert_eq!(got[0].fingerprint, "aaaa");
        // Damage before the last line is not a torn append — refuse it.
        let good = Json::obj(vec![
            ("fingerprint", Json::str("cccc")),
            ("name", Json::str("third")),
            ("scenario", Json::obj(vec![])),
        ])
        .dump();
        std::fs::write(&path, format!("not json at all\n{good}\n")).unwrap();
        assert!(load(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn fingerprint_tracks_every_result_relevant_field() {
        let base = Scenario::smoke().remove(0);
        let fp = fingerprint(&base);
        assert_eq!(fp, fingerprint(&base.clone()), "fingerprint is deterministic");
        let mut seeded = base.clone();
        seeded.seed += 1;
        assert_ne!(fp, fingerprint(&seeded), "seed changes the fingerprint");
        let mut grid = base.clone();
        grid.candidates.push((123, 4));
        assert_ne!(fp, fingerprint(&grid), "candidate grid changes the fingerprint");
        let mut ctx = base;
        ctx.context_length *= 2;
        assert_ne!(fp, fingerprint(&ctx), "context length changes the fingerprint");
    }
}
