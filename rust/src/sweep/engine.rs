//! The parallel scenario-sweep engine.
//!
//! Unifies the previously siloed evaluation paths — `tune`'s grid search,
//! `sim::e2e`'s baseline-vs-ChunkFlow comparison and the `report` table
//! generators — behind one fan-out primitive built on
//! [`crate::util::pool::ThreadPool`].
//!
//! Determinism contract: every work unit derives all of its inputs from the
//! immutable [`Scenario`] description (each unit constructs its own
//! `BatchSampler` from the scenario seed), and [`SweepEngine::map`]
//! preserves input order, so a parallel sweep produces *bit-identical*
//! results — and therefore bit-identical `BENCH_*.json` bytes — to a serial
//! sweep under the same seed. A regression test asserts this.

use std::sync::Arc;

use crate::data::BatchSampler;
use crate::memory::{MemoryModel, GPU_CAPACITY};
use crate::sim::{simulate_baseline_iteration, simulate_chunkflow_iteration, CostModel};
use crate::util::pool::ThreadPool;

use super::scenario::Scenario;

/// How the engine fans work units out.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Parallelism {
    /// Evaluate in the calling thread, in order (reference behaviour).
    Serial,
    /// Fixed-size worker pool.
    Threads(usize),
    /// Pool sized to `std::thread::available_parallelism`.
    Auto,
}

/// Metrics for one evaluated execution model (baseline or one ChunkFlow
/// candidate) on one scenario.
#[derive(Clone, Debug, PartialEq)]
pub struct UnitMetrics {
    /// Mean iteration wall-clock seconds over the scenario's batches.
    pub iteration_seconds: f64,
    /// Mean pipeline bubble ratio.
    pub bubble_ratio: f64,
    /// Mean micro-batches (sequences or chunks) per iteration.
    pub num_microbatches: f64,
    /// Modelled per-GPU peak memory in bytes.
    pub peak_memory_bytes: u64,
}

/// One `(ChunkSize, K)` candidate's result.
#[derive(Clone, Debug, PartialEq)]
pub struct CandidateResult {
    pub chunk_size: u64,
    pub k: u64,
    pub metrics: UnitMetrics,
    /// Fits in [`GPU_CAPACITY`] under the memory model.
    pub feasible: bool,
}

/// Everything measured for one scenario.
#[derive(Clone, Debug)]
pub struct ScenarioResult {
    pub scenario: Scenario,
    pub baseline: UnitMetrics,
    pub candidates: Vec<CandidateResult>,
}

impl ScenarioResult {
    /// Fastest feasible candidate.
    pub fn best(&self) -> Option<&CandidateResult> {
        self.candidates
            .iter()
            .filter(|c| c.feasible)
            .min_by(|a, b| {
                a.metrics
                    .iteration_seconds
                    .partial_cmp(&b.metrics.iteration_seconds)
                    .unwrap()
            })
    }

    /// Baseline-vs-best-candidate speedup (the paper's headline metric).
    pub fn speedup(&self) -> Option<f64> {
        self.best()
            .map(|b| self.baseline.iteration_seconds / b.metrics.iteration_seconds)
    }
}

/// The engine itself: a fan-out policy.
#[derive(Clone, Copy, Debug)]
pub struct SweepEngine {
    pub parallelism: Parallelism,
}

impl Default for SweepEngine {
    fn default() -> Self {
        Self::auto()
    }
}

impl SweepEngine {
    pub fn auto() -> Self {
        Self { parallelism: Parallelism::Auto }
    }

    pub fn serial() -> Self {
        Self { parallelism: Parallelism::Serial }
    }

    pub fn with_threads(n: usize) -> Self {
        Self { parallelism: Parallelism::Threads(n.max(1)) }
    }

    /// Order-preserving map over independent work items — the fan-out
    /// primitive every sweep consumer (grid search, scenario sweeps, report
    /// generators) runs on.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        match self.parallelism {
            Parallelism::Serial => items.into_iter().map(f).collect(),
            Parallelism::Threads(n) => ThreadPool::new(n).map(items, f),
            Parallelism::Auto => ThreadPool::with_default_size().map(items, f),
        }
    }

    /// Evaluate every scenario: the baseline and every `(ChunkSize, K)`
    /// candidate become independent work units fanned out across the pool,
    /// then reassembled in registry order.
    pub fn run(&self, scenarios: &[Scenario]) -> anyhow::Result<Vec<ScenarioResult>> {
        // (scenario index, None = baseline | Some candidate) work units.
        let mut units: Vec<(usize, Option<(u64, u64)>)> = Vec::new();
        for (i, s) in scenarios.iter().enumerate() {
            units.push((i, None));
            for &cand in &s.candidates {
                units.push((i, Some(cand)));
            }
        }
        let shared: Arc<Vec<Scenario>> = Arc::new(scenarios.to_vec());
        let evaluated = self.map(units, move |(i, cand)| {
            let s = &shared[i];
            let r = match cand {
                None => evaluate_baseline(s),
                Some((cs, k)) => evaluate_candidate(s, cs, k).map(|c| c.metrics),
            };
            (i, cand, r)
        });

        // Reassemble preserving scenario order; `map` preserved unit order.
        let mut results: Vec<ScenarioResult> = Vec::with_capacity(scenarios.len());
        for (i, cand, r) in evaluated {
            let metrics = r.map_err(|e| {
                e.context(format!("scenario `{}` unit {cand:?}", scenarios[i].name))
            })?;
            match cand {
                None => results.push(ScenarioResult {
                    scenario: scenarios[i].clone(),
                    baseline: metrics,
                    candidates: Vec::new(),
                }),
                Some((cs, k)) => {
                    // The candidate's peak_memory_bytes IS the modelled
                    // ChunkFlow peak, so feasibility needs no recompute.
                    let feasible = metrics.peak_memory_bytes <= GPU_CAPACITY;
                    results
                        .last_mut()
                        .expect("baseline unit precedes its candidates")
                        .candidates
                        .push(CandidateResult { chunk_size: cs, k, metrics, feasible });
                }
            }
        }
        Ok(results)
    }
}

fn chunkflow_peak(s: &Scenario, chunk_size: u64, k: u64) -> u64 {
    MemoryModel::new(s.model.clone(), s.chunkflow_parallel())
        .chunkflow_peak(chunk_size, k, s.context_length)
}

/// Evaluate the Megatron-like baseline on one scenario.
fn evaluate_baseline(s: &Scenario) -> anyhow::Result<UnitMetrics> {
    let cost = CostModel::new(s.model.clone(), s.parallel.clone());
    let mm = MemoryModel::new(s.model.clone(), s.parallel.clone());
    let mut sampler = BatchSampler::new(
        s.dist()?,
        s.context_length,
        s.global_batch_size,
        s.seed,
    );
    let (mut secs, mut bubbles, mut items) = (0.0, 0.0, 0.0);
    let mut peak = 0u64;
    for _ in 0..s.iters {
        let batch = sampler.next_batch();
        let r = simulate_baseline_iteration(&batch, &cost)?;
        secs += r.iteration_seconds;
        bubbles += r.bubble_ratio;
        items += r.num_items as f64;
        // 1F1B in-flight set at stage 0: the longest sequence plus (PP-1)
        // typical short ones (same accounting as `derive_baseline_config`).
        let longest = batch.iter().map(|q| q.len).max().unwrap_or(0);
        let mut in_flight = vec![longest];
        in_flight.extend(std::iter::repeat(1024).take(s.parallel.pp as usize - 1));
        peak = peak.max(mm.baseline_pipeline_peak(&in_flight));
    }
    let n = s.iters as f64;
    Ok(UnitMetrics {
        iteration_seconds: secs / n,
        bubble_ratio: bubbles / n,
        num_microbatches: items / n,
        peak_memory_bytes: peak,
    })
}

/// Evaluate one ChunkFlow `(ChunkSize, K)` candidate on one scenario.
fn evaluate_candidate(s: &Scenario, chunk_size: u64, k: u64) -> anyhow::Result<CandidateResult> {
    let cost = CostModel::new(s.model.clone(), s.chunkflow_parallel());
    let peak = chunkflow_peak(s, chunk_size, k);
    let mut sampler = BatchSampler::new(
        s.dist()?,
        s.context_length,
        s.global_batch_size,
        s.seed,
    );
    let (mut secs, mut bubbles, mut items) = (0.0, 0.0, 0.0);
    for _ in 0..s.iters {
        let batch = sampler.next_batch();
        let r = simulate_chunkflow_iteration(&batch, &cost, chunk_size, k as usize)?;
        secs += r.iteration_seconds;
        bubbles += r.bubble_ratio;
        items += r.num_items as f64;
    }
    let n = s.iters as f64;
    Ok(CandidateResult {
        chunk_size,
        k,
        metrics: UnitMetrics {
            iteration_seconds: secs / n,
            bubble_ratio: bubbles / n,
            num_microbatches: items / n,
            peak_memory_bytes: peak,
        },
        feasible: peak <= GPU_CAPACITY,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_scenarios() -> Vec<Scenario> {
        Scenario::smoke()
    }

    #[test]
    fn run_evaluates_all_units() {
        let scenarios = tiny_scenarios();
        let results = SweepEngine::serial().run(&scenarios).unwrap();
        assert_eq!(results.len(), scenarios.len());
        for (s, r) in scenarios.iter().zip(&results) {
            assert_eq!(r.candidates.len(), s.candidates.len());
            assert!(r.baseline.iteration_seconds > 0.0);
            assert!(r.best().is_some(), "{}: some candidate must be feasible", s.name);
        }
    }

    #[test]
    fn chunkflow_wins_on_longtail_scenarios() {
        let scenarios = tiny_scenarios();
        let results = SweepEngine::auto().run(&scenarios).unwrap();
        for r in &results {
            if r.scenario.distribution.starts_with("uniform") {
                continue; // the baseline's best case; no win guaranteed
            }
            let speedup = r.speedup().unwrap();
            assert!(speedup > 1.0, "{}: speedup {speedup:.2}", r.scenario.name);
        }
    }

    #[test]
    fn parallel_matches_serial_exactly() {
        let scenarios = tiny_scenarios();
        let serial = SweepEngine::serial().run(&scenarios).unwrap();
        let parallel = SweepEngine::with_threads(8).run(&scenarios).unwrap();
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.baseline, b.baseline, "{}", a.scenario.name);
            assert_eq!(a.candidates, b.candidates, "{}", a.scenario.name);
        }
    }

    #[test]
    fn map_preserves_order_under_all_policies() {
        let input: Vec<u64> = (0..64).collect();
        for engine in [
            SweepEngine::serial(),
            SweepEngine::with_threads(4),
            SweepEngine::auto(),
        ] {
            let out = engine.map(input.clone(), |x| x * 3);
            assert_eq!(out, input.iter().map(|x| x * 3).collect::<Vec<_>>());
        }
    }
}
