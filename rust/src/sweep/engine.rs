//! The parallel scenario-sweep engine.
//!
//! Unifies the previously siloed evaluation paths — `tune`'s grid search,
//! `sim::e2e`'s baseline-vs-ChunkFlow comparison and the `report` table
//! generators — behind one fan-out primitive built on
//! [`crate::util::pool::ThreadPool`].
//!
//! Determinism contract: each scenario's batches are sampled exactly once
//! (serially, from the scenario seed) before the fan-out; every work unit is
//! a pure function of the immutable [`Scenario`] description plus those
//! shared batches; [`SweepEngine::map`] preserves input order; and the
//! reduction accumulates per-batch results in batch order. So a parallel
//! sweep produces *bit-identical* results — and therefore bit-identical
//! `BENCH_*.json` bytes — to a serial sweep under the same seed, and both
//! are bit-identical to the pre-memoization per-candidate evaluation (a
//! regression test asserts each equality).
//!
//! Fan-out granularity is (scenario × batch × unit), where a unit is either
//! the baseline or one ChunkSize *group* of candidates: Algorithm 1 runs
//! once per (batch, ChunkSize) and the resulting `ChunkSet` — plus, for
//! dp > 1 scenarios, its K-invariant rank sharding ([`dp_rank_sets`]) — is
//! shared across all of that group's K values via
//! [`simulate_chunkset_sharded`]; neither chunk construction nor the DP
//! assignment depends on K.

use std::sync::Arc;

use crate::chunk::construct_chunks;
use crate::data::{BatchSampler, Sequence};
use crate::memory::{MemoryModel, GPU_CAPACITY};
use crate::sim::dp::{assign_chunks, assign_sequences, DpPolicy};
use crate::sim::{
    dp_rank_sets, search_elastic, simulate_baseline_iteration, simulate_chunkset_sharded,
    CostModel, IterationResult,
};
use crate::util::pool::ThreadPool;

use super::scenario::Scenario;

/// How the engine fans work units out.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Parallelism {
    /// Evaluate in the calling thread, in order (reference behaviour).
    Serial,
    /// Fixed-size worker pool.
    Threads(usize),
    /// Pool sized to `std::thread::available_parallelism`.
    Auto,
}

/// Metrics for one evaluated execution model (baseline or one ChunkFlow
/// candidate) on one scenario.
#[derive(Clone, Debug, PartialEq)]
pub struct UnitMetrics {
    /// Mean iteration wall-clock seconds over the scenario's batches.
    pub iteration_seconds: f64,
    /// Mean pipeline bubble ratio.
    pub bubble_ratio: f64,
    /// Mean micro-batches (sequences or chunks) per iteration.
    pub num_microbatches: f64,
    /// Modelled per-GPU peak memory in bytes.
    pub peak_memory_bytes: u64,
}

/// One `(ChunkSize, K)` candidate's result.
#[derive(Clone, Debug, PartialEq)]
pub struct CandidateResult {
    pub chunk_size: u64,
    pub k: u64,
    pub metrics: UnitMetrics,
    /// Fits in [`GPU_CAPACITY`] under the memory model.
    pub feasible: bool,
}

/// Additive per-scenario DP load-imbalance metric, emitted only for dp > 1
/// scenarios (existing dp = 1 artifacts stay byte-identical): max/mean
/// token-load ratios of the naive sequence round-robin vs. the
/// chunk-balanced assignment at the scenario's first candidate ChunkSize,
/// averaged over the scenario's batches. `benchdiff` never compares it.
#[derive(Clone, Debug, PartialEq)]
pub struct DpImbalance {
    pub dp: u64,
    pub round_robin: f64,
    pub chunk_balanced: f64,
}

/// Additive per-scenario sequence-parallel sharding metric, emitted only
/// for sp > 1 scenarios (existing sp = 1 artifacts stay byte-identical):
/// how many chunks actually shard under the per-chunk rule
/// ([`crate::config::ParallelConfig::sp_shards`] — dependent chunks shard,
/// standalone chunks stay whole) at the scenario's first candidate
/// ChunkSize, plus the modeled per-iteration ring-KV exchange time, both
/// averaged over the scenario's batches. `benchdiff` never compares it.
#[derive(Clone, Debug, PartialEq)]
pub struct SpSharding {
    pub sp: u64,
    /// Mean chunks per iteration that shard (dependent, sp_shards > 1).
    pub sharded_chunks: f64,
    /// Mean chunks per iteration in total.
    pub total_chunks: f64,
    /// Mean per-iteration seconds spent in the forward ring-KV exchange
    /// across all sharded chunks ([`CostModel::sp_ring_seconds`]).
    pub ring_comm_seconds: f64,
}

/// Additive per-scenario elastic-pipeline block, emitted only when the
/// uneven-partition + schedule-policy search ([`search_elastic`]) strictly
/// beats the equal partition under the default state-aware 1F1B policy on a
/// pp > 1 scenario (both simulated critical path and bubble ratio — with
/// constant total busy time the two move together). Equal-partition wins
/// emit nothing, so every pre-elastic scenario's artifact bytes are
/// unchanged. `benchdiff`'s drift gate never compares it (it only diffs
/// baseline/best/speedup); the separate bubble-drift report surfaces it.
#[derive(Clone, Debug, PartialEq)]
pub struct ElasticPipeline {
    pub pp: u64,
    /// Chosen per-stage layer counts in `--partition` form, e.g. "9,7,7,5".
    pub partition: String,
    /// Chosen schedule policy name ([`crate::pipeline::PolicyKind`]).
    pub policy: String,
    /// Simulated bubble of the equal partition + default policy baseline.
    pub predicted_bubble_equal: f64,
    /// Simulated bubble of the chosen (partition, policy) — strictly lower.
    pub predicted_bubble_elastic: f64,
    /// Executor-probe measurement (attached only under `--measure-exec`;
    /// wall-clock, so never part of the deterministic default artifact).
    pub measured: Option<super::probe::MeasuredElastic>,
}

/// Everything measured for one scenario.
#[derive(Clone, Debug)]
pub struct ScenarioResult {
    pub scenario: Scenario,
    pub baseline: UnitMetrics,
    pub candidates: Vec<CandidateResult>,
    /// Optional executor probe (`probe::attach_measured_exec`, the sweep's
    /// `--measure-exec` pass). None in the default deterministic artifact.
    pub measured_exec: Option<super::probe::MeasuredExec>,
    /// DP load-imbalance metric; Some only when the scenario's strategy has
    /// dp > 1 (additive — absent entries keep old artifact bytes).
    pub dp_imbalance: Option<DpImbalance>,
    /// SP sharding metric; Some only when the scenario's strategy has
    /// sp > 1 (additive — absent entries keep old artifact bytes).
    pub sp_sharding: Option<SpSharding>,
    /// Elastic-pipeline block; Some only when pp > 1 AND the partition/
    /// policy search strictly wins (additive — equal-partition defaults
    /// keep old artifact bytes).
    pub elastic_pipeline: Option<ElasticPipeline>,
}

impl ScenarioResult {
    /// Fastest feasible candidate. `total_cmp` keeps the ranking NaN-safe:
    /// a candidate with a NaN time loses to every finite one instead of
    /// panicking the sweep (and corrupting the committed artifact's `best`).
    pub fn best(&self) -> Option<&CandidateResult> {
        self.candidates
            .iter()
            .filter(|c| c.feasible)
            .min_by(|a, b| {
                a.metrics
                    .iteration_seconds
                    .total_cmp(&b.metrics.iteration_seconds)
            })
    }

    /// Baseline-vs-best-candidate speedup (the paper's headline metric).
    pub fn speedup(&self) -> Option<f64> {
        self.best()
            .map(|b| self.baseline.iteration_seconds / b.metrics.iteration_seconds)
    }
}

/// The engine itself: a fan-out policy.
#[derive(Clone, Copy, Debug)]
pub struct SweepEngine {
    pub parallelism: Parallelism,
}

impl Default for SweepEngine {
    fn default() -> Self {
        Self::auto()
    }
}

impl SweepEngine {
    pub fn auto() -> Self {
        Self { parallelism: Parallelism::Auto }
    }

    pub fn serial() -> Self {
        Self { parallelism: Parallelism::Serial }
    }

    pub fn with_threads(n: usize) -> Self {
        Self { parallelism: Parallelism::Threads(n.max(1)) }
    }

    /// Order-preserving map over independent work items — the fan-out
    /// primitive every sweep consumer (grid search, scenario sweeps, report
    /// generators) runs on.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        match self.parallelism {
            Parallelism::Serial => items.into_iter().map(f).collect(),
            Parallelism::Threads(n) => ThreadPool::new(n).map(items, f),
            Parallelism::Auto => ThreadPool::with_default_size().map(items, f),
        }
    }

    /// Evaluate every scenario, fanning out at (scenario × batch × unit)
    /// granularity — a unit being the baseline or one ChunkSize group of
    /// candidates — and reassembling in registry order.
    pub fn run(&self, scenarios: &[Scenario]) -> anyhow::Result<Vec<ScenarioResult>> {
        // Sample every scenario's batches once, serially and up front: work
        // units share them instead of each re-deriving the identical
        // sampler stream from the scenario seed.
        let mut batches: Vec<Vec<Vec<Sequence>>> = Vec::with_capacity(scenarios.len());
        for s in scenarios {
            let mut sampler =
                BatchSampler::new(s.dist()?, s.context_length, s.global_batch_size, s.seed);
            batches.push((0..s.iters).map(|_| sampler.next_batch()).collect());
        }

        // Group each scenario's candidates by ChunkSize: Algorithm 1 runs
        // once per (batch, ChunkSize) and its ChunkSet is shared across the
        // group's K values. slots[i][j] locates candidate j as
        // (group index, position within the group's K list).
        let mut groups: Vec<Vec<(u64, Vec<u64>)>> = Vec::with_capacity(scenarios.len());
        let mut slots: Vec<Vec<(usize, usize)>> = Vec::with_capacity(scenarios.len());
        for s in scenarios {
            let mut g: Vec<(u64, Vec<u64>)> = Vec::new();
            let mut slot = Vec::with_capacity(s.candidates.len());
            for &(cs, k) in &s.candidates {
                let gi = match g.iter().position(|(c, _)| *c == cs) {
                    Some(gi) => gi,
                    None => {
                        g.push((cs, Vec::new()));
                        g.len() - 1
                    }
                };
                g[gi].1.push(k);
                slot.push((gi, g[gi].1.len() - 1));
            }
            groups.push(g);
            slots.push(slot);
        }

        let mut units: Vec<(usize, usize, UnitKind)> = Vec::new();
        for (i, s) in scenarios.iter().enumerate() {
            for b in 0..s.iters {
                units.push((i, b, UnitKind::Baseline));
                for gi in 0..groups[i].len() {
                    units.push((i, b, UnitKind::Group(gi)));
                }
            }
        }
        let shared = Arc::new((scenarios.to_vec(), batches, groups.clone()));
        let shared_for_units = Arc::clone(&shared);
        let evaluated = self.map(units, move |(i, b, kind)| {
            let (scenarios, batches, groups) = &*shared_for_units;
            let s = &scenarios[i];
            let batch = &batches[i][b];
            let out = match kind {
                UnitKind::Baseline => evaluate_baseline_batch(s, batch),
                UnitKind::Group(gi) => {
                    let (cs, ks) = &groups[i][gi];
                    evaluate_group_batch(s, batch, *cs, ks)
                }
            };
            (i, kind, out)
        });

        // Reduce in unit order (batch index ascending within each scenario),
        // so float accumulation matches the pre-memoization per-candidate
        // loop exactly.
        let mut base_acc: Vec<BatchAcc> = scenarios.iter().map(|_| BatchAcc::default()).collect();
        let mut base_peak: Vec<u64> = vec![0; scenarios.len()];
        let mut cand_acc: Vec<Vec<Vec<BatchAcc>>> = groups
            .iter()
            .map(|g| g.iter().map(|(_, ks)| vec![BatchAcc::default(); ks.len()]).collect())
            .collect();
        for (i, kind, out) in evaluated {
            let out = out.map_err(|e| {
                let unit = match kind {
                    UnitKind::Baseline => "baseline".to_string(),
                    UnitKind::Group(gi) => format!(
                        "ChunkSize {} (Ks {:?})",
                        groups[i][gi].0, groups[i][gi].1
                    ),
                };
                e.context(format!("scenario `{}` unit {unit}", scenarios[i].name))
            })?;
            match (kind, out) {
                (UnitKind::Baseline, UnitOut::Baseline(r, peak)) => {
                    base_acc[i].add(&r);
                    base_peak[i] = base_peak[i].max(peak);
                }
                (UnitKind::Group(gi), UnitOut::Group(rs)) => {
                    for (pos, r) in rs.iter().enumerate() {
                        cand_acc[i][gi][pos].add(r);
                    }
                }
                _ => unreachable!("unit kind and output variant always agree"),
            }
        }

        // Assemble per scenario in registry order; candidate peaks come from
        // the (batch-independent) memory model.
        let batches = &shared.1;
        let mut results: Vec<ScenarioResult> = Vec::with_capacity(scenarios.len());
        for (i, s) in scenarios.iter().enumerate() {
            let n = s.iters as f64;
            let baseline = base_acc[i].metrics(n, base_peak[i]);
            let mut candidates = Vec::with_capacity(s.candidates.len());
            for (j, &(cs, k)) in s.candidates.iter().enumerate() {
                let (gi, pos) = slots[i][j];
                let peak = chunkflow_peak(s, cs, k);
                candidates.push(CandidateResult {
                    chunk_size: cs,
                    k,
                    metrics: cand_acc[i][gi][pos].metrics(n, peak),
                    feasible: peak <= GPU_CAPACITY,
                });
            }
            results.push(ScenarioResult {
                scenario: s.clone(),
                baseline,
                candidates,
                measured_exec: None,
                dp_imbalance: dp_imbalance_for(s, &batches[i])?,
                sp_sharding: sp_sharding_for(s, &batches[i]),
                elastic_pipeline: elastic_pipeline_for(s, &batches[i])?,
            });
        }
        Ok(results)
    }

    /// Crash-resumable sweep: evaluate scenarios one at a time, appending
    /// each finished scenario's rendered artifact entry to the journal at
    /// `journal_path` (fsynced per append). On rerun, scenarios whose
    /// config fingerprint already appears in the journal are skipped and
    /// their journaled render reused verbatim. Returns rendered entries in
    /// scenario order, ready for [`super::output::doc_from_scenarios`] —
    /// the assembled document is byte-identical to an uninterrupted
    /// [`super::output::to_json`] over [`SweepEngine::run`], because each
    /// scenario's batches come from its own seeded sampler (no cross-
    /// scenario state to lose).
    pub fn run_resumable(
        &self,
        scenarios: &[Scenario],
        journal_path: &std::path::Path,
    ) -> anyhow::Result<Vec<crate::util::json::Json>> {
        use super::journal;
        let done = journal::load(journal_path)?;
        let mut out = Vec::with_capacity(scenarios.len());
        let mut skipped = 0usize;
        for s in scenarios {
            let fp = journal::fingerprint(s);
            if let Some(e) = done.iter().find(|e| e.fingerprint == fp) {
                skipped += 1;
                out.push(e.scenario.clone());
                continue;
            }
            let results = self.run(std::slice::from_ref(s))?;
            let rendered = super::output::scenario_json(&results[0]);
            journal::append(
                journal_path,
                &journal::JournalEntry {
                    fingerprint: fp,
                    name: s.name.clone(),
                    scenario: rendered.clone(),
                },
            )?;
            // Deterministic kill site for the resumability tests and the CI
            // fault matrix: dies *after* the journal append — the moment an
            // external kill would be most tempted to lose work.
            crate::util::fault::maybe_abort(crate::util::fault::SWEEP_KILL);
            out.push(rendered);
        }
        if skipped > 0 {
            crate::info!(
                "sweep journal {}: reused {skipped}/{} completed scenario(s)",
                journal_path.display(),
                scenarios.len()
            );
        }
        Ok(out)
    }
}

/// The additive `dp_imbalance` metric for one scenario (None when dp <= 1):
/// deterministic — a pure function of the scenario's sampled batches.
fn dp_imbalance_for(
    s: &Scenario,
    batches: &[Vec<Sequence>],
) -> anyhow::Result<Option<DpImbalance>> {
    let dp = s.parallel.dp as usize;
    if dp <= 1 || batches.is_empty() {
        return Ok(None);
    }
    let chunk_size = s.candidates.first().map(|&(cs, _)| cs).unwrap_or(8 * 1024);
    let (mut rr, mut cb) = (0.0f64, 0.0f64);
    for batch in batches {
        rr += assign_sequences(batch, dp, DpPolicy::RoundRobin)?.imbalance();
        cb += assign_chunks(&construct_chunks(batch, chunk_size), dp, DpPolicy::ChunkBalanced)
            .imbalance();
    }
    let n = batches.len() as f64;
    Ok(Some(DpImbalance {
        dp: s.parallel.dp,
        round_robin: rr / n,
        chunk_balanced: cb / n,
    }))
}

/// The additive `sp_sharding` metric for one scenario (None when sp <= 1):
/// deterministic — a pure function of the scenario's sampled batches and
/// its first candidate ChunkSize (the sharding rule is K-invariant, like
/// chunk construction itself).
fn sp_sharding_for(s: &Scenario, batches: &[Vec<Sequence>]) -> Option<SpSharding> {
    let parallel = s.chunkflow_parallel();
    if parallel.sp <= 1 || batches.is_empty() {
        return None;
    }
    let chunk_size = s.candidates.first().map(|&(cs, _)| cs).unwrap_or(8 * 1024);
    let cost = CostModel::new(s.model.clone(), parallel.clone());
    let (mut sharded, mut total, mut comm) = (0.0f64, 0.0f64, 0.0f64);
    for batch in batches {
        let set = construct_chunks(batch, chunk_size);
        for c in &set.chunks {
            total += 1.0;
            let tokens = c.total_len();
            let shards = parallel.sp_shards(c.is_dependent(), tokens);
            if shards > 1 {
                sharded += 1.0;
                comm += cost.sp_ring_seconds(tokens, shards);
            }
        }
    }
    let n = batches.len() as f64;
    Some(SpSharding {
        sp: parallel.sp,
        sharded_chunks: sharded / n,
        total_chunks: total / n,
        ring_comm_seconds: comm / n,
    })
}

/// The additive `elastic_pipeline` block for one scenario (None when
/// pp <= 1 or when the equal partition under the default policy is already
/// optimal): deterministic — a pure function of the scenario's sampled
/// batches, evaluated at the scenario's first candidate (ChunkSize, K) on
/// batch 0, the same workload shape the `--measure-exec` probe mirrors.
fn elastic_pipeline_for(
    s: &Scenario,
    batches: &[Vec<Sequence>],
) -> anyhow::Result<Option<ElasticPipeline>> {
    let parallel = s.chunkflow_parallel();
    if parallel.pp <= 1 || batches.is_empty() {
        return Ok(None);
    }
    let (chunk_size, k) = s.candidates.first().copied().unwrap_or((8 * 1024, 1));
    let cost = CostModel::new(s.model.clone(), parallel.clone());
    let set = construct_chunks(&batches[0], chunk_size);
    let choice = search_elastic(&cost, &set, k as usize)?;
    Ok(choice.map(|c| ElasticPipeline {
        pp: parallel.pp,
        partition: c.partition_string(),
        policy: c.policy.name().to_string(),
        predicted_bubble_equal: c.bubble_equal,
        predicted_bubble_elastic: c.bubble_elastic,
        measured: None,
    }))
}

/// What one fan-out unit evaluates on one (scenario, batch) pair.
#[derive(Clone, Copy, Debug)]
enum UnitKind {
    Baseline,
    /// Index into the scenario's ChunkSize groups.
    Group(usize),
}

/// A unit's result: one baseline iteration (plus its modelled in-flight
/// peak), or one iteration per K of a ChunkSize group.
enum UnitOut {
    Baseline(IterationResult, u64),
    Group(Vec<IterationResult>),
}

/// Per-batch accumulator whose addition order mirrors the old serial loop.
#[derive(Clone, Copy, Debug, Default)]
struct BatchAcc {
    secs: f64,
    bubbles: f64,
    items: f64,
}

impl BatchAcc {
    fn add(&mut self, r: &IterationResult) {
        self.secs += r.iteration_seconds;
        self.bubbles += r.bubble_ratio;
        self.items += r.num_items as f64;
    }

    fn metrics(&self, n: f64, peak: u64) -> UnitMetrics {
        UnitMetrics {
            iteration_seconds: self.secs / n,
            bubble_ratio: self.bubbles / n,
            num_microbatches: self.items / n,
            peak_memory_bytes: peak,
        }
    }
}

fn chunkflow_peak(s: &Scenario, chunk_size: u64, k: u64) -> u64 {
    // sp-aware: shards long-chunk activations and held KV across the ring
    // (`chunkflow_peak_sp` delegates to `chunkflow_peak` verbatim at
    // sp = 1, so sp-free scenario artifacts keep their exact bytes).
    MemoryModel::new(s.model.clone(), s.chunkflow_parallel())
        .chunkflow_peak_sp(chunk_size, k, s.context_length)
}

/// One baseline work unit: simulate one batch and report its in-flight peak.
fn evaluate_baseline_batch(s: &Scenario, batch: &[Sequence]) -> anyhow::Result<UnitOut> {
    let cost = CostModel::new(s.model.clone(), s.parallel.clone());
    let mm = MemoryModel::new(s.model.clone(), s.parallel.clone());
    let r = simulate_baseline_iteration(batch, &cost)?;
    // 1F1B in-flight set at stage 0: the longest sequence plus (PP-1)
    // typical short ones (same accounting as `derive_baseline_config`).
    let longest = batch.iter().map(|q| q.len).max().unwrap_or(0);
    let mut in_flight = vec![longest];
    in_flight.extend(std::iter::repeat(1024).take(s.parallel.pp as usize - 1));
    let peak = mm.baseline_pipeline_peak(&in_flight);
    Ok(UnitOut::Baseline(r, peak))
}

/// One ChunkFlow work unit: Algorithm 1 once for (batch, ChunkSize), then
/// one state-aware simulation per K on the shared chunk set. The dp rank
/// sharding is K-invariant too, so it is computed once per unit and shared
/// the same way (empty for dp = 1 scenarios).
fn evaluate_group_batch(
    s: &Scenario,
    batch: &[Sequence],
    chunk_size: u64,
    ks: &[u64],
) -> anyhow::Result<UnitOut> {
    let cost = CostModel::new(s.model.clone(), s.chunkflow_parallel());
    let set = construct_chunks(batch, chunk_size);
    let shards = dp_rank_sets(&set, &cost);
    let mut out = Vec::with_capacity(ks.len());
    for &k in ks {
        out.push(simulate_chunkset_sharded(&set, &shards, &cost, k as usize)?);
    }
    Ok(UnitOut::Group(out))
}

/// Pre-memoization reference: evaluate the baseline with a per-unit sampler
/// stream — the shape of the code before the per-batch fan-out. Kept under
/// `#[cfg(test)]` purely as the bit-identity oracle.
#[cfg(test)]
fn evaluate_baseline_reference(s: &Scenario) -> anyhow::Result<UnitMetrics> {
    let cost = CostModel::new(s.model.clone(), s.parallel.clone());
    let mm = MemoryModel::new(s.model.clone(), s.parallel.clone());
    let mut sampler = BatchSampler::new(
        s.dist()?,
        s.context_length,
        s.global_batch_size,
        s.seed,
    );
    let (mut secs, mut bubbles, mut items) = (0.0, 0.0, 0.0);
    let mut peak = 0u64;
    for _ in 0..s.iters {
        let batch = sampler.next_batch();
        let r = simulate_baseline_iteration(&batch, &cost)?;
        secs += r.iteration_seconds;
        bubbles += r.bubble_ratio;
        items += r.num_items as f64;
        let longest = batch.iter().map(|q| q.len).max().unwrap_or(0);
        let mut in_flight = vec![longest];
        in_flight.extend(std::iter::repeat(1024).take(s.parallel.pp as usize - 1));
        peak = peak.max(mm.baseline_pipeline_peak(&in_flight));
    }
    let n = s.iters as f64;
    Ok(UnitMetrics {
        iteration_seconds: secs / n,
        bubble_ratio: bubbles / n,
        num_microbatches: items / n,
        peak_memory_bytes: peak,
    })
}

/// Pre-memoization reference: one ChunkFlow candidate, re-sampling batches
/// and re-running Algorithm 1 per candidate. Bit-identity oracle for tests.
#[cfg(test)]
fn evaluate_candidate_reference(
    s: &Scenario,
    chunk_size: u64,
    k: u64,
) -> anyhow::Result<CandidateResult> {
    let cost = CostModel::new(s.model.clone(), s.chunkflow_parallel());
    let peak = chunkflow_peak(s, chunk_size, k);
    let mut sampler = BatchSampler::new(
        s.dist()?,
        s.context_length,
        s.global_batch_size,
        s.seed,
    );
    let (mut secs, mut bubbles, mut items) = (0.0, 0.0, 0.0);
    for _ in 0..s.iters {
        let batch = sampler.next_batch();
        let r =
            crate::sim::simulate_chunkflow_iteration(&batch, &cost, chunk_size, k as usize)?;
        secs += r.iteration_seconds;
        bubbles += r.bubble_ratio;
        items += r.num_items as f64;
    }
    let n = s.iters as f64;
    Ok(CandidateResult {
        chunk_size,
        k,
        metrics: UnitMetrics {
            iteration_seconds: secs / n,
            bubble_ratio: bubbles / n,
            num_microbatches: items / n,
            peak_memory_bytes: peak,
        },
        feasible: peak <= GPU_CAPACITY,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_scenarios() -> Vec<Scenario> {
        Scenario::smoke()
    }

    #[test]
    fn best_survives_nan_candidate_times() {
        // Regression: `best()` used `partial_cmp(..).unwrap()`, which panics
        // mid-sweep the moment a degenerate candidate yields a NaN time and
        // corrupts the committed artifact's `best`. With `total_cmp` the NaN
        // candidate simply loses to every finite one.
        let candidate = |cs: u64, secs: f64, feasible: bool| CandidateResult {
            chunk_size: cs,
            k: 1,
            metrics: UnitMetrics {
                iteration_seconds: secs,
                bubble_ratio: 0.1,
                num_microbatches: 4.0,
                peak_memory_bytes: 1,
            },
            feasible,
        };
        let result = ScenarioResult {
            scenario: Scenario::smoke().remove(0),
            baseline: UnitMetrics {
                iteration_seconds: 10.0,
                bubble_ratio: 0.5,
                num_microbatches: 4.0,
                peak_memory_bytes: 1,
            },
            candidates: vec![
                candidate(1024, f64::NAN, true),
                candidate(2048, 2.0, true),
                candidate(4096, 1.0, false), // fastest but infeasible
            ],
            measured_exec: None,
            dp_imbalance: None,
            sp_sharding: None,
            elastic_pipeline: None,
        };
        let best = result.best().expect("a finite feasible candidate exists");
        assert_eq!(best.chunk_size, 2048, "NaN must lose; infeasible must be skipped");
        assert_eq!(result.speedup(), Some(5.0));
    }

    #[test]
    fn run_evaluates_all_units() {
        let scenarios = tiny_scenarios();
        let results = SweepEngine::serial().run(&scenarios).unwrap();
        assert_eq!(results.len(), scenarios.len());
        for (s, r) in scenarios.iter().zip(&results) {
            assert_eq!(r.candidates.len(), s.candidates.len());
            assert!(r.baseline.iteration_seconds > 0.0);
            assert!(r.best().is_some(), "{}: some candidate must be feasible", s.name);
        }
    }

    #[test]
    fn chunkflow_wins_on_longtail_scenarios() {
        let scenarios = tiny_scenarios();
        let results = SweepEngine::auto().run(&scenarios).unwrap();
        for r in &results {
            if r.scenario.distribution.starts_with("uniform") {
                continue; // the baseline's best case; no win guaranteed
            }
            let speedup = r.speedup().unwrap();
            assert!(speedup > 1.0, "{}: speedup {speedup:.2}", r.scenario.name);
        }
    }

    #[test]
    fn memoized_run_matches_per_candidate_reference_bit_identically() {
        // The memoized per-batch fan-out must reproduce the old
        // one-sampler-per-unit evaluation exactly: same batches (sampled
        // once instead of once per unit), same float accumulation order.
        let scenarios = tiny_scenarios();
        let results = SweepEngine::serial().run(&scenarios).unwrap();
        for (s, r) in scenarios.iter().zip(&results) {
            let base = evaluate_baseline_reference(s).unwrap();
            assert_eq!(r.baseline, base, "{}: baseline drifted", s.name);
            for (c, &(cs, k)) in r.candidates.iter().zip(&s.candidates) {
                let reference = evaluate_candidate_reference(s, cs, k).unwrap();
                assert_eq!(c, &reference, "{}: candidate ({cs}, {k}) drifted", s.name);
            }
        }
    }

    #[test]
    fn candidates_sharing_a_chunk_size_group_keep_their_order() {
        // Two candidates with equal ChunkSize share one work unit; their
        // results must still come back in candidate-list order.
        let scenarios = tiny_scenarios();
        let results = SweepEngine::with_threads(4).run(&scenarios).unwrap();
        for (s, r) in scenarios.iter().zip(&results) {
            let got: Vec<(u64, u64)> =
                r.candidates.iter().map(|c| (c.chunk_size, c.k)).collect();
            assert_eq!(got, s.candidates, "{}", s.name);
        }
    }

    #[test]
    fn parallel_matches_serial_exactly() {
        let scenarios = tiny_scenarios();
        let serial = SweepEngine::serial().run(&scenarios).unwrap();
        let parallel = SweepEngine::with_threads(8).run(&scenarios).unwrap();
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.baseline, b.baseline, "{}", a.scenario.name);
            assert_eq!(a.candidates, b.candidates, "{}", a.scenario.name);
        }
    }

    #[test]
    fn dp_scenarios_carry_imbalance_metric() {
        let scenarios = tiny_scenarios();
        let results = SweepEngine::serial().run(&scenarios).unwrap();
        for r in &results {
            if r.scenario.parallel.dp > 1 {
                let di = r
                    .dp_imbalance
                    .as_ref()
                    .unwrap_or_else(|| panic!("{}: missing dp_imbalance", r.scenario.name));
                assert_eq!(di.dp, r.scenario.parallel.dp);
                assert!(di.round_robin >= 1.0 && di.chunk_balanced >= 1.0);
                assert!(
                    di.chunk_balanced <= di.round_robin + 1e-9,
                    "{}: chunk-balanced {} vs round-robin {}",
                    r.scenario.name,
                    di.chunk_balanced,
                    di.round_robin
                );
            } else {
                assert!(
                    r.dp_imbalance.is_none(),
                    "{}: dp=1 scenarios must stay metric-free (artifact bytes)",
                    r.scenario.name
                );
            }
        }
        assert!(
            results.iter().any(|r| r.dp_imbalance.is_some()),
            "smoke set must exercise a dp scenario"
        );
    }

    #[test]
    fn dp_scenario_results_are_deterministic_across_engines() {
        let scenarios: Vec<Scenario> = tiny_scenarios()
            .into_iter()
            .filter(|s| s.parallel.dp > 1)
            .collect();
        assert!(!scenarios.is_empty());
        let serial = SweepEngine::serial().run(&scenarios).unwrap();
        let parallel = SweepEngine::with_threads(4).run(&scenarios).unwrap();
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.baseline, b.baseline, "{}", a.scenario.name);
            assert_eq!(a.candidates, b.candidates, "{}", a.scenario.name);
            assert_eq!(a.dp_imbalance, b.dp_imbalance, "{}", a.scenario.name);
        }
    }

    #[test]
    fn sp_scenarios_carry_sharding_metric() {
        let scenarios = tiny_scenarios();
        let results = SweepEngine::serial().run(&scenarios).unwrap();
        for r in &results {
            if r.scenario.parallel.sp > 1 {
                let sh = r
                    .sp_sharding
                    .as_ref()
                    .unwrap_or_else(|| panic!("{}: missing sp_sharding", r.scenario.name));
                assert_eq!(sh.sp, r.scenario.parallel.sp);
                assert!(sh.total_chunks > 0.0);
                assert!(
                    sh.sharded_chunks > 0.0 && sh.sharded_chunks <= sh.total_chunks,
                    "{}: {} of {} chunks shard",
                    r.scenario.name,
                    sh.sharded_chunks,
                    sh.total_chunks
                );
                assert!(sh.ring_comm_seconds > 0.0);
            } else {
                assert!(
                    r.sp_sharding.is_none(),
                    "{}: sp=1 scenarios must stay metric-free (artifact bytes)",
                    r.scenario.name
                );
            }
        }
        assert!(
            results.iter().any(|r| r.sp_sharding.is_some()),
            "smoke set must exercise an sp scenario"
        );
    }

    #[test]
    fn sp_scenario_results_are_deterministic_across_engines() {
        let scenarios: Vec<Scenario> = tiny_scenarios()
            .into_iter()
            .filter(|s| s.parallel.sp > 1)
            .collect();
        assert!(!scenarios.is_empty());
        let serial = SweepEngine::serial().run(&scenarios).unwrap();
        let parallel = SweepEngine::with_threads(4).run(&scenarios).unwrap();
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.baseline, b.baseline, "{}", a.scenario.name);
            assert_eq!(a.candidates, b.candidates, "{}", a.scenario.name);
            assert_eq!(a.sp_sharding, b.sp_sharding, "{}", a.scenario.name);
        }
    }

    #[test]
    fn elastic_blocks_only_on_pp_scenarios_and_only_on_strict_wins() {
        let scenarios = tiny_scenarios();
        let results = SweepEngine::serial().run(&scenarios).unwrap();
        for r in &results {
            match &r.elastic_pipeline {
                Some(ep) => {
                    assert!(
                        r.scenario.parallel.pp > 1,
                        "{}: elastic block on a pp=1 scenario",
                        r.scenario.name
                    );
                    assert_eq!(ep.pp, r.scenario.parallel.pp);
                    assert!(
                        ep.predicted_bubble_elastic < ep.predicted_bubble_equal,
                        "{}: block emitted without a strict bubble win ({} vs {})",
                        r.scenario.name,
                        ep.predicted_bubble_elastic,
                        ep.predicted_bubble_equal
                    );
                    // The chosen partition must be a valid --partition value
                    // for the scenario's model.
                    crate::runtime::StagePartition::parse(
                        &ep.partition,
                        r.scenario.model.num_layers as usize,
                    )
                    .unwrap();
                    assert!(ep.measured.is_none(), "default run attaches no probe");
                }
                None => {}
            }
        }
        assert!(
            results
                .iter()
                .filter(|r| r.scenario.parallel.pp <= 1)
                .all(|r| r.elastic_pipeline.is_none()),
            "pp=1 scenarios must stay block-free (artifact bytes)"
        );
    }

    #[test]
    fn elastic_blocks_are_deterministic_across_engines() {
        let scenarios: Vec<Scenario> = tiny_scenarios()
            .into_iter()
            .filter(|s| s.parallel.pp > 1)
            .collect();
        assert!(!scenarios.is_empty(), "smoke set must exercise a pp scenario");
        let serial = SweepEngine::serial().run(&scenarios).unwrap();
        let parallel = SweepEngine::with_threads(4).run(&scenarios).unwrap();
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.elastic_pipeline, b.elastic_pipeline, "{}", a.scenario.name);
        }
    }

    #[test]
    fn resumable_run_is_byte_identical_to_uninterrupted() {
        let scenarios = tiny_scenarios();
        let dir = std::env::temp_dir().join("chunkflow_resumable_sweep_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let journal = dir.join("bench.journal");
        let engine = SweepEngine::serial();
        let uninterrupted =
            crate::sweep::output::to_json(&engine.run(&scenarios).unwrap(), None);
        // "Crash" after two scenarios: only those land in the journal.
        let partial = engine.run_resumable(&scenarios[..2], &journal).unwrap();
        assert_eq!(partial.len(), 2);
        // The rerun reuses both journaled entries and finishes the rest;
        // the reassembled document must match the uninterrupted bytes.
        let entries = engine.run_resumable(&scenarios, &journal).unwrap();
        let doc = crate::sweep::output::doc_from_scenarios(entries, None);
        assert_eq!(
            doc.pretty(),
            uninterrupted.pretty(),
            "resumed sweep artifact must be byte-identical"
        );
        // A config change (different seed) invalidates the journal entry:
        // its fingerprint no longer matches, so the scenario re-runs
        // instead of reusing a stale result.
        let mut reseeded = scenarios.clone();
        for s in &mut reseeded {
            s.seed += 1;
        }
        let fresh = engine.run_resumable(&reseeded, &journal).unwrap();
        let fresh_doc = crate::sweep::output::doc_from_scenarios(fresh, None);
        assert_eq!(
            fresh_doc.pretty(),
            crate::sweep::output::to_json(&engine.run(&reseeded).unwrap(), None).pretty()
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn map_preserves_order_under_all_policies() {
        let input: Vec<u64> = (0..64).collect();
        for engine in [
            SweepEngine::serial(),
            SweepEngine::with_threads(4),
            SweepEngine::auto(),
        ] {
            let out = engine.map(input.clone(), |x| x * 3);
            assert_eq!(out, input.iter().map(|x| x * 3).collect::<Vec<_>>());
        }
    }
}
