//! # ChunkFlow
//!
//! A full-system reproduction of *"Efficient Long Context Fine-tuning with
//! Chunk Flow"* (ICML 2025) as a three-layer Rust + JAX + Pallas stack:
//!
//! - **Layer 3 (this crate)** — the chunk-centric training coordinator:
//!   chunk construction ([`chunk`], paper Algorithm 1), state-aware chunk
//!   scheduling ([`schedule`], Algorithm 2), the StateStore and its
//!   disk-spilling offload tier ([`state`]), state-aware 1F1B pipeline
//!   scheduling with its discrete-event simulator *and* the stage-parallel
//!   executor that runs the same agendas for real over layer-partitioned
//!   backend stages ([`pipeline`], [`runtime::StageBackend`]), the
//!   analytic memory model ([`memory`]), the
//!   Megatron-LM-like baseline ([`baseline`]), the end-to-end iteration
//!   simulator with chunk-balanced data-parallel sharding and replica-group
//!   execution ([`sim`], [`sim::dp`]), the (ChunkSize, K) tuner ([`tune`]), the parallel
//!   scenario-sweep engine and its `BENCH_chunkflow.json` perf-trajectory
//!   artifact ([`sweep`]), the trainer over pluggable execution backends
//!   ([`runtime`] — the PJRT runtime and the pure-Rust reference backend —
//!   and [`train`]) and the paper-artifact report generators ([`report`]),
//!   plus the static schedule/memory verifier behind `chunkflow check`
//!   ([`verify`]) and the in-tree determinism lint ([`lint`]).
//! - **Layer 2** — `python/compile/model.py`: the chunked transformer
//!   forward/backward in JAX, AOT-lowered to HLO text at build time.
//! - **Layer 1** — `python/compile/kernels/chunk_attn.py`: the chunked
//!   causal flash-attention Pallas kernel with KV-prefix state.
//!
//! Python never runs at training time: `make artifacts` produces
//! `artifacts/*.hlo.txt` + `manifest.json`, and everything here is
//! self-contained Rust over the PJRT C API.

// Paper-notation literals like `1 * K` / `2 * K` mirror the tables verbatim.
#![allow(clippy::identity_op)]

pub mod baseline;
pub mod chunk;
pub mod config;
pub mod data;
pub mod lint;
pub mod memory;
pub mod pipeline;
pub mod report;
pub mod runtime;
pub mod schedule;
pub mod sim;
pub mod state;
pub mod sweep;
pub mod train;
pub mod tune;
pub mod util;
pub mod verify;
